"""Per-cell campaign metrics.

Counting conventions (matching the paper's tables and the fault-injection
literature):

* ``detection_rate``   — detected OR masked, over all faulty trials: a
  fault that provably did not corrupt anything (``corrupted == False``)
  counts as handled, exactly as in benchmarks/ Table II reproduction;
* ``raw_detection_rate`` — flag actually raised, over all faulty trials;
* ``escape_rate``      — corrupted AND undetected (the SDC column);
* ``fp_rate``          — flags on clean runs;
* ``overhead``         — protected/unprotected wall-time ratio minus 1;
* ``ci95``             — Wilson interval on the effective detection rate
  (campaign cells run at modest sample counts; the interval keeps
  cross-PR comparisons honest).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple


def wilson_interval(k: int, n: int, z: float = 1.96) -> Tuple[float, float]:
    """95% Wilson score interval for k successes out of n."""
    if n == 0:
        return (0.0, 1.0)
    p = k / n
    denom = 1.0 + z * z / n
    center = (p + z * z / (2 * n)) / denom
    half = (z / denom) * math.sqrt(p * (1 - p) / n + z * z / (4 * n * n))
    return (max(0.0, center - half), min(1.0, center + half))


@dataclasses.dataclass(frozen=True)
class CellMetrics:
    samples: int
    corrupted: int
    detected: int             # flag raised on faulty trials
    effective_detected: int   # detected | masked (fault didn't corrupt)
    escapes: int              # corrupted & undetected — the SDC count
    clean_samples: int
    false_positives: int
    detection_rate: float
    raw_detection_rate: float
    escape_rate: float
    fp_rate: float
    ci95: Tuple[float, float]
    analytic_bound: Optional[float] = None
    overhead: Optional[float] = None
    protected_s: Optional[float] = None
    unprotected_s: Optional[float] = None
    #: per-phase median wall seconds (quantize/encode/gemm/verify ... —
    #: phase names are target-specific); None when the cell didn't
    #: measure overhead or the target has no phase thunks
    overhead_breakdown: Optional[Dict[str, float]] = None
    # ------- multi-step soak columns (None for single-shot cells) -------
    #: steps per trial the cell actually ran
    steps: Optional[int] = None
    #: hist[t] = trials whose FIRST detection fired t steps after the
    #: upset — the per-step detection-latency histogram; undetected trials
    #: are not in the histogram (they are the escape/masked columns)
    detection_latency_hist: Optional[List[int]] = None
    #: mean of the histogram above (None when nothing was detected)
    mean_detection_latency: Optional[float] = None
    #: relative L2 parameter divergence from the clean twin run, over
    #: faulty trials (the training ground truth: how far did it drift)
    divergence_mean: Optional[float] = None
    divergence_max: Optional[float] = None
    #: max |loss_faulty - loss_clean| over the soak, averaged over trials
    loss_divergence_mean: Optional[float] = None
    # ------- multi-device soak columns (None for non-soak cells) --------
    #: data shards the cell actually executed under (may be lower than
    #: ``plan.data_shards`` when the host had fewer devices)
    shards: Optional[int] = None
    #: True iff ``checked_psum`` ran through a real shard_map collective
    #: at the PLANNED shard count (``shards == plan.data_shards > 1``) —
    #: the column that says the detection claim covers the distributed
    #: reduction the cell id promises; any degradation (partial or to
    #: the ``axis_name=None`` fallback) records False, with ``shards``
    #: holding what actually ran
    collective_verified: Optional[bool] = None
    #: shard_detections[s] = faulty trials whose receive-side payload
    #: verify fired on shard s (the per-shard FaultReport merge) —
    #: attribution telemetry; the detection verdict itself is the
    #: post-collective additivity check
    shard_detections: Optional[List[int]] = None

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["ci95"] = list(self.ci95)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "CellMetrics":
        d = dict(d)
        d["ci95"] = tuple(d["ci95"])
        return cls(**d)


def compute_metrics(*, samples: int, detected: int, corrupted: int,
                    detected_and_corrupted: int, clean_samples: int,
                    false_positives: int,
                    analytic_bound: Optional[float] = None,
                    protected_s: Optional[float] = None,
                    unprotected_s: Optional[float] = None,
                    overhead_breakdown: Optional[Dict[str, float]] = None,
                    steps: Optional[int] = None,
                    detection_latency_hist: Optional[List[int]] = None,
                    divergence_mean: Optional[float] = None,
                    divergence_max: Optional[float] = None,
                    loss_divergence_mean: Optional[float] = None,
                    shards: Optional[int] = None,
                    collective_verified: Optional[bool] = None,
                    shard_detections: Optional[List[int]] = None
                    ) -> CellMetrics:
    # |detected ∪ masked| = samples - |corrupted ∩ undetected|
    escapes = corrupted - detected_and_corrupted
    effective = samples - escapes
    overhead = None
    if protected_s is not None and unprotected_s and unprotected_s > 0:
        overhead = protected_s / unprotected_s - 1.0
    mean_latency = None
    if detection_latency_hist is not None:
        n_det = sum(detection_latency_hist)
        if n_det:
            mean_latency = sum(t * c for t, c in
                               enumerate(detection_latency_hist)) / n_det
    return CellMetrics(
        samples=samples,
        corrupted=corrupted,
        detected=detected,
        effective_detected=effective,
        escapes=escapes,
        clean_samples=clean_samples,
        false_positives=false_positives,
        detection_rate=effective / samples if samples else 0.0,
        raw_detection_rate=detected / samples if samples else 0.0,
        escape_rate=escapes / samples if samples else 0.0,
        fp_rate=(false_positives / clean_samples) if clean_samples else 0.0,
        ci95=wilson_interval(effective, samples),
        analytic_bound=analytic_bound,
        overhead=overhead,
        protected_s=protected_s,
        unprotected_s=unprotected_s,
        overhead_breakdown=overhead_breakdown,
        steps=steps,
        detection_latency_hist=detection_latency_hist,
        mean_detection_latency=mean_latency,
        divergence_mean=divergence_mean,
        divergence_max=divergence_max,
        loss_divergence_mean=loss_divergence_mean,
        shards=shards,
        collective_verified=collective_verified,
        shard_detections=shard_detections,
    )


def merge_shard_detections(per_trial) -> List[int]:
    """Fold per-trial, per-shard detection flags into per-shard counts.

    ``per_trial`` is an iterable of length-S bool/int vectors (one per
    faulty trial: did shard s's receive-side verify fire).  The fold is
    the same monoid FaultReport counters use — elementwise sum, never a
    reset — so a sharded cell's artifact column reads as one merged
    report across the whole soak."""
    totals: Optional[List[int]] = None
    for flags in per_trial:
        vals = [int(v) for v in flags]
        if totals is None:
            totals = vals
        elif len(vals) != len(totals):
            raise ValueError(
                f"shard count changed mid-merge: {len(totals)} != "
                f"{len(vals)}")
        else:
            totals = [a + b for a, b in zip(totals, vals)]
    return totals or []
