"""Resilience-campaign subsystem: declarative fault-injection sweeps.

One engine owns every detection experiment: a :class:`CampaignSpec` names
a grid over (injectable target × fault model × bit band × shape × dtype ×
samples); the executor vmaps thousands of trials per cell (pmap across
host devices); artifacts land as ``BENCH_campaign_*.json`` + markdown so
resilience results persist and compare across PRs.

    python -m repro.campaign --quick
    python -m repro.campaign --grid paper --seed 7 --device-count 8

Library use::

    from repro.campaign import CampaignSpec, run_campaign
    spec = CampaignSpec(name="my-sweep", targets=("gemm_packed",),
                        bit_bands=("significant",), samples=1000)
    result = run_campaign("my-sweep", [spec], out_dir=".")
"""
from repro.campaign.artifacts import (cell_metrics, find_cells,
                                      latency_markdown, load_artifact,
                                      markdown_table, threshold_curve,
                                      threshold_curve_markdown,
                                      write_artifacts)
from repro.campaign.diff import diff_artifacts, format_diff, run_diff
from repro.campaign.executor import (CellResult, resolve_device_count,
                                     run_campaign, run_cell, run_specs)
from repro.campaign.metrics import (CellMetrics, compute_metrics,
                                    merge_shard_detections,
                                    wilson_interval)
from repro.campaign.spec import (CampaignSpec, CellPlan, DLRM_GEMM_SHAPES,
                                 cell_seed, expand)
from repro.campaign.targets import (InjectableTarget, TARGETS, apply_fault,
                                    get_target, register_target)

__all__ = [
    "CampaignSpec", "CellPlan", "expand", "cell_seed", "DLRM_GEMM_SHAPES",
    "InjectableTarget", "TARGETS", "register_target", "get_target",
    "apply_fault",
    "CellMetrics", "compute_metrics", "wilson_interval",
    "merge_shard_detections",
    "CellResult", "run_cell", "run_specs", "run_campaign",
    "resolve_device_count",
    "load_artifact", "write_artifacts", "markdown_table", "cell_metrics",
    "find_cells", "latency_markdown", "threshold_curve",
    "threshold_curve_markdown",
    "diff_artifacts", "format_diff", "run_diff",
]
