"""Campaign artifacts: ``BENCH_campaign_<name>.json`` + markdown summary.

The JSON artifact is the cross-PR comparison record: it embeds the specs,
every cell's plan + metrics, the skipped-cell log, and environment
metadata.  ``load_artifact`` round-trips it (tests assert spec/metrics
equality), and ``markdown_table`` renders the human summary the CLI prints
and CI uploads.
"""
from __future__ import annotations

import json
import os
import platform
from typing import List, Optional

from repro.campaign.metrics import CellMetrics
from repro.campaign.spec import CampaignSpec, CellPlan

SCHEMA_VERSION = 1


def environment_info() -> dict:
    import jax
    return {
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "python": platform.python_version(),
        "platform": platform.platform(),
    }


def campaign_to_dict(name: str, specs: List[CampaignSpec],
                     cells: List[dict], skipped: List[dict],
                     wall_s: float, seed: int) -> dict:
    """``cells`` entries: {"plan": CellPlan, "metrics": CellMetrics,
    "seconds": float}."""
    return {
        "schema": SCHEMA_VERSION,
        "campaign": name,
        "seed": seed,
        "env": environment_info(),
        "wall_seconds": wall_s,
        "specs": [s.to_dict() for s in specs],
        "skipped": skipped,
        "cells": [{
            "cell_id": c["plan"].cell_id,
            "plan": c["plan"].to_dict(),
            "metrics": c["metrics"].to_dict(),
            "seconds": c["seconds"],
        } for c in cells],
    }


def write_artifacts(result: dict, out_dir: str = ".") -> tuple:
    """Write JSON + markdown; returns (json_path, md_path).

    Filenames are deterministic per campaign name so CI artifact diffs and
    cross-PR comparisons line up run-over-run.
    """
    os.makedirs(out_dir, exist_ok=True)
    base = f"BENCH_campaign_{result['campaign']}"
    json_path = os.path.join(out_dir, base + ".json")
    md_path = os.path.join(out_dir, base + ".md")
    with open(json_path, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
        f.write("\n")
    with open(md_path, "w") as f:
        f.write(markdown_table(result))
        lat = latency_markdown(result)
        if lat:
            f.write("\n" + lat)
        bd = breakdown_markdown(result)
        if bd:
            f.write("\n" + bd)
    return json_path, md_path


def load_artifact(path: str) -> dict:
    with open(path) as f:
        result = json.load(f)
    if result.get("schema") != SCHEMA_VERSION:
        raise ValueError(
            f"artifact schema {result.get('schema')} != {SCHEMA_VERSION}")
    return result


def cell_metrics(result: dict, cell_id: str) -> CellMetrics:
    for c in result["cells"]:
        if c["cell_id"] == cell_id:
            return CellMetrics.from_dict(c["metrics"])
    raise KeyError(f"no cell {cell_id!r} in artifact "
                   f"{result.get('campaign')!r}")


def find_cells(result: dict, **field_values) -> List[dict]:
    """Filter cells by plan fields, e.g. ``target="gemm_packed",
    fault_model="bitflip"``."""
    out = []
    for c in result["cells"]:
        if all(c["plan"].get(k) == v for k, v in field_values.items()):
            out.append(c)
    return out


def _fmt_pct(x: Optional[float]) -> str:
    return "—" if x is None else f"{100.0 * x:.2f}%"


def markdown_table(result: dict) -> str:
    lines = [
        f"# Resilience campaign `{result['campaign']}`",
        "",
        f"seed {result['seed']} · {result['env']['backend']} "
        f"×{result['env']['device_count']} · jax {result['env']['jax']} · "
        f"{result['wall_seconds']:.1f}s wall",
        "",
        "| cell | n | detect | escape | FP | bound | overhead |",
        "|---|---|---|---|---|---|---|",
    ]
    for c in result["cells"]:
        m = c["metrics"]
        lines.append(
            "| `{cid}` | {n} | {det} | {esc} | {fp} | {bound} | {ov} |"
            .format(
                cid=c["cell_id"], n=m["samples"],
                det=_fmt_pct(m["detection_rate"]),
                esc=_fmt_pct(m["escape_rate"]),
                fp=_fmt_pct(m["fp_rate"]),
                bound=_fmt_pct(m.get("analytic_bound")),
                ov=_fmt_pct(m.get("overhead"))))
    if result.get("skipped"):
        lines += ["", f"Skipped cells: {len(result['skipped'])}", ""]
        for s in result["skipped"]:
            lines.append(f"- `{s['cell_id']}`: {s['reason']}")
    lines.append("")
    return "\n".join(lines)


def latency_markdown(result: dict) -> str:
    """Detection-latency + divergence summary for soak-protocol cells.

    Renders every cell that carries the soak columns (``steps`` /
    ``detection_latency_hist``), including ``steps=1`` cells — their
    divergence ground truth has no other home in the tables; cells from
    single-shot (``trial``) targets are omitted.  The histogram column
    reads ``t0:n0 t1:n1 ...`` — n trials first detected t steps after
    the upset.  The shards column is ``N✓`` when the cell's
    ``checked_psum`` ran through a real shard_map collective
    (``collective_verified``) with per-shard receive-side attribution in
    brackets, plain ``1`` for the single-device fallback."""
    lines = ["# Soak cells: detection latency & divergence", "",
             "| cell | steps | shards | latency hist | mean lat |"
             " div (mean/max) | loss div |",
             "|---|---|---|---|---|---|---|"]
    found = False
    for c in result["cells"]:
        m = c["metrics"]
        if m.get("steps") is None:
            continue
        found = True
        hist = m.get("detection_latency_hist") or []
        hist_s = " ".join(f"{t}:{n}" for t, n in enumerate(hist) if n) \
            or "—"
        lat = m.get("mean_detection_latency")
        shards_s = "—" if m.get("shards") is None else str(m["shards"])
        if m.get("collective_verified"):
            shards_s += "✓"
            if m.get("shard_detections"):
                shards_s += " [{}]".format(
                    " ".join(str(n) for n in m["shard_detections"]))
        lines.append(
            "| `{cid}` | {steps} | {sh} | {hist} | {lat} | "
            "{dm:.2e}/{dx:.2e} | {ld:.2e} |".format(
                cid=c["cell_id"], steps=m["steps"], sh=shards_s,
                hist=hist_s,
                lat="—" if lat is None else f"{lat:.2f}",
                dm=m.get("divergence_mean") or 0.0,
                dx=m.get("divergence_max") or 0.0,
                ld=m.get("loss_divergence_mean") or 0.0))
    if not found:
        return ""
    lines.append("")
    return "\n".join(lines)


def breakdown_markdown(result: dict) -> str:
    """Per-phase overhead accounting for cells that measured it.

    One row per cell carrying ``overhead_breakdown``; phase columns are
    the union over cells (targets expose different phase names —
    quantize/encode/gemm/verify/...), each cell showing median wall ms
    and the phase's share of that cell's phase total.  Empty string when
    no cell measured a breakdown (the table only appears on
    overhead-measuring grids)."""
    rows = [(c["cell_id"], c["metrics"]["overhead_breakdown"])
            for c in result["cells"]
            if c["metrics"].get("overhead_breakdown")]
    if not rows:
        return ""
    phases: List[str] = []
    for _, bd in rows:
        for name in bd:
            if name not in phases:
                phases.append(name)
    lines = ["# Protection overhead breakdown (median ms / share)", "",
             "| cell | " + " | ".join(phases) + " |",
             "|---|" + "---|" * len(phases)]
    for cid, bd in rows:
        total = sum(bd.values()) or 1.0
        cols = []
        for name in phases:
            v = bd.get(name)
            cols.append("—" if v is None else
                        f"{1e3 * v:.3f} ({100.0 * v / total:.0f}%)")
        lines.append(f"| `{cid}` | " + " | ".join(cols) + " |")
    lines.append("")
    return "\n".join(lines)


def threshold_curve(result: dict, target: str = "embedding_bag") -> dict:
    """Detection-vs-FP tradeoff per bit band from a rel_bound sweep.

    Returns ``{band: [(rel_bound, detection_rate, fp_rate), ...]}`` sorted
    by bound — the curve the ``thresholds`` grid exists to produce."""
    curves: dict = {}
    for c in result["cells"]:
        if c["plan"].get("target") != target:
            continue
        rb = c["plan"].get("rel_bound")
        if rb is None:
            continue
        m = c["metrics"]
        curves.setdefault(c["plan"]["bit_band"], []).append(
            (rb, m["detection_rate"], m["fp_rate"]))
    return {band: sorted(pts) for band, pts in curves.items()}


def threshold_curve_markdown(result: dict,
                             target: str = "embedding_bag") -> str:
    curves = threshold_curve(result, target)
    lines = [f"# EB rel_bound tradeoff curves (`{target}`)", ""]
    for band, pts in sorted(curves.items()):
        lines += [f"## band `{band}`", "",
                  "| rel_bound | detection | false positives |",
                  "|---|---|---|"]
        for rb, det, fp in pts:
            lines.append(f"| {rb:g} | {_fmt_pct(det)} | {_fmt_pct(fp)} |")
        lines.append("")
    return "\n".join(lines)


__all__ = ["campaign_to_dict", "write_artifacts", "load_artifact",
           "cell_metrics", "find_cells", "markdown_table",
           "latency_markdown", "breakdown_markdown", "threshold_curve",
           "threshold_curve_markdown", "environment_info",
           "SCHEMA_VERSION", "CellPlan"]
