"""Longitudinal detection-quality trend gate.

``--diff`` compares exactly two artifacts; this module folds an ordered
series — the committed ``benchmarks/baselines/BENCH_campaign_*.json``
plus a fresh run — into a per-cell history, renders the markdown history
table, and gates the NEWEST entry of each cell against the median of its
prior entries:

* detection rate below the prior median by more than ``det_tol``;
* false-positive rate above the prior median by more than ``fp_tol``;
* (opt-in, wall-clock noise) overhead above the prior median by more
  than ``latency_tol``;
* a cell present in a campaign's previous artifact but missing from its
  newest one (coverage loss).

The median reference is what makes this the *longitudinal* counterpart
of ``--diff``: one noisy historical entry cannot move the gate the way
it would move a pairwise comparison.  Cells with a single entry are
listed but not gated.

    python -m repro.campaign --trend                      # baselines only
    python -m repro.campaign --trend BASE1.json ... NEW.json
"""
from __future__ import annotations

import glob
import os
import statistics
from typing import Dict, List, Optional, Sequence, Tuple

from repro.campaign.artifacts import load_artifact

#: where the committed longitudinal baselines live (repo-relative)
DEFAULT_BASELINE_GLOB = os.path.join("benchmarks", "baselines",
                                     "BENCH_campaign_*.json")


def default_baseline_paths(root: str = ".") -> List[str]:
    return sorted(glob.glob(os.path.join(root, DEFAULT_BASELINE_GLOB)))


def load_history(paths: Sequence[str]) -> Dict[str, List[Tuple[str, dict]]]:
    """paths (oldest -> newest) -> {campaign: [(label, cells_by_id), ...]}.

    Two artifacts of the same campaign name are two chronological
    versions; different campaigns gate independently (their cell ids
    never compare against each other even if they collide)."""
    campaigns: Dict[str, List[Tuple[str, dict]]] = {}
    for path in paths:
        art = load_artifact(path)
        cells = {c["cell_id"]: c["metrics"] for c in art["cells"]}
        campaigns.setdefault(art["campaign"], []).append(
            (os.path.basename(path), cells))
    return campaigns


def _cell_order(versions: List[Tuple[str, dict]]) -> List[str]:
    seen: Dict[str, None] = {}
    for _, cells in versions:
        for cid in cells:
            seen.setdefault(cid)
    return list(seen)


def trend_gate(history: Dict[str, List[Tuple[str, dict]]], *,
               det_tol: float = 0.02, fp_tol: float = 0.02,
               latency_tol: Optional[float] = None) -> dict:
    """Gate each cell's newest entry against the median of its priors."""
    regressions: List[dict] = []
    improvements: List[dict] = []
    gated = single = 0
    for campaign, versions in history.items():
        if len(versions) < 2:
            single += sum(1 for _ in _cell_order(versions))
            continue
        last_label, last_cells = versions[-1]
        prev_cells = versions[-2][1]
        for cid in _cell_order(versions):
            entries = [cells[cid] for _, cells in versions
                       if cid in cells]
            if cid not in last_cells:
                if cid in prev_cells:
                    regressions.append({
                        "campaign": campaign, "cell_id": cid,
                        "kind": "coverage",
                        "ref": prev_cells[cid]["detection_rate"],
                        "new": None, "tol": None})
                continue
            if len(entries) < 2:
                single += 1
                continue
            gated += 1
            cur = last_cells[cid]
            priors = entries[:-1]

            def check(kind, tol, sign):
                if tol is None:
                    return
                vals = [m.get(kind) for m in priors]
                vals = [v for v in vals if v is not None]
                if not vals or cur.get(kind) is None:
                    return
                ref = statistics.median(vals)
                delta = sign * (cur[kind] - ref)
                row = {"campaign": campaign, "cell_id": cid,
                       "kind": kind, "ref": ref, "new": cur[kind],
                       "tol": tol}
                if delta < -tol:
                    regressions.append(row)
                elif delta > tol:
                    improvements.append(row)

            check("detection_rate", det_tol, +1)   # drop = regression
            check("fp_rate", fp_tol, -1)           # rise = regression
            check("overhead", latency_tol, -1)     # rise = regression
    return {"regressions": regressions, "improvements": improvements,
            "gated_cells": gated, "ungated_cells": single}


def _fmt(x) -> str:
    return "—" if x is None else f"{100.0 * x:.2f}%"


def format_trend(history: Dict[str, List[Tuple[str, dict]]],
                 report: dict) -> str:
    """The markdown history table + the gate verdict (CI uploads this)."""
    n_arts = sum(len(v) for v in history.values())
    lines = [f"# Detection-quality trend ({n_arts} artifact(s), "
             f"{len(history)} campaign(s))", ""]
    for campaign, versions in history.items():
        labels = [label for label, _ in versions]
        lines += [f"## campaign `{campaign}`", "",
                  "versions (oldest → newest): "
                  + " → ".join(f"`{v}`" for v in labels), "",
                  "| cell | " + " | ".join(
                      f"v{i} det/fp" for i in range(len(labels)))
                  + " | Δdet |",
                  "|---|" + "---|" * (len(labels) + 1)]
        for cid in _cell_order(versions):
            cols = []
            rates = []
            for _, cells in versions:
                m = cells.get(cid)
                if m is None:
                    cols.append("—")
                else:
                    cols.append(f"{_fmt(m['detection_rate'])}/"
                                f"{_fmt(m['fp_rate'])}")
                    rates.append(m["detection_rate"])
            delta = (f"{100.0 * (rates[-1] - rates[0]):+.2f}pp"
                     if len(rates) >= 2 else "—")
            lines.append(f"| `{cid}` | " + " | ".join(cols)
                         + f" | {delta} |")
        lines.append("")
    lines.append(f"{report['gated_cells']} cell(s) gated against their "
                 f"history, {report['ungated_cells']} with a single "
                 f"entry (listed, not gated)")
    if report["regressions"]:
        lines += ["", "## Trend regressions", "",
                  "| campaign | cell | metric | prior median | new |",
                  "|---|---|---|---|---|"]
        for r in report["regressions"]:
            lines.append(f"| {r['campaign']} | `{r['cell_id']}` | "
                         f"{r['kind']} | {_fmt(r['ref'])} | "
                         f"{_fmt(r['new'])} |")
    else:
        lines += ["", "No trend regressions."]
    if report["improvements"]:
        lines += ["", "## Trend improvements", "",
                  "| campaign | cell | metric | prior median | new |",
                  "|---|---|---|---|---|"]
        for r in report["improvements"]:
            lines.append(f"| {r['campaign']} | `{r['cell_id']}` | "
                         f"{r['kind']} | {_fmt(r['ref'])} | "
                         f"{_fmt(r['new'])} |")
    lines.append("")
    return "\n".join(lines)


def run_trend(paths: Sequence[str], *, det_tol: float = 0.02,
              fp_tol: float = 0.02, latency_tol: Optional[float] = None,
              out_path: Optional[str] = None, emit=print) -> int:
    """CLI body: load, gate, print/write markdown; 1 iff regressions."""
    paths = list(paths) or default_baseline_paths()
    if not paths:
        emit("no artifacts found (pass paths or run from the repo root "
             "so the committed baselines glob resolves)")
        return 2
    history = load_history(paths)
    report = trend_gate(history, det_tol=det_tol, fp_tol=fp_tol,
                        latency_tol=latency_tol)
    md = format_trend(history, report)
    emit(md)
    if out_path:
        with open(out_path, "w") as f:
            f.write(md)
    return 1 if report["regressions"] else 0


__all__ = ["load_history", "trend_gate", "format_trend", "run_trend",
           "default_baseline_paths", "DEFAULT_BASELINE_GLOB"]
