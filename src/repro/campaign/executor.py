"""Batched campaign executor.

One cell = thousands of (inject → run → count) trials.  The ad-hoc
benchmark scripts this subsystem replaces ran Python loops per scenario;
here every cell is ONE jitted ``vmap`` over a key batch (chunked to bound
memory), and with multiple host devices the chunks are ``pmap``'d so a
`--device-count 8` sweep runs eight chunks abreast.

The executor is target-agnostic: it only sees the three pure functions a
target registers (build / trial / clean) plus optional overhead thunks it
times with a median-of-iters wall clock.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.campaign.metrics import CellMetrics, compute_metrics
from repro.campaign.spec import CampaignSpec, CellPlan, expand
from repro.campaign.targets import get_target

#: default trials per compiled vmap chunk — bounds per-chunk memory for
#: targets that materialize a corrupted copy of their state per trial.
CHUNK = 256


@dataclasses.dataclass
class CellResult:
    plan: CellPlan
    metrics: CellMetrics
    seconds: float


def _chunked_counts(fn: Callable, keys: jax.Array, chunk: int,
                    n_outputs: int) -> np.ndarray:
    """Run ``fn(key) -> bool tuple`` over all keys; returns summed counts
    [n_outputs] (plus, for 2-output trial fns, the AND of both flags as a
    third count).  Chunks share at most two jit caches (full chunk +
    remainder); multi-device hosts split each chunk across devices with
    pmap(vmap(...)).
    """
    devs = jax.local_devices()
    ndev = len(devs)

    def batch(ks):
        outs = jax.vmap(fn)(ks)
        outs = outs if isinstance(outs, tuple) else (outs,)
        counts = [jnp.sum(o.astype(jnp.int32)) for o in outs]
        if len(outs) == 2:
            counts.append(jnp.sum((outs[0] & outs[1]).astype(jnp.int32)))
        return jnp.stack(counts)

    jbatch = jax.jit(batch)
    pbatch = jax.pmap(batch) if ndev > 1 else None

    total = np.zeros(n_outputs + (1 if n_outputs == 2 else 0), np.int64)
    i, n = 0, keys.shape[0]
    while i < n:
        take = min(chunk * max(ndev, 1), n - i)
        ks = keys[i:i + take]
        if pbatch is not None and take % ndev == 0 and take >= ndev:
            counts = pbatch(ks.reshape((ndev, take // ndev)
                                       + ks.shape[1:])).sum(axis=0)
        else:
            counts = jbatch(ks)
        total += np.asarray(counts, np.int64)
        i += take
    return total


def _chunked_soak(fn: Callable, keys: jax.Array, chunk: int,
                  steps: int) -> dict:
    """Run a soak-protocol target over all keys.

    ``fn(key) -> {"detected_steps": bool [steps], "corrupted": bool,
    "divergence": f32, "loss_divergence": f32}``.  Returns host-side
    aggregates: detection/corruption/escape counts, the per-step
    first-detection latency histogram, and divergence stats.  Multi-device
    hosts split each chunk across devices with pmap(vmap(...)), like
    :func:`_chunked_counts`.
    """
    def batch(ks):
        out = jax.vmap(fn)(ks)
        det_steps = out["detected_steps"].astype(jnp.int32)   # [B, steps]
        detected = jnp.any(det_steps > 0, axis=1)
        corrupted = out["corrupted"]
        first = jnp.argmax(det_steps, axis=1)                 # [B]
        # histogram of first-detection latency, detected trials only
        hist = jnp.sum(
            (first[:, None] == jnp.arange(steps)[None, :])
            & detected[:, None], axis=0).astype(jnp.int32)
        return {
            "detected": jnp.sum(detected.astype(jnp.int32)),
            "corrupted": jnp.sum(corrupted.astype(jnp.int32)),
            "det_and_cor": jnp.sum((detected & corrupted)
                                   .astype(jnp.int32)),
            "hist": hist,
            "div_sum": jnp.sum(out["divergence"]),
            "div_max": jnp.max(out["divergence"]),
            "loss_div_sum": jnp.sum(out["loss_divergence"]),
        }

    ndev = len(jax.local_devices())
    jbatch = jax.jit(batch)
    pbatch = jax.pmap(batch) if ndev > 1 else None

    total = {"detected": 0, "corrupted": 0, "det_and_cor": 0,
             "hist": np.zeros(steps, np.int64), "div_sum": 0.0,
             "div_max": 0.0, "loss_div_sum": 0.0}
    i, n = 0, keys.shape[0]
    while i < n:
        take = min(chunk * max(ndev, 1), n - i)
        ks = keys[i:i + take]
        if pbatch is not None and take % ndev == 0 and take >= ndev:
            out = jax.device_get(pbatch(
                ks.reshape((ndev, take // ndev) + ks.shape[1:])))
            out = {k: (v.max(axis=0) if k == "div_max" else v.sum(axis=0))
                   for k, v in out.items()}
        else:
            out = jax.device_get(jbatch(ks))
        for k in ("detected", "corrupted", "det_and_cor"):
            total[k] += int(out[k])
        total["hist"] += np.asarray(out["hist"], np.int64)
        total["div_sum"] += float(out["div_sum"])
        total["div_max"] = max(total["div_max"], float(out["div_max"]))
        total["loss_div_sum"] += float(out["loss_div_sum"])
        i += take
    return total


def _median_time(fn: Callable) -> float:
    from repro.campaign.timing import median_time
    return median_time(jax.jit(fn))


def run_cell(plan: CellPlan, *, chunk: int = CHUNK) -> CellResult:
    target = get_target(plan.target)
    t0 = time.perf_counter()
    key = jax.random.key(plan.seed)
    k_build, k_trial, k_clean = jax.random.split(key, 3)

    state = target.build(plan, k_build)

    soak_extras: dict = {}
    if target.soak is not None:
        agg = _chunked_soak(
            lambda k: target.soak(state, plan, k),
            jax.random.split(k_trial, plan.samples), chunk, plan.steps)
        detected = agg["detected"]
        corrupted = agg["corrupted"]
        det_and_cor = agg["det_and_cor"]
        soak_extras = {
            "steps": plan.steps,
            "detection_latency_hist": [int(c) for c in agg["hist"]],
            "divergence_mean": agg["div_sum"] / plan.samples,
            "divergence_max": agg["div_max"],
            "loss_divergence_mean": agg["loss_div_sum"] / plan.samples,
        }
    else:
        trial_counts = _chunked_counts(
            lambda k: target.trial(state, plan, k),
            jax.random.split(k_trial, plan.samples), chunk, 2)
        detected, corrupted, det_and_cor = (int(c) for c in trial_counts)

    false_positives = 0
    if plan.clean_samples > 0:
        clean_counts = _chunked_counts(
            lambda k: target.clean(state, plan, k),
            jax.random.split(k_clean, plan.clean_samples), chunk, 1)
        false_positives = int(clean_counts[0])

    protected_s = unprotected_s = None
    if plan.measure_overhead and target.overhead is not None:
        pair = target.overhead(state, plan)
        if pair is not None:
            prot, unprot = pair
            protected_s = _median_time(prot)
            unprotected_s = _median_time(unprot)

    metrics = compute_metrics(
        samples=plan.samples, detected=detected, corrupted=corrupted,
        detected_and_corrupted=det_and_cor,
        clean_samples=plan.clean_samples,
        false_positives=false_positives,
        analytic_bound=target.analytic_bound(plan),
        protected_s=protected_s, unprotected_s=unprotected_s,
        **soak_extras)
    return CellResult(plan=plan, metrics=metrics,
                      seconds=time.perf_counter() - t0)


def run_specs(specs: Sequence[CampaignSpec], *, chunk: int = CHUNK,
              verbose: Optional[Callable[[str], None]] = None
              ) -> Tuple[List[CellResult], List[dict]]:
    """Expand and execute a list of specs; returns (results, skipped)."""
    results: List[CellResult] = []
    skipped: List[dict] = []
    for spec in specs:
        plans, skips = expand(spec)
        skipped.extend(skips)
        for plan in plans:
            r = run_cell(plan, chunk=chunk)
            results.append(r)
            if verbose:
                m = r.metrics
                verbose(f"[{r.plan.cell_id}] n={m.samples} "
                        f"detect={m.detection_rate:.4f} "
                        f"escape={m.escape_rate:.4f} fp={m.fp_rate:.4f} "
                        f"({r.seconds:.1f}s)")
    return results, skipped


def run_campaign(name: str, specs: Sequence[CampaignSpec], *,
                 out_dir: Optional[str] = None, chunk: int = CHUNK,
                 verbose: Optional[Callable[[str], None]] = None) -> dict:
    """Execute specs, assemble the artifact dict, optionally write it."""
    from repro.campaign.artifacts import campaign_to_dict, write_artifacts

    t0 = time.perf_counter()
    results, skipped = run_specs(specs, chunk=chunk, verbose=verbose)
    result = campaign_to_dict(
        name, list(specs),
        [{"plan": r.plan, "metrics": r.metrics, "seconds": r.seconds}
         for r in results],
        skipped, wall_s=time.perf_counter() - t0,
        seed=specs[0].seed if specs else 0)
    if out_dir is not None:
        write_artifacts(result, out_dir)
    return result
