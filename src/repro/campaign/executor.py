"""Batched campaign executor.

One cell = thousands of (inject → run → count) trials.  The ad-hoc
benchmark scripts this subsystem replaces ran Python loops per scenario;
here every cell is ONE jitted ``vmap`` over a key batch (chunked to bound
memory), and with multiple host devices the chunks are ``pmap``'d so a
`--device-count 8` sweep runs eight chunks abreast.

Multi-device is also a CELL axis, not just a trial axis: a plan with
``data_shards`` > 1 gets its own slice of the host mesh
(:func:`repro.sharding.make_data_mesh` over devices forced with
``XLA_FLAGS=--xla_force_host_platform_device_count``) and its soak runs
under shard_map so ``checked_psum`` verifies a real collective.  Cells
are placed round-robin over the disjoint mesh slices — the sweep itself
is sharded, which is what a fleet-scale runner needs for locality.

The executor is target-agnostic: it only sees the three pure functions a
target registers (build / trial / clean) plus optional overhead thunks it
times with a median-of-iters wall clock.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.campaign.metrics import (CellMetrics, compute_metrics,
                                    merge_shard_detections)
from repro.campaign.spec import CampaignSpec, CellPlan, expand
from repro.campaign.targets import get_target

#: default trials per compiled vmap chunk — bounds per-chunk memory for
#: targets that materialize a corrupted copy of their state per trial.
CHUNK = 256


@dataclasses.dataclass
class CellResult:
    plan: CellPlan
    metrics: CellMetrics
    seconds: float


def resolve_device_count(requested: Optional[int] = None) -> int:
    """Validate a requested host-device count against what jax actually
    has.  ``--device-count`` only works when XLA_FLAGS lands before jax
    initializes; when it didn't (library use, jax already imported), the
    old behavior was to trust the caller and die in a pmap reshape —
    now we warn and fall back to ``jax.local_device_count()``."""
    avail = jax.local_device_count()
    if requested and requested > avail:
        warnings.warn(
            f"requested {requested} host devices but only {avail} exist "
            f"(XLA_FLAGS must be set before jax initializes); falling "
            f"back to {avail}", UserWarning, stacklevel=2)
        return avail
    return requested or avail


def _cell_mesh(plan: CellPlan, slot: int = 0):
    """-> (mesh | None, effective_shards) for one cell.

    Sharded cells land on slices of the host platform assigned
    round-robin by ``slot`` (the cell's index among sharded cells): with
    8 devices and 2-shard cells, four cells run on four disjoint slices
    — the sweep is sharded, not just each cell's trials.  (Disjointness
    holds per shard width; a sweep mixing widths can overlap slices,
    harmless while cells execute sequentially — a concurrent fleet
    runner would need a real slice allocator.)  A host with fewer
    devices than ``plan.data_shards`` degrades to what exists (with a
    warning) instead of failing inside Mesh construction."""
    if plan.data_shards <= 1:
        return None, 1
    shards = min(plan.data_shards, jax.local_device_count())
    if shards < plan.data_shards:
        warnings.warn(
            f"cell {plan.cell_id}: data_shards={plan.data_shards} > "
            f"{shards} available host devices; running at {shards} "
            f"shard(s) (collective_verified will record the degradation)",
            UserWarning, stacklevel=2)
    if shards == 1:
        return None, 1
    devs = jax.local_devices()
    n_slices = len(devs) // shards
    start = (slot % n_slices) * shards
    from repro.sharding import make_data_mesh
    return make_data_mesh(shards, devices=devs[start:start + shards]), \
        shards


def _chunked_counts(fn: Callable, keys: jax.Array, chunk: int,
                    n_outputs: int) -> np.ndarray:
    """Run ``fn(key) -> bool tuple`` over all keys; returns summed counts
    [n_outputs] (plus, for 2-output trial fns, the AND of both flags as a
    third count).  Chunks share at most two jit caches (full chunk +
    remainder); multi-device hosts split each chunk across devices with
    pmap(vmap(...)).
    """
    devs = jax.local_devices()
    ndev = len(devs)

    def batch(ks):
        outs = jax.vmap(fn)(ks)
        outs = outs if isinstance(outs, tuple) else (outs,)
        counts = [jnp.sum(o.astype(jnp.int32)) for o in outs]
        if len(outs) == 2:
            counts.append(jnp.sum((outs[0] & outs[1]).astype(jnp.int32)))
        return jnp.stack(counts)

    jbatch = jax.jit(batch)
    pbatch = jax.pmap(batch) if ndev > 1 else None

    total = np.zeros(n_outputs + (1 if n_outputs == 2 else 0), np.int64)
    i, n = 0, keys.shape[0]
    while i < n:
        take = min(chunk * max(ndev, 1), n - i)
        ks = keys[i:i + take]
        if pbatch is not None and take % ndev == 0 and take >= ndev:
            counts = pbatch(ks.reshape((ndev, take // ndev)
                                       + ks.shape[1:])).sum(axis=0)
        else:
            counts = jbatch(ks)
        total += np.asarray(counts, np.int64)
        i += take
    return total


def _chunked_soak(fn: Callable, keys: jax.Array, chunk: int,
                  steps: int) -> dict:
    """Run a soak-protocol target over all keys.

    ``fn(key) -> {"detected_steps": bool [steps], "corrupted": bool,
    "divergence": f32, "loss_divergence": f32}``.  Returns host-side
    aggregates: detection/corruption/escape counts, the per-step
    first-detection latency histogram, and divergence stats.  Multi-device
    hosts split each chunk across devices with pmap(vmap(...)), like
    :func:`_chunked_counts`.
    """
    def batch(ks):
        out = jax.vmap(fn)(ks)
        det_steps = out["detected_steps"].astype(jnp.int32)   # [B, steps]
        detected = jnp.any(det_steps > 0, axis=1)
        corrupted = out["corrupted"]
        first = jnp.argmax(det_steps, axis=1)                 # [B]
        # histogram of first-detection latency, detected trials only
        hist = jnp.sum(
            (first[:, None] == jnp.arange(steps)[None, :])
            & detected[:, None], axis=0).astype(jnp.int32)
        return {
            "detected": jnp.sum(detected.astype(jnp.int32)),
            "corrupted": jnp.sum(corrupted.astype(jnp.int32)),
            "det_and_cor": jnp.sum((detected & corrupted)
                                   .astype(jnp.int32)),
            "hist": hist,
            "div_sum": jnp.sum(out["divergence"]),
            "div_max": jnp.max(out["divergence"]),
            "loss_div_sum": jnp.sum(out["loss_divergence"]),
        }

    ndev = len(jax.local_devices())
    jbatch = jax.jit(batch)
    pbatch = jax.pmap(batch) if ndev > 1 else None

    total = {"detected": 0, "corrupted": 0, "det_and_cor": 0,
             "hist": np.zeros(steps, np.int64), "div_sum": 0.0,
             "div_max": 0.0, "loss_div_sum": 0.0}
    i, n = 0, keys.shape[0]
    while i < n:
        take = min(chunk * max(ndev, 1), n - i)
        ks = keys[i:i + take]
        if pbatch is not None and take % ndev == 0 and take >= ndev:
            out = jax.device_get(pbatch(
                ks.reshape((ndev, take // ndev) + ks.shape[1:])))
            out = {k: (v.max(axis=0) if k == "div_max" else v.sum(axis=0))
                   for k, v in out.items()}
        else:
            out = jax.device_get(jbatch(ks))
        for k in ("detected", "corrupted", "det_and_cor"):
            total[k] += int(out[k])
        total["hist"] += np.asarray(out["hist"], np.int64)
        total["div_sum"] += float(out["div_sum"])
        total["div_max"] = max(total["div_max"], float(out["div_max"]))
        total["loss_div_sum"] += float(out["loss_div_sum"])
        i += take
    return total


def _sharded_soak(fn: Callable, keys: jax.Array, steps: int,
                  shards: int) -> dict:
    """Run a soak-protocol target whose trial executes under a shard_map
    mesh.  Trials run one jitted call at a time (a sharded trial already
    occupies its whole mesh slice; vmapping over shard_map would fuse
    trial and mesh batching) and the per-shard ``shard_detected`` flags
    are folded with :func:`merge_shard_detections` — same aggregates as
    :func:`_chunked_soak` plus the per-shard column.
    """
    jfn = jax.jit(fn)
    total = {"detected": 0, "corrupted": 0, "det_and_cor": 0,
             "hist": np.zeros(steps, np.int64), "div_sum": 0.0,
             "div_max": 0.0, "loss_div_sum": 0.0}
    per_trial_shards: List[np.ndarray] = []
    for i in range(keys.shape[0]):
        out = jax.device_get(jfn(keys[i]))
        det_steps = np.asarray(out["detected_steps"], bool)
        detected = bool(det_steps.any())
        corrupted = bool(out["corrupted"])
        total["detected"] += detected
        total["corrupted"] += corrupted
        total["det_and_cor"] += detected and corrupted
        if detected:
            total["hist"][int(np.argmax(det_steps))] += 1
        total["div_sum"] += float(out["divergence"])
        total["div_max"] = max(total["div_max"],
                               float(out["divergence"]))
        total["loss_div_sum"] += float(out["loss_divergence"])
        per_trial_shards.append(
            np.asarray(out["shard_detected"], np.int64))
    total["shard_detections"] = merge_shard_detections(per_trial_shards) \
        or [0] * shards
    return total


def _median_time(fn: Callable) -> float:
    from repro.campaign.timing import median_time
    return median_time(jax.jit(fn))


class _NullSpan:
    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


def _cell_span(obs, name: str, plan: CellPlan):
    """A tracer span for one cell phase, or a no-op without obs."""
    if obs is None:
        return _NullSpan()
    return obs.tracer.span(name, cat="campaign", cell=plan.cell_id)


def _publish_cell(obs, plan: CellPlan, metrics: CellMetrics) -> None:
    """Land one finished cell in the obs layer: outcome counters labeled
    by cell id (the Prometheus face of the artifact's CellMetrics) and a
    summary ``cell`` event carrying detector value vs analytic bound."""
    if obs is None:
        return
    from repro.obs import FaultEvent
    reg = obs.registry
    labels = {"cell": plan.cell_id}
    reg.counter("repro_injections_total",
                "injected faults per cell").inc(metrics.samples, **labels)
    reg.counter("repro_detections_total",
                "detected (or masked) faults per cell").inc(
                    metrics.effective_detected, **labels)
    reg.counter("repro_escapes_total",
                "undetected corruptions (SDC) per cell").inc(
                    metrics.escapes, **labels)
    reg.counter("repro_false_positives_total",
                "clean-run flags per cell").inc(
                    metrics.false_positives, **labels)
    obs.bus.emit(FaultEvent(
        op=plan.target, step=0, source="campaign.executor", kind="cell",
        t_s=obs.tracer.now_s(), errors=metrics.detected,
        checks=metrics.samples, cell_id=plan.cell_id,
        bit_band=plan.bit_band,
        detector_value=metrics.detection_rate,
        bound=metrics.analytic_bound,
        attrs={"escapes": metrics.escapes,
               "false_positives": metrics.false_positives,
               "fp_rate": metrics.fp_rate,
               # what the {cell} detections counter was inc'd with —
               # replay reads it so round-trip stays counter-exact
               # (detected alone misses masked-by-recompute trials)
               "effective_detected": metrics.effective_detected,
               "clean_samples": metrics.clean_samples}))
    if metrics.false_positives:
        obs.bus.emit(FaultEvent(
            op=plan.target, step=0, source="campaign.executor",
            kind="false_positive", t_s=obs.tracer.now_s(),
            errors=metrics.false_positives,
            checks=metrics.clean_samples, cell_id=plan.cell_id,
            bit_band=plan.bit_band))


def run_cell(plan: CellPlan, *, chunk: int = CHUNK,
             slot: int = 0, obs=None, monitor=None) -> CellResult:
    if monitor is not None and obs is not None:
        monitor.bind(obs)          # cell events tick the health machine
    target = get_target(plan.target)
    t0 = time.perf_counter()
    key = jax.random.key(plan.seed)
    k_build, k_trial, k_clean = jax.random.split(key, 3)

    mesh, eff_shards = (_cell_mesh(plan, slot) if target.shardable
                        else (None, 1))
    with _cell_span(obs, "build", plan):
        if target.shardable:
            state = target.build(plan, k_build, mesh=mesh)
        else:
            state = target.build(plan, k_build)

    soak_extras: dict = {}
    if target.soak is not None:
        trial_keys = jax.random.split(k_trial, plan.samples)
        with _cell_span(obs, "trials", plan):
            if mesh is not None:
                agg = _sharded_soak(
                    lambda k: target.soak(state, plan, k),
                    trial_keys, plan.steps, eff_shards)
            else:
                agg = _chunked_soak(
                    lambda k: target.soak(state, plan, k),
                    trial_keys, chunk, plan.steps)
        detected = agg["detected"]
        corrupted = agg["corrupted"]
        det_and_cor = agg["det_and_cor"]
        soak_extras = {
            "steps": plan.steps,
            "detection_latency_hist": [int(c) for c in agg["hist"]],
            "divergence_mean": agg["div_sum"] / plan.samples,
            "divergence_max": agg["div_max"],
            "loss_divergence_mean": agg["loss_div_sum"] / plan.samples,
            "shards": eff_shards,
            # True only when the PLANNED multi-device collective ran: a
            # cell degraded to fewer shards (or to the single-device
            # fallback) must not read as mesh-verified even though a
            # smaller real collective may have executed — `shards` says
            # what actually ran
            "collective_verified": (eff_shards > 1
                                    and eff_shards == plan.data_shards),
            "shard_detections": agg.get("shard_detections"),
        }
    else:
        with _cell_span(obs, "trials", plan):
            trial_counts = _chunked_counts(
                lambda k: target.trial(state, plan, k),
                jax.random.split(k_trial, plan.samples), chunk, 2)
        detected, corrupted, det_and_cor = (int(c) for c in trial_counts)

    false_positives = 0
    if plan.clean_samples > 0:
        with _cell_span(obs, "clean", plan):
            clean_counts = _chunked_counts(
                lambda k: target.clean(state, plan, k),
                jax.random.split(k_clean, plan.clean_samples), chunk, 1)
        false_positives = int(clean_counts[0])

    protected_s = unprotected_s = None
    overhead_breakdown = None
    if plan.measure_overhead and target.overhead is not None:
        pair = target.overhead(state, plan)
        if pair is not None:
            prot, unprot = pair
            with _cell_span(obs, "overhead", plan):
                protected_s = _median_time(prot)
                unprotected_s = _median_time(unprot)
    if plan.measure_overhead and target.overhead_phases is not None:
        from repro.campaign.timing import phase_breakdown
        phases = target.overhead_phases(state, plan)
        if phases:
            overhead_breakdown = phase_breakdown(
                phases, tracer=obs.tracer if obs is not None else None,
                cell=plan.cell_id)

    metrics = compute_metrics(
        samples=plan.samples, detected=detected, corrupted=corrupted,
        detected_and_corrupted=det_and_cor,
        clean_samples=plan.clean_samples,
        false_positives=false_positives,
        analytic_bound=target.analytic_bound(plan),
        protected_s=protected_s, unprotected_s=unprotected_s,
        overhead_breakdown=overhead_breakdown,
        **soak_extras)
    _publish_cell(obs, plan, metrics)
    return CellResult(plan=plan, metrics=metrics,
                      seconds=time.perf_counter() - t0)


def run_specs(specs: Sequence[CampaignSpec], *, chunk: int = CHUNK,
              verbose: Optional[Callable[[str], None]] = None,
              obs=None, monitor=None
              ) -> Tuple[List[CellResult], List[dict]]:
    """Expand and execute a list of specs; returns (results, skipped)."""
    results: List[CellResult] = []
    skipped: List[dict] = []
    n_sharded = 0
    for spec in specs:
        plans, skips = expand(spec)
        skipped.extend(skips)
        for plan in plans:
            # sharded cells take successive mesh slices (round-robin)
            slot = n_sharded
            if plan.data_shards > 1:
                n_sharded += 1
            r = run_cell(plan, chunk=chunk, slot=slot, obs=obs,
                         monitor=monitor)
            results.append(r)
            if verbose:
                m = r.metrics
                verbose(f"[{r.plan.cell_id}] n={m.samples} "
                        f"detect={m.detection_rate:.4f} "
                        f"escape={m.escape_rate:.4f} fp={m.fp_rate:.4f} "
                        f"({r.seconds:.1f}s)")
    return results, skipped


def run_campaign(name: str, specs: Sequence[CampaignSpec], *,
                 out_dir: Optional[str] = None, chunk: int = CHUNK,
                 verbose: Optional[Callable[[str], None]] = None,
                 obs=None, monitor=None) -> dict:
    """Execute specs, assemble the artifact dict, optionally write it.
    ``obs`` (a :class:`repro.obs.Observability`) records per-phase spans,
    cell summary events, and outcome counters alongside the artifact;
    ``monitor`` (a :class:`repro.obs.Monitor`) additionally watches the
    published cell outcomes and drives per-cell health states."""
    from repro.campaign.artifacts import campaign_to_dict, write_artifacts

    t0 = time.perf_counter()
    results, skipped = run_specs(specs, chunk=chunk, verbose=verbose,
                                 obs=obs, monitor=monitor)
    result = campaign_to_dict(
        name, list(specs),
        [{"plan": r.plan, "metrics": r.metrics, "seconds": r.seconds}
         for r in results],
        skipped, wall_s=time.perf_counter() - t0,
        seed=specs[0].seed if specs else 0)
    if out_dir is not None:
        write_artifacts(result, out_dir)
    return result
