"""Shared wall-clock timing helper (campaign overhead cells and the
benchmarks/ overhead tables use the same methodology)."""
from __future__ import annotations

import time
from typing import Callable

import jax
import numpy as np


def median_time(fn: Callable, *args, warmup: int = 2, iters: int = 10,
                min_time_s: float = 0.2) -> float:
    """Median wall seconds per call (blocks on outputs).

    ``fn`` should already be jitted (or cheap to trace); timing covers
    dispatch + execution, which is what an inference server pays.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times, total = [], 0.0
    while total < min_time_s or len(times) < iters:
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        dt = time.perf_counter() - t0
        times.append(dt)
        total += dt
        if len(times) >= 100:
            break
    return float(np.median(times))
