"""Shared wall-clock timing helpers (campaign overhead cells and the
benchmarks/ overhead tables use the same methodology).

:func:`median_time` is the protected/unprotected pair's clock;
:func:`phase_breakdown` times a dict of named phase thunks (quantize /
encode / gemm / verify ...) with the same methodology, optionally
landing each phase as an accounting span on a
:class:`repro.obs.Tracer` — the source of the artifact's
``overhead_breakdown`` column."""
from __future__ import annotations

import time
from typing import Callable, Dict, Mapping

import jax
import numpy as np


def median_time(fn: Callable, *args, warmup: int = 2, iters: int = 10,
                min_time_s: float = 0.2) -> float:
    """Median wall seconds per call (blocks on outputs).

    ``fn`` should already be jitted (or cheap to trace); timing covers
    dispatch + execution, which is what an inference server pays.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times, total = [], 0.0
    while total < min_time_s or len(times) < iters:
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        dt = time.perf_counter() - t0
        times.append(dt)
        total += dt
        if len(times) >= 100:
            break
    return float(np.median(times))


def phase_breakdown(phases: Mapping[str, Callable], *,
                    tracer=None, warmup: int = 2, iters: int = 5,
                    min_time_s: float = 0.05,
                    **span_args) -> Dict[str, float]:
    """Median wall seconds per named phase thunk, in mapping order.

    Each thunk is jitted and timed like :func:`median_time` (shorter
    defaults — the breakdown is a per-cell column, not the headline
    overhead number).  With a ``tracer``, each phase also lands as an
    accounting span (cat ``"overhead"``, duration = the median) so the
    breakdown shows up in the exported trace next to the cell's
    build/trials spans."""
    out: Dict[str, float] = {}
    for name, fn in phases.items():
        t0 = tracer.now_s() if tracer is not None else 0.0
        out[name] = median_time(jax.jit(fn), warmup=warmup, iters=iters,
                                min_time_s=min_time_s)
        if tracer is not None:
            tracer.add_span(f"phase:{name}", cat="overhead", start_s=t0,
                            dur_s=out[name], **span_args)
    return out
