"""Declarative resilience-campaign specs.

A :class:`CampaignSpec` names a grid — (injectable target × fault model ×
bit band × shape × dtype × sample count) — and :func:`expand` turns it into
concrete :class:`CellPlan` s, one per grid cell, filtering combinations a
target cannot realize (wrong shape arity, unsupported dtype/band/model)
and recording why each was skipped so sweeps never silently shrink.

Specs are plain frozen dataclasses: serializable to JSON (artifacts embed
them), hashable, and cheap to build programmatically (benchmarks build them
per paper table; users build them in examples/).
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
from typing import List, Optional, Sequence, Tuple

from repro.core.inject import bit_band as inject_bit_band

# ---------------------------------------------------------------------------
# The paper's Fig. 5 evaluates 28 DLRM GEMM shapes (m, n, k) — "peculiar
# matrix sizes": small m (batch), large n/k (layer widths), reconstructed
# from the DLRM bottom (13-512-256-128) and top (479-1024-1024-512-256-1)
# MLPs, the paper's quoted (1, 800, 3200) point, and FBGEMM benchmark
# shapes.  Canonical home of the set; benchmarks/ imports it from here.
# ---------------------------------------------------------------------------
DLRM_GEMM_SHAPES: List[Tuple[int, int, int]] = [
    # bottom MLP, batch 1..256
    (1, 512, 13), (1, 256, 512), (1, 128, 256),
    (20, 512, 13), (20, 256, 512), (20, 128, 256),
    (100, 512, 13), (100, 256, 512), (100, 128, 256),
    (256, 512, 13), (256, 256, 512), (256, 128, 256),
    # top MLP, batch 1..256
    (1, 1024, 479), (1, 1024, 1024), (1, 512, 1024), (1, 256, 512),
    (20, 1024, 479), (20, 1024, 1024), (20, 512, 1024),
    (100, 1024, 479), (100, 1024, 1024), (100, 512, 1024),
    (256, 1024, 479), (256, 1024, 1024),
    # wide serving projections (paper's fast case (1, 800, 3200) included)
    (1, 800, 3200), (10, 800, 3200), (64, 800, 3200), (100, 800, 3200),
]
assert len(DLRM_GEMM_SHAPES) == 28


@dataclasses.dataclass(frozen=True)
class CampaignSpec:
    """One declarative sweep.

    ``shapes=()`` means "each target's default shapes".  When explicit
    shapes are given they must match a target's arity (gemm: (m, n, k);
    embedding_bag: (rows, dim, bags, pool); kv_cache: (b, kv_heads, s, dh);
    decode_step: (batch, prompt_len)) — mismatches are skipped, not errors,
    so one spec can sweep heterogeneous targets with per-target shapes.
    """
    name: str
    targets: Tuple[str, ...]
    fault_models: Tuple[str, ...] = ("bitflip",)
    bit_bands: Tuple[str, ...] = ("all",)
    shapes: Tuple[Tuple[int, ...], ...] = ()
    dtypes: Tuple[str, ...] = ("int8",)
    samples: int = 100
    clean_samples: Optional[int] = None   # None -> same as samples
    flips_per_trial: int = 1
    seed: int = 0
    measure_overhead: bool = False
    #: detection-threshold sweep (thresholded targets only, e.g. the EB
    #: rel_bound): () = each target's default bound
    rel_bounds: Tuple[float, ...] = ()
    #: injection-victim sweep (victim-selectable targets only, e.g. the
    #: decode soak): leaf-path patterns in the protect-plan vocabulary
    #: (``attn.wq``, ``mlp.down``, ``embed.table``, ...); () = each
    #: target's default victim (largest int8 leaf)
    victims: Tuple[str, ...] = ()
    #: multi-step soak depth (soak-capable targets only): each trial runs
    #: ``steps`` consecutive train/decode steps and reports per-step
    #: detection so a single upset's latency is measured, not just its
    #: eventual fate.  1 = the classic single-shot trial.
    steps: int = 1
    #: fault-persistence sweep (soak-capable targets only): False = one
    #: transient upset at step 0; True = the fault re-strikes the same
    #: site every step (a failing cell re-corrupting each access).
    persistent: Tuple[bool, ...] = (False,)
    #: data-shard mesh sweep (shardable targets only): each value N > 1
    #: runs the cell's soak under ``shard_map`` over a fake ``data`` axis
    #: of N host devices, so ``checked_psum`` verifies a REAL collective
    #: (N = 1 is the single-device verify-only path).  The executor
    #: places each sharded cell on its own slice of the host mesh.
    mesh: Tuple[int, ...] = (1,)

    def __post_init__(self):
        if self.samples < 1:
            raise ValueError("samples must be >= 1")
        if self.flips_per_trial < 1:
            raise ValueError("flips_per_trial must be >= 1")
        if any(b <= 0 for b in self.rel_bounds):
            raise ValueError("rel_bounds must be positive")
        if self.steps < 1:
            raise ValueError("steps must be >= 1")
        if any(s < 1 for s in self.mesh):
            raise ValueError("mesh shard counts must be >= 1")
        # tolerate lists from JSON round-trips / hand-written specs
        for f in ("targets", "fault_models", "bit_bands", "dtypes",
                  "rel_bounds", "victims", "persistent", "mesh"):
            v = getattr(self, f)
            if not isinstance(v, tuple):
                object.__setattr__(self, f, tuple(v))
        object.__setattr__(
            self, "shapes", tuple(tuple(s) for s in self.shapes))

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "CampaignSpec":
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class CellPlan:
    """One fully-resolved grid cell: everything an executor needs."""
    cell_id: str
    target: str
    fault_model: str
    bit_band: str
    shape: Tuple[int, ...]
    dtype: str
    samples: int
    clean_samples: int
    flips: int
    seed: int
    measure_overhead: bool
    #: detection-threshold override (None = the target's default bound)
    rel_bound: Optional[float] = None
    #: injection-victim leaf-path pattern (None = target default)
    victim: Optional[str] = None
    #: consecutive steps per trial (soak-capable targets; 1 = single shot)
    steps: int = 1
    #: True = the fault re-strikes the same site every step of the soak
    persistent: bool = False
    #: data shards the soak runs under (shardable targets; 1 = no mesh,
    #: N > 1 = shard_map over a fake ``data`` axis of N host devices)
    data_shards: int = 1

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def cell_seed(spec_seed: int, cell_id: str) -> int:
    """Stable per-cell PRNG seed: independent of cell order and of
    PYTHONHASHSEED, so artifacts reproduce cell-for-cell."""
    h = hashlib.sha256(f"{spec_seed}:{cell_id}".encode()).digest()
    return int.from_bytes(h[:4], "little") & 0x7FFFFFFF


def _cell_id(target: str, model: str, band: str,
             shape: Sequence[int], dtype: str,
             rel_bound: Optional[float] = None,
             victim: Optional[str] = None,
             steps: int = 1, persistent: bool = False,
             data_shards: int = 1) -> str:
    s = "x".join(str(d) for d in shape) if shape else "default"
    base = f"{target}/{model}/{band}/{s}/{dtype}"
    if rel_bound is not None:
        base += f"/rb{rel_bound:g}"
    if victim is not None:
        base += f"/vic={victim}"
    if steps > 1:
        base += f"/steps{steps}"
    if persistent:
        base += "/persistent"
    if data_shards > 1:
        base += f"/shards{data_shards}"
    return base


def expand(spec: CampaignSpec) -> Tuple[List[CellPlan], List[dict]]:
    """Spec -> (plans, skipped).

    ``skipped`` entries are ``{"cell_id": ..., "reason": ...}`` — a sweep
    that silently drops cells reads as "covered everything" when it didn't.
    """
    from repro.campaign.targets import get_target

    plans: List[CellPlan] = []
    skipped: List[dict] = []
    seen = set()
    bounds_or_default = spec.rel_bounds if spec.rel_bounds else (None,)
    victims_or_default = spec.victims if spec.victims else (None,)
    for tname, model, band, dtype in itertools.product(
            spec.targets, spec.fault_models, spec.bit_bands, spec.dtypes):
        target = get_target(tname)   # unknown target = hard error
        shapes = spec.shapes if spec.shapes else target.default_shapes
        bounds = bounds_or_default if target.thresholded else (None,)
        if spec.rel_bounds and not target.thresholded:
            skipped.append({
                "cell_id": _cell_id(tname, model, band, (), dtype),
                "reason": f"target {tname} has no detection threshold "
                          f"(rel_bounds sweep ignored)"})
        victims = victims_or_default if target.victim_selectable \
            else (None,)
        if spec.victims and not target.victim_selectable:
            skipped.append({
                "cell_id": _cell_id(tname, model, band, (), dtype),
                "reason": f"target {tname} has no selectable victim "
                          f"(victims sweep ignored)"})
        soakable = target.soak is not None
        steps = spec.steps if soakable else 1
        if spec.steps > 1 and not soakable:
            skipped.append({
                "cell_id": _cell_id(tname, model, band, (), dtype),
                "reason": f"target {tname} is single-step "
                          f"(steps={spec.steps} ignored)"})
        persistence = tuple(dict.fromkeys(spec.persistent)) if soakable \
            else (False,)
        if any(spec.persistent) and not soakable:
            skipped.append({
                "cell_id": _cell_id(tname, model, band, (), dtype),
                "reason": f"target {tname} cannot carry a persistent "
                          f"fault (persistent sweep ignored)"})
        shard_counts = tuple(dict.fromkeys(spec.mesh)) \
            if target.shardable else (1,)
        if any(s > 1 for s in spec.mesh) and not target.shardable:
            skipped.append({
                "cell_id": _cell_id(tname, model, band, (), dtype),
                "reason": f"target {tname} cannot shard its collective "
                          f"(mesh sweep ignored)"})
        if steps == 1 and any(persistence):
            # a fault that re-strikes "every step" of a 1-step trial IS
            # the transient fault — a /persistent cell here would be a
            # duplicate measurement under a misleading label
            persistence = (False,)
            skipped.append({
                "cell_id": _cell_id(tname, model, band, (), dtype,
                                    persistent=True),
                "reason": "persistent is indistinguishable from "
                          "transient at steps=1 (duplicate cell "
                          "dropped)"})
        for shape, rel_bound, victim, persistent, shards in \
                itertools.product(shapes, bounds, victims, persistence,
                                  shard_counts):
            cid = _cell_id(tname, model, band, shape, dtype, rel_bound,
                           victim, steps, persistent, shards)
            if cid in seen:
                continue
            seen.add(cid)

            def skip(reason):
                skipped.append({"cell_id": cid, "reason": reason})

            if spec.shapes and len(shape) != target.shape_arity:
                skip(f"shape arity {len(shape)} != {target.shape_arity} "
                     f"for target {tname}")
                continue
            if dtype not in target.dtypes:
                skip(f"dtype {dtype} unsupported by {tname}")
                continue
            if model not in target.fault_models:
                skip(f"fault model {model} unsupported by {tname}")
                continue
            if model != "bitflip" and band != "all":
                # bands parameterize bit positions; only flips have them
                skip(f"bit band {band} meaningless for model {model}")
                continue
            if band not in target.bands:
                skip(f"bit band {band} unsupported by {tname}")
                continue
            if model == "bitflip":
                try:
                    inject_bit_band(dtype, band)
                except KeyError:
                    skip(f"bit band {band} undefined for dtype {dtype}")
                    continue
            if spec.flips_per_trial > 1 and not target.multi_flip:
                skip(f"target {tname} injects a single element per trial "
                     f"(flips_per_trial={spec.flips_per_trial})")
                continue
            clean = spec.samples if spec.clean_samples is None \
                else spec.clean_samples
            plans.append(CellPlan(
                cell_id=cid, target=tname, fault_model=model,
                bit_band=band, shape=tuple(shape), dtype=dtype,
                samples=spec.samples, clean_samples=clean,
                flips=spec.flips_per_trial,
                seed=cell_seed(spec.seed, cid),
                measure_overhead=spec.measure_overhead,
                rel_bound=rel_bound, victim=victim,
                steps=steps, persistent=persistent,
                data_shards=shards))
    return plans, skipped
