"""``--grid adaptive``: controller-convergence cells under drifting
workloads.

Each cell drives the :class:`repro.adapt.ThresholdController` loop
end-to-end against a synthetic-but-faithful EmbeddingBag stream: per
evaluation tick it computes the Eq. (5) residual *ratio*
``|rsum - csum| / max(mag, 1)`` per bag on device (replicating
``abft_embedding_bag``'s pieces — ``AbftEbOut`` doesn't expose the raw
residual), then compares host-side against the controller's evolving
``rel_bound``.  Because the bound lives host-side, threshold moves cost
zero recompiles here, and the best-offline-static comparison replays the
*identical* ratio stream against every candidate constant — an exact
apples-to-apples detection comparison on the same workload.

Mid-stream each cell drifts the workload, per the drift kinds Ma et al.
(arxiv 2307.10244) motivate:

* ``variance_shift`` — the accumulation dtype switches f32 → bf16
  (mixed-precision serving), inflating the clean-residual distribution
  ~1000×: the controller must loosen fast or drown in false positives;
* ``prompt_mix`` — the valid-slots-per-bag mix collapses (long prompts →
  short), shrinking accumulated round-off: the controller should tighten
  and buy detection back;
* ``bursty`` — arrivals turn bursty (0–4 batches per tick, idle ticks
  included): the evidence rate varies wildly and the windowed estimator
  plus ``min_checks`` abstention must keep the loop stable.

Cell gates (the committed ``BENCH_campaign_adaptive_quick`` baseline
witnesses all three):

* ``converged`` within the stream and re-converged after the drift;
* ``fp_budget_held`` — post-convergence realized FP is not statistically
  above the budget (Wilson lower bound <= budget);
* ``detection_ok`` — stream-wide detection >= the best offline-swept
  constant that holds the same budget on the same stream.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.adapt import AdaptiveThresholds, ControllerConfig
from repro.campaign.metrics import wilson_interval

ADAPT_OP = "embedding_bag"
ADAPT_TENANT = "premium"

#: drift kinds a spec can sweep (see module docstring)
DRIFTS = ("variance_shift", "prompt_mix", "bursty")


@dataclasses.dataclass(frozen=True)
class AdaptiveSpec:
    """The sweep description embedded in the artifact."""
    name: str
    drifts: Tuple[str, ...]
    shape: Tuple[int, int, int, int]      # rows, dim, bags, pool
    steps: int                            # evaluation ticks per cell
    drift_at: int                         # tick the workload shifts
    fp_budget: float
    seed: int
    #: ControllerConfig fields (kept as a dict so the spec serializes)
    controller: Tuple[Tuple[str, float], ...] = ()

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def controller_config(self) -> ControllerConfig:
        return ControllerConfig(fp_budget=self.fp_budget,
                                **dict(self.controller))


@dataclasses.dataclass(frozen=True)
class AdaptiveCellPlan:
    cell_id: str
    target: str
    kind: str                             # "adaptive" (schema dispatch)
    drift: str
    shape: Tuple[int, int, int, int]
    steps: int
    drift_at: int
    fp_budget: float
    seed: int

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class AdaptiveMetrics:
    """Dict-backed metrics (campaign artifacts just need ``to_dict``)."""

    def __init__(self, d: dict):
        self._d = d

    def to_dict(self) -> dict:
        return self._d

    def __getitem__(self, k):
        return self._d[k]


# ------------------------------ device side ---------------------------------


def _regime(key, shape):
    """The trained-table regime the operator campaign uses: int8 rows,
    per-row dequant scales/offsets, exact int32 rowsums."""
    import jax
    import jax.numpy as jnp

    from repro.core.abft_embedding import table_rowsums
    rows, dim, _, _ = shape
    k1, k2, k3 = jax.random.split(key, 3)
    table = jax.random.randint(k1, (rows, dim), -127, 128, jnp.int8)
    alphas = jax.random.uniform(k2, (rows,), jnp.float32, 0.01, 0.02)
    betas = jax.random.uniform(k3, (rows,), jnp.float32, 0.3, 0.7)
    return {"table": table, "alphas": alphas, "betas": betas,
            "rowsums": table_rowsums(table)}


def _ratio_fns(shape, n_valid: int, acc_dtype):
    """Jitted (clean, trial) residual-ratio kernels for one workload
    regime.  Both draw their own ``indices`` from the key ([bags,
    n_valid] live slots, the rest ``-1`` padding) so one call is one
    stream step; ``trial`` additionally flips one random bit of one
    gathered table element (the operator campaign's fault model)."""
    import jax
    import jax.numpy as jnp

    rows, dim, bags, pool = shape

    def _idx(key):
        live = jax.random.randint(key, (bags, n_valid), 0, rows,
                                  jnp.int32)
        pad = jnp.full((bags, pool - n_valid), -1, jnp.int32)
        return jnp.concatenate([live, pad], axis=1)

    def _ratios(state, table, idx):
        valid = idx >= 0
        safe = jnp.where(valid, idx, 0)
        trows = table[safe].astype(acc_dtype)
        a = state["alphas"][safe]
        b = state["betas"][safe]
        w = jnp.where(valid, 1.0, 0.0)
        deq = (a[..., None].astype(acc_dtype) * trows
               + b[..., None].astype(acc_dtype))
        r = jnp.sum(w[..., None].astype(acc_dtype) * deq, axis=1)
        rsum = jnp.sum(r, axis=-1).astype(jnp.float32)
        ct = state["rowsums"][safe].astype(jnp.float32)
        csum = jnp.sum(w * (a * ct + dim * b), axis=-1)
        mag = jnp.sum(jnp.abs(w) * (jnp.abs(a) * jnp.abs(ct)
                                    + dim * jnp.abs(b)), axis=-1)
        return jnp.abs(rsum - csum) / jnp.maximum(mag, 1.0)

    @jax.jit
    def clean(state, key):
        return _ratios(state, state["table"], _idx(key))

    @jax.jit
    def trial(state, key):
        ki, kb, kp, kc, kbit = jax.random.split(key, 5)
        idx = _idx(ki)
        b = jax.random.randint(kb, (), 0, bags)
        p = jax.random.randint(kp, (), 0, n_valid)
        col = jax.random.randint(kc, (), 0, dim)
        bit = jax.random.randint(kbit, (), 0, 8)
        row = idx[b, p]
        elem = state["table"][row, col]
        bad = (elem.astype(jnp.uint8) ^ (1 << bit).astype(jnp.uint8)
               ).astype(jnp.int8)
        table_bad = state["table"].at[row, col].set(bad)
        return _ratios(state, table_bad, idx), bad != elem

    return clean, trial


def _drift_regimes(drift: str, shape):
    """(n_valid, acc_dtype) for the pre- and post-drift workloads."""
    import jax.numpy as jnp

    _, _, _, pool = shape
    full, quarter = pool, max(pool // 4, 1)
    if drift == "variance_shift":
        return (full, jnp.float32), (full, jnp.bfloat16)
    if drift == "prompt_mix":
        return (full, jnp.float32), (quarter, jnp.float32)
    if drift == "bursty":
        return (full, jnp.float32), (full, jnp.float32)
    raise ValueError(f"unknown drift {drift!r}; have {DRIFTS}")


def _batches_per_tick(drift: str, steps: int, seed: int) -> List[int]:
    """The arrival schedule: 1 batch/tick, except the ``bursty`` drift's
    post-drift half draws 0–4 (0 = an idle tick)."""
    if drift != "bursty":
        return [1] * steps
    rng = np.random.default_rng(seed)
    half = steps // 2
    return [1] * half + [int(b) for b in
                         rng.choice([0, 1, 2, 4], size=steps - half,
                                    p=[0.25, 0.35, 0.25, 0.15])]


# ------------------------------ the cell ------------------------------------


def run_adaptive_cell(plan: AdaptiveCellPlan, *,
                      config: ControllerConfig, obs=None) -> dict:
    """One convergence cell: drive the controller over the drifting
    stream, then replay the stored ratio stream against a static-bound
    ladder for the best-offline-constant comparison."""
    import jax

    from repro.obs import Monitor

    t0 = time.perf_counter()
    monitor = Monitor(rules=())       # pure sensor: no alert rules
    if obs is not None:
        monitor.bind(obs)
    adapt = AdaptiveThresholds(config=config, obs=obs,
                               source="campaign.adaptive")
    ctrl = adapt.manage(ADAPT_OP, ADAPT_TENANT, rel_bound=None)

    (nv_a, dt_a), (nv_b, dt_b) = _drift_regimes(plan.drift, plan.shape)
    state = _regime(jax.random.key(plan.seed), plan.shape)
    fns_a = _ratio_fns(plan.shape, nv_a, dt_a)
    fns_b = _ratio_fns(plan.shape, nv_b, dt_b)
    schedule = _batches_per_tick(plan.drift, plan.steps, plan.seed)

    base = jax.random.key(plan.seed + 1)
    clean_ratios: List[np.ndarray] = []      # per clean batch
    trial_ratios: List[np.ndarray] = []      # per injected trial
    trial_corrupted: List[bool] = []
    trial_bounds: List[float] = []           # bound active at the trial
    fp_by_tick: List[Tuple[int, int, int]] = []  # (tick, fps, checks)
    move_ticks: List[int] = []

    step_i = 0
    for tick, n_batches in enumerate(schedule):
        clean_fn, trial_fn = (fns_a if tick < plan.drift_at
                              else fns_b)
        t_s = 0.01 * (tick + 1)
        if n_batches == 0:
            monitor.idle_tick(t_s)
            adapt.tick(monitor, t_s=t_s, step=tick)
            continue
        fps = checks = 0
        for _ in range(n_batches):
            kc = jax.random.fold_in(base, 2 * step_i)
            kt = jax.random.fold_in(base, 2 * step_i + 1)
            step_i += 1
            rc = np.asarray(clean_fn(state, kc), np.float64)
            rt, corrupted = trial_fn(state, kt)
            rt = np.asarray(rt, np.float64)
            clean_ratios.append(rc)
            trial_ratios.append(rt)
            trial_corrupted.append(bool(corrupted))
            trial_bounds.append(ctrl.rel_bound)
            fps += int(np.sum(rc > ctrl.rel_bound))
            checks += rc.size
        fp_by_tick.append((tick, fps, checks))
        monitor.record_step(t_s, {ADAPT_OP: (checks, fps)},
                            tenants=(ADAPT_TENANT,))
        before = ctrl.adjustments
        adapt.tick(monitor, t_s=t_s, step=tick)
        if ctrl.adjustments > before:
            move_ticks.append(tick)

    # ---- adaptive-run detection/FP over the whole stream ----
    corrupted = sum(trial_corrupted)
    detected = sum(
        1 for rt, c, b in zip(trial_ratios, trial_corrupted,
                              trial_bounds)
        if c and bool(np.any(rt > b)))
    total_checks = sum(c for _, _, c in fp_by_tick)
    total_fps = sum(f for _, f, _ in fp_by_tick)

    # ---- post-convergence realized FP (the budget-held gate) ----
    last_move = move_ticks[-1] if move_ticks else -1
    post = [(f, c) for t, f, c in fp_by_tick if t > last_move]
    post_fps = sum(f for f, _ in post)
    post_checks = sum(c for _, c in post)
    fp_lo, fp_hi = (wilson_interval(post_fps, post_checks)
                    if post_checks else (0.0, 1.0))
    realized = post_fps / post_checks if post_checks else 0.0
    budget_held = bool(ctrl.converged and fp_lo <= plan.fp_budget)

    # ---- best offline-swept constant on the identical stream ----
    ladder = np.geomspace(config.floor, config.ceiling, 33)
    best_rb, best_det, best_fp = None, -1.0, None
    all_clean = np.concatenate(clean_ratios) if clean_ratios else \
        np.zeros(0)
    for t in ladder:
        fp_t = float(np.mean(all_clean > t)) if all_clean.size else 0.0
        if fp_t > plan.fp_budget:
            continue
        det_t = (sum(1 for rt, c in zip(trial_ratios, trial_corrupted)
                     if c and bool(np.any(rt > t))) / corrupted
                 if corrupted else 0.0)
        if det_t > best_det:
            best_rb, best_det, best_fp = float(t), det_t, fp_t
    det_rate = detected / corrupted if corrupted else 0.0
    detection_ok = bool(det_rate + 1e-12 >= best_det)

    metrics = AdaptiveMetrics({
        "samples": len(trial_ratios),
        "corrupted": corrupted,
        "detected": detected,
        "escapes": corrupted - detected,
        "escape_rate": ((corrupted - detected) / corrupted
                        if corrupted else 0.0),
        "detection_rate": det_rate,
        "clean_samples": total_checks,
        "false_positives": total_fps,
        "fp_rate": total_fps / total_checks if total_checks else 0.0,
        "fp_budget": plan.fp_budget,
        "realized_fp_rate": realized,
        "realized_fp_low": fp_lo,
        "realized_fp_high": fp_hi,
        "fp_budget_held": budget_held,
        "fp_budget_in_ci": bool(fp_lo <= plan.fp_budget <= fp_hi),
        "converged": bool(ctrl.converged),
        "converged_rel_bound": ctrl.rel_bound,
        "ticks_to_converge": ctrl.ticks_to_converge,
        "adjustments": ctrl.adjustments,
        "move_ticks": move_ticks,
        "best_static_rel_bound": best_rb,
        "best_static_detection": best_det if best_rb is not None
        else None,
        "best_static_fp": best_fp,
        "detection_ok": detection_ok,
        "overhead": None,
        "analytic_bound": None,
        "controller": ctrl.summary(),
    })
    _publish_adaptive_cell(obs, plan, metrics)
    return {"plan": plan, "metrics": metrics,
            "seconds": time.perf_counter() - t0}


def _publish_adaptive_cell(obs, plan: AdaptiveCellPlan,
                           metrics: AdaptiveMetrics) -> None:
    """Land the cell outcome as campaign counters + one ``cell`` event
    (the controller's own ``threshold`` events were emitted live)."""
    if obs is None:
        return
    from repro.obs import FaultEvent

    reg = obs.registry
    cell = plan.cell_id
    reg.counter("repro_injections_total",
                "injected faults per campaign cell"
                ).inc(metrics["samples"], cell=cell)
    reg.counter("repro_detections_total",
                "online-detected injected faults per campaign cell"
                ).inc(metrics["detected"], cell=cell)
    reg.counter("repro_false_positives_total",
                "clean-pass flags per campaign cell"
                ).inc(metrics["false_positives"], cell=cell)
    obs.bus.emit(FaultEvent(
        op=plan.target, kind="cell", step=0,
        source="campaign.adaptive", cell_id=cell,
        errors=metrics["detected"], checks=metrics["samples"],
        detector_value=metrics["detection_rate"],
        bound=metrics["converged_rel_bound"],
        attrs={"false_positives": metrics["false_positives"],
               "fp_rate": metrics["fp_rate"],
               "converged": metrics["converged"],
               "fp_budget_held": metrics["fp_budget_held"],
               "detection_ok": metrics["detection_ok"]}))


# ------------------------------ the grid ------------------------------------


def adaptive_plans(spec: AdaptiveSpec) -> List[AdaptiveCellPlan]:
    return [AdaptiveCellPlan(
        cell_id=f"adaptive/{drift}/eb{'x'.join(map(str, spec.shape))}"
                f"/fp{spec.fp_budget:g}",
        target="adaptive_eb", kind="adaptive", drift=drift,
        shape=spec.shape, steps=spec.steps, drift_at=spec.drift_at,
        fp_budget=spec.fp_budget, seed=spec.seed + i)
        for i, drift in enumerate(spec.drifts)]


#: controller tuning the campaign cells run with — wide clamp range so
#: the bf16 variance shift stays inside it; min_checks sized to two
#: ticks of fresh evidence (64 checks/tick) so a move's effect is
#: judged after one cooldown tick
CAMPAIGN_CONTROLLER: Tuple[Tuple[str, float], ...] = (
    ("floor", 1e-8), ("ceiling", 0.05), ("step", 1.35),
    ("hysteresis", 0.6), ("min_checks", 128), ("cooldown_ticks", 1),
    ("settle_ticks", 10), ("window_ticks", 24),
)


def quick_adaptive_spec(seed: int = 0) -> AdaptiveSpec:
    return AdaptiveSpec(name="adaptive_quick", drifts=DRIFTS,
                        shape=(128, 16, 64, 32), steps=240,
                        drift_at=120, fp_budget=0.02, seed=seed,
                        controller=CAMPAIGN_CONTROLLER)


def full_adaptive_spec(seed: int = 0) -> AdaptiveSpec:
    return AdaptiveSpec(name="adaptive", drifts=DRIFTS,
                        shape=(256, 32, 64, 32), steps=480,
                        drift_at=240, fp_budget=0.02, seed=seed,
                        controller=CAMPAIGN_CONTROLLER)


def run_adaptive_campaign(spec: Optional[AdaptiveSpec] = None, *,
                          quick: bool = True, seed: int = 0,
                          out_dir: Optional[str] = None,
                          verbose=None, obs=None) -> dict:
    """Run every drift cell; returns (and optionally writes) the
    ``BENCH_campaign_adaptive[_quick]`` artifact dict."""
    from repro.campaign.artifacts import campaign_to_dict, write_artifacts

    if spec is None:
        spec = quick_adaptive_spec(seed) if quick \
            else full_adaptive_spec(seed)
    t0 = time.perf_counter()
    config = spec.controller_config()
    cells = []
    for plan in adaptive_plans(spec):
        cell = run_adaptive_cell(plan, config=config, obs=obs)
        cells.append(cell)
        if verbose:
            m = cell["metrics"]
            verbose(f"[{plan.cell_id}] converged={m['converged']} "
                    f"rb={m['converged_rel_bound']:.3g} "
                    f"moves={m['adjustments']} "
                    f"det={m['detection_rate']:.2f} "
                    f"(best static {m['best_static_detection']}) "
                    f"fp={m['realized_fp_rate']:.4f} "
                    f"budget_held={m['fp_budget_held']} "
                    f"({cell['seconds']:.1f}s)")
    result = campaign_to_dict(spec.name, [spec], cells, [],
                              wall_s=time.perf_counter() - t0,
                              seed=spec.seed)
    if out_dir is not None:
        write_artifacts(result, out_dir)
    return result


__all__ = ["AdaptiveSpec", "AdaptiveCellPlan", "AdaptiveMetrics",
           "run_adaptive_cell", "adaptive_plans",
           "run_adaptive_campaign", "quick_adaptive_spec",
           "full_adaptive_spec", "DRIFTS", "ADAPT_OP", "ADAPT_TENANT"]
