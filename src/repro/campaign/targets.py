"""Registry of injectable targets.

A target owns the three pure functions a campaign cell needs:

* ``build(plan, key)``  — materialize the operand state for one cell
  (tables, weights, precomputed checksums, model params...);
* ``trial(state, plan, key)`` — inject one fault, run the protected op,
  return ``(detected, corrupted)`` booleans.  ``corrupted`` is the target's
  ground truth for "did the fault matter" (bits changed for operator
  targets; observable output changed for the full-model soak), which is
  what separates *masked* faults from *SDC escapes* in the metrics;
* ``clean(state, plan, key)`` — run fault-free, return the (false-positive)
  flag.

All three are jit/vmap-safe; the executor vmaps ``trial``/``clean`` over
key batches and pmaps the batches across host devices.  ``overhead``
optionally returns (protected, unprotected) thunks the executor times to
produce the per-cell overhead column.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.campaign.spec import CellPlan, DLRM_GEMM_SHAPES
from repro.core import abft_gemm as ag
from repro.core import abft_kvcache as kv
from repro.core.inject import (bit_band, random_bitflip,
                               random_bitflip_live, random_bitflips,
                               random_value, victim_leaf_index)
from repro.protect.ops import EMBEDDING_BAG, KV_CACHE, QGEMM
from repro.protect.plan import ResolvedRule


def apply_fault(key: jax.Array, x: jax.Array, plan: CellPlan,
                path: str = "") -> jax.Array:
    """The spec'd fault model applied to one array.  ``path`` (the victim
    leaf's dotted path) lets single bit flips avoid the dead alignment
    lanes of packed weights (:func:`repro.core.inject.random_bitflip_live`)
    so victim sweeps measure live faults, not guaranteed-masked ones."""
    if plan.fault_model == "bitflip":
        rng = bit_band(x.dtype, plan.bit_band)
        if plan.flips == 1:
            return random_bitflip_live(key, x, path, bit_range=rng)
        return random_bitflips(key, x, plan.flips, bit_range=rng)
    if plan.fault_model == "random_value":
        return random_value(key, x)
    raise ValueError(f"unknown fault model {plan.fault_model!r}")


@dataclasses.dataclass(frozen=True)
class InjectableTarget:
    name: str
    build: Callable[[CellPlan, jax.Array], Any]
    #: single-shot trial (exactly one of ``trial`` / ``soak`` must be set)
    trial: Optional[Callable[[Any, CellPlan, jax.Array],
                             Tuple[jax.Array, jax.Array]]] = None
    clean: Optional[Callable[[Any, CellPlan, jax.Array], jax.Array]] = None
    default_shapes: Tuple[Tuple[int, ...], ...] = ()
    shape_arity: int = 0
    dtypes: Tuple[str, ...] = ("int8",)
    fault_models: Tuple[str, ...] = ("bitflip", "random_value")
    bands: Tuple[str, ...] = ("all", "low", "significant", "sign")
    analytic_bound: Callable[[CellPlan], Optional[float]] = lambda p: None
    overhead: Optional[Callable[[Any, CellPlan],
                                Tuple[Callable, Callable]]] = None
    #: optional named phase thunks ({"encode": fn, "gemm": fn, ...}) the
    #: executor times individually into the artifact's
    #: ``overhead_breakdown`` column (measure_overhead cells only)
    overhead_phases: Optional[Callable[[Any, CellPlan],
                                       dict]] = None
    #: False for targets whose trial injects into a single element —
    #: expand() skips flips_per_trial > 1 plans for them
    multi_flip: bool = True
    #: True for targets with a tunable detection threshold (the EB
    #: rel_bound) — expand() sweeps spec.rel_bounds over them only
    thresholded: bool = False
    #: True for targets whose injection victim is addressable by leaf-path
    #: pattern (protect-plan vocabulary) — expand() sweeps spec.victims
    #: over them only
    victim_selectable: bool = False
    #: multi-step soak protocol (replaces ``trial`` when set): one call =
    #: ``plan.steps`` consecutive steps with the fault injected at step 0
    #: (re-struck every step when ``plan.persistent``).  Must return a dict
    #: of fixed-shape arrays: ``detected_steps`` (bool [steps]),
    #: ``corrupted`` (bool), ``divergence`` / ``loss_divergence`` (f32
    #: scalars vs the clean twin run).  expand() routes spec.steps /
    #: spec.persistent sweeps to these targets only.
    soak: Optional[Callable[[Any, CellPlan, jax.Array], dict]] = None
    #: True for soak targets that can run under a data-shard mesh: their
    #: ``build`` accepts a ``mesh=`` kwarg and their soak executes the
    #: collective through ``shard_map`` when ``plan.data_shards > 1`` —
    #: expand() routes spec.mesh sweeps to these targets only.  Sharded
    #: soaks additionally return ``shard_detected`` (bool [shards]) for
    #: the per-shard FaultReport merge.
    shardable: bool = False

    def __post_init__(self):
        if (self.trial is None) == (self.soak is None):
            raise ValueError(
                f"target {self.name!r}: exactly one of trial/soak required")
        if self.clean is None:
            raise ValueError(f"target {self.name!r}: clean is required")


TARGETS: dict = {}


def register_target(target: InjectableTarget) -> InjectableTarget:
    TARGETS[target.name] = target
    return target


def get_target(name: str) -> InjectableTarget:
    if name not in TARGETS:
        raise KeyError(
            f"unknown target {name!r}; registered: {sorted(TARGETS)}")
    return TARGETS[name]


# ---------------------------------------------------------------------------
# GEMM targets — paper Table II.  Serving model: B's checksum is encoded
# once from the CLEAN weights; the injected flip is a memory error the
# amortized checksum must catch (§IV-A1).
# ---------------------------------------------------------------------------

def _gemm_build(plan: CellPlan, key: jax.Array):
    m, n, k = plan.shape
    ka, kb = jax.random.split(key)
    a = jax.random.randint(ka, (m, k), 0, 256, jnp.uint8)
    b = jax.random.randint(kb, (k, n), -127, 128, jnp.int8)
    # serving memory model: checksum lanes encoded ONCE from clean weights
    packed = QGEMM.encode(b)
    return {"a": a, "b": b, "lanes": packed[:, b.shape[1]:],
            "checksum": ag.encode_weight_checksum(b)}


def _gemm_repack(state, b_bad):
    """B' with the (clean, amortized) checksum lanes riding along."""
    return jnp.concatenate([b_bad, state["lanes"]], axis=1)


def _gemm_b_trial(state, plan: CellPlan, key: jax.Array):
    b_bad = apply_fault(key, state["b"], plan)
    _, check = QGEMM(_gemm_repack(state, b_bad), state["a"])
    return check.err_count > 0, jnp.any(b_bad != state["b"])


def _gemm_clean(state, plan: CellPlan, key: jax.Array):
    del key
    _, check = QGEMM(_gemm_repack(state, state["b"]), state["a"])
    return check.err_count > 0


def _gemm_bound(plan: CellPlan):
    m = plan.shape[0]
    if plan.fault_model == "bitflip" and plan.flips == 1 \
            and plan.bit_band == "all":
        return ag.detect_prob_b_bitflip(m)
    if plan.fault_model == "random_value":
        return ag.detect_prob_b_random(m)
    return None


def _gemm_overhead(state, plan: CellPlan):
    a = state["a"]
    b_packed = _gemm_repack(state, state["b"])

    def protected():
        return QGEMM(b_packed, a)[0]

    def unprotected():
        return QGEMM.unprotected(b_packed, a)

    return protected, unprotected


def _gemm_phases(state, plan: CellPlan) -> dict:
    """encode / gemm / verify — §IV's amortization story as numbers: the
    encode phase is the amortized one-time cost, gemm the baseline, and
    verify the per-call detection surcharge."""
    a, b = state["a"], state["b"]
    b_packed = _gemm_repack(state, b)
    n = b.shape[1]
    c_full = jax.lax.dot_general(
        a, b_packed, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    c, check_col = c_full[:, :n], c_full[:, n]
    return {
        "encode": lambda: QGEMM.encode(b),
        "gemm": lambda: QGEMM.unprotected(b_packed, a),
        "verify": lambda: ag.verify_rows(c, check_col),
    }


register_target(InjectableTarget(
    name="gemm_packed",
    build=_gemm_build, trial=_gemm_b_trial, clean=_gemm_clean,
    default_shapes=((20, 256, 512),), shape_arity=3,
    analytic_bound=_gemm_bound, overhead=_gemm_overhead,
    overhead_phases=_gemm_phases))


_UNFUSED = ResolvedRule(scheme="unfused")


def _gemm_unfused_trial(state, plan: CellPlan, key: jax.Array):
    # BLAS-2 verification path (§IV-A3 step ③), amortized clean encode
    b_bad = apply_fault(key, state["b"], plan)
    _, check = QGEMM(_gemm_repack(state, b_bad), state["a"],
                     rule=_UNFUSED)
    return check.err_count > 0, jnp.any(b_bad != state["b"])


def _gemm_unfused_overhead(state, plan: CellPlan):
    a = state["a"]
    b_packed = _gemm_repack(state, state["b"])

    def protected():
        return QGEMM(b_packed, a, rule=_UNFUSED)[0]

    def unprotected():
        return QGEMM.unprotected(b_packed, a)

    return protected, unprotected


register_target(InjectableTarget(
    name="gemm_unfused",
    build=_gemm_build, trial=_gemm_unfused_trial, clean=_gemm_clean,
    default_shapes=((20, 256, 512),), shape_arity=3,
    analytic_bound=_gemm_bound, overhead=_gemm_unfused_overhead,
    overhead_phases=_gemm_phases))


# Fused Pallas implementation as a measured third scheme: identical build
# (so cells differ only in execution path), trials routed through
# scheme="pallas" — interpret mode on CPU, the real kernel on TPU.  The
# --grid pallas campaign runs this next to gemm_packed/gemm_unfused on the
# same flip grid and gates on detection parity (overlapping Wilson CIs).

_PALLAS = ResolvedRule(scheme="pallas")


def _gemm_pallas_trial(state, plan: CellPlan, key: jax.Array):
    b_bad = apply_fault(key, state["b"], plan)
    _, check = QGEMM(_gemm_repack(state, b_bad), state["a"], rule=_PALLAS)
    return check.err_count > 0, jnp.any(b_bad != state["b"])


def _gemm_pallas_clean(state, plan: CellPlan, key: jax.Array):
    del key
    _, check = QGEMM(_gemm_repack(state, state["b"]), state["a"],
                     rule=_PALLAS)
    return check.err_count > 0


def _gemm_pallas_overhead(state, plan: CellPlan):
    a = state["a"]
    b_packed = _gemm_repack(state, state["b"])

    def protected():
        return QGEMM(b_packed, a, rule=_PALLAS)[0]

    def unprotected():
        return QGEMM.unprotected(b_packed, a)

    return protected, unprotected


def _gemm_pallas_phases(state, plan: CellPlan) -> dict:
    """encode / gemm / fused_gemm_verify — the fused kernel has no separate
    verify phase by construction (the epilogue checks the tile the MXU just
    produced), so the breakdown times the whole fused call instead and the
    surcharge is fused_gemm_verify − gemm."""
    a, b = state["a"], state["b"]
    b_packed = _gemm_repack(state, b)
    return {
        "encode": lambda: QGEMM.encode(b),
        "gemm": lambda: QGEMM.unprotected(b_packed, a),
        "fused_gemm_verify": lambda: QGEMM(b_packed, a, rule=_PALLAS)[0],
    }


register_target(InjectableTarget(
    name="gemm_pallas",
    build=_gemm_build, trial=_gemm_pallas_trial, clean=_gemm_pallas_clean,
    default_shapes=((20, 256, 512),), shape_arity=3,
    analytic_bound=_gemm_bound, overhead=_gemm_pallas_overhead,
    overhead_phases=_gemm_pallas_phases))


def _gemm_c_build(plan: CellPlan, key: jax.Array):
    """Precompute the clean int32 C and its checksum column once per cell;
    trials corrupt C (the accumulator-resident intermediate, §IV-C2)."""
    m, n, k = plan.shape
    st = _gemm_build(plan, key)
    b_packed = ag.pack_encoded_b(st["b"], st["checksum"])
    c_full = jax.lax.dot_general(
        st["a"], b_packed, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    return {"c": c_full[:, :n], "check_col": c_full[:, n]}


def _gemm_c_trial(state, plan: CellPlan, key: jax.Array):
    c_bad = apply_fault(key, state["c"], plan)
    _, err = ag.verify_rows(c_bad, state["check_col"])
    return err > 0, jnp.any(c_bad != state["c"])


def _gemm_c_clean(state, plan: CellPlan, key: jax.Array):
    del key
    _, err = ag.verify_rows(state["c"], state["check_col"])
    return err > 0


def _gemm_c_bound(plan: CellPlan):
    if plan.fault_model == "bitflip":
        return 1.0          # 2^k mod 127 != 0 for every k: always caught
    return ag.detect_prob_c_random()


register_target(InjectableTarget(
    name="gemm_c",
    build=_gemm_c_build, trial=_gemm_c_trial, clean=_gemm_c_clean,
    default_shapes=((20, 256, 512),), shape_arity=3,
    dtypes=("int32",), analytic_bound=_gemm_c_bound))


# ---------------------------------------------------------------------------
# EmbeddingBag target — paper Table III.  The flip strikes a random element
# among the rows a bag accesses (an untouched-row flip is invisible by
# construction).  α/β follow the trained-table regime of
# benchmarks/eb_detection.py: α ~ U(0.01, 0.02), β ~ U(0.3, 0.7), so the
# low-bit band straddles the round-off bound exactly as in the paper.
# ---------------------------------------------------------------------------

def _eb_build(plan: CellPlan, key: jax.Array):
    rows, dim, _, _ = plan.shape
    kt, ka, kb = jax.random.split(key, 3)
    table = jax.random.randint(kt, (rows, dim), -128, 128, jnp.int8)
    alphas = jax.random.uniform(ka, (rows,), jnp.float32, 1e-2, 2e-2)
    betas = jax.random.uniform(kb, (rows,), jnp.float32, 0.3, 0.7)
    return {"table": table, "alphas": alphas, "betas": betas,
            "rowsums": EMBEDDING_BAG.encode((table, alphas, betas))[-1]}


def _eb_rule(plan: CellPlan) -> ResolvedRule:
    """The cell's Eq. (5) threshold as a plan rule (None = default)."""
    return ResolvedRule(rel_bound=plan.rel_bound)


def _eb_rule_pallas(plan: CellPlan) -> ResolvedRule:
    """Same threshold, forced through the fused Pallas kernel — ONE trial
    body serves both EB targets (rule_fn partial below), so the flip grid
    and the Eq. (5) semantics cannot drift between the measured paths."""
    return ResolvedRule(rel_bound=plan.rel_bound, scheme="pallas")


def _eb_enc(state):
    return (state["table"], state["alphas"], state["betas"],
            state["rowsums"])


def _eb_trial(state, plan: CellPlan, key: jax.Array, rule_fn=_eb_rule):
    rows, dim, bags, pool = plan.shape
    table = state["table"]
    k1, k2, k3, k4 = jax.random.split(key, 4)
    idx = jax.random.randint(k1, (bags, pool), 0, rows, jnp.int32)
    # distinct keys per victim coordinate: reusing k2 for both draws
    # made (b, p) perfectly correlated quantiles, so sweep points within
    # a bit band sampled a 1-D slice of the victim space
    b = jax.random.randint(k2, (), 0, bags)
    p = jax.random.randint(jax.random.fold_in(k2, 1), (), 0, pool)
    row = idx[b, p]
    col = jax.random.randint(k3, (), 0, dim)
    elem = table[row, col]
    bad = apply_fault(k4, elem[None], plan)[0]
    table_bad = table.at[row, col].set(bad)
    _, check = EMBEDDING_BAG(
        (table_bad, state["alphas"], state["betas"], state["rowsums"]),
        idx, rule=rule_fn(plan))
    return check.err_count > 0, bad != elem


def _eb_clean(state, plan: CellPlan, key: jax.Array, rule_fn=_eb_rule):
    rows, dim, bags, pool = plan.shape
    idx = jax.random.randint(key, (bags, pool), 0, rows, jnp.int32)
    _, check = EMBEDDING_BAG(_eb_enc(state), idx, rule=rule_fn(plan))
    return check.err_count > 0


def _eb_overhead(state, plan: CellPlan, rule_fn=_eb_rule):
    rows, dim, bags, pool = plan.shape
    idx = jax.random.randint(jax.random.key(0), (bags, pool), 0, rows,
                             jnp.int32)
    enc, rule = _eb_enc(state), rule_fn(plan)

    def protected():
        return EMBEDDING_BAG(enc, idx, rule=rule)[0]

    def unprotected():
        return EMBEDDING_BAG.unprotected(enc, idx)

    return protected, unprotected


def _eb_phases(state, plan: CellPlan, rule_fn=_eb_rule) -> dict:
    rows, dim, bags, pool = plan.shape
    idx = jax.random.randint(jax.random.key(0), (bags, pool), 0, rows,
                             jnp.int32)
    enc, rule = _eb_enc(state), rule_fn(plan)
    return {
        "encode": lambda: EMBEDDING_BAG.encode(
            (state["table"], state["alphas"], state["betas"])),
        "lookup": lambda: EMBEDDING_BAG.unprotected(enc, idx),
        "lookup_verify": lambda: EMBEDDING_BAG(enc, idx, rule=rule)[0],
    }


register_target(InjectableTarget(
    name="embedding_bag",
    build=_eb_build, trial=_eb_trial, clean=_eb_clean,
    default_shapes=((10_000, 128, 10, 100),), shape_arity=4,
    overhead=_eb_overhead, overhead_phases=_eb_phases,
    multi_flip=False, thresholded=True))


# the fused EB kernel vmaps in interpret mode but at ~CPU-emulation speed,
# so the default cell is smaller than embedding_bag's; the pallas grid pins
# BOTH EB targets to this shape so their cells stay directly comparable
register_target(InjectableTarget(
    name="eb_pallas",
    build=_eb_build,
    trial=functools.partial(_eb_trial, rule_fn=_eb_rule_pallas),
    clean=functools.partial(_eb_clean, rule_fn=_eb_rule_pallas),
    default_shapes=((2000, 64, 8, 32),), shape_arity=4,
    overhead=functools.partial(_eb_overhead, rule_fn=_eb_rule_pallas),
    overhead_phases=functools.partial(_eb_phases,
                                      rule_fn=_eb_rule_pallas),
    multi_flip=False, thresholded=True))


# ---------------------------------------------------------------------------
# KV-cache target (beyond-paper: core.abft_kvcache).  dtype selects the
# victim: int8 = the quantized cache payload (exact integer checksum — the
# detector's home turf), float32 = the α dequant scales, which the rowsum
# does NOT cover — a deliberate coverage-gap cell whose escape rate
# quantifies what an attacker of the scales gets away with.
# ---------------------------------------------------------------------------

def _kv_build(plan: CellPlan, key: jax.Array):
    b, heads, s, dh = plan.shape
    x = jax.random.normal(key, (b, heads, s, dh), jnp.float32)
    return {"kv": KV_CACHE.encode(x), "x": x}


def _kv_trial(state, plan: CellPlan, key: jax.Array):
    q = state["kv"]
    if plan.dtype == "float32":
        alpha_bad = apply_fault(key, q.alpha, plan)
        bad = kv.QuantKV(q.q, alpha_bad, q.beta, q.rowsum)
        changed = jnp.any(alpha_bad != q.alpha)
    else:
        q_bad = apply_fault(key, q.q, plan)
        bad = kv.QuantKV(q_bad, q.alpha, q.beta, q.rowsum)
        changed = jnp.any(q_bad != q.q)
    _, err = kv.verify_kv(bad)
    return err > 0, changed


def _kv_clean(state, plan: CellPlan, key: jax.Array):
    del key
    _, err = kv.verify_kv(state["kv"])
    return err > 0


def _kv_bound(plan: CellPlan):
    if plan.dtype == "int8" and plan.fault_model == "bitflip":
        return 1.0          # exact integer rowsum: any payload flip caught
    if plan.dtype == "float32":
        return 0.0          # scales are outside the checksum: by design
    return None


def _kv_overhead(state, plan: CellPlan):
    q = state["kv"]

    def protected():
        _, err = kv.verify_kv(q)
        return KV_CACHE.dequantize(q), err

    def unprotected():
        return KV_CACHE.dequantize(q)

    return protected, unprotected


def _kv_phases(state, plan: CellPlan) -> dict:
    q = state["kv"]
    return {
        "quantize": lambda: KV_CACHE.encode(state["x"]),
        "verify": lambda: kv.verify_kv(q),
        "dequantize": lambda: KV_CACHE.dequantize(q),
    }


register_target(InjectableTarget(
    name="kv_cache",
    build=_kv_build, trial=_kv_trial, clean=_kv_clean,
    default_shapes=((2, 2, 128, 64),), shape_arity=4,
    dtypes=("int8", "float32"),
    bands=("all", "low", "significant", "sign", "exponent", "mantissa",
           "high_mantissa"),
    analytic_bound=_kv_bound, overhead=_kv_overhead,
    overhead_phases=_kv_phases))


# ---------------------------------------------------------------------------
# Full-model decode soak (launch.steps + a reduced registry arch).
# One trial = flip bits in a weight leaf, scan ``plan.steps`` consecutive
# decode steps (fault struck at step 0; re-struck every step when
# ``plan.persistent``), read each step's ABFT counters.  ``corrupted`` is
# the OBSERVABLE output change (any generated token differs from the
# clean twin sequence), so the cell's categories line up with the
# fault-injection literature: detected / masked / SDC escape — and the
# soak protocol gives persistent weight faults the same per-step
# detection-latency histograms the training targets report.  At steps=1
# this is bit-identical to the legacy single-shot trial (same key, same
# flip, one decode), so the committed quick baseline stays valid.
# ---------------------------------------------------------------------------

DECODE_ARCH = "llama3.2-1b"


def _decode_build(plan: CellPlan, key: jax.Array):
    import numpy as np

    from repro.configs import reduce_cfg
    from repro.configs.registry import get_arch
    from repro.launch.steps import make_decode_step, make_prefill_step
    from repro.layers.common import Ctx
    from repro.models.base import build_model
    from repro.protect import default_plan, unprotected_plan
    from repro.sharding import values_of

    batch, prompt_len = plan.shape
    cfg = reduce_cfg(get_arch(DECODE_ARCH))
    cache_len = prompt_len + cfg.meta_tokens + 8
    model = build_model(cfg, max_pos=cache_len + 8)
    ctx = Ctx(quant=True, plan=default_plan(),
              compute_dtype=jnp.bfloat16)
    params = values_of(
        jax.jit(lambda k: model.init(k, quant=True))(key))

    rng = np.random.default_rng(plan.seed)
    batch_in = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (batch, prompt_len)), jnp.int32)}
    prefill = jax.jit(make_prefill_step(model, ctx, cache_len=cache_len))
    tok, cache, _ = prefill(params, batch_in)
    pos = jnp.full((batch,), prompt_len + cfg.meta_tokens, jnp.int32)

    decode = make_decode_step(model, ctx)

    # the clean twin: plan.steps greedy decode steps from the prefill
    # state — the soak's per-step SDC ground truth (deterministic decode)
    def _clean_scan(carry, _):
        c_cache, c_tok, c_pos = carry
        t2, c2, _ = decode(params, c_cache, c_tok, c_pos)
        return (c2, t2, c_pos + 1), t2

    (_, clean_toks) = jax.lax.scan(
        _clean_scan, (cache, tok, pos), None, length=plan.steps)
    clean_toks = jax.block_until_ready(clean_toks)      # [steps, batch]

    # victim: addressed by the plan's leaf-path pattern in the protect
    # vocabulary (``attn.wq``, ``mlp.down``, ``embed.table``, ...); the
    # default (None) keeps the legacy choice — largest int8 leaf
    leaves, treedef = jax.tree_util.tree_flatten(params)
    victim_idx, victim_path = victim_leaf_index(params, plan.victim)

    state = {"leaves": leaves, "treedef": treedef,
             "victim_idx": victim_idx, "victim_path": victim_path,
             "cache": cache, "tok": tok,
             "pos": pos, "decode": decode, "clean_toks": clean_toks}
    if plan.measure_overhead:
        ctx_off = Ctx(quant=True, plan=unprotected_plan(),
                      compute_dtype=jnp.bfloat16)
        state["decode_off"] = make_decode_step(model, ctx_off)
        state["params"] = params
    return state


def _decode_soak(state, plan: CellPlan, key: jax.Array):
    victim = state["leaves"][state["victim_idx"]]
    # the flip is computed ONCE from the trial key (exactly the legacy
    # single-shot fault) and gated per step with a where-mask, so the
    # scan body stays shape-static under vmap
    bad = apply_fault(key, victim, plan, path=state["victim_path"])
    strike = jnp.ones((plan.steps,), bool) if plan.persistent \
        else (jnp.arange(plan.steps) == 0)

    def body(carry, do_strike):
        cache, tok, pos = carry
        leaves = list(state["leaves"])
        leaves[state["victim_idx"]] = jnp.where(do_strike, bad, victim)
        params = jax.tree_util.tree_unflatten(state["treedef"], leaves)
        tok2, cache2, metrics = state["decode"](params, cache, tok, pos)
        errs = metrics.get("abft/qgemm_errors", 0) \
            + metrics.get("abft/embedding_bag_errors", 0) \
            + metrics.get("abft/kv_cache_errors", 0)
        return (cache2, tok2, pos + 1), (jnp.asarray(errs) > 0, tok2)

    _, (det_steps, toks) = jax.lax.scan(
        body, (state["cache"], state["tok"], state["pos"]), strike)
    # toks: [steps, batch] vs the clean twin sequence
    mismatch = toks != state["clean_toks"]
    return {
        "detected_steps": det_steps,
        "corrupted": jnp.any(mismatch),
        "divergence": jnp.mean(mismatch.astype(jnp.float32)),
        "loss_divergence": jnp.zeros((), jnp.float32),
    }


def _decode_clean(state, plan: CellPlan, key: jax.Array):
    del key
    params = jax.tree_util.tree_unflatten(state["treedef"],
                                          state["leaves"])
    _, _, metrics = state["decode"](params, state["cache"], state["tok"],
                                    state["pos"])
    errs = metrics.get("abft/qgemm_errors", 0) \
        + metrics.get("abft/embedding_bag_errors", 0) \
        + metrics.get("abft/kv_cache_errors", 0)
    return jnp.asarray(errs) > 0


def _decode_overhead(state, plan: CellPlan):
    if "decode_off" not in state:
        return None
    params, cache = state["params"], state["cache"]
    tok, pos = state["tok"], state["pos"]

    def protected():
        return state["decode"](params, cache, tok, pos)[0]

    def unprotected():
        return state["decode_off"](params, cache, tok, pos)[0]

    return protected, unprotected


register_target(InjectableTarget(
    name="decode_step",
    build=_decode_build, soak=_decode_soak, clean=_decode_clean,
    default_shapes=((2, 16),), shape_arity=2,
    overhead=_decode_overhead, victim_selectable=True))


__all__ = ["InjectableTarget", "TARGETS", "register_target", "get_target",
           "apply_fault", "DLRM_GEMM_SHAPES", "DECODE_ARCH"]

# training-step targets register themselves on import (kept in their own
# module — they pull in launch/optim/runtime machinery this module's
# operator targets never need)
from repro.campaign import targets_training  # noqa: E402,F401
