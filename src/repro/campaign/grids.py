"""Named campaign grids.

``quick``  — the CI smoke: every target class exercised, minutes on CPU,
             sample counts sized so the GEMM bit-flip cell is statistically
             comparable (±2%) to the §IV-C analytic bound.
``paper``  — the paper's Tables II + III campaigns at full shape coverage.
``thresholds`` — EB rel_bound sweep: detection-vs-FP tradeoff per bit band.
``pallas`` — fused-kernel parity: the identical bit-flip grid through the
             fused Pallas path (interpret mode on CPU) and the XLA paths,
             gating on overlapping detection CIs + the overhead columns.
``soak``   — the full-model decode-step sweep across fault models/bands.
``victims`` — decode-soak victim sweep: which leaf gets flipped, addressed
             by protect-plan path patterns (``attn.wq``, ``mlp.down``, ...).
``training`` — training-step resilience: faults at every seam of the
             compressed-gradient optimizer pipeline (pre/post checked_psum,
             int8 payload, error feedback, AdamW moments) plus multi-step
             persistent-fault soaks with detection-latency histograms.
``multidevice`` — mesh-sharded training soaks: cells run under shard_map
             over a fake ``data`` axis so checked_psum verifies a REAL
             collective per step (single-shard transit flips, the
             post-reduction window, and a sharded-vs-single contrast).
``full``   — everything above plus the beyond-paper KV-cache cells.

(The ``serving_soak`` grid — faults under live traffic — lives in
:mod:`repro.serving.soak`; the CLI dispatches to it.)
"""
from __future__ import annotations

from typing import Dict, List

from repro.campaign.spec import CampaignSpec, DLRM_GEMM_SHAPES


def quick_specs(seed: int = 0, samples: int = 600) -> List[CampaignSpec]:
    return [
        CampaignSpec(
            name="quick-gemm",
            targets=("gemm_packed", "gemm_c"),
            fault_models=("bitflip", "random_value"),
            bit_bands=("all",),
            shapes=((1, 256, 512), (20, 256, 512)),
            dtypes=("int8", "int32"),
            samples=max(samples, 500), seed=seed,
            measure_overhead=True),
        CampaignSpec(
            name="quick-eb",
            targets=("embedding_bag",),
            fault_models=("bitflip",),
            bit_bands=("significant", "low"),
            samples=500, seed=seed, measure_overhead=True),
        CampaignSpec(
            name="quick-kv",
            targets=("kv_cache",),
            fault_models=("bitflip",),
            bit_bands=("all",),
            dtypes=("int8", "float32"),
            samples=200, seed=seed),
        CampaignSpec(
            name="quick-soak",
            targets=("decode_step",),
            fault_models=("bitflip",),
            bit_bands=("significant",),
            samples=8, clean_samples=4, seed=seed),
    ]


def paper_specs(seed: int = 0, quick: bool = False) -> List[CampaignSpec]:
    """Tables II (GEMM, 28 DLRM shapes × B/C errors × clean) and III
    (EmbeddingBag high/low bands + clean)."""
    shapes = tuple(DLRM_GEMM_SHAPES[::4] if quick else DLRM_GEMM_SHAPES)
    return [
        CampaignSpec(
            name="paper-gemm",
            targets=("gemm_packed", "gemm_c"),
            fault_models=("bitflip",),
            bit_bands=("all",),
            shapes=shapes,
            dtypes=("int8", "int32"),
            samples=100, seed=seed),
        CampaignSpec(
            name="paper-eb",
            targets=("embedding_bag",),
            fault_models=("bitflip",),
            bit_bands=("significant", "low"),
            samples=200, clean_samples=400, seed=seed),
    ]


def thresholds_specs(seed: int = 0,
                     samples: int = 400) -> List[CampaignSpec]:
    """EB ``rel_bound`` sweep: the detection-vs-false-positive tradeoff
    curve per bit band (ROADMAP open item).  Tight bounds catch low-bit
    flips but false-positive on round-off; the paper's 1e-5 sits between.
    Clean samples run at every bound so the FP side of the curve is
    measured, not assumed."""
    return [CampaignSpec(
        name="eb-thresholds",
        targets=("embedding_bag",),
        fault_models=("bitflip",),
        bit_bands=("significant", "low", "sign"),
        rel_bounds=(1e-7, 1e-6, 1e-5, 1e-4, 1e-3),
        samples=samples, clean_samples=samples, seed=seed)]


def pallas_specs(seed: int = 0, quick: bool = False,
                 samples: int = 0) -> List[CampaignSpec]:
    """Fused-kernel detection parity (ROADMAP open item 1): run the SAME
    bit-flip grid through the fused Pallas implementation and the XLA
    reference schemes, so the artifact holds fused vs unfused vs packed
    detection rates side by side.  Cell seeds derive from cell ids (which
    include the target name), so the fused and unfused cells draw
    *different* fault samples — the parity gate is therefore statistical:
    overlapping 95% Wilson intervals on the same grid point
    (:func:`repro.campaign.diff` compares detection the same way).  A
    deterministic bit-exact parity check (same flips through both paths)
    lives in tests/test_kernels.py; this grid measures at campaign scale
    and times the fused kernel (interpret mode on CPU — honest wall-clock
    for parity, not a TPU latency claim; the roofline benchmark models
    the TPU traffic).

    The EB cells run BOTH targets at the pallas-sized shape so cells stay
    comparable (interpret-mode emulation makes the default EB shape
    needlessly slow)."""
    n = samples or (400 if quick else 800)
    gemm = CampaignSpec(
        name="pallas-gemm",
        targets=("gemm_pallas", "gemm_packed", "gemm_unfused"),
        fault_models=("bitflip",),
        bit_bands=("all",),
        shapes=((20, 256, 512),),
        samples=n, clean_samples=max(64, n // 4), seed=seed,
        measure_overhead=True)
    eb = CampaignSpec(
        name="pallas-eb",
        targets=("eb_pallas", "embedding_bag"),
        fault_models=("bitflip",),
        bit_bands=("significant", "low"),
        shapes=((2000, 64, 8, 32),),
        samples=n, clean_samples=max(64, n // 4), seed=seed,
        measure_overhead=True)
    return [gemm, eb]


#: the decode soak's victim sweep: one packed projection per layer role,
#: plus the token table — the per-layer "which leaf gets flipped" axis the
#: protect plan's path vocabulary makes addressable (ROADMAP item).
VICTIM_PATTERNS = ("attn.wq", "attn.wk", "attn.wo", "mlp.up", "mlp.down",
                   "embed.table", "lm_head")


def victims_specs(seed: int = 0, samples: int = 12) -> List[CampaignSpec]:
    """Per-layer victim selection in the decode soak: sweep which leaf of
    the reduced LM gets flipped (path patterns in the protect-plan
    vocabulary) and compare end-to-end detection/escape per victim —
    attention projections vs MLP vs the embedding table behave very
    differently (an untouched-row table flip is invisible by
    construction)."""
    return [CampaignSpec(
        name="decode-victims",
        targets=("decode_step",),
        fault_models=("bitflip",),
        bit_bands=("significant",),
        victims=VICTIM_PATTERNS,
        samples=samples, clean_samples=4, seed=seed)]


#: every training-pipeline injection seam (repro.campaign.targets_training)
TRAINING_TARGETS = ("train_grad_pre", "train_payload", "train_grad_post",
                    "train_moments")


def training_specs(seed: int = 0, quick: bool = False,
                   samples: int = 0) -> List[CampaignSpec]:
    """Training-step resilience (ROADMAP item): real optimizer steps with
    faults at every seam of the compressed-gradient pipeline, plus a
    multi-step soak that tracks one upset across consecutive steps until
    detected / masked / escaped.

    Two specs: single-step coverage of all four seams (the per-seam
    detection/escape/divergence table), then the ``steps``-deep soak over
    the stateful seams (payload transport + error feedback + moments) with
    a transient-vs-persistent sweep — the per-step detection-latency
    histogram lands in the artifact's soak columns.
    """
    n = samples or (6 if quick else 20)
    soak_steps = 4 if quick else 8
    single = CampaignSpec(
        name="train-seams",
        targets=TRAINING_TARGETS,
        fault_models=("bitflip",),
        bit_bands=("significant",) if quick else ("significant", "low"),
        dtypes=("int8", "float32"),
        samples=n, clean_samples=2, seed=seed,
        measure_overhead=True)
    soak = CampaignSpec(
        name="train-soak",
        targets=("train_payload", "train_moments"),
        fault_models=("bitflip",),
        bit_bands=("significant",),
        dtypes=("int8", "float32"),
        samples=max(4, n // 2), clean_samples=2, seed=seed,
        steps=soak_steps, persistent=(False, True))
    return [single, soak]


#: the mesh seams (repro.campaign.targets_training): one shard's payload
#: in transit + the post-reduction summed payload
MULTIDEVICE_TARGETS = ("train_payload_shard", "train_reduced")


def multidevice_specs(seed: int = 0, quick: bool = False,
                      samples: int = 0,
                      shards: int = 4) -> List[CampaignSpec]:
    """Mesh-sharded campaign execution (ROADMAP items): training soaks
    run under shard_map over a fake ``data`` axis of ``shards`` host
    devices, so ``checked_psum`` verifies a REAL collective on every
    step instead of the ``axis_name=None`` fallback every other grid
    exercises.

    Two specs: the mesh seams at full shard count — a single-shard int8
    payload flip that only the post-psum additivity check can see
    (detected after the collective, never before) and the summed payload
    after verification (the post-reduction escape window) — then the
    shard-contrast soak sweeping ``train_payload`` over ``mesh=(1,
    shards)`` so the artifact holds the same seam with and without a
    real reduction in the loop (Ma et al.: fault outcomes shift once
    distributed reductions are real).

    Run under ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (the
    CLI forces it for this grid when ``--device-count`` is absent); a
    host with fewer devices degrades per cell with a warning and records
    ``collective_verified=False``.
    """
    n = samples or (4 if quick else 12)
    soak_steps = 2 if quick else 4
    seams = CampaignSpec(
        name="multidevice-seams",
        targets=MULTIDEVICE_TARGETS,
        fault_models=("bitflip",),
        bit_bands=("significant",),
        dtypes=("int8", "int32"),
        samples=n, clean_samples=2, seed=seed,
        steps=soak_steps, mesh=(shards,))
    contrast = CampaignSpec(
        name="multidevice-contrast",
        targets=("train_payload",),
        fault_models=("bitflip",),
        bit_bands=("significant",),
        dtypes=("int8",),
        samples=n, clean_samples=2, seed=seed,
        steps=soak_steps, mesh=(1, shards))
    return [seams, contrast]


def soak_specs(seed: int = 0) -> List[CampaignSpec]:
    """Full-model decode sweep plus a multi-step decode soak.

    ``decode_step`` now runs the ``soak`` protocol, so the second spec
    holds one upset across ``steps`` consecutive decode steps —
    transient (strike once, watch the KV-cache residue) vs persistent
    (flipped weight left in place) — and the per-step detection-latency
    histogram lands in the artifact's soak columns.  The single-step
    spec keeps ``steps=1`` and therefore the baseline cell ids/seeds."""
    single = CampaignSpec(
        name="soak",
        targets=("decode_step",),
        fault_models=("bitflip", "random_value"),
        bit_bands=("all", "significant", "low"),
        samples=16, clean_samples=8, seed=seed,
        measure_overhead=True)
    multi = CampaignSpec(
        name="decode-soak",
        targets=("decode_step",),
        fault_models=("bitflip",),
        bit_bands=("significant",),
        samples=8, clean_samples=2, seed=seed,
        steps=4, persistent=(False, True))
    return [single, multi]


def full_specs(seed: int = 0) -> List[CampaignSpec]:
    kv = CampaignSpec(
        name="kv-sweep",
        targets=("kv_cache",),
        fault_models=("bitflip", "random_value"),
        bit_bands=("all", "low", "significant", "exponent"),
        dtypes=("int8", "float32"),
        samples=400, seed=seed, measure_overhead=True)
    return paper_specs(seed) + [kv] + soak_specs(seed) \
        + training_specs(seed) + multidevice_specs(seed)


GRIDS: Dict[str, object] = {
    "quick": quick_specs,
    "paper": paper_specs,
    "thresholds": thresholds_specs,
    "pallas": pallas_specs,
    "soak": soak_specs,
    "victims": victims_specs,
    "training": training_specs,
    "multidevice": multidevice_specs,
    "full": full_specs,
}
