"""``python -m repro.campaign`` — run a resilience campaign.

Examples::

    python -m repro.campaign --quick
    python -m repro.campaign --grid paper --seed 7
    python -m repro.campaign --grid full --device-count 8 --out bench/
"""
from __future__ import annotations

import argparse
import os
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.campaign",
        description="Declarative fault-injection sweeps with batched "
                    "execution and JSON artifacts.")
    ap.add_argument("--quick", action="store_true",
                    help="shorthand for --grid quick (the CI smoke grid)")
    ap.add_argument("--grid", default=None,
                    choices=["quick", "paper", "soak", "full"],
                    help="named grid to run (see repro.campaign.grids)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--samples", type=int, default=0,
                    help="override the quick grid's GEMM sample count")
    ap.add_argument("--out", default=".",
                    help="artifact directory (default: cwd)")
    ap.add_argument("--chunk", type=int, default=0,
                    help="trials per compiled vmap chunk")
    ap.add_argument("--device-count", type=int, default=0,
                    help="fake host devices (XLA_FLAGS) to pmap across")
    args = ap.parse_args(argv)

    if args.device_count:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.device_count}"
        ).strip()

    # jax import happens after XLA_FLAGS is set
    from repro.campaign.executor import CHUNK, run_campaign
    from repro.campaign.grids import (GRIDS, paper_specs, quick_specs)

    grid = args.grid or ("quick" if args.quick else None)
    if grid is None:
        ap.error("pick a grid: --quick or --grid {quick,paper,soak,full}")
    if grid == "quick":
        specs = quick_specs(seed=args.seed, samples=args.samples or 600)
    elif grid == "paper":
        specs = paper_specs(seed=args.seed, quick=args.quick)
    else:
        specs = GRIDS[grid](seed=args.seed)

    result = run_campaign(grid, specs, out_dir=args.out,
                          chunk=args.chunk or CHUNK,
                          verbose=lambda s: print(s, flush=True))

    from repro.campaign.artifacts import markdown_table
    print()
    print(markdown_table(result))
    print(f"artifact: {os.path.join(args.out, 'BENCH_campaign_' + grid)}"
          f".json")
    return 0


if __name__ == "__main__":
    sys.exit(main())
