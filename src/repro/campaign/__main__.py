"""``python -m repro.campaign`` — run a resilience campaign, or diff two
campaign artifacts.

Examples::

    python -m repro.campaign --quick
    python -m repro.campaign --grid paper --seed 7
    python -m repro.campaign --grid thresholds        # EB rel_bound sweep
    python -m repro.campaign --grid pallas --quick    # fused-kernel parity
    python -m repro.campaign --grid victims           # decode victim sweep
    python -m repro.campaign --grid training --quick  # train-step seams
    python -m repro.campaign --grid multidevice --quick  # sharded cells
    python -m repro.campaign --grid serving_soak --quick   # live-traffic
    python -m repro.campaign --grid adaptive --quick  # threshold loop
    python -m repro.campaign --grid full --device-count 8 --out bench/
    python -m repro.campaign --diff OLD.json NEW.json # exit 1 on regression
    python -m repro.campaign --trend                  # baseline history gate
    python -m repro.campaign --trend BASE.json ... NEW.json
    python -m repro.campaign --quick --obs-dir obs/   # event/trace export
"""
from __future__ import annotations

import argparse
import os
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.campaign",
        description="Declarative fault-injection sweeps with batched "
                    "execution, JSON artifacts, and a cross-PR differ.")
    ap.add_argument("--quick", action="store_true",
                    help="shorthand for --grid quick (the CI smoke grid)")
    ap.add_argument("--grid", default=None,
                    choices=["quick", "paper", "thresholds", "pallas",
                             "soak", "victims", "training", "multidevice",
                             "serving_soak", "paging", "adaptive",
                             "full"],
                    help="named grid to run (see repro.campaign.grids; "
                         "serving_soak runs repro.serving.soak, paging "
                         "runs repro.serving.paging_soak, adaptive runs "
                         "repro.campaign.adaptive)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--plan", default=None,
                    help="serving grids: override every tenant's "
                         "protection plan — compact string, or "
                         "@path.json holding a plan dict")
    ap.add_argument("--samples", type=int, default=0,
                    help="override the per-cell sample count "
                         "(quick / thresholds grids)")
    ap.add_argument("--out", default=".",
                    help="artifact directory (default: cwd)")
    ap.add_argument("--chunk", type=int, default=0,
                    help="trials per compiled vmap chunk")
    ap.add_argument("--device-count", type=int, default=0,
                    help="fake host devices (XLA_FLAGS) to pmap across")
    ap.add_argument("--diff", nargs=2, metavar=("OLD", "NEW"),
                    help="diff two BENCH_campaign_*.json artifacts and "
                         "exit 1 on detection/FP regressions")
    ap.add_argument("--det-tol", type=float, default=0.02,
                    help="--diff: allowed detection-rate drop")
    ap.add_argument("--fp-tol", type=float, default=0.02,
                    help="--diff: allowed false-positive-rate rise")
    ap.add_argument("--overhead-tol", type=float, default=None,
                    help="--diff: allowed overhead rise (opt-in — "
                         "wall-clock noise on shared runners)")
    ap.add_argument("--diff-out", default=None,
                    help="--diff: also write the markdown report here")
    ap.add_argument("--trend", nargs="*", metavar="ARTIFACT", default=None,
                    help="fold artifacts (oldest..newest) into a per-cell "
                         "history table and gate the newest against the "
                         "prior median; with no paths, uses the committed "
                         "benchmarks/baselines/BENCH_campaign_*.json; "
                         "exits 1 on trend regressions")
    ap.add_argument("--trend-out", default=None,
                    help="--trend: also write the markdown history here")
    ap.add_argument("--latency-tol", type=float, default=None,
                    help="--trend: allowed overhead rise vs the prior "
                         "median (opt-in — wall-clock noise)")
    ap.add_argument("--obs-dir", default=None,
                    help="export observability artifacts (fault-event "
                         "JSONL, Chrome trace, Prometheus text) for the "
                         "run into this directory")
    ap.add_argument("--obs-flush-every", type=int, default=0, metavar="N",
                    help="crash-durable obs: append events to the JSONL "
                         "as they happen and rewrite metric/trace "
                         "snapshots every N events (needs --obs-dir) — "
                         "a killed soak keeps everything flushed so far")
    ap.add_argument("--monitor", action="store_true",
                    help="attach the live detection-health monitor to "
                         "the obs bus (windowed alert rules + per-scope "
                         "health states; summary printed at the end)")
    args = ap.parse_args(argv)

    if args.diff:
        from repro.campaign.diff import run_diff
        return run_diff(args.diff[0], args.diff[1], det_tol=args.det_tol,
                        fp_tol=args.fp_tol,
                        overhead_tol=args.overhead_tol,
                        out_path=args.diff_out)
    if args.trend is not None:
        from repro.campaign.trend import run_trend
        return run_trend(args.trend, det_tol=args.det_tol,
                         fp_tol=args.fp_tol,
                         latency_tol=args.latency_tol,
                         out_path=args.trend_out)

    grid = args.grid or ("quick" if args.quick else None)
    if grid is None:
        ap.error("pick a grid (--quick / --grid {quick,paper,thresholds,"
                 "pallas,soak,victims,training,multidevice,serving_soak,"
                 "paging,adaptive,full}) or --diff OLD NEW")

    # grids with sharded cells are pointless on a 1-device host: force
    # the 4-device host platform the multidevice baseline was produced
    # on unless the caller chose a count themselves (full includes the
    # multidevice specs).  Say so: the split platform also hosts the
    # grid's overhead timings, which must not silently change regime.
    if grid in ("multidevice", "full") and not args.device_count:
        args.device_count = 4
        print(f"[{grid}] forcing --device-count 4 for the sharded cells "
              f"(overhead timings run on the split host platform; pass "
              f"--device-count to override)", flush=True)
    if args.device_count:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.device_count}"
        ).strip()

    # jax import happens after XLA_FLAGS is set
    from repro.campaign.executor import (CHUNK, resolve_device_count,
                                         run_campaign)
    from repro.campaign.grids import (GRIDS, multidevice_specs,
                                      pallas_specs, paper_specs,
                                      quick_specs, thresholds_specs,
                                      training_specs, victims_specs)

    # warns and falls back when the flag landed after jax initialized
    resolve_device_count(args.device_count or None)

    obs = None
    if args.obs_dir or args.monitor:
        from repro.obs import Observability
        obs = Observability.create()
        if args.obs_dir and args.obs_flush_every > 0:
            obs.open_incremental(args.obs_dir,
                                 every=args.obs_flush_every)
    monitor = None
    if args.monitor:
        from repro.obs import Monitor
        monitor = Monitor()

    if grid == "serving_soak":
        # live-traffic soak: the serving engine, not the vmapped executor
        import dataclasses

        from repro.campaign.artifacts import markdown_table
        from repro.serving.soak import (full_soak_spec, quick_soak_spec,
                                        run_soak_campaign)
        spec = None
        if args.plan is not None:
            spec = quick_soak_spec(args.seed) if args.quick \
                else full_soak_spec(args.seed)
            spec = dataclasses.replace(spec, tenants=tuple(
                (n, w, args.plan) for n, w, _ in spec.tenants))
        result = run_soak_campaign(spec, quick=args.quick, seed=args.seed,
                                   out_dir=args.out, obs=obs,
                                   monitor=monitor,
                                   verbose=lambda s: print(s, flush=True))
        print()
        print(markdown_table(result))
        print(f"artifact: "
              f"{os.path.join(args.out, 'BENCH_campaign_serving_soak')}"
              f".json")
        _print_monitor(monitor)
        _write_obs(obs, args.obs_dir)
        return 0
    if grid == "adaptive":
        # controller-convergence cells (repro.campaign.adaptive)
        from repro.campaign.adaptive import run_adaptive_campaign
        from repro.campaign.artifacts import markdown_table
        result = run_adaptive_campaign(quick=args.quick, seed=args.seed,
                                       out_dir=args.out, obs=obs,
                                       verbose=lambda s: print(s,
                                                               flush=True))
        print()
        print(markdown_table(result))
        name = "adaptive_quick" if args.quick else "adaptive"
        print(f"artifact: "
              f"{os.path.join(args.out, 'BENCH_campaign_' + name)}.json")
        _write_obs(obs, args.obs_dir)
        return 0
    if grid == "paging":
        # paged-KV parity + repair cells (repro.serving.paging_soak)
        from repro.campaign.artifacts import markdown_table
        from repro.serving.paging_soak import run_paging_campaign
        result = run_paging_campaign(quick=args.quick, seed=args.seed,
                                     plan=args.plan, out_dir=args.out,
                                     obs=obs,
                                     verbose=lambda s: print(s,
                                                             flush=True))
        print()
        print(markdown_table(result))
        name = "paging_quick" if args.quick else "paging"
        print(f"artifact: "
              f"{os.path.join(args.out, 'BENCH_campaign_' + name)}.json")
        _write_obs(obs, args.obs_dir)
        return 0
    if grid == "quick":
        specs = quick_specs(seed=args.seed, samples=args.samples or 600)
    elif grid == "paper":
        specs = paper_specs(seed=args.seed, quick=args.quick)
    elif grid == "thresholds":
        specs = thresholds_specs(seed=args.seed,
                                 samples=args.samples or 400)
    elif grid == "pallas":
        specs = pallas_specs(seed=args.seed, quick=args.quick,
                             samples=args.samples or 0)
    elif grid == "victims":
        specs = victims_specs(seed=args.seed, samples=args.samples or 12)
    elif grid == "training":
        specs = training_specs(seed=args.seed, quick=args.quick,
                               samples=args.samples or 0)
    elif grid == "multidevice":
        specs = multidevice_specs(seed=args.seed, quick=args.quick,
                                  samples=args.samples or 0)
    else:
        specs = GRIDS[grid](seed=args.seed)

    # quick training/multidevice runs get their own artifact name: the
    # committed CI baselines are the quick variants and must not collide
    # with full runs
    name = f"{grid}_quick" if grid in ("training", "multidevice",
                                       "pallas") \
        and args.quick else grid
    result = run_campaign(name, specs, out_dir=args.out,
                          chunk=args.chunk or CHUNK, obs=obs,
                          monitor=monitor,
                          verbose=lambda s: print(s, flush=True))

    from repro.campaign.artifacts import (breakdown_markdown,
                                          latency_markdown, markdown_table,
                                          threshold_curve_markdown)
    print()
    print(markdown_table(result))
    if grid == "thresholds":
        print(threshold_curve_markdown(result))
    if grid in ("training", "multidevice", "full"):
        print(latency_markdown(result))
    bd = breakdown_markdown(result)
    if bd:
        print(bd)
    print(f"artifact: {os.path.join(args.out, 'BENCH_campaign_' + name)}"
          f".json")
    _print_monitor(monitor)
    _write_obs(obs, args.obs_dir)
    return 0


def _print_monitor(monitor) -> None:
    if monitor is None:
        return
    ms = monitor.summary()
    print(f"monitor: {ms['ticks']} tick(s), {ms['alerts_fired']} "
          f"alert(s), health {ms['health'] or '{}'}")
    for a in ms["alerts"]:
        print(f"  alert {a['rule']} [{a['severity']}] {a['scope']}: "
              f"{a['metric']}={a['value']:.4g} vs {a['threshold']:.4g}")


def _write_obs(obs, obs_dir) -> None:
    if obs is None:
        return
    if obs_dir is None:
        return
    paths = obs.write(obs_dir)
    for kind, path in sorted(paths.items()):
        print(f"obs {kind}: {path}")


if __name__ == "__main__":
    sys.exit(main())
