"""Training-step injectable targets — faults in the optimizer pipeline.

The operator targets in :mod:`repro.campaign.targets` answer "does one
protected op call catch its own fault".  These targets answer the training
question the ROADMAP left open: a real optimizer step — ``model.loss`` →
grad → int8 error-feedback compression → :func:`checked_psum` →
decompress → clip → AdamW, the same primitives in the same order as
``launch.steps.make_train_step(compress=True)`` / ``launch.train``, built
here with injection seams between the stages (intentional deviations from
the production step: fixed ``TRAIN_LR`` instead of the warmup-cosine
schedule, ``accum=1``, single-device collective) — run for ``plan.steps``
consecutive steps over the seeded data pipeline, with a bit flip injected
at a chosen seam:

* ``train_grad_pre``   — the raw f32 gradient BEFORE compression.  The
  payload checksum is computed *after* the corruption, so the collective
  verifies a consistently-wrong payload: undetectable by construction
  (analytic bound 0).  What saves training here is masking — int8
  quantization rounds low-bit flips away (the clean-twin ground truth
  counts those as masked, not escaped).
* ``train_grad_post``  — the mean gradient AFTER the verified collective:
  the post-verify window.  Also bound 0; its escape rate prices the gap
  between "collective verified" and "update applied".
* ``train_payload``    — dtype ``int8``: the compressed payload between
  checksum encode and the all-reduce — transport corruption, exactly what
  the mod-8191 additivity check covers (any single int8 bit flip shifts
  the residue: bound 1).  dtype ``float32``: the error-feedback residual —
  local state outside the checksum (bound 0) whose corruption only
  surfaces one step later, which is why it is a soak target.
* ``train_moments``    — the AdamW first moment: silent optimizer-state
  corruption (Ma et al. 2023's parameter-corruption regime, one level
  up).  Bound 0; divergence measures how hard the moment EMA smears one
  upset across subsequent steps.
* ``train_payload_shard`` — ONE shard's int8 payload, after encode and
  before the all-reduce: corruption in transit on a real mesh.  The
  corrupted shard's contribution shifts the summed residue while the
  expected value — ``psum`` of per-shard checksums encoded pre-flip —
  does not, so the flip is detected AFTER the collective by the
  additivity check (bound 1: |Δ| = 2^k ≤ 128 < 8191), never before (a
  sender-side recompute cannot see a wire fault).  At ``data_shards=1``
  this degenerates to ``train_payload``.
* ``train_reduced``    — the summed int32 payload after the verified
  collective, before decompression: the post-reduction window.  Bound 0
  (the additivity check already passed); its escape rate prices the gap
  on the *reduced* side exactly as ``train_grad_post`` does one stage
  later.

Multi-device semantics (``plan.data_shards`` > 1): the whole soak runs
under :func:`repro.sharding.shard_map` over a fake ``data`` axis — each
shard computes gradients on its own slice of the seeded pipeline, keeps
its own error-feedback residual, and the compressed payload goes through
a REAL ``psum`` with the mod-8191 receive-side check live on every step
(:func:`checked_psum_attributed` additionally reports each shard's local
verify count, folded into the artifact's ``shard_detections`` column).
Shard-local seams (``grad_pre``, ``payload_shard``, ``error_feedback``)
strike shard 0 only; replicated seams (``grad_post``, ``reduced``,
``moment``) strike every shard identically so parameters stay replicated.

Ground truth is a **clean twin**: the same scan over the same batches with
injection masked off, computed once per cell at build time.  ``corrupted``
is exact final-parameter mismatch; ``divergence`` (relative L2 parameter
drift) and ``loss_divergence`` quantify *how far* the fault propagated —
the metrics the artifact's soak columns carry.

Multi-step semantics (``plan.steps`` > 1): transient faults strike once at
step 0; ``plan.persistent`` re-strikes the same element/bit every step (a
failing cell re-corrupting each access).  ``detected_steps`` feeds the
executor's per-step detection-latency histogram.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

import numpy as np

from repro.campaign.spec import CellPlan
from repro.campaign.targets import (InjectableTarget, apply_fault,
                                    register_target)
from repro.core.inject import victim_leaf_index
from repro.optim import adamw_init, adamw_update, clip_by_global_norm
from repro.runtime.compression import (CompressionState, checked_psum,
                                       checked_psum_attributed,
                                       compress_grads, decompress_grads,
                                       init_compression)

TRAIN_ARCH = "llama3.2-1b"
TRAIN_LR = 1e-3
MAX_GRAD_NORM = 1.0

#: default injection victim: an MLP projection, NOT the largest leaf.
#: The largest leaf is the token embedding whose gradient is ~95% zeros
#: (only accessed rows get gradient), and a bit flip on a 0.0 element
#: yields a subnormal that AdamW's eps crushes to an exactly-zero update
#: — every trial masked, the cell uninformative.  MLP gradients are
#: dense, so the default measures live faults; sweep
#: ``victims=("embed.table",)`` to measure the sparsity-masking effect
#: itself.
TRAIN_DEFAULT_VICTIM = "mlp"

#: injection seams, in pipeline order (module doc above)
INJECT_POINTS = ("grad_pre", "payload", "payload_shard", "error_feedback",
                 "reduced", "grad_post", "moment")

#: seams that strike local, per-shard state when the soak runs under a
#: data mesh — the flip lands on shard 0 only; everything else strikes
#: replicated values identically on every shard
SHARD_LOCAL_POINTS = ("grad_pre", "payload_shard", "error_feedback")


def _flip_leaf(tree, victim_idx: int, key: jax.Array, plan: CellPlan,
               do_inject: jax.Array, path: str = ""):
    """Flip the spec'd fault into leaf ``victim_idx``; identity when
    ``do_inject`` is False (the transient-vs-persistent step mask)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    victim = leaves[victim_idx]
    bad = apply_fault(key, victim, plan, path=path)
    leaves[victim_idx] = jnp.where(do_inject, bad, victim)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _inject_point(plan: CellPlan) -> str:
    """The seam a cell injects at.  ``train_payload`` uses the dtype axis
    to pick payload (int8) vs error-feedback residual (float32), the same
    trick the kv_cache target plays with its scales."""
    point = {"train_grad_pre": "grad_pre", "train_grad_post": "grad_post",
             "train_moments": "moment",
             "train_payload_shard": "payload_shard",
             "train_reduced": "reduced"}.get(plan.target)
    if point is not None:
        return point
    return "payload" if plan.dtype == "int8" else "error_feedback"


def _train_build(plan: CellPlan, key: jax.Array, mesh=None):
    from repro.configs import reduce_cfg
    from repro.configs.base import ShapeConfig
    from repro.configs.registry import get_arch
    from repro.data import make_dataset
    from repro.layers.common import Ctx
    from repro.models.base import build_model
    from repro.protect import default_plan
    from repro.sharding import values_of

    batch, seq_len = plan.shape
    shards = int(np.prod(mesh.devices.shape)) if mesh is not None else 1
    cfg = reduce_cfg(get_arch(TRAIN_ARCH))
    model = build_model(cfg, max_pos=seq_len + cfg.meta_tokens + 8)
    ctx = Ctx(plan=default_plan(), quant=False,
              compute_dtype=jnp.float32)

    params = values_of(jax.jit(lambda k: model.init(k))(key))
    opt = adamw_init(params)
    comm = init_compression(params)
    if shards > 1:
        # each data shard keeps its OWN error-feedback residual (that is
        # the point of error feedback); leading [shards] axis, P("data")
        comm = CompressionState(error=jax.tree.map(
            lambda e: jnp.zeros((shards,) + e.shape, e.dtype), comm.error))

    # the real seeded pipeline, stacked to [steps, ...] (sharded cells:
    # [steps, shards, ...] — every shard sees a DIFFERENT batch, so the
    # psum reduces genuinely distinct payloads), plus one held-out batch
    # to evaluate the post-soak loss on — without it a steps=1 cell could
    # never observe a loss effect (per-step losses are computed on
    # PRE-update params, and every seam injects after that point)
    dataset = make_dataset(cfg, ShapeConfig("campaign", "train",
                                            seq_len, batch))
    per_step = [dataset.batch_at(t)
                for t in range(plan.steps * shards + 1)]
    batches = {k: jnp.stack([jnp.asarray(b[k]) for b in per_step[:-1]])
               for k in per_step[0]}
    if shards > 1:
        batches = {k: v.reshape((plan.steps, shards) + v.shape[1:])
                   for k, v in batches.items()}
    eval_batch = {k: jnp.asarray(per_step[-1][k]) for k in per_step[-1]}

    def loss_fn(p, mb):
        loss, (metrics, rep) = model.loss(p, mb, ctx)
        return loss, rep.total_errors()

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    # all injection trees (grads / payload q / summed int32 / residuals /
    # moments) mirror the param tree, so one victim index addresses every
    # seam
    victim_idx, victim_path = victim_leaf_index(
        params, plan.victim or TRAIN_DEFAULT_VICTIM, prefer_int8=False)

    state = {"params": params, "opt": opt, "comm": comm,
             "batches": batches, "eval_batch": eval_batch,
             "grad_fn": grad_fn,
             "loss_only": lambda p, mb: loss_fn(p, mb)[0],
             "victim_idx": victim_idx, "victim_path": victim_path,
             "mesh": mesh, "shards": shards}

    # clean twin: same scan (same mesh), injection masked off everywhere
    zeros = jnp.zeros((plan.steps,), bool)
    clean_params, clean_errs, clean_losses, clean_final, _ = jax.jit(
        lambda: _run_soak(state, plan, jax.random.key(0), zeros))()
    state.update(clean_params=clean_params, clean_errs=clean_errs,
                 clean_losses=clean_losses, clean_final_loss=clean_final)
    return state


def _run_soak(state, plan: CellPlan, key: jax.Array,
              inject_mask: jax.Array) -> Tuple:
    """``plan.steps`` train steps with the fault struck where
    ``inject_mask`` is True.  -> (final_params, errs [steps], losses
    [steps], final_loss, local_errs [shards, steps]) — ``final_loss``
    evaluates the post-soak params on the held-out batch, the only loss a
    fault in the LAST step's update can move; ``local_errs`` is the
    per-shard receive-side verify count (attribution — which shard
    carried a corrupted payload).  The same key every step means a
    persistent fault re-strikes the SAME element/bit (stuck-site
    semantics, not a fresh random upset).
    """
    if state.get("mesh") is not None:
        return _run_soak_sharded(state, plan, key, inject_mask)
    body = _make_step_body(state, plan, key, on_shard=jnp.asarray(True),
                           axis_name=None)
    carry = (state["params"], state["opt"], state["comm"].error)
    (params_f, _, _), (errs, losses, local) = jax.lax.scan(
        body, carry, (state["batches"], inject_mask))
    final_loss = state["loss_only"](params_f, state["eval_batch"])
    return params_f, errs, losses, final_loss, local[None, :]


def _make_step_body(state, plan: CellPlan, key: jax.Array, on_shard,
                    axis_name):
    """The ONE train-step body both soak variants scan: grad →
    [grad_pre] → compress → [payload / payload_shard] →
    [error_feedback] → checked psum → [reduced] → decompress →
    [grad_post] → clip → AdamW → [moment], the cell's seam flipped where
    its gate is True.

    Carry = (params, opt, error-feedback tree); per-step outputs =
    (global err count, loss, this-shard receive-side verify count).
    ``axis_name=None`` is the single-device pipeline, where the
    additivity check IS the receive-side verify — ``local_errs`` aliases
    ``comm_errs`` rather than recomputing the checksums a second time.
    Under a mesh, ``on_shard`` gates shard-local seams to shard 0 and
    the fwd/loss aggregates reduce over the axis."""
    point = _inject_point(plan)
    vidx, vpath = state["victim_idx"], state["victim_path"]
    grad_fn = state["grad_fn"]
    n_shards = state["shards"] if axis_name is not None else 1

    def flip(tree, do_inj, path=""):
        return _flip_leaf(tree, vidx, key, plan, do_inj, path=path)

    def body(carry, inp):
        params, opt, error = carry
        mb, do_inj = inp
        do_loc = do_inj & on_shard      # shard-local seams: shard 0 only
        (loss, fwd_errs), grads = grad_fn(params, mb)
        if point == "grad_pre":
            grads = flip(grads, do_loc, path=vpath)
        payload, comm = compress_grads(grads,
                                       CompressionState(error=error))
        if point in ("payload", "payload_shard"):
            # at data_shards=1 "one shard's payload" IS the payload
            payload = dict(payload, q=flip(payload["q"], do_loc))
        if point == "error_feedback":
            comm = CompressionState(error=flip(comm.error, do_loc))
        if axis_name is None:
            summed, scale_sum, comm_errs = checked_psum(payload, None)
            local_errs = comm_errs
        else:
            summed, scale_sum, comm_errs, local_errs = \
                checked_psum_attributed(payload, axis_name)
        if point == "reduced":
            # post-verify: escapes; same flip on every shard (replicated)
            summed = flip(summed, do_inj)
        mean = decompress_grads(summed, scale_sum, n_shards)
        if point == "grad_post":
            mean = flip(mean, do_inj)
        clipped, _ = clip_by_global_norm(mean, MAX_GRAD_NORM)
        new_params, new_opt = adamw_update(clipped, opt, params, TRAIN_LR)
        if point == "moment":
            new_opt = dict(new_opt, m=flip(new_opt["m"], do_inj))
        if axis_name is not None:
            fwd_errs = jax.lax.psum(fwd_errs, axis_name)
            loss = jax.lax.pmean(loss, axis_name)
        return (new_params, new_opt, comm.error), \
            (fwd_errs + comm_errs, loss, local_errs)

    return body


def _run_soak_sharded(state, plan: CellPlan, key: jax.Array,
                      inject_mask: jax.Array) -> Tuple:
    """The mesh path: the whole scan runs under ``shard_map`` over the
    fake ``data`` axis, so every step's ``checked_psum`` is a REAL
    collective — S distinct payloads reduced, the additivity check
    comparing checksum(psum(q)) against psum(checksum(q)) live.

    Same contract (and same step body) as :func:`_run_soak`.  Per-shard
    inputs carry a leading [shards] axis split by ``P("data")`` (batches
    at axis 1: ``P(None, "data")``); params/opt and the inject mask are
    replicated.  Shard-local seams gate the flip on ``axis_index == 0``;
    replicated seams flip with the same key on every shard so parameters
    stay replicated through the update.
    """
    from jax.sharding import PartitionSpec as P

    from repro.sharding import shard_map

    mesh = state["mesh"]
    shard_local = _inject_point(plan) in SHARD_LOCAL_POINTS

    def run(params, opt, error0, batches, mask):
        # local blocks: batches [steps, 1, B, ...] -> [steps, B, ...];
        # residual [1, ...] -> [...]
        batches = jax.tree.map(lambda x: x[:, 0], batches)
        error0 = jax.tree.map(lambda e: e[0], error0)
        on_shard = jax.lax.axis_index("data") == 0 if shard_local \
            else jnp.asarray(True)
        body = _make_step_body(state, plan, key, on_shard=on_shard,
                               axis_name="data")
        (params_f, _, _), (errs, losses, local) = jax.lax.scan(
            body, (params, opt, error0), (batches, mask))
        # errs/losses are replicated (psum/pmean products); local is this
        # shard's [steps] verify counts -> [1, steps] for P("data") out
        return params_f, errs, losses, local[None, :]

    sharded = shard_map(
        run, mesh=mesh,
        in_specs=(P(), P(), P("data"), P(None, "data"), P()),
        out_specs=(P(), P(), P(), P("data")))
    params_f, errs, losses, local = sharded(
        state["params"], state["opt"], state["comm"].error,
        state["batches"], inject_mask)
    final_loss = state["loss_only"](params_f, state["eval_batch"])
    return params_f, errs, losses, final_loss, local


def _divergence(params_f, params_c) -> Tuple[jax.Array, jax.Array]:
    """(relative L2 drift, exact-mismatch bool) vs the clean twin."""
    lf, lc = jax.tree.leaves(params_f), jax.tree.leaves(params_c)
    num = sum(jnp.sum(jnp.square(a.astype(jnp.float32)
                                 - b.astype(jnp.float32)))
              for a, b in zip(lf, lc))
    den = sum(jnp.sum(jnp.square(b.astype(jnp.float32))) for b in lc)
    rel = jnp.sqrt(num) / jnp.maximum(jnp.sqrt(den), 1e-30)
    changed = sum((jnp.any(a != b).astype(jnp.int32)
                   for a, b in zip(lf, lc)), jnp.zeros((), jnp.int32)) > 0
    return rel, changed


def _train_soak_fn(state, plan: CellPlan, key: jax.Array) -> dict:
    steps = plan.steps
    mask = jnp.ones((steps,), bool) if plan.persistent \
        else jnp.arange(steps) == 0
    params_f, errs, losses, final_loss, local = _run_soak(
        state, plan, key, mask)
    div, changed = _divergence(params_f, state["clean_params"])
    loss_div = jnp.maximum(
        jnp.max(jnp.abs(losses - state["clean_losses"])),
        jnp.abs(final_loss - state["clean_final_loss"]))
    return {
        "detected_steps": errs > 0,
        "corrupted": changed,
        "divergence": div,
        "loss_divergence": loss_div,
        # per-shard attribution: did shard s's receive-side verify fire
        # at any step (local_errs [shards, steps])
        "shard_detected": jnp.sum(local, axis=1) > 0,
    }


def _train_clean(state, plan: CellPlan, key: jax.Array):
    # the clean trajectory is deterministic (seeded batches, no key use):
    # its flags were computed once at build; any flag = a false positive
    del key
    return jnp.any(state["clean_errs"] > 0)


def _train_overhead(state, plan: CellPlan):
    """One protected (compress + checked psum) vs one plain train step.
    Both return the updated params so XLA cannot dead-code the update.

    The thunks do not depend on the cell's seam/band/dtype, so timing
    them per cell would just re-measure one pipeline N times and ship N
    contradictory noise samples (plus two extra train-step compiles per
    cell).  Only the canonical cell — the int8 payload seam at the
    significant band, single step — reports the number; every other cell
    returns None and the executor leaves its overhead column empty.
    Sharded cells skip it too: the timing thunks are single-device."""
    if not (_inject_point(plan) == "payload"
            and plan.bit_band == "significant" and plan.steps == 1
            and plan.data_shards == 1):
        return None
    grad_fn = state["grad_fn"]
    params, opt, comm = state["params"], state["opt"], state["comm"]
    mb = jax.tree.map(lambda x: x[0], state["batches"])

    def protected():
        (_, _), grads = grad_fn(params, mb)
        payload, comm2 = compress_grads(grads, comm)
        summed, scale_sum, errs = checked_psum(payload, None)
        mean = decompress_grads(summed, scale_sum, 1)
        clipped, _ = clip_by_global_norm(mean, MAX_GRAD_NORM)
        new_params, _ = adamw_update(clipped, opt, params, TRAIN_LR)
        return new_params, errs

    def unprotected():
        (_, _), grads = grad_fn(params, mb)
        clipped, _ = clip_by_global_norm(grads, MAX_GRAD_NORM)
        new_params, _ = adamw_update(clipped, opt, params, TRAIN_LR)
        return new_params

    return protected, unprotected


def _train_bound(target: str):
    def bound(plan: CellPlan):
        point = _inject_point(plan)
        if point in ("payload", "payload_shard"):
            if plan.fault_model == "bitflip" and plan.flips == 1:
                # |Δ| = 2^k ≤ 128 < 8191: one shard's residue shift always
                # moves the summed residue (payload_shard), and with the
                # same flip on every shard a cancellation mod 8191 leaves
                # the SUM clean — masked, so the effective (detected |
                # masked) rate the bound speaks about is still 1
                return 1.0
            return None
        # every other seam is outside the transport checksum by design
        return 0.0
    return bound


_F32_BANDS = ("all", "low", "significant", "sign", "exponent", "mantissa",
              "high_mantissa")
_TRAIN_SHAPES = ((2, 16),)     # (batch, seq_len) of the reduced LM


def _register(name: str, dtypes: Tuple[str, ...],
              bands: Tuple[str, ...]) -> None:
    register_target(InjectableTarget(
        name=name,
        build=_train_build, soak=_train_soak_fn, clean=_train_clean,
        default_shapes=_TRAIN_SHAPES, shape_arity=2,
        dtypes=dtypes, bands=bands,
        analytic_bound=_train_bound(name), overhead=_train_overhead,
        multi_flip=True, victim_selectable=True, shardable=True))


_register("train_grad_pre", ("float32",), _F32_BANDS)
_register("train_grad_post", ("float32",), _F32_BANDS)
_register("train_payload", ("int8", "float32"),
          ("all", "low", "significant", "sign", "exponent", "mantissa",
           "high_mantissa"))
_register("train_moments", ("float32",), _F32_BANDS)
# mesh seams: one shard's payload in transit (caught AFTER the psum by
# the additivity check) and the summed int32 payload after the verified
# collective (the post-reduction escape window)
_register("train_payload_shard", ("int8",),
          ("all", "low", "significant", "sign"))
_register("train_reduced", ("int32",),
          ("all", "low", "significant", "sign"))


__all__ = ["TRAIN_ARCH", "TRAIN_LR", "INJECT_POINTS",
           "SHARD_LOCAL_POINTS"]
