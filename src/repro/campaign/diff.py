"""Cross-PR artifact differ: flag detection-rate / FP / overhead
regressions between two ``BENCH_campaign_*.json`` files.

    python -m repro.campaign --diff OLD.json NEW.json

Cells are matched by ``cell_id``.  A **regression** is:

* new effective detection rate below old by more than ``det_tol``;
* new false-positive rate above old by more than ``fp_tol``;
* (only when ``overhead_tol`` is given — wall-clock overhead is noisy on
  shared CI runners, so it is opt-in) new overhead above old by more than
  ``overhead_tol``;
* a cell present in the old artifact but missing from the new one
  (silent coverage loss reads as "no regressions" when it is the worst
  kind).

Detection counts are deterministic per (seed, jax version), so the default
tolerances mostly absorb cross-version PRNG/codegen drift.  The CLI exits
nonzero iff regressions exist — wire it against a committed baseline in CI.
"""
from __future__ import annotations

from typing import List, Optional

from repro.campaign.artifacts import load_artifact


def _cells_by_id(result: dict) -> dict:
    return {c["cell_id"]: c["metrics"] for c in result["cells"]}


def diff_artifacts(old: dict, new: dict, *, det_tol: float = 0.02,
                   fp_tol: float = 0.02,
                   overhead_tol: Optional[float] = None) -> dict:
    """Compare two loaded artifacts; returns the diff record.

    ``{"regressions": [...], "improvements": [...], "added": [...],
    "removed": [...], "unchanged": int, "old": name, "new": name}`` —
    regression entries carry ``cell_id``, ``kind``, ``old``/``new`` values
    and the tolerance that was exceeded.
    """
    oc, nc = _cells_by_id(old), _cells_by_id(new)
    regressions: List[dict] = []
    improvements: List[dict] = []
    unchanged = 0

    for cid in sorted(set(oc) & set(nc)):
        om, nm = oc[cid], nc[cid]
        flagged = False

        d_old, d_new = om["detection_rate"], nm["detection_rate"]
        if d_new < d_old - det_tol:
            regressions.append({"cell_id": cid, "kind": "detection_rate",
                                "old": d_old, "new": d_new,
                                "tol": det_tol})
            flagged = True
        elif d_new > d_old + det_tol:
            improvements.append({"cell_id": cid, "kind": "detection_rate",
                                 "old": d_old, "new": d_new})
            flagged = True

        f_old, f_new = om["fp_rate"], nm["fp_rate"]
        if f_new > f_old + fp_tol:
            regressions.append({"cell_id": cid, "kind": "fp_rate",
                                "old": f_old, "new": f_new, "tol": fp_tol})
            flagged = True
        elif f_new < f_old - fp_tol:
            improvements.append({"cell_id": cid, "kind": "fp_rate",
                                 "old": f_old, "new": f_new})
            flagged = True

        o_old, o_new = om.get("overhead"), nm.get("overhead")
        if overhead_tol is not None and o_old is not None \
                and o_new is not None:
            if o_new > o_old + overhead_tol:
                regressions.append({"cell_id": cid, "kind": "overhead",
                                    "old": o_old, "new": o_new,
                                    "tol": overhead_tol})
                flagged = True
            elif o_new < o_old - overhead_tol:
                improvements.append({"cell_id": cid, "kind": "overhead",
                                     "old": o_old, "new": o_new})
                flagged = True

        # unchanged = neither regressed nor improved (counts must add up)
        if not flagged:
            unchanged += 1

    removed = sorted(set(oc) - set(nc))
    for cid in removed:
        regressions.append({"cell_id": cid, "kind": "coverage",
                            "old": oc[cid]["detection_rate"], "new": None,
                            "tol": None})
    return {
        "old": old.get("campaign"), "new": new.get("campaign"),
        "regressions": regressions,
        "improvements": improvements,
        "added": sorted(set(nc) - set(oc)),
        "removed": removed,
        "unchanged": unchanged,
    }


def _fmt(x) -> str:
    return "—" if x is None else f"{100.0 * x:.2f}%"


def format_diff(diff: dict) -> str:
    """Markdown rendering (CI uploads this next to the artifacts)."""
    lines = [
        f"# Campaign diff: `{diff['old']}` -> `{diff['new']}`",
        "",
        f"{len(diff['regressions'])} regression(s), "
        f"{len(diff['improvements'])} improvement(s), "
        f"{diff['unchanged']} unchanged, {len(diff['added'])} added, "
        f"{len(diff['removed'])} removed",
    ]
    if diff["regressions"]:
        lines += ["", "## Regressions", "",
                  "| cell | metric | old | new |", "|---|---|---|---|"]
        for r in diff["regressions"]:
            lines.append(f"| `{r['cell_id']}` | {r['kind']} | "
                         f"{_fmt(r['old'])} | {_fmt(r['new'])} |")
    if diff["improvements"]:
        lines += ["", "## Improvements", "",
                  "| cell | metric | old | new |", "|---|---|---|---|"]
        for r in diff["improvements"]:
            lines.append(f"| `{r['cell_id']}` | {r['kind']} | "
                         f"{_fmt(r['old'])} | {_fmt(r['new'])} |")
    if diff["added"]:
        lines += ["", "New cells: " + ", ".join(
            f"`{c}`" for c in diff["added"])]
    lines.append("")
    return "\n".join(lines)


def run_diff(old_path: str, new_path: str, *, det_tol: float = 0.02,
             fp_tol: float = 0.02, overhead_tol: Optional[float] = None,
             out_path: Optional[str] = None,
             emit=print) -> int:
    """CLI body: load, diff, print/write markdown; 1 iff regressions."""
    diff = diff_artifacts(load_artifact(old_path), load_artifact(new_path),
                          det_tol=det_tol, fp_tol=fp_tol,
                          overhead_tol=overhead_tol)
    md = format_diff(diff)
    emit(md)
    if out_path:
        with open(out_path, "w") as f:
            f.write(md)
    return 1 if diff["regressions"] else 0


__all__ = ["diff_artifacts", "format_diff", "run_diff"]
