"""Model facade: family dispatch + input specs + loss functions.

``Model(cfg)`` exposes a uniform API over the zoo:

  init(key, quant, dtype)              -> LogicalParam tree
  loss(params, batch, ctx)             -> (loss, (metrics, report))
  prefill(params, batch, ctx, cache_len) -> (logits, cache, report)
  decode(params, cache, tokens, pos, ctx) -> (logits, cache, report)
  init_cache(batch, cache_len)         -> LogicalParam tree
  input_specs(shape)                   -> LogicalParam(ShapeDtypeStruct) tree

All batch leaves are LogicalParam-wrapped ShapeDtypeStructs in
``input_specs`` so the launcher can derive shardings uniformly.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core import policy
from repro.layers.common import Ctx
from repro.models import lm, rwkv, whisper
from repro.sharding import LogicalParam

IGNORE = -1


def cross_entropy(logits, labels, vocab: int):
    """Masked CE over padded vocab. logits [..., Vp] f32-castable."""
    lf = logits.astype(jnp.float32)
    mask = (labels >= 0) & (labels < vocab)
    safe = jnp.clip(labels, 0, lf.shape[-1] - 1)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    tgt = jnp.take_along_axis(lf, safe[..., None], axis=-1)[..., 0]
    ce = (lse - tgt) * mask
    return jnp.sum(ce) / jnp.maximum(jnp.sum(mask), 1)


class Model:
    def __init__(self, cfg: ArchConfig, max_pos: int = 4096):
        self.cfg = cfg
        self.max_pos = max_pos  # whisper learned-position table size

    # ------------------------------ init -----------------------------------
    def init(self, key, quant: bool = False, dtype=jnp.float32):
        cfg = self.cfg
        if cfg.family == "encdec":
            return whisper.init_whisper(key, cfg, self.max_pos, quant, dtype)
        if cfg.family == "ssm":
            return rwkv.init_rwkv(key, cfg, quant, dtype)
        return lm.init_lm(key, cfg, quant, dtype)

    # ------------------------------ loss ------------------------------------
    def loss(self, params, batch, ctx: Ctx):
        cfg = self.cfg
        if cfg.family == "encdec":
            logits, rep, aux = whisper.whisper_logits(
                params, batch["frames"], batch["tokens"], ctx, cfg)
            labels = batch["labels"]
        elif cfg.family == "ssm":
            logits, rep, aux = rwkv.rwkv_logits(params, batch["tokens"],
                                                ctx, cfg)
            labels = batch["labels"]
        else:
            patches = batch.get("patches")
            logits, rep, aux = lm.lm_logits(params, batch["tokens"], ctx,
                                            cfg, patches=patches)
            labels = batch["labels"]
            prefix = logits.shape[1] - labels.shape[1]
            if prefix > 0:   # vlm patches / hymba meta tokens: no loss there
                labels = jnp.concatenate(
                    [jnp.full(labels.shape[:1] + (prefix,), IGNORE,
                              labels.dtype), labels], axis=1)
        loss = cross_entropy(logits, labels, cfg.vocab)
        loss = loss + 0.01 * aux
        metrics = {"loss": loss, "aux_loss": aux, **rep.as_metrics()}
        return loss, (metrics, rep)

    # ---------------------------- serving -----------------------------------
    def prefill(self, params, batch, ctx: Ctx, cache_len: int):
        cfg = self.cfg
        if cfg.family == "encdec":
            return whisper.whisper_prefill(params, batch["frames"],
                                           batch["tokens"], ctx, cfg,
                                           cache_len=cache_len)
        if cfg.family == "ssm":
            return rwkv.rwkv_prefill(params, batch["tokens"], ctx, cfg)
        return lm.lm_prefill(params, batch["tokens"], ctx, cfg,
                             cache_len=cache_len,
                             patches=batch.get("patches"))

    def decode(self, params, cache, tokens, pos, ctx: Ctx):
        cfg = self.cfg
        if cfg.family == "encdec":
            return whisper.whisper_decode(params, cache, tokens, pos, ctx,
                                          cfg)
        if cfg.family == "ssm":
            return rwkv.rwkv_decode(params, cache, tokens, pos, ctx, cfg)
        return lm.lm_decode(params, cache, tokens, pos, ctx, cfg)

    def init_cache(self, batch: int, cache_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        if cfg.family == "encdec":
            return whisper.init_whisper_cache(cfg, batch, cache_len, dtype)
        if cfg.family == "ssm":
            return rwkv.init_rwkv_cache(cfg, batch, cache_len, dtype)
        return lm.init_lm_cache(cfg, batch, cache_len, dtype)

    # --------------------------- input specs --------------------------------
    def input_specs(self, shape: ShapeConfig):
        """LogicalParam(ShapeDtypeStruct) tree for the given shape suite."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len

        def tok(shp):
            return LogicalParam(jax.ShapeDtypeStruct(shp, jnp.int32),
                                ("batch",) + (None,) * (len(shp) - 1))

        if shape.kind == "decode":
            return {"tokens": tok((B,)), "pos": tok((B,))}

        specs = {}
        text_len = S
        if cfg.family == "vlm":
            text_len = S - cfg.n_patches
            specs["patches"] = LogicalParam(
                jax.ShapeDtypeStruct((B, cfg.n_patches, cfg.patch_dim),
                                     jnp.float32), ("batch", None, None))
        if cfg.family == "hybrid":
            text_len = S - cfg.meta_tokens   # meta tokens count toward S
        if cfg.family == "encdec":
            specs["frames"] = LogicalParam(
                jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model),
                                     jnp.float32), ("batch", None, None))
        specs["tokens"] = tok((B, text_len))
        if shape.kind == "train":
            specs["labels"] = tok((B, text_len))
        return specs


def build_model(cfg: ArchConfig, max_pos: int = 4096) -> Model:
    return Model(cfg, max_pos=max_pos)
