"""RWKV6 (Finch) language model: stacked time-mix + channel-mix blocks.

Decode state is O(1) per layer (matrix-valued S + two shift vectors), which
is why this arch runs the long_500k cell: the "KV cache" never grows.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import policy
from repro.layers import rwkv6 as rk
from repro.layers.common import Ctx
from repro.layers.embedding import apply_embed, init_embed, init_qembed
from repro.layers.linear import apply_linear, maybe_qlinear_init
from repro.layers.norms import init_layernorm, layernorm
from repro.models.lm import _stack_layer_axes
from repro.sharding import LogicalParam, constrain


def _init_layer(key, cfg, quant, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_layernorm(cfg.d_model, dtype),
        "tm": rk.init_timemix(k1, cfg.d_model, cfg.n_heads, quant=quant,
                              dtype=dtype),
        "ln2": init_layernorm(cfg.d_model, dtype),
        "cm": rk.init_channelmix(k2, cfg.d_model, cfg.d_ff, quant=quant,
                                 dtype=dtype),
    }


def init_rwkv(key, cfg: ArchConfig, quant: bool = False, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    vp = cfg.vocab_padded
    layers = jax.vmap(lambda k: _init_layer(k, cfg, quant, dtype))(
        jax.random.split(k2, cfg.n_layers))
    return {
        "embed": (init_qembed(k1, vp, cfg.d_model) if quant
                  else init_embed(k1, vp, cfg.d_model, dtype)),
        "ln0": init_layernorm(cfg.d_model, dtype),
        "layers": _stack_layer_axes(layers),
        "ln_out": init_layernorm(cfg.d_model, dtype),
        "head": maybe_qlinear_init(k3, cfg.d_model, vp, ("embed", "vocab"),
                                   quant, dtype, bias=False),
    }


def _zero_states(cfg: ArchConfig, b: int):
    dh = cfg.d_model // cfg.n_heads
    return {
        "S": jnp.zeros((b, cfg.n_heads, dh, dh), jnp.float32),
        "x_tm": jnp.zeros((b, cfg.d_model), jnp.float32),
        "x_cm": jnp.zeros((b, cfg.d_model), jnp.float32),
    }


def _block(layer_p, x, state, ctx, cfg):
    """x [B,S,d] + per-layer state -> (x', new_state, report)."""
    h = layernorm(layer_p["ln1"], x)
    y, x_tm, s_new, r1 = rk.timemix(
        layer_p["tm"], h, state["x_tm"].astype(h.dtype), state["S"], ctx,
        n_heads=cfg.n_heads)
    x = x + y
    h2 = layernorm(layer_p["ln2"], x)
    y2, x_cm, r2 = rk.channelmix(layer_p["cm"], h2,
                                 state["x_cm"].astype(h2.dtype), ctx)
    x = x + y2
    new_state = {"S": s_new, "x_tm": x_tm.astype(jnp.float32),
                 "x_cm": x_cm.astype(jnp.float32)}
    return x, new_state, policy.merge_reports(r1, r2)


def rwkv_hidden(params, tokens, ctx: Ctx, cfg: ArchConfig, states=None,
                with_states: bool = False):
    b = tokens.shape[0]
    x, rep0 = apply_embed(params["embed"], tokens, ctx)
    x = layernorm(params["ln0"], x)
    x = constrain(x, ("batch", "seq", None), ctx.rules)

    def body(carry, xs):
        x, rep = carry
        if states is None:
            layer_p = xs
            st = _zero_states(cfg, b)
        else:
            layer_p, st = xs
        x, new_st, r = _block(layer_p, x, st, ctx, cfg)
        x = constrain(x, ("batch", "seq", None), ctx.rules)
        return (x, policy.merge_reports(rep, r)), \
            (new_st if with_states else None)

    xs = params["layers"] if states is None else (params["layers"], states)
    step = jax.checkpoint(body) if not with_states else body
    (x, rep), new_states = jax.lax.scan(step, (x, rep0), xs,
                                        unroll=ctx.unroll_layers)
    x = layernorm(params["ln_out"], x)
    return x, new_states, rep


def rwkv_logits(params, tokens, ctx: Ctx, cfg: ArchConfig):
    x, _, rep = rwkv_hidden(params, tokens, ctx, cfg)
    logits, r_h = apply_linear(params["head"], x, ctx, name="lm_head")
    logits = constrain(logits, ("batch", "seq", "vocab"), ctx.rules)
    return logits, policy.merge_reports(rep, r_h), \
        jnp.zeros((), jnp.float32)


def rwkv_prefill(params, tokens, ctx: Ctx, cfg: ArchConfig):
    """Returns last-token logits + the recurrent state as 'cache'."""
    x, states, rep = rwkv_hidden(params, tokens, ctx, cfg,
                                 states=init_rwkv_state_values(cfg,
                                                               tokens.shape[0]),
                                 with_states=True)
    logits, r_h = apply_linear(params["head"], x[:, -1, :], ctx,
                               name="lm_head")
    return logits, states, policy.merge_reports(rep, r_h)


def rwkv_decode(params, cache, tokens, pos, ctx: Ctx, cfg: ArchConfig):
    """One token; cache = stacked per-layer states. pos unused (recurrent)."""
    del pos
    b = tokens.shape[0]
    x, rep = apply_embed(params["embed"], tokens, ctx)
    x = layernorm(params["ln0"], x[:, None, :])

    def body(carry, xs):
        x, rep = carry
        layer_p, st = xs
        x, new_st, r = _block(layer_p, x, st, ctx, cfg)
        return (x, policy.merge_reports(rep, r)), new_st

    (x, rep), new_states = jax.lax.scan(body, (x, rep),
                                        (params["layers"], cache),
                                        unroll=ctx.unroll_layers)
    x = layernorm(params["ln_out"], x[:, 0, :])
    logits, r_h = apply_linear(params["head"], x, ctx, name="lm_head")
    return logits, new_states, policy.merge_reports(rep, r_h)


def init_rwkv_state_values(cfg: ArchConfig, batch: int):
    """Plain-value stacked states [L, ...] (used inside jit)."""
    dh = cfg.d_model // cfg.n_heads
    L = cfg.n_layers
    return {
        "S": jnp.zeros((L, batch, cfg.n_heads, dh, dh), jnp.float32),
        "x_tm": jnp.zeros((L, batch, cfg.d_model), jnp.float32),
        "x_cm": jnp.zeros((L, batch, cfg.d_model), jnp.float32),
    }


def init_rwkv_cache(cfg: ArchConfig, batch: int, cache_len: int,
                    dtype=jnp.bfloat16):
    """LogicalParam tree; cache_len is irrelevant (O(1) state)."""
    del cache_len, dtype
    v = init_rwkv_state_values(cfg, batch)
    return {
        "S": LogicalParam(v["S"], ("layers", "batch", "heads_x", None, None)),
        "x_tm": LogicalParam(v["x_tm"], ("layers", "batch", None)),
        "x_cm": LogicalParam(v["x_cm"], ("layers", "batch", None)),
    }
