from repro.models.base import Model, build_model

__all__ = ["Model", "build_model"]
