"""Whisper-style encoder-decoder backbone (conv/mel frontend stubbed).

Encoder: precomputed frame embeddings [B, T_enc, d] + sinusoid positions,
non-causal self-attention, GeLU MLP.  Decoder: learned positions, causal
self-attention + cross-attention over the encoder memory.  LayerNorm
everywhere (faithful to Whisper), no RoPE.

``max_pos`` sizes the decoder's learned position table; the assigned shape
suite drives it to 32k/4k (beyond the real model's 448 — synthetic, noted
in DESIGN.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import policy
from repro.layers import attention as attn
from repro.layers.common import Ctx
from repro.layers.embedding import apply_embed, init_embed, init_qembed
from repro.layers.linear import apply_linear, maybe_qlinear_init
from repro.layers.mlp import init_mlp, mlp
from repro.layers.norms import init_layernorm, layernorm
from repro.layers.rope import sinusoid_positions
from repro.models.lm import _stack_layer_axes
from repro.sharding import LogicalParam, constrain, param


def _init_enc_layer(key, cfg, quant, dtype):
    ks = jax.random.split(key, 2)
    return {
        "ln1": init_layernorm(cfg.d_model, dtype),
        "attn": attn.init_attention(ks[0], cfg.d_model, cfg.n_heads,
                                    cfg.n_kv_heads, cfg.head_dim_,
                                    quant=quant, dtype=dtype, bias=True),
        "ln2": init_layernorm(cfg.d_model, dtype),
        "mlp": init_mlp(ks[1], cfg.d_model, cfg.d_ff, gated=False,
                        quant=quant, dtype=dtype, bias=True),
    }


def _init_dec_layer(key, cfg, quant, dtype):
    ks = jax.random.split(key, 3)
    return {
        "ln1": init_layernorm(cfg.d_model, dtype),
        "self": attn.init_attention(ks[0], cfg.d_model, cfg.n_heads,
                                    cfg.n_kv_heads, cfg.head_dim_,
                                    quant=quant, dtype=dtype, bias=True),
        "ln2": init_layernorm(cfg.d_model, dtype),
        "cross": attn.init_attention(ks[1], cfg.d_model, cfg.n_heads,
                                     cfg.n_kv_heads, cfg.head_dim_,
                                     quant=quant, dtype=dtype, bias=True),
        "ln3": init_layernorm(cfg.d_model, dtype),
        "mlp": init_mlp(ks[2], cfg.d_model, cfg.d_ff, gated=False,
                        quant=quant, dtype=dtype, bias=True),
    }


def init_whisper(key, cfg: ArchConfig, max_pos: int, quant: bool = False,
                 dtype=jnp.float32):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    vp = cfg.vocab_padded
    enc_layers = jax.vmap(
        lambda k: _init_enc_layer(k, cfg, quant, dtype))(
        jax.random.split(k1, cfg.enc_layers))
    dec_layers = jax.vmap(
        lambda k: _init_dec_layer(k, cfg, quant, dtype))(
        jax.random.split(k2, cfg.n_layers))
    return {
        "enc": {"layers": _stack_layer_axes(enc_layers),
                "ln_post": init_layernorm(cfg.d_model, dtype)},
        "dec": {
            "embed": (init_qembed(k3, vp, cfg.d_model) if quant
                      else init_embed(k3, vp, cfg.d_model, dtype)),
            "pos": param(k4, (max_pos, cfg.d_model), (None, "embed"), dtype),
            "layers": _stack_layer_axes(dec_layers),
            "ln": init_layernorm(cfg.d_model, dtype),
            "head": maybe_qlinear_init(k5, cfg.d_model, vp,
                                       ("embed", "vocab"), quant, dtype,
                                       bias=False),
        },
    }


def encode(params, frames, ctx: Ctx, cfg: ArchConfig):
    """frames [B, T, d] (stub frontend output) -> (memory [B,T,d], report)."""
    b, t, d = frames.shape
    x = frames.astype(ctx.compute_dtype) + \
        sinusoid_positions(t, d).astype(ctx.compute_dtype)[None]
    x = constrain(x, ("batch", "seq", None), ctx.rules)
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))

    def body(carry, layer_p):
        x, rep = carry
        h = layernorm(layer_p["ln1"], x)
        a, r1 = attn.attention(layer_p["attn"], h, ctx, n_heads=cfg.n_heads,
                               n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim_,
                               positions=positions, use_rope=False,
                               causal=False, chunk=cfg.attn_chunk)
        x = x + a
        h2 = layernorm(layer_p["ln2"], x)
        f, r2 = mlp(layer_p["mlp"], h2, ctx)
        x = x + f
        return (x, policy.merge_reports(rep, r1, r2)), None

    (x, rep), _ = jax.lax.scan(jax.checkpoint(body),
                               (x, policy.empty_report()),
                               params["enc"]["layers"],
                               unroll=ctx.unroll_layers)
    return layernorm(params["enc"]["ln_post"], x), rep


def _dec_embed(params, tokens, positions, ctx):
    x, rep = apply_embed(params["dec"]["embed"], tokens, ctx)
    pos_tab = params["dec"]["pos"].astype(ctx.compute_dtype)
    return x + pos_tab[positions], rep


def decode_train(params, tokens, memory, ctx: Ctx, cfg: ArchConfig,
                 with_cache: bool = False, cache_len: int = 0):
    """Teacher-forced decoder pass. Returns (x, cache|None, report)."""
    b, s = tokens.shape
    t_enc = memory.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    mem_pos = jnp.broadcast_to(jnp.arange(t_enc, dtype=jnp.int32)[None],
                               (b, t_enc))
    x, rep0 = _dec_embed(params, tokens, positions, ctx)
    x = constrain(x, ("batch", "seq", None), ctx.rules)

    def body(carry, layer_p):
        x, rep = carry
        h = layernorm(layer_p["ln1"], x)
        if with_cache:
            a, kv, r1 = attn.attention_prefill(
                layer_p["self"], h, ctx, n_heads=cfg.n_heads,
                n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim_,
                positions=positions, cache_len=cache_len, use_rope=False,
                chunk=cfg.attn_chunk)
        else:
            a, r1 = attn.attention(layer_p["self"], h, ctx,
                                   n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                                   head_dim=cfg.head_dim_,
                                   positions=positions, use_rope=False,
                                   causal=True, chunk=cfg.attn_chunk)
            kv = None
        x = x + a
        h2 = layernorm(layer_p["ln2"], x)
        c, r2 = attn.attention(layer_p["cross"], h2, ctx,
                               n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                               head_dim=cfg.head_dim_, positions=positions,
                               use_rope=False, causal=False, x_kv=memory,
                               kv_positions=mem_pos, chunk=cfg.attn_chunk)
        x = x + c
        h3 = layernorm(layer_p["ln3"], x)
        f, r3 = mlp(layer_p["mlp"], h3, ctx)
        x = x + f
        cache_l = None
        if with_cache:
            # static cross K/V for decode steps
            ck, rk = apply_linear(layer_p["cross"]["wk"], memory, ctx,
                                  name="cross.wk")
            cv, rv = apply_linear(layer_p["cross"]["wv"], memory, ctx,
                                  name="cross.wv")
            ck = ck.reshape(b, t_enc, cfg.n_kv_heads,
                            cfg.head_dim_).transpose(0, 2, 1, 3)
            cv = cv.reshape(b, t_enc, cfg.n_kv_heads,
                            cfg.head_dim_).transpose(0, 2, 1, 3)
            cache_l = {"self": kv, "cross": {"k": ck, "v": cv}}
            rep = policy.merge_reports(rep, rk, rv)
        return (x, policy.merge_reports(rep, r1, r2, r3)), cache_l

    step = body if with_cache else jax.checkpoint(body)
    (x, rep), cache = jax.lax.scan(step, (x, rep0), params["dec"]["layers"],
                                   unroll=ctx.unroll_layers)
    x = layernorm(params["dec"]["ln"], x)
    return x, cache, rep


def whisper_logits(params, frames, tokens, ctx: Ctx, cfg: ArchConfig):
    memory, r_enc = encode(params, frames, ctx, cfg)
    x, _, r_dec = decode_train(params, tokens, memory, ctx, cfg)
    logits, r_h = apply_linear(params["dec"]["head"], x, ctx,
                               name="lm_head")
    logits = constrain(logits, ("batch", "seq", "vocab"), ctx.rules)
    return logits, policy.merge_reports(r_enc, r_dec, r_h), \
        jnp.zeros((), jnp.float32)


def whisper_prefill(params, frames, tokens, ctx: Ctx, cfg: ArchConfig, *,
                    cache_len: int):
    memory, r_enc = encode(params, frames, ctx, cfg)
    x, cache, r_dec = decode_train(params, tokens, memory, ctx, cfg,
                                   with_cache=True, cache_len=cache_len)
    logits, r_h = apply_linear(params["dec"]["head"], x[:, -1, :], ctx,
                               name="lm_head")
    return logits, cache, policy.merge_reports(r_enc, r_dec, r_h)


def whisper_decode(params, cache, tokens, pos, ctx: Ctx, cfg: ArchConfig):
    """One decoder token against self- and (static) cross-caches."""
    b = tokens.shape[0]
    x, rep = apply_embed(params["dec"]["embed"], tokens, ctx)
    x = x + params["dec"]["pos"].astype(ctx.compute_dtype)[pos]

    def body(carry, xs):
        x, rep = carry
        layer_p, layer_cache = xs
        h = layernorm(layer_p["ln1"], x)
        a, new_self, r1 = attn.attention_decode(
            layer_p["self"], h, layer_cache["self"], pos, ctx,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
            head_dim=cfg.head_dim_, use_rope=False)
        x = x + a
        h2 = layernorm(layer_p["ln2"], x)
        c, _, r2 = attn.attention_decode(
            layer_p["cross"], h2, layer_cache["cross"], pos, ctx,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
            head_dim=cfg.head_dim_, use_rope=False, cross=True)
        x = x + c
        h3 = layernorm(layer_p["ln3"], x)
        f, r3 = mlp(layer_p["mlp"], h3[:, None, :], ctx)
        x = x + f[:, 0, :]
        new_cache = {"self": new_self, "cross": layer_cache["cross"]}
        return (x, policy.merge_reports(rep, r1, r2, r3)), new_cache

    (x, rep), new_cache = jax.lax.scan(body, (x, rep),
                                       (params["dec"]["layers"], cache),
                                       unroll=ctx.unroll_layers)
    x = layernorm(params["dec"]["ln"], x)
    logits, r_h = apply_linear(params["dec"]["head"], x, ctx,
                               name="lm_head")
    return logits, new_cache, policy.merge_reports(rep, r_h)


def init_whisper_cache(cfg: ArchConfig, batch: int, cache_len: int,
                       dtype=jnp.bfloat16):
    def kv(seq, axes):
        return {
            "k": LogicalParam(jnp.zeros(
                (cfg.n_layers, batch, cfg.n_kv_heads, seq, cfg.head_dim_),
                dtype), axes),
            "v": LogicalParam(jnp.zeros(
                (cfg.n_layers, batch, cfg.n_kv_heads, seq, cfg.head_dim_),
                dtype), axes),
        }
    return {
        "self": kv(cache_len, ("layers", "batch", None, "kv_seq", None)),
        "cross": kv(cfg.enc_seq, ("layers", "batch", None, None, None)),
    }
