"""Configurable decoder-only LM.

One implementation covers the dense (llama3.2 / internlm2 / qwen3 /
mistral-large), MoE (llama4-scout / granite-moe), hybrid (hymba: parallel
attention+mamba heads, meta tokens, SWA+global layers) and VLM
(llava-next: stub patch features + real projector) families.

Layers are stacked & scanned (single-layer HLO — compile-time and remat
friendly); every forward threads a FaultReport.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import policy
from repro.layers import attention as attn
from repro.layers import mamba as mam
from repro.layers.common import Ctx
from repro.layers.embedding import (apply_embed, init_embed, init_qembed)
from repro.layers.linear import apply_linear, maybe_qlinear_init
from repro.layers.mlp import init_mlp, mlp
from repro.layers.moe import init_moe, moe_ffn
from repro.layers.norms import init_rmsnorm, rmsnorm
from repro.sharding import LogicalParam, constrain, is_lp, param

HUGE_WINDOW = 1 << 30


# ------------------------------- init ---------------------------------------

def init_layer(key, cfg: ArchConfig, quant: bool, dtype):
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p = {
        "ln1": init_rmsnorm(d, dtype),
        "ln2": init_rmsnorm(d, dtype),
        "attn": attn.init_attention(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                                    cfg.head_dim_, qk_norm=cfg.qk_norm,
                                    quant=quant, dtype=dtype),
    }
    if cfg.family == "moe":
        p["moe"] = init_moe(ks[1], d, cfg.d_ff, cfg.n_experts, quant=quant,
                            dtype=dtype)
    else:
        p["mlp"] = init_mlp(ks[1], d, cfg.d_ff, gated=cfg.gated_mlp,
                            quant=quant, dtype=dtype)
    if cfg.family == "hybrid":
        p["ssm"] = mam.init_mamba(ks[2], d, cfg.d_inner_, cfg.ssm_state,
                                  quant=quant, dtype=dtype)
        p["attn_out_norm"] = init_rmsnorm(d, dtype)
        p["ssm_out_norm"] = init_rmsnorm(d, dtype)
    return p


def _stack_layer_axes(tree):
    return jax.tree.map(
        lambda p: LogicalParam(p.value, ("layers",) + p.axes), tree,
        is_leaf=is_lp)


def init_lm(key, cfg: ArchConfig, quant: bool = False, dtype=jnp.float32):
    k_embed, k_layers, k_head, k_extra = jax.random.split(key, 4)
    vp = cfg.vocab_padded
    p = {
        "embed": (init_qembed(k_embed, vp, cfg.d_model) if quant
                  else init_embed(k_embed, vp, cfg.d_model, dtype)),
        "final_norm": init_rmsnorm(cfg.d_model, dtype),
        "lm_head": maybe_qlinear_init(k_head, cfg.d_model, vp,
                                      ("embed", "vocab"), quant, dtype,
                                      bias=False),
    }
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(
        lambda k: init_layer(k, cfg, quant, dtype))(layer_keys)
    p["layers"] = _stack_layer_axes(layers)
    if cfg.meta_tokens:
        p["meta"] = param(k_extra, (cfg.meta_tokens, cfg.d_model),
                          (None, "embed"), dtype)
    if cfg.patch_dim:
        ks = jax.random.split(k_extra, 2)
        p["projector"] = {
            "fc1": maybe_qlinear_init(ks[0], cfg.patch_dim, cfg.d_model,
                                      ("frontend", "embed"), quant, dtype),
            "fc2": maybe_qlinear_init(ks[1], cfg.d_model, cfg.d_model,
                                      ("embed", "embed2"), quant, dtype),
        }
    return p


def window_schedule(cfg: ArchConfig) -> jnp.ndarray:
    """Per-layer attention window ([L] int32; HUGE = full attention)."""
    ws = []
    for i in range(cfg.n_layers):
        if cfg.sliding_window == 0 or cfg.is_global_layer(i):
            ws.append(HUGE_WINDOW)
        else:
            ws.append(cfg.sliding_window)
    return jnp.asarray(ws, jnp.int32)


# ----------------------------- shared pieces --------------------------------

def _prefix_embeds(params, x_text, ctx, cfg: ArchConfig, patches,
                   reports: list):
    """Prepend projector(patches) (VLM) and meta tokens (Hymba)."""
    b = x_text.shape[0]
    parts = []
    if cfg.patch_dim and patches is not None:
        h, r1 = apply_linear(params["projector"]["fc1"],
                             patches.astype(ctx.compute_dtype), ctx,
                             name="projector.fc1")
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(ctx.compute_dtype)
        h, r2 = apply_linear(params["projector"]["fc2"], h, ctx,
                             name="projector.fc2")
        reports += [r1, r2]
        parts.append(h)
    if cfg.meta_tokens:
        meta = jnp.broadcast_to(
            params["meta"].astype(ctx.compute_dtype)[None],
            (b, cfg.meta_tokens, cfg.d_model))
        parts.insert(0, meta)
    parts.append(x_text)
    return jnp.concatenate(parts, axis=1) if len(parts) > 1 else x_text


def _ffn(layer_p, h, ctx, cfg: ArchConfig):
    if cfg.family == "moe":
        return moe_ffn(layer_p["moe"], h, ctx,
                       n_experts=cfg.n_experts, top_k=cfg.top_k,
                       capacity_factor=cfg.capacity_factor,
                       group_size=cfg.moe_group)
    y, rep = mlp(layer_p["mlp"], h, ctx)
    return y, jnp.zeros((), jnp.float32), rep


# ------------------------------ full-seq forward ----------------------------

def lm_hidden(params, tokens, ctx: Ctx, cfg: ArchConfig, *,
              patches=None, with_cache: bool = False, cache_len: int = 0):
    """Embed + all layers. Returns (x [B,S',d], cache|None, report, aux)."""
    reports: list = []
    x_text, rep0 = apply_embed(params["embed"], tokens, ctx)
    reports.append(rep0)
    x = _prefix_embeds(params, x_text, ctx, cfg, patches, reports)
    b, s_total, d = x.shape
    x = constrain(x, ("batch", "seq", None), ctx.rules)
    positions = jnp.broadcast_to(jnp.arange(s_total, dtype=jnp.int32)[None],
                                 (b, s_total))
    windows = window_schedule(cfg)

    def body(carry, xs):
        x, rep, aux = carry
        layer_p, window_l = xs
        h = rmsnorm(layer_p["ln1"], x)
        if with_cache:
            a_out, cache_l, r_a = attn.attention_prefill(
                layer_p["attn"], h, ctx, n_heads=cfg.n_heads,
                n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim_,
                positions=positions, cache_len=cache_len,
                rope_theta=cfg.rope_theta, use_rope=cfg.use_rope,
                window=window_l, prefix_global=cfg.meta_tokens,
                chunk=cfg.attn_chunk)
        else:
            a_out, r_a = attn.attention(
                layer_p["attn"], h, ctx, n_heads=cfg.n_heads,
                n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim_,
                positions=positions, rope_theta=cfg.rope_theta,
                use_rope=cfg.use_rope, causal=True, window=window_l,
                prefix_global=cfg.meta_tokens, chunk=cfg.attn_chunk)
            cache_l = None
        rep = policy.merge_reports(rep, r_a)
        if cfg.family == "hybrid":
            ssm_cache0 = {
                "conv": jnp.zeros((b, mam.CONV_K - 1, cfg.d_inner_),
                                  jnp.float32),
                "h": jnp.zeros((b, cfg.d_inner_, cfg.ssm_state),
                               jnp.float32),
            }
            s_out, ssm_cache, r_s = mam.mamba(
                layer_p["ssm"], h, ssm_cache0, ctx, d_inner=cfg.d_inner_,
                n_state=cfg.ssm_state)
            rep = policy.merge_reports(rep, r_s)
            mix = 0.5 * (rmsnorm(layer_p["attn_out_norm"], a_out)
                         + rmsnorm(layer_p["ssm_out_norm"], s_out))
            x = x + mix
            if with_cache:
                cache_l = {"attn": cache_l, "ssm": ssm_cache}
        else:
            x = x + a_out
            if with_cache:
                cache_l = {"attn": cache_l}
        h2 = rmsnorm(layer_p["ln2"], x)
        f_out, aux_l, r_f = _ffn(layer_p, h2, ctx, cfg)
        x = x + f_out
        x = constrain(x, ("batch", "seq", None), ctx.rules)
        rep = policy.merge_reports(rep, r_f)
        return (x, rep, aux + aux_l), cache_l

    if not with_cache and not ctx.no_remat:
        body = jax.checkpoint(body)
    carry0 = (x, policy.merge_reports(*reports), jnp.zeros((), jnp.float32))
    (x, rep, aux), cache = jax.lax.scan(body, carry0,
                                        (params["layers"], windows),
                                        unroll=ctx.unroll_layers)
    x = rmsnorm(params["final_norm"], x)
    return x, cache, rep, aux


def lm_logits(params, tokens, ctx: Ctx, cfg: ArchConfig, patches=None):
    """Training forward: full logits [B, S', vocab_padded]."""
    x, _, rep, aux = lm_hidden(params, tokens, ctx, cfg, patches=patches)
    logits, r_h = apply_linear(params["lm_head"], x, ctx, name="lm_head")
    logits = constrain(logits, ("batch", "seq", "vocab"), ctx.rules)
    return logits, policy.merge_reports(rep, r_h), aux


def lm_prefill(params, tokens, ctx: Ctx, cfg: ArchConfig, *, cache_len: int,
               patches=None):
    """Prefill: last-position logits + populated KV cache."""
    x, cache, rep, _ = lm_hidden(params, tokens, ctx, cfg, patches=patches,
                                 with_cache=True, cache_len=cache_len)
    last = x[:, -1, :]
    logits, r_h = apply_linear(params["lm_head"], last, ctx,
                               name="lm_head")
    return logits, cache, policy.merge_reports(rep, r_h)


# ------------------------------ decode --------------------------------------

def lm_decode(params, cache, tokens, pos, ctx: Ctx, cfg: ArchConfig):
    """One decode step. tokens [B] int32, pos [B] int32 (absolute, incl. any
    prefix).  Returns (logits [B, vp], new_cache, report)."""
    x, rep = apply_embed(params["embed"], tokens, ctx)     # [B, d]
    windows = window_schedule(cfg)

    def body(carry, xs):
        x, rep = carry
        layer_p, layer_cache, window_l = xs
        h = rmsnorm(layer_p["ln1"], x)
        a_out, new_attn, r_a = attn.attention_decode(
            layer_p["attn"], h, layer_cache["attn"], pos, ctx,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
            head_dim=cfg.head_dim_, rope_theta=cfg.rope_theta,
            use_rope=cfg.use_rope, window=window_l,
            prefix_global=cfg.meta_tokens)
        rep = policy.merge_reports(rep, r_a)
        new_cache_l = {"attn": new_attn}
        if cfg.family == "hybrid":
            s_out, new_ssm, r_s = mam.mamba(
                layer_p["ssm"], h[:, None, :], layer_cache["ssm"], ctx,
                d_inner=cfg.d_inner_, n_state=cfg.ssm_state)
            rep = policy.merge_reports(rep, r_s)
            mix = 0.5 * (rmsnorm(layer_p["attn_out_norm"], a_out)
                         + rmsnorm(layer_p["ssm_out_norm"], s_out[:, 0, :]))
            x = x + mix
            new_cache_l["ssm"] = new_ssm
        else:
            x = x + a_out
        h2 = rmsnorm(layer_p["ln2"], x)
        if cfg.family == "moe":
            f_out, _, r_f = moe_ffn(layer_p["moe"], h2[:, None, :], ctx,
                                    n_experts=cfg.n_experts,
                                    top_k=cfg.top_k,
                                    capacity_factor=cfg.capacity_factor,
                                    group_size=cfg.moe_group)
            f_out = f_out[:, 0, :]
        else:
            f_out, r_f = mlp(layer_p["mlp"], h2, ctx)
        x = x + f_out
        rep = policy.merge_reports(rep, r_f)
        return (x, rep), new_cache_l

    (x, rep), new_cache = jax.lax.scan(
        body, (x, rep), (params["layers"], cache, windows),
        unroll=ctx.unroll_layers)
    x = rmsnorm(params["final_norm"], x)
    logits, r_h = apply_linear(params["lm_head"], x, ctx, name="lm_head")
    return logits, new_cache, policy.merge_reports(rep, r_h)


# ------------------------------ cache init ----------------------------------

def init_lm_cache(cfg: ArchConfig, batch: int, cache_len: int,
                  dtype=jnp.bfloat16):
    """LogicalParam tree of zeros, stacked [L, ...] for the layer scan."""
    total = cache_len + cfg.meta_tokens
    kv = {
        "k": LogicalParam(
            jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads, total,
                       cfg.head_dim_), dtype),
            ("layers", "batch", None, "kv_seq", None)),
        "v": LogicalParam(
            jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads, total,
                       cfg.head_dim_), dtype),
            ("layers", "batch", None, "kv_seq", None)),
    }
    cache = {"attn": kv}
    if cfg.family == "hybrid":
        cache["ssm"] = {
            "conv": LogicalParam(
                jnp.zeros((cfg.n_layers, batch, mam.CONV_K - 1,
                           cfg.d_inner_), jnp.float32),
                ("layers", "batch", None, "mlp")),
            "h": LogicalParam(
                jnp.zeros((cfg.n_layers, batch, cfg.d_inner_,
                           cfg.ssm_state), jnp.float32),
                ("layers", "batch", "mlp", None)),
        }
    return cache
