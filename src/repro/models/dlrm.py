"""DLRM — the paper's own architecture (bottom MLP + EmbeddingBags +
pairwise interaction + top MLP), int8-quantized with ABFT end to end.

This model is the native home of the two protected operators: every MLP
GEMM runs Algorithm 1, every table lookup runs Algorithm 2.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.dlrm import DlrmExtras
from repro.core import policy
from repro.layers.common import Ctx
from repro.layers.embedding import embedding_bag_fwd, init_embedding_bag
from repro.layers.linear import apply_linear, maybe_qlinear_init
from repro.sharding import LogicalParam, is_lp


def _init_mlp_stack(key, dims, quant, dtype, in_axis="embed",
                    out_axis="mlp"):
    ks = jax.random.split(key, len(dims) - 1)
    return [maybe_qlinear_init(ks[i], dims[i], dims[i + 1],
                               (in_axis, out_axis) if i % 2 == 0
                               else (out_axis, in_axis),
                               quant, dtype)
            for i in range(len(dims) - 1)]


def init_dlrm(key, ex: DlrmExtras, quant: bool = True, dtype=jnp.float32,
              table_rows: int | None = None):
    rows = table_rows or ex.table_rows
    k1, k2, k3 = jax.random.split(key, 3)
    bottom = _init_mlp_stack(k1, (ex.n_dense,) + ex.bottom_mlp, quant, dtype)
    n_feat = ex.n_tables + 1
    inter_dim = ex.emb_dim + n_feat * (n_feat - 1) // 2
    top = _init_mlp_stack(k2, (inter_dim,) + ex.top_mlp, quant, dtype)
    tables = jax.vmap(
        lambda k: init_embedding_bag(k, rows, ex.emb_dim))(
        jax.random.split(k3, ex.n_tables))
    tables = jax.tree.map(
        lambda p: LogicalParam(p.value, ("tables",) + p.axes), tables,
        is_leaf=is_lp)
    return {"bottom": bottom, "top": top, "tables": tables}


def _mlp_stack(layers, x, ctx, final_relu=False, name="mlp"):
    rep = policy.empty_report()
    for i, p in enumerate(layers):
        x, r = apply_linear(p, x, ctx, name=f"{name}.{i}")
        rep = policy.merge_reports(rep, r)
        if i < len(layers) - 1 or final_relu:
            x = jax.nn.relu(x.astype(jnp.float32)).astype(x.dtype)
    return x, rep


def dlrm_forward(params, dense, indices, ctx: Ctx, ex: DlrmExtras,
                 weights=None) -> Tuple[jax.Array, policy.FaultReport]:
    """dense [B, n_dense] f32; indices [n_tables, B, pool] int32 (−1 pad).

    Returns (logit [B], report)."""
    b = dense.shape[0]
    bot, r1 = _mlp_stack(params["bottom"], dense.astype(ctx.compute_dtype),
                         ctx, final_relu=True, name="bottom")  # [B, emb]

    def one_table(tp, idx):
        r, rep = embedding_bag_fwd(tp, idx, ctx)
        return r, rep

    embs, table_reps = jax.vmap(one_table)(params["tables"], indices)
    # vmapped FaultReport: reduce counts over the table axis
    table_rep = jax.tree.map(lambda x: jnp.sum(x), table_reps)

    feats = jnp.concatenate([bot[None].astype(jnp.float32),
                             embs.astype(jnp.float32)], axis=0)  # [F,B,e]
    f = feats.transpose(1, 0, 2)                                # [B,F,e]
    gram = jnp.einsum("bfe,bge->bfg", f, f)                     # [B,F,F]
    iu = jnp.triu_indices(f.shape[1], k=1)
    inter = gram[:, iu[0], iu[1]]                               # [B,F(F-1)/2]
    z = jnp.concatenate([bot.astype(jnp.float32), inter], axis=-1)
    logit, r2 = _mlp_stack(params["top"], z.astype(ctx.compute_dtype), ctx,
                           name="top")
    return logit[:, 0], policy.merge_reports(r1, table_rep, r2)
