"""Declarative protection plans: which ops are protected, how, with what
policy and thresholds.

A :class:`ProtectionPlan` is an ordered tuple of :class:`OpRule` patterns.
Every protected call site is addressed as ``"<op_kind>/<path>"`` — e.g.
``qgemm/attn.wq``, ``embedding_bag/tables``, ``kv_cache/attn`` — and a rule
pattern is an ``fnmatch`` glob over that string (a pattern without ``/``
also matches the bare op kind, so ``qgemm`` covers every int8 GEMM).
Rules are applied in order, later rules overriding earlier ones
field-by-field; unset (``None``) fields inherit.  Resolution produces a
:class:`ResolvedRule` with concrete defaults.

Plans are frozen (hashable — they ride inside the jit-static layer ``Ctx``),
serialize to/from dicts for configs, and parse from compact CLI strings::

    *:policy=log                          # protect everything, log-only
    embedding_bag:off                     # ...but EB protection disabled
    qgemm:policy=recompute:retries=2      # int8 GEMMs retry on detection
    qgemm/attn.*:scheme=unfused           # attention projections, BLAS-2
    embedding_bag:rel_bound=1e-4          # looser Eq. (5) threshold

joined with commas:
``"*:policy=log,embedding_bag:off,qgemm/attn.*:scheme=unfused"``.
"""
from __future__ import annotations

import dataclasses
import fnmatch
from typing import Optional, Tuple

#: the detect->act policies repro.core.policy implements.
POLICY_NAMES = ("log", "recompute", "correct", "abort")

#: how a float-checked op's ``rel_bound`` is chosen: ``static`` (the
#: rule's/op's constant — the default) or ``adaptive`` (an online
#: FP-budget controller from ``repro.adapt`` owns it and rewrites the
#: bound at evaluation ticks).  The field is pure metadata to the
#: resolver — the adapt layer reads it to decide which ops it manages.
THRESHOLD_MODES = ("static", "adaptive")

#: op kinds that default to DISABLED unless a matching rule enables them:
#: the quantized KV cache changes the cache representation (lossy int8),
#: and float-GEMM ABFT adds training-path work — both are opt-in, so a
#: plan like ``"*:policy=recompute"`` tunes the paper's serving operators
#: without silently switching these on.  An explicit ``kv_cache:on`` (or a
#: wildcard rule carrying ``on``/``off``) overrides.  The paged cache
#: (``kv_cache_paged``) follows the same opt-in contract as the
#: contiguous one — same representation change, same policy surface.
OPT_IN_OPS = ("float_gemm", "kv_cache", "kv_cache_paged")


@dataclasses.dataclass(frozen=True)
class OpRule:
    """One pattern's (partial) protection settings. ``None`` = inherit."""
    pattern: str = "*"
    enabled: Optional[bool] = None
    scheme: Optional[str] = None          # adapter-specific (e.g. qgemm:
    policy: Optional[str] = None          #   packed | unfused | pallas)
    rel_bound: Optional[float] = None     # float-checked ops' threshold
    max_retries: Optional[int] = None     # recompute policy budget
    threshold: Optional[str] = None       # static | adaptive (None=inherit)

    def __post_init__(self):
        if self.policy is not None and self.policy not in POLICY_NAMES:
            raise ValueError(f"unknown policy {self.policy!r}; "
                             f"have {POLICY_NAMES}")
        if self.max_retries is not None and self.max_retries < 1:
            raise ValueError("max_retries must be >= 1")
        if self.threshold is not None and \
                self.threshold not in THRESHOLD_MODES:
            raise ValueError(f"unknown threshold mode {self.threshold!r}; "
                             f"have {THRESHOLD_MODES}")

    def matches(self, op: str, path: str = "") -> bool:
        target = f"{op}/{path}"
        return (fnmatch.fnmatchcase(target, self.pattern)
                or fnmatch.fnmatchcase(op, self.pattern))

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class ResolvedRule:
    """A fully-resolved rule for one call site (all defaults applied)."""
    enabled: bool = True
    scheme: Optional[str] = None          # None = adapter default
    policy: str = "log"
    rel_bound: Optional[float] = None     # None = op default
    max_retries: int = 1
    threshold: str = "static"


@dataclasses.dataclass(frozen=True)
class ProtectionPlan:
    """Ordered protection rules over every ABFT-protected operator."""
    rules: Tuple[OpRule, ...] = ()
    name: str = ""

    def __post_init__(self):
        if not isinstance(self.rules, tuple):
            object.__setattr__(self, "rules", tuple(self.rules))

    # ------------------------------ resolve ---------------------------------

    def resolve(self, op: str, path: str = "") -> ResolvedRule:
        enabled = op not in OPT_IN_OPS
        scheme, policy = None, None
        rel_bound, max_retries = None, None
        threshold = None
        for r in self.rules:
            if not r.matches(op, path):
                continue
            if r.enabled is not None:
                enabled = r.enabled
            if r.scheme is not None:
                scheme = r.scheme
            if r.policy is not None:
                policy = r.policy
            if r.rel_bound is not None:
                rel_bound = r.rel_bound
            if r.max_retries is not None:
                max_retries = r.max_retries
            if r.threshold is not None:
                threshold = r.threshold
        return ResolvedRule(enabled=enabled, scheme=scheme,
                            policy=policy or "log", rel_bound=rel_bound,
                            max_retries=max_retries or 1,
                            threshold=threshold or "static")

    def with_rules(self, *rules: OpRule) -> "ProtectionPlan":
        """A new plan with ``rules`` appended (they override)."""
        return dataclasses.replace(self, rules=self.rules + tuple(rules))

    def escalated(self) -> "ProtectionPlan":
        """The detect→act escalation of this plan: every ``log`` policy
        upgraded to ``recompute`` (and a leading wildcard recompute rule
        so un-policied sites stop at log no longer).  Enablement is left
        untouched — no op switches on or off, so the escalated plan runs
        against the same compiled cache/batch structure; the serving
        engine applies it when the health monitor degrades a lane."""
        rules = tuple(
            dataclasses.replace(r, policy="recompute")
            if r.policy == "log" else r
            for r in self.rules)
        return dataclasses.replace(
            self, rules=(OpRule("*", policy="recompute"),) + rules,
            name=f"{self.name}+escalated" if self.name else "escalated")

    # ------------------------------ serde -----------------------------------

    @classmethod
    def parse(cls, text: str, name: str = "") -> "ProtectionPlan":
        """Parse the compact CLI form (see module docstring)."""
        rules = []
        for clause in (text or "").split(","):
            clause = clause.strip()
            if not clause:
                continue
            parts = clause.split(":")
            head, settings = parts[0], parts[1:]
            if head in ("on", "off") and not settings:
                # bare on/off applies to everything
                head, settings = "*", [head]
            kw = {}
            for s in settings:
                s = s.strip()
                if s == "on":
                    kw["enabled"] = True
                elif s == "off":
                    kw["enabled"] = False
                elif "=" in s:
                    k, v = s.split("=", 1)
                    k = k.strip()
                    if k == "policy":
                        kw["policy"] = v.strip()
                    elif k == "scheme":
                        kw["scheme"] = v.strip()
                    elif k == "rel_bound":
                        kw["rel_bound"] = float(v)
                    elif k in ("retries", "max_retries"):
                        kw["max_retries"] = int(v)
                    elif k == "threshold":
                        kw["threshold"] = v.strip()
                    else:
                        raise ValueError(f"unknown plan setting {k!r} in "
                                         f"clause {clause!r}")
                else:
                    raise ValueError(f"bad plan clause {clause!r}: "
                                     f"setting {s!r} is not on/off/key=val")
            rules.append(OpRule(pattern=head, **kw))
        return cls(rules=tuple(rules), name=name or text)

    def to_dict(self) -> dict:
        return {"name": self.name,
                "rules": [r.to_dict() for r in self.rules]}

    @classmethod
    def from_dict(cls, d: dict) -> "ProtectionPlan":
        return cls(rules=tuple(OpRule(**r) for r in d.get("rules", ())),
                   name=d.get("name", ""))

    @classmethod
    def from_any(cls, spec, name: str = "") -> "ProtectionPlan":
        """Resolve a plan from whatever a config hands us.

        * a :class:`ProtectionPlan` passes through;
        * a dict goes through :meth:`from_dict` (a bare list is treated
          as the ``rules`` entry);
        * a string starting with ``@`` names a JSON file holding any of
          the above (or a compact plan string);
        * any other string is the compact CLI form (:meth:`parse`).
        """
        if isinstance(spec, ProtectionPlan):
            return spec
        if isinstance(spec, dict):
            return cls.from_dict(spec)
        if isinstance(spec, (list, tuple)):
            return cls.from_dict({"rules": list(spec), "name": name})
        if isinstance(spec, str):
            s = spec.strip()
            if s.startswith("@"):
                import json
                import os
                path = s[1:]
                with open(path) as f:
                    loaded = json.load(f)
                base = os.path.splitext(os.path.basename(path))[0]
                return cls.from_any(loaded, name=name or base)
            return cls.parse(s, name=name)
        raise TypeError(f"cannot build a ProtectionPlan from "
                        f"{type(spec).__name__}")

    def describe(self) -> str:
        if not self.rules:
            return "<all ops protected, policy=log>"
        out = []
        for r in self.rules:
            bits = [r.pattern]
            if r.enabled is not None:
                bits.append("on" if r.enabled else "off")
            if r.policy is not None:
                bits.append(f"policy={r.policy}")
            if r.scheme is not None:
                bits.append(f"scheme={r.scheme}")
            if r.rel_bound is not None:
                bits.append(f"rel_bound={r.rel_bound:g}")
            if r.max_retries is not None:
                bits.append(f"retries={r.max_retries}")
            if r.threshold is not None:
                bits.append(f"threshold={r.threshold}")
            out.append(":".join(bits))
        return ",".join(out)


def default_plan() -> ProtectionPlan:
    """Serving default: the paper's two operators protected with policy
    ``log``; the :data:`OPT_IN_OPS` (float GEMM, KV cache) stay off until
    a rule enables them — byte-for-byte the behavior of the legacy
    ``Ctx(abft=True)`` flags."""
    return ProtectionPlan(rules=(OpRule("*", policy="log"),),
                          name="default")


def unprotected_plan() -> ProtectionPlan:
    """Everything off — the overhead-comparison baseline."""
    return ProtectionPlan(rules=(OpRule("*", enabled=False),),
                          name="unprotected")
