"""The :class:`ProtectedOp` protocol and the registered adapters.

Every ABFT-protected operator family exposes one uniform surface:

* ``encode(params) -> encoded`` — the amortized, load-time encoding step
  (pack the weight checksum, precompute table row sums, quantize+checksum
  KV rows);
* ``__call__(encoded, *inputs, rule=...) -> (out, Check)`` — the protected
  hot-path call: run the op, verify, return the result plus a
  :class:`Check`;
* ``unprotected(encoded, *inputs) -> out`` — the baseline the overhead
  benchmarks (and disabled plan rules) run.

Adapters registered here (``qgemm``, ``float_gemm``, ``embedding_bag``,
``kv_cache``) dispatch through :mod:`repro.kernels.ops` where a Pallas
kernel exists, so scheme selection (``packed`` / ``unfused`` / ``pallas``)
is a plan concern, not a call-site concern.  Register a custom adapter with
:func:`register_op`; its name becomes a report key and a plan pattern.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Protocol, Tuple, \
    runtime_checkable

import jax
import jax.numpy as jnp

from repro.core import (EB_REL_BOUND, LANE, QuantKV, abft_gemm_f32,
                        attend_quantized, correct_single_error,
                        correct_weight_flip, dequantize_kv, embedding_bag,
                        encode_activation_checksum, encode_weight_f32,
                        pack_encoded_b, quantize_kv_rows, table_rowsums,
                        update_kv_row, verify_rows)
from repro.core.policy import register_op_kind
from repro.kernels import ops as kops
from repro.paging.cache import attend_paged, paged_append
from repro.protect.plan import ResolvedRule

_DEFAULT_RULE = ResolvedRule()


class Check(NamedTuple):
    """What a protected call learned: residual error count, an optional
    per-row/bag error mask, and adapter-specific correction aux (e.g. the
    expected column sums the ``correct`` policy consumes)."""
    err_count: jax.Array
    err_mask: Optional[jax.Array] = None
    aux: Any = None


@runtime_checkable
class ProtectedOp(Protocol):
    """Structural protocol every adapter satisfies."""
    name: str
    schemes: Tuple[str, ...]
    supports_correct: bool

    def encode(self, params): ...                        # noqa: E704

    def __call__(self, encoded, *inputs, rule=None): ...  # noqa: E704

    def unprotected(self, encoded, *inputs): ...         # noqa: E704


# ---------------------------------------------------------------------------
# int8 GEMM (paper Algorithm 1)
# ---------------------------------------------------------------------------

class QGemmOp:
    """Quantized GEMM: encoded = packed B' (int8 [k, n+LANE]), input = A_q.

    Schemes: ``packed`` (fused checksum column, Pallas on TPU / XLA ref on
    CPU), ``pallas`` (force the Pallas kernel, interpret-mode off-TPU),
    ``unfused`` (the BLAS-2 baseline the paper argues against §IV-A3).

    ``encoded`` may also be ``(packed, colsum_ref)`` where ``colsum_ref``
    is the exact int32 column sums of the clean B block (amortized at
    pack time, like the row checksum).  With it, the ``correct`` policy
    additionally repairs single *weight* flips — a corrupted ``B[k, j]``
    poisons a whole output column, which the single-cell accumulator
    repair cannot handle, but the two stale B encodings localize (k, j)
    and the exact delta (:func:`repro.core.correct_weight_flip`).
    """
    name = "qgemm"
    schemes = ("packed", "pallas", "unfused")
    supports_correct = True
    lane = LANE

    def encode(self, w_q: jax.Array) -> jax.Array:
        return pack_encoded_b(w_q)

    @staticmethod
    def _unpack(encoded):
        if isinstance(encoded, tuple):
            return encoded
        return encoded, None

    def out_dim(self, encoded) -> int:
        packed, _ = self._unpack(encoded)
        return packed.shape[-1] - LANE

    def dequant_colsum(self, w_q: jax.Array) -> jax.Array:
        """The Eq. 1 rank-1 requantization constant: f32 column sums of
        the int8 weight block ([..., k, n] -> [..., n]).  One definition —
        a colsum out of sync with the weights is silent output corruption,
        not a detection miss, so every producer (init, quantization,
        re-encoding) must share it."""
        return jnp.sum(w_q.astype(jnp.int32), axis=-2).astype(jnp.float32)

    def _aux(self, col_check, a_q, packed, colsum_ref):
        if col_check is not None and colsum_ref is not None:
            return {"col_check": col_check, "a_q": a_q, "packed": packed,
                    "colsum_ref": colsum_ref}
        return col_check

    def __call__(self, encoded, a_q, *, rule: ResolvedRule = _DEFAULT_RULE):
        packed, colsum_ref = self._unpack(encoded)
        scheme = rule.scheme or "packed"
        want_col = rule.policy == "correct"
        n = self.out_dim(packed)
        if scheme == "unfused":
            b_q = packed[:, :n]
            checksum = packed[:, n]                        # lane 0 of block
            c = jax.lax.dot_general(a_q, b_q, (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.int32)
            check_col = jax.lax.dot_general(
                a_q, checksum, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32)
            err_rows, err = verify_rows(c, check_col)
            col_check = None
            if want_col:
                col_check = jax.lax.dot_general(
                    encode_activation_checksum(a_q),
                    b_q.astype(jnp.int32), (((0,), (0,)), ((), ())),
                    preferred_element_type=jnp.int32)
            return c, Check(err, err_rows,
                            self._aux(col_check, a_q, packed, colsum_ref))
        if scheme not in ("packed", "pallas"):
            raise ValueError(f"unknown qgemm scheme {scheme!r}; "
                             f"have {self.schemes}")
        use_pallas = True if scheme == "pallas" else None
        out = kops.abft_qgemm(a_q, packed, use_pallas=use_pallas,
                              with_colcheck=want_col)
        if want_col:
            c, err_rows, col_check = out
        else:
            (c, err_rows), col_check = out, None
        err_mask = err_rows.astype(bool)
        return c, Check(jnp.sum(err_rows).astype(jnp.int32), err_mask,
                        self._aux(col_check, a_q, packed, colsum_ref))

    def unprotected(self, encoded, a_q):
        packed, _ = self._unpack(encoded)
        n = self.out_dim(packed)
        return jax.lax.dot_general(a_q, packed[:, :n],
                                   (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.int32)

    def correct(self, out, check: Check):
        """Single-error repair; returns (fixed, residual_err, applied).

        Tries the single-cell accumulator repair first, then (when the
        encoded side carried a column-sum reference) the weight-flip
        repair.  The two cannot mis-fire together: a weight flip leaves
        the accumulator column deltas self-consistent (zero), and a
        clean B leaves the weight encodings self-consistent.
        """
        aux = check.aux
        if isinstance(aux, dict):
            fixed, cell = correct_single_error(out, check.err_mask,
                                               aux["col_check"])
            fixed, wflip = correct_weight_flip(fixed, aux["a_q"],
                                               aux["packed"],
                                               aux["colsum_ref"])
            applied = cell | wflip
        else:
            fixed, applied = correct_single_error(out, check.err_mask, aux)
        residual = jnp.where(applied, 0, check.err_count).astype(jnp.int32)
        return fixed, residual, applied.astype(jnp.int32)


# ---------------------------------------------------------------------------
# float GEMM (beyond-paper: training-time bf16/f32 matmuls)
# ---------------------------------------------------------------------------

class FloatGemmOp:
    """Float ABFT GEMM: encoded = (W, f32 row sums | None), input = A."""
    name = "float_gemm"
    schemes = ("default",)
    supports_correct = False

    def encode(self, w: jax.Array):
        return (w, encode_weight_f32(w))

    def __call__(self, encoded, a, *, rule: ResolvedRule = _DEFAULT_RULE):
        w, checksum = encoded if isinstance(encoded, tuple) else (encoded,
                                                                  None)
        rel = 1e-3 if rule.rel_bound is None else rule.rel_bound
        out = abft_gemm_f32(a, w, checksum=checksum, rel_bound=rel)
        return out.c, Check(out.err_count, out.err_rows)

    def unprotected(self, encoded, a):
        w = encoded[0] if isinstance(encoded, tuple) else encoded
        return jnp.dot(a.astype(jnp.float32), w.astype(jnp.float32),
                       preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# EmbeddingBag (paper Algorithm 2)
# ---------------------------------------------------------------------------

class EmbeddingBagOp:
    """Quantized EB: encoded = (table_q, alphas, betas, rowsums);
    inputs = (indices [bags, pool] (−1 padded), optional weights)."""
    name = "embedding_bag"
    schemes = ("xla", "pallas")
    supports_correct = False
    default_rel_bound = EB_REL_BOUND

    def encode(self, params):
        """(table, alphas, betas) -> the 4-tuple with fresh row sums."""
        table_q, alphas, betas = params
        return (table_q, alphas, betas, table_rowsums(table_q))

    def __call__(self, encoded, indices, weights=None, *,
                 rule: ResolvedRule = _DEFAULT_RULE):
        table_q, alphas, betas, rowsums = encoded
        rel = self.default_rel_bound if rule.rel_bound is None \
            else rule.rel_bound
        if rule.scheme is None:
            use_pallas = None                      # auto: Pallas on TPU
        elif rule.scheme == "pallas":
            use_pallas = True
        elif rule.scheme == "xla":
            use_pallas = False
        else:
            raise ValueError(f"unknown embedding_bag scheme "
                             f"{rule.scheme!r}; have {self.schemes}")
        out = kops.abft_embedding_bag(table_q, alphas, betas, indices,
                                      rowsums, weights, rel_bound=rel,
                                      use_pallas=use_pallas)
        return out.r, Check(out.err_count, out.err_bags)

    def unprotected(self, encoded, indices, weights=None):
        table_q, alphas, betas, _ = encoded
        return embedding_bag(table_q, alphas, betas, indices, weights)


# ---------------------------------------------------------------------------
# Quantized KV cache (beyond-paper)
# ---------------------------------------------------------------------------

class KvCacheOp:
    """Checksummed int8 KV cache: encoded = (kv_k, kv_v) QuantKV pair;
    inputs = (q_heads [B, H, dh], pos [B]); static n_heads/n_kv plus the
    window/prefix masking of ``layers.attention.attention_decode``."""
    name = "kv_cache"
    schemes = ("default",)
    supports_correct = False

    def encode(self, kv):
        """Float K/V rows ([..., S, dh]) -> QuantKV (quantize + checksum).

        Accepts a single array or a (k, v) tuple."""
        if isinstance(kv, tuple):
            return tuple(quantize_kv_rows(x) for x in kv)
        return quantize_kv_rows(kv)

    def update(self, kv: QuantKV, batch_idx, pos, new_row) -> QuantKV:
        return update_kv_row(kv, batch_idx, pos, new_row)

    def __call__(self, encoded, q_heads, pos, *,
                 rule: ResolvedRule = _DEFAULT_RULE, n_heads: int,
                 n_kv: int, window=None, prefix_global: int = 0):
        kv_k, kv_v = encoded
        out, errs = attend_quantized(q_heads, kv_k, kv_v, pos,
                                     n_heads=n_heads, n_kv=n_kv,
                                     verify=True, window=window,
                                     prefix_global=prefix_global)
        return out, Check(errs)

    def unprotected(self, encoded, q_heads, pos, *, n_heads: int,
                    n_kv: int, window=None, prefix_global: int = 0):
        kv_k, kv_v = encoded
        out, _ = attend_quantized(q_heads, kv_k, kv_v, pos,
                                  n_heads=n_heads, n_kv=n_kv,
                                  verify=False, window=window,
                                  prefix_global=prefix_global)
        return out

    def dequantize(self, kv: QuantKV, dtype=jnp.bfloat16):
        return dequantize_kv(kv, dtype)


# ---------------------------------------------------------------------------
# Paged quantized KV cache (repro.paging)
# ---------------------------------------------------------------------------

class KvCachePagedOp:
    """Page-table int8 KV cache with per-page folded checksums.

    encoded = (pk, pv) :class:`repro.paging.PagedKV` pair (per-layer
    layout); inputs = (q_heads [B, H, dh], pos [B]).  Verify-on-touch:
    the check covers exactly the pages the attention mask reads, one
    int32 compare per (page, kv head), and the touched-page count rides
    the report's ``checks`` counter so telemetry can price verification
    per decode token.  Page repair (evict/rebuild/abort-owner) is a
    host-side allocator action — the serving engine interprets the plan
    policy; in-jit the op only counts, so call sites pass a log-policy
    rule.
    """
    name = "kv_cache_paged"
    schemes = ("default",)
    supports_correct = False

    def encode(self, kv):
        """Pool encoding lives in :mod:`repro.paging.cache`
        (pack_prompt_pages / paged_append); pass pools through."""
        return kv

    def append(self, pk, pos, new_rows):
        return paged_append(pk, pos, new_rows)

    def __call__(self, encoded, q_heads, pos, *,
                 rule: ResolvedRule = _DEFAULT_RULE, n_heads: int,
                 n_kv: int, window=None, prefix_global: int = 0):
        pk, pv = encoded
        out, errs, pages = attend_paged(q_heads, pk, pv, pos,
                                        n_heads=n_heads, n_kv=n_kv,
                                        verify=True, window=window,
                                        prefix_global=prefix_global)
        return out, Check(errs, aux={"n_checks": pages})

    def unprotected(self, encoded, q_heads, pos, *, n_heads: int,
                    n_kv: int, window=None, prefix_global: int = 0):
        pk, pv = encoded
        out, _, _ = attend_paged(q_heads, pk, pv, pos, n_heads=n_heads,
                                 n_kv=n_kv, verify=False, window=window,
                                 prefix_global=prefix_global)
        return out


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

OPS: Dict[str, ProtectedOp] = {}


def register_op(op: ProtectedOp) -> ProtectedOp:
    """Register an adapter; its name becomes a FaultReport key and a plan
    pattern.  Call at import time (report pytree structure is static)."""
    OPS[op.name] = op
    register_op_kind(op.name)
    return op


def get_op(name: str) -> ProtectedOp:
    if name not in OPS:
        raise KeyError(f"unknown protected op {name!r}; "
                       f"registered: {sorted(OPS)}")
    return OPS[name]


QGEMM = register_op(QGemmOp())
FLOAT_GEMM = register_op(FloatGemmOp())
EMBEDDING_BAG = register_op(EmbeddingBagOp())
KV_CACHE = register_op(KvCacheOp())
KV_CACHE_PAGED = register_op(KvCachePagedOp())

__all__ = ["Check", "ProtectedOp", "OPS", "register_op", "get_op",
           "QGemmOp", "FloatGemmOp", "EmbeddingBagOp", "KvCacheOp",
           "KvCachePagedOp", "QGEMM", "FLOAT_GEMM", "EMBEDDING_BAG",
           "KV_CACHE", "KV_CACHE_PAGED", "QuantKV", "LANE"]
