"""Protected-call runtime: resolve a plan rule, run the adapter, apply the
detect->act policy, emit the op-keyed report.

This is the single code path every protected call site goes through —
layers no longer hand-wire scheme/policy/threshold plumbing:

    c, rep = protected_call("qgemm", packed, x_q, ctx=ctx, name="attn.wq")

``ctx`` is duck-typed: anything with an optional ``plan``
(:class:`~repro.protect.plan.ProtectionPlan`) attribute plus the legacy
``abft`` / ``float_abft`` booleans the pre-plan ``Ctx`` carried.  With no
plan, the legacy flags reproduce the old behavior exactly (qgemm/EB gated
by ``abft``, float GEMMs by ``float_abft``, KV cache off).
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.core.policy import (abort_if_errors, empty_report, op_report,
                               with_recompute)
from repro.protect.ops import get_op
from repro.protect.plan import ProtectionPlan, ResolvedRule


def rule_for(ctx, op: str, name: str = "") -> ResolvedRule:
    """The plan rule governing op kind ``op`` at call site ``name``."""
    plan: Optional[ProtectionPlan] = getattr(ctx, "plan", None)
    if plan is not None:
        return plan.resolve(op, name)
    if ctx is None:
        return ResolvedRule()
    # legacy Ctx flags (pre-plan behavior, byte-for-byte)
    if op == "float_gemm":
        return ResolvedRule(enabled=bool(getattr(ctx, "float_abft", False)))
    if op in ("kv_cache", "kv_cache_paged"):
        return ResolvedRule(enabled=False)
    return ResolvedRule(enabled=bool(getattr(ctx, "abft", True)))


def protected_call(op: str, encoded, *inputs, ctx=None,
                   rule: Optional[ResolvedRule] = None, name: str = "",
                   **call_kwargs):
    """Run one protected op under its resolved plan rule.

    Returns ``(out, FaultReport)``.  Policy semantics:

    * ``log``       — verify, count, pass through;
    * ``recompute`` — ``lax.cond`` re-run up to ``rule.max_retries`` times
                      while errors persist (retries counted);
    * ``correct``   — adapters with ``supports_correct`` repair the single
                      flagged cell via row+column checksums; others fall
                      back to ``recompute`` (repair-or-retry);
    * ``abort``     — host callback raises
                      :class:`repro.core.policy.FaultAbort`.

    A disabled rule runs the adapter's unprotected baseline and reports
    zero checks.
    """
    adapter = get_op(op)
    if rule is None:
        rule = rule_for(ctx, op, name)
    if not rule.enabled:
        return adapter.unprotected(encoded, *inputs,
                                   **call_kwargs), empty_report()

    policy_name = rule.policy
    if policy_name == "correct" and not adapter.supports_correct:
        policy_name = "recompute"

    if policy_name == "correct":
        out, check = adapter(encoded, *inputs, rule=rule, **call_kwargs)
        out, residual, applied = adapter.correct(out, check)
        return out, op_report(op, residual, corrections=applied)

    if policy_name == "recompute":
        def run():
            o, c = adapter(encoded, *inputs, rule=rule, **call_kwargs)
            return o, c.err_count

        out, err, retries = with_recompute(
            run, max_retries=rule.max_retries)()
        return out, op_report(op, err, retries=retries)

    out, check = adapter(encoded, *inputs, rule=rule, **call_kwargs)
    if policy_name == "abort":
        jax.debug.callback(abort_if_errors, check.err_count)
    # adapters whose one call covers a variable amount of verified state
    # (e.g. pages touched by a paged KV read) report it via Check.aux so
    # the checks counter prices verification work, not call count
    n_checks = 1
    if isinstance(check.aux, dict) and "n_checks" in check.aux:
        n_checks = check.aux["n_checks"]
    return out, op_report(op, check.err_count, checks=n_checks)


def observe_metrics(metrics, *, source: str, step: int = 0,
                    t_s: float = 0.0, obs=None, cell_id=None,
                    request_ids=(), bit_band=None, shard=None,
                    attrs=None) -> int:
    """Land one step's device-side FaultReport counters host-side.

    ``protected_call`` runs traced (jit/scan/vmap), so per-call host
    emission is impossible there — this is the single host-side choke
    point the consumers (serving engine, train loop, campaign executor)
    call with a step's ``device_get``'d metrics dict.  Increments the
    ``repro_abft_{checks,errors}_total`` counters (plus one
    ``repro_detections_total{op,source}`` inc per flagged op), emits one
    ``detection`` :class:`~repro.obs.FaultEvent` per flagged op kind,
    and — when anything was checked or the caller passed step ``attrs``
    (the serving engine's lane/tenant/duration context) — one
    ``info``/``channel=step`` summary event carrying the per-op
    (checks, errors) counts.  That summary is what feeds the live
    :class:`~repro.obs.Monitor` and makes ``repro.obs.replay`` exact.
    Returns the step's total residual errors; a ``None`` obs is a cheap
    no-op path that still returns the error count.
    """
    from repro.obs.events import op_counts

    counts = op_counts(metrics)
    errors = sum(errs for _, _, errs in counts)
    if obs is None:
        return errors
    from repro.obs import FaultEvent, events_from_metrics
    by_op = {}
    total_checks = 0
    for kind, checks, errs in counts:
        if checks or errs:
            by_op[kind] = [int(checks), int(errs)]
            total_checks += int(checks)
            obs.registry.counter(
                "repro_abft_checks_total",
                "ABFT checks by op kind").inc(checks, op=kind,
                                              source=source)
            obs.registry.counter(
                "repro_abft_errors_total",
                "residual ABFT errors by op kind").inc(errs, op=kind,
                                                       source=source)
        if errs > 0:
            labels = {"op": kind, "source": source}
            if cell_id:
                labels["cell"] = cell_id
            obs.registry.counter(
                "repro_detections_total",
                "detected faults by op kind, source, and cell"
            ).inc(1, **labels)
    obs.bus.extend(events_from_metrics(
        metrics, step=step, source=source, t_s=t_s, cell_id=cell_id,
        request_ids=tuple(request_ids), bit_band=bit_band, shard=shard))
    if by_op or attrs:
        obs.bus.emit(FaultEvent(
            op="step", step=step, source=source, kind="info", t_s=t_s,
            errors=int(errors), checks=total_checks, cell_id=cell_id,
            attrs={"channel": "step", "by_op": by_op, **(attrs or {})}))
    return errors


def kv_rule(ctx, name: str = "attn") -> ResolvedRule:
    """Convenience for attention layers: the kv_cache rule, additionally
    gated on the int8 serving path (``ctx.quant``) — a bf16 training cache
    has nothing to checksum."""
    r = rule_for(ctx, "kv_cache", name)
    if r.enabled and not bool(getattr(ctx, "quant", False)):
        return ResolvedRule(enabled=False, scheme=r.scheme, policy=r.policy,
                            rel_bound=r.rel_bound,
                            max_retries=r.max_retries,
                            threshold=r.threshold)
    return r


def paged_kv_rule(ctx, name: str = "attn") -> ResolvedRule:
    """The kv_cache_paged rule with its policy forced to ``log``.

    Page repair under recompute/abort is a host-side allocator action
    (evict the flagged page, rebuild prompt pages via re-prefill, or
    abort the owning request) — the serving engine applies it between
    steps.  In-jit the op can only count, so the traced call always
    logs; the plan's policy still decides what the engine does with the
    flag."""
    import dataclasses

    r = rule_for(ctx, "kv_cache_paged", name)
    return dataclasses.replace(r, policy="log")
