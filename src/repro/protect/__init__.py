"""Unified declarative protection over every ABFT operator.

One API answers "which ops are protected, how, and with what
policy/threshold" for the whole stack:

* :class:`ProtectionPlan` / :class:`OpRule` — ordered per-op-pattern rules
  (``"qgemm/attn.*:policy=recompute,embedding_bag:off"``), parseable from
  CLI strings and config dicts;
* :class:`~repro.protect.ops.ProtectedOp` adapters — uniform
  ``encode / __call__ / unprotected`` over int8 GEMM (packed / unfused /
  Pallas via :mod:`repro.kernels.ops`), float GEMM, EmbeddingBag, and the
  quantized KV cache;
* :func:`protected_call` — the single runtime every layer call site goes
  through (rule resolution, scheme dispatch, per-op policy application:
  log / recompute / correct / abort);
* :class:`~repro.core.policy.FaultReport` — op-name-keyed counters threaded
  as a pytree through jit/scan/vmap;
* :func:`protect` — wrap a model apply function so serving and experiments
  select protection purely by plan.

    from repro.protect import ProtectionPlan, protect
    plan = ProtectionPlan.parse("*:policy=log,kv_cache:on")
    prefill = protect(model.prefill, plan)
    (logits, cache), report = prefill(params, batch, cache_len=256)
"""
from repro.core.policy import (FaultReport, empty_report, merge_reports,
                               op_kinds, op_report, register_op_kind)
from repro.protect.api import Protected, encode_tree, protect
from repro.protect.ops import (Check, OPS, ProtectedOp, get_op,
                               register_op)
from repro.protect.plan import (OpRule, POLICY_NAMES, ProtectionPlan,
                                ResolvedRule, default_plan,
                                unprotected_plan)
from repro.protect.runtime import (kv_rule, observe_metrics,
                                   protected_call, rule_for)

__all__ = [
    "ProtectionPlan", "OpRule", "ResolvedRule", "POLICY_NAMES",
    "default_plan", "unprotected_plan",
    "ProtectedOp", "Check", "OPS", "register_op", "get_op",
    "protected_call", "rule_for", "kv_rule", "observe_metrics",
    "protect", "Protected", "encode_tree",
    "FaultReport", "op_report", "empty_report", "merge_reports",
    "op_kinds", "register_op_kind",
]
