"""``protect(apply_fn, plan)`` — the one-call front door.

Wraps a model apply function (anything with the repo's
``fn(params, *args, ctx=..., **kw) -> (..., FaultReport)`` shape:
``Model.prefill``, ``Model.decode``, ``Model.loss``, ``dlrm_forward``
partials, ...) so that:

* the plan is threaded to every protected call site via the layer ``Ctx``
  (no per-callsite wiring — flipping an op off or changing its policy is a
  plan edit, not a model edit);
* weights are encoded once via :meth:`Protected.encode` (checksum lanes
  packed, table row sums refreshed) — the amortized §IV-A1 step;
* the trailing :class:`~repro.core.policy.FaultReport` is split off and
  returned uniformly as ``(output, report)``; apply functions that nest
  their report (``Model.loss`` -> ``(loss, (metrics, rep))``) keep their
  output shape, with the merged report surfaced alongside.

    plan = ProtectionPlan.parse("*:policy=log,embedding_bag:off")
    prefill = protect(model.prefill, plan)
    (logits, cache), report = prefill(params, batch, cache_len=256)
"""
from __future__ import annotations

from typing import Any, Callable

import jax

from repro.core.policy import FaultReport, empty_report, merge_reports
from repro.protect.ops import get_op
from repro.protect.plan import ProtectionPlan


def _find_reports(out: Any) -> list:
    """Every FaultReport reachable through tuples/lists/dicts in ``out``."""
    if isinstance(out, FaultReport):
        return [out]
    if isinstance(out, (tuple, list)):
        return [r for v in out for r in _find_reports(v)]
    if isinstance(out, dict):
        return [r for v in out.values() for r in _find_reports(v)]
    return []


class Protected:
    """A plan-bound apply function.  See module docstring."""

    def __init__(self, apply_fn: Callable, plan: ProtectionPlan, *,
                 ctx=None, **ctx_overrides):
        from repro.layers.common import Ctx
        base = ctx if ctx is not None else Ctx(quant=True)
        self.plan = plan
        self.ctx = base.replace(plan=plan, **ctx_overrides)
        self.apply_fn = apply_fn

    def encode(self, params):
        """Refresh every amortized encoding in a param tree (packed GEMM
        checksum lanes, EB/token-table row sums).  Idempotent; call once
        after loading or mutating weights."""
        return encode_tree(params)

    def __call__(self, params, *args, **kwargs):
        out = self.apply_fn(params, *args, ctx=self.ctx, **kwargs)
        if isinstance(out, tuple) and out and isinstance(out[-1],
                                                         FaultReport):
            rest = out[:-1]
            return (rest[0] if len(rest) == 1 else rest), out[-1]
        # nested-report shapes (e.g. Model.loss -> (loss, (metrics, rep))):
        # surface the merged report without restructuring the output
        reports = _find_reports(out)
        return out, (merge_reports(*reports) if reports else empty_report())


def protect(apply_fn: Callable, plan: ProtectionPlan, *, ctx=None,
            **ctx_overrides) -> Protected:
    """Bind ``apply_fn`` to a :class:`ProtectionPlan`.

    ``ctx`` seeds the layer context (default: the int8 serving
    ``Ctx(quant=True)``); keyword overrides are forwarded to
    ``ctx.replace`` (e.g. ``compute_dtype=jnp.float32``).
    """
    return Protected(apply_fn, plan, ctx=ctx, **ctx_overrides)


def encode_tree(params: Any) -> Any:
    """Walk a param (value) tree and recompute every derived encoding:

    * dicts holding ``w_packed`` get their checksum lanes re-encoded from
      the weight block (vmapped over leading stack dims), and a sibling
      ``colsum`` (the Eq. 1 requantization constant) recomputed with them;
    * dicts holding ``table`` + ``rowsums`` get row sums recomputed.

    LogicalParam wrappers are preserved.  Everything else passes through
    untouched.
    """
    from repro.core import table_rowsums
    from repro.sharding import LogicalParam, is_lp

    qgemm = get_op("qgemm")

    def val(x):
        return x.value if is_lp(x) else x

    def rewrap(ref, v):
        return LogicalParam(v, ref.axes) if is_lp(ref) else v

    def repack(packed):
        w_q = packed[..., :, :packed.shape[-1] - qgemm.lane]
        fn = qgemm.encode
        for _ in range(packed.ndim - 2):
            fn = jax.vmap(fn)
        return fn(w_q)

    def rec(node):
        if isinstance(node, dict):
            node = {k: rec(v) for k, v in node.items()}
            if "w_packed" in node:
                packed = val(node["w_packed"])
                node["w_packed"] = rewrap(node["w_packed"], repack(packed))
                if "colsum" in node:
                    # the requantization constant (Eq. 1 rank-1 term) is
                    # derived from the weight block too — stale colsum is
                    # silent output corruption, not a detection miss
                    w_q = packed[..., :, :packed.shape[-1] - qgemm.lane]
                    node["colsum"] = rewrap(node["colsum"],
                                            qgemm.dequant_colsum(w_q))
            if "table" in node and "rowsums" in node:
                node["rowsums"] = rewrap(
                    node["rowsums"], table_rowsums(val(node["table"])))
            return node
        if isinstance(node, (list, tuple)):
            return type(node)(rec(v) for v in node)
        return node

    return rec(params)
