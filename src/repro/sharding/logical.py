"""Logical-axis sharding (MaxText-style, minimal).

Every parameter (and cache buffer) carries a tuple of *logical* axis names —
one per dimension — via :class:`LogicalParam`, a pytree node whose axes are
**static treedef metadata**.  That makes ``jax.eval_shape(init)`` work: the
dry-run derives full sharding trees for 100B+ parameter models without ever
allocating them.

A :class:`Rules` mapping translates logical names to mesh axes per run mode
(train vs serve, single- vs multi-pod).  Specs are derived shape-aware: a
mesh axis that does not divide the dimension, or that is already consumed by
an earlier dimension of the same tensor, falls back to replication (never a
compile error).
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@jax.tree_util.register_pytree_node_class
class LogicalParam:
    """array (or ShapeDtypeStruct) + logical axes (static, one per dim)."""

    __slots__ = ("value", "axes")

    def __init__(self, value: Any, axes: Sequence[Optional[str]]):
        self.value = value
        self.axes = tuple(axes)

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, axes, children):
        return cls(children[0], axes)

    def __repr__(self):
        shape = getattr(self.value, "shape", None)
        return f"LogicalParam(shape={shape}, axes={self.axes})"


def is_lp(x) -> bool:
    return isinstance(x, LogicalParam)


def param(key, shape, axes, dtype, scale: float = 0.02,
          init: str = "normal") -> LogicalParam:
    assert len(shape) == len(axes), (shape, axes)
    if init == "normal":
        v = jax.random.normal(key, shape, dtype) * jnp.asarray(scale, dtype)
    elif init == "zeros":
        v = jnp.zeros(shape, dtype)
    elif init == "ones":
        v = jnp.ones(shape, dtype)
    else:
        raise ValueError(init)
    return LogicalParam(v, tuple(axes))


def values_of(tree):
    """LogicalParam tree -> plain value tree (same dict structure)."""
    return jax.tree.map(lambda p: p.value if is_lp(p) else p, tree,
                        is_leaf=is_lp)


def split_tree(tree):
    return values_of(tree), tree  # values + (the LP tree doubles as axes)


def spec_for(shape: Sequence[int], axes: Sequence[Optional[str]],
             rules: dict, mesh_shape: dict) -> P:
    """Shape-aware PartitionSpec: divisibility + no-mesh-axis-reuse."""
    used: set = set()
    parts = []
    for dim, name in zip(shape, axes):
        mapped = rules.get(name) if name is not None else None
        if mapped is None:
            parts.append(None)
            continue
        mesh_axes = (mapped,) if isinstance(mapped, str) else tuple(mapped)
        mesh_axes = tuple(a for a in mesh_axes
                          if a not in used and a in mesh_shape)
        size = 1
        keep = []
        for a in mesh_axes:
            if dim % (size * mesh_shape[a]) == 0:
                keep.append(a)
                size *= mesh_shape[a]
        if not keep:
            parts.append(None)
        else:
            used.update(keep)
            parts.append(tuple(keep) if len(keep) > 1 else keep[0])
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def specs_of(lp_tree, rules: dict, mesh: Mesh):
    """LogicalParam tree -> PartitionSpec tree (same structure, P leaves)."""
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    return jax.tree.map(
        lambda p: spec_for(p.value.shape, p.axes, rules, mesh_shape),
        lp_tree, is_leaf=is_lp)


def shardings_of(lp_tree, rules: dict, mesh: Mesh):
    specs = specs_of(lp_tree, rules, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def like_shardings(values_tree, spec, mesh: Mesh):
    """Uniform sharding for a whole tree (e.g. replicated scalars)."""
    return jax.tree.map(lambda _: NamedSharding(mesh, spec), values_tree)


# -------------------- in-function sharding constraints ----------------------

def _context_mesh() -> Optional[Mesh]:
    try:
        from jax._src.mesh import thread_resources
        m = thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:  # pragma: no cover
        return None


def _manual_axes() -> set:
    """Mesh axes currently under manual (shard_map) control."""
    try:
        from jax.sharding import get_abstract_mesh
        am = get_abstract_mesh()
        if am is None or am.empty:
            return set()
        return {name for name, t in zip(am.axis_names, am.axis_types)
                if str(t) == "Manual"}
    except Exception:  # pragma: no cover
        return set()


def constrain(x, axes: Sequence[Optional[str]], rules: Optional[dict]):
    """with_sharding_constraint by logical axes.

    No-op when no rules are given or no mesh is active (smoke tests run
    un-meshed on one device).  Inside a partial-manual shard_map, axes that
    are Manual (e.g. the deferred-sync data axis) are dropped from the
    spec — constraints only apply to the remaining auto axes.
    """
    if rules is None:
        return x
    mesh = _context_mesh()
    if mesh is None:
        return x
    manual = _manual_axes()
    mesh_shape = {name: size
                  for name, size in zip(mesh.axis_names, mesh.devices.shape)
                  if name not in manual}
    spec = spec_for(x.shape, axes, rules, mesh_shape)
    if manual:
        return jax.lax.with_sharding_constraint(x, spec)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
