from repro.sharding.logical import (
    LogicalParam,
    is_lp,
    param,
    values_of,
    spec_for,
    specs_of,
    shardings_of,
    like_shardings,
    constrain,
)
from repro.sharding.rules import Rules, train_rules, serve_rules, batch_axes

__all__ = [
    "LogicalParam", "is_lp", "param", "values_of",
    "spec_for", "specs_of", "shardings_of", "like_shardings", "constrain",
    "Rules", "train_rules", "serve_rules", "batch_axes",
]
