from repro.sharding.logical import (
    LogicalParam,
    is_lp,
    param,
    values_of,
    spec_for,
    specs_of,
    shardings_of,
    like_shardings,
    constrain,
)
from repro.sharding.rules import Rules, train_rules, serve_rules, batch_axes

import jax as _jax


def shard_map(f, *, mesh, in_specs, out_specs, manual_axes=None):
    """Version-portable manual-sharding wrapper.

    jax >= 0.5 exposes ``jax.shard_map(check_vma=..., axis_names=...)``;
    0.4.x has ``jax.experimental.shard_map.shard_map(check_rep=...,
    auto=...)`` where ``auto`` is the COMPLEMENT of the manual axis set.
    Replication checking is disabled on both paths (our steps psum
    explicitly).
    """
    manual = set(manual_axes) if manual_axes is not None \
        else set(mesh.axis_names)
    if hasattr(_jax, "shard_map"):
        return _jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_vma=False,
                              axis_names=manual)
    from jax.experimental.shard_map import shard_map as _sm
    auto = frozenset(mesh.axis_names) - manual
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False, auto=auto)


def make_data_mesh(n_shards: int, devices=None):
    """One-axis ``("data",)`` mesh of ``n_shards`` host devices — the fake
    data-parallel axis campaign soaks :func:`shard_map` over so
    ``checked_psum`` verifies a real collective.  ``devices`` selects an
    explicit slice (cell placement); default is the front of the host
    platform."""
    from repro.launch.mesh import make_host_mesh
    return make_host_mesh((n_shards,), ("data",), devices=devices)


__all__ = [
    "LogicalParam", "is_lp", "param", "values_of",
    "spec_for", "specs_of", "shardings_of", "like_shardings", "constrain",
    "Rules", "train_rules", "serve_rules", "batch_axes", "shard_map",
    "make_data_mesh",
]
