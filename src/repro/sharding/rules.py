"""Logical-axis -> mesh-axis rule sets (DESIGN.md §5).

Axes used by the model zoo:
  batch        activation batch dim
  seq          activation sequence dim (unsharded in baseline)
  kv_seq       decode KV-cache sequence dim (sequence-parallel decode)
  embed        d_model dim of weights (FSDP axis in training)
  mlp          FFN hidden dim (column-parallel)
  mlp_in       FFN hidden dim as a *contraction* dim (row-parallel)
  heads_x      merged q/k/v/o projection output dim
  vocab        vocabulary dim (embedding rows / lm-head cols)
  expert       MoE expert dim
  expert_mlp   per-expert FFN hidden dim
  table_rows   DLRM embedding-table rows
  conv / state small dims, never sharded
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple, Union

Rules = Dict[str, Union[str, Tuple[str, ...], None]]


def batch_axes(multi_pod: bool) -> Tuple[str, ...]:
    return ("pod", "data") if multi_pod else ("data",)


def train_rules(multi_pod: bool = False) -> Rules:
    """Training: FSDP over `data` for params + TP over `model`."""
    return {
        "batch": batch_axes(multi_pod),
        "seq": None,
        "kv_seq": "model",
        "embed": "data",
        "mlp": "model",
        "mlp_in": "model",
        "heads_x": "model",
        "vocab": "model",
        "expert": "model",
        "expert_mlp": "model",
        "table_rows": "model",
        "frontend": None,
    }


def serve_rules(multi_pod: bool = False) -> Rules:
    """Serving: pure TP (weights static — no FSDP gathers), batch over data,
    KV-cache sequence-parallel over `model`."""
    r = train_rules(multi_pod)
    r["embed"] = None        # replicate weight d_model dim across `data`
    return r
