from repro.checkpoint.ckpt import (  # noqa: F401
    CheckpointManager,
    save_checkpoint,
    load_checkpoint,
    latest_step,
    CheckpointCorruption,
)
