"""Checksummed, sharded, async checkpoints — the paper's integrity
philosophy applied to persistent state.

Layout (one directory per step)::

    <dir>/step_000123/
        shard_00000.npz          # flat {index -> array} for this host
        MANIFEST.json            # treedef, shapes, dtypes, per-leaf checksums
        COMMIT                   # written last — a step without it is torn

Design points:
- **ABFT-flavored integrity**: every leaf is checksummed (mod 2^31-1 byte
  sum — ``core.checksum``) at save; restore verifies before handing state to
  the trainer.  A flipped bit in storage or DMA surfaces as
  :class:`CheckpointCorruption`, not NaNs ten thousand steps later.
- **Atomicity**: write to ``.tmp`` dir, fsync, rename, then COMMIT marker.
  ``latest_step`` only considers committed steps, so a mid-save crash
  restarts from the previous step.
- **Async save**: serialization happens on a background thread from a
  host-side snapshot (``jax.device_get`` runs in the caller to keep the
  donated-buffer story simple); the training loop overlaps the next steps
  with the disk write. ``wait()`` joins before the next save or exit.
- **keep_last_k** garbage collection of committed steps.
- **Elastic restore**: arrays are saved host-global (per-process shard in
  multihost); on restore they are re-placed onto the *current* mesh via the
  target shardings — a checkpoint from a 512-chip run restores onto 256
  chips (or 1 CPU device) unchanged.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d{9})$")
_MOD = 2147483647  # 2^31-1, matches core.checksum.MOD_U32


class CheckpointCorruption(RuntimeError):
    """A shard failed its checksum on restore."""


def _np_checksum(x: np.ndarray) -> int:
    """Mod-(2^31-1) byte-sum — numpy twin of core.checksum.tensor_checksum."""
    u8 = np.ascontiguousarray(x).view(np.uint8).ravel()
    # chunked exact sum (uint64 accumulators cannot overflow for < 2^56 bytes)
    return int(u8.astype(np.uint64).sum() % _MOD)


def _step_dir(base: str, step: int) -> str:
    return os.path.join(base, f"step_{step:09d}")


def latest_step(base: str) -> Optional[int]:
    """Largest committed step in ``base`` (None if empty)."""
    if not os.path.isdir(base):
        return None
    steps = []
    for name in os.listdir(base):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(base, name, "COMMIT")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def save_checkpoint(base: str, step: int, state: Any) -> str:
    """Synchronous checksummed save. Returns the committed directory."""
    snapshot = jax.device_get(state)
    return _write(base, step, snapshot)


def _write(base: str, step: int, snapshot: Any) -> str:
    leaves, treedef = jax.tree.flatten(snapshot)
    final = _step_dir(base, step)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    arrays = {f"a{i}": np.asarray(l) for i, l in enumerate(leaves)}
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "leaves": {
            k: {"shape": list(v.shape), "dtype": str(v.dtype),
                "checksum": _np_checksum(v)}
            for k, v in arrays.items()
        },
    }
    shard = os.path.join(tmp, f"shard_{jax.process_index():05d}.npz")
    np.savez(shard, **arrays)
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    # COMMIT marker last: a crash before this line leaves a torn (ignored)
    # step; after it the step is durable.
    with open(os.path.join(final, "COMMIT"), "w") as f:
        f.write("ok")
        f.flush()
        os.fsync(f.fileno())
    return final


def load_checkpoint(base: str, step: int, like: Any,
                    shardings: Any = None, *, verify: bool = True) -> Any:
    """Restore ``step`` into the structure of ``like``.

    ``shardings`` (same tree structure or a single sharding) re-places each
    leaf onto the current mesh — this is the elastic-rescale path.
    """
    import ml_dtypes  # noqa: F401 — registers bfloat16/… dtype names

    d = _step_dir(base, step)
    with open(os.path.join(d, "MANIFEST.json")) as f:
        manifest = json.load(f)
    shard = os.path.join(d, f"shard_{jax.process_index():05d}.npz")
    with np.load(shard) as z:
        arrays = {k: z[k] for k in z.files}
    # npz stores extended dtypes (bfloat16, float8…) as raw void bytes;
    # reinterpret from the manifest record.
    for k, meta in manifest["leaves"].items():
        want = np.dtype(meta["dtype"])
        if arrays[k].dtype != want:
            arrays[k] = arrays[k].view(want)

    if verify:
        for k, meta in manifest["leaves"].items():
            got = _np_checksum(arrays[k])
            if got != meta["checksum"]:
                raise CheckpointCorruption(
                    f"{d}: leaf {k} checksum mismatch "
                    f"(manifest {meta['checksum']}, got {got})")

    leaves_like, treedef = jax.tree.flatten(like)
    if len(leaves_like) != manifest["n_leaves"]:
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, "
            f"target structure has {len(leaves_like)}")
    leaves = [arrays[f"a{i}"] for i in range(len(leaves_like))]
    restored = jax.tree.unflatten(treedef, leaves)
    if shardings is not None:
        if not isinstance(shardings, (dict, list, tuple)):
            restored = jax.tree.map(
                lambda x: jax.device_put(x, shardings), restored)
        else:
            restored = jax.tree.map(jax.device_put, restored, shardings)
    return restored


class CheckpointManager:
    """Async save + keep-last-k + resume, for the fault-tolerant loop."""

    def __init__(self, base: str, *, keep_last: int = 3,
                 save_every: int = 100):
        self.base = base
        self.keep_last = keep_last
        self.save_every = save_every
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(base, exist_ok=True)

    # -------------------------------- save ---------------------------------
    def maybe_save(self, step: int, state: Any, *, force: bool = False):
        if not force and (self.save_every <= 0
                          or step % self.save_every != 0):
            return False
        self.wait()
        snapshot = jax.device_get(state)   # sync point; write is async

        def work():
            try:
                _write(self.base, step, snapshot)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        return True

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(
            int(m.group(1)) for m in
            (_STEP_RE.match(n) for n in os.listdir(self.base)) if m)
        committed = [s for s in steps if os.path.exists(
            os.path.join(_step_dir(self.base, s), "COMMIT"))]
        for s in committed[:-self.keep_last]:
            shutil.rmtree(_step_dir(self.base, s), ignore_errors=True)

    # ------------------------------- restore -------------------------------
    def restore_latest(self, like: Any, shardings: Any = None,
                       *, verify: bool = True):
        """(state, step) from the newest committed checkpoint, else None.

        A corrupt newest step falls back to the previous committed one —
        detection plus recovery, per the paper's detect->recompute policy.
        """
        self.wait()
        step = latest_step(self.base)
        tried = []
        while step is not None:
            try:
                return (load_checkpoint(self.base, step, like, shardings,
                                        verify=verify), step)
            # any unreadable committed step (our checksum, zip CRC, torn
            # file) is corruption: evict it and fall back one step.
            except Exception as e:  # noqa: BLE001 — deliberate fallback
                tried.append(str(e))
                shutil.rmtree(_step_dir(self.base, step),
                              ignore_errors=True)
                step = latest_step(self.base)
        if tried:
            raise CheckpointCorruption(
                "all checkpoints corrupt:\n" + "\n".join(tried))
        return None
