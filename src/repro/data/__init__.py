from repro.data.pipeline import (  # noqa: F401
    DataConfig,
    SyntheticLMDataset,
    SyntheticDLRMDataset,
    make_dataset,
    shard_batch,
    Prefetcher,
)
