"""Synthetic data pipelines: deterministic, host-sharded, prefetched.

Production DLRM/LM input pipelines stream from feature stores; here the
substrate is complete but the source is synthetic (seeded — every batch is a
pure function of (seed, step), so a restarted/elastic run regenerates the
exact same stream without data-loader checkpoints; the paper's philosophy of
cheap recompute applies to data too).

Pieces:
- :class:`SyntheticLMDataset` — next-token LM batches for every LM-family
  arch (token/label shift, optional patch/frame stubs for vlm/encdec).
- :class:`SyntheticDLRMDataset` — the paper's own workload: dense features +
  26 multi-hot categorical bags (variable pooling, padded to fixed shape).
- :func:`shard_batch` — places a host-global numpy batch onto the mesh
  according to the step's input shardings (multi-host ready: each host only
  materializes its addressable shard).
- :class:`Prefetcher` — double-buffered host->device pipeline.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig

IGNORE = -1


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    # DLRM-specific knobs (paper Table I scale-down happens in configs)
    avg_pool: int = 100
    max_pool: int = 128


def _rng_for(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step]))


class SyntheticLMDataset:
    """Seeded synthetic LM batches matching ``Model.input_specs`` layouts."""

    def __init__(self, cfg: ArchConfig, shape: ShapeConfig,
                 data_cfg: DataConfig = DataConfig()):
        self.cfg = cfg
        self.shape = shape
        self.data_cfg = data_cfg

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg, shape = self.cfg, self.shape
        rng = _rng_for(self.data_cfg.seed, step)
        B, S = shape.global_batch, shape.seq_len
        text_len = S
        batch: Dict[str, np.ndarray] = {}
        if cfg.family == "vlm":
            text_len = S - cfg.n_patches
            batch["patches"] = rng.standard_normal(
                (B, cfg.n_patches, cfg.patch_dim), dtype=np.float32)
        if cfg.family == "hybrid":
            text_len = S - cfg.meta_tokens
        if cfg.family == "encdec":
            batch["frames"] = rng.standard_normal(
                (B, cfg.enc_seq, cfg.d_model), dtype=np.float32)
        toks = rng.integers(0, cfg.vocab, (B, text_len + 1), dtype=np.int64)
        batch["tokens"] = toks[:, :-1].astype(np.int32)
        if shape.kind == "train":
            batch["labels"] = toks[:, 1:].astype(np.int32)
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class SyntheticDLRMDataset:
    """The paper's workload: dense features + multi-hot categorical bags.

    Bags use the fixed-shape padded layout of core.abft_embedding:
    ``indices [B, n_tables, max_pool]`` padded with -1, pooling sizes drawn
    around ``avg_pool`` (paper Table I uses avg 100).
    """

    def __init__(self, cfg: ArchConfig, shape: ShapeConfig,
                 data_cfg: DataConfig = DataConfig()):
        self.cfg = cfg
        self.shape = shape
        self.data_cfg = data_cfg

    @property
    def extras(self):
        from repro.configs.dlrm import EXTRAS
        return EXTRAS

    def batch_at(self, step: int, *, table_rows: Optional[int] = None
                 ) -> Dict[str, np.ndarray]:
        ex, dc = self.extras, self.data_cfg
        rows = table_rows or ex.table_rows
        rng = _rng_for(dc.seed, step)
        B = self.shape.global_batch
        dense = rng.standard_normal((B, ex.n_dense)).astype(np.float32)
        pools = rng.integers(1, dc.max_pool + 1, (ex.n_tables, B))
        idx = rng.integers(0, rows,
                           (ex.n_tables, B, dc.max_pool), dtype=np.int64)
        mask = np.arange(dc.max_pool)[None, None, :] < pools[..., None]
        idx = np.where(mask, idx, -1).astype(np.int32)
        label = rng.integers(0, 2, (B,)).astype(np.float32)
        return {"dense": dense, "bags": idx, "label": label}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_dataset(cfg: ArchConfig, shape: ShapeConfig,
                 data_cfg: DataConfig = DataConfig()):
    if cfg.family == "dlrm":
        return SyntheticDLRMDataset(cfg, shape, data_cfg)
    return SyntheticLMDataset(cfg, shape, data_cfg)


def shard_batch(batch: Dict[str, np.ndarray], shardings) -> Dict:
    """Host-global numpy batch -> sharded jax arrays.

    Single-process: ``device_put`` with the target sharding. Multi-host: each
    process passes only its addressable slice via
    ``jax.make_array_from_process_local_data`` (shape-preserving).
    """
    def put(x, s):
        if jax.process_count() > 1:  # pragma: no cover - multihost only
            return jax.make_array_from_process_local_data(s, x)
        return jax.device_put(x, s)

    return jax.tree.map(put, batch, shardings)


class Prefetcher:
    """Double-buffered background host->device transfer."""

    def __init__(self, it: Iterator, shardings=None, depth: int = 2):
        self._it = it
        self._shardings = shardings
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._done = object()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        try:
            for batch in self._it:
                if self._shardings is not None:
                    batch = shard_batch(batch, self._shardings)
                self._q.put(batch)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item
