"""Metrics registry with Prometheus-text and JSON exporters.

Counters, gauges, and histograms with flat string labels — the
host-side, pull-exportable face of the fault pipeline.  Naming follows
Prometheus conventions (``repro_`` prefix, ``_total`` suffix on
counters); the text output of :meth:`MetricsRegistry.to_prometheus` is
valid exposition format a node scraper ingests as-is.

Metric namespace used across the repo:

* ``repro_detections_total{cell=...}`` / ``repro_false_positives_total``
  / ``repro_escapes_total`` / ``repro_injections_total`` — campaign-level
  outcomes, one label per cell id, matching the artifact's CellMetrics;
* ``repro_abft_checks_total`` / ``repro_abft_errors_total``
  ``{op=..., source=...}`` — per-op FaultReport counters as they land
  host-side (serving engine steps, train-loop steps);
* ``repro_steps_total{kind=..., source=...}`` and the
  ``repro_step_duration_ms`` histogram — throughput/latency context.
"""
from __future__ import annotations

import json
import math
from typing import Dict, List, Optional, Tuple

_LabelKey = Tuple[Tuple[str, str], ...]

#: default histogram buckets (ms-scale step durations)
DEFAULT_BUCKETS = (0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0,
                   500.0, 1000.0)


def _label_key(labels: Dict[str, str]) -> _LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _escape(v: str) -> str:
    return v.replace("\\", r"\\").replace('"', r'\"').replace("\n", r"\n")


def _fmt_labels(key: _LabelKey, extra: Tuple[Tuple[str, str], ...] = ()
                ) -> str:
    items = key + extra
    if not items:
        return ""
    return "{" + ",".join(f'{k}="{_escape(v)}"' for k, v in items) + "}"


def _fmt_value(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    f = float(v)
    return str(int(f)) if f == int(f) else repr(f)


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help

    def prometheus_lines(self) -> List[str]:
        raise NotImplementedError

    def to_json(self) -> dict:
        raise NotImplementedError


class Counter(_Metric):
    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        super().__init__(name, help)
        self._values: Dict[_LabelKey, float] = {}

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease")
        key = _label_key(labels)
        self._values[key] = self._values.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        return self._values.get(_label_key(labels), 0.0)

    def total(self) -> float:
        return sum(self._values.values())

    def prometheus_lines(self) -> List[str]:
        return [f"{self.name}{_fmt_labels(k)} {_fmt_value(v)}"
                for k, v in sorted(self._values.items())]

    def to_json(self) -> dict:
        return {"type": self.kind, "help": self.help,
                "samples": [{"labels": dict(k), "value": v}
                            for k, v in sorted(self._values.items())]}


class Gauge(Counter):
    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        self._values[_label_key(labels)] = float(value)

    def inc(self, amount: float = 1.0, **labels) -> None:   # may go down
        key = _label_key(labels)
        self._values[key] = self._values.get(key, 0.0) + amount


class Histogram(_Metric):
    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Tuple[float, ...] = DEFAULT_BUCKETS):
        super().__init__(name, help)
        self.buckets = tuple(sorted(buckets))
        self._counts: Dict[_LabelKey, List[int]] = {}
        self._sums: Dict[_LabelKey, float] = {}
        self._totals: Dict[_LabelKey, int] = {}

    def observe(self, value: float, **labels) -> None:
        key = _label_key(labels)
        counts = self._counts.setdefault(key,
                                         [0] * (len(self.buckets) + 1))
        for i, ub in enumerate(self.buckets):
            if value <= ub:
                counts[i] += 1
                break
        else:
            counts[-1] += 1
        self._sums[key] = self._sums.get(key, 0.0) + float(value)
        self._totals[key] = self._totals.get(key, 0) + 1

    def count(self, **labels) -> int:
        return self._totals.get(_label_key(labels), 0)

    def prometheus_lines(self) -> List[str]:
        lines = []
        for key in sorted(self._counts):
            cum = 0
            for i, ub in enumerate(self.buckets):
                cum += self._counts[key][i]
                lines.append(
                    f"{self.name}_bucket"
                    f"{_fmt_labels(key, (('le', _fmt_value(ub)),))} {cum}")
            cum += self._counts[key][-1]
            lines.append(f"{self.name}_bucket"
                         f"{_fmt_labels(key, (('le', '+Inf'),))} {cum}")
            lines.append(f"{self.name}_sum{_fmt_labels(key)} "
                         f"{_fmt_value(self._sums[key])}")
            lines.append(f"{self.name}_count{_fmt_labels(key)} "
                         f"{self._totals[key]}")
        return lines

    def to_json(self) -> dict:
        return {"type": self.kind, "help": self.help,
                "buckets": list(self.buckets),
                "samples": [{"labels": dict(k),
                             "counts": list(self._counts[k]),
                             "sum": self._sums[k],
                             "count": self._totals[k]}
                            for k in sorted(self._counts)]}


class MetricsRegistry:
    """Get-or-create registry; export order is registration order."""

    def __init__(self):
        self._metrics: Dict[str, _Metric] = {}

    def _get_or_create(self, cls, name: str, help: str, **kwargs):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, help, **kwargs)
            self._metrics[name] = m
        elif not isinstance(m, cls) or (isinstance(m, Gauge)
                                        != (cls is Gauge)):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{m.kind}, not {cls.kind}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Tuple[float, ...] = DEFAULT_BUCKETS
                  ) -> Histogram:
        return self._get_or_create(Histogram, name, help, buckets=buckets)

    def get(self, name: str) -> Optional[_Metric]:
        return self._metrics.get(name)

    def to_prometheus(self) -> str:
        out = []
        for name, m in self._metrics.items():
            if m.help:
                out.append(f"# HELP {name} {m.help}")
            out.append(f"# TYPE {name} {m.kind}")
            out.extend(m.prometheus_lines())
        return "\n".join(out) + ("\n" if out else "")

    def to_json(self) -> dict:
        return {name: m.to_json() for name, m in self._metrics.items()}

    def write_prometheus(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_prometheus())
        return path

    def write_json(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)
        return path


_DEFAULT: Optional[MetricsRegistry] = None


def default_registry() -> MetricsRegistry:
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = MetricsRegistry()
    return _DEFAULT


__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "default_registry", "DEFAULT_BUCKETS"]
