"""Timed spans with Chrome/Perfetto trace export.

A :class:`Tracer` collects named wall-clock spans — the campaign
executor's build/trials/clean/overhead phases, the serving engine's
prefill/decode steps, a target's encode/compute/verify breakdown — and
serializes them as Chrome Trace Event JSON (``"ph": "X"`` complete
events), which both ``chrome://tracing`` and https://ui.perfetto.dev
open directly.  Track assignment: ``pid`` 0, one ``tid`` per category,
so campaign phases and serving steps land on separate rows.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import time
from typing import Any, Dict, List, Optional


@dataclasses.dataclass
class Span:
    name: str
    cat: str
    start_s: float              # seconds since the tracer's epoch
    dur_s: float
    args: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class Tracer:
    def __init__(self, clock=time.perf_counter):
        self._clock = clock
        self._epoch = clock()
        self.spans: List[Span] = []

    def now_s(self) -> float:
        return self._clock() - self._epoch

    @contextlib.contextmanager
    def span(self, name: str, cat: str = "phase", **args):
        """Time a ``with`` block as one span."""
        t0 = self.now_s()
        try:
            yield self
        finally:
            self.add_span(name, cat=cat, start_s=t0,
                          dur_s=self.now_s() - t0, **args)

    def add_span(self, name: str, *, cat: str = "phase", start_s: float,
                 dur_s: float, **args) -> Span:
        """Record an externally-timed span (e.g. the serving engine's
        measured step durations on its hybrid clock)."""
        span = Span(name=name, cat=cat, start_s=float(start_s),
                    dur_s=float(max(0.0, dur_s)), args=dict(args))
        self.spans.append(span)
        return span

    # ------------------------------ export ----------------------------------

    def to_chrome_trace(self) -> dict:
        """Chrome Trace Event format (Perfetto-compatible), one complete
        ("ph": "X") event per span, microsecond timestamps."""
        cats = {}
        events = []
        for s in self.spans:
            tid = cats.setdefault(s.cat, len(cats))
            events.append({
                "name": s.name, "cat": s.cat, "ph": "X",
                "ts": round(s.start_s * 1e6, 3),
                "dur": round(s.dur_s * 1e6, 3),
                "pid": 0, "tid": tid, "args": s.args,
            })
        meta = [{"name": "thread_name", "ph": "M", "pid": 0, "tid": tid,
                 "args": {"name": cat}} for cat, tid in cats.items()]
        return {"traceEvents": meta + events, "displayTimeUnit": "ms"}

    def write(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)
        return path

    def total_s(self, cat: Optional[str] = None) -> float:
        return sum(s.dur_s for s in self.spans
                   if cat is None or s.cat == cat)

    def __len__(self) -> int:
        return len(self.spans)


__all__ = ["Span", "Tracer"]
