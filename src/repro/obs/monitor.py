"""Live detection-health monitor over the obs event bus.

:class:`Monitor` subscribes to an :class:`~repro.obs.EventBus` and keeps
sliding-window estimators per ``(op, tenant, cell)`` scope — windowed
detection counts and rates, false-positive rate vs. check count with
Wilson intervals, an escape proxy (injections seen minus flags seen),
and step-latency percentiles.  A declarative :class:`AlertRule` set is
evaluated on every observed step; firings drive a per-scope
``healthy → degraded → quarantined`` state machine
(:mod:`repro.obs.health`) with hysteresis and recovery probes.

Every consumer that already publishes into an ``Observability`` bundle
feeds the monitor for free: the serving engine's per-step summaries
(kind ``info`` / ``channel=step``, carrying per-op check/error counts,
resident tenants, and the step's wall duration), the campaign
executor's / serving soak's ``cell`` summaries, and injection events.
Alert firings and health transitions are emitted back onto the same bus
as typed events (schema v2 kinds ``alert`` / ``health``) plus registry
counters and tracer instants, so the whole control loop replays from
``obs_events.jsonl`` alone.

Windows are **tick-based by default** (last N observed steps per scope)
so alerting is deterministic under the engine's hybrid clock; time-based
windows (``window_s``) remain available for wall-rate rules like
``detections_per_s``.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import math
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

from repro.obs.health import (HEALTH_STATES, HealthPolicy, HealthTracker,
                              Transition)

_SEVERITIES = ("warn", "degrade", "quarantine")
_SEV_ORDER = {s: i for i, s in enumerate(_SEVERITIES)}
_CMPS = {">": lambda a, b: a > b, ">=": lambda a, b: a >= b,
         "<": lambda a, b: a < b, "<=": lambda a, b: a <= b}

#: metrics an AlertRule may watch
RULE_METRICS = ("detections", "detections_per_s", "checks", "flag_rate",
                "flag_rate_low", "flag_rate_high", "fp_rate",
                "fp_rate_low", "escape_proxy", "latency_p99_ms")


def wilson_interval(k: int, n: int, z: float = 1.96
                    ) -> Tuple[float, float]:
    """Wilson score interval for k successes in n trials (duplicated
    from ``repro.campaign.metrics`` to keep obs import-free of the
    campaign layer)."""
    if n == 0:
        return (0.0, 1.0)
    p = k / n
    denom = 1.0 + z * z / n
    center = (p + z * z / (2 * n)) / denom
    half = (z / denom) * math.sqrt(p * (1 - p) / n
                                   + z * z / (4 * n * n))
    return (max(0.0, center - half), min(1.0, center + half))


@dataclasses.dataclass(frozen=True)
class AlertRule:
    """One declarative alert condition.

    ``metric`` is computed per matching scope over the last
    ``window_ticks`` samples (or the last ``window_s`` seconds when
    ``window_ticks`` is 0).  With ``long_window_ticks``/``long_window_s``
    set, the rule is SLO burn-rate style: it fires only when BOTH the
    short and the long window exceed their thresholds (``long_threshold``
    defaults to ``threshold``), so a brief spike on an otherwise-quiet
    scope doesn't page.  ``severity`` feeds the health machine: ``warn``
    only records, ``degrade`` counts as alert pressure, ``quarantine``
    escalates the scope straight to quarantined."""
    name: str
    metric: str
    threshold: float
    cmp: str = ">="
    window_ticks: int = 8
    window_s: float = 0.0
    long_window_ticks: int = 0
    long_window_s: float = 0.0
    long_threshold: Optional[float] = None
    min_checks: int = 0          # rate metrics: skip below this many checks
    min_samples: int = 1
    op: str = "*"                # fnmatch over the scope's op kind
    tenant: str = "*"
    cell: str = "*"
    severity: str = "degrade"

    def __post_init__(self):
        if self.metric not in RULE_METRICS:
            raise ValueError(f"rule {self.name!r}: unknown metric "
                             f"{self.metric!r}; have {RULE_METRICS}")
        if self.cmp not in _CMPS:
            raise ValueError(f"rule {self.name!r}: unknown cmp "
                             f"{self.cmp!r}; have {tuple(_CMPS)}")
        if self.severity not in _SEVERITIES:
            raise ValueError(f"rule {self.name!r}: unknown severity "
                             f"{self.severity!r}; have {_SEVERITIES}")
        if not (self.window_ticks or self.window_s):
            raise ValueError(f"rule {self.name!r}: needs window_ticks "
                             f"or window_s")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def default_rules() -> Tuple[AlertRule, ...]:
    """The stock rule set the CLIs enable with ``--monitor``."""
    return (
        # a burst of detections within a handful of steps: degrade
        AlertRule("detection-burst", metric="detections", threshold=2,
                  cmp=">=", window_ticks=8, severity="degrade"),
        # sustained detections every step (persistent fault): quarantine
        AlertRule("detection-storm", metric="detections", threshold=6,
                  cmp=">=", window_ticks=12, severity="quarantine"),
        # burn-rate FP budget: Wilson lower bound above budget in BOTH
        # the short and the long window
        AlertRule("fp-budget-burn", metric="fp_rate_low", threshold=0.02,
                  cmp=">", window_ticks=32, long_window_ticks=128,
                  min_checks=40, severity="degrade"),
        # injections observed with no matching flags: detector may be off
        AlertRule("escape-proxy", metric="escape_proxy", threshold=1,
                  cmp=">=", window_ticks=16, severity="warn"),
    )


@dataclasses.dataclass(frozen=True)
class EngineResponses:
    """Which real responses the serving engine applies on transitions."""
    quarantine: bool = True      # gate the tenant's admissions
    escalate: bool = True        # upgrade the lane's ProtectionPlan
    scrub: bool = True           # scrub + repair the lane's paged KV

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class AlertFiring:
    """One rising-edge alert occurrence (until resolved)."""
    rule: str
    severity: str
    metric: str
    scope: str                   # health-scope label, e.g. "tenant:x"
    op: str
    tenant: str
    cell: str
    value: float
    threshold: float
    t_s: float
    tick: int
    resolved_t_s: Optional[float] = None

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class _Window:
    """Per-scope sample store: (tick, t, errors, checks) step samples
    plus (tick, t, ms) latency samples, bounded deques.  Samples carry
    the evaluation tick they were observed on so tick-windows age them
    out during idle ticks (otherwise a quarantined, traffic-gated scope
    would keep its last flagged samples in-window forever and never
    recover)."""
    __slots__ = ("samples", "lat")

    def __init__(self, maxlen: int = 2048):
        self.samples: deque = deque(maxlen=maxlen)
        self.lat: deque = deque(maxlen=maxlen)


def _percentile(values: List[float], q: float) -> float:
    if not values:
        return 0.0
    vs = sorted(values)
    idx = min(len(vs) - 1, max(0, int(math.ceil(q * len(vs))) - 1))
    return vs[idx]


def health_scope(op: str, tenant: str, cell: str) -> str:
    """The health-machine key an alert on (op, tenant, cell) rolls up
    to: tenants first (they gate admissions), then cells, then ops."""
    if tenant:
        return f"tenant:{tenant}"
    if cell:
        return f"cell:{cell}"
    return f"op:{op}"


class Monitor:
    """Streaming alert evaluator + health machine over the obs bus."""

    def __init__(self, rules: Optional[Sequence[AlertRule]] = None,
                 health: Optional[HealthPolicy] = None,
                 responses: Optional[EngineResponses] = None):
        self.rules: Tuple[AlertRule, ...] = tuple(
            rules if rules is not None else default_rules())
        self.health_policy = health if health is not None else \
            HealthPolicy()
        self.responses = responses if responses is not None else \
            EngineResponses()
        self._windows: Dict[Tuple[str, str, str], _Window] = {}
        self._match_cache: Dict[tuple, bool] = {}
        self._inj: deque = deque(maxlen=2048)   # (tick, t_s) injections
        self._active: Dict[Tuple[str, Tuple[str, str, str]],
                           AlertFiring] = {}
        self.alerts: List[AlertFiring] = []
        self.trackers: Dict[str, HealthTracker] = {}
        self._pending: List[Transition] = []
        self._obs = None
        self._tick = 0
        self._now = 0.0

    # ------------------------------ wiring -----------------------------------

    def bind(self, obs) -> "Monitor":
        """Subscribe to ``obs.bus`` (idempotent per bundle) and emit
        alert/health events + counters into the same bundle."""
        if obs is not None and obs is not self._obs:
            self._obs = obs
            obs.bus.subscribe(self.on_event)
        return self

    def on_event(self, ev) -> None:
        """Bus subscriber: folds every published event into the windows.
        The monitor's own ``alert``/``health`` events are ignored, so
        subscribing to the bus it emits into cannot recurse."""
        if ev.kind in ("alert", "health"):
            return
        if ev.kind == "injection":
            self._inj.append((self._tick + 1, ev.t_s))
            return
        if ev.kind == "cell":
            eff = int(ev.attrs.get("effective_detected", ev.errors))
            self.record_step(
                ev.t_s, {ev.op: (int(ev.checks), eff)},
                cell=ev.cell_id or "")
            return
        if ev.kind == "info" and ev.attrs.get("channel") == "step":
            by_op = {op: (int(ce[0]), int(ce[1]))
                     for op, ce in (ev.attrs.get("by_op") or {}).items()}
            self.record_step(
                ev.t_s, by_op,
                tenants=tuple(ev.attrs.get("tenants") or ()),
                duration_ms=ev.attrs.get("duration_ms"),
                kind=str(ev.attrs.get("kind", "")))
        # detection / false_positive events are per-op echoes of the
        # step summary — counting them too would double the windows

    # ------------------------------ ingestion --------------------------------

    def _window(self, op: str, tenant: str, cell: str) -> _Window:
        key = (op, tenant, cell)
        w = self._windows.get(key)
        if w is None:
            w = self._windows[key] = _Window()
        return w

    def record_step(self, t_s: float,
                    by_op: Dict[str, Tuple[int, int]], *,
                    tenants: Sequence[str] = (), cell: str = "",
                    duration_ms: Optional[float] = None,
                    kind: str = "") -> List[Transition]:
        """Fold one observed step into the windows and run an
        evaluation tick.  ``by_op`` maps op kind -> (checks, errors);
        counts are attributed to every resident tenant (a lane-step's
        flag blames everyone resident, same as request attribution).
        Returns any newly applied health transitions."""
        scopes = list(tenants) or [""]
        tick = self._tick + 1                 # the tick evaluate() runs
        for op, (checks, errors) in by_op.items():
            for tn in scopes:
                self._window(op, tn, cell).samples.append(
                    (tick, t_s, int(errors), int(checks)))
        if duration_ms is not None:
            for tn in scopes:
                self._window(f"step/{kind or 'step'}", tn, cell).lat \
                    .append((tick, t_s, float(duration_ms)))
        return self.evaluate(t_s)

    def idle_tick(self, t_s: float) -> List[Transition]:
        """A no-step evaluation tick (the engine calls this while all
        admissions are gated, so probes unlock and recovery can run)."""
        return self.evaluate(t_s)

    # ------------------------------ estimators -------------------------------

    @staticmethod
    def _tail(samples: deque, key: int, cutoff: float,
              strict: bool) -> List[tuple]:
        """The suffix of a tick/time-ordered deque past ``cutoff`` —
        scanned from the right with early exit, so a full 2048-sample
        deque costs only the window, not the history."""
        sel: List[tuple] = []
        for s in reversed(samples):
            if (s[key] <= cutoff) if strict else (s[key] < cutoff):
                break
            sel.append(s)
        sel.reverse()
        return sel

    def _agg(self, win: _Window, ticks: int, seconds: float,
             now: float) -> Tuple[int, int, int, int, float]:
        """One fused early-exit pass over the window's tail:
        (n, errors, checks, flagged, t0).  This runs per rule per tick
        on the hot path — no intermediate lists."""
        n = errors = checks = flagged = 0
        t0 = now
        if ticks > 0:
            cutoff = self._tick - ticks
            for s in reversed(win.samples):
                if s[0] <= cutoff:
                    break
                n += 1
                errors += s[2]
                checks += s[3]
                flagged += s[2] > 0
                t0 = s[1]
        else:
            cutoff_t = now - seconds
            for s in reversed(win.samples):
                if s[1] < cutoff_t:
                    break
                n += 1
                errors += s[2]
                checks += s[3]
                flagged += s[2] > 0
                t0 = s[1]
        return n, errors, checks, flagged, t0

    def _inj_in_window(self, ticks: int, seconds: float,
                       now: float) -> int:
        if ticks > 0:
            return len(self._tail(self._inj, 0, self._tick - ticks,
                                  True))
        return len(self._tail(self._inj, 1, now - seconds, False))

    def _metric_value(self, win: _Window, rule: AlertRule, now: float,
                      *, long: bool = False) -> Optional[float]:
        ticks = rule.long_window_ticks if long else rule.window_ticks
        seconds = rule.long_window_s if long else rule.window_s
        if long and not (ticks or seconds):
            return None
        m = rule.metric
        # empty windows can never clear min_samples — skip the scan
        if not (win.lat if m == "latency_p99_ms" else win.samples):
            return None
        if m == "latency_p99_ms":
            if ticks > 0:
                lat = [s[2] for s in self._tail(
                    win.lat, 0, self._tick - ticks, True)]
            else:
                lat = [s[2] for s in self._tail(
                    win.lat, 1, now - seconds, False)]
            if len(lat) < max(1, rule.min_samples):
                return None
            return _percentile(lat, 0.99)
        n, errors, checks, flagged, t0 = self._agg(win, ticks, seconds,
                                                   now)
        if n < max(1, rule.min_samples):
            return None
        if m == "detections":
            return float(errors)
        if m == "checks":
            return float(checks)
        if m == "detections_per_s":
            return errors / max(now - t0, 1e-9)
        if m == "escape_proxy":
            inj = self._inj_in_window(ticks, seconds, now)
            return float(max(0, inj - flagged))
        # rate metrics below need checks
        if checks < max(1, rule.min_checks):
            return None
        if m.startswith("fp_rate"):
            # FP proxy: flags with no known injection in the window are
            # presumed false (exactly right on clean runs)
            if self._inj_in_window(ticks, seconds, now):
                return 0.0
        lo, hi = wilson_interval(errors, checks)
        if m in ("flag_rate", "fp_rate"):
            return errors / checks
        if m in ("flag_rate_low", "fp_rate_low"):
            return lo
        return hi                                     # flag_rate_high

    def estimate(self, *, op: str = "*", tenant: str = "*",
                 cell: str = "*", window_ticks: int = 32) -> dict:
        """Windowed FP/detection estimate over matching scopes — the
        sensor ROADMAP item 2's threshold controller reads."""
        errors = checks = n = 0
        for (o, tn, cl), win in self._windows.items():
            if not (fnmatch.fnmatch(o, op) and fnmatch.fnmatch(tn, tenant)
                    and fnmatch.fnmatch(cl, cell)):
                continue
            wn, we, wc, _, _ = self._agg(win, window_ticks, 0.0,
                                         self._now)
            errors += we
            checks += wc
            n += wn
        lo, hi = wilson_interval(errors, checks) if checks else (0.0, 1.0)
        return {"samples": n, "errors": errors, "checks": checks,
                "flag_rate": errors / checks if checks else 0.0,
                "flag_rate_low": lo, "flag_rate_high": hi}

    # ------------------------------ evaluation -------------------------------

    def _rule_matches(self, rule: AlertRule,
                      key: Tuple[str, str, str]) -> bool:
        # memoized: the (rule, scope) product is re-walked every tick
        # and fnmatch is the hot path otherwise
        ck = (rule.name, key)
        hit = self._match_cache.get(ck)
        if hit is None:
            op, tenant, cell = key
            hit = ((rule.op == "*" or fnmatch.fnmatch(op, rule.op))
                   and (rule.tenant == "*"
                        or fnmatch.fnmatch(tenant, rule.tenant))
                   and (rule.cell == "*"
                        or fnmatch.fnmatch(cell, rule.cell)))
            self._match_cache[ck] = hit
        return hit

    def evaluate(self, t_s: float) -> List[Transition]:
        """One evaluation tick: re-check every rule against every scope,
        emit rising/falling alert edges, advance every health tracker.
        Returns the newly applied transitions (also queued for
        :meth:`poll_transitions`)."""
        self._now = max(self._now, t_s)
        self._tick += 1
        for rule in self.rules:
            for key, win in list(self._windows.items()):
                if not self._rule_matches(rule, key):
                    continue
                value = self._metric_value(win, rule, self._now)
                firing = value is not None and \
                    _CMPS[rule.cmp](value, rule.threshold)
                if firing and (rule.long_window_ticks
                               or rule.long_window_s):
                    lv = self._metric_value(win, rule, self._now,
                                            long=True)
                    lt = rule.long_threshold if rule.long_threshold \
                        is not None else rule.threshold
                    firing = lv is not None and _CMPS[rule.cmp](lv, lt)
                akey = (rule.name, key)
                if firing and akey not in self._active:
                    op, tenant, cell = key
                    f = AlertFiring(
                        rule=rule.name, severity=rule.severity,
                        metric=rule.metric,
                        scope=health_scope(op, tenant, cell),
                        op=op, tenant=tenant, cell=cell,
                        value=float(value), threshold=rule.threshold,
                        t_s=self._now, tick=self._tick)
                    self._active[akey] = f
                    self.alerts.append(f)
                    self._emit_alert(f, "firing")
                elif firing:
                    self._active[akey].value = float(value)
                elif akey in self._active:
                    f = self._active.pop(akey)
                    f.resolved_t_s = self._now
                    self._emit_alert(f, "resolved")

        # one health tick per evaluation, every known scope
        pressure: Dict[str, str] = {}       # scope -> max severity
        reasons: Dict[str, List[str]] = {}
        for f in self._active.values():
            if _SEV_ORDER[f.severity] < _SEV_ORDER["degrade"]:
                continue                     # warn never degrades health
            cur = pressure.get(f.scope)
            if cur is None or _SEV_ORDER[f.severity] > _SEV_ORDER[cur]:
                pressure[f.scope] = f.severity
            reasons.setdefault(f.scope, []).append(f.rule)
        applied: List[Transition] = []
        for scope in set(self.trackers) | set(pressure):
            tr = self.trackers.get(scope)
            if tr is None:
                tr = self.trackers[scope] = HealthTracker(
                    scope, self.health_policy)
            t = tr.update(
                scope in pressure, self._now,
                quarantine_grade=pressure.get(scope) == "quarantine",
                reason=",".join(sorted(set(reasons.get(scope, ())))))
            if t is not None:
                applied.append(t)
                self._emit_health(t)
        self._pending.extend(applied)
        return applied

    # ------------------------------ queries ----------------------------------

    def poll_transitions(self) -> List[Transition]:
        """Drain transitions applied since the last poll (the engine's
        response hook)."""
        out, self._pending = self._pending, []
        return out

    def state(self, scope: str) -> str:
        tr = self.trackers.get(scope)
        return tr.state if tr is not None else "healthy"

    def tenant_state(self, tenant: str) -> str:
        return self.state(f"tenant:{tenant}")

    def admission_allowed(self, tenant: str) -> bool:
        """False while the tenant's scope is quarantined, except for one
        recovery probe every ``probe_every`` ticks."""
        tr = self.trackers.get(f"tenant:{tenant}")
        if tr is None:
            return True
        return tr.take_probe()

    def active_alerts(self) -> List[AlertFiring]:
        return list(self._active.values())

    def summary(self) -> dict:
        transitions = sorted(
            (t for tr in self.trackers.values() for t in tr.transitions),
            key=lambda t: (t.t_s, t.tick))
        return {
            "ticks": self._tick,
            "rules": [r.name for r in self.rules],
            "responses": self.responses.to_dict(),
            "alerts_fired": len(self.alerts),
            "alerts": [f.to_dict() for f in self.alerts],
            "active_alerts": [f.to_dict()
                              for f in self._active.values()],
            "health": {s: tr.state
                       for s, tr in sorted(self.trackers.items())},
            "transitions": [t.to_dict() for t in transitions],
        }

    # ------------------------------ emission ---------------------------------

    def _emit_alert(self, f: AlertFiring, state: str) -> None:
        obs = self._obs
        if obs is None:
            return
        from repro.obs.events import FaultEvent
        if state == "firing":
            obs.registry.counter(
                "repro_alerts_total",
                "alert-rule firings by rule and scope").inc(
                    1, rule=f.rule, scope=f.scope, severity=f.severity)
        obs.tracer.add_span(f"alert:{f.rule}", cat="monitor",
                            start_s=f.t_s, dur_s=0.0, scope=f.scope,
                            state=state)
        obs.bus.emit(FaultEvent(
            op=f.op, step=f.tick, source="obs.monitor", kind="alert",
            t_s=self._now, cell_id=f.cell or None,
            detector_value=f.value, bound=f.threshold,
            attrs={"rule": f.rule, "severity": f.severity,
                   "metric": f.metric, "scope": f.scope,
                   "tenant": f.tenant, "state": state}))

    def _emit_health(self, t: Transition) -> None:
        obs = self._obs
        if obs is None:
            return
        from repro.obs.events import FaultEvent
        obs.registry.counter(
            "repro_health_transitions_total",
            "health state transitions by scope").inc(
                1, scope=t.scope, to=t.new)
        obs.registry.gauge(
            "repro_health_state",
            "current health (0 healthy / 1 degraded / 2 quarantined)"
        ).set(HEALTH_STATES.index(t.new), scope=t.scope)
        obs.tracer.add_span(f"health:{t.scope}", cat="monitor",
                            start_s=t.t_s, dur_s=0.0,
                            to=t.new)
        obs.bus.emit(FaultEvent(
            op="health", step=t.tick, source="obs.monitor",
            kind="health", t_s=t.t_s,
            attrs={"scope": t.scope, "from": t.old, "to": t.new,
                   "reason": t.reason, "tick": t.tick}))


__all__ = ["AlertRule", "AlertFiring", "EngineResponses", "Monitor",
           "RULE_METRICS", "default_rules", "health_scope",
           "wilson_interval"]
