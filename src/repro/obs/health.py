"""Quarantine-grade health state machine for monitored scopes.

One :class:`HealthTracker` per scope (a tenant, a campaign cell, an op
kind) walks ``healthy → degraded → quarantined`` on alert pressure and
back down one state at a time on sustained quiet — the hysteresis that
keeps a single noisy window from flapping a lane in and out of
quarantine.  Time is measured in **evaluation ticks** (one per monitor
evaluation, i.e. one per observed step), not wall seconds, so the
machine is deterministic under the serving engine's hybrid clock.

Escalation:

* ``healthy``: ``degrade_after`` consecutive alerting ticks → ``degraded``
  (a quarantine-severity alert jumps straight to ``quarantined``);
* ``degraded``: a quarantine-severity alert, or ``quarantine_after``
  consecutive alerting ticks → ``quarantined``.

Recovery steps DOWN one state per ``recover_after`` consecutive clean
ticks (``quarantined → degraded → healthy``), resetting the clean streak
at each step so every level earns its own quiet period.  While
quarantined, :meth:`HealthTracker.take_probe` admits one recovery probe
every ``probe_every`` ticks — the engine uses it to let a single request
through a quarantined lane so clean evidence can accumulate.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

#: state order; transitions move one index at a time on recovery
HEALTH_STATES = ("healthy", "degraded", "quarantined")


@dataclasses.dataclass(frozen=True)
class HealthPolicy:
    """Hysteresis knobs, all in evaluation ticks."""
    degrade_after: int = 1      # alerting ticks: healthy -> degraded
    quarantine_after: int = 3   # alerting ticks while degraded
    recover_after: int = 4      # clean ticks per one-state step-down
    probe_every: int = 4        # quarantined: one probe per N ticks

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Transition:
    """One applied state change, as the monitor reports it."""
    scope: str                  # e.g. "tenant:premium", "op:qgemm"
    old: str
    new: str
    t_s: float
    tick: int
    reason: str = ""            # the alert rule(s) that drove it

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class HealthTracker:
    """Per-scope state machine; :meth:`update` is one evaluation tick."""

    def __init__(self, scope: str, policy: Optional[HealthPolicy] = None):
        self.scope = scope
        self.policy = policy if policy is not None else HealthPolicy()
        self.state = "healthy"
        self.tick = 0
        self.alert_streak = 0
        self.clean_streak = 0
        self.transitions: List[Transition] = []
        self._last_probe = 0

    def _move(self, new: str, t_s: float, reason: str) -> Transition:
        tr = Transition(scope=self.scope, old=self.state, new=new,
                        t_s=t_s, tick=self.tick, reason=reason)
        self.state = new
        self.alert_streak = 0
        self.clean_streak = 0
        if new == "quarantined":
            self._last_probe = self.tick     # first probe earns its wait
        self.transitions.append(tr)
        return tr

    def update(self, alerting: bool, t_s: float, *,
               quarantine_grade: bool = False,
               reason: str = "") -> Optional[Transition]:
        """Advance one tick; returns the transition applied, if any."""
        p = self.policy
        self.tick += 1
        if alerting:
            self.alert_streak += 1
            self.clean_streak = 0
            if self.state == "healthy" \
                    and self.alert_streak >= p.degrade_after:
                target = "quarantined" if quarantine_grade else "degraded"
                return self._move(target, t_s, reason)
            if self.state == "degraded" and (
                    quarantine_grade
                    or self.alert_streak >= p.quarantine_after):
                return self._move("quarantined", t_s, reason)
            return None
        self.clean_streak += 1
        self.alert_streak = 0
        if self.state != "healthy" and self.clean_streak >= p.recover_after:
            down = HEALTH_STATES[HEALTH_STATES.index(self.state) - 1]
            return self._move(down, t_s, reason or "recovered")
        return None

    def take_probe(self) -> bool:
        """While quarantined: True once per ``probe_every`` ticks (the
        admission the engine lets through as a recovery probe)."""
        if self.state != "quarantined":
            return True
        if self.tick - self._last_probe >= self.policy.probe_every:
            self._last_probe = self.tick
            return True
        return False

    def to_dict(self) -> dict:
        return {"scope": self.scope, "state": self.state,
                "tick": self.tick, "alert_streak": self.alert_streak,
                "clean_streak": self.clean_streak,
                "transitions": [t.to_dict() for t in self.transitions]}


__all__ = ["HEALTH_STATES", "HealthPolicy", "HealthTracker", "Transition"]
