"""``repro.obs`` — unified observability for the fault-detection stack.

Host-side primitives shared by campaign, training, and serving:

* :class:`EventBus` + :class:`FaultEvent` (``events.py``) — typed fault
  events with JSONL export and schema validation;
* :class:`Tracer` (``trace.py``) — timed spans with Chrome/Perfetto
  trace export;
* :class:`MetricsRegistry` (``metrics.py``) — counters/gauges/histograms
  with Prometheus-text and JSON exporters;
* :class:`Monitor` (``monitor.py``) + :mod:`repro.obs.health` — live
  windowed rate estimators, alert rules, and quarantine-grade health
  states over the bus.

:class:`Observability` bundles bus/tracer/registry; pass one instance
through ``run_campaign(obs=...)`` / ``ServingEngine.run(obs=...)`` /
``TrainLoop.run(obs=...)`` and call :meth:`Observability.write` to drop
``events.jsonl`` / ``trace.json`` / ``metrics.prom`` / ``metrics.json``
into a directory — or :meth:`Observability.open_incremental` first so a
long soak flushes crash-durably as it runs.  ``FaultReport`` stays the
on-device monoid — obs is where its counters land after ``device_get``.

**Counter-mirror invariant** (what makes :func:`replay` exact): every
live event emission site pairs with specific registry increments, and
``replay`` re-applies exactly those increments from the event stream —
so a registry rebuilt from ``obs_events.jsonl`` alone matches the live
run's fault-pipeline counters sample-for-sample.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Iterable, Optional, Union

from repro.obs.events import (EVENT_KINDS, EVENT_SCHEMA,
                              EVENT_SCHEMA_VERSION, EventBus, FaultEvent,
                              events_from_metrics, validate_event)
from repro.obs.health import (HEALTH_STATES, HealthPolicy, HealthTracker,
                              Transition)
from repro.obs.metrics import (DEFAULT_BUCKETS, Counter, Gauge, Histogram,
                               MetricsRegistry, default_registry)
from repro.obs.monitor import (AlertFiring, AlertRule, EngineResponses,
                               Monitor, default_rules)
from repro.obs.trace import Span, Tracer


@dataclasses.dataclass
class Observability:
    """One run's event bus, tracer, and metrics registry."""
    bus: EventBus
    tracer: Tracer
    registry: MetricsRegistry
    _flush: Optional[dict] = dataclasses.field(
        default=None, repr=False, compare=False)

    @classmethod
    def create(cls) -> "Observability":
        return cls(bus=EventBus(), tracer=Tracer(),
                   registry=MetricsRegistry())

    # --------------------------- incremental flushing ------------------------

    def open_incremental(self, out_dir: str, prefix: str = "obs",
                         every: int = 100) -> Dict[str, str]:
        """Make this bundle crash-durable: append each event to
        ``<prefix>_events.jsonl`` as it is emitted (fsync'd), and rewrite
        the metrics/trace snapshots every ``every`` events.  A final
        :meth:`write` to the same directory is still a full, clean
        rewrite.  Returns the artifact paths."""
        os.makedirs(out_dir, exist_ok=True)
        join = lambda ext: os.path.join(out_dir, f"{prefix}_{ext}")  # noqa: E731
        paths = {"events": join("events.jsonl"),
                 "trace": join("trace.json"),
                 "prometheus": join("metrics.prom"),
                 "metrics_json": join("metrics.json")}
        f = open(paths["events"], "w")
        state = {"dir": out_dir, "prefix": prefix, "every": max(1, every),
                 "file": f, "since_snapshot": 0, "paths": paths}
        self._flush = state

        def _on_event(ev, _state=state, _self=self):
            fh = _state["file"]
            if fh is None or fh.closed:
                return
            fh.write(json.dumps(ev.to_dict(), sort_keys=True) + "\n")
            fh.flush()
            os.fsync(fh.fileno())
            _state["since_snapshot"] += 1
            if _state["since_snapshot"] >= _state["every"]:
                _self.maybe_flush(force=True)

        self.bus.subscribe(_on_event)
        # events emitted before opening must not be lost
        for ev in self.bus.events:
            f.write(json.dumps(ev.to_dict(), sort_keys=True) + "\n")
        f.flush()
        os.fsync(f.fileno())
        self.maybe_flush(force=True)
        return paths

    def maybe_flush(self, force: bool = False) -> bool:
        """Rewrite the metrics/trace snapshots if the incremental sink
        is open and due (or ``force``).  Returns True when written."""
        state = self._flush
        if state is None:
            return False
        if not force and state["since_snapshot"] < state["every"]:
            return False
        state["since_snapshot"] = 0
        paths = state["paths"]
        self.tracer.write(paths["trace"])
        self.registry.write_prometheus(paths["prometheus"])
        self.registry.write_json(paths["metrics_json"])
        return True

    def write(self, out_dir: str, prefix: str = "obs") -> Dict[str, str]:
        """Export everything; returns {artifact kind: path}.  Closes the
        incremental sink (if open on the same directory) first so the
        full rewrite wins."""
        state = self._flush
        if state is not None:
            if state["file"] is not None and not state["file"].closed:
                state["file"].close()
            self._flush = None
        os.makedirs(out_dir, exist_ok=True)
        join = lambda ext: os.path.join(out_dir, f"{prefix}_{ext}")  # noqa: E731
        return {
            "events": self.bus.to_jsonl(join("events.jsonl")),
            "trace": self.tracer.write(join("trace.json")),
            "prometheus": self.registry.write_prometheus(
                join("metrics.prom")),
            "metrics_json": self.registry.write_json(
                join("metrics.json")),
        }


def replay(events: Union[str, EventBus, Iterable[FaultEvent]],
           registry: Optional[MetricsRegistry] = None) -> MetricsRegistry:
    """Rebuild the fault-pipeline counters from an exported event stream.

    ``events`` may be a JSONL path, an :class:`EventBus`, or an iterable
    of :class:`FaultEvent` — what ``examples/obs_dashboard.py`` uses to
    turn a soak's ``obs_events.jsonl`` back into Prometheus text.

    Mirrors the live emission sites increment-for-increment:

    * ``detection`` — one ``repro_detections_total{op,source[,cell]}``
      inc per event (``observe_metrics`` pairs each flagged-op event
      with exactly one);
    * ``info``/``channel=step`` — the per-step summary's ``by_op``
      carries (checks, errors) per op kind →
      ``repro_abft_{checks,errors}_total{op,source}``;
    * ``info``/``channel=paging`` — ``repro_paging_ops_total{action,lane}``;
    * ``injection`` — ``repro_injections_total{source}``;
    * ``cell`` — the per-cell outcome counters the campaign/soak
      publishers inc (detections from ``attrs.effective_detected`` when
      present else ``errors``; injections from ``checks``; escapes /
      false_positives from attrs when the publisher emitted them);
    * ``threshold`` — adaptive-threshold controller moves →
      ``repro_threshold_adjustments_total{op,tenant,direction}`` + the
      ``repro_threshold_rel_bound`` gauge set to the new bound
      (``detector_value``);
    * ``alert`` (state=firing) — ``repro_alerts_total{rule,scope,severity}``;
    * ``health`` — monitor transitions →
      ``repro_health_transitions_total{scope,to}`` + the
      ``repro_health_state`` gauge; engine response actions →
      ``repro_health_actions_total{action,scope}``.

    ``false_positive`` cell-roll-up events carry no paired live inc (the
    ``cell`` event already covers the counter) and are replayed as
    events only.
    """
    if isinstance(events, str):
        events = EventBus.from_jsonl(events)
    registry = registry if registry is not None else MetricsRegistry()
    det = registry.counter(
        "repro_detections_total",
        "detected faults by op kind, source, and cell")
    fp = registry.counter(
        "repro_false_positives_total", "clean-run flags per cell")
    inj = registry.counter(
        "repro_injections_total", "injected faults by source and cell")
    esc = registry.counter(
        "repro_escapes_total", "undetected corruptions (SDC) per cell")
    errs = registry.counter(
        "repro_abft_errors_total", "residual ABFT errors by op kind")
    checks = registry.counter(
        "repro_abft_checks_total", "ABFT checks by op kind")
    for ev in events:
        if ev.kind == "detection":
            labels = {"op": ev.op, "source": ev.source}
            if ev.cell_id:
                labels["cell"] = ev.cell_id
            det.inc(1, **labels)
        elif ev.kind == "injection":
            inj.inc(1, source=ev.source)
        elif ev.kind == "cell":
            cell = ev.cell_id or ""
            det.inc(int(ev.attrs.get("effective_detected", ev.errors)),
                    cell=cell)
            inj.inc(int(ev.checks), cell=cell)
            if "escapes" in ev.attrs:
                esc.inc(int(ev.attrs["escapes"]), cell=cell)
            if "false_positives" in ev.attrs:
                fp.inc(int(ev.attrs["false_positives"]), cell=cell)
        elif ev.kind == "info":
            channel = ev.attrs.get("channel")
            if channel == "step":
                for op, ce in (ev.attrs.get("by_op") or {}).items():
                    checks.inc(int(ce[0]), op=op, source=ev.source)
                    errs.inc(int(ce[1]), op=op, source=ev.source)
            elif channel == "paging":
                registry.counter(
                    "repro_paging_ops_total",
                    "paged-KV lifecycle operations by action and lane"
                ).inc(1, action=str(ev.attrs.get("action", "")),
                      lane=str(ev.attrs.get("lane", "")))
        elif ev.kind == "threshold":
            op = ev.op
            tenant = str(ev.attrs.get("tenant", "*"))
            registry.counter(
                "repro_threshold_adjustments_total",
                "threshold-controller moves by op, tenant, and direction"
            ).inc(1, op=op, tenant=tenant,
                  direction=str(ev.attrs.get("direction", "")))
            if ev.detector_value is not None:
                registry.gauge(
                    "repro_threshold_rel_bound",
                    "current adaptive rel_bound per op and tenant").set(
                        float(ev.detector_value), op=op, tenant=tenant)
        elif ev.kind == "alert":
            if ev.attrs.get("state") == "firing":
                registry.counter(
                    "repro_alerts_total",
                    "alert-rule firings by rule and scope").inc(
                        1, rule=str(ev.attrs.get("rule", "")),
                        scope=str(ev.attrs.get("scope", "")),
                        severity=str(ev.attrs.get("severity", "")))
        elif ev.kind == "health":
            if "action" in ev.attrs:
                registry.counter(
                    "repro_health_actions_total",
                    "engine responses to health transitions").inc(
                        1, action=str(ev.attrs["action"]),
                        scope=str(ev.attrs.get("scope", "")))
            else:
                scope = str(ev.attrs.get("scope", ""))
                to = str(ev.attrs.get("to", ""))
                registry.counter(
                    "repro_health_transitions_total",
                    "health state transitions by scope").inc(
                        1, scope=scope, to=to)
                if to in HEALTH_STATES:
                    registry.gauge(
                        "repro_health_state",
                        "current health (0 healthy / 1 degraded / "
                        "2 quarantined)").set(
                            HEALTH_STATES.index(to), scope=scope)
    return registry


__all__ = ["Observability", "replay", "EventBus", "FaultEvent",
           "events_from_metrics", "validate_event", "EVENT_SCHEMA",
           "EVENT_SCHEMA_VERSION", "EVENT_KINDS", "Tracer", "Span",
           "MetricsRegistry", "Counter", "Gauge", "Histogram",
           "default_registry", "DEFAULT_BUCKETS",
           "Monitor", "AlertRule", "AlertFiring", "EngineResponses",
           "default_rules", "HealthPolicy", "HealthTracker", "Transition",
           "HEALTH_STATES"]
