"""``repro.obs`` — unified observability for the fault-detection stack.

Three host-side primitives shared by campaign, training, and serving:

* :class:`EventBus` + :class:`FaultEvent` (``events.py``) — typed fault
  events with JSONL export and schema validation;
* :class:`Tracer` (``trace.py``) — timed spans with Chrome/Perfetto
  trace export;
* :class:`MetricsRegistry` (``metrics.py``) — counters/gauges/histograms
  with Prometheus-text and JSON exporters.

:class:`Observability` bundles the three; pass one instance through
``run_campaign(obs=...)`` / ``ServingEngine.run(obs=...)`` /
``TrainLoop.run(obs=...)`` and call :meth:`Observability.write` to drop
``events.jsonl`` / ``trace.json`` / ``metrics.prom`` / ``metrics.json``
into a directory.  ``FaultReport`` stays the on-device monoid — obs is
where its counters land after ``device_get``.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, Iterable, Optional, Union

from repro.obs.events import (EVENT_KINDS, EVENT_SCHEMA,
                              EVENT_SCHEMA_VERSION, EventBus, FaultEvent,
                              events_from_metrics, validate_event)
from repro.obs.metrics import (DEFAULT_BUCKETS, Counter, Gauge, Histogram,
                               MetricsRegistry, default_registry)
from repro.obs.trace import Span, Tracer


@dataclasses.dataclass
class Observability:
    """One run's event bus, tracer, and metrics registry."""
    bus: EventBus
    tracer: Tracer
    registry: MetricsRegistry

    @classmethod
    def create(cls) -> "Observability":
        return cls(bus=EventBus(), tracer=Tracer(),
                   registry=MetricsRegistry())

    def write(self, out_dir: str, prefix: str = "obs") -> Dict[str, str]:
        """Export everything; returns {artifact kind: path}."""
        os.makedirs(out_dir, exist_ok=True)
        join = lambda ext: os.path.join(out_dir, f"{prefix}_{ext}")  # noqa: E731
        return {
            "events": self.bus.to_jsonl(join("events.jsonl")),
            "trace": self.tracer.write(join("trace.json")),
            "prometheus": self.registry.write_prometheus(
                join("metrics.prom")),
            "metrics_json": self.registry.write_json(
                join("metrics.json")),
        }


def replay(events: Union[str, EventBus, Iterable[FaultEvent]],
           registry: Optional[MetricsRegistry] = None) -> MetricsRegistry:
    """Rebuild a metrics registry from an exported event stream.

    ``events`` may be a JSONL path, an :class:`EventBus`, or an iterable
    of :class:`FaultEvent` — what ``examples/obs_dashboard.py`` uses to
    turn a soak's ``obs_events.jsonl`` back into Prometheus text."""
    if isinstance(events, str):
        events = EventBus.from_jsonl(events)
    registry = registry if registry is not None else MetricsRegistry()
    det = registry.counter(
        "repro_detections_total",
        "detected faults (detection events) by op kind and source")
    fp = registry.counter(
        "repro_false_positives_total",
        "clean-run flags (false_positive events) by op kind and source")
    inj = registry.counter(
        "repro_injections_total", "injected faults by source")
    errs = registry.counter(
        "repro_abft_errors_total", "residual ABFT errors by op kind")
    checks = registry.counter(
        "repro_abft_checks_total", "ABFT checks by op kind")
    for ev in events:
        labels = {"op": ev.op, "source": ev.source}
        if ev.cell_id:
            labels["cell"] = ev.cell_id
        if ev.kind == "detection":
            det.inc(1, **labels)
            errs.inc(ev.errors, op=ev.op)
            checks.inc(ev.checks, op=ev.op)
        elif ev.kind == "false_positive":
            fp.inc(1, **labels)
        elif ev.kind == "injection":
            inj.inc(1, source=ev.source)
    return registry


__all__ = ["Observability", "replay", "EventBus", "FaultEvent",
           "events_from_metrics", "validate_event", "EVENT_SCHEMA",
           "EVENT_SCHEMA_VERSION", "EVENT_KINDS", "Tracer", "Span",
           "MetricsRegistry", "Counter", "Gauge", "Histogram",
           "default_registry", "DEFAULT_BUCKETS"]
