"""Typed fault-event stream: the host-side landing zone for detections.

``FaultReport`` is the on-device monoid — static pytree structure, safe
to thread through ``lax.scan`` / ``vmap`` bodies.  This module is where
those counters *land* once a step's metrics are ``device_get``'d: each
flagged op kind becomes one :class:`FaultEvent` carrying the op kind,
the step, the emitting subsystem, and (when the caller knows them) the
cell id, shard, bit band, detector value vs. bound, and the request ids
resident in the affected slots.

The :class:`EventBus` mirrors the FaultReport contract host-side: it is
a monoid (``EventBus.merged`` is associative with the empty bus as
identity, and ``counters()`` of a merged bus equals the elementwise sum
of the parts), events append in emission order and never reset, and the
JSONL export round-trips through :func:`validate_event` so downstream
consumers (the CI obs-smoke job, ``examples/obs_dashboard.py``) can
treat the file as a schema'd stream rather than loose dicts.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Any, Dict, Iterable, Iterator, List, Optional, Tuple

#: bump when FaultEvent gains/renames REQUIRED fields.  v2 added the
#: monitor kinds ``alert`` / ``health``; v3 adds the adaptive-threshold
#: ``threshold`` kind (controller adjustments).  The wire format is
#: otherwise unchanged, so v1/v2 files (old committed artifacts) still
#: load.
EVENT_SCHEMA_VERSION = 3

#: the event taxonomy; ``validate_event`` rejects anything else
EVENT_KINDS = ("detection", "false_positive", "injection", "cell", "info",
               "alert", "health", "threshold")

#: required keys and their types in the JSONL wire format
EVENT_SCHEMA: Dict[str, tuple] = {
    "schema": (int,),
    "kind": (str,),
    "op": (str,),
    "step": (int,),
    "source": (str,),
    "t_s": (int, float),
    "errors": (int,),
    "checks": (int,),
    "cell_id": (str, type(None)),
    "shard": (int, type(None)),
    "bit_band": (str, type(None)),
    "detector_value": (int, float, type(None)),
    "bound": (int, float, type(None)),
    "request_ids": (list,),
    "attrs": (dict,),
}


@dataclasses.dataclass
class FaultEvent:
    """One observable fault-pipeline occurrence.

    ``op`` is a registered FaultReport op kind for detections
    (``qgemm`` / ``embedding_bag`` / ``kv_cache`` / ...); injection and
    cell-summary events use the injecting target's name.  ``request_ids``
    are the serving requests resident in the affected batcher slots when
    the flag fired — the per-request attribution the SLO lines consume.
    """
    op: str
    step: int
    source: str                              # e.g. "serving.engine"
    kind: str = "detection"
    t_s: float = 0.0
    errors: int = 0
    checks: int = 0
    cell_id: Optional[str] = None
    shard: Optional[int] = None
    bit_band: Optional[str] = None
    detector_value: Optional[float] = None   # what the detector measured
    bound: Optional[float] = None            # the threshold it compared to
    request_ids: Tuple[int, ...] = ()
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["request_ids"] = list(self.request_ids)
        d["schema"] = EVENT_SCHEMA_VERSION
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "FaultEvent":
        d = dict(d)
        d.pop("schema", None)
        d["request_ids"] = tuple(d.get("request_ids") or ())
        return cls(**d)


def validate_event(d: dict) -> dict:
    """Validate one JSONL record against :data:`EVENT_SCHEMA`.

    Returns the record; raises ``ValueError`` naming every violation (the
    CI obs-smoke job runs this over the whole exported stream)."""
    problems = []
    for key, types in EVENT_SCHEMA.items():
        if key not in d:
            problems.append(f"missing key {key!r}")
        elif not isinstance(d[key], types):
            problems.append(
                f"{key!r} has type {type(d[key]).__name__}, want one of "
                f"{[t.__name__ for t in types]}")
    if not problems:
        if d["kind"] not in EVENT_KINDS:
            problems.append(f"kind {d['kind']!r} not in {EVENT_KINDS}")
        if d["schema"] > EVENT_SCHEMA_VERSION:
            problems.append(f"schema {d['schema']} is newer than "
                            f"{EVENT_SCHEMA_VERSION}")
        if any(not isinstance(r, int) for r in d["request_ids"]):
            problems.append("request_ids must be a list of ints")
    if problems:
        raise ValueError(f"invalid FaultEvent: {'; '.join(problems)}")
    return d


class EventBus:
    """Append-only host-side sink for :class:`FaultEvent`s.

    Live consumers (the detection-health :class:`~repro.obs.Monitor`,
    incremental flushing) attach via :meth:`subscribe`; subscribers see
    each event at emission time, in order.  Subscribers are wiring, not
    data: they are NOT part of the monoid (``merged`` and ``from_jsonl``
    return un-subscribed buses)."""

    def __init__(self, events: Optional[Iterable[FaultEvent]] = None):
        self.events: List[FaultEvent] = list(events or [])
        self._subscribers: List = []

    def subscribe(self, fn) -> None:
        """Call ``fn(event)`` synchronously on every subsequent emit.
        Idempotent per callable."""
        if fn not in self._subscribers:
            self._subscribers.append(fn)

    # ------------------------------ monoid ----------------------------------

    def emit(self, event: FaultEvent) -> FaultEvent:
        self.events.append(event)
        for fn in list(self._subscribers):
            fn(event)
        return event

    def extend(self, events: Iterable[FaultEvent]) -> None:
        for ev in events:
            self.emit(ev)

    @classmethod
    def merged(cls, *buses: "EventBus") -> "EventBus":
        """Order-preserving concatenation — the host-side analogue of
        ``merge_reports`` (associative; the empty bus is the identity)."""
        out = cls()
        for b in buses:
            out.events.extend(b.events)
        return out

    def counters(self) -> Dict[str, int]:
        """Per-op error totals over the stream — comparable 1:1 with a
        merged FaultReport's ``errors`` dict for detection events."""
        out: Dict[str, int] = {}
        for ev in self.events:
            if ev.kind in ("detection", "false_positive"):
                out[ev.op] = out.get(ev.op, 0) + int(ev.errors)
        return out

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self) -> Iterator[FaultEvent]:
        return iter(self.events)

    # ------------------------------ JSONL -----------------------------------

    def to_jsonl(self, path: str) -> str:
        with open(path, "w") as f:
            for ev in self.events:
                f.write(json.dumps(ev.to_dict(), sort_keys=True) + "\n")
        return path

    @classmethod
    def from_jsonl(cls, path: str) -> "EventBus":
        """Load an exported stream; reads any schema <= the current
        version (v1 files predate the ``alert``/``health`` kinds, v2
        files predate ``threshold``, but are otherwise identical).
        Invalid records raise ``ValueError`` naming the offending
        ``path:line``."""
        bus = cls()
        with open(path) as f:
            for ln, line in enumerate(f, start=1):
                line = line.strip()
                if not line:
                    continue
                try:
                    bus.emit(FaultEvent.from_dict(
                        validate_event(json.loads(line))))
                except (ValueError, TypeError, KeyError) as e:
                    raise ValueError(f"{path}:{ln}: {e}") from e
        return bus


def op_counts(metrics: dict) -> List[Tuple[str, int, int]]:
    """``(op, checks, errors)`` per detection channel in a step's metrics.

    Accepts both metric spellings in circulation — the protect-layer
    ``abft/<kind>_*`` keys and the serving StepEvent's bare
    ``<kind>_*`` counters — with ``TrainLoop._errors_in``'s dedup rule:
    the legacy aggregate aliases (``abft/gemm_*`` = int8 + float GEMMs,
    ``abft/eb_*``) are consulted only when NO keyed counter is present,
    so a ``FaultReport.as_metrics()`` dict (which carries both) never
    double-counts.  The ``comm/errors`` checked_psum channel rides
    along as its own op.  Counts are ceiled: grad-accum averaging can
    make a detection arrive fractional (0.25 with accum=4), and
    truncation would silently drop it."""
    from repro.core.policy import op_kinds

    ceil = lambda v: int(math.ceil(float(v)))  # noqa: E731
    out: List[Tuple[str, int, int]] = []
    keyed = False
    for op in op_kinds():
        for prefix in (f"abft/{op}_", f"{op}_"):
            if f"{prefix}errors" in metrics or f"{prefix}checks" in metrics:
                keyed = True
                out.append((op, ceil(metrics.get(f"{prefix}checks", 0)),
                            ceil(metrics.get(f"{prefix}errors", 0))))
                break
    if not keyed:
        for alias, op in (("abft/gemm", "gemm"),
                          ("abft/eb", "embedding_bag")):
            if f"{alias}_errors" in metrics:
                out.append((op, ceil(metrics.get(f"{alias}_checks", 0)),
                            ceil(metrics[f"{alias}_errors"])))
    if "comm/errors" in metrics:
        out.append(("comm", ceil(metrics.get("comm/checks", 0)),
                    ceil(metrics["comm/errors"])))
    return out


def events_from_metrics(metrics: dict, *, step: int, source: str,
                        t_s: float = 0.0, kind: str = "detection",
                        cell_id: Optional[str] = None,
                        shard: Optional[int] = None,
                        bit_band: Optional[str] = None,
                        request_ids: Tuple[int, ...] = (),
                        ) -> List[FaultEvent]:
    """One :class:`FaultEvent` per detection channel with errors this
    step (see :func:`op_counts` for the spelling/dedup rules)."""
    return [FaultEvent(
        op=op, step=step, source=source, kind=kind, t_s=t_s,
        errors=errors, checks=checks, cell_id=cell_id,
        shard=shard, bit_band=bit_band,
        request_ids=tuple(request_ids))
        for op, checks, errors in op_counts(metrics) if errors > 0]


__all__ = ["FaultEvent", "EventBus", "events_from_metrics", "op_counts",
           "validate_event", "EVENT_SCHEMA", "EVENT_SCHEMA_VERSION",
           "EVENT_KINDS"]
