"""``repro.adapt`` — V-ABFT adaptive thresholds with an online FP-budget
controller.

The paper's EmbeddingBag detector (Eq. 5) compares the checksum residual
against ``rel_bound * max(mag, 1)`` where ``rel_bound`` has so far been a
static constant swept offline via ``--grid thresholds``.  Per V-ABFT
(arxiv 2602.08043) a threshold derived from the *observed* residual
variance dominates any fixed constant in mixed precision, and the right
operating point drifts with workload mix — so this module closes the
loop:

* :class:`VarianceModel` — per-op online EWMA estimators of the clean
  checksum-residual ratio (and, optionally, of the EB activation
  magnitudes it is normalized by); maps a target FP quantile to a
  ``rel_bound`` via the normal quantile of the tracked distribution.
  This is the *open-loop* prior: what the bound should be if the
  residual stream is the whole story.
* :class:`ThresholdController` — the *closed loop*: one controller per
  (op, tenant) reads the :class:`repro.obs.Monitor`'s Wilson-interval
  flag-rate estimate each evaluation tick and nudges ``rel_bound`` with
  bounded multiplicative steps (hysteresis deadband, hard floor/ceiling,
  cooldown between moves) to hold a configured FP budget while
  maximizing detection (the bound only rises when the Wilson *lower*
  bound exceeds the budget — i.e. when the FP overrun is statistically
  certain — and tightens when the Wilson *upper* bound sits safely
  under it).
* :class:`AdaptiveThresholds` — the per-run manager: owns controllers,
  ticks them from a Monitor, and emits every adjustment as a typed
  schema-v3 ``threshold`` event paired with registry increments (the
  live↔replay counter-mirror invariant extends to these events).
* :func:`calibrate_from_sweep` — seeds a controller's initial bound from
  a committed ``--grid thresholds`` sweep artifact: the sweep is the
  calibration tool, the controller keeps it on-budget online.

Direction convention: *raising* the FP budget buys a *tighter* (lower)
converged ``rel_bound`` — more FP headroom is spent on detection.  A
zero-FP stream therefore converges at the floor and stops moving.
"""
from __future__ import annotations

import dataclasses
import math
from statistics import NormalDist
from typing import Dict, Iterable, List, Optional, Tuple

#: registry names for the counter-mirror invariant (replay re-applies
#: these from ``threshold`` events)
ADJUSTMENTS_COUNTER = "repro_threshold_adjustments_total"
REL_BOUND_GAUGE = "repro_threshold_rel_bound"

_NORMAL = NormalDist()


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    """Tuning for one FP-budget control loop.

    ``fp_budget`` is the tolerated clean-flag (false-positive) rate.
    Moves are multiplicative by ``step`` and clamped to
    ``[floor, ceiling]``; ``hysteresis`` widens the deadband (the bound
    only tightens when the Wilson upper bound sits under
    ``fp_budget * hysteresis``), ``min_checks`` makes the controller
    abstain on thin evidence, ``cooldown_ticks`` spaces moves so each
    one's effect is observed before the next, and the loop counts as
    converged after ``settle_ticks`` evidence-bearing ticks without a
    move."""
    fp_budget: float = 0.01
    floor: float = 1e-7
    ceiling: float = 1e-2
    step: float = 1.5
    hysteresis: float = 0.5
    min_checks: int = 64
    cooldown_ticks: int = 2
    settle_ticks: int = 8
    window_ticks: int = 32

    def __post_init__(self):
        if not (0.0 < self.fp_budget < 1.0):
            raise ValueError("fp_budget must be in (0, 1)")
        if not (0.0 < self.floor <= self.ceiling):
            raise ValueError("need 0 < floor <= ceiling")
        if self.step <= 1.0:
            raise ValueError("step must be > 1 (multiplicative)")
        if not (0.0 < self.hysteresis <= 1.0):
            raise ValueError("hysteresis must be in (0, 1]")


class VarianceModel:
    """Online EWMA mean/variance of the clean residual ratio (and,
    optionally, of the raw EB activation magnitudes).

    ``observe`` folds clean-pass residual samples in; ``rel_bound(q)``
    returns the threshold at which a fraction ``q`` of the tracked
    (assumed-normal) residual distribution would flag — the open-loop
    V-ABFT bound for a target FP quantile ``q``.  When magnitudes are
    supplied alongside raw residuals, the ratio ``r / max(mag, 1)`` is
    what gets tracked, matching Eq. (5)'s comparison exactly."""

    def __init__(self, decay: float = 0.98):
        if not (0.0 < decay < 1.0):
            raise ValueError("decay must be in (0, 1)")
        self.decay = decay
        self.count = 0
        self._mean = 0.0
        self._var = 0.0
        self._mag_mean = 0.0

    def observe(self, residuals: Iterable[float],
                magnitudes: Optional[Iterable[float]] = None) -> None:
        if magnitudes is not None:
            pairs = [(float(r), float(m))
                     for r, m in zip(residuals, magnitudes)]
            values = [r / max(m, 1.0) for r, m in pairs]
            mags = [m for _, m in pairs]
        else:
            values = [float(r) for r in residuals]
            mags = []
        d = self.decay
        for v in values:
            if self.count == 0:
                self._mean, self._var = v, 0.0
            else:
                delta = v - self._mean
                self._mean += (1.0 - d) * delta
                # EWMA variance (West 1979 exponential form)
                self._var = d * (self._var + (1.0 - d) * delta * delta)
            self.count += 1
        for m in mags:
            self._mag_mean = (d * self._mag_mean + (1.0 - d) * m
                              if self._mag_mean else m)

    @property
    def mean(self) -> float:
        return self._mean

    @property
    def std(self) -> float:
        return math.sqrt(max(self._var, 0.0))

    @property
    def mag_mean(self) -> float:
        return self._mag_mean

    def rel_bound(self, fp_quantile: float, *, floor: float = 0.0,
                  ceiling: float = math.inf) -> float:
        """The bound at which the tracked ratio distribution flags with
        probability ``fp_quantile`` (normal-quantile approximation),
        clamped to ``[floor, ceiling]``."""
        if not (0.0 < fp_quantile < 1.0):
            raise ValueError("fp_quantile must be in (0, 1)")
        if self.count == 0:
            raise ValueError("no observations folded in yet")
        z = _NORMAL.inv_cdf(1.0 - fp_quantile)
        return min(max(self._mean + z * self.std, floor), ceiling)


class ThresholdController:
    """One (op, tenant)'s FP-budget control loop over ``rel_bound``.

    Feed it the Monitor's :meth:`~repro.obs.Monitor.estimate` dict once
    per evaluation tick; it returns the new bound when it moved, else
    ``None``.  Control law (all comparisons against Wilson interval
    endpoints, so moves only happen on statistically-backed evidence):

    * ``flag_rate_low > fp_budget`` — the FP overrun is certain: loosen
      (raise) the bound by ``×step``;
    * ``flag_rate_high < fp_budget * hysteresis`` — comfortably under
      budget: tighten (lower) by ``÷step`` to buy detection;
    * otherwise hold (deadband).

    Two refinements make the loop stable on real residual streams:

    * **fresh evidence only** — flags recorded before the last move were
      measured against a *different* bound; :meth:`evidence_window`
      clamps the estimator window to ticks-since-last-move so a move's
      effect is judged on its own evidence (otherwise stale flags keep
      driving same-direction moves for a full window after the bound is
      already right — runaway overshoot);
    * **cliff memory** — quantized residual distributions are steplike:
      fp(bound) can jump from ~0 to far over budget across a single
      multiplicative step, so no bound lands *inside* the deadband.  The
      controller remembers the highest bound observed to overrun and
      never tightens back to it, which turns the cliff's edge into a
      stable fixed point (one step above the last overrun).

    Bounds never exit ``[floor, ceiling]``; moves respect
    ``cooldown_ticks``; fewer than ``min_checks`` checks in the window
    means abstain.  ``converged`` after ``settle_ticks`` consecutive
    evidence-bearing ticks without a move."""

    def __init__(self, op: str, tenant: str = "*", *,
                 rel_bound: float,
                 config: ControllerConfig = ControllerConfig()):
        cfg = config
        self.op = op
        self.tenant = tenant
        self.config = cfg
        self.rel_bound = min(max(float(rel_bound), cfg.floor), cfg.ceiling)
        self.tick_count = 0
        self.adjustments = 0
        self.ticks_to_converge: Optional[int] = None
        self._last_move_tick = -cfg.cooldown_ticks - 1
        self._ticks_without_move = 0
        #: highest bound observed to overrun the budget (cliff memory)
        self._overrun_bound = 0.0

    @property
    def converged(self) -> bool:
        return self._ticks_without_move >= self.config.settle_ticks

    def evidence_window(self) -> int:
        """The estimator window (in ticks) for the *next* tick: capped
        at ``window_ticks`` and at ticks-since-last-move, so decisions
        never rest on flags measured against a superseded bound.
        Before the first move the seed bound has been in effect the
        whole time, so the full window applies."""
        if self.adjustments == 0:
            return self.config.window_ticks
        fresh = self.tick_count + 1 - self._last_move_tick
        return max(1, min(self.config.window_ticks, fresh))

    def tick(self, estimate: dict) -> Optional[float]:
        """One evaluation tick; returns the new bound iff it moved."""
        cfg = self.config
        self.tick_count += 1
        if int(estimate.get("checks", 0)) < cfg.min_checks:
            return None                       # abstain: thin evidence
        lo = float(estimate.get("flag_rate_low", 0.0))
        hi = float(estimate.get("flag_rate_high", 1.0))
        moved = None
        if self.tick_count - self._last_move_tick > cfg.cooldown_ticks:
            if lo > cfg.fp_budget and self.rel_bound < cfg.ceiling:
                self._overrun_bound = max(self._overrun_bound,
                                          self.rel_bound)
                moved = min(cfg.ceiling, self.rel_bound * cfg.step)
            elif (hi < cfg.fp_budget * cfg.hysteresis
                  and self.rel_bound > cfg.floor
                  and self.rel_bound / cfg.step
                  > self._overrun_bound * (1.0 + 1e-9)):
                moved = max(cfg.floor, self.rel_bound / cfg.step)
        if moved is None:
            self._ticks_without_move += 1
            if self.converged and self.ticks_to_converge is None:
                self.ticks_to_converge = self.tick_count
            return None
        self.rel_bound = moved
        self.adjustments += 1
        self._last_move_tick = self.tick_count
        self._ticks_without_move = 0
        self.ticks_to_converge = None         # drift restarts the clock
        return moved

    def summary(self) -> dict:
        return {"op": self.op, "tenant": self.tenant,
                "rel_bound": self.rel_bound,
                "adjustments": self.adjustments,
                "converged": self.converged,
                "ticks_to_converge": self.ticks_to_converge,
                "ticks": self.tick_count,
                "overrun_bound": self._overrun_bound}


class AdaptiveThresholds:
    """The per-run manager: controllers keyed by (op, tenant), ticked
    from a Monitor, every move a typed ``threshold`` event.

    Live emission per adjustment (mirrored exactly by
    :func:`repro.obs.replay`):

    * ``repro_threshold_adjustments_total{op,tenant,direction}`` +1;
    * ``repro_threshold_rel_bound{op,tenant}`` gauge set to the new
      bound;
    * a zero-duration tracer span ``threshold:<op>``;
    * one ``threshold`` :class:`~repro.obs.FaultEvent` carrying the new
      bound as ``detector_value``, the old as ``bound``, and the
      estimate snapshot in ``attrs``.
    """

    def __init__(self, *, config: ControllerConfig = ControllerConfig(),
                 obs=None, source: str = "adapt.controller"):
        self.config = config
        self.source = source
        self.controllers: Dict[Tuple[str, str], ThresholdController] = {}
        self._obs = obs

    def bind(self, obs) -> "AdaptiveThresholds":
        self._obs = obs
        return self

    def manage(self, op: str, tenant: str = "*", *,
               rel_bound: Optional[float] = None,
               config: Optional[ControllerConfig] = None
               ) -> ThresholdController:
        """Get-or-create the (op, tenant) controller.  ``rel_bound``
        seeds the initial bound (e.g. from
        :func:`calibrate_from_sweep`); ``None`` falls back to the op's
        registered default threshold."""
        key = (op, tenant)
        if key not in self.controllers:
            if rel_bound is None:
                rel_bound = _op_default_bound(op)
            self.controllers[key] = ThresholdController(
                op, tenant, rel_bound=rel_bound,
                config=config or self.config)
        return self.controllers[key]

    def tick(self, monitor, *, t_s: float = 0.0, step: int = 0
             ) -> Dict[Tuple[str, str], float]:
        """One evaluation tick over every controller; returns the moved
        (op, tenant) -> new bound map (empty = no recompiles needed)."""
        moved: Dict[Tuple[str, str], float] = {}
        for (op, tenant), c in self.controllers.items():
            est = monitor.estimate(op=op, tenant=tenant,
                                   window_ticks=c.evidence_window())
            old = c.rel_bound
            new = c.tick(est)
            if new is not None:
                moved[(op, tenant)] = new
                self._emit_threshold(c, old, new, est, t_s=t_s, step=step)
        return moved

    def summary(self) -> List[dict]:
        return [c.summary() for c in self.controllers.values()]

    def _emit_threshold(self, c: ThresholdController, old: float,
                        new: float, est: dict, *, t_s: float,
                        step: int) -> None:
        obs = self._obs
        if obs is None:
            return
        from repro.obs.events import FaultEvent
        direction = "raise" if new > old else "lower"
        obs.registry.counter(
            ADJUSTMENTS_COUNTER,
            "threshold-controller moves by op, tenant, and direction"
        ).inc(1, op=c.op, tenant=c.tenant, direction=direction)
        obs.registry.gauge(
            REL_BOUND_GAUGE,
            "current adaptive rel_bound per op and tenant").set(
                new, op=c.op, tenant=c.tenant)
        obs.tracer.add_span(f"threshold:{c.op}", cat="adapt",
                            start_s=t_s, dur_s=0.0, tenant=c.tenant,
                            direction=direction)
        obs.bus.emit(FaultEvent(
            op=c.op, step=step, source=self.source, kind="threshold",
            t_s=t_s, errors=int(est.get("errors", 0)),
            checks=int(est.get("checks", 0)),
            detector_value=new, bound=old,
            attrs={"tenant": c.tenant, "direction": direction,
                   "flag_rate": float(est.get("flag_rate", 0.0)),
                   "fp_budget": c.config.fp_budget,
                   "tick": c.tick_count, "converged": c.converged}))


def _op_default_bound(op: str) -> float:
    """The op adapter's static default threshold (the controller's seed
    when no calibration artifact is supplied)."""
    try:
        from repro.protect.ops import get_op
        d = getattr(get_op(op), "default_rel_bound", None)
        if d is not None:
            return float(d)
    except (KeyError, ImportError):
        pass
    from repro.core.abft_embedding import EB_REL_BOUND
    return float(EB_REL_BOUND)


def calibrate_from_sweep(artifact, *, fp_budget: float,
                         band: str = "*",
                         target: str = "embedding_bag") -> float:
    """Seed ``rel_bound`` from a ``--grid thresholds`` sweep artifact.

    ``artifact`` is a loaded artifact dict or a path to one.  Among the
    sweep points (restricted to ``band`` unless ``"*"``) whose measured
    FP rate is within ``fp_budget``, pick the smallest ``rel_bound`` —
    the tightest constant that held the budget offline, i.e. maximum
    detection.  If no point holds the budget, return the point with the
    lowest FP rate (the controller will loosen from there)."""
    import fnmatch

    from repro.campaign.artifacts import load_artifact, threshold_curve
    if isinstance(artifact, str):
        artifact = load_artifact(artifact)
    curve = threshold_curve(artifact, target=target)
    points = [p for b, pts in curve.items()
              if fnmatch.fnmatch(b, band) for p in pts]
    if not points:
        raise ValueError(f"no {target!r} sweep points matching band "
                         f"{band!r} in artifact")
    within = [p for p in points if p[2] <= fp_budget]
    if within:
        return min(p[0] for p in within)
    return min(points, key=lambda p: p[2])[0]


__all__ = ["ControllerConfig", "VarianceModel", "ThresholdController",
           "AdaptiveThresholds", "calibrate_from_sweep",
           "ADJUSTMENTS_COUNTER", "REL_BOUND_GAUGE"]
