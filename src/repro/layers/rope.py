"""Rotary position embeddings (f32 angles — exact out to 500k+ positions)."""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float = 10000.0):
    """x [..., S, H?, dh] with positions [..., S] broadcastable to x[..., S].

    Layout convention here: x is [B, S, H, dh]; positions [B, S] (or [S]).
    """
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # [dh/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, S, dh/2]
    cos = jnp.cos(ang)[..., None, :]                    # [B, S, 1, dh/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def sinusoid_positions(seq_len: int, d: int) -> jnp.ndarray:
    """Whisper-encoder style absolute sinusoid table [seq_len, d] (f32)."""
    half = d // 2
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    inv = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                  / max(half - 1, 1))
    ang = pos * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
