"""GShard-style Mixture of Experts (top-k gating, capacity factor).

Dispatch/combine are one-hot einsums within token groups — the TPU-native
MoE pattern (dense MXU work, static shapes, expert-parallel over `model`
when E divides the axis, expert-FFN TP otherwise; DESIGN.md §5).

In quant mode the per-expert FFN GEMMs run the paper's int8 ABFT pipeline,
batched over experts via vmap (one packed checksum per expert weight).  The
router and the dispatch/combine data movement stay in floating point: they
are index logic, which ABFT does not cover (same caveat as EB indices).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import policy
from repro.kernels import ops as kops
from repro.layers.common import Ctx
from repro.layers.linear import init_linear
from repro.protect import ops as pops
from repro.protect.runtime import protected_call
from repro.sharding import LogicalParam, constrain, param


def init_moe(key, d_model: int, d_ff: int, n_experts: int, *,
             quant: bool = False, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    p = {"router": init_linear(ks[0], d_model, n_experts,
                               ("embed", None), dtype, bias=False)}
    if quant:
        def q_expert(k, din, dout):
            kk = jax.random.split(k, n_experts)
            ws = jax.vmap(lambda kki: jax.random.randint(
                kki, (din, dout), -127, 128, jnp.int8))(kk)
            packed = jax.vmap(pops.QGEMM.encode)(ws)
            alpha = jax.random.uniform(k, (n_experts, dout), jnp.float32,
                                       1e-3, 2e-3)
            colsum = pops.QGEMM.dequant_colsum(ws)
            return {
                "w_packed": LogicalParam(packed,
                                         ("expert", "embed", "expert_mlp")),
                "alpha": LogicalParam(alpha, ("expert", "expert_mlp")),
                "colsum": LogicalParam(colsum, ("expert", "expert_mlp")),
            }
        p["gate"] = q_expert(ks[1], d_model, d_ff)
        p["up"] = q_expert(ks[2], d_model, d_ff)
        p["down"] = q_expert(ks[3], d_ff, d_model)
    else:
        p["gate"] = {"w": param(ks[1], (n_experts, d_model, d_ff),
                                ("expert", "embed", "expert_mlp"), dtype,
                                scale=d_model ** -0.5)}
        p["up"] = {"w": param(ks[2], (n_experts, d_model, d_ff),
                              ("expert", "embed", "expert_mlp"), dtype,
                              scale=d_model ** -0.5)}
        p["down"] = {"w": param(ks[3], (n_experts, d_ff, d_model),
                                ("expert", "expert_mlp", "embed"), dtype,
                                scale=d_ff ** -0.5)}
    return p


def _expert_matmul(wp, h, ctx: Ctx, name: str = "moe"):
    """h [E, C', d_in] x expert weights -> ([E, C', d_out], report)."""
    if "w_packed" in wp:
        def one(packed_e, h_e):
            h_q, a_alpha, a_beta = kops.quantize_rows(h_e)
            c, rep = protected_call("qgemm", packed_e, h_q, ctx=ctx,
                                    name=name)
            return c, a_alpha, a_beta, rep

        c, a_alpha, a_beta, reps = jax.vmap(one)(wp["w_packed"], h)
        # vmapped FaultReport: reduce counters over the expert axis
        report = jax.tree.map(jnp.sum, reps)
        y = (a_alpha[..., None] * (c.astype(jnp.float32)
                                   * wp["alpha"][:, None, :])
             + a_beta[..., None] * (wp["alpha"] * wp["colsum"])[:, None, :])
        return y.astype(ctx.compute_dtype), report
    y = jnp.einsum("ecd,edf->ecf", h.astype(ctx.compute_dtype),
                   wp["w"].astype(ctx.compute_dtype),
                   preferred_element_type=ctx.compute_dtype)
    return y, policy.empty_report()


def _route(xg, router_w, top_k: int):
    gate_logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(gate_logits, axis=-1)          # [g, G, E]
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)     # [g, G, k]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
    return probs, gate_vals, gate_idx


def _aux_loss(probs, gate_idx, n_experts: int):
    """Switch-style load-balance loss."""
    frac_tokens = jnp.mean(
        jax.nn.one_hot(gate_idx[..., 0], n_experts, dtype=jnp.float32),
        axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    return n_experts * jnp.sum(frac_tokens * frac_probs)


def _slot_assignment(gate_idx, n_experts: int, capacity: int):
    """Per-(token, k) expert slot via the per-k cumsum ordering.

    Returns slot [g, G, k] int32 (= e·C + pos, or E·C for dropped) — the
    same capacity/drop semantics as the one-hot dispatch, as integers.
    """
    g, G, k = gate_idx.shape
    counts = jnp.zeros((g, n_experts), jnp.int32)
    slots = []
    for kk in range(k):
        sel = jax.nn.one_hot(gate_idx[..., kk], n_experts,
                             dtype=jnp.int32)              # [g, G, E]
        pos = jnp.cumsum(sel, axis=1) - 1 + counts[:, None, :]
        pos_k = jnp.take_along_axis(
            pos, gate_idx[..., kk:kk + 1], axis=-1)[..., 0]       # [g, G]
        keep = pos_k < capacity
        slots.append(jnp.where(keep,
                               gate_idx[..., kk] * capacity + pos_k,
                               n_experts * capacity))
        counts = counts + jnp.sum(sel * (pos < capacity), axis=1)
    return jnp.stack(slots, axis=-1)                       # [g, G, k]


def moe_ffn(p, x, ctx: Ctx, *, n_experts: int, top_k: int,
            capacity_factor: float = 1.25, group_size: int = 1024
            ) -> Tuple[jax.Array, jax.Array, policy.FaultReport]:
    """x [B, S, d] -> (y [B, S, d], aux_loss scalar, report).

    Dispatch/combine implementation is selected by ``ctx.moe_gather``:
      * False — GShard one-hot einsums (baseline; dense MXU work of
        O(G·E·C·d) MACs over mostly-zero one-hots);
      * True  — scatter/gather indexing with identical capacity semantics:
        O(E·C·d) pure data movement, zero matmul waste
        (EXPERIMENTS §Perf hillclimb 2).
    """
    b, s, d = x.shape
    tokens = b * s
    g_sz = min(group_size, tokens)
    n_groups = tokens // g_sz
    assert n_groups * g_sz == tokens, (tokens, g_sz)
    xg = x.reshape(n_groups, g_sz, d)

    router_w = p["router"]["w"].astype(jnp.float32)
    probs, gate_vals, gate_idx = _route(xg, router_w, top_k)
    aux = _aux_loss(probs, gate_idx, n_experts)

    capacity = max(int(g_sz * top_k * capacity_factor / n_experts), 4)
    capacity = min(capacity, g_sz)

    if ctx.moe_seq_groups and n_groups > 1:
        # Sequence the group dim: one group's 10x-amplified expert buffers
        # live at a time (top-k · capacity_factor token amplification is
        # what blows HBM on high-top-k archs) — EXPERIMENTS §Perf
        # hillclimb 2, iteration 5.
        @jax.checkpoint
        def group_body(_, inp):
            xg_g, gv_g, gi_g = inp
            y_g, rep_g = _moe_group(p, xg_g[None], gv_g[None], gi_g[None],
                                    ctx, n_experts, top_k, capacity)
            return None, (y_g[0], rep_g)

        _, (yg, reps) = jax.lax.scan(group_body, None,
                                     (xg, gate_vals, gate_idx))
        rep = jax.tree.map(jnp.sum, reps)
        return (yg.reshape(b, s, d).astype(ctx.compute_dtype), aux, rep)

    y, rep = _moe_group(p, xg, gate_vals, gate_idx, ctx, n_experts, top_k,
                        capacity)
    return (y.reshape(b, s, d).astype(ctx.compute_dtype), aux, rep)


def _moe_group(p, xg, gate_vals, gate_idx, ctx: Ctx, n_experts: int,
               top_k: int, capacity: int):
    """Dispatch -> expert FFN -> combine for a block of groups."""
    n_groups, g_sz, d = xg.shape
    if ctx.moe_gather:
        e_in, slot = _dispatch_gather(xg, gate_idx, n_experts, capacity)
    else:
        e_in, combine = _dispatch_onehot(xg, gate_vals, gate_idx,
                                         n_experts, capacity)

    # Token-parallel MoE (EXPERIMENTS §Perf hillclimb 2): ONLY when the
    # rules map "moe_tokens" (small-expert archs whose weights fit
    # replicated) — an unconditional constraint would DEMAND replication
    # of the unmapped dims and defeat SPMD propagation (measured: granite
    # collective term 25 -> 198 s; reverted).
    tp = ctx.rules is not None and ctx.rules.get("moe_tokens") is not None

    def _tp(x):
        return constrain(x, ("expert", "moe_tokens", None),
                         ctx.rules) if tp else x

    e_in = _tp(e_in)
    gate_h, r1 = _expert_matmul(p["gate"], e_in, ctx)
    up_h, r2 = _expert_matmul(p["up"], e_in, ctx)
    h = jax.nn.silu(gate_h.astype(jnp.float32)).astype(ctx.compute_dtype) \
        * up_h
    h = _tp(h)
    out, r3 = _expert_matmul(p["down"], h, ctx)            # [E, g*C, d]
    out = _tp(out)

    if ctx.moe_gather:
        y = _combine_gather(out, slot, gate_vals, n_groups, n_experts,
                            capacity, ctx)
    else:
        out = out.reshape(n_experts, n_groups, capacity,
                          d).transpose(1, 0, 2, 3)
        y = jnp.einsum("gsec,gecd->gsd", combine.astype(jnp.bfloat16),
                       out.astype(jnp.bfloat16),
                       preferred_element_type=ctx.compute_dtype)
    return y, policy.merge_reports(r1, r2, r3)


def _dispatch_onehot(xg, gate_vals, gate_idx, n_experts: int,
                     capacity: int):
    """GShard baseline: one-hot [g,G,E,C] dispatch/combine tensors."""
    n_groups, g_sz, d = xg.shape
    top_k = gate_idx.shape[-1]
    dispatch = jnp.zeros((n_groups, g_sz, n_experts, capacity), jnp.bfloat16)
    combine = jnp.zeros((n_groups, g_sz, n_experts, capacity), jnp.float32)
    counts = jnp.zeros((n_groups, n_experts), jnp.int32)
    for kk in range(top_k):
        sel = jax.nn.one_hot(gate_idx[..., kk], n_experts,
                             dtype=jnp.int32)              # [g, G, E]
        pos = jnp.cumsum(sel, axis=1) - 1 + counts[:, None, :]
        keep = (pos < capacity) & (sel > 0)
        pos_oh = jax.nn.one_hot(jnp.where(keep, pos, capacity),
                                capacity + 1, dtype=jnp.bfloat16)[..., :-1]
        slot = sel.astype(jnp.bfloat16)[..., None] * pos_oh  # [g,G,E,C]
        dispatch = dispatch + slot
        combine = combine + slot.astype(jnp.float32) * \
            gate_vals[..., kk][..., None, None]
        counts = counts + jnp.sum(sel * keep.astype(jnp.int32), axis=1)

    expert_in = jnp.einsum("gsec,gsd->gecd", dispatch,
                           xg.astype(jnp.bfloat16),
                           preferred_element_type=jnp.bfloat16)
    e_in = expert_in.transpose(1, 0, 2, 3).reshape(
        n_experts, n_groups * capacity, xg.shape[-1])      # [E, g*C, d]
    return e_in, combine


def _dispatch_gather(xg, gate_idx, n_experts: int, capacity: int):
    """Index-based dispatch: scatter token ids into expert slots, gather
    rows.  Same slot assignment as the one-hot path, none of its MACs."""
    n_groups, g_sz, d = xg.shape
    top_k = gate_idx.shape[-1]
    slot = _slot_assignment(gate_idx, n_experts, capacity)   # [g, G, k]

    token_ids = jnp.broadcast_to(
        jnp.arange(g_sz, dtype=jnp.int32)[None, :, None],
        (n_groups, g_sz, top_k)).reshape(n_groups, -1)
    flat_slot = slot.reshape(n_groups, -1)                   # [g, G*k]

    def scatter_one(slots_g, toks_g):
        init = jnp.full((n_experts * capacity,), g_sz, jnp.int32)
        return init.at[slots_g].set(toks_g, mode="drop")

    token_for_slot = jax.vmap(scatter_one)(flat_slot, token_ids)
    xg_pad = jnp.concatenate(
        [xg, jnp.zeros((n_groups, 1, d), xg.dtype)], axis=1)
    rows = jnp.take_along_axis(
        xg_pad, token_for_slot[..., None], axis=1)           # [g, E*C, d]
    e_in = (rows.reshape(n_groups, n_experts, capacity, d)
            .transpose(1, 0, 2, 3)
            .reshape(n_experts, n_groups * capacity, d)
            .astype(jnp.bfloat16))
    return e_in, slot


def _combine_gather(out, slot, gate_vals, n_groups: int, n_experts: int,
                    capacity: int, ctx: Ctx):
    """y[s] = Σ_k gate[s,k] · out[slot[s,k]] (dropped slots → 0)."""
    d = out.shape[-1]
    out_g = (out.reshape(n_experts, n_groups, capacity, d)
             .transpose(1, 0, 2, 3)
             .reshape(n_groups, n_experts * capacity, d))
    out_pad = jnp.concatenate(
        [out_g, jnp.zeros((n_groups, 1, d), out_g.dtype)], axis=1)
    g_sz = slot.shape[1]
    flat = slot.reshape(n_groups, -1)                        # [g, G*k]
    picked = jnp.take_along_axis(
        out_pad, flat[..., None], axis=1).reshape(
        n_groups, g_sz, -1, d)                               # [g, G, k, d]
    y = jnp.sum(picked.astype(jnp.float32)
                * gate_vals[..., None].astype(jnp.float32), axis=2)
    return y.astype(ctx.compute_dtype)
