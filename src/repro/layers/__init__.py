"""Model-building layers. Every forward returns ``(y, FaultReport)`` so ABFT
detection results flow up to the step functions."""
