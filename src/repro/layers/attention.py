"""GQA attention: chunked flash-scan (train/prefill) + KV-cache decode.

Layouts (sharding-driven, DESIGN.md §5):
  * train/prefill: q,k,v in **H-layout** [B, H, S, dh] with KV heads repeated
    to H — the head dim shards cleanly on `model` (H % 16 == 0 archs) and the
    repeat is a local slice under SPMD.  KV memory stays O(local heads).
  * decode: cache in **grouped KV layout** [B, Kv, S, dh] with the *sequence*
    dim sequence-parallel over `model` (kv_heads of 5/8/20 never divide 16);
    softmax statistics and PV partials reduce over shards with tiny
    collectives.

The flash-scan streams KV chunks with online-softmax statistics (f32), so
score matrices never materialize beyond [.., Sq, chunk].  Masking supports:
causal, sliding window (traced per-layer scalar), and an always-visible
global prefix (Hymba meta tokens).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import policy
from repro.layers.common import Ctx
from repro.layers.linear import apply_linear, maybe_qlinear_init
from repro.paging.cache import PagedKV
from repro.protect.ops import KV_CACHE, KV_CACHE_PAGED, QuantKV
from repro.protect.runtime import kv_rule, paged_kv_rule, protected_call
from repro.layers.norms import headnorm, init_headnorm
from repro.layers.rope import apply_rope
from repro.sharding import constrain

NEG_INF = -1e30


def _constrain_quant_kv(kv: QuantKV, rules) -> QuantKV:
    """Sequence-parallel constraints for the int8 cache — same ``kv_seq``
    layout as the bf16 cache, applied per QuantKV field (the payload has a
    trailing head dim; the affine params and rowsums do not)."""
    return QuantKV(
        q=constrain(kv.q, ("batch", None, "kv_seq", None), rules),
        alpha=constrain(kv.alpha, ("batch", None, "kv_seq"), rules),
        beta=constrain(kv.beta, ("batch", None, "kv_seq"), rules),
        rowsum=constrain(kv.rowsum, ("batch", None, "kv_seq"), rules),
    )


def init_attention(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
                   *, qk_norm: bool = False, quant: bool = False,
                   dtype=jnp.float32, bias: bool = False):
    ks = jax.random.split(key, 4)
    p = {
        "wq": maybe_qlinear_init(ks[0], d_model, n_heads * head_dim,
                                 ("embed", "heads_x"), quant, dtype, bias),
        "wk": maybe_qlinear_init(ks[1], d_model, n_kv * head_dim,
                                 ("embed", "heads_x"), quant, dtype, bias),
        "wv": maybe_qlinear_init(ks[2], d_model, n_kv * head_dim,
                                 ("embed", "heads_x"), quant, dtype, bias),
        "wo": maybe_qlinear_init(ks[3], n_heads * head_dim, d_model,
                                 ("heads_x", "embed"), quant, dtype, bias),
    }
    if qk_norm:
        p["q_norm"] = init_headnorm(head_dim, dtype)
        p["k_norm"] = init_headnorm(head_dim, dtype)
    return p


def _split_heads(x, n: int, head_dim: int):
    b, s, _ = x.shape
    return x.reshape(b, s, n, head_dim)


def _qkv(p, x, x_kv, ctx, *, n_heads, n_kv, head_dim, positions, kv_pos,
         use_rope, rope_theta, rules):
    """Project + norm + rope + repeat-to-H. Returns q,k,v in H-layout."""
    src = x if x_kv is None else x_kv
    q, r1 = apply_linear(p["wq"], x, ctx, name="attn.wq")
    k, r2 = apply_linear(p["wk"], src, ctx, name="attn.wk")
    v, r3 = apply_linear(p["wv"], src, ctx, name="attn.wv")
    q = _split_heads(q, n_heads, head_dim)
    k = _split_heads(k, n_kv, head_dim)
    v = _split_heads(v, n_kv, head_dim)
    if "q_norm" in p:
        q = headnorm(p["q_norm"], q)
        k = headnorm(p["k_norm"], k)
    if use_rope and x_kv is None:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, kv_pos, rope_theta)
    g = n_heads // n_kv
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    # H-layout [B, H, S, dh]; H shards on `model` when divisible.
    q = constrain(q.transpose(0, 2, 1, 3), ("batch", "heads_x", None, None),
                  rules)
    k = constrain(k.transpose(0, 2, 1, 3), ("batch", "heads_x", None, None),
                  rules)
    v = constrain(v.transpose(0, 2, 1, 3), ("batch", "heads_x", None, None),
                  rules)
    return q, k, v, (r1, r2, r3)


def flash_attention(q, k, v, *, q_positions, kv_positions, causal: bool,
                    window=None, prefix_global: int = 0, chunk: int = 1024):
    """Online-softmax attention over KV chunks.

    q [B,H,Sq,dh]; k,v [B,H,Skv,dh]; q_positions [B,Sq]; kv_positions
    [B,Skv] (−1 marks padding); window may be a traced scalar.
    Returns [B,H,Sq,dh] (f32)."""
    b, h, sq, dh = q.shape
    skv = k.shape[2]
    chunk = min(chunk, skv)
    n_chunks = -(-skv // chunk)
    pad = n_chunks * chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pad)),
                               constant_values=-1)
    kc = k.reshape(b, h, n_chunks, chunk, dh).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, h, n_chunks, chunk, dh).transpose(2, 0, 1, 3, 4)
    pc = kv_positions.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    scale = dh ** -0.5
    qf = q.astype(jnp.bfloat16)

    def body(carry, xs):
        m, l, acc = carry
        k_i, v_i, p_i = xs                                # [B,H,C,dh],[B,C]
        s = jnp.einsum("bhsd,bhtd->bhst", qf, k_i.astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32) * scale
        qp = q_positions[:, None, :, None]                # [B,1,Sq,1]
        kp = p_i[:, None, None, :]                        # [B,1,1,C]
        mask = kp >= 0
        if causal:
            mask &= qp >= kp
        if window is not None:
            in_win = (qp - kp) < window
            if prefix_global > 0:
                in_win |= kp < prefix_global
            mask &= in_win
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = (acc * corr[..., None]
                   + jnp.einsum("bhst,bhtd->bhsd",
                                p.astype(jnp.bfloat16),
                                v_i.astype(jnp.bfloat16),
                                preferred_element_type=jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    acc0 = jnp.zeros((b, h, sq, dh), jnp.float32)
    if n_chunks == 1:
        (m, l, acc), _ = body((m0, l0, acc0), (kc[0], vc[0], pc[0]))
    else:
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (kc, vc, pc))
    return acc / jnp.maximum(l, 1e-37)[..., None]


def attention(p, x, ctx: Ctx, *, n_heads: int, n_kv: int, head_dim: int,
              positions, rope_theta: float = 10000.0, use_rope: bool = True,
              causal: bool = True, window=None, prefix_global: int = 0,
              x_kv=None, kv_positions=None,
              chunk: int = 1024) -> Tuple[jax.Array, policy.FaultReport]:
    """Full-sequence attention (train). x [B,S,d] -> [B,S,d].

    ``x_kv`` switches to cross-attention (keys/values from the encoder)."""
    b, s, _ = x.shape
    kv_pos = positions if kv_positions is None else kv_positions
    q, k, v, reps = _qkv(p, x, x_kv, ctx, n_heads=n_heads, n_kv=n_kv,
                         head_dim=head_dim, positions=positions,
                         kv_pos=kv_pos, use_rope=use_rope,
                         rope_theta=rope_theta, rules=ctx.rules)
    out = flash_attention(q, k, v, q_positions=positions,
                          kv_positions=kv_pos, causal=causal, window=window,
                          prefix_global=prefix_global, chunk=chunk)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, n_heads * head_dim)
    y, r4 = apply_linear(p["wo"], out.astype(ctx.compute_dtype), ctx,
                         name="attn.wo")
    return y, policy.merge_reports(*reps, r4)


def attention_prefill(p, x, ctx: Ctx, *, n_heads, n_kv, head_dim, positions,
                      cache_len: int, rope_theta=10000.0, use_rope=True,
                      window=None, prefix_global: int = 0, chunk: int = 1024):
    """Prefill: attention() + the populated grouped-layout KV cache, padded
    to ``cache_len``."""
    b, s, _ = x.shape
    q, r1 = apply_linear(p["wq"], x, ctx, name="attn.wq")
    k, r2 = apply_linear(p["wk"], x, ctx, name="attn.wk")
    v, r3 = apply_linear(p["wv"], x, ctx, name="attn.wv")
    q = _split_heads(q, n_heads, head_dim)
    kh = _split_heads(k, n_kv, head_dim)
    vh = _split_heads(v, n_kv, head_dim)
    if "q_norm" in p:
        q = headnorm(p["q_norm"], q)
        kh = headnorm(p["k_norm"], kh)
    if use_rope:
        q = apply_rope(q, positions, rope_theta)
        kh = apply_rope(kh, positions, rope_theta)
    g = n_heads // n_kv
    k_full = jnp.repeat(kh, g, axis=2) if g > 1 else kh
    v_full = jnp.repeat(vh, g, axis=2) if g > 1 else vh
    qh = constrain(q.transpose(0, 2, 1, 3),
                   ("batch", "heads_x", None, None), ctx.rules)
    k_full = constrain(k_full.transpose(0, 2, 1, 3),
                       ("batch", "heads_x", None, None), ctx.rules)
    v_full = constrain(v_full.transpose(0, 2, 1, 3),
                       ("batch", "heads_x", None, None), ctx.rules)
    out = flash_attention(qh, k_full, v_full, q_positions=positions,
                          kv_positions=positions, causal=True, window=window,
                          prefix_global=prefix_global, chunk=chunk)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, n_heads * head_dim)
    y, r4 = apply_linear(p["wo"], out.astype(ctx.compute_dtype), ctx,
                         name="attn.wo")
    pad = cache_len - s
    kt = kh.transpose(0, 2, 1, 3)            # grouped layout [B,Kv,S,dh]
    vt = vh.transpose(0, 2, 1, 3)
    if kv_rule(ctx).enabled:
        # plan-selected quantized + checksummed cache (op kind kv_cache):
        # per-(position, head) int8 rows with rowsum checksums — decode
        # verifies every read (core.abft_kvcache)
        kt = jnp.pad(kt.astype(jnp.float32),
                     ((0, 0), (0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(vt.astype(jnp.float32),
                     ((0, 0), (0, 0), (0, pad), (0, 0)))
        cache = {"k": _constrain_quant_kv(KV_CACHE.encode(kt), ctx.rules),
                 "v": _constrain_quant_kv(KV_CACHE.encode(vt), ctx.rules)}
    else:
        cache = {
            "k": constrain(jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0))),
                           ("batch", None, "kv_seq", None), ctx.rules),
            "v": constrain(jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0))),
                           ("batch", None, "kv_seq", None), ctx.rules),
        }
    return y, cache, policy.merge_reports(r1, r2, r3, r4)


def attention_decode(p, x, cache, pos, ctx: Ctx, *, n_heads: int, n_kv: int,
                     head_dim: int, rope_theta: float = 10000.0,
                     use_rope: bool = True, window=None,
                     prefix_global: int = 0, cross: bool = False):
    """One-token decode. x [B,d]; cache {k,v [B,Kv,S,dh]} (seq-sharded) —
    bf16 arrays, or QuantKV when the plan enables kv_cache protection;
    pos [B].  Cross-attention decode attends a static (encoder) cache.
    Returns (y [B,d], new_cache, report)."""
    b, d = x.shape
    paged_kv = isinstance(cache["k"], PagedKV)
    quant_kv = isinstance(cache["k"], QuantKV)
    s_max = 0 if paged_kv \
        else (cache["k"].q if quant_kv else cache["k"]).shape[2]
    q, r1 = apply_linear(p["wq"], x[:, None, :], ctx, name="attn.wq")
    q = _split_heads(q, n_heads, head_dim)                  # [B,1,H,dh]
    if not cross:
        k_new, r2 = apply_linear(p["wk"], x[:, None, :], ctx,
                                 name="attn.wk")
        v_new, r3 = apply_linear(p["wv"], x[:, None, :], ctx,
                                 name="attn.wv")
        k_new = _split_heads(k_new, n_kv, head_dim)
        v_new = _split_heads(v_new, n_kv, head_dim)
        if "q_norm" in p:
            q = headnorm(p["q_norm"], q)
            k_new = headnorm(p["k_norm"], k_new)
        if use_rope:
            q = apply_rope(q, pos[:, None], rope_theta)
            k_new = apply_rope(k_new, pos[:, None], rope_theta)
        bidx = jnp.arange(b)
        if paged_kv:
            # scatter into the mapped page (page checksum maintained
            # incrementally); unmapped slots drop the write.  Paged mode
            # is single-host serving — no sharding constraints.
            cache = {
                "k": KV_CACHE_PAGED.append(cache["k"], pos, k_new[:, 0]),
                "v": KV_CACHE_PAGED.append(cache["v"], pos, v_new[:, 0]),
            }
        elif quant_kv:
            # append: quantize + checksum the new rows (Alg. 2 style)
            cache = {
                "k": _constrain_quant_kv(
                    KV_CACHE.update(cache["k"], bidx, pos, k_new[:, 0]),
                    ctx.rules),
                "v": _constrain_quant_kv(
                    KV_CACHE.update(cache["v"], bidx, pos, v_new[:, 0]),
                    ctx.rules),
            }
        else:
            cache = {
                "k": cache["k"].at[bidx, :, pos].set(
                    k_new[:, 0].astype(cache["k"].dtype)),
                "v": cache["v"].at[bidx, :, pos].set(
                    v_new[:, 0].astype(cache["v"].dtype)),
            }
            cache = {
                "k": constrain(cache["k"], ("batch", None, "kv_seq", None),
                               ctx.rules),
                "v": constrain(cache["v"], ("batch", None, "kv_seq", None),
                               ctx.rules),
            }
        reports = (r1, r2, r3)
    else:
        if "q_norm" in p:
            q = headnorm(p["q_norm"], q)
        reports = (r1,)

    if paged_kv and not cross:
        # verify-on-touch read off the paged pools: one checksum compare
        # per touched page.  The rule's policy is forced to log in-jit;
        # the engine applies evict/rebuild/abort host-side on the flag.
        out, r_kv = protected_call(
            "kv_cache_paged", (cache["k"], cache["v"]), q[:, 0], pos,
            ctx=ctx, rule=paged_kv_rule(ctx), name="attn", n_heads=n_heads,
            n_kv=n_kv, window=window, prefix_global=prefix_global)
        out = out.reshape(b, n_heads * head_dim).astype(ctx.compute_dtype)
        y, r4 = apply_linear(p["wo"], out, ctx, name="attn.wo")
        return y, cache, policy.merge_reports(*reports, r_kv, r4)

    if quant_kv and not cross:
        # verified read + affine-expanded attention off the int8 cache;
        # policy (log/recompute/abort) comes from the plan rule
        out, r_kv = protected_call(
            "kv_cache", (cache["k"], cache["v"]), q[:, 0], pos, ctx=ctx,
            name="attn", n_heads=n_heads, n_kv=n_kv, window=window,
            prefix_global=prefix_global)
        out = out.reshape(b, n_heads * head_dim).astype(ctx.compute_dtype)
        y, r4 = apply_linear(p["wo"], out, ctx, name="attn.wo")
        return y, cache, policy.merge_reports(*reports, r_kv, r4)

    g = n_heads // n_kv
    qg = q.reshape(b, n_kv, g, head_dim)
    kf = cache["k"].astype(jnp.bfloat16)
    s = jnp.einsum("bkgd,bksd->bkgs", qg.astype(jnp.bfloat16), kf,
                   preferred_element_type=jnp.float32) * head_dim ** -0.5
    kv_pos = jnp.arange(s_max)[None, None, None, :]
    if cross:
        valid = jnp.broadcast_to(kv_pos >= 0, s.shape)
    else:
        valid = kv_pos <= pos[:, None, None, None]
        if window is not None:
            in_win = (pos[:, None, None, None] - kv_pos) < window
            if prefix_global > 0:
                in_win |= kv_pos < prefix_global
            valid &= in_win
    s = jnp.where(valid, s, NEG_INF)
    probs = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", probs.astype(jnp.bfloat16),
                     cache["v"].astype(jnp.bfloat16),
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, n_heads * head_dim).astype(ctx.compute_dtype)
    y, r4 = apply_linear(p["wo"], out, ctx, name="attn.wo")
    return y, cache, policy.merge_reports(*reports, r4)
