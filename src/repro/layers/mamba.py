"""Selective SSM (Mamba-style) branch, used by Hymba's hybrid heads.

    h_t = exp(Δ_t ∘ A) ∘ h_{t-1} + (Δ_t ∘ B_t) x_t
    y_t = C_t · h_t + D ∘ x_t

h ∈ R^{d_inner × N} (N = ssm_state).  Elementwise recurrence — not a GEMM —
so ABFT does not apply to the scan itself (DESIGN.md §Arch-applicability);
in/out projections are ABFT-protected linears.

The depthwise causal conv (kernel 4) is implemented with shifts; its state
(last 3 inputs) joins the decode cache with the SSM state h.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import policy
from repro.layers.common import Ctx
from repro.layers.linear import apply_linear, maybe_qlinear_init
from repro.sharding import LogicalParam, param

CONV_K = 4


def init_mamba(key, d: int, d_inner: int, n_state: int, *,
               dt_rank: int = 32, quant: bool = False, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    return {
        "in_proj": maybe_qlinear_init(ks[0], d, 2 * d_inner,
                                      ("embed", "mlp"), quant, dtype,
                                      bias=False),
        "conv_w": param(ks[1], (CONV_K, d_inner), (None, "mlp"), dtype,
                        scale=0.5),
        "x_proj": maybe_qlinear_init(ks[2], d_inner, dt_rank + 2 * n_state,
                                     ("mlp_in", None), quant, dtype,
                                     bias=False),
        "dt_proj": init_dt(ks[3], dt_rank, d_inner, dtype),
        "a_log": param(ks[4], (d_inner, n_state), ("mlp", None), dtype,
                       scale=0.5, init="ones"),
        "d_skip": param(ks[5], (d_inner,), ("mlp",), dtype, init="ones"),
        "out_proj": maybe_qlinear_init(jax.random.fold_in(key, 7), d_inner,
                                       d, ("mlp_in", "embed"), quant, dtype,
                                       bias=False),
    }


def init_dt(key, dt_rank: int, d_inner: int, dtype):
    return {
        "w": param(key, (dt_rank, d_inner), (None, "mlp"), dtype),
        "b": LogicalParam(jnp.zeros((d_inner,), dtype), ("mlp",)),
    }


def _causal_conv(x, conv_w, conv_state):
    """x [B,S,di]; conv_state [B, K-1, di] (previous inputs).

    Returns (y [B,S,di], new_conv_state)."""
    xc = jnp.concatenate([conv_state, x], axis=1)           # [B, S+K-1, di]
    y = sum(xc[:, i:i + x.shape[1], :] * conv_w[i][None, None, :]
            for i in range(CONV_K))
    return y, xc[:, -(CONV_K - 1):, :]


def mamba(p, x, cache, ctx: Ctx, *, d_inner: int, n_state: int,
          dt_rank: int = 32) -> Tuple[jax.Array, dict, policy.FaultReport]:
    """x [B,S,d]; cache {"conv": [B,K-1,di], "h": [B,di,N]} (f32).

    Returns (y [B,S,d], new_cache, report)."""
    b, s, d = x.shape
    xz, r1 = apply_linear(p["in_proj"], x, ctx, name="ssm.in_proj")
    xin, z = jnp.split(xz, 2, axis=-1)                       # [B,S,di]
    xin_f = xin.astype(jnp.float32)
    conv_w = p["conv_w"].astype(jnp.float32)
    xc, conv_state = _causal_conv(xin_f, conv_w, cache["conv"])
    xc = jax.nn.silu(xc)

    bcd, r2 = apply_linear(p["x_proj"], xc.astype(ctx.compute_dtype), ctx,
                           name="ssm.x_proj")
    bcd = bcd.astype(jnp.float32)
    dt_in = bcd[..., :dt_rank]
    b_t = bcd[..., dt_rank:dt_rank + n_state]                # [B,S,N]
    c_t = bcd[..., dt_rank + n_state:]                       # [B,S,N]
    dt = jax.nn.softplus(dt_in @ p["dt_proj"]["w"].astype(jnp.float32)
                         + p["dt_proj"]["b"].astype(jnp.float32))  # [B,S,di]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))             # [di,N]

    def step(h, inp):
        x_t, dt_t, b_tt, c_tt = inp          # [B,di],[B,di],[B,N],[B,N]
        da = jnp.exp(dt_t[..., None] * a[None])              # [B,di,N]
        h = da * h + (dt_t * x_t)[..., None] * b_tt[:, None, :]
        y_t = jnp.einsum("bdn,bn->bd", h, c_tt)
        return h, y_t

    seq = (xc.transpose(1, 0, 2), dt.transpose(1, 0, 2),
           b_t.transpose(1, 0, 2), c_t.transpose(1, 0, 2))
    chunk = ctx.ssm_chunk
    if chunk and s > 1 and s % chunk == 0:
        # Two-level scan: outer over chunks (h stashed at boundaries only),
        # inner per-token under remat (one chunk's residuals live at a
        # time).  Bounds the backward stash from O(S) states to
        # O(S/chunk) + one chunk — the hymba train_4k OOM fix
        # (EXPERIMENTS §Dry-run).  Streaming traffic still per-token; the
        # structural fix is a Pallas selective-scan kernel (DESIGN §3).
        seq_c = jax.tree.map(
            lambda t: t.reshape((s // chunk, chunk) + t.shape[1:]), seq)

        @jax.checkpoint
        def chunk_body(h, inp_chunk):
            return jax.lax.scan(step, h, inp_chunk)

        h, ys = jax.lax.scan(chunk_body, cache["h"], seq_c)
        ys = ys.reshape((s,) + ys.shape[2:])
    else:
        h, ys = jax.lax.scan(step, cache["h"], seq, unroll=ctx.unroll_time)
    y = ys.transpose(1, 0, 2) + xc * p["d_skip"].astype(jnp.float32)[None,
                                                                     None, :]
    y = y.astype(ctx.compute_dtype) * jax.nn.silu(
        z.astype(jnp.float32)).astype(ctx.compute_dtype)
    y, r3 = apply_linear(p["out_proj"], y, ctx, name="ssm.out_proj")
    return y, {"conv": conv_state, "h": h}, policy.merge_reports(r1, r2, r3)


def init_mamba_cache(batch: int, d_inner: int, n_state: int):
    return {
        "conv": LogicalParam(
            jnp.zeros((batch, CONV_K - 1, d_inner), jnp.float32),
            ("batch", None, "mlp")),
        "h": LogicalParam(
            jnp.zeros((batch, d_inner, n_state), jnp.float32),
            ("batch", "mlp", None)),
    }
