"""Token embeddings: bf16 gather (training) and quantized EB + ABFT (serving).

A token lookup is an EmbeddingBag with pooling size 1 (paper §III-C); the
serving path therefore verifies Eq. (5) per token batch.  DLRM's multi-hot
bags use the same code with pool > 1 and optional per-index weights.
Verification routes through :func:`repro.protect.protected_call`
(op kind ``embedding_bag``) so the plan controls on/off, policy, and the
Eq. (5) ``rel_bound`` per call site.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import policy
from repro.layers.common import Ctx
from repro.protect import ops as pops
from repro.protect.runtime import protected_call
from repro.sharding import LogicalParam, param


def init_embed(key, vocab: int, d: int, dtype=jnp.float32):
    return {"table": param(key, (vocab, d), ("vocab", "embed"), dtype)}


def embed(p, tokens, ctx: Ctx):
    x = p["table"][tokens].astype(ctx.compute_dtype)
    return x, policy.empty_report()


def init_qembed(key, vocab: int, d: int):
    """Quantized table (+ per-row alpha/beta) with precomputed row sums."""
    k1, k2, k3 = jax.random.split(key, 3)
    table = jax.random.randint(k1, (vocab, d), -127, 128, jnp.int8)
    alphas = jax.random.uniform(k2, (vocab,), jnp.float32, 5e-3, 2e-2)
    betas = jax.random.uniform(k3, (vocab,), jnp.float32, -0.1, 0.1)
    _, _, _, rowsums = pops.EMBEDDING_BAG.encode((table, alphas, betas))
    return {
        "table": LogicalParam(table, ("vocab", "embed")),
        "alphas": LogicalParam(alphas, ("vocab",)),
        "betas": LogicalParam(betas, ("vocab",)),
        "rowsums": LogicalParam(rowsums, ("vocab",)),
    }


def qembed(p, tokens, ctx: Ctx, name: str = "embed"):
    """tokens [...] int32 -> ([..., d] bf16, report). Pool size 1 EB-ABFT."""
    shape = tokens.shape
    bags = tokens.reshape(-1, 1)
    enc = (p["table"], p["alphas"], p["betas"], p["rowsums"])
    r, report = protected_call("embedding_bag", enc, bags, ctx=ctx,
                               name=name)
    d = p["table"].shape[-1]
    return r.astype(ctx.compute_dtype).reshape(*shape, d), report


def init_embedding_bag(key, rows: int, d: int):
    """DLRM-style multi-hot table (quantized, ABFT-ready)."""
    p = init_qembed(key, rows, d)
    p["table"] = LogicalParam(p["table"].value, ("table_rows", "embed"))
    p["alphas"] = LogicalParam(p["alphas"].value, ("table_rows",))
    p["betas"] = LogicalParam(p["betas"].value, ("table_rows",))
    p["rowsums"] = LogicalParam(p["rowsums"].value, ("table_rows",))
    return p


def embedding_bag_fwd(p, indices, ctx: Ctx, weights=None,
                      name: str = "tables"):
    """indices [bags, pool] (−1 padded) -> ([bags, d], report)."""
    enc = (p["table"], p["alphas"], p["betas"], p["rowsums"])
    r, report = protected_call("embedding_bag", enc, indices, weights,
                               ctx=ctx, name=name)
    return r.astype(ctx.compute_dtype), report


def apply_embed(p, tokens, ctx: Ctx):
    if "alphas" in p:
        return qembed(p, tokens, ctx)
    return embed(p, tokens, ctx)
