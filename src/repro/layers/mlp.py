"""Feed-forward blocks: SwiGLU (llama family) and GeLU (whisper)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import policy
from repro.layers.common import Ctx
from repro.layers.linear import apply_linear, maybe_qlinear_init


def init_mlp(key, d_model: int, d_ff: int, *, gated: bool = True,
             quant: bool = False, dtype=jnp.float32, bias: bool = False):
    ks = jax.random.split(key, 3)
    p = {
        "up": maybe_qlinear_init(ks[0], d_model, d_ff, ("embed", "mlp"),
                                 quant, dtype, bias),
        "down": maybe_qlinear_init(ks[1], d_ff, d_model, ("mlp_in", "embed"),
                                   quant, dtype, bias),
    }
    if gated:
        p["gate"] = maybe_qlinear_init(ks[2], d_model, d_ff, ("embed", "mlp"),
                                       quant, dtype, bias)
    return p


def mlp(p, x, ctx: Ctx):
    up, r1 = apply_linear(p["up"], x, ctx, name="mlp.up")
    if "gate" in p:
        gate, r2 = apply_linear(p["gate"], x, ctx, name="mlp.gate")
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(ctx.compute_dtype) * up
    else:
        r2 = policy.empty_report()
        h = jax.nn.gelu(up.astype(jnp.float32)).astype(ctx.compute_dtype)
    y, r3 = apply_linear(p["down"], h, ctx, name="mlp.down")
    return y, policy.merge_reports(r1, r2, r3)
