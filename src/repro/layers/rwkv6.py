"""RWKV6 "Finch" blocks: time-mix with data-dependent decay + channel-mix.

Per head (dh-dim), per timestep t::

    wkv_t = S_{t-1} + (u ∘ k_t) ⊗ v_t          (bonus for current token)
    y_t   = r_t · wkv_t
    S_t   = diag(w_t) · S_{t-1} + k_t ⊗ v_t     (data-dependent decay w_t)

with w_t = exp(-exp(w0 + lora(x_t))) ∈ (0, 1) per channel (the Finch
innovation — decay depends on input).  The recurrence is an outer-product
state update, not a GEMM, so the paper's ABFT does not apply to it (DESIGN.md
§Arch-applicability); the R/K/V/G/O projections and channel-mix are
ABFT-protected linears like any other.

Training runs lax.scan over time; decode carries ``(S, x_prev)`` as cache —
O(1) per token (this is why rwkv6 runs the long_500k cell).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import policy
from repro.layers.common import Ctx
from repro.layers.linear import apply_linear, maybe_qlinear_init
from repro.layers.norms import init_layernorm, layernorm
from repro.sharding import LogicalParam, param


def init_timemix(key, d: int, n_heads: int, *, lora_rank: int = 64,
                 quant: bool = False, dtype=jnp.float32):
    ks = jax.random.split(key, 9)
    dh = d // n_heads
    return {
        "mu": param(ks[0], (5, d), (None, "embed"), dtype, scale=0.5),
        "w0": param(ks[1], (d,), ("embed",), dtype, scale=0.5),
        "w_lora_a": param(ks[2], (d, lora_rank), ("embed", None), dtype),
        "w_lora_b": param(ks[3], (lora_rank, d), (None, "embed"), dtype),
        "bonus": param(ks[4], (n_heads, dh), (None, None), dtype, scale=0.5),
        "wr": maybe_qlinear_init(ks[5], d, d, ("embed", "heads_x"),
                                 quant, dtype, bias=False),
        "wk": maybe_qlinear_init(ks[6], d, d, ("embed", "heads_x"),
                                 quant, dtype, bias=False),
        "wv": maybe_qlinear_init(ks[7], d, d, ("embed", "heads_x"),
                                 quant, dtype, bias=False),
        "wg": maybe_qlinear_init(ks[8], d, d, ("embed", "heads_x"),
                                 quant, dtype, bias=False),
        "wo": maybe_qlinear_init(jax.random.fold_in(key, 99), d, d,
                                 ("heads_x", "embed"), quant, dtype,
                                 bias=False),
        "ln_x": init_layernorm(d, dtype),
    }


def _token_shift(x, x_prev):
    """[B,S,d] shifted right by one; position 0 sees x_prev (decode carry)."""
    shifted = jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)
    return shifted


#: log-decay clamp: w = exp(lw), lw ∈ [LOG_W_MIN, 0].  Decays below
#: e^-5 ≈ 6.7e-3 wipe the state within one step anyway; the clamp bounds
#: the chunked form's intra-chunk exponents (C·|lw| ≤ 80 < log f32max ≈ 88
#: for C=16) — the same clamp the official RWKV CUDA kernels apply.
LOG_W_MIN = -5.0


def wkv_recurrent(rh, kh, vh, lwh, u, state, *, unroll=False):
    """Per-token reference recurrence (paper-faithful baseline; decode).

    rh/kh/vh/lwh [B,S,H,dh] f32 (lwh = log decay), u [H,dh],
    state [B,H,dh,dh].  Returns (ys [B,S,H,dh], new_state)."""
    wh = jnp.exp(lwh)

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp                                 # [B,H,dh]
        kv = k_t[..., :, None] * v_t[..., None, :]               # [B,H,dh,dh]
        wkv = S + u[None, :, :, None] * kv
        y_t = jnp.einsum("bhk,bhkv->bhv", r_t, wkv)
        S_new = w_t[..., :, None] * S + kv
        return S_new, y_t

    xs_seq = (rh.transpose(1, 0, 2, 3), kh.transpose(1, 0, 2, 3),
              vh.transpose(1, 0, 2, 3), wh.transpose(1, 0, 2, 3))
    state, ys = jax.lax.scan(step, state, xs_seq, unroll=unroll)
    return ys.transpose(1, 0, 2, 3), state


def wkv_chunked(rh, kh, vh, lwh, u, state, *, chunk: int = 16,
                mm_dtype=None):
    """Matmul-form chunked WKV6 (beyond-paper perf path, EXPERIMENTS §Perf).

    Exact reformulation of :func:`wkv_recurrent` (same clamp):
      la_t = Σ_{τ≤t} lw_τ  (in-chunk cumulative log decay, la_0 = 0)
      y_t  = (r_t∘e^{la_{t-1}})·S_0                     [inter — one matmul]
           + Σ_{j<t} (r_t∘e^{la_{t-1}})·(k_j∘e^{-la_j}) v_j   [intra — [C,C]]
           + (r_t·(u∘k_t)) v_t                          [bonus diagonal]
      S_C  = e^{la_C}∘S_0 + Σ_j (k_j∘e^{la_C-la_j}) ⊗ v_j
    The state is read/written once per *chunk* instead of once per token
    (HBM traffic ÷ C on the dominant term) and every Σ_j is an MXU matmul.
    Exponent bounds: la ≤ 0 and -la_j ≤ C·|LOG_W_MIN| < log(f32max).
    """
    b, s, h, dh = rh.shape
    assert s % chunk == 0, (s, chunk)
    # f32 safety envelope: the intra-chunk factor e^{-la_j} reaches
    # e^{chunk·|LOG_W_MIN|}; keep it clear of f32 max (e^88.7).
    assert chunk * abs(LOG_W_MIN) <= 80.0, (
        f"chunk={chunk} exceeds the f32-safe envelope for "
        f"LOG_W_MIN={LOG_W_MIN}; use chunk <= {int(80 / abs(LOG_W_MIN))}")
    n = s // chunk

    def to_chunks(x):   # [B,S,H,K] -> [n, B, H, C, K]
        return (x.reshape(b, n, chunk, h, dh)
                .transpose(1, 0, 3, 2, 4))

    rc, kc, vc, lwc = map(to_chunks, (rh, kh, vh, lwh))
    la = jnp.cumsum(lwc, axis=-2)                       # [n,B,H,C,K]
    la_prev = la - lwc                                  # la_{t-1} (la_0 = 0)
    la_end = la[..., -1:, :]                            # [n,B,H,1,K]

    # Precomputed-stacked normalization beats in-body recomputation AND
    # in-body + remat under XLA fusion (both measured worse — EXPERIMENTS
    # §Perf hillclimb 1, iterations 2-3): one vectorized cumsum/exp pass,
    # and the scan backward re-slices the stacks instead of re-deriving.
    mm = jnp.float32 if mm_dtype is None else mm_dtype
    r_t_ = (rc * jnp.exp(la_prev)).astype(mm)           # bounded ≤ |r|
    k_in = (kc * jnp.exp(-la)).astype(mm)               # ≤ e^{C·|lw_min|}
    k_st = (kc * jnp.exp(la_end - la)).astype(mm)       # bounded ≤ |k|
    v_mm = vc.astype(mm)
    diag = jnp.sum(rc * u[None, None, :, None, :] * kc, axis=-1)  # [n,B,H,C]

    mask = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), k=-1)

    def chunk_step(S, inp):
        r_, kin, kst, v_, lae, dg = inp
        y_inter = jnp.einsum("bhck,bhkv->bhcv", r_.astype(jnp.float32), S)
        scores = jnp.einsum("bhck,bhjk->bhcj", r_, kin,
                            preferred_element_type=jnp.float32) * mask
        y_intra = jnp.einsum("bhcj,bhjv->bhcv", scores.astype(mm), v_,
                             preferred_element_type=jnp.float32)
        y = y_inter + y_intra + dg[..., None] * v_.astype(jnp.float32)
        S_new = (jnp.exp(lae[..., 0, :])[..., None] * S
                 + jnp.einsum("bhjk,bhjv->bhkv", kst, v_,
                              preferred_element_type=jnp.float32))
        return S_new, y

    state, ys = jax.lax.scan(
        chunk_step, state, (r_t_, k_in, k_st, v_mm, la_end, diag))
    # ys [n,B,H,C,V] -> [B,S,H,V]
    return (ys.transpose(1, 0, 3, 2, 4).reshape(b, s, h, dh), state)


def timemix(p, x, x_prev, state, ctx: Ctx, *, n_heads: int
            ) -> Tuple[jax.Array, jax.Array, jax.Array, policy.FaultReport]:
    """x [B,S,d], x_prev [B,d], state S [B,H,dh,dh] (f32).

    ``ctx.wkv_chunk > 0`` selects the chunked matmul form when the length
    divides; decode (S=1) and the paper-faithful baseline use the per-token
    recurrence.  Returns (y, new_x_prev, new_state, report)."""
    b, s, d = x.shape
    dh = d // n_heads
    xs = _token_shift(x, x_prev)
    mu = p["mu"].astype(jnp.float32)
    xf, xsf = x.astype(jnp.float32), xs.astype(jnp.float32)

    def mix(i):
        return (xf + (xsf - xf) * mu[i]).astype(ctx.compute_dtype)

    xr, xk, xv, xw, xg = (mix(i) for i in range(5))
    r, r1 = apply_linear(p["wr"], xr, ctx, name="tm.wr")
    k, r2 = apply_linear(p["wk"], xk, ctx, name="tm.wk")
    v, r3 = apply_linear(p["wv"], xv, ctx, name="tm.wv")
    g, r4 = apply_linear(p["wg"], xg, ctx, name="tm.wg")
    # data-dependent decay (Finch): w = exp(-exp(w0 + lora(xw))), log-clamped
    lora = jnp.tanh(xw.astype(jnp.float32) @ p["w_lora_a"].astype(jnp.float32)
                    ) @ p["w_lora_b"].astype(jnp.float32)
    lw = jnp.clip(-jnp.exp(p["w0"].astype(jnp.float32) + lora),
                  LOG_W_MIN, 0.0)                                # [B,S,d]

    rh = r.reshape(b, s, n_heads, dh).astype(jnp.float32)
    kh = k.reshape(b, s, n_heads, dh).astype(jnp.float32)
    vh = v.reshape(b, s, n_heads, dh).astype(jnp.float32)
    lwh = lw.reshape(b, s, n_heads, dh)
    u = p["bonus"].astype(jnp.float32)                           # [H,dh]

    chunk = ctx.wkv_chunk
    if chunk and s > 1 and s % chunk == 0:
        ys, state = wkv_chunked(
            rh, kh, vh, lwh, u, state, chunk=chunk,
            mm_dtype=jnp.bfloat16 if ctx.wkv_mm_bf16 else jnp.float32)
    else:
        ys, state = wkv_recurrent(rh, kh, vh, lwh, u, state,
                                  unroll=ctx.unroll_time)
    y = ys.reshape(b, s, d)
    y = layernorm(p["ln_x"], y.astype(ctx.compute_dtype))
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(ctx.compute_dtype)
    y, r5 = apply_linear(p["wo"], y, ctx, name="tm.wo")
    return (y, x[:, -1, :], state,
            policy.merge_reports(r1, r2, r3, r4, r5))


def init_channelmix(key, d: int, d_ff: int, *, quant: bool = False,
                    dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return {
        "mu": param(ks[0], (2, d), (None, "embed"), dtype, scale=0.5),
        "wk": maybe_qlinear_init(ks[1], d, d_ff, ("embed", "mlp"),
                                 quant, dtype, bias=False),
        "wv": maybe_qlinear_init(ks[2], d_ff, d, ("mlp_in", "embed"),
                                 quant, dtype, bias=False),
    }


def channelmix(p, x, x_prev, ctx: Ctx):
    """Squared-ReLU channel mix. Returns (y, new_x_prev, report)."""
    xs = _token_shift(x, x_prev)
    mu = p["mu"].astype(jnp.float32)
    xf, xsf = x.astype(jnp.float32), xs.astype(jnp.float32)
    xk = (xf + (xsf - xf) * mu[0]).astype(ctx.compute_dtype)
    k, r1 = apply_linear(p["wk"], xk, ctx, name="cm.wk")
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(
        ctx.compute_dtype)
    y, r2 = apply_linear(p["wv"], k, ctx, name="cm.wv")
    return y, x[:, -1, :], policy.merge_reports(r1, r2)
