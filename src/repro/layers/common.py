"""Shared layer context."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Ctx:
    """Per-call layer context (static under jit).

    quant=True selects the paper's int8 pipeline (Fig. 1) with ABFT; False is
    the bf16 training path.  Protection is governed by ``plan`` (a
    :class:`repro.protect.ProtectionPlan` — per-op-pattern scheme / policy /
    threshold rules); when ``plan`` is None the legacy booleans apply:
    ``abft`` gates int8 GEMM + EB verification (off = the paper's
    "unprotected" baseline for overhead measurements), ``float_abft`` gates
    float-GEMM ABFT, and the KV cache stays unprotected.
    """
    rules: Optional[dict] = None          # sharding rules for constrain()
    quant: bool = False                   # int8 serving path
    abft: bool = True                     # ABFT verification on (legacy)
    float_abft: bool = False              # float ABFT on bf16 GEMMs (legacy)
    plan: Optional[Any] = None            # ProtectionPlan (overrides flags)
    compute_dtype: Any = jnp.bfloat16
    abft_tp_local: bool = False           # per-shard checksums (hillclimb)
    wkv_chunk: int = 0                    # >0: chunked matmul-form WKV6
                                          # (EXPERIMENTS.md §Perf hillclimb 1)
    wkv_mm_bf16: bool = False             # bf16 WKV matmul operands (f32 acc)
    ssm_chunk: int = 0                    # >0: two-level rematted mamba scan
    moe_gather: bool = False              # scatter/gather MoE dispatch
                                          # (EXPERIMENTS.md §Perf hillclimb 2)
    no_remat: bool = False                # disable layer-scan remat
    moe_seq_groups: bool = False          # scan over MoE token groups
                                          # (bounds live dispatch buffers)
    # Cost-probe controls (EXPERIMENTS.md §Dry-run methodology): XLA counts
    # while bodies once, so probes unroll scans and the launcher
    # extrapolates exactly in trip counts.
    unroll_layers: bool = False           # unroll the layer-stack scans
    unroll_time: bool = False             # unroll seq scans (rwkv/mamba)

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)
