"""Linear layers: bf16 (training) and int8+ABFT (the paper's serving path).

The quantized linear runs Fig. 1 end to end:
  dynamic per-row activation quant (signed int8)  ->  int8 GEMM against the
  packed, checksum-encoded weight  ->  Eq. (3b) verify on the int32 C_temp
  (BEFORE requantization, §IV-B)  ->  rank-1 dequant + bias -> bf16.

Weights are packed once at init/conversion (amortized encoding, §IV-A1).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import abft_gemm as ag
from repro.core import policy
from repro.core.abft_float import abft_gemm_f32, encode_weight_f32
from repro.kernels import ref as kref
from repro.layers.common import Ctx
from repro.sharding import LogicalParam, constrain, param


# ----------------------------- bf16 linear ---------------------------------

def init_linear(key, d_in: int, d_out: int,
                axes: Tuple[str, str] = ("embed", "mlp"),
                dtype=jnp.float32, bias: bool = True,
                scale: Optional[float] = None):
    scale = (d_in ** -0.5) if scale is None else scale
    p = {"w": param(key, (d_in, d_out), axes, dtype, scale=scale)}
    if bias:
        p["b"] = LogicalParam(jnp.zeros((d_out,), dtype), (axes[1],))
    return p


def linear(p, x, ctx: Ctx):
    """bf16 linear, optional float-ABFT (beyond paper) on the 2D GEMM."""
    w = p["w"].astype(ctx.compute_dtype)
    if ctx.float_abft:
        m_shape = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        out = abft_gemm_f32(x2, w)
        y = out.c.astype(ctx.compute_dtype).reshape(*m_shape, w.shape[-1])
        report = policy.gemm_report(out.err_count)
    else:
        y = jnp.dot(x.astype(ctx.compute_dtype), w,
                    preferred_element_type=ctx.compute_dtype)
        report = policy.empty_report()
    if "b" in p:
        y = y + p["b"].astype(ctx.compute_dtype)
    return y, report


# --------------------------- int8 ABFT linear ------------------------------

def init_qlinear(key, d_in: int, d_out: int,
                 axes: Tuple[str, str] = ("embed", "mlp"),
                 bias: bool = True):
    """Random-int8 quantized weight, packed with a consistent checksum.

    Real deployments convert from trained bf16 weights via
    :func:`quantize_linear`; random init keeps dry-run/eval_shape pure.
    """
    k1, k2 = jax.random.split(key)
    w_q = jax.random.randint(k1, (d_in, d_out), -127, 128, jnp.int8)
    packed = ag.pack_encoded_b(w_q)                     # [d_in, d_out+128]
    alpha = jax.random.uniform(k2, (d_out,), jnp.float32, 1e-3, 2e-3)
    colsum = jnp.sum(w_q.astype(jnp.int32), axis=0).astype(jnp.float32)
    p = {
        "w_packed": LogicalParam(packed, (axes[0], axes[1])),
        "alpha": LogicalParam(alpha, (axes[1],)),
        "colsum": LogicalParam(colsum, (axes[1],)),
    }
    if bias:
        p["b"] = LogicalParam(jnp.zeros((d_out,), jnp.float32), (axes[1],))
    return p


def quantize_linear(p_f32, axes: Tuple[str, str] = ("embed", "mlp")):
    """Convert a trained bf16/f32 linear into the packed ABFT form."""
    from repro.quant import quantize_channels
    w = p_f32["w"].value if isinstance(p_f32["w"], LogicalParam) else p_f32["w"]
    q = quantize_channels(jnp.asarray(w, jnp.float32))
    packed = ag.pack_encoded_b(q.values)
    colsum = jnp.sum(q.values.astype(jnp.int32), axis=0).astype(jnp.float32)
    out = {
        "w_packed": LogicalParam(packed, (axes[0], axes[1])),
        "alpha": LogicalParam(q.alpha, (axes[1],)),
        "colsum": LogicalParam(colsum, (axes[1],)),
    }
    if "b" in p_f32:
        b = p_f32["b"].value if isinstance(p_f32["b"], LogicalParam) else p_f32["b"]
        out["b"] = LogicalParam(jnp.asarray(b, jnp.float32), (axes[1],))
    return out


def qlinear(p, x, ctx: Ctx):
    """int8 ABFT linear: x [..., d_in] -> (y [..., d_out] bf16, report)."""
    packed = p["w_packed"]
    d_in = packed.shape[0]
    d_out = packed.shape[1] - ag.LANE
    m_shape = x.shape[:-1]
    x2 = x.reshape(-1, d_in)

    # dynamic per-row signed-int8 quantization (kernels/quantize_rows target)
    x_q, a_alpha, a_beta = kref.quantize_rows_ref(x2)

    if ctx.abft:
        c, err_rows = kref.abft_qgemm_ref(x_q, packed)   # fused checksum GEMM
        err_count = jnp.sum(err_rows).astype(jnp.int32)
        report = policy.gemm_report(err_count)
    else:
        c = jax.lax.dot_general(
            x_q, packed[:, :d_out], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        report = policy.empty_report()

    # Requantization rank-1 algebra (Eq. 1 with symmetric B: beta_B = 0):
    #   y = alpha_A[i] * alpha_B[j] * C[i,j] + beta_A[i] * alpha_B[j] * colsum_B[j]
    w_alpha = p["alpha"]
    y = (a_alpha[:, None] * (c.astype(jnp.float32) * w_alpha[None, :])
         + a_beta[:, None] * (w_alpha * p["colsum"])[None, :])
    if "b" in p:
        y = y + p["b"][None, :]
    y = y.astype(ctx.compute_dtype).reshape(*m_shape, d_out)
    return y, report


def maybe_qlinear_init(key, d_in, d_out, axes, quant: bool,
                       dtype=jnp.float32, bias: bool = True):
    if quant:
        return init_qlinear(key, d_in, d_out, axes, bias=bias)
    return init_linear(key, d_in, d_out, axes, dtype=dtype, bias=bias)


def apply_linear(p, x, ctx: Ctx):
    """Dispatch on parameter form (packed int8 vs float)."""
    if "w_packed" in p:
        return qlinear(p, x, ctx)
    return linear(p, x, ctx)
