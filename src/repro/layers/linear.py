"""Linear layers: bf16 (training) and int8+ABFT (the paper's serving path).

The quantized linear runs Fig. 1 end to end:
  dynamic per-row activation quant (signed int8)  ->  int8 GEMM against the
  packed, checksum-encoded weight  ->  Eq. (3b) verify on the int32 C_temp
  (BEFORE requantization, §IV-B)  ->  rank-1 dequant + bias -> bf16.

Weights are packed once at init/conversion (amortized encoding, §IV-A1).
All verification goes through :func:`repro.protect.protected_call` — the
plan in ``ctx`` decides scheme (packed / unfused / Pallas), policy
(log / recompute / correct / abort), and on/off per call site ``name``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import policy
from repro.kernels import ops as kops
from repro.layers.common import Ctx
from repro.protect import ops as pops
from repro.protect.runtime import protected_call, rule_for
from repro.sharding import LogicalParam, param


# ----------------------------- bf16 linear ---------------------------------

def init_linear(key, d_in: int, d_out: int,
                axes: Tuple[str, str] = ("embed", "mlp"),
                dtype=jnp.float32, bias: bool = True,
                scale: Optional[float] = None):
    scale = (d_in ** -0.5) if scale is None else scale
    p = {"w": param(key, (d_in, d_out), axes, dtype, scale=scale)}
    if bias:
        p["b"] = LogicalParam(jnp.zeros((d_out,), dtype), (axes[1],))
    return p


def linear(p, x, ctx: Ctx, name: str = ""):
    """bf16 linear, optional float-ABFT (beyond paper) on the 2D GEMM."""
    w = p["w"].astype(ctx.compute_dtype)
    if rule_for(ctx, "float_gemm", name).enabled:
        m_shape = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        c, report = protected_call("float_gemm", (w, None), x2, ctx=ctx,
                                   name=name)
        y = c.astype(ctx.compute_dtype).reshape(*m_shape, w.shape[-1])
    else:
        y = jnp.dot(x.astype(ctx.compute_dtype), w,
                    preferred_element_type=ctx.compute_dtype)
        report = policy.empty_report()
    if "b" in p:
        y = y + p["b"].astype(ctx.compute_dtype)
    return y, report


# --------------------------- int8 ABFT linear ------------------------------

def init_qlinear(key, d_in: int, d_out: int,
                 axes: Tuple[str, str] = ("embed", "mlp"),
                 bias: bool = True):
    """Random-int8 quantized weight, packed with a consistent checksum.

    Real deployments convert from trained bf16 weights via
    :func:`quantize_linear`; random init keeps dry-run/eval_shape pure.
    """
    k1, k2 = jax.random.split(key)
    w_q = jax.random.randint(k1, (d_in, d_out), -127, 128, jnp.int8)
    packed = pops.QGEMM.encode(w_q)                     # [d_in, d_out+128]
    alpha = jax.random.uniform(k2, (d_out,), jnp.float32, 1e-3, 2e-3)
    colsum = pops.QGEMM.dequant_colsum(w_q)
    p = {
        "w_packed": LogicalParam(packed, (axes[0], axes[1])),
        "alpha": LogicalParam(alpha, (axes[1],)),
        "colsum": LogicalParam(colsum, (axes[1],)),
    }
    if bias:
        p["b"] = LogicalParam(jnp.zeros((d_out,), jnp.float32), (axes[1],))
    return p


def quantize_linear(p_f32, axes: Tuple[str, str] = ("embed", "mlp")):
    """Convert a trained bf16/f32 linear into the packed ABFT form."""
    from repro.quant import quantize_channels
    w = p_f32["w"].value if isinstance(p_f32["w"], LogicalParam) else p_f32["w"]
    q = quantize_channels(jnp.asarray(w, jnp.float32))
    packed = pops.QGEMM.encode(q.values)
    colsum = pops.QGEMM.dequant_colsum(q.values)
    out = {
        "w_packed": LogicalParam(packed, (axes[0], axes[1])),
        "alpha": LogicalParam(q.alpha, (axes[1],)),
        "colsum": LogicalParam(colsum, (axes[1],)),
    }
    if "b" in p_f32:
        b = p_f32["b"].value if isinstance(p_f32["b"], LogicalParam) else p_f32["b"]
        out["b"] = LogicalParam(jnp.asarray(b, jnp.float32), (axes[1],))
    return out


def qlinear(p, x, ctx: Ctx, name: str = ""):
    """int8 ABFT linear: x [..., d_in] -> (y [..., d_out] bf16, report)."""
    packed = p["w_packed"]
    d_in = packed.shape[0]
    d_out = packed.shape[1] - pops.QGEMM.lane
    m_shape = x.shape[:-1]
    x2 = x.reshape(-1, d_in)

    # dynamic per-row signed-int8 quantization (kernels/quantize_rows)
    x_q, a_alpha, a_beta = kops.quantize_rows(x2)

    # the plan decides scheme + policy + on/off for this call site; a
    # correct-policy site also hands over the exact int32 column sums so
    # single weight flips are repairable, not just detectable (the f32
    # colsum is exact for any d_in the int8 path supports: |sum| < 2^24)
    rule = rule_for(ctx, "qgemm", name)
    encoded = packed
    if rule.enabled and rule.policy == "correct" and "colsum" in p:
        encoded = (packed, jnp.round(p["colsum"]).astype(jnp.int32))
    c, report = protected_call("qgemm", encoded, x_q, ctx=ctx, rule=rule,
                               name=name)

    # Requantization rank-1 algebra (Eq. 1 with symmetric B: beta_B = 0):
    #   y = alpha_A[i] * alpha_B[j] * C[i,j] + beta_A[i] * alpha_B[j] * colsum_B[j]
    w_alpha = p["alpha"]
    y = (a_alpha[:, None] * (c.astype(jnp.float32) * w_alpha[None, :])
         + a_beta[:, None] * (w_alpha * p["colsum"])[None, :])
    if "b" in p:
        y = y + p["b"][None, :]
    y = y.astype(ctx.compute_dtype).reshape(*m_shape, d_out)
    return y, report


def maybe_qlinear_init(key, d_in, d_out, axes, quant: bool,
                       dtype=jnp.float32, bias: bool = True):
    if quant:
        return init_qlinear(key, d_in, d_out, axes, bias=bias)
    return init_linear(key, d_in, d_out, axes, dtype=dtype, bias=bias)


def apply_linear(p, x, ctx: Ctx, name: str = ""):
    """Dispatch on parameter form (packed int8 vs float)."""
    if "w_packed" in p:
        return qlinear(p, x, ctx, name)
    return linear(p, x, ctx, name)
