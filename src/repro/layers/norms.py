"""RMSNorm / LayerNorm (f32 statistics, cast back to compute dtype)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding import LogicalParam, param


def init_rmsnorm(d: int, dtype=jnp.float32):
    return {"scale": LogicalParam(jnp.ones((d,), dtype), ("embed",))}


def rmsnorm(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d: int, dtype=jnp.float32):
    return {
        "scale": LogicalParam(jnp.ones((d,), dtype), ("embed",)),
        "bias": LogicalParam(jnp.zeros((d,), dtype), ("embed",)),
    }


def layernorm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def init_headnorm(head_dim: int, dtype=jnp.float32):
    """qk-norm (qwen3): RMS over head_dim with learned scale."""
    return {"scale": LogicalParam(jnp.ones((head_dim,), dtype), (None,))}


def headnorm(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)
