"""Prefix tree over token chunks -> resident page ids (host-side).

Each node owns one page-sized token chunk; a path from the root spells a
prompt prefix, so two requests share pages exactly when their token
streams agree chunk-for-chunk from position 0.  Sharing is
copy-on-write at page granularity: shared pages are immutable (decode
appends always land in private tail pages the engine allocates outside
the tree), and the tree itself holds one allocator reference per node so
a popular system prompt stays quantized+checksummed in the pool across
request lifetimes.

Eviction is by detaching nodes: ``evict_page`` removes a corrupted
page's node *and its subtree* (descendants are only reachable through
the corrupt prefix), dropping the tree's references; ``evict_lru`` frees
cold leaves when the allocator runs dry.  Active requests keep their own
allocator references, so a detached page is recycled only once its last
reader retires.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple


class _Node:
    __slots__ = ("key", "page_id", "children", "parent", "last_use")

    def __init__(self, key: bytes, page_id: int, parent: "Optional[_Node]"):
        self.key = key
        self.page_id = page_id
        self.children: Dict[bytes, _Node] = {}
        self.parent = parent
        self.last_use = 0


class PrefixTree:
    def __init__(self):
        self._root = _Node(b"", -1, None)
        self._by_page: Dict[int, _Node] = {}
        self._clock = 0

    def __len__(self) -> int:
        return len(self._by_page)

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def match(self, chunk_keys: Sequence[bytes]) -> List[_Node]:
        """Longest chain of consecutive chunk matches from the root."""
        t = self._tick()
        out: List[_Node] = []
        node = self._root
        for key in chunk_keys:
            child = node.children.get(key)
            if child is None:
                break
            child.last_use = t
            out.append(child)
            node = child
        return out

    def insert(self, parent: Optional[_Node], key: bytes,
               page_id: int) -> _Node:
        """Register ``page_id`` as the chunk ``key`` under ``parent``
        (None = root).  The caller transfers one allocator reference to
        the tree."""
        parent = parent or self._root
        node = _Node(key, page_id, parent)
        node.last_use = self._tick()
        parent.children[key] = node
        self._by_page[page_id] = node
        return node

    def _detach(self, node: _Node) -> List[int]:
        """Remove ``node`` and its subtree; returns the page ids whose
        tree references the caller must release."""
        if node.parent is not None:
            node.parent.children.pop(node.key, None)
        node.parent = None
        freed: List[int] = []
        stack = [node]
        while stack:
            n = stack.pop()
            self._by_page.pop(n.page_id, None)
            freed.append(n.page_id)
            stack.extend(n.children.values())
            n.children.clear()
        return freed

    def evict_page(self, page_id: int) -> List[int]:
        """Evict a (corrupted) page and everything reachable only
        through it.  No-op (empty list) if the page isn't tree-owned."""
        node = self._by_page.get(page_id)
        return self._detach(node) if node is not None else []

    def evict_lru(self) -> Optional[int]:
        """Detach the least-recently-used leaf; returns its page id (the
        caller releases the tree's reference) or None if the tree is
        empty."""
        leaf: Optional[_Node] = None
        for node in self._by_page.values():
            if node.children:
                continue
            if leaf is None or node.last_use < leaf.last_use:
                leaf = node
        if leaf is None:
            return None
        self._detach(leaf)
        return leaf.page_id

    def reset(self) -> None:
        self._root = _Node(b"", -1, None)
        self._by_page.clear()
        self._clock = 0


def chunk_keys(tokens, page_size: int) -> Tuple[bytes, ...]:
    """Split a (padded) prompt into page-sized chunk keys.  Only whole
    chunks are shareable; callers pad prompts to a page multiple first."""
    import numpy as np

    t = np.asarray(tokens, np.int32)
    n = (t.shape[0] // page_size) * page_size
    return tuple(t[i:i + page_size].tobytes()
                 for i in range(0, n, page_size))
