"""repro.paging — paged, prefix-shared, per-page-checksummed KV cache.

The contiguous QuantKV cache (core.abft_kvcache) is sequence-contiguous
per fixed batcher slot: memory scales with the worst-case prompt bucket
and a verified decode read re-covers the whole prefix every step.  This
subsystem rebuilds it as a page-table cache:

  * fixed-size token **pages** of int8 QuantKV rows with per-row affine
    params and a **per-page** int32 checksum folded from the rowsums
    (one compare verifies ``page_size`` rows);
  * a host-side free-list :class:`PageAllocator` with refcounts, so
    memory scales with tokens actually resident;
  * a :class:`PrefixTree` keyed on token chunks, so requests sharing a
    system prompt share quantized+checksummed pages (copy-on-write at
    page granularity: shared pages are immutable, writers get private
    pages);
  * **verify-on-touch**: a decode read checks only the pages its
    attention mask actually covers, and a mismatched page is evicted
    and rebuilt / the owning request aborted per the ``kv_cache_paged``
    ProtectionPlan policy — never the whole lane.
"""
from repro.paging.alloc import PageAllocator
from repro.paging.cache import (PagedKV, attend_paged, pack_prompt_pages,
                                page_errors, paged_append, paged_pool,
                                pool_page_bytes, reset_pages, scrub_cache)
from repro.paging.manager import AdmitPlan, PagedKVManager, PagingConfig
from repro.paging.prefixtree import PrefixTree

__all__ = [
    "PagedKV", "PageAllocator", "PrefixTree", "PagedKVManager",
    "PagingConfig", "AdmitPlan", "attend_paged", "paged_append",
    "paged_pool", "pack_prompt_pages", "page_errors", "reset_pages",
    "scrub_cache", "pool_page_bytes",
]
