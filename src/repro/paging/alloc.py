"""Free-list page allocator with refcounts (host-side).

Pages are plain integers into the device pools; the allocator never
touches device memory.  Refcounts let the prefix tree and any number of
resident requests share a page: the page returns to the free list only
when the last holder releases it.  ``high_water`` is the peak
simultaneously-allocated page count — multiplied by the per-page byte
cost it is the "peak resident KV bytes" the campaign compares against
the fixed-slot contiguous layout.
"""
from __future__ import annotations

from typing import Dict, List, Optional


class PageAllocator:
    def __init__(self, n_pages: int):
        if n_pages <= 0:
            raise ValueError("n_pages must be positive")
        self.n_pages = n_pages
        self._free: List[int] = list(range(n_pages - 1, -1, -1))
        self._ref: Dict[int, int] = {}
        self.high_water = 0

    def alloc(self) -> Optional[int]:
        """One page at refcount 1, or None when the pool is exhausted."""
        if not self._free:
            return None
        pid = self._free.pop()
        self._ref[pid] = 1
        self.high_water = max(self.high_water, len(self._ref))
        return pid

    def retain(self, pid: int) -> None:
        self._ref[pid] += 1

    def release(self, pid: int) -> bool:
        """Drop one reference; True iff the page went back to the free
        list."""
        n = self._ref[pid] - 1
        if n:
            self._ref[pid] = n
            return False
        del self._ref[pid]
        self._free.append(pid)
        return True

    def refcount(self, pid: int) -> int:
        return self._ref.get(pid, 0)

    @property
    def used(self) -> int:
        return len(self._ref)

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def shared_count(self) -> int:
        """Pages held by more than one owner (tree + >=1 request, or
        several requests)."""
        return sum(1 for n in self._ref.values() if n > 1)

    def reset(self) -> None:
        self._free = list(range(self.n_pages - 1, -1, -1))
        self._ref.clear()
        self.high_water = 0
