"""Host-side paging manager: allocator + prefix tree + slot page tables.

The serving engine owns one :class:`PagedKVManager` per paged lane.  All
decisions that need host control flow live here — page allocation,
prefix-tree lookup at admission, LRU eviction under memory pressure,
corrupted-page eviction, retire-time release — while the device only
ever sees the resulting int32 table and fixed-shape scatter ids.

Reference protocol: a tree-owned page carries one reference from the
tree plus one per resident request mapping it; a decode-tail page (never
shared) carries only its owner's reference.  ``admit`` is transactional:
if the pool is exhausted mid-admission (even after LRU eviction), every
reference the call took is rolled back and ``AdmitPlan.ok`` is False.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.paging.alloc import PageAllocator
from repro.paging.prefixtree import PrefixTree, chunk_keys


@dataclasses.dataclass(frozen=True)
class PagingConfig:
    """Paged-KV knobs for the serving engine.

    ``page_size`` trades checksum granularity (bigger pages = fewer
    compares but a bigger blast radius and coarser sharing) against
    table overhead; ``n_pages`` sizes the pool shared by every slot in
    the lane."""
    page_size: int = 16
    n_pages: int = 256

    def to_dict(self):
        return dataclasses.asdict(self)


@dataclasses.dataclass
class AdmitPlan:
    """Result of an admission-time prefix lookup + allocation."""
    ok: bool
    bucket: int = 0
    page_ids: Optional[np.ndarray] = None   # [bucket // P]; sentinel = skip
    shared_pages: int = 0
    new_pages: int = 0

    def tokens(self, page_size: int):
        """(prefill_tokens actually quantized, tokens served from shared
        pages) — what telemetry attributes to this admission."""
        return self.new_pages * page_size, self.shared_pages * page_size


class PagedKVManager:
    def __init__(self, cfg: PagingConfig, n_slots: int,
                 max_pages_per_slot: int):
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_pages = max_pages_per_slot
        self.alloc = PageAllocator(cfg.n_pages)
        self.tree = PrefixTree()
        self.table = np.full((n_slots, max_pages_per_slot), -1, np.int32)
        self._prompt_pages: List[List[int]] = [[] for _ in range(n_slots)]
        self._tail_pages: List[List[int]] = [[] for _ in range(n_slots)]
        self.prompt_chunks = [0] * n_slots
        self.prefix_hits = 0
        self.prefix_misses = 0
        self.evictions = 0
        self.rebuilds = 0

    # -- admission -------------------------------------------------------

    def _alloc_or_evict(self) -> Optional[int]:
        pid = self.alloc.alloc()
        while pid is None:
            victim = self.tree.evict_lru()
            if victim is None:
                return None
            self.alloc.release(victim)
            self.evictions += 1
            pid = self.alloc.alloc()
        return pid

    def admit(self, slot: int, tokens: np.ndarray) -> AdmitPlan:
        """Map a padded prompt (len multiple of page_size) onto pages.

        Shared prefix chunks are served from the tree (no write needed);
        the rest get fresh pages and are registered for future sharers.
        """
        p = self.cfg.page_size
        keys = chunk_keys(tokens, p)
        if len(keys) > self.max_pages:
            return AdmitPlan(ok=False)
        nodes = self.tree.match(keys)
        shared = [n.page_id for n in nodes]
        for pid in shared:
            self.alloc.retain(pid)
        new_ids: List[int] = []
        parent = nodes[-1] if nodes else None
        for key in keys[len(nodes):]:
            pid = self._alloc_or_evict()
            if pid is None:
                for s in shared:
                    self.alloc.release(s)
                for n in new_ids:
                    # evict_page returns the tree refs still held (it may
                    # come back empty: under extreme pressure the LRU
                    # loop above can have detached a page we inserted
                    # earlier in this very call)
                    for freed in self.tree.evict_page(n):
                        self.alloc.release(freed)       # tree refs
                    self.alloc.release(n)               # request ref
                return AdmitPlan(ok=False)
            parent = self.tree.insert(parent, key, pid)  # tree takes alloc ref
            self.alloc.retain(pid)                       # request ref
            new_ids.append(pid)
        ordered = shared + new_ids
        self.table[slot, :] = -1
        self.table[slot, :len(ordered)] = ordered
        self._prompt_pages[slot] = ordered
        self._tail_pages[slot] = []
        self.prompt_chunks[slot] = len(ordered)
        self.prefix_hits += len(shared)
        self.prefix_misses += len(new_ids)
        sentinel = self.cfg.n_pages
        page_ids = np.full(len(keys), sentinel, np.int32)
        page_ids[len(shared):] = new_ids
        return AdmitPlan(ok=True, bucket=len(keys) * p, page_ids=page_ids,
                         shared_pages=len(shared), new_pages=len(new_ids))

    # -- decode ----------------------------------------------------------

    def decode_page(self, slot: int, chunk: int) -> Optional[int]:
        """Private tail page for the next decode block; None = pool full
        (the engine aborts the request)."""
        pid = self._alloc_or_evict()
        if pid is None:
            return None
        self.table[slot, chunk] = pid
        self._tail_pages[slot].append(pid)
        return pid

    # -- lifecycle -------------------------------------------------------

    def retire(self, slot: int) -> None:
        for pid in self._prompt_pages[slot]:
            self.alloc.release(pid)      # tree keeps its ref: page stays warm
        for pid in self._tail_pages[slot]:
            self.alloc.release(pid)
        self.table[slot, :] = -1
        self._prompt_pages[slot] = []
        self._tail_pages[slot] = []
        self.prompt_chunks[slot] = 0

    def release_prompt(self, slot: int) -> None:
        """Drop the slot's prompt mappings (rebuild path) but keep its
        decode-tail pages — generated KV survives the rebuild."""
        for pid in self._prompt_pages[slot]:
            self.alloc.release(pid)
        self.table[slot, :self.prompt_chunks[slot]] = -1
        self._prompt_pages[slot] = []

    def readmit(self, slot: int, tokens: np.ndarray) -> AdmitPlan:
        """Re-map a slot's prompt after eviction, preserving tail pages.

        ``admit`` wipes the whole table row; restore the tail mappings
        after it runs."""
        tail = list(self._tail_pages[slot])
        n_prompt = len(chunk_keys(tokens, self.cfg.page_size))
        plan = self.admit(slot, tokens)
        if plan.ok:
            self.rebuilds += 1
            for i, pid in enumerate(tail):
                self.table[slot, n_prompt + i] = pid
            self._tail_pages[slot] = tail
        return plan

    def evict_corrupt(self, slot: int, chunk: int) -> bool:
        """Evict the page under (slot, chunk) from the prefix tree (plus
        any descendants).  True if it was a prompt page (rebuildable);
        False means a private tail page — the owner must abort."""
        pid = int(self.table[slot, chunk])
        if pid < 0:
            return True
        if chunk >= self.prompt_chunks[slot]:
            return False
        for freed in self.tree.evict_page(pid):
            self.alloc.release(freed)
            self.evictions += 1
        return True

    def reset(self) -> None:
        self.alloc.reset()
        self.tree.reset()
        self.table[:] = -1
        self._prompt_pages = [[] for _ in range(self.n_slots)]
        self._tail_pages = [[] for _ in range(self.n_slots)]
        self.prompt_chunks = [0] * self.n_slots
        self.prefix_hits = 0
        self.prefix_misses = 0
        self.evictions = 0
        self.rebuilds = 0

    # -- stats -----------------------------------------------------------

    @property
    def prefix_hit_rate(self) -> float:
        total = self.prefix_hits + self.prefix_misses
        return self.prefix_hits / total if total else 0.0

    def stats(self) -> dict:
        return {
            "pages_resident": self.alloc.used,
            "pages_free": self.alloc.free_count,
            "pages_shared": self.alloc.shared_count,
            "pages_high_water": self.alloc.high_water,
            "prefix_hits": self.prefix_hits,
            "prefix_misses": self.prefix_misses,
            "prefix_hit_rate": self.prefix_hit_rate,
            "page_evictions": self.evictions,
            "page_rebuilds": self.rebuilds,
        }
