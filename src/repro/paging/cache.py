"""Device-side paged KV cache: page pools, per-page checksums, attention.

Layout (per layer; the model's layer scan stacks a leading ``L`` on every
leaf, including the page table):

    q        int8  [n_pages, Kv, P, dh]   quantized rows (core.abft_kvcache)
    alpha    f32   [n_pages, Kv, P]       per-row affine scale
    beta     f32   [n_pages, Kv, P]       per-row affine offset
    pagesum  int32 [n_pages, Kv]          ABFT page checksum = Σ_rows rowsum
    table    int32 [B, max_pages]         page ids per slot, -1 = unmapped

One page id names the same pool row in every layer's K and V pools — a
page is a block of ``P`` token positions across the whole model, so the
host allocator hands out a single id per token block.  The page checksum
folds the paper's Alg.-2 rowsums one level further: a single int32
compare verifies ``P`` rows (× ``dh`` int8 elements × ``L`` layers when
merged across the scan), which is what makes verify-on-touch cheap
enough to run on every decode read.

Scatters use out-of-range sentinels (``page id == n_pages``) for "skip
this write": JAX drops out-of-bounds scatter updates, so one compiled
program serves any subset of shared/unshared pages.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.abft_kvcache import QuantKV, quantize_kv_rows

NEG_INF = -1e30


class PagedKV(NamedTuple):
    q: jax.Array        # int8  [n_pages, Kv, P, dh]
    alpha: jax.Array    # f32   [n_pages, Kv, P]
    beta: jax.Array     # f32   [n_pages, Kv, P]
    pagesum: jax.Array  # int32 [n_pages, Kv]
    table: jax.Array    # int32 [B, max_pages], -1 = unmapped


def paged_pool(n_pages: int, n_kv: int, page_size: int, head_dim: int,
               n_slots: int, max_pages: int,
               n_layers: int = 0) -> PagedKV:
    """A zeroed pool with an all-unmapped table.  ``n_layers > 0`` stacks
    a leading layer axis on every leaf (the shape the layer scan wants)."""
    lead = (n_layers,) if n_layers else ()
    return PagedKV(
        q=jnp.zeros(lead + (n_pages, n_kv, page_size, head_dim), jnp.int8),
        alpha=jnp.zeros(lead + (n_pages, n_kv, page_size), jnp.float32),
        beta=jnp.zeros(lead + (n_pages, n_kv, page_size), jnp.float32),
        pagesum=jnp.zeros(lead + (n_pages, n_kv), jnp.int32),
        table=jnp.full(lead + (n_slots, max_pages), -1, jnp.int32),
    )


def pack_prompt_pages(pool: PagedKV, src, page_ids: jax.Array) -> PagedKV:
    """Write a prefilled prompt's rows into pool pages (stacked layout).

    ``pool`` leaves carry a leading L; ``src`` is the batch-1 prefill
    cache entry — a QuantKV (or float array to quantize here) with leaves
    [L, 1, Kv, S, dh] where S is a multiple of the page size.
    ``page_ids`` [S // P] maps prompt chunk -> pool page; entries >=
    n_pages are dropped (chunk already resident via the prefix tree).
    The table is left untouched — mapping is the host allocator's job.
    """
    n_pages, page = pool.q.shape[1], pool.q.shape[3]
    if not isinstance(src, QuantKV):
        src = quantize_kv_rows(jnp.asarray(src, jnp.float32))
    ell, _, kv, s, dh = src.q.shape
    nc = s // page
    q = src.q.reshape(ell, kv, nc, page, dh).transpose(0, 2, 1, 3, 4)
    alpha = src.alpha.reshape(ell, kv, nc, page).transpose(0, 2, 1, 3)
    beta = src.beta.reshape(ell, kv, nc, page).transpose(0, 2, 1, 3)
    pagesum = jnp.sum(src.rowsum.reshape(ell, kv, nc, page),
                      axis=-1).transpose(0, 2, 1).astype(jnp.int32)
    return pool._replace(
        q=pool.q.at[:, page_ids].set(q),
        alpha=pool.alpha.at[:, page_ids].set(alpha),
        beta=pool.beta.at[:, page_ids].set(beta),
        pagesum=pool.pagesum.at[:, page_ids].set(pagesum),
    )


def reset_pages(pool: PagedKV, page_ids: jax.Array) -> PagedKV:
    """Zero freshly-allocated pages (stacked layout) so decode appends
    accumulate pagesums from a clean slate.  Sentinel ids are dropped —
    the engine always passes a fixed-length [n_slots] vector."""
    return pool._replace(
        q=pool.q.at[:, page_ids].set(0),
        alpha=pool.alpha.at[:, page_ids].set(0.0),
        beta=pool.beta.at[:, page_ids].set(0.0),
        pagesum=pool.pagesum.at[:, page_ids].set(0),
    )


def paged_append(pk: PagedKV, pos: jax.Array, new_rows: jax.Array) -> PagedKV:
    """Decode-step append into the mapped page (per-layer layout).

    new_rows [B, Kv, dh] float; pos [B] is the write position.  Unmapped
    table entries (retired slots) turn into out-of-range scatter ids and
    the write is dropped.  The page checksum is maintained incrementally:
    pagesum += rowsum of the new row.
    """
    n_pages = pk.q.shape[0]
    b = new_rows.shape[0]
    nq = quantize_kv_rows(new_rows)                    # leaves [B, Kv, ...]
    pid = pk.table[jnp.arange(b), pos // pk.q.shape[2]]
    pid = jnp.where(pid >= 0, pid, n_pages)            # drop unmapped
    off = pos % pk.q.shape[2]
    return pk._replace(
        q=pk.q.at[pid, :, off].set(nq.q),
        alpha=pk.alpha.at[pid, :, off].set(nq.alpha),
        beta=pk.beta.at[pid, :, off].set(nq.beta),
        pagesum=pk.pagesum.at[pid].add(nq.rowsum),
    )


def page_errors(pk: PagedKV, pos: jax.Array) -> jax.Array:
    """Per-(slot, chunk) checksum mismatches among touched pages.

    pos [B] -> int32 [B, max_pages]: how many (page, kv-head) checksums
    disagree with the recomputed fold.  Verify-on-touch masking: only
    mapped pages at or below the read frontier count.
    """
    n_pages, _, page = pk.q.shape[:3]
    tbl = pk.table
    safe = jnp.clip(tbl, 0, n_pages - 1)
    got = jnp.sum(pk.q[safe].astype(jnp.int32), axis=(-1, -2))  # [B,MP,Kv]
    touched = (tbl >= 0) & (
        jnp.arange(tbl.shape[1])[None, :] * page <= pos[:, None])
    err = (got != pk.pagesum[safe]) & touched[..., None]
    return jnp.sum(err.astype(jnp.int32), axis=-1)


def attend_paged(q_heads: jax.Array, pk: PagedKV, pv: PagedKV,
                 pos: jax.Array, *, n_heads: int, n_kv: int,
                 verify: bool = True, window=None, prefix_global: int = 0
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode attention off the paged int8 pools.

    q_heads [B, H, dh]; returns (out [B, H, dh] f32, err_count int32,
    pages_verified int32).  Same affine score expansion as
    :func:`~repro.core.abft_kvcache.attend_quantized` —
    ``q·k_row = α_row (q·k_q_row) + β_row Σ_d q_d`` — but the contraction
    runs over gathered pages and the ABFT check is ONE int32 compare per
    touched (page, kv head) instead of one per row.  ``pages_verified``
    counts touched pages over both pools — the verify work actually done,
    which for short resident requests is far below the contiguous path's
    whole-bucket re-verify.
    """
    b, h, dh = q_heads.shape
    g = n_heads // n_kv
    n_pages, kvh, page = pk.q.shape[:3]
    mp = pk.table.shape[1]
    tbl = pk.table
    safe = jnp.clip(tbl, 0, n_pages - 1)
    mapped = tbl >= 0                                          # [B, MP]
    touched = mapped & (jnp.arange(mp)[None, :] * page <= pos[:, None])

    kq = pk.q[safe]                                 # [B, MP, Kv, P, dh]
    vq = pv.q[safe]

    errs = jnp.zeros((), jnp.int32)
    pages = jnp.zeros((), jnp.int32)
    if verify:
        got_k = jnp.sum(kq.astype(jnp.int32), axis=(-1, -2))   # [B,MP,Kv]
        got_v = jnp.sum(vq.astype(jnp.int32), axis=(-1, -2))
        err_k = (got_k != pk.pagesum[safe]) & touched[..., None]
        err_v = (got_v != pv.pagesum[safe]) & touched[..., None]
        errs = (jnp.sum(err_k) + jnp.sum(err_v)).astype(jnp.int32)
        pages = (2 * jnp.sum(touched)).astype(jnp.int32)

    # gathered pages -> grouped sequence layout [B, Kv, MP*P, *]
    ks = kq.transpose(0, 2, 1, 3, 4).reshape(b, kvh, mp * page, dh)
    vs = vq.transpose(0, 2, 1, 3, 4).reshape(b, kvh, mp * page, dh)
    ka = pk.alpha[safe].transpose(0, 2, 1, 3).reshape(b, kvh, mp * page)
    kb = pk.beta[safe].transpose(0, 2, 1, 3).reshape(b, kvh, mp * page)
    va = pv.alpha[safe].transpose(0, 2, 1, 3).reshape(b, kvh, mp * page)
    vb = pv.beta[safe].transpose(0, 2, 1, 3).reshape(b, kvh, mp * page)

    qg = q_heads.reshape(b, n_kv, g, dh).astype(jnp.float32)
    qk_int = jnp.einsum("bkgd,bksd->bkgs", qg, ks.astype(jnp.float32))
    qsum = jnp.sum(qg, axis=-1)                                # [B, Kv, g]
    s = (ka[:, :, None, :] * qk_int
         + kb[:, :, None, :] * qsum[..., None]) * dh ** -0.5

    kv_pos = jnp.arange(mp * page)[None, None, None, :]
    in_map = jnp.repeat(mapped, page, axis=1)[:, None, None, :]
    valid = in_map & (kv_pos <= pos[:, None, None, None])
    if window is not None:
        in_win = (pos[:, None, None, None] - kv_pos) < window
        if prefix_global > 0:
            in_win |= kv_pos < prefix_global
        valid &= in_win
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)                       # [B, Kv, g, MP*P]

    pv_int = jnp.einsum("bkgs,bksd->bkgd", p * va[:, :, None, :],
                        vs.astype(jnp.float32))
    pbeta = jnp.sum(p * vb[:, :, None, :], axis=-1)
    out = pv_int + pbeta[..., None]
    return out.reshape(b, h, dh), errs, pages


def scrub_cache(cache, pos: jax.Array):
    """Whole-pool page verify for the engine's evict/rebuild path.

    ``cache`` is the stacked attn cache ({"attn": {"k": PagedKV, "v":
    PagedKV}} with leading-L leaves); returns {"k": [B, MP], "v": ...}
    int32 mismatch counts summed over layers — the host maps flagged
    (slot, chunk) pairs back to page ids and applies the plan policy.
    """
    attn = cache["attn"]
    per_layer = jax.vmap(page_errors, in_axes=(0, None))
    return {"k": jnp.sum(per_layer(attn["k"], pos), axis=0),
            "v": jnp.sum(per_layer(attn["v"], pos), axis=0)}


def pool_page_bytes(pool: PagedKV) -> int:
    """Bytes one page owns in this pool (table excluded) — the unit the
    allocator's high-water mark converts to peak resident KV bytes."""
    axis = 1 if pool.q.ndim == 5 else 0
    total = 0
    for leaf in (pool.q, pool.alpha, pool.beta, pool.pagesum):
        total += (leaf.size // leaf.shape[axis]) * leaf.dtype.itemsize
    return int(total)
