"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import LANE, MOD, embedding_bag


def int8_dot(a_q: jax.Array, b_q: jax.Array) -> jax.Array:
    """int8 x int8 -> int32 dot WITHOUT materializing int32 operands.

    ``a.astype(int32) @ b.astype(int32)`` writes 4x-sized converted copies
    of both operands to HBM on every call (measured: +2.8 TB/token on the
    123B decode cell — EXPERIMENTS §Perf hillclimb 3).  The MXU consumes
    int8 natively; expressing the dot on int8 operands with an int32
    accumulator is both the TPU-faithful form and the XLA fix.
    """
    return jax.lax.dot_general(a_q, b_q, (((a_q.ndim - 1,), (0,)), ((), ())),
                               preferred_element_type=jnp.int32)


def abft_qgemm_ref(a_q: jax.Array, b_packed: jax.Array, mod: int = MOD):
    """Oracle for kernels.abft_qgemm: (C int32 [m,n], err_rows int32 [m])."""
    n = b_packed.shape[1] - LANE
    c_full = int8_dot(a_q, b_packed)
    c = c_full[:, :n]
    check = c_full[:, n] % mod
    rowsum = jnp.sum(c % mod, axis=1) % mod
    return c, (rowsum != check).astype(jnp.int32)


def abft_eb_ref(table_q, alphas, betas, indices, weights=None):
    """Oracle for kernels.abft_embeddingbag: (R [bags,d], rsum [bags])."""
    r = embedding_bag(table_q, alphas, betas, indices, weights)
    return r, jnp.sum(r, axis=-1)


def quantize_rows_ref(x: jax.Array):
    """Oracle for kernels.quantize_rows (signed int8 per-row affine)."""
    x = x.astype(jnp.float32)
    xmin = jnp.min(x, axis=1)
    xmax = jnp.max(x, axis=1)
    span = jnp.maximum(xmax - xmin, 1e-12)
    alpha = span / 255.0
    beta = xmin + 128.0 * alpha
    q = jnp.clip(jnp.round((x - beta[:, None]) / alpha[:, None]), -128, 127)
    return q.astype(jnp.int8), alpha, beta
