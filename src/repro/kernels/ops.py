"""Public jit'd wrappers over the Pallas kernels.

``use_pallas`` selects the Pallas path (TPU target; ``interpret=True``
executes the kernel body on CPU for validation) vs. the pure-XLA path (the
op set the dry-run lowers — identical math, real HLO cost model).  On a CPU
container the default is the XLA path; on TPU it is the Pallas path.

Dispatch precedence (all three wrappers): an EXPLICIT ``use_pallas``
(True/False) always wins.  Only when it is None does ``interpret=True``
(validate the kernel body on CPU) or a TPU backend select the Pallas path.

These wrappers are the operator surface the :mod:`repro.protect` adapters
dispatch to — layer code should not call them directly.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import (AbftEbOut, EB_REL_BOUND, LANE,
                        abft_embedding_bag as _abft_eb_core,
                        encode_activation_checksum, verify_bags)
from repro.kernels import ref as _ref


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:  # pragma: no cover
        return False


def _use_pallas(use_pallas: Optional[bool], interpret: bool) -> bool:
    """Resolve the scheme: explicit beats auto, auto = interpret-or-TPU.

    (The old ``if use_pallas or interpret`` sent ``use_pallas=False,
    interpret=True`` to the Pallas kernel — an explicit XLA request lost.)
    """
    if use_pallas is not None:
        return use_pallas
    return interpret or _on_tpu()


def abft_qgemm(a_q: jax.Array, b_packed: jax.Array, *,
               use_pallas: Optional[bool] = None, interpret: bool = False,
               with_colcheck: bool = False,
               bm: int = 128, bn: int = 128, bk: int = 128):
    """ABFT int8 GEMM against a packed B'. -> (C int32, err_rows int32 [m]).

    ``with_colcheck=True`` additionally returns the **exact expected int32
    column sums of C** — ``encode_activation_checksum(A) @ B`` — the second
    encoding axis :func:`repro.core.correct_single_error` needs to localize
    and repair a single flagged cell.  The column product is a k×n matvec
    (one extra GEMM row's worth of MACs) and runs in int32 (an int8 column
    sum of A overflows int8, so it cannot ride the packed operand); it is
    therefore gated behind the flag and only paid by ``correct``-policy
    call sites.  On the Pallas path the matvec is fused into the kernel's
    per-tile pass, so the ``correct`` policy pays no second read of A/B'.
    """
    if _use_pallas(use_pallas, interpret):
        from repro.kernels.abft_qgemm import abft_qgemm_pallas
        return abft_qgemm_pallas(a_q, b_packed, bm=bm, bn=bn, bk=bk,
                                 interpret=interpret or not _on_tpu(),
                                 with_colcheck=with_colcheck)
    c, err_rows = _ref.abft_qgemm_ref(a_q, b_packed)
    if not with_colcheck:
        return c, err_rows
    n = b_packed.shape[1] - LANE
    col_a = encode_activation_checksum(a_q)                   # int32 [k]
    col_check = jax.lax.dot_general(
        col_a, b_packed[:, :n].astype(jnp.int32),
        (((0,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    return c, err_rows, col_check


def abft_embedding_bag(table_q, alphas, betas, indices, rowsums,
                       weights=None, *, rel_bound: float = EB_REL_BOUND,
                       use_pallas: Optional[bool] = None,
                       interpret: bool = False):
    """EB forward + Eq. (5) check. -> AbftEbOut(r, err_bags, err_count)."""
    if _use_pallas(use_pallas, interpret):
        from repro.kernels.abft_embeddingbag import abft_eb_pallas
        r, rsum = abft_eb_pallas(table_q, alphas, betas, indices, weights,
                                 interpret=interpret or not _on_tpu())
        # ONE Eq. (5) definition for both paths (repro.core.verify_bags):
        # the kernel's fused rsum feeds the shared check, so rel_bound
        # semantics cannot drift between XLA and Pallas
        err_bags = verify_bags(rsum, alphas, betas, indices, rowsums,
                               table_q.shape[-1], weights, rel_bound)
        return AbftEbOut(r, err_bags, jnp.sum(err_bags).astype(jnp.int32))
    return _abft_eb_core(table_q, alphas, betas, indices, rowsums,
                         weights, rel_bound)


def quantize_rows(x: jax.Array, *, use_pallas: Optional[bool] = None,
                  interpret: bool = False):
    """Per-row signed-int8 dynamic quantization. -> (q, alpha, beta)."""
    if _use_pallas(use_pallas, interpret):
        from repro.kernels.quantize_rows import quantize_rows_pallas
        return quantize_rows_pallas(x, interpret=interpret or not _on_tpu())
    return _ref.quantize_rows_ref(x)
