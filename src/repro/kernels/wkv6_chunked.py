"""Pallas TPU kernel: chunked matmul-form WKV6 forward.

The XLA chunked form (layers.rwkv6.wkv_chunked) already lands 123x on the
rwkv train cell, but XLA still materializes every per-chunk normalization
tensor to HBM (EXPERIMENTS §Perf hillclimb 1, iters 2-4).  This kernel is
the structural fix: ALL per-chunk tensors (cumulative log-decay, the three
normalized operands, the [C, C] score tile) live in VMEM/registers; HBM
traffic is exactly the r/k/v/lw input streams + the y output stream + the
state carried in VMEM across the whole sequence.

Grid: (B·H, n_chunks) — batch·head parallel, chunks sequential
("arbitrary") so the S scratch [dh, dh] carries across chunk steps.

Math is identical to layers.rwkv6.wkv_chunked (same f32 envelope:
chunk · |LOG_W_MIN| ≤ 80); the pure-jnp oracle is
layers.rwkv6.wkv_recurrent, asserted in tests/test_wkv_chunked.py.

TPU note: dh = 64 for the assigned rwkv6-1.6b; production would pad the
lane dim to 128 (the wrapper zero-pads — checksum-neutral like the qgemm
kernel's padding).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 names this TPUCompilerParams; newer releases dropped the prefix.
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref, y_ref, sout_ref,
            s_ref, *, n_chunks: int, chunk: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _load_state():
        s_ref[...] = s0_ref[0]

    r_ = r_ref[0, 0]                      # [C, dh] f32
    k_ = k_ref[0, 0]
    v_ = v_ref[0, 0]
    lw = lw_ref[0, 0]
    u = u_ref[0]                          # [dh]

    la = jnp.cumsum(lw, axis=0)           # [C, dh]
    la_prev = la - lw
    la_end = la[-1:, :]                   # [1, dh]

    rt = r_ * jnp.exp(la_prev)            # bounded ≤ |r|
    kin = k_ * jnp.exp(-la)               # ≤ e^{C·|lw_min|} (envelope)
    kst = k_ * jnp.exp(la_end - la)       # bounded ≤ |k|
    diag = jnp.sum(r_ * u[None, :] * k_, axis=1)          # [C]

    s_cur = s_ref[...]                    # [dh, dh] (key x value)
    y_inter = jnp.dot(rt, s_cur, preferred_element_type=jnp.float32)
    scores = jnp.dot(rt, kin.T, preferred_element_type=jnp.float32)
    mask = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
            > jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    scores = jnp.where(mask, scores, 0.0)
    y = (y_inter + jnp.dot(scores, v_, preferred_element_type=jnp.float32)
         + diag[:, None] * v_)
    y_ref[0, 0] = y

    s_ref[...] = (jnp.exp(la_end[0])[:, None] * s_cur
                  + jnp.dot(kst.T, v_, preferred_element_type=jnp.float32))

    @pl.when(c == n_chunks - 1)
    def _store_state():
        sout_ref[0] = s_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv_chunked_pallas(rh, kh, vh, lwh, u, state, *, chunk: int = 16,
                       interpret: bool = False):
    """rh/kh/vh/lwh [B,S,H,dh] f32, u [H,dh], state [B,H,dh,dh].

    Returns (ys [B,S,H,dh], new_state [B,H,dh,dh]) — drop-in for
    layers.rwkv6.wkv_chunked.
    """
    b, s, h, dh = rh.shape
    assert s % chunk == 0, (s, chunk)
    n_chunks = s // chunk
    bh = b * h

    def prep(x):   # [B,S,H,dh] -> [BH, n_chunks, C, dh]
        return (x.transpose(0, 2, 1, 3)
                .reshape(bh, n_chunks, chunk, dh))

    rc, kc, vc, lwc = map(prep, (rh, kh, vh, lwh))
    u_bh = jnp.broadcast_to(u[None], (b, h, dh)).reshape(bh, dh)
    s0 = state.reshape(bh, dh, dh).astype(jnp.float32)

    kernel = functools.partial(_kernel, n_chunks=n_chunks, chunk=chunk)
    ys, s_out = pl.pallas_call(
        kernel,
        grid=(bh, n_chunks),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, dh), lambda i, c: (i, c, 0, 0)),
            pl.BlockSpec((1, 1, chunk, dh), lambda i, c: (i, c, 0, 0)),
            pl.BlockSpec((1, 1, chunk, dh), lambda i, c: (i, c, 0, 0)),
            pl.BlockSpec((1, 1, chunk, dh), lambda i, c: (i, c, 0, 0)),
            pl.BlockSpec((1, dh), lambda i, c: (i, 0)),
            pl.BlockSpec((1, dh, dh), lambda i, c: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, dh), lambda i, c: (i, c, 0, 0)),
            pl.BlockSpec((1, dh, dh), lambda i, c: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, n_chunks, chunk, dh), jnp.float32),
            jax.ShapeDtypeStruct((bh, dh, dh), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dh, dh), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(rc, kc, vc, lwc, u_bh, s0)

    ys = (ys.reshape(b, h, s, dh).transpose(0, 2, 1, 3))
    return ys, s_out.reshape(b, h, dh, dh)
