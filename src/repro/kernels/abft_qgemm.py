"""Pallas TPU kernel: int8 ABFT GEMM with fused checksum verification.

Computes ``C[int32] = A[int8] @ B'[int8]`` where ``B' = [B | checksum-block]``
(:func:`repro.core.abft_gemm.pack_encoded_b`), and verifies Eq. (3b) row-wise
*in the epilogue* while C tiles are still in VMEM.

Tiling (DESIGN.md §3):
  grid = (M/bm, N'/bn, K/bk), K innermost (accumulation), then N, then M.
  * ``acc``     VMEM scratch [bm, bn] int32 — MXU accumulator across K tiles.
  * ``rowsum``  VMEM scratch [bm]    int32 — running ``Σ_j C[i,j] mod 127``
                across N tiles of the same M row-block (grid order makes N
                sequential for fixed M, so the scratch carries across tiles).
  * The final N tile group is the 128-lane checksum block: lane 0 holds
    ``A @ S_B``; the epilogue compares it (mod 127) against ``rowsum`` and
    writes the per-row error flags.

Per-element ``mod`` before the row reduction keeps the verify exact for any N
(no int32 overflow), per DESIGN.md §3.

The verify costs zero extra HBM traffic: the paper's CPU version re-reads C
from cache (O(mn) reads); here the reduction happens on the tile the MXU just
produced.  This is the kernel-level beyond-paper win.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import LANE, MOD

# jax < 0.5 names this TPUCompilerParams; newer releases dropped the prefix.
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams


def _kernel(a_ref, bp_ref, c_ref, err_ref, acc_ref, rowsum_ref, *,
            n_tiles: int, k_tiles: int, mod: int):
    j = pl.program_id(1)
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when((j == 0) & (kk == 0))
    def _zero_row_state():
        rowsum_ref[...] = jnp.zeros_like(rowsum_ref)
        err_ref[...] = jnp.zeros_like(err_ref)

    # MXU step: int8 x int8 -> int32.
    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], bp_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(kk == k_tiles - 1)
    def _epilogue():
        tile = acc_ref[...]
        c_ref[...] = tile

        @pl.when(j < n_tiles - 1)
        def _accumulate_rowsum():
            # per-element mod bounds the row sum by 126*bn (no overflow).
            rowsum_ref[...] = (rowsum_ref[...]
                               + jnp.sum(tile % mod, axis=1)) % mod

        @pl.when(j == n_tiles - 1)
        def _verify():
            check = tile[:, 0] % mod          # lane 0 = A @ S_B
            bad = rowsum_ref[...] != check
            err_ref[...] = bad.astype(jnp.int32)[:, None]


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "mod", "interpret"))
def abft_qgemm_pallas(a_q: jax.Array, b_packed: jax.Array, *,
                      bm: int = 128, bn: int = 128, bk: int = 128,
                      mod: int = MOD, interpret: bool = False):
    """Run the fused ABFT GEMM. Returns ``(C [m,n] int32, err_rows [m] i32)``.

    ``a_q``: int8 [m, k] (activations, signed-quantized);
    ``b_packed``: int8 [k, n + LANE] from :func:`pack_encoded_b`.
    Shapes are padded up to tile multiples internally; zero padding is
    checksum-neutral (zero rows/cols contribute 0 to every sum).
    """
    m, k = a_q.shape
    k2, n_packed = b_packed.shape
    assert k == k2, (a_q.shape, b_packed.shape)
    n = n_packed - LANE
    assert n >= 1
    assert LANE % bn == 0 or bn % LANE == 0, "checksum block must tile evenly"

    mp = -(-m // bm) * bm
    kp = -(-k // bk) * bk
    np_ = -(-n // bn) * bn
    cs_width = max(LANE, bn)  # checksum block padded to a whole tile group

    a_pad = jnp.zeros((mp, kp), jnp.int8).at[:m, :k].set(a_q.astype(jnp.int8))
    bp_pad = jnp.zeros((kp, np_ + cs_width), jnp.int8)
    bp_pad = bp_pad.at[:k, :n].set(b_packed[:, :n])
    bp_pad = bp_pad.at[:k, np_:np_ + LANE].set(b_packed[:, n:])

    n_tiles_c = np_ // bn               # tiles holding real C columns
    cs_tiles = cs_width // bn           # tiles holding the checksum block
    n_tiles = n_tiles_c + cs_tiles
    k_tiles = kp // bk
    grid = (mp // bm, n_tiles, k_tiles)

    # NOTE: when bn > LANE the checksum block is one tile (cs_tiles == 1);
    # when bn < LANE it spans several tiles but lane 0 of the *first* of them
    # carries the checksum, so we treat tile index n_tiles_c as "the" verify
    # tile and ignore the trailing zero tiles.
    kernel = functools.partial(
        _kernel, n_tiles=n_tiles_c + 1, k_tiles=k_tiles, mod=mod)

    c_full, err = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, n_tiles * bn), jnp.int32),
            jax.ShapeDtypeStruct((mp, 1), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.int32),
            pltpu.VMEM((bm,), jnp.int32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(a_pad, bp_pad)

    return c_full[:m, :n], err[:m, 0]
