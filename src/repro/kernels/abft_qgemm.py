"""Pallas TPU kernel: int8 ABFT GEMM with fused checksum verification.

Computes ``C[int32] = A[int8] @ B'[int8]`` where ``B' = [B | checksum-block]``
(:func:`repro.core.abft_gemm.pack_encoded_b`), and verifies Eq. (3b) row-wise
*in the epilogue* while C tiles are still in VMEM.

Tiling (DESIGN.md §3):
  grid = (M/bm, N'/bn, K/bk), K innermost (accumulation), then N, then M.
  * ``acc``     VMEM scratch [bm, bn] int32 — MXU accumulator across K tiles.
  * ``rowsum``  VMEM scratch [bm]    int32 — running ``Σ_j C[i,j] mod 127``
                across N tiles of the same M row-block (grid order makes N
                sequential for fixed M, so the scratch carries across tiles).
  * The final N tile group is the 128-lane checksum block: lane 0 holds
    ``A @ S_B``; the epilogue compares it (mod 127) against ``rowsum`` and
    writes the per-row error flags.

Per-element ``mod`` before the row reduction keeps the verify exact for any N
(no int32 overflow), per DESIGN.md §3.

uint8 activations ride a zero-point path: the wrapper shifts ``A_u`` to
``A_s = A_u - 128`` (int8, a bit-xor), the MXU runs signed, and the epilogue
adds ``128 · Σ_k B'[k, j]`` back per column from the ``bcol`` scratch —
**before** the rowsum/verify, so the flags are bit-identical to the unsigned
reference path (128 ≡ 1 mod 127, so a clean checksum block stays clean and a
corrupted one trips exactly when the reference trips).  The correction costs
zero extra HBM traffic: ``bcol`` accumulates from the B' tiles already in
VMEM for the MXU step.

``with_colcheck=True`` additionally emits the Eq.-1 expected column sums
``colsum(A) @ B'`` in the same pass — an independent per-tile matvec over the
A/B' tiles (NOT a reduction of the C tiles: an accumulator fault must show up
as a *disagreement* between C's column sums and this check, which a fold of C
would cancel by construction).

The verify costs zero extra HBM traffic: the paper's CPU version re-reads C
from cache (O(mn) reads); here the reduction happens on the tile the MXU just
produced.  This is the kernel-level beyond-paper win.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import LANE, MOD

# jax < 0.5 names this TPUCompilerParams; newer releases dropped the prefix.
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams


def _kernel(a_ref, bp_ref, *refs, n_tiles: int, k_tiles: int, m_tiles: int,
            mod: int, zero_point: int, valid_m: int, with_colcheck: bool,
            bn: int):
    # refs = outputs (c, err[, col]) then scratches (acc, rowsum[, bcol]
    # [, colacc]) — the optional ones exist only when their static flag is
    # set, so unpack by the same flags.
    if with_colcheck:
        c_ref, err_ref, col_ref = refs[:3]
        scratch = refs[3:]
    else:
        c_ref, err_ref = refs[:2]
        scratch = refs[2:]
    acc_ref, rowsum_ref = scratch[:2]
    scratch = scratch[2:]
    if zero_point:
        bcol_ref, scratch = scratch[0], scratch[1:]
    if with_colcheck:
        colacc_ref = scratch[0]

    i = pl.program_id(0)
    j = pl.program_id(1)
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        if zero_point:
            bcol_ref[...] = jnp.zeros_like(bcol_ref)

    @pl.when((j == 0) & (kk == 0))
    def _zero_row_state():
        rowsum_ref[...] = jnp.zeros_like(rowsum_ref)
        err_ref[...] = jnp.zeros_like(err_ref)

    if with_colcheck:
        @pl.when((i == 0) & (kk == 0))
        def _zero_colacc():
            colacc_ref[0, pl.ds(j * bn, bn)] = jnp.zeros((bn,), jnp.int32)

    # MXU step: int8 x int8 -> int32.
    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], bp_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    if zero_point:
        # per-column Σ_k B'[k, j] for the epilogue's zero-point correction
        bcol_ref[...] += jnp.sum(bp_ref[...].astype(jnp.int32), axis=0)

    if with_colcheck:
        # Eq.-1 colsum matvec fused into the same pass: colsum of the A
        # tile (zero-padded rows contribute 0) times the B' tile.  Runs on
        # the tiles already in VMEM — no extra HBM reads.
        asum = jnp.sum(a_ref[...].astype(jnp.int32), axis=0)
        contrib = jax.lax.dot_general(
            asum, bp_ref[...].astype(jnp.int32), (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        colacc_ref[0, pl.ds(j * bn, bn)] += contrib
        if zero_point:
            # the unsigned colsum is colsum(A_s) + 128·m; add the constant
            # term once per (j, kk) — it does not depend on the A row tile
            @pl.when(i == 0)
            def _colacc_zp():
                colacc_ref[0, pl.ds(j * bn, bn)] += (
                    zero_point * valid_m
                    * jnp.sum(bp_ref[...].astype(jnp.int32), axis=0))

    @pl.when(kk == k_tiles - 1)
    def _epilogue():
        tile = acc_ref[...]
        if zero_point:
            # restore the unsigned product before verify: the flags must
            # be computed on C_u = C_s + 128·Σ_k B', not on the shifted
            # intermediate, or uint8 detection would diverge from the
            # reference path
            tile = tile + zero_point * bcol_ref[...][None, :]
        c_ref[...] = tile

        @pl.when(j < n_tiles - 1)
        def _accumulate_rowsum():
            # per-element mod bounds the row sum by 126*bn (no overflow).
            rowsum_ref[...] = (rowsum_ref[...]
                               + jnp.sum(tile % mod, axis=1)) % mod

        @pl.when(j == n_tiles - 1)
        def _verify():
            check = tile[:, 0] % mod          # lane 0 = A @ S_B
            bad = rowsum_ref[...] != check
            err_ref[...] = bad.astype(jnp.int32)[:, None]

        if with_colcheck:
            @pl.when(i == m_tiles - 1)
            def _flush_col():
                col_ref[...] = colacc_ref[0:1, pl.ds(j * bn, bn)]


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "mod", "interpret", "with_colcheck"))
def abft_qgemm_pallas(a_q: jax.Array, b_packed: jax.Array, *,
                      bm: int = 128, bn: int = 128, bk: int = 128,
                      mod: int = MOD, interpret: bool = False,
                      with_colcheck: bool = False):
    """Run the fused ABFT GEMM. Returns ``(C [m,n] int32, err_rows [m] i32)``,
    plus the Eq.-1 expected column sums (``int32 [n]``) when
    ``with_colcheck=True``.

    ``a_q``: uint8 or int8 [m, k] (activations; uint8 rides the zero-point
    path and produces bit-identical C/flags to the reference);
    ``b_packed``: int8 [k, n + LANE] from :func:`pack_encoded_b`.
    Shapes are padded up to tile multiples internally; zero padding is
    checksum-neutral (zero rows/cols contribute 0 to every sum).
    """
    m, k = a_q.shape
    k2, n_packed = b_packed.shape
    assert k == k2, (a_q.shape, b_packed.shape)
    n = n_packed - LANE
    assert n >= 1
    assert LANE % bn == 0 or bn % LANE == 0, "checksum block must tile evenly"
    if b_packed.dtype != jnp.int8:
        raise TypeError(f"b_packed must be int8 (pack_encoded_b output), "
                        f"got {b_packed.dtype}")
    if a_q.dtype == jnp.int8:
        zero_point = 0
    elif a_q.dtype == jnp.uint8:
        # A_u = A_s + 128 with A_s = (A_u ^ 0x80) as int8 — exact, and the
        # epilogue adds 128·Σ_k B' back per column.  A bare astype would
        # silently reinterpret values >= 128 as negative.
        zero_point = 128
        a_q = (a_q ^ jnp.uint8(0x80)).astype(jnp.int8)
    else:
        raise TypeError(f"a_q must be int8 or uint8, got {a_q.dtype}")

    mp = -(-m // bm) * bm
    kp = -(-k // bk) * bk
    np_ = -(-n // bn) * bn
    cs_width = max(LANE, bn)  # checksum block padded to a whole tile group

    a_pad = jnp.zeros((mp, kp), jnp.int8).at[:m, :k].set(a_q)
    bp_pad = jnp.zeros((kp, np_ + cs_width), jnp.int8)
    bp_pad = bp_pad.at[:k, :n].set(b_packed[:, :n])
    bp_pad = bp_pad.at[:k, np_:np_ + LANE].set(b_packed[:, n:])

    n_tiles_c = np_ // bn               # tiles holding real C columns
    cs_tiles = cs_width // bn           # tiles holding the checksum block
    n_tiles = n_tiles_c + cs_tiles
    k_tiles = kp // bk
    m_tiles = mp // bm
    grid = (m_tiles, n_tiles, k_tiles)

    # NOTE: when bn > LANE the checksum block is one tile (cs_tiles == 1);
    # when bn < LANE it spans several tiles but lane 0 of the *first* of them
    # carries the checksum, so we treat tile index n_tiles_c as "the" verify
    # tile and ignore the trailing zero tiles.
    kernel = functools.partial(
        _kernel, n_tiles=n_tiles_c + 1, k_tiles=k_tiles, m_tiles=m_tiles,
        mod=mod, zero_point=zero_point, valid_m=m,
        with_colcheck=with_colcheck, bn=bn)

    out_specs = [
        pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((mp, n_tiles * bn), jnp.int32),
        jax.ShapeDtypeStruct((mp, 1), jnp.int32),
    ]
    scratch_shapes = [
        pltpu.VMEM((bm, bn), jnp.int32),
        pltpu.VMEM((bm,), jnp.int32),
    ]
    # the col output block (0, j) is revisited across M tiles, so the M
    # dimension loses its "parallel" independence when the check is fused
    m_semantics = "parallel"
    if zero_point:
        scratch_shapes.append(pltpu.VMEM((bn,), jnp.int32))
    if with_colcheck:
        out_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
        out_shape.append(
            jax.ShapeDtypeStruct((1, n_tiles * bn), jnp.int32))
        scratch_shapes.append(pltpu.VMEM((1, n_tiles * bn), jnp.int32))
        m_semantics = "arbitrary"

    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch_shapes,
        compiler_params=_CompilerParams(
            dimension_semantics=(m_semantics, "arbitrary", "arbitrary")),
        interpret=interpret,
    )(a_pad, bp_pad)

    if with_colcheck:
        c_full, err, col = outs
        return c_full[:m, :n], err[:m, 0], col[0, :n]
    c_full, err = outs
    return c_full[:m, :n], err[:m, 0]
