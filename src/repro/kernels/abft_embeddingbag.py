"""Pallas TPU kernel: quantized EmbeddingBag with fused ABFT row-sum.

TPU-native analogue of FBGEMM's prefetching EB (DESIGN.md §3): bag indices
are *scalar-prefetched* (``PrefetchScalarGridSpec``) so the index of the next
row is known to the DMA engine ahead of the grid step; each step streams one
embedding row HBM→VMEM, dequantizes (α_i, β_i), and accumulates both the bag
vector and its scalar sum — the left side of Eq. (5) — in the same pass.

grid = (bags, pool): for bag ``b``, steps ``p = 0..pool-1`` accumulate row
``indices[b, p]``.  Padded slots (index < 0) are pre-masked by the wrapper
into (row 0, weight 0).

Outputs: ``R [bags, d] f32`` and ``rsum [bags, 1] f32`` (Σ_j R[b, j]).
The Eq. (5) comparison against the gathered table row-sums is O(bags·pool)
and happens in the ops wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, row_ref, ab_ref, r_ref, rsum_ref, acc_ref, *,
            pool: int):
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    alpha = ab_ref[0, 0, p]
    beta = ab_ref[0, 1, p]
    w = ab_ref[0, 2, p]
    row = row_ref[...].astype(jnp.float32)      # [1, d]
    acc_ref[...] += w * (alpha * row + beta)

    @pl.when(p == pool - 1)
    def _flush():
        r_ref[...] = acc_ref[...]
        rsum_ref[...] = jnp.sum(acc_ref[...], axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("interpret",))
def abft_eb_pallas(table_q: jax.Array, alphas: jax.Array, betas: jax.Array,
                   indices: jax.Array, weights: jax.Array | None = None, *,
                   interpret: bool = False):
    """Gather-and-sum with fused RSum. Returns ``(R [bags,d], rsum [bags])``.

    table_q int8 [rows, d]; alphas/betas f32 [rows]; indices int32
    [bags, pool] (−1 padded); weights f32 [bags, pool] or None.
    """
    bags, pool = indices.shape
    rows, d = table_q.shape
    valid = indices >= 0
    safe_idx = jnp.where(valid, indices, 0).astype(jnp.int32)
    w = jnp.ones_like(alphas[safe_idx]) if weights is None else weights
    w = jnp.where(valid, w, 0.0)
    # [bags, 3, pool]: per-slot (alpha, beta*w-handling, weight) — gathered by
    # XLA (O(bags*pool) — negligible vs the O(bags*pool*d) row traffic).
    ab = jnp.stack([alphas[safe_idx], betas[safe_idx], w], axis=1)

    grid = (bags, pool)
    r, rsum = pl.pallas_call(
        functools.partial(_kernel, pool=pool),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                # one embedding row per step, addressed by the prefetched
                # flat index — the TPU analogue of software prefetch.
                pl.BlockSpec(
                    (1, d), lambda b, p, idx_ref: (idx_ref[b, p], 0)),
                pl.BlockSpec((1, 3, pool), lambda b, p, idx_ref: (b, 0, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, d), lambda b, p, idx_ref: (b, 0)),
                pl.BlockSpec((1, 1), lambda b, p, idx_ref: (b, 0)),
            ],
            scratch_shapes=[pltpu.VMEM((1, d), jnp.float32)],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((bags, d), jnp.float32),
            jax.ShapeDtypeStruct((bags, 1), jnp.float32),
        ],
        interpret=interpret,
    )(safe_idx, table_q, ab)
    return r, rsum[:, 0]
