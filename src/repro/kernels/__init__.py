"""Pallas TPU kernels for the paper's two hot-spot operators.

The paper's contribution is precisely a kernel-level one (FBGEMM-fused ABFT);
we provide the TPU-native equivalents:

- :mod:`repro.kernels.abft_qgemm`        — int8 GEMM with lane-aligned
  checksum block and verification fused in the epilogue (zero extra HBM
  traffic for the verify pass — beyond the paper's cache-resident re-read).
- :mod:`repro.kernels.abft_embeddingbag` — scalar-prefetch gather + bag-sum
  with the Eq. 5 row-sum accumulated in the same pass.
- :mod:`repro.kernels.quantize_rows`     — per-row dynamic activation
  quantization feeding the GEMM.
- :mod:`repro.kernels.wkv6_chunked`      — chunked matmul-form WKV6 with
  the state resident in VMEM across the sequence (EXPERIMENTS §Perf
  hillclimb 1, iteration 5).

``ref.py`` holds the pure-jnp oracles; ``ops.py`` the jit'd public wrappers
(with ``interpret=`` plumbed through for CPU validation).
"""
