"""Pallas TPU kernel: per-row dynamic activation quantization (signed int8).

One pass per row block: rowwise min/max reduction (VPU), derive (α, β) with
``x ≈ α·q + β`` over the signed range [-128, 127], emit q int8 + α, β f32.
Feeds :mod:`repro.kernels.abft_qgemm` (whose MXU path is s8×s8).

Block shape: (bm, n) — a full activation row must fit VMEM, which holds for
every assigned arch (max d_model 12288 → 48 KiB/row in f32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

INT8_LO, INT8_HI = -128, 127


def _kernel(x_ref, q_ref, alpha_ref, beta_ref):
    x = x_ref[...]
    xmin = jnp.min(x, axis=1, keepdims=True)
    xmax = jnp.max(x, axis=1, keepdims=True)
    span = jnp.maximum(xmax - xmin, 1e-12)
    alpha = span / (INT8_HI - INT8_LO)
    beta = xmin - INT8_LO * alpha
    q = jnp.clip(jnp.round((x - beta) / alpha), INT8_LO, INT8_HI)
    q_ref[...] = q.astype(jnp.int8)
    alpha_ref[...] = alpha
    beta_ref[...] = beta


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def quantize_rows_pallas(x: jax.Array, *, bm: int = 128,
                         interpret: bool = False):
    """f32 [m, n] -> (q int8 [m, n], alpha f32 [m], beta f32 [m])."""
    m, n = x.shape
    mp = -(-m // bm) * bm
    x_pad = jnp.zeros((mp, n), x.dtype).at[:m].set(x)
    q, alpha, beta = pl.pallas_call(
        _kernel,
        grid=(mp // bm,),
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, n), jnp.int8),
            jax.ShapeDtypeStruct((mp, 1), jnp.float32),
            jax.ShapeDtypeStruct((mp, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x_pad.astype(jnp.float32))
    return q[:m], alpha[:m, 0], beta[:m, 0]
