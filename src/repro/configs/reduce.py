"""Reduced-size config builders (smoke tests, --smoke serving, campaign
decode soaks).

Lives in the package (not tests/) so runtime entry points — serve --smoke,
``repro.campaign``'s full-model soak target — can build a tiny model of any
registered architecture without reaching into the test tree.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig
from repro.configs.registry import ARCHS


def reduce_cfg(cfg: ArchConfig) -> ArchConfig:
    """Shrink an assigned architecture to smoke-test size, preserving its
    family and structural quirks (GQA ratio, qk_norm, MoE top-k, SWA, meta
    tokens, frontend stubs...)."""
    kw = dict(
        n_layers=2,
        d_model=64,
        d_ff=128,
        vocab=97,            # deliberately unaligned: exercises vocab padding
        head_dim=16,
        attn_chunk=8,
        train_accum=1,
    )
    if cfg.n_heads:
        kw["n_heads"] = 4
        kw["n_kv_heads"] = 2 if cfg.n_kv_heads < cfg.n_heads else 4
    if cfg.family == "moe":
        kw["n_experts"] = 4
        kw["top_k"] = min(cfg.top_k, 2)
        kw["moe_group"] = 16
    if cfg.family == "hybrid":
        kw["ssm_state"] = 4
        kw["d_inner"] = 128
        kw["sliding_window"] = 8
        kw["global_layer_every"] = 2
        kw["meta_tokens"] = 4
    if cfg.family == "encdec":
        kw["enc_layers"] = 2
        kw["enc_seq"] = 12
    if cfg.family == "vlm":
        kw["patch_dim"] = 24
        kw["n_patches"] = 6
    return dataclasses.replace(cfg, **kw)


def small_arch(name: str) -> ArchConfig:
    return reduce_cfg(ARCHS[name])
