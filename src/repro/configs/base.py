"""Architecture + shape configuration schema."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense|moe|ssm|hybrid|encdec|vlm|dlrm
    n_layers: int
    d_model: int
    vocab: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    head_dim: int = 0           # 0 => d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 500000.0
    use_rope: bool = True
    gated_mlp: bool = True      # SwiGLU vs plain GeLU MLP
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_group: int = 1024
    capacity_factor: float = 1.25
    # --- SSM / hybrid ---
    ssm_state: int = 0
    d_inner: int = 0            # mamba inner width (0 => 2*d_model)
    sliding_window: int = 0     # 0 => full attention everywhere
    global_layer_every: int = 0  # hymba: every k-th layer is global attn
    meta_tokens: int = 0
    # --- encoder-decoder (whisper) ---
    enc_layers: int = 0
    enc_seq: int = 1500
    # --- VLM stub frontend ---
    patch_dim: int = 0          # vision feature dim fed to projector
    n_patches: int = 0          # patches prepended in train/prefill
    # --- runtime ---
    sub_quadratic: bool = False  # may run long_500k
    train_accum: int = 1         # gradient-accumulation microbatches
    attn_chunk: int = 1024
    wkv_chunk: int = 0           # chunked matmul-form WKV6 (rwkv; §Perf)
    ssm_chunk: int = 0           # two-level rematted mamba scan (hymba)
    deferred_grad_sync: bool = False  # shard_map manual data axis, one
    # int8+checksum grad collective per step (needs params+opt to fit
    # replicated over data — no ZeRO; EXPERIMENTS §Perf hillclimb 2)
    moe_token_parallel: bool = False  # replicate expert weights, shard the
    # expert-slot dim over `model`: collective-free MoE FFN for
    # small-expert archs (granite) — EXPERIMENTS §Perf hillclimb 2
    zero1: bool = False          # pure DP over all axes + flat ZeRO-1
    # optimizer shards (bf16 params must fit one chip) — hillclimb 2 winner
    seq_parallel: bool = False   # shard activation seq dim over `model`
    # between layers (Megatron-SP): divides the remat stash by TP degree
    source: str = ""             # provenance note

    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def vocab_padded(self) -> int:
        """Vocab padded to 256 for clean TP sharding (DESIGN.md §5)."""
        return -(-self.vocab // 256) * 256

    @property
    def d_inner_(self) -> int:
        return self.d_inner or 2 * self.d_model

    def is_global_layer(self, i: int) -> bool:
        """Hymba-style: first/last + every k-th layer use full attention."""
        if self.sliding_window == 0:
            return True
        if self.global_layer_every <= 0:
            return False
        return (i == 0 or i == self.n_layers - 1
                or i % self.global_layer_every == 0)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str                   # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}
