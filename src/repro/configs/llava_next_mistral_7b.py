"""llava-next-mistral-7b [vlm]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000 — anyres tiling.  [hf:llava-hf/llava-v1.6-mistral-7b-hf;
unverified]

The vision tower is a STUB: ``input_specs`` feeds precomputed patch features
[B, 576, 1024] (one anyres tile); the 2-layer MLP projector into d_model is
real (and ABFT-protected).  Text backbone = mistral-7b."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    head_dim=128,
    patch_dim=1024,
    n_patches=576,
    rope_theta=1000000.0,
    train_accum=8,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
)
