"""The paper's own architecture: DLRM (quantized, ABFT-protected).

Bottom MLP over dense features, 26 quantized embedding tables with multi-hot
EmbeddingBag lookups (pooling 100 — Table I), dot-product feature
interaction, top MLP -> CTR logit.  Table geometry follows the paper's EB
evaluation (4M rows); the GEMM shapes exercised by benchmarks/gemm_overhead
follow Fig. 5."""
import dataclasses

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class DlrmExtras:
    n_dense: int = 13
    bottom_mlp: tuple = (512, 256, 128)
    n_tables: int = 26
    table_rows: int = 4_000_000
    emb_dim: int = 128
    pooling: int = 100
    top_mlp: tuple = (1024, 1024, 512, 256, 1)
    batch: int = 10             # paper Table I batch size


CONFIG = ArchConfig(
    name="dlrm",
    family="dlrm",
    n_layers=0,
    d_model=128,                # = emb_dim (interaction width)
    vocab=0,
    source="paper §VI (Fig. 5, Table I)",
)

EXTRAS = DlrmExtras()
