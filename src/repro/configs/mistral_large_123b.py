"""mistral-large-123b [dense]: 88L d_model=12288 96H (GQA kv=8) d_ff=28672
vocab=32768.  [hf:mistralai/Mistral-Large-Instruct-2407; unverified]

The largest assigned cell: training runs with 8-way gradient accumulation +
scan-remat to fit 16 GB/chip on the (16,16) mesh (verified by the dry-run's
memory_analysis)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mistral-large-123b",
    family="dense",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=28672,
    vocab=32768,
    head_dim=128,
    rope_theta=1000000.0,
    train_accum=16,
    source="hf:mistralai/Mistral-Large-Instruct-2407; unverified",
)
