"""whisper-large-v3 [audio]: enc-dec, conv frontend stubbed.

32L (x2: encoder+decoder stacks) d_model=1280 20H (kv=20 — effectively MHA)
d_ff=5120 vocab=51866.  [arXiv:2212.04356; unverified]

The mel/conv frontend is a STUB: ``input_specs`` feeds precomputed frame
embeddings [B, 1500, 1280].  20 heads do not divide the 16-wide `model` mesh
axis, so attention projections replicate under TP (DESIGN.md §5); MLP and
vocab dims shard.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=32,
    enc_layers=32,
    enc_seq=1500,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    use_rope=False,             # sinusoid (enc) + learned (dec) positions
    gated_mlp=False,            # GeLU MLP
    rope_theta=10000.0,
    train_accum=8,
    source="arXiv:2212.04356; unverified",
)
