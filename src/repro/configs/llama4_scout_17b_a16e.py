"""llama4-scout-17b-a16e [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

16 experts divide the 16-wide `model` axis exactly => expert-parallel.
40 heads do not divide 16 => attention projections replicate under TP
(experts dominate FLOPs).  Shared expert / early-fusion omitted (not in the
assigned config line)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,                  # per-expert FFN width
    vocab=202048,
    head_dim=128,
    n_experts=16,
    top_k=1,
    moe_group=2048,
    rope_theta=500000.0,
    train_accum=16,
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
)
