"""rwkv6-1.6b "Finch" [ssm]: 24L d_model=2048 (attention-free) d_ff=7168
vocab=65536, data-dependent decay.  [arXiv:2404.05892; unverified]

Attention-free => O(1) decode state; runs the long_500k cell.  The WKV
recurrence is not a GEMM, so the paper's ABFT covers only the projections
(DESIGN.md §Arch-applicability)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,                 # wkv heads (dh = 64)
    n_kv_heads=32,
    d_ff=7168,
    vocab=65536,
    use_rope=False,
    sub_quadratic=True,
    train_accum=4,
    wkv_chunk=16,
    source="arXiv:2404.05892; unverified",
)
