"""Assigned-architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

from repro.configs import (
    whisper_large_v3,
    llama3_2_1b,
    internlm2_20b,
    qwen3_8b,
    mistral_large_123b,
    rwkv6_1b6,
    llama4_scout_17b_a16e,
    granite_moe_3b_a800m,
    hymba_1b5,
    llava_next_mistral_7b,
    dlrm,
)

ARCHS = {
    "whisper-large-v3": whisper_large_v3.CONFIG,
    "llama3.2-1b": llama3_2_1b.CONFIG,
    "internlm2-20b": internlm2_20b.CONFIG,
    "qwen3-8b": qwen3_8b.CONFIG,
    "mistral-large-123b": mistral_large_123b.CONFIG,
    "rwkv6-1.6b": rwkv6_1b6.CONFIG,
    "llama4-scout-17b-a16e": llama4_scout_17b_a16e.CONFIG,
    "granite-moe-3b-a800m": granite_moe_3b_a800m.CONFIG,
    "hymba-1.5b": hymba_1b5.CONFIG,
    "llava-next-mistral-7b": llava_next_mistral_7b.CONFIG,
    "dlrm": dlrm.CONFIG,            # the paper's own architecture
}


def get_arch(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs():
    return sorted(ARCHS)
