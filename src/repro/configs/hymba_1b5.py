"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attention + mamba heads, 128 meta
tokens, sliding-window attention with 3 global layers.
[arXiv:2411.13676; hf]

Sub-quadratic (SWA + SSM) => runs long_500k.  25 heads / kv=5 do not divide
the mesh => attention projections replicate; the mamba branch (d_inner=3200)
shards on `model`."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab=32001,
    head_dim=64,
    ssm_state=16,
    d_inner=3200,
    sliding_window=1024,
    global_layer_every=16,      # layers 0, 16, 31 ≈ the paper's 3 global
    meta_tokens=128,
    rope_theta=10000.0,
    sub_quadratic=True,
    train_accum=8,
    ssm_chunk=64,
    source="arXiv:2411.13676; hf",
)
