"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]

40 experts do not divide the 16-wide `model` axis => expert dim replicates
and the (tiny, 512-wide) expert FFN hidden dim shards instead — but 512/16 =
32 lanes per chip, so the sharding rules keep `expert_mlp` unsharded below
128 lanes and the FLOP-light experts replicate; 24 heads likewise.  This arch
is intentionally the poster child for "the mesh doesn't fit the model":
see EXPERIMENTS.md §Roofline."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,                   # per-expert FFN width
    vocab=49155,
    head_dim=64,
    n_experts=40,
    top_k=8,
    moe_group=512,
    rope_theta=10000.0,
    train_accum=16,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
)
