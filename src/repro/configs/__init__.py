from repro.configs.base import ArchConfig, ShapeConfig, SHAPES
from repro.configs.reduce import reduce_cfg, small_arch
from repro.configs.registry import get_arch, list_archs, ARCHS

__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "get_arch", "list_archs",
           "ARCHS", "reduce_cfg", "small_arch"]
