from repro.configs.base import ArchConfig, ShapeConfig, SHAPES
from repro.configs.registry import get_arch, list_archs, ARCHS

__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "get_arch", "list_archs",
           "ARCHS"]
