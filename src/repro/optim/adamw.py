"""AdamW + gradient clipping, from scratch (no optax).

Moments are f32 regardless of param dtype (bf16-safe), sharded like the
parameters (the launcher derives moment shardings from the param tree, so
ZeRO-style partitioning falls out of the FSDP rules for free).

Integer / packed-int8 leaves (the ABFT serving weights, EB tables, rowsum
checksums) are non-trainable: they get zero-size moment placeholders and are
passed through untouched.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def is_trainable(x) -> bool:
    """True for leaves AdamW updates: floating dtypes.  Integer / packed
    int8 leaves (ABFT serving weights, EB tables, rowsum checksums) are
    frozen: they get zero-size moment placeholders and pass through the
    update untouched."""
    return jnp.issubdtype(x.dtype, jnp.floating)


_trainable = is_trainable


def adamw_init(params):
    def mom(p):
        if _trainable(p):
            return jnp.zeros(p.shape, jnp.float32)
        return jnp.zeros((0,), jnp.float32)
    return {
        "m": jax.tree.map(mom, params),
        "v": jax.tree.map(mom, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(grads, opt_state, params, lr, *, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1):
    count = opt_state["count"] + 1
    cf = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** cf
    bc2 = 1.0 - b2 ** cf

    def upd(g, m, v, p):
        if not _trainable(p):
            return p, m, v
        gf = g.astype(jnp.float32)
        m_new = b1 * m + (1.0 - b1) * gf
        v_new = b2 * v + (1.0 - b2) * gf * gf
        mh = m_new / bc1
        vh = v_new / bc2
        step = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return p_new, m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree) if _trainable(x)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))

    def clip(g):
        return (g.astype(jnp.float32) * scale).astype(g.dtype) \
            if _trainable(g) else g
    return jax.tree.map(clip, grads), gn
