from repro.optim.adamw import (adamw_init, adamw_update, global_norm,
                               clip_by_global_norm, is_trainable)
from repro.optim.schedule import warmup_cosine, constant_lr

__all__ = ["adamw_init", "adamw_update", "global_norm",
           "clip_by_global_norm", "is_trainable", "warmup_cosine",
           "constant_lr"]
