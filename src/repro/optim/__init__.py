from repro.optim.adamw import adamw_init, adamw_update, global_norm, clip_by_global_norm
from repro.optim.schedule import warmup_cosine, constant_lr

__all__ = ["adamw_init", "adamw_update", "global_norm",
           "clip_by_global_norm", "warmup_cosine", "constant_lr"]
