"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, peak: float, warmup: int, total: int,
                  floor: float = 0.0):
    s = step.astype(jnp.float32)
    warm = peak * s / jnp.maximum(warmup, 1)
    prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = floor + 0.5 * (peak - floor) * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(s < warmup, warm, cos)


def constant_lr(step, *, peak: float, **_):
    return jnp.full_like(step, peak, dtype=jnp.float32)
