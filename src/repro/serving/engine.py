"""The protected serving engine: continuous batching over plan lanes.

:class:`ServingEngine` turns the repo's models into a request-serving
stack.  Tenants (traffic classes) carry their own
:class:`~repro.protect.ProtectionPlan` — per-tenant policies and
thresholds, the V-ABFT direction — and tenants sharing a plan share a
**lane**: one jitted prefill/decode pair compiled against that plan (the
plan rides in the jit-static ``Ctx``, so distinct plans are necessarily
distinct compiled programs) and one fixed-slot continuous batcher.

Per engine iteration:

1. arrivals whose (virtual) time has come enter the admission queue;
2. each lane fills its free decode slots FIFO from the queue and runs a
   batch=1 prefill per admission (first token = TTFT), inserting the
   request's KV state into its slot of the lane's batched cache;
3. each lane with active slots runs ONE batched decode step; detect→act
   policies run inside (recompute retries, correct, abort — an abort
   fails the lane's in-flight requests, never the server);
4. finished requests retire, freeing slots for the next iteration.

The clock is hybrid: arrivals are simulated offsets, service time is the
measured wall time of the jitted steps (compiles are excluded via
:meth:`warmup`), so SLO percentiles reflect real compute under the
chosen protection plans.

Fault injection is first-class: a :class:`FaultInjection` flips a bit in
a plan-path-addressed weight leaf right before a chosen step and — unless
``persistent`` — restores the clean weight right after it, so a
recompute-policy retry measures one *transient* upset, not a permanently
corrupted model.  Detection shows up in the same telemetry timeline as
the latency it costs.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serving.batcher import ContinuousBatcher, Slot
from repro.serving.queue import AdmissionQueue
from repro.serving.telemetry import (InjectionRecord, RequestRecord,
                                     StepEvent, Telemetry)
from repro.serving.workload import Request


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One traffic class: its protection plan and relative traffic share."""
    name: str
    plan: object = None            # ProtectionPlan (None = default_plan())
    weight: float = 1.0

    def resolved_plan(self):
        from repro.protect import default_plan
        return self.plan if self.plan is not None else default_plan()


@dataclasses.dataclass
class FaultInjection:
    """Flip one bit of one weight leaf before global step ``step``."""
    step: int
    victim: Optional[str] = None   # dotted-path pattern (core.inject)
    persistent: bool = False
    seed: int = 0


def tenant_weights(tenants: Sequence[TenantSpec]) -> Dict[str, float]:
    return {t.name: t.weight for t in tenants}


def _counters_of(metrics: dict) -> tuple:
    """(per-op int counters, total residual errors) from step metrics."""
    from repro.core.policy import op_kinds
    out: Dict[str, int] = {}
    errors = 0
    for k in op_kinds():
        c = int(metrics.get(f"abft/{k}_checks", 0))
        e = int(metrics.get(f"abft/{k}_errors", 0))
        out[f"{k}_checks"] = c
        out[f"{k}_errors"] = e
        errors += e
    out["retries"] = int(metrics.get("abft/retries", 0))
    out["corrections"] = int(metrics.get("abft/corrections", 0))
    return out, errors


class _Lane:
    """One protection plan's slice of the engine: jitted steps + batcher +
    the jax-side decode state (cache / last tokens / positions)."""

    def __init__(self, key: str, plan, tenants: List[str], n_slots: int):
        self.key = key
        self.plan = plan
        self.tenants = set(tenants)
        self.batcher = ContinuousBatcher(n_slots)
        self.n_slots = n_slots
        self.cache = None
        self.tokens = None
        self.pos = None
        self.prefill_fn = None
        self.decode_fn = None
        self.insert_fn = None
        self.forward_fn = None         # dlrm one-shot lanes

    def accepts(self, req: Request) -> bool:
        return req.tenant in self.tenants

    def reset(self):
        """Drop all jax-side state (post-abort lane reset)."""
        self.cache = None
        self.tokens = None
        self.pos = None
        return self.batcher.drain()


class ServingEngine:
    def __init__(self, cfg, tenants: Sequence[TenantSpec], *,
                 n_slots: int = 4, max_prompt: int = 64,
                 max_new_tokens: int = 32, queue_depth: int = 0,
                 seed: int = 0, compute_dtype=None,
                 dlrm_extras=None):
        import jax
        import jax.numpy as jnp

        from repro.models.base import build_model
        from repro.sharding import values_of

        if not tenants:
            raise ValueError("need at least one TenantSpec")
        names = [t.name for t in tenants]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tenant names in {names}")

        self.cfg = cfg
        self.tenants = {t.name: t for t in tenants}
        self.n_slots = n_slots
        self.max_prompt = max_prompt
        self.max_new_tokens = max_new_tokens
        self.queue = AdmissionQueue(max_depth=queue_depth)
        self.clock_s = 0.0
        self.global_step = 0
        self._compute_dtype = (jnp.bfloat16 if compute_dtype is None
                               else compute_dtype)
        #: applied-injection stack: [(leaf_idx, clean_leaf, persistent)]
        #: in application order — restores pop in reverse so an earlier
        #: fault's clean copy survives a later fault on the same leaf
        self._injection_state: list = []
        self._warm = False
        #: Observability bundle for the CURRENT run (set by run(obs=...))
        self._obs = None

        self.is_dlrm = cfg.family == "dlrm"
        if self.is_dlrm:
            from repro.configs.dlrm import EXTRAS
            self.dlrm_extras = dlrm_extras if dlrm_extras is not None \
                else EXTRAS
            from repro.models.dlrm import init_dlrm
            self.model = None
            self.cache_len = 0
            self.params = values_of(jax.jit(
                functools.partial(init_dlrm, ex=self.dlrm_extras,
                                  quant=True,
                                  table_rows=self.dlrm_extras.table_rows)
            )(jax.random.key(seed)))
        else:
            extra = cfg.meta_tokens + 8
            if cfg.family == "vlm":
                extra += cfg.n_patches
            self.cache_len = max_prompt + max_new_tokens + extra
            self.model = build_model(cfg, max_pos=self.cache_len + 8)
            self.params = values_of(jax.jit(
                lambda k: self.model.init(k, quant=True)
            )(jax.random.key(seed)))

        # ------------------------- plan lanes --------------------------------
        by_plan: Dict[str, List[TenantSpec]] = {}
        for t in tenants:
            by_plan.setdefault(t.resolved_plan().describe(), []).append(t)
        self.lanes: List[_Lane] = []
        for i, (pkey, specs) in enumerate(sorted(by_plan.items())):
            lane = _Lane(key=f"lane{i}[{specs[0].resolved_plan().name or pkey}]",
                         plan=specs[0].resolved_plan(),
                         tenants=[t.name for t in specs],
                         n_slots=n_slots)
            self._build_lane_fns(lane)
            self.lanes.append(lane)
        self._lane_of = {name: lane for lane in self.lanes
                         for name in lane.tenants}

    # ------------------------------ compiled steps ---------------------------

    def _build_lane_fns(self, lane: _Lane) -> None:
        import jax
        import jax.numpy as jnp

        from repro.protect import protect

        if self.is_dlrm:
            from repro.models.dlrm import dlrm_forward
            fwd_p = protect(
                functools.partial(dlrm_forward, ex=self.dlrm_extras),
                lane.plan, compute_dtype=self._compute_dtype)

            @jax.jit
            def forward(params, dense, bags):
                logit, rep = fwd_p(params, dense, bags)
                return logit, rep.as_metrics()

            lane.forward_fn = forward
            return

        cfg = self.cfg
        prefill_p = protect(self.model.prefill, lane.plan,
                            compute_dtype=self._compute_dtype)
        decode_p = protect(self.model.decode, lane.plan,
                           compute_dtype=self._compute_dtype)

        @jax.jit
        def prefill(params, batch):
            (logits, cache), rep = prefill_p(params, batch,
                                             cache_len=self.cache_len)
            tok = jnp.argmax(logits[..., :cfg.vocab],
                             axis=-1).astype(jnp.int32)
            return tok, cache, rep.as_metrics()

        @jax.jit
        def decode(params, cache, tokens, pos):
            (logits, new_cache), rep = decode_p(params, cache, tokens, pos)
            tok = jnp.argmax(logits[..., :cfg.vocab],
                             axis=-1).astype(jnp.int32)
            return tok, new_cache, rep.as_metrics()

        @jax.jit
        def insert(full, one, slot):
            return jax.tree.map(
                lambda f, o: jax.lax.dynamic_update_slice_in_dim(
                    f, o.astype(f.dtype), slot, axis=1), full, one)

        lane.prefill_fn = prefill
        lane.decode_fn = decode
        lane.insert_fn = insert

    # ------------------------------ request payloads -------------------------

    def _chat_batch(self, req: Request) -> dict:
        import jax.numpy as jnp
        cfg = self.cfg
        bucket = self.max_prompt            # single prompt bucket
        rng = np.random.default_rng(req.seed)
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (1, bucket)), jnp.int32)}
        if cfg.family == "vlm":
            batch["patches"] = jnp.asarray(rng.standard_normal(
                (1, cfg.n_patches, cfg.patch_dim)), jnp.float32)
        if cfg.family == "encdec":
            batch["frames"] = jnp.asarray(rng.standard_normal(
                (1, cfg.enc_seq, cfg.d_model)), jnp.float32)
        return batch

    def _prefill_pos(self) -> int:
        cfg = self.cfg
        pos = self.max_prompt + cfg.meta_tokens
        if cfg.family == "vlm":
            pos += cfg.n_patches
        return pos

    # ------------------------------ warmup -----------------------------------

    def warmup(self, sample: Optional[Request] = None) -> None:
        """Compile every lane's steps outside the telemetry clock.
        ``sample`` pins the dlrm payload shapes (jit traces by shape)."""
        import jax
        import jax.numpy as jnp

        if self._warm:
            return
        dummy = Request(rid=-1, tenant="_warm", arrival_s=0.0,
                        prompt_len=self.max_prompt, max_new_tokens=1,
                        seed=0)
        for lane in self.lanes:
            if self.is_dlrm:
                ex = self.dlrm_extras
                if sample is not None and sample.payload is not None:
                    dense = jnp.zeros(sample.payload["dense"].shape,
                                      jnp.float32)
                    bags = jnp.zeros(sample.payload["bags"].shape,
                                     jnp.int32)
                else:
                    dense = jnp.zeros((1, ex.n_dense), jnp.float32)
                    bags = jnp.zeros((ex.n_tables, 1, 1), jnp.int32)
                jax.block_until_ready(
                    lane.forward_fn(self.params, dense, bags))
                continue
            tok, cache1, _ = lane.prefill_fn(self.params,
                                             self._chat_batch(dummy))
            full = self._widened_cache(cache1, lane.n_slots)
            full = lane.insert_fn(full, cache1, 0)
            toks = jnp.zeros((lane.n_slots,), jnp.int32)
            pos = jnp.full((lane.n_slots,), self._prefill_pos(), jnp.int32)
            jax.block_until_ready(
                lane.decode_fn(self.params, full, toks, pos))
        self._warm = True

    @staticmethod
    def _widened_cache(cache1, n_slots: int):
        import jax
        import jax.numpy as jnp
        return jax.tree.map(
            lambda x: jnp.zeros((x.shape[0], n_slots) + x.shape[2:],
                                x.dtype), cache1)

    # ------------------------------ fault injection --------------------------

    def _apply_injection(self, inj: FaultInjection, telemetry: Telemetry):
        import jax

        from repro.core.inject import random_bitflip_live, victim_leaf_index

        leaves, treedef = jax.tree_util.tree_flatten(self.params)
        idx, path = victim_leaf_index(self.params, inj.victim)
        clean = leaves[idx]
        leaves[idx] = random_bitflip_live(jax.random.key(inj.seed), clean,
                                          path)
        self.params = jax.tree_util.tree_unflatten(treedef, leaves)
        self._injection_state.append((idx, clean, inj.persistent))
        telemetry.add_injection(InjectionRecord(
            step=self.global_step, victim=path, clock_s=self.clock_s,
            persistent=inj.persistent))
        if self._obs is not None:
            from repro.obs import FaultEvent
            self._obs.bus.emit(FaultEvent(
                op=path, step=self.global_step, source="serving.engine",
                kind="injection", t_s=self.clock_s,
                attrs={"persistent": inj.persistent, "seed": inj.seed}))

    def _restore_injection(self, *, include_persistent: bool = False):
        """Undo applied injections in reverse application order —
        transient ones always, persistent ones only on request
        (:meth:`reset_state`)."""
        import jax
        keep = []
        leaves, treedef = jax.tree_util.tree_flatten(self.params)
        for idx, clean, persistent in reversed(self._injection_state):
            if persistent and not include_persistent:
                keep.append((idx, clean, persistent))
                continue
            leaves[idx] = clean
        self.params = jax.tree_util.tree_unflatten(treedef, leaves)
        self._injection_state = list(reversed(keep))

    # ------------------------------ engine steps -----------------------------

    def _timed(self, fn, *args):
        import jax
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn(*args))
        dt = time.perf_counter() - t0
        self.clock_s += dt
        return out, dt

    def _record_slot(self, slot: Slot, telemetry: Telemetry,
                     aborted: bool = False):
        req = slot.request
        telemetry.add_request(RequestRecord(
            rid=req.rid, tenant=req.tenant, kind=req.kind,
            arrival_s=req.arrival_s, admit_s=slot.admit_s,
            first_token_s=slot.first_token_s, finish_s=self.clock_s,
            prompt_len=req.prompt_len, tokens_out=slot.generated,
            queue_wait_s=slot.queue_wait_s, aborted=aborted,
            tokens=getattr(slot, "token_ids", None)))

    def _step_event(self, kind: str, lane: _Lane, dt: float, metrics,
                    telemetry: Telemetry, injected: bool = False,
                    errors_override: Optional[int] = None,
                    slot_rids: tuple = ()):
        counters, errors = (_counters_of(metrics) if metrics is not None
                            else ({}, 0))
        if errors_override is not None:
            errors = errors_override
        telemetry.add_step(StepEvent(
            step=self.global_step, t_s=self.clock_s, kind=kind,
            lane=lane.key, duration_s=dt,
            occupancy=lane.batcher.occupancy(),
            queue_depth=self.queue.depth(), counters=counters,
            errors=errors, injected=injected,
            slot_rids=tuple(slot_rids)))
        if self._obs is not None:
            self._obs.tracer.add_span(
                kind, cat="serving", start_s=self.clock_s - dt, dur_s=dt,
                lane=lane.key, step=self.global_step,
                occupancy=lane.batcher.occupancy())
            self._obs.registry.counter(
                "repro_steps_total", "engine steps by kind").inc(
                    1, kind=kind, source="serving.engine")
            self._obs.registry.histogram(
                "repro_step_duration_ms",
                "engine step wall duration").observe(
                    dt * 1e3, kind=kind)
            if metrics is not None:
                from repro.protect.runtime import observe_metrics
                observe_metrics(metrics, source="serving.engine",
                                step=self.global_step, t_s=self.clock_s,
                                obs=self._obs,
                                request_ids=tuple(slot_rids))
        return errors

    def _abort_lane(self, lane: _Lane, telemetry: Telemetry, dt: float,
                    injected: bool, slot_rids: tuple = ()):
        """Policy ``abort`` fired: fail the lane's in-flight requests,
        reset the lane, keep serving."""
        for slot in lane.reset():
            self._record_slot(slot, telemetry, aborted=True)
        self._step_event("decode", lane, dt, None, telemetry,
                         injected=injected, errors_override=1,
                         slot_rids=slot_rids)

    def _do_prefill(self, lane: _Lane, slot: Slot, telemetry: Telemetry,
                    injected: bool):
        from repro.core.policy import is_fault_abort

        req = slot.request
        try:
            (tok, cache1, metrics), dt = self._timed(
                lane.prefill_fn, self.params, self._chat_batch(req))
        except Exception as e:          # noqa: BLE001 - abort policy only
            if not is_fault_abort(e):
                raise
            self.clock_s += 1e-6
            lane.batcher.retire(slot.index)
            self._record_slot(slot, telemetry, aborted=True)
            self._step_event("prefill", lane, 0.0, None, telemetry,
                             injected=injected, errors_override=1,
                             slot_rids=(req.rid,))
            return
        if lane.cache is None:
            import jax.numpy as jnp
            lane.cache = self._widened_cache(cache1, lane.n_slots)
            lane.tokens = jnp.zeros((lane.n_slots,), jnp.int32)
            lane.pos = jnp.zeros((lane.n_slots,), jnp.int32)
        lane.cache = lane.insert_fn(lane.cache, cache1, slot.index)
        lane.tokens = lane.tokens.at[slot.index].set(tok[0])
        lane.pos = lane.pos.at[slot.index].set(self._prefill_pos())
        slot.pos = self._prefill_pos()
        slot.generated = 1
        slot.first_token_s = self.clock_s
        slot.token_ids = [int(tok[0])]
        self._step_event("prefill", lane, dt, metrics, telemetry,
                         injected=injected, slot_rids=(req.rid,))

    def _do_decode(self, lane: _Lane, telemetry: Telemetry,
                   injected: bool):
        from repro.core.policy import is_fault_abort

        resident = tuple(s.request.rid
                         for s in lane.batcher.active_slots())
        try:
            (tok, cache, metrics), dt = self._timed(
                lane.decode_fn, self.params, lane.cache, lane.tokens,
                lane.pos)
        except Exception as e:          # noqa: BLE001 - abort policy only
            if not is_fault_abort(e):
                raise
            self.clock_s += 1e-6
            self._abort_lane(lane, telemetry, 0.0, injected,
                             slot_rids=resident)
            return
        lane.cache = cache
        lane.tokens = tok
        lane.pos = lane.pos + 1
        tok_host = np.asarray(tok)
        for slot in lane.batcher.active_slots():
            slot.generated += 1
            slot.pos += 1
            slot.token_ids.append(int(tok_host[slot.index]))
        self._step_event("decode", lane, dt, metrics, telemetry,
                         injected=injected, slot_rids=resident)
        for slot in lane.batcher.retire_finished():
            self._record_slot(slot, telemetry)

    def _do_dlrm(self, lane: _Lane, slot_like: Slot, telemetry: Telemetry,
                 injected: bool):
        import jax.numpy as jnp

        from repro.core.policy import is_fault_abort

        req = slot_like.request
        dense = jnp.asarray(req.payload["dense"])
        bags = jnp.asarray(req.payload["bags"])
        aborted = False
        metrics, dt = None, 0.0
        try:
            (_, metrics), dt = self._timed(
                lane.forward_fn, self.params, dense, bags)
        except Exception as e:          # noqa: BLE001 - abort policy only
            if not is_fault_abort(e):
                raise
            self.clock_s += 1e-6
            aborted = True
        slot_like.first_token_s = None if aborted else self.clock_s
        self._record_slot(slot_like, telemetry, aborted=aborted)
        self._step_event("dlrm", lane, dt, metrics, telemetry,
                         injected=injected,
                         errors_override=1 if aborted else None,
                         slot_rids=(req.rid,))

    def reset_state(self) -> None:
        """Fresh run state (clock, queue, lanes) with compiled steps kept —
        soak campaigns run a clean and a faulty pass on one engine.  Any
        still-applied (persistent) injected fault is restored."""
        if self._injection_state:
            self._restore_injection(include_persistent=True)
        self.clock_s = 0.0
        self.global_step = 0
        self.queue = AdmissionQueue(max_depth=self.queue.max_depth)
        for lane in self.lanes:
            lane.reset()

    # ------------------------------ main loop --------------------------------

    def run(self, requests: Sequence[Request], *,
            inject: Optional[Sequence[FaultInjection]] = None,
            telemetry: Optional[Telemetry] = None,
            warmup: bool = True,
            max_iterations: int = 1_000_000,
            obs=None) -> Telemetry:
        """Serve ``requests`` to completion.  ``obs`` (an
        :class:`repro.obs.Observability`) additionally lands every step's
        FaultReport counters, spans, and per-request-attributed detection
        events host-side for the duration of this run."""
        telemetry = telemetry if telemetry is not None else Telemetry()
        self._obs = obs
        pending = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
        for r in pending:
            if r.tenant not in self._lane_of:
                raise ValueError(f"request {r.rid} names unknown tenant "
                                 f"{r.tenant!r}; have "
                                 f"{sorted(self._lane_of)}")
        injections = sorted(inject or [], key=lambda i: i.step)
        inj_i = 0
        if warmup:
            self.warmup(pending[0] if pending else None)

        try:
            return self._run_loop(pending, injections, inj_i, telemetry,
                                  max_iterations)
        finally:
            self._obs = None

    def _run_loop(self, pending, injections, inj_i, telemetry,
                  max_iterations) -> Telemetry:
        i = 0
        it = 0
        while True:
            it += 1
            if it > max_iterations:
                raise RuntimeError("engine exceeded max_iterations "
                                   "(stuck request stream?)")
            # 1. arrivals whose time has come; a full bounded queue sheds
            #    load — the rejection IS the SLO story, so it is recorded
            while i < len(pending) and pending[i].arrival_s <= self.clock_s:
                req = pending[i]
                if not self.queue.push(req, self.clock_s):
                    telemetry.add_request(RequestRecord(
                        rid=req.rid, tenant=req.tenant, kind=req.kind,
                        arrival_s=req.arrival_s, admit_s=self.clock_s,
                        first_token_s=None, finish_s=self.clock_s,
                        prompt_len=req.prompt_len, tokens_out=0,
                        queue_wait_s=0.0, aborted=True, rejected=True))
                i += 1
            active = any(lane.batcher.occupancy() for lane in self.lanes)
            if not self.queue and not active:
                if i >= len(pending):
                    break
                # idle: jump the virtual clock to the next arrival
                self.clock_s = max(self.clock_s, pending[i].arrival_s)
                continue

            injected_now = (inj_i < len(injections)
                            and injections[inj_i].step <= self.global_step)
            if injected_now:
                self._apply_injection(injections[inj_i], telemetry)
                inj_i += 1

            # 2. admissions + prefills (or one-shot dlrm execution)
            for lane in self.lanes:
                for slot in lane.batcher.admit(self.queue, self.clock_s,
                                               accept=lane.accepts):
                    if slot.request.kind == "dlrm":
                        lane.batcher.retire(slot.index)
                        self._do_dlrm(lane, slot, telemetry, injected_now)
                    else:
                        self._do_prefill(lane, slot, telemetry,
                                         injected_now)
                for slot in lane.batcher.retire_finished():
                    self._record_slot(slot, telemetry)

            # 3. one decode step per lane with active slots
            for lane in self.lanes:
                if lane.batcher.occupancy():
                    self._do_decode(lane, telemetry, injected_now)

            if injected_now:
                self._restore_injection()
            self.global_step += 1

        telemetry.finalize_injections()
        return telemetry


__all__ = ["ServingEngine", "TenantSpec", "FaultInjection",
           "tenant_weights"]
