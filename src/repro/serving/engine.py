"""The protected serving engine: continuous batching over plan lanes.

:class:`ServingEngine` turns the repo's models into a request-serving
stack.  Tenants (traffic classes) carry their own
:class:`~repro.protect.ProtectionPlan` — per-tenant policies and
thresholds, the V-ABFT direction — and tenants sharing a plan share a
**lane**: one jitted prefill/decode pair compiled against that plan (the
plan rides in the jit-static ``Ctx``, so distinct plans are necessarily
distinct compiled programs) and one fixed-slot continuous batcher.

Per engine iteration:

1. arrivals whose (virtual) time has come enter the admission queue;
2. each lane fills its free decode slots FIFO from the queue and runs a
   batch=1 prefill per admission (first token = TTFT), inserting the
   request's KV state into its slot of the lane's batched cache;
3. each lane with active slots runs ONE batched decode step; detect→act
   policies run inside (recompute retries, correct, abort — an abort
   fails the lane's in-flight requests, never the server);
4. finished requests retire, freeing slots for the next iteration.

The clock is hybrid: arrivals are simulated offsets, service time is the
measured wall time of the jitted steps (compiles are excluded via
:meth:`warmup`), so SLO percentiles reflect real compute under the
chosen protection plans.

Fault injection is first-class: a :class:`FaultInjection` flips a bit in
a plan-path-addressed weight leaf right before a chosen step and — unless
``persistent`` — restores the clean weight right after it, so a
recompute-policy retry measures one *transient* upset, not a permanently
corrupted model.  Detection shows up in the same telemetry timeline as
the latency it costs.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serving.batcher import ContinuousBatcher, Slot
from repro.serving.queue import AdmissionQueue
from repro.serving.telemetry import (InjectionRecord, RequestRecord,
                                     StepEvent, Telemetry)
from repro.serving.workload import Request


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One traffic class: its protection plan and relative traffic share."""
    name: str
    plan: object = None            # ProtectionPlan (None = default_plan())
    weight: float = 1.0

    def resolved_plan(self):
        from repro.protect import default_plan
        return self.plan if self.plan is not None else default_plan()


@dataclasses.dataclass
class FaultInjection:
    """Flip one bit before global step ``step``.

    ``target="weights"`` flips a bit of a plan-path-addressed weight leaf
    (restored after the step unless ``persistent``).  ``target="kv"``
    flips one int8 payload byte of a resident request's KV cache — a
    memory-resident fault, inherently persistent until the row is
    overwritten, the page evicted, or the cache dropped
    (:meth:`ServingEngine.reset_state`); ``victim`` is ignored and the
    flip location is drawn from ``seed``."""
    step: int
    victim: Optional[str] = None   # dotted-path pattern (core.inject)
    persistent: bool = False
    seed: int = 0
    target: str = "weights"        # "weights" | "kv"


def tenant_weights(tenants: Sequence[TenantSpec]) -> Dict[str, float]:
    return {t.name: t.weight for t in tenants}


def _counters_of(metrics: dict) -> tuple:
    """(per-op int counters, total residual errors) from step metrics."""
    from repro.core.policy import op_kinds
    out: Dict[str, int] = {}
    errors = 0
    for k in op_kinds():
        c = int(metrics.get(f"abft/{k}_checks", 0))
        e = int(metrics.get(f"abft/{k}_errors", 0))
        out[f"{k}_checks"] = c
        out[f"{k}_errors"] = e
        errors += e
    out["retries"] = int(metrics.get("abft/retries", 0))
    out["corrections"] = int(metrics.get("abft/corrections", 0))
    return out, errors


class _Lane:
    """One protection plan's slice of the engine: jitted steps + batcher +
    the jax-side decode state (cache / last tokens / positions)."""

    def __init__(self, key: str, plan, tenants: List[str], n_slots: int):
        self.key = key
        self.plan = plan
        self.tenants = set(tenants)
        self.batcher = ContinuousBatcher(n_slots)
        self.n_slots = n_slots
        self.cache = None
        self.tokens = None
        self.pos = None
        self.prefill_fn = None
        self.decode_fn = None
        self.insert_fn = None
        self.forward_fn = None         # dlrm one-shot lanes
        # paged-KV lanes (engine fills these when paging is configured)
        self.pager = None              # PagedKVManager
        self.n_layers = 0
        self.table_fn = None
        self.reset_fn = None
        self.scrub_fn = None

    def accepts(self, req: Request) -> bool:
        return req.tenant in self.tenants

    def reset(self):
        """Drop all jax-side state (post-abort lane reset)."""
        self.cache = None
        self.tokens = None
        self.pos = None
        if self.pager is not None:
            self.pager.reset()
        return self.batcher.drain()


class ServingEngine:
    def __init__(self, cfg, tenants: Sequence[TenantSpec], *,
                 n_slots: int = 4, max_prompt: int = 64,
                 max_new_tokens: int = 32, queue_depth: int = 0,
                 seed: int = 0, compute_dtype=None,
                 dlrm_extras=None, paging=None):
        import jax
        import jax.numpy as jnp

        from repro.models.base import build_model
        from repro.sharding import values_of

        if not tenants:
            raise ValueError("need at least one TenantSpec")
        names = [t.name for t in tenants]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tenant names in {names}")

        self.cfg = cfg
        self.tenants = {t.name: t for t in tenants}
        self.n_slots = n_slots
        self.max_prompt = max_prompt
        self.max_new_tokens = max_new_tokens
        self.queue = AdmissionQueue(max_depth=queue_depth)
        self.clock_s = 0.0
        self.global_step = 0
        self._compute_dtype = (jnp.bfloat16 if compute_dtype is None
                               else compute_dtype)
        #: applied-injection stack: [(leaf_idx, clean_leaf, persistent)]
        #: in application order — restores pop in reverse so an earlier
        #: fault's clean copy survives a later fault on the same leaf
        self._injection_state: list = []
        self._warm = False
        #: Observability bundle for the CURRENT run (set by run(obs=...))
        self._obs = None
        #: detection-health Monitor for the CURRENT run (run(monitor=...))
        self._monitor = None
        #: AdaptiveThresholds controller bundle (run(adapt=...))
        self._adapt = None
        #: lane keys whose plan was already escalated (one-way per engine)
        self._escalated = set()

        #: PagingConfig | None — paged, prefix-shared, per-page-checksummed
        #: KV mode.  Prompts round up to page-multiple buckets, slots hold
        #: page tables into a lane-shared pool, admission runs a
        #: prefix-tree lookup, retire frees non-shared pages.
        self.paging = paging
        if paging is not None:
            if cfg.family not in ("dense", "moe"):
                raise ValueError(
                    f"paged KV serves attention-only decode caches; "
                    f"family {cfg.family!r} is not supported")
            if cfg.meta_tokens:
                raise ValueError("paged KV assumes positions start at 0 "
                                 "(meta_tokens must be 0)")
            p = paging.page_size
            self._max_bucket = -(-max_prompt // p) * p
            mp_per_slot = (self._max_bucket + max_new_tokens - 1) // p + 1
            if mp_per_slot > paging.n_pages:
                raise ValueError(
                    f"pool of {paging.n_pages} pages cannot hold even one "
                    f"slot's {mp_per_slot} pages")
        self.is_dlrm = cfg.family == "dlrm"
        if self.is_dlrm:
            from repro.configs.dlrm import EXTRAS
            self.dlrm_extras = dlrm_extras if dlrm_extras is not None \
                else EXTRAS
            from repro.models.dlrm import init_dlrm
            self.model = None
            self.cache_len = 0
            self.params = values_of(jax.jit(
                functools.partial(init_dlrm, ex=self.dlrm_extras,
                                  quant=True,
                                  table_rows=self.dlrm_extras.table_rows)
            )(jax.random.key(seed)))
        else:
            extra = cfg.meta_tokens + 8
            if cfg.family == "vlm":
                extra += cfg.n_patches
            self.cache_len = max_prompt + max_new_tokens + extra
            if paging is not None:
                self.cache_len = self._max_bucket + max_new_tokens + extra
            self.model = build_model(cfg, max_pos=self.cache_len + 8)
            self.params = values_of(jax.jit(
                lambda k: self.model.init(k, quant=True)
            )(jax.random.key(seed)))

        # ------------------------- plan lanes --------------------------------
        by_plan: Dict[str, List[TenantSpec]] = {}
        for t in tenants:
            by_plan.setdefault(t.resolved_plan().describe(), []).append(t)
        self.lanes: List[_Lane] = []
        for i, (pkey, specs) in enumerate(sorted(by_plan.items())):
            lane = _Lane(key=f"lane{i}[{specs[0].resolved_plan().name or pkey}]",
                         plan=specs[0].resolved_plan(),
                         tenants=[t.name for t in specs],
                         n_slots=n_slots)
            if paging is not None:
                from repro.paging import PagedKVManager
                p = paging.page_size
                lane.pager = PagedKVManager(
                    paging, n_slots,
                    (self._max_bucket + max_new_tokens - 1) // p + 1)
            self._build_lane_fns(lane)
            self.lanes.append(lane)
        self._lane_of = {name: lane for lane in self.lanes
                         for name in lane.tenants}

    # ------------------------------ compiled steps ---------------------------

    def _build_lane_fns(self, lane: _Lane) -> None:
        import jax
        import jax.numpy as jnp

        from repro.protect import protect

        if self.is_dlrm:
            from repro.models.dlrm import dlrm_forward
            fwd_p = protect(
                functools.partial(dlrm_forward, ex=self.dlrm_extras),
                lane.plan, compute_dtype=self._compute_dtype)

            @jax.jit
            def forward(params, dense, bags):
                logit, rep = fwd_p(params, dense, bags)
                return logit, rep.as_metrics()

            lane.forward_fn = forward
            return

        cfg = self.cfg
        prefill_p = protect(self.model.prefill, lane.plan,
                            compute_dtype=self._compute_dtype)
        decode_p = protect(self.model.decode, lane.plan,
                           compute_dtype=self._compute_dtype)

        @jax.jit
        def decode(params, cache, tokens, pos):
            (logits, new_cache), rep = decode_p(params, cache, tokens, pos)
            tok = jnp.argmax(logits[..., :cfg.vocab],
                             axis=-1).astype(jnp.int32)
            return tok, new_cache, rep.as_metrics()

        lane.decode_fn = decode

        if self.paging is not None:
            from repro.paging import (pack_prompt_pages, reset_pages,
                                      scrub_cache)

            # prefill compiles once per prompt bucket (cache_len static)
            @functools.partial(jax.jit, static_argnums=(2,))
            def prefill_paged(params, batch, cache_len):
                (logits, cache), rep = prefill_p(params, batch,
                                                 cache_len=cache_len)
                tok = jnp.argmax(logits[..., :cfg.vocab],
                                 axis=-1).astype(jnp.int32)
                return tok, cache, rep.as_metrics()

            @jax.jit
            def insert_pages(cache, one, page_ids, table):
                attn = cache["attn"]
                k = pack_prompt_pages(attn["k"], one["attn"]["k"], page_ids)
                v = pack_prompt_pages(attn["v"], one["attn"]["v"], page_ids)
                return {**cache, "attn": {"k": k._replace(table=table),
                                          "v": v._replace(table=table)}}

            @jax.jit
            def set_table(cache, table):
                attn = cache["attn"]
                return {**cache, "attn": {
                    "k": attn["k"]._replace(table=table),
                    "v": attn["v"]._replace(table=table)}}

            @jax.jit
            def reset_tail(cache, page_ids):
                attn = cache["attn"]
                return {**cache, "attn": {
                    "k": reset_pages(attn["k"], page_ids),
                    "v": reset_pages(attn["v"], page_ids)}}

            lane.prefill_fn = prefill_paged
            lane.insert_fn = insert_pages
            lane.table_fn = set_table
            lane.reset_fn = reset_tail
            lane.scrub_fn = jax.jit(scrub_cache)
            return

        @jax.jit
        def prefill(params, batch):
            (logits, cache), rep = prefill_p(params, batch,
                                             cache_len=self.cache_len)
            tok = jnp.argmax(logits[..., :cfg.vocab],
                             axis=-1).astype(jnp.int32)
            return tok, cache, rep.as_metrics()

        @jax.jit
        def insert(full, one, slot):
            return jax.tree.map(
                lambda f, o: jax.lax.dynamic_update_slice_in_dim(
                    f, o.astype(f.dtype), slot, axis=1), full, one)

        lane.prefill_fn = prefill
        lane.insert_fn = insert

    # ------------------------------ request payloads -------------------------

    def _chat_tokens(self, req: Request, bucket: int,
                     rng=None) -> np.ndarray:
        """The request's deterministic prompt tokens, padded to ``bucket``.

        A request carrying (prefix_seed, prefix_len) opens with the shared
        system prompt — byte-identical across every request with the same
        prefix seed, which is what the paged prefix tree keys on; the
        suffix (and padding) comes from the request's own seed."""
        cfg = self.cfg
        rng = np.random.default_rng(req.seed) if rng is None else rng
        pfx = min(int(req.prefix_len or 0), bucket)
        if pfx > 0 and req.prefix_seed is not None:
            head = np.random.default_rng(req.prefix_seed).integers(
                0, cfg.vocab, pfx)
            return np.concatenate(
                [head, rng.integers(0, cfg.vocab, bucket - pfx)])
        return rng.integers(0, cfg.vocab, bucket)

    def _chat_batch(self, req: Request) -> dict:
        import jax.numpy as jnp
        cfg = self.cfg
        bucket = self.max_prompt            # single prompt bucket
        rng = np.random.default_rng(req.seed)
        batch = {"tokens": jnp.asarray(
            self._chat_tokens(req, bucket, rng)[None, :], jnp.int32)}
        if cfg.family == "vlm":
            batch["patches"] = jnp.asarray(rng.standard_normal(
                (1, cfg.n_patches, cfg.patch_dim)), jnp.float32)
        if cfg.family == "encdec":
            batch["frames"] = jnp.asarray(rng.standard_normal(
                (1, cfg.enc_seq, cfg.d_model)), jnp.float32)
        return batch

    def _prefill_pos(self) -> int:
        cfg = self.cfg
        pos = self.max_prompt + cfg.meta_tokens
        if cfg.family == "vlm":
            pos += cfg.n_patches
        return pos

    # ------------------------------ warmup -----------------------------------

    def warmup(self, sample: Optional[Request] = None) -> None:
        """Compile every lane's steps outside the telemetry clock.
        ``sample`` pins the dlrm payload shapes (jit traces by shape)."""
        import jax
        import jax.numpy as jnp

        if self._warm:
            return
        dummy = Request(rid=-1, tenant="_warm", arrival_s=0.0,
                        prompt_len=self.max_prompt, max_new_tokens=1,
                        seed=0)
        for lane in self.lanes:
            if self.is_dlrm:
                ex = self.dlrm_extras
                if sample is not None and sample.payload is not None:
                    dense = jnp.zeros(sample.payload["dense"].shape,
                                      jnp.float32)
                    bags = jnp.zeros(sample.payload["bags"].shape,
                                     jnp.int32)
                else:
                    dense = jnp.zeros((1, ex.n_dense), jnp.float32)
                    bags = jnp.zeros((ex.n_tables, 1, 1), jnp.int32)
                jax.block_until_ready(
                    lane.forward_fn(self.params, dense, bags))
                continue
            if lane.pager is not None:
                self._warmup_paged(lane, dummy)
                continue
            tok, cache1, _ = lane.prefill_fn(self.params,
                                             self._chat_batch(dummy))
            full = self._widened_cache(cache1, lane.n_slots)
            full = lane.insert_fn(full, cache1, 0)
            toks = jnp.zeros((lane.n_slots,), jnp.int32)
            pos = jnp.full((lane.n_slots,), self._prefill_pos(), jnp.int32)
            jax.block_until_ready(
                lane.decode_fn(self.params, full, toks, pos))
        self._warm = True

    def _warmup_paged(self, lane: _Lane, dummy: Request) -> None:
        """Compile the paged lane's steps against throwaway pool state
        (the allocator/tree are untouched: slot 0's warmup pages live in
        a synthetic table that is discarded afterwards).  Only the
        ``max_prompt`` bucket's prefill/insert compile here; smaller
        buckets compile lazily on first admission."""
        import jax
        import jax.numpy as jnp

        p = self.paging.page_size
        bucket = self._max_bucket
        nc = bucket // p
        batch = {"tokens": jnp.asarray(
            self._chat_tokens(dummy, bucket)[None, :], jnp.int32)}
        tok, cache1, _ = lane.prefill_fn(self.params, batch, bucket)
        if lane.cache is None:
            self._init_paged_cache(lane, cache1)
        tb = np.full((lane.n_slots, lane.pager.max_pages), -1, np.int32)
        tb[0, :nc + 1] = np.arange(nc + 1)
        tdev = jnp.broadcast_to(jnp.asarray(tb),
                                (lane.n_layers,) + tb.shape)
        cache = lane.insert_fn(lane.cache, cache1,
                               jnp.arange(nc, dtype=jnp.int32), tdev)
        cache = lane.reset_fn(cache, self._reset_vec(lane, [nc]))
        toks = jnp.zeros((lane.n_slots,), jnp.int32)
        pos = jnp.full((lane.n_slots,), bucket, jnp.int32)
        jax.block_until_ready(
            lane.decode_fn(self.params, cache, toks, pos))
        jax.block_until_ready(lane.scrub_fn(cache, pos))
        jax.block_until_ready(lane.table_fn(cache, tdev))

    @staticmethod
    def _widened_cache(cache1, n_slots: int):
        import jax
        import jax.numpy as jnp
        return jax.tree.map(
            lambda x: jnp.zeros((x.shape[0], n_slots) + x.shape[2:],
                                x.dtype), cache1)

    # ------------------------------ paged-KV state ---------------------------

    def _init_paged_cache(self, lane: _Lane, cache1) -> None:
        """Size the lane's page pools from the first prefill's cache
        shapes and zero the decode-side state."""
        import jax.numpy as jnp

        from repro.core import QuantKV
        from repro.paging import paged_pool

        if set(cache1) != {"attn"}:
            raise ValueError(f"paged KV expects an attention-only cache; "
                             f"got entries {sorted(cache1)}")
        leaf = cache1["attn"]["k"]
        arr = leaf.q if isinstance(leaf, QuantKV) else leaf
        ell, _, kvh, _, dh = arr.shape
        lane.n_layers = ell
        pg = self.paging
        pool = paged_pool(pg.n_pages, kvh, pg.page_size, dh,
                          lane.n_slots, lane.pager.max_pages, n_layers=ell)
        lane.cache = {"attn": {"k": pool, "v": pool}}
        lane.tokens = jnp.zeros((lane.n_slots,), jnp.int32)
        lane.pos = jnp.zeros((lane.n_slots,), jnp.int32)

    def _table_dev(self, lane: _Lane):
        """The manager's host table broadcast to the stacked-layer shape
        (one page id names the same pool row in every layer)."""
        import jax.numpy as jnp
        t = jnp.asarray(lane.pager.table)
        return jnp.broadcast_to(t, (lane.n_layers,) + t.shape)

    def _reset_vec(self, lane: _Lane, page_ids):
        """Fixed-length page-id vector (sentinel-padded) so reset_pages
        compiles once regardless of how many pages need zeroing."""
        import jax.numpy as jnp
        vec = np.full((lane.n_slots,), self.paging.n_pages, np.int32)
        vec[:len(page_ids)] = page_ids
        return jnp.asarray(vec)

    def _bucket_of(self, req: Request) -> int:
        p = self.paging.page_size
        return min(self._max_bucket, -(-max(int(req.prompt_len), 1) // p) * p)

    def _abort_slot(self, lane: _Lane, slot: Slot, telemetry: Telemetry):
        """Fail ONE request (pool exhausted / unrebuildable page) and free
        its slot + pages; the lane keeps serving."""
        lane.pager.retire(slot.index)
        lane.batcher.retire(slot.index)
        self._record_slot(slot, telemetry, aborted=True)

    def _publish_paging(self, lane: _Lane) -> None:
        if self._obs is None or lane.pager is None:
            return
        st = lane.pager.stats()
        g = self._obs.registry.gauge
        g("repro_paging_pages_resident",
          "allocated pages in the lane pool").set(
              st["pages_resident"], lane=lane.key)
        g("repro_paging_pages_free", "free pages in the lane pool").set(
            st["pages_free"], lane=lane.key)
        g("repro_paging_pages_shared",
          "pages referenced by more than one holder").set(
              st["pages_shared"], lane=lane.key)
        g("repro_paging_pages_high_water",
          "peak allocated pages since reset").set(
              st["pages_high_water"], lane=lane.key)
        g("repro_paging_prefix_hit_rate",
          "prompt chunks served from shared pages").set(
              st["prefix_hit_rate"], lane=lane.key)
        g("repro_paging_page_evictions",
          "pages evicted (LRU pressure + corrupt)").set(
              st["page_evictions"], lane=lane.key)
        g("repro_paging_page_rebuilds",
          "prompt re-prefills after corrupt-page eviction").set(
              st["page_rebuilds"], lane=lane.key)

    def _paging_event(self, action: str, lane: _Lane, *,
                      dur_s: float = 0.0, **attrs) -> None:
        """One paged-KV lifecycle operation (admit / evict_corrupt /
        rebuild / scrub_cache): a tracer span, a
        ``repro_paging_ops_total{action,lane}`` inc, and a typed
        ``info``/``channel=paging`` event — so page-fault response is
        visible in Chrome traces and replayable from the JSONL."""
        if self._obs is None:
            return
        from repro.obs import FaultEvent
        self._obs.tracer.add_span(
            f"paged_{action}", cat="paging",
            start_s=self.clock_s - dur_s, dur_s=dur_s, lane=lane.key,
            step=self.global_step, **attrs)
        self._obs.registry.counter(
            "repro_paging_ops_total",
            "paged-KV lifecycle operations by action and lane").inc(
                1, action=action, lane=lane.key)
        rid = attrs.get("rid")
        self._obs.bus.emit(FaultEvent(
            op=action, step=self.global_step, source="serving.engine",
            kind="info", t_s=self.clock_s,
            request_ids=(int(rid),) if rid is not None else (),
            attrs={"channel": "paging", "action": action,
                   "lane": lane.key, **attrs}))

    def paging_stats(self) -> Dict[str, dict]:
        """Per-lane paging stats + byte accounting (campaign metrics)."""
        from repro.paging import pool_page_bytes
        out = {}
        for lane in self.lanes:
            if lane.pager is None:
                continue
            st = lane.pager.stats()
            if lane.cache is not None:
                attn = lane.cache["attn"]
                per_page = (pool_page_bytes(attn["k"])
                            + pool_page_bytes(attn["v"]))
                st["page_bytes"] = per_page
                st["peak_resident_bytes"] = \
                    st["pages_high_water"] * per_page
            out[lane.key] = st
        return out

    # ------------------------------ fault injection --------------------------

    def _apply_injection(self, inj: FaultInjection, telemetry: Telemetry):
        import jax

        from repro.core.inject import random_bitflip_live, victim_leaf_index

        if inj.target == "kv":
            self._apply_kv_injection(inj, telemetry)
            return
        if inj.target != "weights":
            raise ValueError(f"unknown injection target {inj.target!r}; "
                             f"have ('weights', 'kv')")
        leaves, treedef = jax.tree_util.tree_flatten(self.params)
        idx, path = victim_leaf_index(self.params, inj.victim)
        clean = leaves[idx]
        leaves[idx] = random_bitflip_live(jax.random.key(inj.seed), clean,
                                          path)
        self.params = jax.tree_util.tree_unflatten(treedef, leaves)
        self._injection_state.append((idx, clean, inj.persistent))
        telemetry.add_injection(InjectionRecord(
            step=self.global_step, victim=path, clock_s=self.clock_s,
            persistent=inj.persistent))
        if self._obs is not None:
            from repro.obs import FaultEvent
            self._obs.registry.counter(
                "repro_injections_total",
                "injected faults by source").inc(1,
                                                 source="serving.engine")
            self._obs.bus.emit(FaultEvent(
                op=path, step=self.global_step, source="serving.engine",
                kind="injection", t_s=self.clock_s,
                attrs={"persistent": inj.persistent, "seed": inj.seed}))

    def _apply_kv_injection(self, inj: FaultInjection,
                            telemetry: Telemetry) -> bool:
        """Flip one int8 KV payload bit of a resident request's prompt
        region — paged lanes flip inside a mapped prompt page, contiguous
        quantized lanes inside the prompt rows of the victim's slot.  The
        flip is memory-resident (no restore entry): it persists until the
        page is evicted/rebuilt or the cache is dropped.  Returns False
        (and records nothing) when no lane holds flippable state."""
        import jax.numpy as jnp

        from repro.core import QuantKV

        rng = np.random.default_rng(inj.seed)
        lanes = [ln for ln in self.lanes
                 if ln.cache is not None and ln.batcher.occupancy()
                 and not self.is_dlrm]
        if not lanes:
            return False
        lane = lanes[int(rng.integers(len(lanes)))]
        slots = lane.batcher.active_slots()
        slot = slots[int(rng.integers(len(slots)))]
        pool_name = "k" if int(rng.integers(2)) == 0 else "v"
        leaf = lane.cache["attn"][pool_name]
        bit = int(rng.integers(8))
        mask = jnp.int8((1 << bit) if bit < 7 else -128)
        if lane.pager is not None:
            chunks = [c for c in
                      range(lane.pager.prompt_chunks[slot.index])
                      if lane.pager.table[slot.index, c] >= 0]
            if not chunks:
                return False
            chunk = chunks[int(rng.integers(len(chunks)))]
            pid = int(lane.pager.table[slot.index, chunk])
            ell, _, kvh, pgs, dh = leaf.q.shape
            idx = (int(rng.integers(ell)), pid, int(rng.integers(kvh)),
                   int(rng.integers(pgs)), int(rng.integers(dh)))
            victim = (f"kv_page/{pool_name}/page{pid}"
                      f"/l{idx[0]}h{idx[2]}r{idx[3]}d{idx[4]}b{bit}")
        elif isinstance(leaf, QuantKV):
            ell, _, kvh, _, dh = leaf.q.shape
            row = int(rng.integers(min(slot.pos, self.max_prompt)))
            idx = (int(rng.integers(ell)), slot.index,
                   int(rng.integers(kvh)), row, int(rng.integers(dh)))
            victim = (f"kv_row/{pool_name}/slot{slot.index}"
                      f"/l{idx[0]}h{idx[2]}r{row}d{idx[4]}b{bit}")
        else:
            return False                  # bf16 cache: nothing checksummed
        newq = leaf.q.at[idx].set(leaf.q[idx] ^ mask)
        lane.cache = {**lane.cache, "attn": {
            **lane.cache["attn"], pool_name: leaf._replace(q=newq)}}
        telemetry.add_injection(InjectionRecord(
            step=self.global_step, victim=victim, clock_s=self.clock_s,
            persistent=True))
        if self._obs is not None:
            from repro.obs import FaultEvent
            self._obs.registry.counter(
                "repro_injections_total",
                "injected faults by source").inc(1,
                                                 source="serving.engine")
            self._obs.bus.emit(FaultEvent(
                op=victim, step=self.global_step, source="serving.engine",
                kind="injection", t_s=self.clock_s,
                attrs={"persistent": True, "seed": inj.seed,
                       "target": "kv"}))
        return True

    def _restore_injection(self, *, include_persistent: bool = False):
        """Undo applied injections in reverse application order —
        transient ones always, persistent ones only on request
        (:meth:`reset_state`)."""
        import jax
        keep = []
        leaves, treedef = jax.tree_util.tree_flatten(self.params)
        for idx, clean, persistent in reversed(self._injection_state):
            if persistent and not include_persistent:
                keep.append((idx, clean, persistent))
                continue
            leaves[idx] = clean
        self.params = jax.tree_util.tree_unflatten(treedef, leaves)
        self._injection_state = list(reversed(keep))

    # ------------------------------ engine steps -----------------------------

    def _timed(self, fn, *args):
        import jax
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn(*args))
        dt = time.perf_counter() - t0
        self.clock_s += dt
        return out, dt

    def _record_slot(self, slot: Slot, telemetry: Telemetry,
                     aborted: bool = False):
        req = slot.request
        telemetry.add_request(RequestRecord(
            rid=req.rid, tenant=req.tenant, kind=req.kind,
            arrival_s=req.arrival_s, admit_s=slot.admit_s,
            first_token_s=slot.first_token_s, finish_s=self.clock_s,
            prompt_len=req.prompt_len, tokens_out=slot.generated,
            queue_wait_s=slot.queue_wait_s, aborted=aborted,
            tokens=getattr(slot, "token_ids", None),
            prefill_tokens=slot.prefill_tokens,
            shared_prefix_tokens=slot.shared_prefix_tokens))

    def _step_event(self, kind: str, lane: _Lane, dt: float, metrics,
                    telemetry: Telemetry, injected: bool = False,
                    errors_override: Optional[int] = None,
                    slot_rids: tuple = ()):
        counters, errors = (_counters_of(metrics) if metrics is not None
                            else ({}, 0))
        if errors_override is not None:
            errors = errors_override
        telemetry.add_step(StepEvent(
            step=self.global_step, t_s=self.clock_s, kind=kind,
            lane=lane.key, duration_s=dt,
            occupancy=lane.batcher.occupancy(),
            queue_depth=self.queue.depth(), counters=counters,
            errors=errors, injected=injected,
            slot_rids=tuple(slot_rids)))
        if self._obs is not None:
            self._obs.tracer.add_span(
                kind, cat="serving", start_s=self.clock_s - dt, dur_s=dt,
                lane=lane.key, step=self.global_step,
                occupancy=lane.batcher.occupancy())
            self._obs.registry.counter(
                "repro_steps_total", "engine steps by kind").inc(
                    1, kind=kind, source="serving.engine")
            self._obs.registry.histogram(
                "repro_step_duration_ms",
                "engine step wall duration").observe(
                    dt * 1e3, kind=kind)
            if metrics is not None:
                from repro.protect.runtime import observe_metrics
                observe_metrics(
                    metrics, source="serving.engine",
                    step=self.global_step, t_s=self.clock_s,
                    obs=self._obs, request_ids=tuple(slot_rids),
                    attrs={"kind": kind, "lane": lane.key,
                           "duration_ms": dt * 1e3,
                           "tenants": sorted({
                               s.request.tenant
                               for s in lane.batcher.active_slots()})})
        return errors

    def _abort_lane(self, lane: _Lane, telemetry: Telemetry, dt: float,
                    injected: bool, slot_rids: tuple = ()):
        """Policy ``abort`` fired: fail the lane's in-flight requests,
        reset the lane, keep serving."""
        for slot in lane.reset():
            self._record_slot(slot, telemetry, aborted=True)
        self._step_event("decode", lane, dt, None, telemetry,
                         injected=injected, errors_override=1,
                         slot_rids=slot_rids)

    def _do_prefill(self, lane: _Lane, slot: Slot, telemetry: Telemetry,
                    injected: bool):
        from repro.core.policy import is_fault_abort

        if lane.pager is not None:
            self._do_prefill_paged(lane, slot, telemetry, injected)
            return
        req = slot.request
        try:
            (tok, cache1, metrics), dt = self._timed(
                lane.prefill_fn, self.params, self._chat_batch(req))
        except Exception as e:          # noqa: BLE001 - abort policy only
            if not is_fault_abort(e):
                raise
            self.clock_s += 1e-6
            lane.batcher.retire(slot.index)
            self._record_slot(slot, telemetry, aborted=True)
            self._step_event("prefill", lane, 0.0, None, telemetry,
                             injected=injected, errors_override=1,
                             slot_rids=(req.rid,))
            return
        if lane.cache is None:
            import jax.numpy as jnp
            lane.cache = self._widened_cache(cache1, lane.n_slots)
            lane.tokens = jnp.zeros((lane.n_slots,), jnp.int32)
            lane.pos = jnp.zeros((lane.n_slots,), jnp.int32)
        lane.cache = lane.insert_fn(lane.cache, cache1, slot.index)
        lane.tokens = lane.tokens.at[slot.index].set(tok[0])
        lane.pos = lane.pos.at[slot.index].set(self._prefill_pos())
        slot.pos = self._prefill_pos()
        slot.generated = 1
        slot.first_token_s = self.clock_s
        slot.token_ids = [int(tok[0])]
        slot.prefill_tokens = self.max_prompt   # full fixed-slot bucket
        self._step_event("prefill", lane, dt, metrics, telemetry,
                         injected=injected, slot_rids=(req.rid,))

    def _do_prefill_paged(self, lane: _Lane, slot: Slot,
                          telemetry: Telemetry, injected: bool):
        """Paged admission: prefix-tree lookup, page-bucketed prefill,
        pack the non-shared pages, allocate the first decode-tail page."""
        import jax.numpy as jnp

        from repro.core.policy import is_fault_abort

        req = slot.request
        pager = lane.pager
        p = self.paging.page_size
        bucket = self._bucket_of(req)
        tokens = self._chat_tokens(req, bucket)
        plan = pager.admit(slot.index, tokens)
        if not plan.ok:                      # pool exhausted: shed it
            lane.batcher.retire(slot.index)
            self._record_slot(slot, telemetry, aborted=True)
            return
        batch = {"tokens": jnp.asarray(tokens[None, :], jnp.int32)}
        try:
            (tok, cache1, metrics), dt = self._timed(
                lane.prefill_fn, self.params, batch, bucket)
        except Exception as e:          # noqa: BLE001 - abort policy only
            if not is_fault_abort(e):
                raise
            self.clock_s += 1e-6
            self._abort_slot(lane, slot, telemetry)
            self._step_event("prefill", lane, 0.0, None, telemetry,
                             injected=injected, errors_override=1,
                             slot_rids=(req.rid,))
            return
        if lane.cache is None:
            self._init_paged_cache(lane, cache1)
        tail = pager.decode_page(slot.index, bucket // p)
        if tail is None:
            self._abort_slot(lane, slot, telemetry)
            return
        lane.cache = lane.insert_fn(lane.cache, cache1,
                                    jnp.asarray(plan.page_ids),
                                    self._table_dev(lane))
        lane.cache = lane.reset_fn(lane.cache, self._reset_vec(lane,
                                                               [tail]))
        lane.tokens = lane.tokens.at[slot.index].set(tok[0])
        lane.pos = lane.pos.at[slot.index].set(bucket)
        slot.pos = bucket
        slot.generated = 1
        slot.first_token_s = self.clock_s
        slot.token_ids = [int(tok[0])]
        slot.bucket = bucket
        slot.prefill_tokens, slot.shared_prefix_tokens = plan.tokens(p)
        self._paging_event("admit", lane, slot=slot.index, rid=req.rid,
                           bucket=bucket, pages=len(plan.page_ids),
                           shared_pages=plan.shared_pages,
                           new_pages=plan.new_pages)
        self._step_event("prefill", lane, dt, metrics, telemetry,
                         injected=injected, slot_rids=(req.rid,))
        self._publish_paging(lane)

    def _do_decode(self, lane: _Lane, telemetry: Telemetry,
                   injected: bool):
        from repro.core.policy import is_fault_abort

        if lane.pager is not None:
            self._paged_pre_decode(lane, telemetry)
            if not lane.batcher.occupancy():
                return
        resident = tuple(s.request.rid
                         for s in lane.batcher.active_slots())
        try:
            (tok, cache, metrics), dt = self._timed(
                lane.decode_fn, self.params, lane.cache, lane.tokens,
                lane.pos)
        except Exception as e:          # noqa: BLE001 - abort policy only
            if not is_fault_abort(e):
                raise
            self.clock_s += 1e-6
            self._abort_lane(lane, telemetry, 0.0, injected,
                             slot_rids=resident)
            return
        lane.cache = cache
        lane.tokens = tok
        lane.pos = lane.pos + 1
        tok_host = np.asarray(tok)
        for slot in lane.batcher.active_slots():
            slot.generated += 1
            slot.pos += 1
            slot.token_ids.append(int(tok_host[slot.index]))
        errors = self._step_event("decode", lane, dt, metrics, telemetry,
                                  injected=injected, slot_rids=resident)
        if lane.pager is not None and errors > 0:
            policy = lane.plan.resolve("kv_cache_paged", "attn").policy
            if int(metrics.get("abft/kv_cache_paged_errors", 0)) > 0 \
                    and policy != "log":
                self._paged_repair(lane, telemetry, policy)
        for slot in lane.batcher.retire_finished():
            if lane.pager is not None:
                lane.pager.retire(slot.index)
            self._record_slot(slot, telemetry)
        self._publish_paging(lane)

    def _paged_pre_decode(self, lane: _Lane, telemetry: Telemetry):
        """Before a paged decode step: allocate decode-tail pages for
        slots crossing a page boundary (aborting the owner if the pool is
        truly full), zero them, and push the current page table."""
        pager = lane.pager
        p = self.paging.page_size
        fresh = []
        for slot in list(lane.batcher.active_slots()):
            chunk = slot.pos // p
            if slot.pos % p == 0 and pager.table[slot.index, chunk] < 0:
                pid = pager.decode_page(slot.index, chunk)
                if pid is None:
                    self._abort_slot(lane, slot, telemetry)
                    continue
                fresh.append(pid)
        if not lane.batcher.occupancy():
            return
        if fresh:
            lane.cache = lane.reset_fn(lane.cache,
                                       self._reset_vec(lane, fresh))
        lane.cache = lane.table_fn(lane.cache, self._table_dev(lane))

    def _paged_repair(self, lane: _Lane, telemetry: Telemetry,
                      policy: str):
        """Detect→act for paged KV, host-side: scrub the pool, map the
        flagged (slot, chunk) pairs to pages, then per the plan policy
        evict + rebuild shared/prompt pages via re-prefill
        (``recompute``/``correct``) or abort the owning request
        (``abort`` — and always for an unrebuildable decode-tail page).
        Only the touched requests pay; the lane keeps serving."""
        pager = lane.pager
        t0 = time.perf_counter()
        flags = lane.scrub_fn(lane.cache, lane.pos)
        bad = np.asarray(flags["k"]) + np.asarray(flags["v"])
        self._paging_event("scrub_cache", lane,
                           dur_s=time.perf_counter() - t0,
                           flagged=int((bad > 0).sum()), policy=policy)
        for slot in list(lane.batcher.active_slots()):
            chunks = [int(c) for c in np.nonzero(bad[slot.index])[0]]
            if not chunks:
                continue
            rebuild = policy != "abort"
            if rebuild:
                for c in chunks:
                    if not pager.evict_corrupt(slot.index, c):
                        rebuild = False      # corrupt decode-tail page
                self._paging_event(
                    "evict_corrupt", lane, slot=slot.index,
                    rid=slot.request.rid, chunks=chunks,
                    rebuildable=rebuild)
            if not (rebuild and self._rebuild_prompt(lane, slot,
                                                     telemetry)):
                self._abort_slot(lane, slot, telemetry)
        if lane.batcher.occupancy():
            lane.cache = lane.table_fn(lane.cache, self._table_dev(lane))

    def _rebuild_prompt(self, lane: _Lane, slot: Slot,
                        telemetry: Telemetry) -> bool:
        """Re-prefill a slot's prompt onto fresh pages after a corrupt
        prompt page was evicted; decode-tail pages (the generated KV)
        survive untouched.  Returns False when the pool cannot hold the
        rebuilt pages or the re-prefill itself aborts."""
        import jax.numpy as jnp

        from repro.core.policy import is_fault_abort

        pager = lane.pager
        req = slot.request
        bucket = slot.bucket
        tokens = self._chat_tokens(req, bucket)
        pager.release_prompt(slot.index)
        plan = pager.readmit(slot.index, tokens)
        if not plan.ok:
            return False
        batch = {"tokens": jnp.asarray(tokens[None, :], jnp.int32)}
        try:
            (_, cache1, metrics), dt = self._timed(
                lane.prefill_fn, self.params, batch, bucket)
        except Exception as e:          # noqa: BLE001 - abort policy only
            if not is_fault_abort(e):
                raise
            self.clock_s += 1e-6
            return False
        lane.cache = lane.insert_fn(lane.cache, cache1,
                                    jnp.asarray(plan.page_ids),
                                    self._table_dev(lane))
        self._paging_event("rebuild", lane, dur_s=dt, slot=slot.index,
                           rid=req.rid, pages=len(plan.page_ids))
        self._step_event("rebuild", lane, dt, metrics, telemetry,
                         slot_rids=(req.rid,))
        return True

    def _do_dlrm(self, lane: _Lane, slot_like: Slot, telemetry: Telemetry,
                 injected: bool):
        import jax.numpy as jnp

        from repro.core.policy import is_fault_abort

        req = slot_like.request
        dense = jnp.asarray(req.payload["dense"])
        bags = jnp.asarray(req.payload["bags"])
        aborted = False
        metrics, dt = None, 0.0
        try:
            (_, metrics), dt = self._timed(
                lane.forward_fn, self.params, dense, bags)
        except Exception as e:          # noqa: BLE001 - abort policy only
            if not is_fault_abort(e):
                raise
            self.clock_s += 1e-6
            aborted = True
        slot_like.first_token_s = None if aborted else self.clock_s
        self._record_slot(slot_like, telemetry, aborted=aborted)
        self._step_event("dlrm", lane, dt, metrics, telemetry,
                         injected=injected,
                         errors_override=1 if aborted else None,
                         slot_rids=(req.rid,))

    def reset_state(self) -> None:
        """Fresh run state (clock, queue, lanes) with compiled steps kept —
        soak campaigns run a clean and a faulty pass on one engine.  Any
        still-applied (persistent) injected fault is restored."""
        if self._injection_state:
            self._restore_injection(include_persistent=True)
        self.clock_s = 0.0
        self.global_step = 0
        self.queue = AdmissionQueue(max_depth=self.queue.max_depth)
        for lane in self.lanes:
            lane.reset()

    # ------------------------------ monitor responses ------------------------

    def _admits(self, lane: _Lane):
        """The lane's admission predicate, gated by tenant health when a
        monitor is attached (quarantined tenants only pass as recovery
        probes)."""
        if self._monitor is None:
            return lane.accepts
        mon = self._monitor
        return lambda req: (lane.accepts(req)
                            and mon.admission_allowed(req.tenant))

    def _health_action(self, action: str, scope: str,
                       lane: _Lane) -> None:
        """Record one applied engine response (quarantine / escalate /
        scrub / recover) as a counter + typed health event, so the
        response is visible from the JSONL alone."""
        if self._obs is None:
            return
        from repro.obs import FaultEvent
        self._obs.registry.counter(
            "repro_health_actions_total",
            "engine responses to health transitions").inc(
                1, action=action, scope=scope)
        self._obs.bus.emit(FaultEvent(
            op="health", step=self.global_step, source="serving.engine",
            kind="health", t_s=self.clock_s,
            attrs={"scope": scope, "action": action, "lane": lane.key}))

    def _escalate_lane(self, lane: _Lane) -> bool:
        """Upgrade the lane's plan detect→act policies (``log`` →
        ``recompute``) and re-jit its steps; one-way per engine.  The
        escalated plan changes no op enablement, so cache/batch structure
        is stable across the swap."""
        if lane.key in self._escalated:
            return False
        lane.plan = lane.plan.escalated()
        self._build_lane_fns(lane)
        self._escalated.add(lane.key)
        return True

    def _apply_monitor_responses(self, telemetry: Telemetry) -> None:
        """Drain the monitor's health transitions and apply the
        configured responses to the owning tenant lanes."""
        mon = self._monitor
        from repro.obs.health import HEALTH_STATES
        for tr in mon.poll_transitions():
            if not tr.scope.startswith("tenant:"):
                continue
            tenant = tr.scope.split(":", 1)[1]
            lane = self._lane_of.get(tenant)
            if lane is None:
                continue
            worse = (HEALTH_STATES.index(tr.new)
                     > HEALTH_STATES.index(tr.old))
            if not worse:
                self._health_action("recover", tr.scope, lane)
                continue
            if tr.new == "quarantined" and mon.responses.quarantine:
                self._health_action("quarantine", tr.scope, lane)
            if mon.responses.escalate and self._escalate_lane(lane):
                self._health_action("escalate", tr.scope, lane)
            if mon.responses.scrub and lane.pager is not None \
                    and lane.cache is not None:
                self._paged_repair(lane, telemetry, "recompute")
                self._health_action("scrub", tr.scope, lane)

    # ------------------------------ adaptive thresholds ----------------------

    def _register_adaptive(self) -> None:
        """One controller per (op, tenant) whose lane plan opts the op
        into ``threshold=adaptive``, seeded from the plan's resolved
        ``rel_bound`` (or the op default) unless the caller pre-seeded
        the controller (e.g. from ``calibrate_from_sweep``)."""
        from repro.adapt import _op_default_bound
        from repro.core.policy import op_kinds
        for lane in self.lanes:
            for op in op_kinds():
                r = lane.plan.resolve(op)
                if not (r.enabled and r.threshold == "adaptive"):
                    continue
                current = (r.rel_bound if r.rel_bound is not None
                           else _op_default_bound(op))
                for tenant in sorted(lane.tenants):
                    c = self._adapt.manage(op, tenant,
                                           rel_bound=r.rel_bound)
                    # the lane compiles against the controller's bound
                    # (which may predate this run via calibration)
                    if c.rel_bound != current:
                        self._apply_bound(op, tenant, c.rel_bound)

    def _apply_bound(self, op: str, tenant: str, bound: float) -> None:
        """Rewrite one tenant lane's plan with the controller's bound
        and re-jit — the ``_escalate_lane`` precedent.  Hysteresis +
        cooldown keep moves (and hence recompiles) rare."""
        lane = self._lane_of.get(tenant)
        if lane is None:
            return
        from repro.protect.plan import OpRule
        lane.plan = lane.plan.with_rules(
            OpRule(pattern=op, rel_bound=float(bound)))
        self._build_lane_fns(lane)

    def _apply_adaptive(self) -> None:
        if self._adapt is None or self._monitor is None:
            return
        moved = self._adapt.tick(self._monitor, t_s=self.clock_s,
                                 step=self.global_step)
        for (op, tenant), bound in moved.items():
            self._apply_bound(op, tenant, bound)

    # ------------------------------ main loop --------------------------------

    def run(self, requests: Sequence[Request], *,
            inject: Optional[Sequence[FaultInjection]] = None,
            telemetry: Optional[Telemetry] = None,
            warmup: bool = True,
            max_iterations: int = 1_000_000,
            obs=None, monitor=None, adapt=None) -> Telemetry:
        """Serve ``requests`` to completion.  ``obs`` (an
        :class:`repro.obs.Observability`) additionally lands every step's
        FaultReport counters, spans, and per-request-attributed detection
        events host-side for the duration of this run.

        ``monitor`` (a :class:`repro.obs.Monitor`) closes the loop: it is
        bound to ``obs`` (one is created if the caller passed none), fed
        by the engine's step summaries over the bus, and its health
        transitions trigger real responses between iterations — gate a
        quarantined tenant's admissions (with recovery probes), escalate
        the lane's ProtectionPlan (``log`` → ``recompute``), and schedule
        a paged-KV scrub+repair.  The monitor's summary lands on the
        returned telemetry.

        ``adapt`` (a :class:`repro.adapt.AdaptiveThresholds`) closes the
        *threshold* loop on top of the monitor: lanes whose plan marks
        an op ``threshold=adaptive`` get one FP-budget controller per
        (op, tenant) which reads the monitor's Wilson flag-rate estimate
        each iteration and rewrites the lane's ``rel_bound`` (plan
        rewrite + re-jit) when it moves; requires ``monitor``."""
        telemetry = telemetry if telemetry is not None else Telemetry()
        if adapt is not None and monitor is None:
            raise ValueError("adapt= needs monitor= (its sensor)")
        if monitor is not None and obs is None:
            from repro.obs import Observability
            obs = Observability.create()
        self._obs = obs
        self._monitor = monitor
        if monitor is not None:
            monitor.bind(obs)
        self._adapt = adapt
        if adapt is not None:
            adapt.bind(obs)
            self._register_adaptive()
        pending = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
        for r in pending:
            if r.tenant not in self._lane_of:
                raise ValueError(f"request {r.rid} names unknown tenant "
                                 f"{r.tenant!r}; have "
                                 f"{sorted(self._lane_of)}")
        injections = sorted(inject or [], key=lambda i: i.step)
        inj_i = 0
        if warmup:
            self.warmup(pending[0] if pending else None)

        try:
            out = self._run_loop(pending, injections, inj_i, telemetry,
                                 max_iterations)
            if monitor is not None:
                out.monitor = monitor.summary()
            if adapt is not None:
                out.thresholds = adapt.summary()
            return out
        finally:
            self._obs = None
            self._monitor = None
            self._adapt = None

    def _run_loop(self, pending, injections, inj_i, telemetry,
                  max_iterations) -> Telemetry:
        i = 0
        it = 0
        while True:
            it += 1
            if it > max_iterations:
                raise RuntimeError("engine exceeded max_iterations "
                                   "(stuck request stream?)")
            # 1. arrivals whose time has come; a full bounded queue sheds
            #    load — the rejection IS the SLO story, so it is recorded
            while i < len(pending) and pending[i].arrival_s <= self.clock_s:
                req = pending[i]
                if not self.queue.push(req, self.clock_s):
                    telemetry.add_request(RequestRecord(
                        rid=req.rid, tenant=req.tenant, kind=req.kind,
                        arrival_s=req.arrival_s, admit_s=self.clock_s,
                        first_token_s=None, finish_s=self.clock_s,
                        prompt_len=req.prompt_len, tokens_out=0,
                        queue_wait_s=0.0, aborted=True, rejected=True))
                i += 1
            active = any(lane.batcher.occupancy() for lane in self.lanes)
            if not self.queue and not active:
                if i >= len(pending):
                    break
                # idle: jump the virtual clock to the next arrival
                self.clock_s = max(self.clock_s, pending[i].arrival_s)
                continue

            injected_now = (inj_i < len(injections)
                            and injections[inj_i].step <= self.global_step)
            if injected_now:
                self._apply_injection(injections[inj_i], telemetry)
                inj_i += 1

            clock_before = self.clock_s
            # 2. admissions + prefills (or one-shot dlrm execution) —
            #    a quarantined tenant's requests stay queued, except for
            #    the monitor's periodic recovery probes
            for lane in self.lanes:
                for slot in lane.batcher.admit(self.queue, self.clock_s,
                                               accept=self._admits(lane)):
                    if slot.request.kind == "dlrm":
                        lane.batcher.retire(slot.index)
                        self._do_dlrm(lane, slot, telemetry, injected_now)
                    else:
                        self._do_prefill(lane, slot, telemetry,
                                         injected_now)
                for slot in lane.batcher.retire_finished():
                    if lane.pager is not None:
                        lane.pager.retire(slot.index)
                    self._record_slot(slot, telemetry)

            # 3. one decode step per lane with active slots
            for lane in self.lanes:
                if lane.batcher.occupancy():
                    self._do_decode(lane, telemetry, injected_now)

            if self._monitor is not None:
                if self.clock_s == clock_before and (
                        self.queue or any(l.batcher.occupancy()
                                          for l in self.lanes)):
                    # fully gated iteration: nothing stepped, so nothing
                    # ticked the monitor — advance the clock a hair and
                    # tick it manually so recovery/probes can unlock
                    self.clock_s += 1e-3
                    self._monitor.idle_tick(self.clock_s)
                self._apply_monitor_responses(telemetry)
                self._apply_adaptive()

            if injected_now:
                self._restore_injection()
            self.global_step += 1

        telemetry.finalize_injections()
        return telemetry


__all__ = ["ServingEngine", "TenantSpec", "FaultInjection",
           "tenant_weights"]
