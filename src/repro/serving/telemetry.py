"""Serving telemetry: SLO metrics merged with op-keyed fault counters.

One timeline owns both stories.  Every engine step appends a
:class:`StepEvent` — wall duration, batch occupancy, queue depth, and the
step's :class:`~repro.core.policy.FaultReport` counters — and every
finished request appends a :class:`RequestRecord`.  Because ABFT counters
and latency samples share the clock, a mid-traffic bit flip shows up in
the same timeline as its cost: the detection spike, the recompute retries,
and the TTFT/per-token-latency degradation of the requests in flight.

``summary()`` rolls the timeline up into per-tenant SLO percentiles
(p50/p95/p99 TTFT, per-token latency, end-to-end latency), throughput,
queue-depth stats, per-op fault counters, and per-injection detection
outcome + latency.  ``to_dict()`` is the JSON artifact the soak campaign
and the serve CLI write.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

PCTS = (50.0, 95.0, 99.0)

#: bump when to_dict() gains/renames fields — the serve CLI --json output
#: and the soak artifacts carry this so downstream parsers can dispatch
#: (v2: per-request/per-tenant prefill_tokens + shared_prefix_tokens)
TELEMETRY_SCHEMA_VERSION = 2


def percentiles_ms(xs_s: List[float]) -> Dict[str, float]:
    """{"p50": ..., "p95": ..., "p99": ..., "n": ...} in milliseconds.

    NaN-free by construction: non-finite samples are dropped, an empty
    stream returns explicit zeros (with ``n = 0`` so "no samples" stays
    distinguishable from "zero latency"), and a single sample is every
    percentile of itself — no reliance on np/list degenerate behavior."""
    xs = [float(x) for x in xs_s
          if x is not None and math.isfinite(float(x))]
    if not xs:
        return {**{f"p{int(p)}": 0.0 for p in PCTS}, "n": 0}
    if len(xs) == 1:
        v = xs[0] * 1e3
        return {**{f"p{int(p)}": v for p in PCTS}, "n": 1}
    arr = np.asarray(xs, np.float64) * 1e3
    out = {f"p{int(p)}": float(np.percentile(arr, p)) for p in PCTS}
    out["n"] = len(xs)
    return out


@dataclasses.dataclass
class RequestRecord:
    rid: int
    tenant: str
    kind: str
    arrival_s: float
    admit_s: float
    first_token_s: Optional[float]
    finish_s: float
    prompt_len: int
    tokens_out: int
    queue_wait_s: float
    aborted: bool = False
    rejected: bool = False               # shed at the admission queue
    tokens: Optional[List[int]] = None   # emitted ids (soak ground truth)
    #: prompt tokens this admission actually quantized at prefill vs
    #: served from already-resident shared prefix pages (paged KV lanes;
    #: contiguous lanes report the full bucket and zero shared)
    prefill_tokens: int = 0
    shared_prefix_tokens: int = 0
    #: flagged steps this request was resident in a slot for (attribution
    #: runs in finalize — a fault blames the requests it touched, not
    #: just the step)
    detections: int = 0
    suspect: bool = False                # detections > 0

    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_s is None:
            return None
        return self.first_token_s - self.arrival_s

    @property
    def e2e_s(self) -> float:
        return self.finish_s - self.arrival_s

    @property
    def per_token_s(self) -> Optional[float]:
        if self.first_token_s is None or self.tokens_out <= 1:
            return None
        return ((self.finish_s - self.first_token_s)
                / (self.tokens_out - 1))

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.pop("tokens")                  # bulky; kept host-side only
        return d


@dataclasses.dataclass
class StepEvent:
    step: int
    t_s: float                           # clock at step end
    kind: str                            # prefill | decode | dlrm
    lane: str
    duration_s: float
    occupancy: int
    queue_depth: int
    counters: Dict[str, int]             # abft/<op>_{checks,errors}, ...
    errors: int                          # total residual errors this step
    injected: bool = False
    #: request ids resident in the step's batcher slots when it ran —
    #: the attribution join key (prefill: the admitted request; decode:
    #: every active slot; abort: the drained slots)
    slot_rids: Tuple[int, ...] = ()

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class InjectionRecord:
    step: int
    victim: str
    clock_s: float
    persistent: bool = False
    detected: bool = False
    detect_step: Optional[int] = None
    latency_steps: Optional[int] = None
    latency_s: Optional[float] = None
    #: requests resident in slots at the detecting step
    attributed_rids: Tuple[int, ...] = ()

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["attributed_rids"] = list(self.attributed_rids)
        return d


class Telemetry:
    """Collects the request/step/injection timeline for one engine run."""

    def __init__(self):
        self.requests: List[RequestRecord] = []
        self.steps: List[StepEvent] = []
        self.injections: List[InjectionRecord] = []
        #: detection-health monitor summary (alerts, health states,
        #: transitions) — set by ServingEngine.run(monitor=...)
        self.monitor: Optional[dict] = None
        #: adaptive-threshold controller summaries (per (op, tenant):
        #: final rel_bound, adjustments, convergence) — set by
        #: ServingEngine.run(adapt=...)
        self.thresholds: Optional[list] = None

    # ------------------------------ recording -------------------------------

    def add_request(self, rec: RequestRecord) -> None:
        self.requests.append(rec)

    def add_step(self, ev: StepEvent) -> None:
        self.steps.append(ev)

    def add_injection(self, rec: InjectionRecord) -> None:
        self.injections.append(rec)

    # ------------------------------ analysis --------------------------------

    def finalize_injections(self) -> None:
        """Attribute each injection to the first flagged step at-or-after
        it (the engine's detect→act policies run online; this records how
        long the flag took in steps and wall seconds)."""
        for inj in self.injections:
            for ev in self.steps:
                if ev.step < inj.step or ev.errors <= 0:
                    continue
                inj.detected = True
                inj.detect_step = ev.step
                inj.latency_steps = ev.step - inj.step
                inj.latency_s = ev.t_s - inj.clock_s
                inj.attributed_rids = tuple(ev.slot_rids)
                break
        self.attribute_detections()

    def attribute_detections(self) -> None:
        """Blame flagged steps on the requests resident in their slots:
        every request whose rid appears in a flagged step's ``slot_rids``
        gains a detection count and the ``suspect`` bit.  Idempotent —
        recomputed from the timeline on every call."""
        by_rid = {r.rid: r for r in self.requests}
        for rec in by_rid.values():
            rec.detections = 0
            rec.suspect = False
        for ev in self.steps:
            if ev.errors <= 0:
                continue
            for rid in ev.slot_rids:
                rec = by_rid.get(rid)
                if rec is not None:
                    rec.detections += 1
                    rec.suspect = True

    def fault_counters(self) -> Dict[str, int]:
        total: Dict[str, int] = {}
        for ev in self.steps:
            for k, v in ev.counters.items():
                total[k] = total.get(k, 0) + int(v)
        return total

    def detection_steps(self) -> List[int]:
        return [ev.step for ev in self.steps if ev.errors > 0]

    def _tenant_summary(self, recs: List[RequestRecord]) -> dict:
        served = [r for r in recs if not r.rejected]
        ttft = [r.ttft_s for r in served if r.ttft_s is not None]
        ptl = [r.per_token_s for r in served if r.per_token_s is not None]
        return {
            "requests": len(recs),
            "completed": sum(1 for r in served if not r.aborted),
            "aborted": sum(1 for r in served if r.aborted),
            "rejected": sum(1 for r in recs if r.rejected),
            "tokens_out": sum(r.tokens_out for r in recs),
            "prefill_tokens": sum(r.prefill_tokens for r in served),
            "shared_prefix_tokens": sum(
                r.shared_prefix_tokens for r in served),
            "suspect": sum(1 for r in served if r.suspect),
            "detections": sum(r.detections for r in served),
            "ttft_ms": percentiles_ms(ttft),
            "per_token_ms": percentiles_ms(ptl),
            "e2e_ms": percentiles_ms([r.e2e_s for r in served]),
            "queue_wait_ms": percentiles_ms(
                [r.queue_wait_s for r in served]),
        }

    def summary(self) -> dict:
        self.finalize_injections()
        tenants = sorted({r.tenant for r in self.requests})
        span = max((ev.t_s for ev in self.steps), default=0.0)
        depths = [ev.queue_depth for ev in self.steps]
        occ = [ev.occupancy for ev in self.steps if ev.kind == "decode"]
        tokens = sum(r.tokens_out for r in self.requests)
        return {
            "requests": len(self.requests),
            "steps": len(self.steps),
            "span_s": span,
            "throughput_tok_s": tokens / span if span > 0 else 0.0,
            "queue_depth_max": max(depths, default=0),
            "queue_depth_mean": float(np.mean(depths)) if depths else 0.0,
            "decode_occupancy_mean": (float(np.mean(occ)) if occ else 0.0),
            "per_tenant": {t: self._tenant_summary(
                [r for r in self.requests if r.tenant == t])
                for t in tenants},
            "faults": {
                "counters": self.fault_counters(),
                "flagged_steps": len(self.detection_steps()),
                "injections": [i.to_dict() for i in self.injections],
                "injections_detected": sum(
                    1 for i in self.injections if i.detected),
                "suspect_requests": sum(
                    1 for r in self.requests if r.suspect),
            },
            **({"monitor": self.monitor}
               if self.monitor is not None else {}),
            **({"thresholds": self.thresholds}
               if self.thresholds is not None else {}),
        }

    def to_dict(self) -> dict:
        return {
            "schema_version": TELEMETRY_SCHEMA_VERSION,
            "summary": self.summary(),
            "requests": [r.to_dict() for r in self.requests],
            "steps": [ev.to_dict() for ev in self.steps],
        }


__all__ = ["Telemetry", "RequestRecord", "StepEvent", "InjectionRecord",
           "percentiles_ms", "PCTS", "TELEMETRY_SCHEMA_VERSION"]
