"""Request-stream generators for the serving engine.

A workload is a finite, seeded list of :class:`Request` s sorted by arrival
time.  Arrival processes model the traffic shapes Ma et al. (arXiv
2307.10244) show matter for error impact — steady Poisson, bursty
on/off, and trace replay — and two request kinds ride on them:

* ``chat`` — LM requests with sampled prompt/output lengths (lognormal,
  clipped), served by the continuous batcher (prefill + N decode steps);
* ``dlrm`` — one-shot recommendation lookups whose payload reuses the
  padded multi-hot layout of :class:`repro.data.pipeline.SyntheticDLRMDataset`
  (``dense [B, n_dense]``, ``bags [n_tables, B, max_pool]`` with −1 pads).

Everything is a pure function of the seed: a soak re-run regenerates the
exact request stream, so faulty and clean runs are step-for-step
comparable (the campaign's masked/SDC ground truth depends on this).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

ARRIVALS = ("poisson", "bursty", "trace")


@dataclasses.dataclass
class Request:
    """One inference request.  ``payload`` is filled lazily for chat
    requests (the engine synthesizes prompt tokens from ``seed``) and
    eagerly for dlrm lookups (numpy arrays)."""
    rid: int
    tenant: str
    arrival_s: float
    kind: str = "chat"                  # "chat" | "dlrm"
    prompt_len: int = 32
    max_new_tokens: int = 16
    seed: int = 0
    payload: Optional[dict] = None
    #: shared system-prompt prefix: the first ``prefix_len`` prompt tokens
    #: are drawn from ``prefix_seed`` instead of ``seed``, so every
    #: request carrying the same (prefix_seed, prefix_len) opens with
    #: byte-identical tokens — the paged KV cache's prefix tree serves
    #: those pages from shared, already-checksummed storage
    prefix_len: int = 0
    prefix_seed: Optional[int] = None

    def __post_init__(self):
        if self.kind not in ("chat", "dlrm"):
            raise ValueError(f"unknown request kind {self.kind!r}")


# ------------------------------ arrivals ------------------------------------

def poisson_arrivals(rate_rps: float, n: int,
                     rng: np.random.Generator) -> np.ndarray:
    """n arrival offsets (seconds) of a Poisson process at ``rate_rps``."""
    if rate_rps <= 0:
        raise ValueError("rate_rps must be positive")
    gaps = rng.exponential(1.0 / rate_rps, size=n)
    return np.cumsum(gaps)


def bursty_arrivals(rate_rps: float, n: int, rng: np.random.Generator, *,
                    burst_size: int = 8,
                    burst_spread_s: float = 1e-3) -> np.ndarray:
    """On/off traffic: requests arrive in bursts of ``burst_size`` whose
    *burst* starts form a Poisson process at ``rate_rps / burst_size``
    (same long-run rate as the Poisson stream, very different queueing)."""
    n_bursts = -(-n // burst_size)
    starts = poisson_arrivals(rate_rps / burst_size, n_bursts, rng)
    times = (starts[:, None]
             + rng.uniform(0.0, burst_spread_s, (n_bursts, burst_size)))
    return np.sort(times.reshape(-1)[:n])


def trace_arrivals(trace: Sequence[float], n: int,
                   rng: np.random.Generator) -> np.ndarray:
    """Replay recorded arrival offsets, tiling (with the trace span as the
    period) when the trace is shorter than ``n``."""
    t = np.asarray(sorted(float(x) for x in trace), np.float64)
    if t.size == 0:
        raise ValueError("empty trace")
    del rng
    span = max(float(t[-1]), 1e-9)
    reps = -(-n // t.size)
    tiled = np.concatenate([t + i * span for i in range(reps)])
    return tiled[:n]


def make_arrivals(pattern: str, rate_rps: float, n: int,
                  rng: np.random.Generator, *,
                  trace: Optional[Sequence[float]] = None,
                  burst_size: int = 8) -> np.ndarray:
    if pattern == "poisson":
        return poisson_arrivals(rate_rps, n, rng)
    if pattern == "bursty":
        return bursty_arrivals(rate_rps, n, rng, burst_size=burst_size)
    if pattern == "trace":
        if trace is None:
            raise ValueError("pattern 'trace' needs a trace")
        return trace_arrivals(trace, n, rng)
    raise ValueError(f"unknown arrival pattern {pattern!r}; "
                     f"have {ARRIVALS}")


# ------------------------------ tenants -------------------------------------

def sample_tenants(weights: Dict[str, float], n: int,
                   rng: np.random.Generator) -> List[str]:
    names = sorted(weights)
    w = np.asarray([weights[t] for t in names], np.float64)
    if np.any(w < 0) or w.sum() <= 0:
        raise ValueError(f"bad tenant weights {weights!r}")
    return [names[i] for i in rng.choice(len(names), size=n, p=w / w.sum())]


def _clipped_lognormal(rng, mean: float, sigma: float, lo: int,
                       hi: int, size: int) -> np.ndarray:
    x = rng.lognormal(np.log(max(mean, 1)), sigma, size)
    return np.clip(np.round(x), lo, hi).astype(np.int64)


# ------------------------------ streams -------------------------------------

def chat_stream(n: int, *, tenants: Dict[str, float], rate_rps: float = 20.0,
                arrival: str = "poisson", seed: int = 0,
                mean_prompt: int = 32, max_prompt: int = 64,
                mean_output: int = 12, max_output: int = 32,
                trace: Optional[Sequence[float]] = None,
                burst_size: int = 8, prefix_len: int = 0,
                prefix_seed: Optional[int] = None) -> List[Request]:
    """LM chat request stream with sampled prompt/output lengths.

    ``prefix_len``/``prefix_seed`` give every request the same opening
    system prompt (prompt lengths are floored at ``prefix_len`` so the
    prefix is always fully present) — the workload shape that makes the
    paged KV cache's prefix sharing measurable."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0xC4A7]))
    times = make_arrivals(arrival, rate_rps, n, rng, trace=trace,
                          burst_size=burst_size)
    who = sample_tenants(tenants, n, rng)
    plens = _clipped_lognormal(rng, mean_prompt, 0.4, 4, max_prompt, n)
    olens = _clipped_lognormal(rng, mean_output, 0.5, 1, max_output, n)
    if prefix_len > 0:
        plens = np.maximum(plens, min(prefix_len, max_prompt))
    return [Request(rid=i, tenant=who[i], arrival_s=float(times[i]),
                    kind="chat", prompt_len=int(plens[i]),
                    max_new_tokens=int(olens[i]),
                    seed=int(rng.integers(0, 2**31 - 1)),
                    prefix_len=prefix_len if prefix_seed is not None else 0,
                    prefix_seed=prefix_seed)
            for i in range(n)]


def dlrm_stream(n: int, *, tenants: Dict[str, float], rate_rps: float = 50.0,
                arrival: str = "poisson", seed: int = 0,
                lookup_batch: int = 10, table_rows: int = 1000,
                n_tables: Optional[int] = None,
                max_pool: int = 16,
                trace: Optional[Sequence[float]] = None,
                burst_size: int = 8) -> List[Request]:
    """One-shot DLRM lookup requests.  Payload shapes follow
    :class:`repro.data.pipeline.SyntheticDLRMDataset`: ``dense
    [B, n_dense]`` f32 and ``bags [n_tables, B, max_pool]`` int32 with −1
    padding and variable pooling."""
    from repro.configs.dlrm import EXTRAS

    nt = EXTRAS.n_tables if n_tables is None else n_tables
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0xD12A]))
    times = make_arrivals(arrival, rate_rps, n, rng, trace=trace,
                          burst_size=burst_size)
    who = sample_tenants(tenants, n, rng)
    out = []
    for i in range(n):
        dense = rng.standard_normal(
            (lookup_batch, EXTRAS.n_dense)).astype(np.float32)
        pools = rng.integers(1, max_pool + 1, (nt, lookup_batch))
        idx = rng.integers(0, table_rows, (nt, lookup_batch, max_pool))
        mask = np.arange(max_pool)[None, None, :] < pools[..., None]
        bags = np.where(mask, idx, -1).astype(np.int32)
        out.append(Request(
            rid=i, tenant=who[i], arrival_s=float(times[i]), kind="dlrm",
            prompt_len=0, max_new_tokens=0,
            seed=int(rng.integers(0, 2**31 - 1)),
            payload={"dense": dense, "bags": bags}))
    return out


def stream_span_s(requests: Sequence[Request]) -> float:
    return max((r.arrival_s for r in requests), default=0.0)


__all__ = ["Request", "ARRIVALS", "poisson_arrivals", "bursty_arrivals",
           "trace_arrivals", "make_arrivals", "sample_tenants",
           "chat_stream", "dlrm_stream", "stream_span_s"]
