"""``serving_soak``: fault injection under live traffic, as a campaign.

Each cell runs the full serving engine twice over the SAME seeded request
stream — once clean, once with bit flips injected at chosen steps of the
live trace — and reduces the two telemetry timelines into
campaign-artifact metrics:

* ``detection_rate`` / ``escape_rate`` — per injected fault, was it
  flagged online (first flagged step at-or-after the injection), and did
  it corrupt any request's output tokens vs. the clean run (greedy decode
  over a seeded stream is deterministic, so token-for-token comparison is
  the masked/SDC ground truth);
* ``fp_rate`` — flagged steps in the clean run, per step (the serving
  analogue of the operator campaigns' clean-trial column);
* per-tenant SLO percentiles (p50/p95/p99 TTFT, per-token, e2e) for both
  runs plus the faulty-over-clean p99 degradation — detection latency and
  recovery cost land in the same artifact as the resilience numbers.

Cells sweep the arrival pattern (Poisson vs bursty vs trace — Ma et al.
show error impact is workload-dependent) and, in the full grid, the
injected victim path and fault persistence.  Artifacts are ordinary
``BENCH_campaign_serving_soak.json`` files: the cross-PR differ and CI
artifact upload work unchanged.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

SOAK_ARCH = "llama3.2-1b"

#: the default multi-tenant mix: a premium class with retry-on-detect,
#: checksummed int8 KV cache and a tight EB threshold, and a best-effort
#: class with log-only protection — per-tenant plans exercised end to end.
DEFAULT_TENANTS: Tuple[Tuple[str, float, str], ...] = (
    ("premium", 1.0,
     "*:policy=recompute,kv_cache:on,embedding_bag:rel_bound=1e-5"),
    ("standard", 2.0, "*:policy=log"),
)


@dataclasses.dataclass(frozen=True)
class SoakSpec:
    """The sweep description embedded in the artifact."""
    name: str
    arch: str
    arrivals: Tuple[str, ...]
    n_requests: int
    n_slots: int
    rate_rps: float
    max_new_tokens: int
    seed: int
    tenants: Tuple[Tuple[str, float, str], ...] = DEFAULT_TENANTS
    victims: Tuple[Optional[str], ...] = (None,)
    persistent: bool = False

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class SoakCellPlan:
    cell_id: str
    target: str
    arrival: str
    arch: str
    n_requests: int
    n_slots: int
    rate_rps: float
    inject_steps: Tuple[int, ...]
    victim: Optional[str]
    persistent: bool
    seed: int
    #: (name, weight, plan_text) triples — the cell is self-contained
    tenants: Tuple[Tuple[str, float, str], ...] = DEFAULT_TENANTS

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class SoakMetrics:
    """Dict-backed metrics (campaign artifacts just need ``to_dict``)."""

    def __init__(self, d: dict):
        self._d = d

    def to_dict(self) -> dict:
        return self._d

    def __getitem__(self, k):
        return self._d[k]


def _tenant_specs(tenants=DEFAULT_TENANTS):
    from repro.protect import ProtectionPlan
    from repro.serving.engine import TenantSpec

    # from_any: compact strings, plan dicts, and @path.json all work —
    # the campaign CLI's --plan override passes through unparsed
    return [TenantSpec(name=n, weight=w,
                       plan=ProtectionPlan.from_any(p, name=n))
            for n, w, p in tenants]


def _token_map(telemetry) -> Dict[int, tuple]:
    return {r.rid: (tuple(r.tokens or ()), r.aborted)
            for r in telemetry.requests}


def _slo_of(summary: dict) -> dict:
    return {t: {"ttft_ms": s["ttft_ms"], "per_token_ms": s["per_token_ms"],
                "e2e_ms": s["e2e_ms"], "completed": s["completed"],
                "aborted": s["aborted"]}
            for t, s in summary["per_tenant"].items()}


def _degradation(clean: dict, faulty: dict) -> dict:
    out = {}
    for t in faulty:
        c = clean.get(t, {}).get("ttft_ms", {}).get("p99", float("nan"))
        f = faulty[t]["ttft_ms"]["p99"]
        out[t] = {"ttft_p99_ratio":
                  (f / c if c and np.isfinite(c) and c > 0
                   else float("nan"))}
    return out


def _publish_soak_cell(obs, plan: SoakCellPlan, metrics: "SoakMetrics",
                       injected: List[dict]) -> None:
    """Land the cell's outcome in the obs registry + event bus.

    Counters mirror the artifact's SoakMetrics exactly (detections =
    flagged injections, false positives = clean-pass flags) so the
    Prometheus text and the JSON artifact can be cross-checked; the
    per-step/per-op detection events were already emitted live by the
    engine during the faulty pass."""
    if obs is None:
        return
    from repro.obs import FaultEvent

    reg = obs.registry
    cell = plan.cell_id
    reg.counter("repro_injections_total",
                "injected faults per campaign cell"
                ).inc(metrics["samples"], cell=cell)
    reg.counter("repro_detections_total",
                "online-detected injected faults per campaign cell"
                ).inc(metrics["detected"], cell=cell)
    reg.counter("repro_escapes_total",
                "corrupted-and-undetected faults per campaign cell"
                ).inc(metrics["escapes"], cell=cell)
    reg.counter("repro_false_positives_total",
                "clean-pass flags per campaign cell"
                ).inc(metrics["false_positives"], cell=cell)
    for inj in injected:
        reg.counter("repro_injections_total",
                    "injected faults per campaign cell"
                    ).inc(1, source="serving.soak")
        obs.bus.emit(FaultEvent(
            op=inj.get("victim") or "auto", kind="injection",
            step=inj["step"], source="serving.soak",
            cell_id=plan.cell_id, errors=int(bool(inj["detected"])),
            checks=1, request_ids=tuple(inj.get("attributed_rids", ())),
            attrs={"detected": inj["detected"],
                   "latency_steps": inj["latency_steps"],
                   "persistent": plan.persistent}))
    obs.bus.emit(FaultEvent(
        op=plan.target, kind="cell", step=0, source="serving.soak",
        cell_id=plan.cell_id, errors=metrics["detected"],
        checks=metrics["samples"],
        detector_value=metrics["detection_rate"],
        attrs={"escapes": metrics["escapes"],
               "false_positives": metrics["false_positives"],
               "fp_rate": metrics["fp_rate"]}))


def run_soak_cell(plan: SoakCellPlan, *, engine=None,
                  keep_telemetry: bool = False, obs=None,
                  monitor=None) -> dict:
    """One cell: clean pass + faulty pass over the same stream.

    Returns ``{"plan", "metrics", "seconds"[, "telemetry"]}``; pass a
    prebuilt ``engine`` (same arch/tenants) to amortize compiles across
    cells.  With ``obs``, the FAULTY pass runs instrumented (per-step
    detection events with resident request ids, spans, step counters) and
    the cell outcome lands as campaign-level counters; the clean pass
    stays uninstrumented so its flags count only as the cell's
    false-positive column, not as detection events."""
    from repro.configs import reduce_cfg
    from repro.configs.registry import get_arch
    from repro.serving.engine import (FaultInjection, ServingEngine,
                                      tenant_weights)
    from repro.serving.workload import chat_stream

    t0 = time.perf_counter()
    specs = _tenant_specs(plan.tenants)
    if engine is None:
        cfg = reduce_cfg(get_arch(plan.arch))
        engine = ServingEngine(cfg, specs, n_slots=plan.n_slots,
                               max_prompt=32, max_new_tokens=16,
                               seed=plan.seed)
    stream = chat_stream(
        plan.n_requests, tenants=tenant_weights(specs),
        rate_rps=plan.rate_rps, arrival=plan.arrival, seed=plan.seed,
        mean_prompt=24, max_prompt=32, mean_output=8,
        max_output=engine.max_new_tokens)

    engine.reset_state()
    clean = engine.run(stream)
    clean_summary = clean.summary()
    clean_steps = len(clean.steps)
    clean_flags = len(clean.detection_steps())

    engine.reset_state()
    injections = [FaultInjection(step=s, victim=plan.victim,
                                 persistent=plan.persistent,
                                 seed=plan.seed + 17 * i)
                  for i, s in enumerate(plan.inject_steps)]
    faulty = engine.run(stream, inject=injections, obs=obs,
                        monitor=monitor)
    engine.reset_state()          # restores any persistent fault
    faulty_summary = faulty.summary()

    clean_toks, faulty_toks = _token_map(clean), _token_map(faulty)
    corrupted_rids = [rid for rid in faulty_toks
                      if faulty_toks[rid] != clean_toks.get(rid)]
    injected = faulty_summary["faults"]["injections"]
    detected = sum(1 for i in injected if i["detected"])
    samples = max(len(injected), 1)
    # per-fault escape accounting: with one fault per run-slice the
    # stream-level "any token changed & nothing flagged" is the SDC bit
    escapes = sum(1 for i in injected
                  if not i["detected"]) if corrupted_rids else 0

    slo_clean = _slo_of(clean_summary)
    slo_faulty = _slo_of(faulty_summary)
    metrics = SoakMetrics({
        "samples": len(injected),
        "detected": detected,
        "corrupted": len(corrupted_rids),
        "escapes": escapes,
        "detection_rate": detected / samples,
        "escape_rate": escapes / samples,
        "clean_samples": clean_steps,
        "false_positives": clean_flags,
        "fp_rate": clean_flags / clean_steps if clean_steps else 0.0,
        "analytic_bound": None,
        "overhead": None,
        "detection_latency_steps": [i["latency_steps"] for i in injected],
        "detection_latency_ms": [
            None if i["latency_s"] is None else 1e3 * i["latency_s"]
            for i in injected],
        "injections": injected,
        "throughput_tok_s": faulty_summary["throughput_tok_s"],
        "queue_depth_max": faulty_summary["queue_depth_max"],
        "slo": slo_faulty,
        "slo_clean": slo_clean,
        "slo_degradation": _degradation(slo_clean, slo_faulty),
    })
    _publish_soak_cell(obs, plan, metrics, injected)
    out = {"plan": plan, "metrics": metrics,
           "seconds": time.perf_counter() - t0}
    if keep_telemetry:
        out["telemetry"] = {"clean": clean, "faulty": faulty}
    return out


def soak_plans(spec: SoakSpec) -> List[SoakCellPlan]:
    rng = np.random.default_rng(spec.seed)
    plans = []
    for arrival in spec.arrivals:
        for victim in spec.victims:
            # inject inside the early-traffic window every pattern reaches
            steps = tuple(sorted(int(s) for s in
                                 rng.integers(5, 30, size=1)))
            vic = victim if victim is None else str(victim)
            cid = f"serving_soak/{arrival}/" \
                  f"{vic or 'auto'}/{spec.arch}" \
                  + ("/persistent" if spec.persistent else "")
            plans.append(SoakCellPlan(
                cell_id=cid, target="serving_soak", arrival=arrival,
                arch=spec.arch, n_requests=spec.n_requests,
                n_slots=spec.n_slots, rate_rps=spec.rate_rps,
                inject_steps=steps, victim=victim,
                persistent=spec.persistent, seed=spec.seed,
                tenants=tuple(spec.tenants)))
    return plans


def quick_soak_spec(seed: int = 0, n_requests: int = 200) -> SoakSpec:
    return SoakSpec(name="serving_soak", arch=SOAK_ARCH,
                    arrivals=("poisson", "bursty"),
                    n_requests=n_requests, n_slots=4, rate_rps=200.0,
                    max_new_tokens=16, seed=seed)


def full_soak_spec(seed: int = 0) -> SoakSpec:
    return SoakSpec(name="serving_soak", arch=SOAK_ARCH,
                    arrivals=("poisson", "bursty"),
                    n_requests=400, n_slots=4, rate_rps=200.0,
                    max_new_tokens=16, seed=seed,
                    victims=(None, "attn.wq", "mlp.down"))


def run_soak_campaign(spec: Optional[SoakSpec] = None, *,
                      quick: bool = True, seed: int = 0,
                      out_dir: Optional[str] = None,
                      verbose=None, obs=None, monitor=None) -> dict:
    """Run every cell of the spec; returns (and optionally writes) the
    ``BENCH_campaign_serving_soak`` artifact dict."""
    from repro.campaign.artifacts import campaign_to_dict, write_artifacts
    from repro.configs import reduce_cfg
    from repro.configs.registry import get_arch
    from repro.serving.engine import ServingEngine

    if spec is None:
        spec = quick_soak_spec(seed) if quick else full_soak_spec(seed)
    t0 = time.perf_counter()
    cfg = reduce_cfg(get_arch(spec.arch))
    engine = ServingEngine(cfg, _tenant_specs(spec.tenants),
                           n_slots=spec.n_slots, max_prompt=32,
                           max_new_tokens=spec.max_new_tokens,
                           seed=spec.seed)
    cells = []
    for plan in soak_plans(spec):
        cell = run_soak_cell(plan, engine=engine, obs=obs,
                             monitor=monitor)
        cells.append(cell)
        if verbose:
            m = cell["metrics"]
            verbose(f"[{plan.cell_id}] inj={m['samples']} "
                    f"detect={m['detection_rate']:.2f} "
                    f"escape={m['escape_rate']:.2f} "
                    f"fp={m['fp_rate']:.4f} ({cell['seconds']:.1f}s)")
    result = campaign_to_dict("serving_soak", [spec], cells, [],
                              wall_s=time.perf_counter() - t0,
                              seed=spec.seed)
    if out_dir is not None:
        write_artifacts(result, out_dir)
    return result


__all__ = ["SoakSpec", "SoakCellPlan", "SoakMetrics", "run_soak_cell",
           "soak_plans", "run_soak_campaign", "quick_soak_spec",
           "full_soak_spec", "DEFAULT_TENANTS", "SOAK_ARCH"]
