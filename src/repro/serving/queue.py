"""Admission queue: bounded FIFO between the arrival process and the
continuous batcher.

The queue is strictly FIFO *per admissible set* — ``pop_next(accept)``
returns the oldest request the caller can currently place, so two plan
lanes draining one queue each preserve arrival order within their own
traffic, and a burst can never reorder a tenant's requests (the batcher
invariant tests pin this down).  A full queue rejects at ``push`` — the
load-shedding counter feeds the SLO telemetry, not an exception.
"""
from __future__ import annotations

from collections import deque
from typing import Callable, Dict, List, Optional

from repro.serving.workload import Request


class AdmissionQueue:
    def __init__(self, max_depth: int = 0):
        """``max_depth=0`` means unbounded."""
        self.max_depth = max_depth
        self._q: deque = deque()       # (request, enqueue_clock_s)
        self.rejected: Dict[str, int] = {}
        self.admitted: Dict[str, int] = {}

    # ------------------------------ producer --------------------------------

    def push(self, req: Request, clock_s: float) -> bool:
        """Enqueue; returns False (and counts the rejection) when full."""
        if self.max_depth and len(self._q) >= self.max_depth:
            self.rejected[req.tenant] = self.rejected.get(req.tenant, 0) + 1
            return False
        self._q.append((req, clock_s))
        return True

    # ------------------------------ consumer --------------------------------

    def pop_next(self, accept: Optional[Callable[[Request], bool]] = None
                 ) -> Optional[tuple]:
        """Oldest request with ``accept(req)`` (default: any).  Returns
        ``(request, enqueue_clock_s)`` or None.  FIFO among the accepted
        subset; non-accepted requests keep their positions."""
        for i, (req, t) in enumerate(self._q):
            if accept is None or accept(req):
                del self._q[i]
                self.admitted[req.tenant] = \
                    self.admitted.get(req.tenant, 0) + 1
                return req, t
        return None

    # ------------------------------ telemetry -------------------------------

    def depth(self) -> int:
        return len(self._q)

    def tenant_depths(self) -> Dict[str, int]:
        d: Dict[str, int] = {}
        for req, _ in self._q:
            d[req.tenant] = d.get(req.tenant, 0) + 1
        return d

    def peek_all(self) -> List[Request]:
        return [req for req, _ in self._q]

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)


__all__ = ["AdmissionQueue"]
