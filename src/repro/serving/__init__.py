"""``repro.serving`` — continuous-batching protected serving engine with
live-traffic fault telemetry.

The serving stack the paper's overhead argument is really about: request
streams (Poisson / bursty / trace replay; LM chat + one-shot DLRM
lookups) flow through an admission queue into a fixed-slot continuous
batcher; a :class:`ServingEngine` wraps the model with
:func:`repro.protect.protect` under **per-tenant protection plans**
(tenants sharing a plan share a jit lane) and applies detect→act
policies online; telemetry merges SLO percentiles (TTFT / per-token /
e2e, p50/p95/p99) with the op-keyed fault counters on one timeline.

    from repro.serving import ServingEngine, TenantSpec, chat_stream
    engine = ServingEngine(cfg, [TenantSpec("premium", plan_a),
                                 TenantSpec("batch", plan_b)])
    telemetry = engine.run(chat_stream(200, tenants={"premium": 1,
                                                     "batch": 2}))
    telemetry.summary()["per_tenant"]["premium"]["ttft_ms"]["p99"]

``repro.serving.soak`` packages the fault-under-traffic experiment as a
campaign (``python -m repro.campaign --grid serving_soak``).
"""
from repro.serving.batcher import ContinuousBatcher, Slot
from repro.serving.engine import (FaultInjection, ServingEngine,
                                  TenantSpec, tenant_weights)
from repro.serving.queue import AdmissionQueue
from repro.serving.telemetry import (InjectionRecord, RequestRecord,
                                     StepEvent, Telemetry, percentiles_ms)
from repro.serving.workload import (ARRIVALS, Request, bursty_arrivals,
                                    chat_stream, dlrm_stream,
                                    make_arrivals, poisson_arrivals,
                                    sample_tenants, trace_arrivals)

__all__ = [
    "ServingEngine", "TenantSpec", "FaultInjection", "tenant_weights",
    "ContinuousBatcher", "Slot", "AdmissionQueue",
    "Telemetry", "RequestRecord", "StepEvent", "InjectionRecord",
    "percentiles_ms",
    "Request", "ARRIVALS", "chat_stream", "dlrm_stream", "make_arrivals",
    "poisson_arrivals", "bursty_arrivals", "trace_arrivals",
    "sample_tenants",
]
