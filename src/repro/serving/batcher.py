"""Continuous batcher: fixed-slot decode batch with mid-stream admission.

The decode batch has ``n_slots`` fixed positions (the jitted decode step
is compiled once per lane at that width).  A finished request retires its
slot immediately; the next engine iteration admits the oldest queued
request into the free slot and prefills it while the other slots keep
decoding — classic continuous batching, host-side bookkeeping only (the
engine owns the jax-side cache/pos/token arrays this mirrors).

Invariants (pinned by tests/test_serving_batcher.py):

* ``len(free) + len(active) == n_slots`` after every operation — no slot
  leaks, no double-occupancy;
* admission order == arrival order among a lane's requests (FIFO under
  burst);
* a slot's request is returned exactly once by :meth:`retire`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.serving.queue import AdmissionQueue
from repro.serving.workload import Request


@dataclasses.dataclass
class Slot:
    """One occupied decode-batch position."""
    index: int
    request: Request
    admit_s: float
    pos: int = 0                    # absolute decode position (incl. prefix)
    generated: int = 0
    first_token_s: Optional[float] = None
    queue_wait_s: float = 0.0
    #: prompt tokens this admission actually prefilled/quantized (the
    #: engine refines it post-prefill: the contiguous path pays the full
    #: bucket, the paged path only the non-shared pages) and the tokens
    #: served from shared prefix pages instead
    prefill_tokens: int = 0
    shared_prefix_tokens: int = 0


class ContinuousBatcher:
    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        self.n_slots = n_slots
        self._free: List[int] = list(range(n_slots))
        self._active: Dict[int, Slot] = {}

    # ------------------------------ queries ---------------------------------

    def free_count(self) -> int:
        return len(self._free)

    def active_slots(self) -> List[Slot]:
        return [self._active[i] for i in sorted(self._active)]

    def occupancy(self) -> int:
        return len(self._active)

    def check_invariants(self) -> None:
        assert len(self._free) + len(self._active) == self.n_slots, \
            (self._free, sorted(self._active))
        assert not (set(self._free) & set(self._active)), \
            (self._free, sorted(self._active))
        assert len(set(self._free)) == len(self._free), self._free

    # ------------------------------ transitions -----------------------------

    def admit(self, queue: AdmissionQueue, clock_s: float,
              accept=None) -> List[Slot]:
        """Fill free slots from the queue (FIFO among accepted requests)."""
        admitted: List[Slot] = []
        while self._free:
            item = queue.pop_next(accept)
            if item is None:
                break
            req, enq_s = item
            idx = self._free.pop(0)
            slot = Slot(index=idx, request=req, admit_s=clock_s,
                        queue_wait_s=max(0.0, clock_s - enq_s),
                        prefill_tokens=req.prompt_len)
            self._active[idx] = slot
            admitted.append(slot)
        self.check_invariants()
        return admitted

    def retire(self, index: int) -> Slot:
        if index not in self._active:
            raise KeyError(f"slot {index} is not active")
        slot = self._active.pop(index)
        self._free.append(index)
        self.check_invariants()
        return slot

    def retire_finished(self) -> List[Slot]:
        done = [i for i, s in self._active.items()
                if s.generated >= s.request.max_new_tokens]
        return [self.retire(i) for i in sorted(done)]

    def drain(self) -> List[Slot]:
        """Retire everything (lane reset after an abort)."""
        return [self.retire(i) for i in sorted(self._active)]


__all__ = ["ContinuousBatcher", "Slot"]
