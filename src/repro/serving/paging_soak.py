"""``paging``: the paged-KV serving campaign — parity, cost, and repair.

Two cells, both over the same mixed-length two-tenant request stream
whose requests open with a shared system prompt (``prefix_len`` tokens
from one ``prefix_seed``):

* **parity** — the paged engine (``kv_cache_paged``) and the contiguous
  fixed-slot engine (``kv_cache``) each serve the stream clean once and
  then once per fault of the SAME KV bit-flip grid (one persistent int8
  payload flip per pass, same seeds/steps on both sides).  The cell
  records detection-rate parity (Wilson-interval overlap), the measured
  pages-verified-per-decode-token of the paged scheme against the
  contiguous whole-prefix re-verify (computed analytically: ``2*pos``
  row checksums per slot per decode step), and the paged pool's peak
  resident KV bytes against the fixed-slot ``max_prompt`` layout.
* **rebuild** — the paged engine under ``policy=recompute`` takes one
  persistent KV flip; detect→scrub→evict→re-prefill must repair it
  online (``page_rebuilds >= 1``) without aborting the stream.

Artifacts are ordinary ``BENCH_campaign_paging*.json`` files: the
cross-PR differ (detection/FP gates) and CI artifact upload work
unchanged, and the extra parity/cost booleans ride in the cell metrics.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Tuple

import numpy as np

PAGING_ARCH = "llama3.2-1b"

#: detection-parity pair: same log-only policy, only the KV scheme moves
PAGED_PLAN = "*:policy=log,kv_cache_paged:on"
CONTIG_PLAN = "*:policy=log,kv_cache:on"
#: the repair cell's plan: detect -> evict corrupt page -> re-prefill
REBUILD_PLAN = "*:policy=recompute,kv_cache_paged:on"


@dataclasses.dataclass(frozen=True)
class PagingSoakSpec:
    """The sweep description embedded in the artifact."""
    name: str
    arch: str
    n_requests: int
    n_slots: int
    rate_rps: float
    max_new_tokens: int
    page_size: int
    n_pages: int
    prefix_len: int
    n_faults: int
    seed: int
    plan: str = PAGED_PLAN
    contig_plan: str = CONTIG_PLAN
    rebuild_plan: str = REBUILD_PLAN

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class PagingCellPlan:
    cell_id: str
    target: str
    kind: str                        # "parity" | "rebuild"
    arch: str
    n_requests: int
    n_slots: int
    rate_rps: float
    page_size: int
    n_pages: int
    prefix_len: int
    inject_steps: Tuple[int, ...]
    seed: int
    plan: str

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class PagingMetrics:
    def __init__(self, d: dict):
        self._d = d

    def to_dict(self) -> dict:
        return self._d

    def __getitem__(self, k):
        return self._d[k]


def wilson_interval(k: int, n: int, z: float = 1.96) -> Tuple[float, float]:
    """Wilson score interval for a binomial rate (the campaign's standard
    small-n detection-rate CI)."""
    if n <= 0:
        return 0.0, 1.0
    p = k / n
    denom = 1.0 + z * z / n
    center = (p + z * z / (2 * n)) / denom
    half = (z / denom) * np.sqrt(p * (1 - p) / n + z * z / (4 * n * n))
    return max(0.0, center - half), min(1.0, center + half)


def intervals_overlap(a: Tuple[float, float],
                      b: Tuple[float, float]) -> bool:
    return a[0] <= b[1] and b[0] <= a[1]


# ------------------------------ engines -------------------------------------

def _engines(spec: PagingSoakSpec):
    """(paged-log, contiguous, paged-rebuild) engines over two tenants
    sharing each plan — one lane, one shared page pool per engine."""
    from repro.configs import reduce_cfg
    from repro.configs.registry import get_arch
    from repro.paging import PagingConfig
    from repro.protect import ProtectionPlan
    from repro.serving.engine import ServingEngine, TenantSpec

    cfg = reduce_cfg(get_arch(spec.arch))
    pcfg = PagingConfig(page_size=spec.page_size, n_pages=spec.n_pages)

    def build(plan_text: str, paging):
        plan = ProtectionPlan.from_any(plan_text, name="paging")
        tenants = [TenantSpec("tenant_a", plan), TenantSpec("tenant_b", plan)]
        return ServingEngine(cfg, tenants, n_slots=spec.n_slots,
                             max_prompt=32,
                             max_new_tokens=spec.max_new_tokens,
                             seed=spec.seed, paging=paging)

    return (build(spec.plan, pcfg), build(spec.contig_plan, None),
            build(spec.rebuild_plan, pcfg))


def _stream(spec: PagingSoakSpec, engine):
    from repro.serving.workload import chat_stream
    return chat_stream(
        spec.n_requests, tenants={"tenant_a": 1.0, "tenant_b": 1.0},
        rate_rps=spec.rate_rps, seed=spec.seed, mean_prompt=24,
        max_prompt=32, mean_output=8, max_output=engine.max_new_tokens,
        prefix_len=spec.prefix_len, prefix_seed=spec.seed + 0x5EED)


def _token_map(telemetry) -> Dict[int, tuple]:
    return {r.rid: (tuple(r.tokens or ()), r.aborted)
            for r in telemetry.requests}


def _decode_tokens(telemetry) -> int:
    """Decode tokens emitted = sum of occupancy over decode steps."""
    return sum(ev.occupancy for ev in telemetry.steps
               if ev.kind == "decode")


def _contig_compares_per_token(telemetry, max_prompt: int) -> float:
    """Analytic checksum compares per decode token of the contiguous
    whole-prefix re-verify: each decode step at absolute position
    ``pos`` verifies all ``pos`` written rows of K and of V."""
    compares = tokens = 0
    for r in telemetry.requests:
        if r.rejected or r.tokens_out <= 1:
            continue
        for g in range(1, r.tokens_out):
            compares += 2 * (max_prompt + g)
            tokens += 1
    return compares / tokens if tokens else 0.0


def _fault_grid(spec: PagingSoakSpec):
    """The shared bit-flip grid: (step, seed) per fault — the paged and
    contiguous passes replay the identical list."""
    rng = np.random.default_rng(spec.seed + 0xFA11)
    steps = sorted(int(s) for s in
                   rng.choice(np.arange(4, 28), size=spec.n_faults,
                              replace=False))
    return [(s, spec.seed + 17 * i) for i, s in enumerate(steps)]


def _fault_passes(engine, stream, grid, obs=None):
    """One clean pass + one faulty pass per grid entry; returns
    (clean_telemetry, clean-pass state snapshot, [per-fault dicts]).

    The snapshot (pager stats + resident cache bytes) is taken right
    after the clean pass — ``reset_state`` wipes pager counters and
    drops lane caches, so it cannot be read after the fault loop."""
    import jax

    from repro.serving.engine import FaultInjection

    engine.reset_state()
    clean = engine.run(stream, obs=None)
    clean_toks = _token_map(clean)
    snapshot = {
        "paging": engine.paging_stats(),
        "cache_bytes": int(sum(
            sum(x.nbytes for x in jax.tree_util.tree_leaves(lane.cache))
            for lane in engine.lanes if lane.cache is not None)),
    }
    out = []
    for step, seed in grid:
        engine.reset_state()
        faulty = engine.run(stream, inject=[FaultInjection(
            step=step, target="kv", persistent=True, seed=seed)], obs=obs)
        summ = faulty.summary()
        inj = summ["faults"]["injections"]
        toks = _token_map(faulty)
        corrupted = [rid for rid in toks
                     if toks[rid] != clean_toks.get(rid)]
        out.append({
            "step": step, "seed": seed,
            "applied": len(inj) > 0,
            "detected": any(i["detected"] for i in inj),
            "corrupted": len(corrupted),
            "injections": inj,
            "summary": summ,
        })
    engine.reset_state()
    return clean, snapshot, out


# ------------------------------ cells ---------------------------------------

def run_parity_cell(plan: PagingCellPlan, spec: PagingSoakSpec, *,
                    paged_engine, contig_engine, obs=None) -> dict:
    """Paged vs contiguous under the same KV bit-flip grid."""
    t0 = time.perf_counter()
    grid = [(s, spec.seed + 17 * i)
            for i, s in enumerate(plan.inject_steps)]
    stream_p = _stream(spec, paged_engine)
    stream_c = _stream(spec, contig_engine)

    clean_p, snap_p, faults_p = _fault_passes(paged_engine, stream_p,
                                              grid, obs=obs)
    pstats = next(iter(snap_p["paging"].values()), {})
    clean_c, snap_c, faults_c = _fault_passes(contig_engine, stream_c,
                                              grid)

    def rates(clean, faults):
        applied = [f for f in faults if f["applied"]]
        det = sum(1 for f in applied if f["detected"])
        n = len(applied)
        esc = sum(1 for f in applied
                  if not f["detected"] and f["corrupted"])
        steps = len(clean.steps)
        flags = len(clean.detection_steps())
        return {"samples": n, "detected": det,
                "detection_rate": det / n if n else 0.0,
                "escapes": esc, "escape_rate": esc / n if n else 0.0,
                "false_positives": flags, "clean_samples": steps,
                "fp_rate": flags / steps if steps else 0.0}

    rp, rc = rates(clean_p, faults_p), rates(clean_c, faults_c)
    ci_p = wilson_interval(rp["detected"], rp["samples"])
    ci_c = wilson_interval(rc["detected"], rc["samples"])
    parity_ok = intervals_overlap(ci_p, ci_c)

    # verify-cost: measured paged page compares vs analytic contiguous
    # whole-prefix row compares, both per emitted decode token
    checks = clean_p.fault_counters().get("kv_cache_paged_checks", 0)
    dtoks = _decode_tokens(clean_p)
    pages_per_token = checks / dtoks if dtoks else 0.0
    contig_per_token = _contig_compares_per_token(
        clean_c, contig_engine.max_prompt)
    verify_ok = 0.0 < pages_per_token < contig_per_token

    # memory: peak resident paged pool bytes vs the fixed-slot layout
    peak_bytes = int(pstats.get("peak_resident_bytes", 0))
    fixed_bytes = snap_c["cache_bytes"]
    bytes_ok = 0 < peak_bytes < fixed_bytes

    clean_ps = clean_p.summary()
    metrics = PagingMetrics({
        **rp,
        "analytic_bound": None,
        "overhead": None,
        "contig_detection_rate": rc["detection_rate"],
        "contig_fp_rate": rc["fp_rate"],
        "contig_samples": rc["samples"],
        "detection_ci": list(ci_p),
        "contig_detection_ci": list(ci_c),
        "parity_ok": bool(parity_ok),
        "pages_verified_per_token": pages_per_token,
        "contig_rows_verified_per_token": contig_per_token,
        "verify_ok": bool(verify_ok),
        "peak_resident_kv_bytes": peak_bytes,
        "fixed_slot_kv_bytes": fixed_bytes,
        "bytes_ok": bool(bytes_ok),
        "prefix_hit_rate": pstats.get("prefix_hit_rate", 0.0),
        "shared_prefix_tokens": sum(
            t["shared_prefix_tokens"]
            for t in clean_ps["per_tenant"].values()),
        "prefill_tokens": sum(
            t["prefill_tokens"] for t in clean_ps["per_tenant"].values()),
        "completed": sum(
            t["completed"] for t in clean_ps["per_tenant"].values()),
        "throughput_tok_s": clean_ps["throughput_tok_s"],
    })
    _publish_cell(obs, plan, metrics)
    return {"plan": plan, "metrics": metrics,
            "seconds": time.perf_counter() - t0}


def run_rebuild_cell(plan: PagingCellPlan, spec: PagingSoakSpec, *,
                     rebuild_engine, obs=None) -> dict:
    """One persistent KV flip under ``policy=recompute``: the engine must
    detect it, evict the corrupt page, and re-prefill the owner online."""
    t0 = time.perf_counter()
    grid = [(s, spec.seed + 17 * i)
            for i, s in enumerate(plan.inject_steps)]
    stream = _stream(spec, rebuild_engine)

    from repro.serving.engine import FaultInjection
    rebuild_engine.reset_state()
    clean = rebuild_engine.run(stream)
    clean_toks = _token_map(clean)
    clean_flags = len(clean.detection_steps())
    clean_steps = len(clean.steps)

    detected = applied = rebuilds = aborted = completed = 0
    escapes = 0
    for step, seed in grid:
        rebuild_engine.reset_state()
        faulty = rebuild_engine.run(stream, inject=[FaultInjection(
            step=step, target="kv", persistent=True, seed=seed)], obs=obs)
        st = next(iter(rebuild_engine.paging_stats().values()), {})
        rebuilds += int(st.get("page_rebuilds", 0))
        summ = faulty.summary()
        inj = summ["faults"]["injections"]
        applied += len(inj) > 0
        detected += any(i["detected"] for i in inj)
        toks = _token_map(faulty)
        corrupted = [rid for rid in toks
                     if toks[rid] != clean_toks.get(rid)]
        if inj and not any(i["detected"] for i in inj) and corrupted:
            escapes += 1
        aborted += sum(t["aborted"]
                       for t in summ["per_tenant"].values())
        completed += sum(t["completed"]
                         for t in summ["per_tenant"].values())
    rebuild_engine.reset_state()

    n = max(applied, 1)
    metrics = PagingMetrics({
        "samples": applied,
        "detected": detected,
        "detection_rate": detected / n,
        "escapes": escapes,
        "escape_rate": escapes / n,
        "false_positives": clean_flags,
        "clean_samples": clean_steps,
        "fp_rate": clean_flags / clean_steps if clean_steps else 0.0,
        "analytic_bound": None,
        "overhead": None,
        "page_rebuilds": rebuilds,
        "rebuild_ok": bool(rebuilds >= 1 and completed > 0),
        "aborted": aborted,
        "completed": completed,
    })
    _publish_cell(obs, plan, metrics)
    return {"plan": plan, "metrics": metrics,
            "seconds": time.perf_counter() - t0}


def _publish_cell(obs, plan: PagingCellPlan,
                  metrics: PagingMetrics) -> None:
    if obs is None:
        return
    from repro.obs import FaultEvent
    reg = obs.registry
    reg.counter("repro_injections_total",
                "injected faults per campaign cell"
                ).inc(metrics["samples"], cell=plan.cell_id)
    reg.counter("repro_detections_total",
                "online-detected injected faults per campaign cell"
                ).inc(metrics["detected"], cell=plan.cell_id)
    reg.counter("repro_false_positives_total",
                "clean-pass flags per campaign cell"
                ).inc(metrics["false_positives"], cell=plan.cell_id)
    obs.bus.emit(FaultEvent(
        op=plan.target, kind="cell", step=0, source="serving.paging",
        cell_id=plan.cell_id, errors=metrics["detected"],
        checks=metrics["samples"],
        detector_value=metrics["detection_rate"],
        attrs={k: metrics[k] for k in
               ("fp_rate", "false_positives", "parity_ok", "verify_ok",
                "bytes_ok", "rebuild_ok", "page_rebuilds")
               if k in metrics.to_dict()}))


# ------------------------------ campaign ------------------------------------

def quick_paging_spec(seed: int = 0, plan: Optional[str] = None
                      ) -> PagingSoakSpec:
    # pool sizing: 4 slots * 6 pages worst case = 24 referenced pages;
    # 28 leaves warm-prefix headroom while staying strictly below the
    # fixed-slot layout's bytes, so the cell's memory bit measures real
    # LRU eviction behavior rather than an oversized pool
    return PagingSoakSpec(
        name="paging", arch=PAGING_ARCH, n_requests=24, n_slots=4,
        rate_rps=200.0, max_new_tokens=16, page_size=8, n_pages=28,
        prefix_len=16, n_faults=6, seed=seed,
        plan=plan if plan is not None else PAGED_PLAN)


def full_paging_spec(seed: int = 0, plan: Optional[str] = None
                     ) -> PagingSoakSpec:
    return PagingSoakSpec(
        name="paging", arch=PAGING_ARCH, n_requests=64, n_slots=4,
        rate_rps=200.0, max_new_tokens=16, page_size=8, n_pages=28,
        prefix_len=16, n_faults=12, seed=seed,
        plan=plan if plan is not None else PAGED_PLAN)


def paging_plans(spec: PagingSoakSpec):
    grid = _fault_grid(spec)
    steps = tuple(s for s, _ in grid)
    base = dict(arch=spec.arch, n_requests=spec.n_requests,
                n_slots=spec.n_slots, rate_rps=spec.rate_rps,
                page_size=spec.page_size, n_pages=spec.n_pages,
                prefix_len=spec.prefix_len, seed=spec.seed)
    return [
        PagingCellPlan(cell_id=f"paging/parity/{spec.arch}",
                       target="paging", kind="parity",
                       inject_steps=steps, plan=spec.plan, **base),
        PagingCellPlan(cell_id=f"paging/rebuild/{spec.arch}",
                       target="paging", kind="rebuild",
                       inject_steps=steps[:2],
                       plan=spec.rebuild_plan, **base),
    ]


def run_paging_campaign(spec: Optional[PagingSoakSpec] = None, *,
                        quick: bool = True, seed: int = 0,
                        plan: Optional[str] = None,
                        out_dir: Optional[str] = None,
                        verbose=None, obs=None) -> dict:
    """Run the parity + rebuild cells; returns (and optionally writes)
    the ``BENCH_campaign_paging[_quick]`` artifact dict."""
    from repro.campaign.artifacts import campaign_to_dict, write_artifacts

    if spec is None:
        spec = (quick_paging_spec(seed, plan) if quick
                else full_paging_spec(seed, plan))
    t0 = time.perf_counter()
    paged, contig, rebuild = _engines(spec)
    cells = []
    for cp in paging_plans(spec):
        if cp.kind == "parity":
            cell = run_parity_cell(cp, spec, paged_engine=paged,
                                   contig_engine=contig, obs=obs)
        else:
            cell = run_rebuild_cell(cp, spec, rebuild_engine=rebuild,
                                    obs=obs)
        cells.append(cell)
        if verbose:
            m = cell["metrics"]
            extra = (f"parity={m['parity_ok']} verify={m['verify_ok']} "
                     f"bytes={m['bytes_ok']}" if cp.kind == "parity"
                     else f"rebuilds={m['page_rebuilds']} "
                          f"ok={m['rebuild_ok']}")
            verbose(f"[{cp.cell_id}] inj={m['samples']} "
                    f"detect={m['detection_rate']:.2f} "
                    f"fp={m['fp_rate']:.4f} {extra} "
                    f"({cell['seconds']:.1f}s)")
    name = "paging_quick" if quick else "paging"
    result = campaign_to_dict(name, [spec], cells, [],
                              wall_s=time.perf_counter() - t0,
                              seed=spec.seed)
    if out_dir is not None:
        write_artifacts(result, out_dir)
    return result


__all__ = ["PagingSoakSpec", "PagingCellPlan", "PagingMetrics",
           "wilson_interval", "intervals_overlap", "run_parity_cell",
           "run_rebuild_cell", "paging_plans", "run_paging_campaign",
           "quick_paging_spec", "full_paging_spec", "PAGING_ARCH",
           "PAGED_PLAN", "CONTIG_PLAN", "REBUILD_PLAN"]
