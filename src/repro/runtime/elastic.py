"""Elastic re-mesh: shrink/grow the data axis and re-shard live state.

Failure story at scale (DESIGN.md §5): a host dies -> the job restarts on
the surviving N-k hosts (or a standby pool swaps in). The *model* axes must
keep their size (TP/EP shardings bake into the weights' divisibility); the
*data* (and pod) axes are elastic. ``plan_remesh`` computes the largest
valid data axis for the surviving device count; ``remesh_state`` re-places
a state pytree (from a checkpoint restore or live donation) onto the new
mesh with shardings re-derived from the same logical rules.

The batch contract: global batch stays fixed (per-replica batch grows), so
training dynamics and the data stream (seeded by step) are unchanged — an
elastic event is invisible in the loss curve modulo one repeated step.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class RemeshPlan:
    old_shape: tuple
    new_shape: tuple
    axes: tuple
    dropped_devices: int

    @property
    def data_parallel(self) -> int:
        sizes = dict(zip(self.axes, self.new_shape))
        return sizes.get("data", 1) * sizes.get("pod", 1)


def plan_remesh(n_devices: int, *, model_parallel: int = 16,
                axes=("data", "model"),
                old_shape: Optional[tuple] = None) -> RemeshPlan:
    """Largest (data, model) mesh with fixed model axis that fits
    ``n_devices``. Raises if fewer than one model group survives."""
    if n_devices < model_parallel:
        raise ValueError(
            f"{n_devices} devices cannot host model_parallel="
            f"{model_parallel}; a standby pool or smaller TP is required")
    data = n_devices // model_parallel
    new_shape = (data, model_parallel)
    used = data * model_parallel
    return RemeshPlan(old_shape or new_shape, new_shape, tuple(axes),
                      n_devices - used)


def make_mesh_from_plan(plan: RemeshPlan):
    import jax
    from jax.sharding import Mesh

    n = int(np.prod(plan.new_shape))
    devices = np.asarray(jax.devices()[:n]).reshape(plan.new_shape)
    return Mesh(devices, plan.axes)


def remesh_state(state, lp_tree, rules: dict, mesh):
    """Re-place ``state`` onto ``mesh`` using logical-axis ``rules``.

    ``lp_tree`` is the LogicalParam tree (axes metadata); ``state`` is the
    matching value tree (params or full train state leaf-aligned subtree).
    """
    import jax
    from repro.sharding import shardings_of

    sh = shardings_of(lp_tree, rules, mesh)
    return jax.tree.map(jax.device_put, state, sh)
