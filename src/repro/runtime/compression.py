"""int8 gradient compression with error feedback + checksum-verified
all-reduce — the paper's quantized-operator + ABFT recipe applied to the
data-parallel collective (beyond paper, DESIGN.md §5).

Scheme per leaf:
  1. residual-corrected gradient g' = g + e  (error feedback)
  2. per-leaf symmetric int8 quantization: q = round(g' / s), s = max|g'|/127
  3. all-reduce the int8 payload **in int32** (sums of <=127-magnitude int8
     over <= 2^24 replicas cannot overflow) and all-reduce the scales;
  4. verify: the mod-(2^31-1) value-checksum of an integer sum equals the
     mod-sum of the per-replica checksums (additivity) — so one extra scalar
     psum per leaf detects a corrupted reduction without re-sending data;
  5. e <- g' - dequant(q)  (local residual for the next step).

Detection-only + policy, exactly like the GEMM ABFT: on mismatch the loop's
policy decides (log / recompute the step / restore from checkpoint).

All functions are shard_map/pjit-friendly: they take an ``axis_name``.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

# 2^13-1 (Mersenne prime). Residues < 8191 sum exactly in int32 across
# chunks of 262k elements and across 262k replicas — no int64 needed (JAX
# x64 is off in production configs).
MOD = 8191


class CompressionState(NamedTuple):
    error: dict   # per-leaf f32 residuals (error feedback memory)


def init_compression(params) -> CompressionState:
    return CompressionState(
        error=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                           params))


def _quantize_leaf(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _mod_checksum(q_i32: jax.Array, mod: int = MOD) -> jax.Array:
    """Value checksum of an int32 tensor, additive under summation.

    Residues of a sum == sum of residues (mod M). Chunked reduction keeps
    every int32 partial sum exact (chunk * mod < 2^31), so the checksum is
    bit-exact for any leaf size without int64.
    """
    r = q_i32.reshape(-1) % mod            # non-negative residues < mod
    chunk = (2 ** 31 - 1) // mod           # exact-accumulation bound
    while r.size > chunk:
        pad = (-r.size) % chunk
        r = jnp.pad(r, (0, pad))
        r = jnp.sum(r.reshape(-1, chunk), axis=1) % mod
    return (jnp.sum(r) % mod).astype(jnp.int32)


def compress_grads(grads, state: CompressionState):
    """-> (payload {q:int8, scale:f32, checksum:int64}, new_state)."""
    corrected = jax.tree.map(
        lambda g, e: g.astype(jnp.float32) + e, grads, state.error)
    qs = jax.tree.map(_quantize_leaf, corrected)
    q = jax.tree.map(lambda t: t[0], qs,
                     is_leaf=lambda x: isinstance(x, tuple))
    scale = jax.tree.map(lambda t: t[1], qs,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_error = jax.tree.map(
        lambda c, qq, s: c - qq.astype(jnp.float32) * s, corrected, q, scale)
    checksum = jax.tree.map(
        lambda qq: _mod_checksum(qq.astype(jnp.int32)), q)
    payload = {"q": q, "scale": scale, "checksum": checksum}
    return payload, CompressionState(error=new_error)


def verify_payload(payload: dict) -> jax.Array:
    """Recompute checksums of a (possibly transported) payload; -> #mismatches.

    Host-to-host transport (RDMA, spilled buffers) is exactly where silent
    corruption was observed at scale [Dixit et al. 2021]; this is the local
    receive-side check when the collective is staged manually.
    """
    got = jax.tree.map(
        lambda q: _mod_checksum(q.astype(jnp.int32)), payload["q"])
    errs = jax.tree.map(lambda e, g: (e != g).astype(jnp.int32),
                        payload["checksum"], got)
    return jax.tree.reduce(lambda a, b: a + b, errs,
                           jnp.zeros((), jnp.int32))


def checked_psum(payload: dict, axis_name: Optional[str]):
    """All-reduce the int8 payload with ABFT verification.

    Returns (summed_q int32 tree, mean_scale tree, err_count int32 scalar).

    ``axis_name=None`` is the single-device degenerate collective: the
    "sum" is the payload itself, but the additivity check still runs —
    recomputing each leaf's checksum against the one encoded at compress
    time.  That makes the mismatch branch reachable (and testable) without
    a mesh, and is the receive-side verify for a payload that crossed any
    transport between :func:`compress_grads` and here.
    """
    def psum(x):
        return x if axis_name is None else jax.lax.psum(x, axis_name)

    q32 = jax.tree.map(lambda q: q.astype(jnp.int32), payload["q"])
    summed = jax.tree.map(psum, q32)
    scale_sum = jax.tree.map(psum, payload["scale"])
    # additivity check: checksum(psum(q)) == psum(checksum(q)) mod M
    expected = jax.tree.map(
        lambda c: psum(c % MOD) % MOD, payload["checksum"])
    got = jax.tree.map(_mod_checksum, summed)
    errs = jax.tree.map(
        lambda e, g: (e != g).astype(jnp.int32), expected, got)
    err_count = jax.tree.reduce(lambda a, b: a + b, errs,
                                jnp.zeros((), jnp.int32))
    return summed, scale_sum, err_count


def checked_psum_attributed(payload: dict, axis_name: Optional[str]):
    """:func:`checked_psum` + shard-local receive-side attribution.

    Returns (summed_q, mean_scale, err_count, local_errs).  ``err_count``
    is the collective additivity verdict — checksum(psum(q)) vs
    psum(checksum(q)) — which is what detects in-transit corruption (the
    sender's recompute cannot see a flip that happens on the wire) and is
    replicated across the axis.  ``local_errs`` is THIS shard's
    :func:`verify_payload` count — a per-shard recompute of the payload it
    is about to contribute, so a staged/manual collective can attribute a
    mismatch to the shard that carried it instead of only knowing "the
    reduction was wrong".  Campaign soaks fold the per-shard counts into
    the artifact's ``shard_detections`` column.
    """
    local_errs = verify_payload(payload)
    summed, scale_sum, errs = checked_psum(payload, axis_name)
    return summed, scale_sum, errs, local_errs


def decompress_grads(summed_q, scale_sum, n_replicas: int):
    """Mean gradient: (Σ_r q_r) * (Σ_r s_r / R) / R ≈ mean(g).

    Each replica quantized with its own scale; using the mean scale on the
    summed payload is exact when scales agree and first-order otherwise —
    the error-feedback residual absorbs the difference next step.
    """
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * (s / n_replicas) / n_replicas,
        summed_q, scale_sum)


def compressed_allreduce(grads, state: CompressionState,
                         axis_name: Optional[str], n_replicas: int):
    """One-call fused path: compress -> checked psum -> decompress.

    ``axis_name=None`` with ``n_replicas=1`` is the single-device path
    (verify-only, no collective).  -> (mean_grads f32, new_state,
    err_count)."""
    payload, new_state = compress_grads(grads, state)
    summed, scale_sum, errs = checked_psum(payload, axis_name)
    mean = decompress_grads(summed, scale_sum, n_replicas)
    return mean, new_state, errs
