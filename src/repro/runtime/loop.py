"""Fault-tolerant training loop.

Composes the substrate into the driver a cluster job actually runs:

    loop = TrainLoop(model, ctx, mesh, rules, cfg)
    loop.run(steps)

Per step:
  1. next batch from the seeded pipeline (pure fn of step — restart-safe),
  2. jitted train step (grad accum, AdamW, ABFT reports in metrics),
  3. **detect -> act**: if the step's FaultReport shows errors, policy:
       - ``log``: record and continue (transient, detection-only — paper's
         default for serving);
       - ``recompute``: re-run the same step from the pre-step state (the
         paper's "error striking twice is very rare" argument — one retry);
       - ``restore``: reload last checkpoint (persistent corruption);
  4. straggler telemetry,
  5. async checksummed checkpoint every ``save_every``.

Crash-restart: ``run`` resumes from the newest committed checkpoint; the
data pipeline regenerates the exact stream from the step index.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.runtime.straggler import StragglerMonitor

log = logging.getLogger("repro.loop")


@dataclasses.dataclass
class LoopConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    save_every: int = 100
    keep_last: int = 3
    fault_policy: str = "recompute"   # log | recompute | restore
    max_recomputes_per_step: int = 1
    straggler_threshold: float = 2.0
    log_every: int = 10


class TrainLoop:
    """Drives (state, batch) -> (state, metrics) with fault handling."""

    def __init__(self, step_fn: Callable, dataset, *, cfg: LoopConfig,
                 shardings=None, metrics_hook: Optional[Callable] = None,
                 obs=None, monitor=None, adapt=None,
                 on_threshold: Optional[Callable] = None):
        self.step_fn = step_fn
        self.dataset = dataset
        self.cfg = cfg
        self.shardings = shardings
        self.metrics_hook = metrics_hook
        if monitor is not None and obs is None:
            from repro.obs import Observability
            obs = Observability.create()
        self.obs = obs
        #: detection-health Monitor fed by this loop's step summaries
        self.monitor = monitor
        if monitor is not None:
            monitor.bind(obs)
        #: AdaptiveThresholds bundle ticked after each observed step —
        #: the train-side twin of the serving engine's threshold loop.
        #: The step_fn is caller-jitted, so applying a moved bound is the
        #: caller's job: ``on_threshold(moved)`` receives the
        #: {(op, tenant): new_bound} map and may return a replacement
        #: step_fn (re-jitted against the new plan); returning ``None``
        #: keeps the current one (log-only adaptation).
        if adapt is not None and monitor is None:
            raise ValueError("adapt= needs monitor= (its sensor)")
        self.adapt = adapt
        self.on_threshold = on_threshold
        if adapt is not None:
            adapt.bind(obs)
        self.ckpt = CheckpointManager(cfg.ckpt_dir,
                                      keep_last=cfg.keep_last,
                                      save_every=cfg.save_every)
        self.straggler = StragglerMonitor(threshold=cfg.straggler_threshold)
        self.stats = {"recomputes": 0, "restores": 0, "faulty_steps": 0}

    # ------------------------------------------------------------------
    #: legacy alias keys FaultReport.as_metrics emits NEXT TO the keyed
    #: counters (gemm = qgemm + float_gemm, eb = embedding_bag) — summing
    #: them alongside the keyed set would double-count
    _LEGACY_ALIASES = ("abft/gemm_errors", "abft/eb_errors")

    def _errors_in(self, metrics: Dict[str, Any]) -> int:
        keyed = [k for k in metrics
                 if k.startswith("abft/") and k.endswith("_errors")
                 and k not in self._LEGACY_ALIASES]
        keys = keyed or [k for k in self._LEGACY_ALIASES if k in metrics]
        keys += [k for k in ("comm/errors",) if k in metrics]
        # grad-accum steps AVERAGE metrics over microbatches, so a single
        # detection can arrive as a fraction (e.g. 0.25 with accum=4) —
        # ceil instead of truncate, or the policy would never fire
        total = sum(float(np.asarray(jax.device_get(metrics[k])))
                    for k in keys)
        return int(np.ceil(total))

    def _put_batch(self, batch):
        if self.shardings is None:
            return batch
        from repro.data import shard_batch
        return shard_batch(batch, self.shardings)

    def _observe_step(self, step: int, metrics, dur_s: float) -> None:
        """One trained step lands in the obs layer (span, step counters,
        per-op ABFT counters, detection events) — the train-side twin of
        the serving engine's per-step emission."""
        if self.obs is None:
            return
        from repro.protect.runtime import observe_metrics
        now = self.obs.tracer.now_s()
        self.obs.tracer.add_span("train_step", cat="runtime",
                                 start_s=now - dur_s, dur_s=dur_s,
                                 step=step)
        self.obs.registry.counter(
            "repro_steps_total", "executed steps by kind and source"
        ).inc(1, kind="train", source="runtime.loop")
        self.obs.registry.histogram(
            "repro_step_duration_ms", "step wall time (ms)"
        ).observe(1e3 * dur_s, kind="train")
        observe_metrics(jax.device_get(metrics), source="runtime.loop",
                        step=step, t_s=now, obs=self.obs,
                        attrs={"kind": "train",
                               "duration_ms": 1e3 * dur_s})

    # ------------------------------------------------------------------
    def run(self, state, n_steps: int, *, start_step: Optional[int] = None,
            resume: bool = True):
        """Run to ``n_steps`` (absolute). Returns (state, last_metrics)."""
        step = 0 if start_step is None else start_step
        if resume:
            restored = self.ckpt.restore_latest(jax.device_get(state))
            if restored is not None:
                snap, step = restored
                state = jax.tree.map(
                    lambda cur, new: jax.device_put(
                        np.asarray(new),
                        getattr(cur, "sharding", None) or jax.devices()[0]),
                    state, snap)
                log.info("resumed from checkpoint at step %d", step)

        metrics = {}
        while step < n_steps:
            batch = self._put_batch(self.dataset.batch_at(step))
            self.straggler.step_start()
            pre_state = state
            t_step = time.perf_counter()
            state, metrics = self.step_fn(state, batch)

            errs = self._errors_in(metrics)
            # observe the PRE-policy metrics: a recompute that clears the
            # flag must not erase the detection from the event stream
            self._observe_step(step, metrics,
                               time.perf_counter() - t_step)
            if self.adapt is not None and self.monitor is not None:
                moved = self.adapt.tick(self.monitor,
                                        t_s=self.obs.tracer.now_s()
                                        if self.obs else 0.0, step=step)
                if moved and self.on_threshold is not None:
                    new_fn = self.on_threshold(moved)
                    if new_fn is not None:
                        self.step_fn = new_fn
            if errs:
                self.stats["faulty_steps"] += 1
                if self.cfg.fault_policy == "recompute":
                    for _ in range(self.cfg.max_recomputes_per_step):
                        self.stats["recomputes"] += 1
                        state, metrics = self.step_fn(pre_state, batch)
                        if self._errors_in(metrics) == 0:
                            break
                    else:
                        log.warning(
                            "step %d still faulty after recompute", step)
                elif self.cfg.fault_policy == "restore":
                    restored = self.ckpt.restore_latest(
                        jax.device_get(state))
                    if restored is not None:
                        snap, step = restored
                        state = jax.tree.map(
                            lambda cur, new: jax.device_put(
                                np.asarray(new),
                                getattr(cur, "sharding", None)
                                or jax.devices()[0]),
                            state, snap)
                        self.stats["restores"] += 1
                        continue
                # "log": fall through

            self.straggler.step_end(step)
            step += 1
            self.ckpt.maybe_save(step, state)
            if self.metrics_hook and step % self.cfg.log_every == 0:
                self.metrics_hook(step, jax.device_get(metrics))

        self.ckpt.maybe_save(step, state, force=True)
        self.ckpt.wait()
        return state, metrics
