"""Straggler detection from step-time telemetry.

At thousand-node scale a single slow host (thermal throttling, failing NIC,
the SDC-adjacent "degraded but not dead" mode of [Dixit et al. 2021])
gates every synchronous step. The monitor keeps a robust running estimate
of per-step latency and flags:

- **step stragglers**: a step slower than ``threshold`` x the rolling
  median — logged, and after ``patience`` consecutive flags the policy
  callback fires (typical action: trigger elastic re-mesh to evict the
  slow host, or dump a profile).
- **persistent skew** (multi-host): per-host step times gathered via the
  telemetry all-gather that piggybacks on metrics; hosts consistently
  ``threshold``x slower than the fleet median are reported.

Pure host-side python over already-materialized metrics — zero device cost.
"""
from __future__ import annotations

import collections
import time
from typing import Callable, Deque, Dict, List, Optional


class StragglerMonitor:
    def __init__(self, *, window: int = 50, threshold: float = 2.0,
                 patience: int = 3,
                 on_straggler: Optional[Callable[[dict], None]] = None):
        self.window = window
        self.threshold = threshold
        self.patience = patience
        self.on_straggler = on_straggler
        self._times: Deque[float] = collections.deque(maxlen=window)
        self._consecutive = 0
        self._last_start: Optional[float] = None
        self.events: List[dict] = []

    # ------------------------------------------------------------------
    def step_start(self):
        self._last_start = time.monotonic()

    def step_end(self, step: int, host_times: Optional[Dict[int, float]]
                 = None) -> Optional[dict]:
        """Record a step; returns an event dict if this step straggled."""
        assert self._last_start is not None, "step_start not called"
        dt = time.monotonic() - self._last_start
        self._last_start = None
        return self.observe(step, dt, host_times)

    def observe(self, step: int, dt: float,
                host_times: Optional[Dict[int, float]] = None
                ) -> Optional[dict]:
        med = self.median()
        self._times.append(dt)
        event = None
        if med is not None and dt > self.threshold * med:
            self._consecutive += 1
            event = {"step": step, "dt": dt, "median": med,
                     "ratio": dt / med,
                     "consecutive": self._consecutive}
            if host_times:
                fleet_med = sorted(host_times.values())[len(host_times) // 2]
                event["slow_hosts"] = [
                    h for h, t in host_times.items()
                    if t > self.threshold * fleet_med]
            self.events.append(event)
            if (self._consecutive >= self.patience
                    and self.on_straggler is not None):
                self.on_straggler(event)
                self._consecutive = 0
        else:
            self._consecutive = 0
        return event

    def median(self) -> Optional[float]:
        if len(self._times) < max(5, self.window // 10):
            return None
        s = sorted(self._times)
        return s[len(s) // 2]

    def summary(self) -> dict:
        return {"steps": len(self._times), "median": self.median(),
                "straggler_events": len(self.events)}
