from repro.runtime.compression import (  # noqa: F401
    CompressionState,
    compress_grads,
    decompress_grads,
    init_compression,
    checked_psum,
)
from repro.runtime.straggler import StragglerMonitor  # noqa: F401
from repro.runtime.elastic import plan_remesh, remesh_state  # noqa: F401
from repro.runtime.loop import TrainLoop, LoopConfig  # noqa: F401
