"""Quantized tensors and the Eq. (1) quantized GEMM pipeline.

Paper convention (§III-A): ``x ≈ alpha * x_I + beta`` where ``x_I`` is an
8-bit integer.  Activations (matrix A) are quantized to *unsigned* 8-bit with
a per-row dynamic range; weights (matrix B) to *signed* 8-bit, symmetric
(beta = 0) per output channel, which is the FBGEMM/DLRM deployment default.

The quantized matrix product (Eq. 1) is::

    AB ≈ aA*aB * (A_I @ B_I)
       + aA*bB * (A_I @ e) e^T
       + aB*bA * e (e^T @ B_I)
       + k*bA*bB * e e^T

i.e. the int32 product ``C_temp = A_I @ B_I`` plus rank-1 corrections.
ABFT (repro.core.abft_gemm) verifies ``C_temp`` *before* requantization
(§IV-B: requantization is non-linear, checksums cannot survive it).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

INT8_MIN, INT8_MAX = -128, 127
UINT8_MAX = 255


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QTensor:
    """An integer tensor with affine dequantization parameters.

    ``values`` has an integer dtype; ``alpha``/``beta`` broadcast against the
    value tensor along ``axis`` (None => per-tensor scalars).
    """

    values: jax.Array          # int8 / uint8 (stored as int8 with unsigned flag)
    alpha: jax.Array           # f32, scalar or per-row/per-channel
    beta: jax.Array            # f32, same shape as alpha
    axis: Optional[int] = None  # axis the (alpha, beta) pairs index, or None

    def tree_flatten(self):
        return (self.values, self.alpha, self.beta), (self.axis,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        values, alpha, beta = children
        return cls(values, alpha, beta, axis=aux[0])

    @property
    def shape(self):
        return self.values.shape

    @property
    def dtype(self):
        return self.values.dtype


def _expand(param: jax.Array, ndim: int, axis: Optional[int]) -> jax.Array:
    """Broadcast a per-axis parameter vector against an ndim tensor."""
    if axis is None:
        return param
    shape = [1] * ndim
    shape[axis] = -1
    return param.reshape(shape)


def quantize_tensor(x: jax.Array, *, unsigned: bool = False) -> QTensor:
    """Per-tensor affine quantization of ``x`` into 8 bits."""
    return _quantize(x, axis=None, unsigned=unsigned)


def quantize_rows(x: jax.Array, *, unsigned: bool = True) -> QTensor:
    """Per-row dynamic quantization (activation matrices; paper's A)."""
    return _quantize(x, axis=0, unsigned=unsigned)


def quantize_channels(w: jax.Array, *, unsigned: bool = False) -> QTensor:
    """Per-output-channel (column) symmetric quantization (weights; paper's B)."""
    # Symmetric: beta = 0 keeps the rank-1 correction terms cheap and the
    # int32 accumulator centered.
    amax = jnp.max(jnp.abs(w), axis=0)
    alpha = jnp.maximum(amax, 1e-12) / INT8_MAX
    q = jnp.clip(jnp.round(w / alpha[None, :]), INT8_MIN, INT8_MAX).astype(jnp.int8)
    return QTensor(q, alpha.astype(jnp.float32),
                   jnp.zeros_like(alpha, dtype=jnp.float32), axis=1)


def _quantize(x: jax.Array, *, axis: Optional[int], unsigned: bool) -> QTensor:
    reduce_axes = tuple(i for i in range(x.ndim) if axis is None or i != axis)
    xmin = jnp.min(x, axis=reduce_axes)
    xmax = jnp.max(x, axis=reduce_axes)
    lo, hi = (0, UINT8_MAX) if unsigned else (INT8_MIN, INT8_MAX)
    span = jnp.maximum(xmax - xmin, 1e-12)
    alpha = span / (hi - lo)
    beta = xmin - lo * alpha
    xe = x
    a = _expand(alpha, x.ndim, axis)
    b = _expand(beta, x.ndim, axis)
    q = jnp.clip(jnp.round((xe - b) / a), lo, hi)
    # uint8 stored as int8 bit-pattern free; keep uint8 dtype for clarity.
    dtype = jnp.uint8 if unsigned else jnp.int8
    return QTensor(q.astype(dtype), alpha.astype(jnp.float32),
                   beta.astype(jnp.float32), axis=axis)


def dequantize(q: QTensor) -> jax.Array:
    a = _expand(q.alpha, q.values.ndim, q.axis)
    b = _expand(q.beta, q.values.ndim, q.axis)
    return a * q.values.astype(jnp.float32) + b


def int_matmul(a_q: jax.Array, b_q: jax.Array) -> jax.Array:
    """``C_temp = A_I @ B_I`` in int32 (the MXU int8 path on TPU)."""
    # int8 operands directly (no 4x int32 staging copies; §Perf)
    return jax.lax.dot_general(
        a_q, b_q, (((a_q.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def qgemm_f32(a: QTensor, b: QTensor,
              c_temp: Optional[jax.Array] = None) -> jax.Array:
    """Full Eq. (1) pipeline: int32 product + rank-1 corrections -> f32.

    ``c_temp`` may be supplied when the caller already computed the int32
    product (e.g. through the ABFT-verified path) so the correction terms
    reuse it.
    """
    m, k = a.values.shape
    n = b.values.shape[1]
    if c_temp is None:
        c_temp = int_matmul(a.values, b.values)
    a_alpha = a.alpha if a.axis == 0 else jnp.broadcast_to(a.alpha, (m,))
    a_beta = a.beta if a.axis == 0 else jnp.broadcast_to(a.beta, (m,))
    b_alpha = b.alpha if b.axis == 1 else jnp.broadcast_to(b.alpha, (n,))
    b_beta = b.beta if b.axis == 1 else jnp.broadcast_to(b.beta, (n,))

    out = (a_alpha[:, None] * b_alpha[None, :]) * c_temp.astype(jnp.float32)
    # + aA*bB * (A_I @ e_k) e_n^T   (row sums of A)
    a_rows = jnp.sum(a.values.astype(jnp.int32), axis=1).astype(jnp.float32)
    out = out + (a_alpha * a_rows)[:, None] * b_beta[None, :]
    # + aB*bA * e_m (e_k^T @ B_I)   (col sums of B)
    b_cols = jnp.sum(b.values.astype(jnp.int32), axis=0).astype(jnp.float32)
    out = out + a_beta[:, None] * (b_alpha * b_cols)[None, :]
    # + k*bA*bB
    out = out + k * a_beta[:, None] * b_beta[None, :]
    return out


def requantize(x: jax.Array, *, unsigned: bool = False) -> QTensor:
    """Requantization ``Q`` of a float matrix into 8 bits (Fig. 1 last stage)."""
    return quantize_rows(x, unsigned=unsigned)
