"""Affine quantization substrate (paper §III-A).

Representation follows the paper: a real tensor ``x`` is represented by an
integer tensor ``x_I`` plus floating-point ``(alpha, beta)`` such that
``x ≈ alpha * x_I + beta``.
"""
from repro.quant.qtensor import (
    QTensor,
    quantize_tensor,
    quantize_rows,
    quantize_channels,
    dequantize,
    qgemm_f32,
    requantize,
)

__all__ = [
    "QTensor",
    "quantize_tensor",
    "quantize_rows",
    "quantize_channels",
    "dequantize",
    "qgemm_f32",
    "requantize",
]
