"""The paper's contribution: ABFT soft-error detection for low-precision ops.

- :mod:`repro.core.abft_gemm`      — Algorithm 1 (ABFT for quantized GEMM)
- :mod:`repro.core.abft_embedding` — Algorithm 2 (ABFT for quantized EmbeddingBag)
- :mod:`repro.core.abft_kvcache`   — beyond-paper quantized KV cache + checksums
- :mod:`repro.core.abft_float`     — beyond-paper float ABFT (training GEMMs)
- :mod:`repro.core.inject`         — bit-flip / value-replacement fault injection
- :mod:`repro.core.policy`         — FaultReport plumbing + detect->act policies
- :mod:`repro.core.checksum`       — pytree mod-checksums (checkpoints, collectives)

This package namespace is the stable import surface for the checksum
algebra.  Call sites (layers, kernels, benchmarks, examples) should import
from ``repro.core`` or — for protected execution — go through
:mod:`repro.protect`; the ``repro.core.abft_*`` module paths are an
implementation detail.
"""
from repro.core.abft_gemm import (
    LANE,
    MOD,
    AbftGemmOut,
    abft_qgemm,
    abft_qgemm_packed,
    abft_qgemm_unfused,
    correct_single_error,
    correct_weight_flip,
    detect_prob_b_bitflip,
    detect_prob_b_random,
    detect_prob_c_random,
    encode_activation_checksum,
    encode_weight_checksum,
    encode_weight_colsum,
    pack_encoded_b,
    verify_rows,
)
from repro.core.abft_embedding import (
    EB_REL_BOUND,
    AbftEbOut,
    abft_embedding_bag,
    eb_overhead_model,
    embedding_bag,
    table_rowsums,
    verify_bags,
)
from repro.core.abft_kvcache import (
    QuantKV,
    attend_quantized,
    dequantize_kv,
    quantize_kv_rows,
    update_kv_row,
    verify_kv,
)
from repro.core.abft_float import (
    FloatAbftOut,
    abft_gemm_f32,
    encode_weight_f32,
)
from repro.core.policy import (
    FaultReport,
    empty_report,
    merge_reports,
    op_report,
)

__all__ = [
    "MOD", "LANE", "AbftGemmOut",
    "encode_weight_checksum", "encode_activation_checksum",
    "abft_qgemm", "abft_qgemm_packed", "abft_qgemm_unfused",
    "pack_encoded_b", "verify_rows", "correct_single_error",
    "encode_weight_colsum", "correct_weight_flip",
    "detect_prob_b_bitflip", "detect_prob_b_random", "detect_prob_c_random",
    "EB_REL_BOUND", "AbftEbOut", "table_rowsums", "embedding_bag",
    "abft_embedding_bag", "verify_bags", "eb_overhead_model",
    "QuantKV", "quantize_kv_rows", "dequantize_kv", "verify_kv",
    "update_kv_row", "attend_quantized",
    "FloatAbftOut", "encode_weight_f32", "abft_gemm_f32",
    "FaultReport", "op_report", "merge_reports", "empty_report",
]
