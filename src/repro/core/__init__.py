"""The paper's contribution: ABFT soft-error detection for low-precision ops.

- :mod:`repro.core.abft_gemm`      — Algorithm 1 (ABFT for quantized GEMM)
- :mod:`repro.core.abft_embedding` — Algorithm 2 (ABFT for quantized EmbeddingBag)
- :mod:`repro.core.abft_float`     — beyond-paper float ABFT (training GEMMs)
- :mod:`repro.core.inject`         — bit-flip / value-replacement fault injection
- :mod:`repro.core.policy`         — FaultReport plumbing + detect->act policies
- :mod:`repro.core.checksum`       — pytree mod-checksums (checkpoints, collectives)
"""
from repro.core.abft_gemm import (
    MOD,
    encode_weight_checksum,
    abft_qgemm,
    abft_qgemm_packed,
    pack_encoded_b,
    verify_rows,
)
from repro.core.abft_embedding import (
    table_rowsums,
    embedding_bag,
    abft_embedding_bag,
)
from repro.core.policy import FaultReport, merge_reports, empty_report

__all__ = [
    "MOD",
    "encode_weight_checksum",
    "abft_qgemm",
    "abft_qgemm_packed",
    "pack_encoded_b",
    "verify_rows",
    "table_rowsums",
    "embedding_bag",
    "abft_embedding_bag",
    "FaultReport",
    "merge_reports",
    "empty_report",
]
