"""Beyond-paper: quantized KV cache with ABFT rowsum checksums.

EXPERIMENTS §Perf hillclimb 3 identified the 32k-context decode bottleneck
as the KV cache (12 GB/token/device read vs 7.7 GB of int8 weights).  The
paper's own recipe extends naturally:

  * **quantize** the cache like the paper quantizes embedding tables
    (§III-C): per-(position, head) int8 rows with (α, β) — halves the
    dominant decode term vs bf16;
  * **checksum** it like the paper checksums embedding tables (Alg. 2):
    an int32 rowsum `C_T[pos] = Σ_d k_q[pos, d]` stored beside the cache,
    verified on read — extending soft-error coverage to the largest
    resident state in a serving fleet (the cache lives in HBM for the
    whole request; the paper's §IV-A1 residency argument applies even
    more strongly than for weights, since a corrupted cache poisons every
    subsequent token of the request).

Layout per layer (grouped KV layout of layers.attention):
    k_q, v_q   int8  [B, Kv, S, dh]
    k_a/k_b, v_a/v_b  f32 [B, Kv, S]     (per-row affine params)
    k_sum, v_sum      int32 [B, Kv, S]   (ABFT rowsums)

Verification (Eq. 5 with pool size 1, exact integer form): a read row is
corrupt iff ``Σ_d k_q[r, d] != k_sum[r]`` — pure int math, zero false
positives, and the check rides the same reduction the dequantization
performs.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class QuantKV(NamedTuple):
    q: jax.Array       # int8 [..., S, dh]
    alpha: jax.Array   # f32  [..., S]
    beta: jax.Array    # f32  [..., S]
    rowsum: jax.Array  # int32 [..., S]  (ABFT checksum, Alg. 2 style)


def quantize_kv_rows(x: jax.Array) -> QuantKV:
    """Per-row affine int8 quantization + rowsum checksum.

    x [..., S, dh] float -> QuantKV. Rows are (position, head) vectors —
    the same granularity the paper uses for embedding rows.
    """
    xf = x.astype(jnp.float32)
    xmin = jnp.min(xf, axis=-1)
    xmax = jnp.max(xf, axis=-1)
    span = jnp.maximum(xmax - xmin, 1e-12)
    alpha = span / 255.0
    beta = xmin + 128.0 * alpha
    q = jnp.clip(jnp.round((xf - beta[..., None]) / alpha[..., None]),
                 -128, 127).astype(jnp.int8)
    rowsum = jnp.sum(q.astype(jnp.int32), axis=-1)
    return QuantKV(q, alpha, beta, rowsum)


def dequantize_kv(kv: QuantKV, dtype=jnp.bfloat16) -> jax.Array:
    return (kv.alpha[..., None] * kv.q.astype(jnp.float32)
            + kv.beta[..., None]).astype(dtype)


def verify_kv(kv: QuantKV, valid_mask=None) -> Tuple[jax.Array, jax.Array]:
    """Exact integer check: (err_rows bool [..., S], err_count int32).

    ``valid_mask`` [..., S] restricts the check to written positions (a
    fresh cache is zeros, which self-consistently checksum to 0 — but the
    mask keeps the error count semantically 'rows in use')."""
    got = jnp.sum(kv.q.astype(jnp.int32), axis=-1)
    err = got != kv.rowsum
    if valid_mask is not None:
        err = err & valid_mask
    return err, jnp.sum(err).astype(jnp.int32)


def update_kv_row(kv: QuantKV, batch_idx: jax.Array, pos: jax.Array,
                  new_row: jax.Array) -> QuantKV:
    """Decode-step cache append: quantize + checksum the new row.

    kv leaves [B, Kv, S, ...]; new_row [B, Kv, dh] float; pos [B].
    """
    nq = quantize_kv_rows(new_row)                     # [B, Kv]
    return QuantKV(
        q=kv.q.at[batch_idx, :, pos].set(nq.q),
        alpha=kv.alpha.at[batch_idx, :, pos].set(nq.alpha),
        beta=kv.beta.at[batch_idx, :, pos].set(nq.beta),
        rowsum=kv.rowsum.at[batch_idx, :, pos].set(nq.rowsum),
    )


def attend_quantized(q_heads: jax.Array, kv_k: QuantKV, kv_v: QuantKV,
                     pos: jax.Array, *, n_heads: int, n_kv: int,
                     verify: bool = True, window=None,
                     prefix_global: int = 0):
    """One-token decode attention straight off the int8 cache.

    q_heads [B, H, dh] (bf16/f32); kv_* int8 caches [B, Kv, S, *].
    Returns (out [B, H, dh] f32, err_count int32).

    ``window`` (sliding-window size, may be a traced scalar) and
    ``prefix_global`` (always-visible prefix length) mirror the masking of
    ``layers.attention.attention_decode`` so the quantized cache is a
    drop-in for windowed archs.

    Scores expand affinely without dequantizing the whole cache:
        q·k_row = α_row (q·k_q_row) + β_row Σ_d q_d
    i.e. ONE int8-resident contraction + rank-1 corrections — the same
    Eq. 1 decomposition the paper uses for GEMM, applied to attention.
    """
    b, h, dh = q_heads.shape
    g = n_heads // n_kv
    s_max = kv_k.q.shape[2]
    qg = q_heads.reshape(b, n_kv, g, dh).astype(jnp.float32)

    errs = jnp.zeros((), jnp.int32)
    if verify:
        kv_pos_ = jnp.arange(s_max)[None, None, :]
        mask = kv_pos_ <= pos[:, None, None]
        _, e1 = verify_kv(kv_k, mask)
        _, e2 = verify_kv(kv_v, mask)
        errs = e1 + e2

    # scores: affine expansion (cache stays int8 in the contraction)
    qk_int = jnp.einsum("bkgd,bksd->bkgs", qg,
                        kv_k.q.astype(jnp.float32))
    qsum = jnp.sum(qg, axis=-1)                          # [B, Kv, g]
    s = (kv_k.alpha[:, :, None, :] * qk_int
         + kv_k.beta[:, :, None, :] * qsum[..., None]) * dh ** -0.5

    kv_pos_ = jnp.arange(s_max)[None, None, None, :]
    valid = kv_pos_ <= pos[:, None, None, None]
    if window is not None:
        in_win = (pos[:, None, None, None] - kv_pos_) < window
        if prefix_global > 0:
            in_win |= kv_pos_ < prefix_global
        valid &= in_win
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)                       # [B, Kv, g, S]

    # output: p @ V = Σ_s p_s (α_s v_q_s + β_s) — same affine split
    pv_int = jnp.einsum("bkgs,bksd->bkgd",
                        p * kv_v.alpha[:, :, None, :],
                        kv_v.q.astype(jnp.float32))
    pbeta = jnp.sum(p * kv_v.beta[:, :, None, :], axis=-1)  # [B,Kv,g]
    out = pv_int + pbeta[..., None]
    return out.reshape(b, h, dh), errs
