"""ABFT for low-precision EmbeddingBag — the paper's Algorithm 2 (§V).

EmbeddingBag with batch size n gathers rows ``I_b`` from a quantized table and
returns ``R_b = Σ_{i∈I_b} w_i (α_i · eb_i + β_i · e_d)`` per bag ``b``.

Detection invariant (Eq. 5, extended with optional per-index weights)::

    Σ_j R_b[j]  ==  Σ_{i∈I_b} w_i (α_i · C_T[i] + d · β_i)

with ``C_T[i] = Σ_j table[i, j]`` precomputed in *unscaled int32* (§V-B: this
minimizes float round-off in the checksum sum).  Since the EB output is
floating point, equality holds up to round-off; the check uses the paper's
loose relative bound (1e-5 by default, §V-D).

Batch layout: fixed-shape ``indices [bags, pool]`` padded with ``-1`` — the
JAX-native analogue of the offsets layout in torch.nn.EmbeddingBag.  Padded
slots contribute nothing to either side of Eq. 5.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

#: paper §V-D: loose relative bound to trade false positives for low-bit misses.
REL_BOUND = 1e-5

#: package-level alias (the name ``repro.core`` exports — "rel bound" alone
#: is ambiguous next to the float-GEMM bound).
EB_REL_BOUND = REL_BOUND


class AbftEbOut(NamedTuple):
    r: jax.Array           # f32 [bags, d]
    err_bags: jax.Array    # bool [bags]
    err_count: jax.Array   # int32 scalar


def table_rowsums(table_q: jax.Array) -> jax.Array:
    """Precompute ``C_T``: exact int32 row sums of the int8/int4 table.

    Amortized like the GEMM weight checksum — the table is frozen after
    training (§V-C), so this is computed once at model load.
    """
    return jnp.sum(table_q.astype(jnp.int32), axis=-1)


def _gather_terms(table_q, alphas, betas, indices, weights):
    """Shared gather of (rows, alpha, beta, weight, validity mask)."""
    valid = indices >= 0
    safe_idx = jnp.where(valid, indices, 0)
    rows = table_q[safe_idx].astype(jnp.float32)        # [bags, pool, d]
    a = alphas[safe_idx]                                 # [bags, pool]
    b = betas[safe_idx]
    w = jnp.ones_like(a) if weights is None else weights
    w = jnp.where(valid, w, 0.0)
    return rows, a, b, w


def embedding_bag(table_q: jax.Array, alphas: jax.Array, betas: jax.Array,
                  indices: jax.Array,
                  weights: Optional[jax.Array] = None) -> jax.Array:
    """The unprotected low-precision EB (§III-C): per-row dequant + bag sum."""
    rows, a, b, w = _gather_terms(table_q, alphas, betas, indices, weights)
    deq = a[..., None] * rows + b[..., None]             # [bags, pool, d]
    return jnp.sum(w[..., None] * deq, axis=1)           # [bags, d]


def verify_bags(rsum: jax.Array, alphas: jax.Array, betas: jax.Array,
                indices: jax.Array, rowsums: jax.Array, d: int,
                weights: Optional[jax.Array] = None,
                rel_bound: float = REL_BOUND) -> jax.Array:
    """The Eq. (5) compare: per-bag error flags from the EB output row sums.

    ``rsum`` is ``Σ_j R_b[j]`` ([bags]), however the forward pass produced
    it (XLA reduction or the Pallas kernel's fused accumulator).  This is
    the ONE definition of the check — the ``rel_bound`` semantics (incl.
    ``threshold=adaptive`` controller moves) must not drift between
    execution paths, so both :func:`abft_embedding_bag` and the Pallas
    wrapper in :mod:`repro.kernels.ops` call here.

    |RSum - CSum| > bound  =>  soft error (Alg. 2 line 5).  The paper uses
    a bound relative to the result; float round-off however scales with
    the ACCUMULATED magnitude, so a cancellation-heavy bag (|Σx| ≪ Σ|x|)
    would false-positive.  We scale the bound by Σ|terms| instead —
    strictly fewer false positives at the paper's rel_bound (its measured
    9.5% FP rate is this very effect), same high-bit sensitivity.
    """
    valid = indices >= 0
    safe_idx = jnp.where(valid, indices, 0)
    a = alphas[safe_idx]
    b = betas[safe_idx]
    w = (jnp.ones_like(a) if weights is None else weights)
    w = jnp.where(valid, w, 0.0)
    ct = rowsums[safe_idx].astype(jnp.float32)           # [bags, pool]
    csum = jnp.sum(w * (a * ct + d * b), axis=-1)        # [bags]
    mag = jnp.sum(jnp.abs(w) * (jnp.abs(a) * jnp.abs(ct)
                                + d * jnp.abs(b)), axis=-1)
    tol = rel_bound * jnp.maximum(mag, 1.0)
    return jnp.abs(rsum - csum) > tol


def abft_embedding_bag(table_q: jax.Array, alphas: jax.Array,
                       betas: jax.Array, indices: jax.Array,
                       rowsums: jax.Array,
                       weights: Optional[jax.Array] = None,
                       rel_bound: float = REL_BOUND) -> AbftEbOut:
    """Algorithm 2: EB forward + Eq. (5) check per bag.

    ``rowsums`` is the precomputed ``C_T`` (int32 [rows]).
    """
    d = table_q.shape[-1]
    r = embedding_bag(table_q, alphas, betas, indices, weights)
    rsum = jnp.sum(r, axis=-1)                           # [bags]
    err_bags = verify_bags(rsum, alphas, betas, indices, rowsums, d,
                           weights, rel_bound)
    return AbftEbOut(r, err_bags, jnp.sum(err_bags).astype(jnp.int32))


def eb_overhead_model(m: int, d: int) -> float:
    """§V-C analytic overhead: (3m + d) extra ops over 3md ≈ 1/d + 1/(3m)."""
    return 1.0 / d + 1.0 / (3.0 * m)
