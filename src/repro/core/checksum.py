"""Mod-checksums over pytrees — the paper's philosophy applied to the
framework substrate (checkpoints and collectives).

A tensor's checksum is the mod-M sum of its byte view; a pytree checksum is
the dict of per-leaf checksums.  Pure integer arithmetic => exact, cheap,
dtype-agnostic.  Used by:

- checkpoint/ckpt.py  — verify shards on restore (bit rot / torn writes)
- runtime/compression — verify int8-compressed gradient payloads around the
  data-parallel all-reduce (additivity: the checksum of a sum of int payloads
  equals the mod-sum of checksums, so the reduced result is verifiable
  without a second all-reduce of the data)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

MOD_U32 = np.uint32(2147483647)  # 2^31 - 1 (Mersenne prime)


def tensor_checksum(x: jax.Array) -> jax.Array:
    """Mod-(2^31-1) sum of the uint8 byte view (jit-safe)."""
    u8 = jax.lax.bitcast_convert_type(
        x.reshape(-1), jnp.uint8) if x.dtype != jnp.uint8 else x.reshape(-1)
    u8 = u8.reshape(-1)
    return jnp.sum(u8.astype(jnp.uint32)) % MOD_U32


def int_payload_checksum(x: jax.Array, mod: int = 2147483647) -> jax.Array:
    """Value (not byte) checksum — additive across an integer all-reduce."""
    return jnp.sum(x.astype(jnp.int64) % mod if x.dtype == jnp.int64
                   else x.astype(jnp.int32) % mod) % mod


def tree_checksum(tree) -> dict:
    leaves, treedef = jax.tree.flatten(tree)
    return {f"leaf_{i}": tensor_checksum(l) for i, l in enumerate(leaves)}


def verify_tree(tree, expected: dict) -> bool:
    got = jax.device_get(tree_checksum(tree))
    exp = jax.device_get(expected)
    return all(int(got[k]) == int(exp[k]) for k in exp)
