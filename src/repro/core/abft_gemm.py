"""ABFT for low-precision GEMM — the paper's Algorithm 1, TPU-adapted.

Scheme (§IV):
  * encode only B (weights): ``rowSum[i] = (Σ_j B[i,j]) mod 127`` kept in int8,
  * run the one int8 GEMM with the checksum fused in (BLAS-3, §IV-A3),
  * verify per row: ``(Σ_j C[i,j]) mod 127 == (A @ rowSum)[i] mod 127`` — any
    mismatch marks row ``i`` corrupted; ``errCount`` is returned with C.

TPU adaptations (DESIGN.md §3):
  * the packed checksum is a 128-lane-aligned block (first lane = checksum,
    rest zero) instead of an ``n+1``-th column, keeping MXU tiles aligned;
  * row sums of C reduce ``C mod 127`` element-wise *before* the row sum so
    the verification is exact for any ``n`` (a raw int32 row sum can overflow
    for LLM-sized n; 2^32 is not ≡ 0 mod 127 so wraparound would otherwise
    produce false positives).

All functions are jit-safe and differentiable-free (integer domain).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

#: modulus of the paper (§IV-C): largest odd prime in the int8 value range.
MOD = 127

#: TPU lane width — the checksum block is padded to this many columns.
LANE = 128


class AbftGemmOut(NamedTuple):
    c: jax.Array           # int32 [m, n] — C_temp, checksum column excluded
    err_rows: jax.Array    # bool  [m]    — per-row violation of Eq. (3b)
    err_count: jax.Array   # int32 scalar — number of corrupted rows


def encode_weight_checksum(b_q: jax.Array, mod: int = MOD) -> jax.Array:
    """Alg. 1 lines 2-5: int8 mod-``mod`` row sums of B ([k, n] -> [k]).

    The sum is taken over int32 (exact: |entries| ≤ 128, n ≤ 2^24) and folded
    back into int8 via the modulus, so the checksum rides the int8 pipeline
    (§IV-A2).
    """
    rs = jnp.sum(b_q.astype(jnp.int32), axis=-1) % mod
    return rs.astype(jnp.int8)


def pack_encoded_b(b_q: jax.Array, checksum: Optional[jax.Array] = None,
                   mod: int = MOD, lanes: int = LANE) -> jax.Array:
    """Pack B' = [B | checksum-block] (§IV-A3, TPU-lane-aligned).

    Returns int8 [k, n + lanes]: the final ``lanes`` columns hold the checksum
    in lane 0 and zeros elsewhere, so every MXU tile stays 128-aligned.
    """
    if checksum is None:
        checksum = encode_weight_checksum(b_q, mod)
    k, _ = b_q.shape
    block = jnp.zeros((k, lanes), dtype=jnp.int8).at[:, 0].set(checksum)
    return jnp.concatenate([b_q, block], axis=1)


def _rowsum_mod(c: jax.Array, mod: int) -> jax.Array:
    """Exact ``(Σ_j c[..., j]) mod mod`` without int32 overflow for any n."""
    # (c mod m) ∈ [0, m); the row sum is ≤ 126 * n < 2^31 for n < 1.7e7.
    return jnp.sum(c % mod, axis=-1) % mod


def verify_rows(c: jax.Array, check_col: jax.Array,
                mod: int = MOD) -> Tuple[jax.Array, jax.Array]:
    """Eq. (3b) check: per-row mismatch mask + count.

    ``check_col`` is the int32 checksum product column ``A_I @ rowSum``.
    """
    expected = check_col % mod
    got = _rowsum_mod(c, mod)
    err_rows = got != expected
    return err_rows, jnp.sum(err_rows).astype(jnp.int32)


def abft_qgemm(a_q: jax.Array, b_q: jax.Array,
               checksum: Optional[jax.Array] = None,
               mod: int = MOD) -> AbftGemmOut:
    """Algorithm 1 with the checksum product fused into one GEMM (BLAS-3).

    a_q: uint8/int8 [m, k] activations, b_q: int8 [k, n] weights.
    When ``checksum`` (int8 [k]) is precomputed (the weight-amortized serving
    path, §IV-A1), encoding cost is zero per call.
    """
    b_packed = pack_encoded_b(b_q, checksum, mod)
    return abft_qgemm_packed(a_q, b_packed, mod)


def abft_qgemm_packed(a_q: jax.Array, b_packed: jax.Array,
                      mod: int = MOD, lanes: int = LANE) -> AbftGemmOut:
    """GEMM against a pre-packed B' and fused verification.

    This is the serving hot path: B' lives packed in memory (encode-once),
    each call is one int8 GEMM of width n+128 plus an O(mn) verify.
    """
    n = b_packed.shape[1] - lanes
    # int8 operands feed the dot directly (int32 accumulate) — converting
    # to int32 first materializes 4x-sized copies (§Perf hillclimb 3)
    c_full = jax.lax.dot_general(
        a_q, b_packed, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    c = c_full[:, :n]
    check_col = c_full[:, n]          # lane 0 of the checksum block
    err_rows, err_count = verify_rows(c, check_col, mod)
    return AbftGemmOut(c, err_rows, err_count)


def abft_qgemm_unfused(a_q: jax.Array, b_q: jax.Array,
                       mod: int = MOD) -> AbftGemmOut:
    """The BLAS-2 baseline the paper argues *against* (§IV-A3 step ③).

    Kept for benchmarking the packing trick: the checksum product is a
    separate matrix-vector product.
    """
    checksum = encode_weight_checksum(b_q, mod)
    c = jax.lax.dot_general(
        a_q, b_q, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    check_col = jax.lax.dot_general(
        a_q, checksum, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    err_rows, err_count = verify_rows(c, check_col, mod)
    return AbftGemmOut(c, err_rows, err_count)


def encode_activation_checksum(a_q: jax.Array) -> jax.Array:
    """Column-side encoding: exact int32 column sums of A ([m, k] -> [k]).

    ``encode_activation_checksum(a) @ B`` equals the exact column sums of
    ``C = A @ B`` — the second encoding axis single-error correction needs
    (the row side stays the mod-127 checksum of Alg. 1).
    """
    return jnp.sum(a_q.astype(jnp.int32), axis=0)


def correct_single_error(c: jax.Array, err_rows: jax.Array,
                         col_check: jax.Array
                         ) -> Tuple[jax.Array, jax.Array]:
    """Single-error correction (paper §IV intro): row/column checksum
    repair of one flagged cell.

    The mod-127 row check localizes the corrupted row i (``err_rows``);
    ``col_check`` — the EXACT expected int32 column sums of C, i.e.
    ``encode_activation_checksum(a) @ b`` (amortizable per batch) —
    localizes the column j AND yields the additive error magnitude, so
    ``C[i, j]`` is repaired in place.  Applies only when exactly one row
    and one column are flagged (the single-error model); anything else is
    left untouched for the recompute path.

    Returns ``(corrected_c, applied)`` where ``applied`` is a bool scalar.
    """
    delta = col_check.astype(jnp.int32) - jnp.sum(c, axis=0)
    j = jnp.argmax(jnp.abs(delta))
    i = jnp.argmax(err_rows)
    one_row = jnp.sum(err_rows.astype(jnp.int32)) == 1
    one_col = jnp.sum((delta != 0).astype(jnp.int32)) == 1
    applied = one_row & one_col
    fix = jnp.where(applied, delta[j], 0)
    return c.at[i, j].add(fix), applied


def encode_weight_colsum(b_q: jax.Array) -> jax.Array:
    """Weight-side column encoding: exact int32 column sums of B
    ([k, n] -> [n]), amortized at pack time like the row checksum.

    Together with the packed mod-127 row checksum this makes B itself a
    2D-checksummed block: a flipped weight is *localized* — the stale row
    checksum flags row k, the stale column sum flags column j and yields
    the exact additive delta — so the ``correct`` policy can repair the
    GEMM output without re-quantizing or re-running anything.
    """
    return jnp.sum(b_q.astype(jnp.int32), axis=-2)


def correct_weight_flip(c: jax.Array, a_q: jax.Array, b_packed: jax.Array,
                        colsum_ref: jax.Array, mod: int = MOD,
                        lanes: int = LANE) -> Tuple[jax.Array, jax.Array]:
    """Repair C after a single corrupted *weight* (not accumulator) cell.

    A flip in ``B[k0, j0]`` corrupts every row of column j0 of C — too
    many flagged rows for :func:`correct_single_error`'s single-cell
    model.  But the encodings of B localize it exactly:

    * recomputed mod-127 row sums vs the packed checksum lane flag k0
      (a single-bit int8 delta is ±2^b, never ≡ 0 mod 127);
    * recomputed column sums vs ``colsum_ref`` (the exact int32 sums of
      the clean B, stored at encode time) flag j0 *and* give the exact
      delta;
    * then ``C[:, j0] -= A[:, k0] * delta`` restores the clean product.

    Applies only when exactly one row and one column are flagged; a flip
    landing in the checksum lane or a multi-flip pattern leaves C
    untouched (``applied`` False) for the recompute fallback.
    Returns ``(corrected_c, applied)``.
    """
    n = b_packed.shape[1] - lanes
    b_q = b_packed[:, :n].astype(jnp.int32)
    row_ref = b_packed[:, n].astype(jnp.int32)
    row_bad = (jnp.sum(b_q, axis=-1) - row_ref) % mod != 0
    col_delta = jnp.sum(b_q, axis=0) - colsum_ref.astype(jnp.int32)
    col_bad = col_delta != 0
    k0 = jnp.argmax(row_bad)
    j0 = jnp.argmax(col_bad)
    applied = (jnp.sum(row_bad.astype(jnp.int32)) == 1) & \
        (jnp.sum(col_bad.astype(jnp.int32)) == 1)
    fix = jnp.where(applied, col_delta[j0], 0)
    return c.at[:, j0].add(-a_q.astype(jnp.int32)[:, k0] * fix), applied


# ---------------------------------------------------------------------------
# Detection-probability model (§IV-C) — used by tests and benchmarks to
# compare measured accuracy against the paper's analytical bounds.
# ---------------------------------------------------------------------------

def detect_prob_b_bitflip(m: int, mod: int = MOD) -> float:
    """§IV-C1 fault model 1: P[detect] = 1 - (3/256)^m."""
    assert mod == 127, "closed form derived for mod=127"
    return 1.0 - (3.0 / 256.0) ** m


def detect_prob_b_random(m: int, mod: int = MOD) -> float:
    """§IV-C1 fault model 2: P[detect] = 1 - (1018/32640)^m."""
    assert mod == 127
    return 1.0 - (1018.0 / 32640.0) ** m


def detect_prob_c_random(mod: int = MOD) -> float:
    """§IV-C2 fault model 2: P[detect] ≥ 1 - 1/mod."""
    return 1.0 - 1.0 / mod
