"""Beyond-paper: float ABFT for training-time (bf16/f32) GEMMs.

The paper scopes ABFT to int8 inference (§III); training matmuls are bf16.
Classic HPC float ABFT (Huang & Abraham '84 with a round-off bound) applies:
encode B with exact f32 row sums, verify row sums of C against ``A @ s_B``
within a norm-scaled tolerance.  This protects the forward matmuls of the
training step and — applied to flattened gradients — the data-parallel
all-reduce (see runtime.compression for the checksummed collective).

The bound follows the standard forward-error model for inner products:
|fp(sum) - sum| ≤ k·eps·Σ|terms|, so we scale the tolerance by the
accumulated magnitude row-wise rather than using a single global epsilon.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class FloatAbftOut(NamedTuple):
    c: jax.Array
    err_rows: jax.Array
    err_count: jax.Array


def encode_weight_f32(b: jax.Array) -> jax.Array:
    """f32 row sums of B ([k, n] -> [k]); computed once per weight version."""
    return jnp.sum(b.astype(jnp.float32), axis=-1)


def abft_gemm_f32(a: jax.Array, b: jax.Array,
                  checksum: Optional[jax.Array] = None,
                  rel_bound: float = 1e-3) -> FloatAbftOut:
    """C = A @ B with row-sum verification under a round-off-aware bound.

    ``rel_bound`` is deliberately loose for bf16 inputs (the paper's EB
    reasoning §V-D: small float fluctuations rarely change inference
    outcomes; we only want large corruptions).
    """
    if checksum is None:
        checksum = encode_weight_f32(b)
    c = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                preferred_element_type=jnp.float32)
    got = jnp.sum(c, axis=-1)
    expected = jnp.dot(a.astype(jnp.float32), checksum)
    # Round-off scale: k * eps * ||A_row|| * ||B||_colsum-ish; we use the
    # cheap surrogate Σ|C_row| which upper-bounds the accumulated magnitude.
    scale = jnp.sum(jnp.abs(c), axis=-1) + 1.0
    err_rows = jnp.abs(got - expected) > rel_bound * scale
    return FloatAbftOut(c, err_rows, jnp.sum(err_rows).astype(jnp.int32))
