"""Fault injection — the paper's two fault models (§IV-C, §VI-B).

Model 1: *random single-bit flip* — flip one random bit of one random element.
Model 2: *random data fluctuation* — replace one element with a uniform random
value of its dtype's range.

Injectors are pure functions (value in, corrupted value out) so they compose
with jit/vmap; benchmark harnesses vmap over keys to run the paper's
2800-sample campaigns in one call.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def _uint_dtype(dtype) -> jnp.dtype:
    return {1: jnp.uint8, 2: jnp.uint16, 4: jnp.uint32}[jnp.dtype(dtype).itemsize]


def flip_bit(x: jax.Array, flat_index: jax.Array, bit: jax.Array) -> jax.Array:
    """Flip bit ``bit`` of the element at ``flat_index`` (any int/float dtype)."""
    udtype = _uint_dtype(x.dtype)
    flat = jax.lax.bitcast_convert_type(x.reshape(-1), udtype)
    mask = (jnp.asarray(1, udtype) << bit.astype(udtype))
    flat = flat.at[flat_index].set(flat[flat_index] ^ mask)
    return jax.lax.bitcast_convert_type(flat, x.dtype).reshape(x.shape)


def random_bitflip(key: jax.Array, x: jax.Array,
                   bit_range: tuple[int, int] | None = None) -> jax.Array:
    """Fault model 1. ``bit_range=(lo, hi)`` restricts to bits [lo, hi)."""
    nbits = jnp.dtype(x.dtype).itemsize * 8
    lo, hi = bit_range if bit_range is not None else (0, nbits)
    k1, k2 = jax.random.split(key)
    idx = jax.random.randint(k1, (), 0, x.size)
    bit = jax.random.randint(k2, (), lo, hi)
    return flip_bit(x, idx, bit)


def random_value(key: jax.Array, x: jax.Array) -> jax.Array:
    """Fault model 2: one element replaced by a uniform random bit-pattern."""
    udtype = _uint_dtype(x.dtype)
    k1, k2 = jax.random.split(key)
    idx = jax.random.randint(k1, (), 0, x.size)
    nbits = jnp.dtype(x.dtype).itemsize * 8
    rnd_bits = jax.random.bits(k2, (), jnp.uint32)
    rnd = (rnd_bits & jnp.uint32((1 << min(nbits, 32)) - 1)).astype(udtype)
    flat = jax.lax.bitcast_convert_type(x.reshape(-1), udtype)
    flat = flat.at[idx].set(rnd)
    return jax.lax.bitcast_convert_type(flat, x.dtype).reshape(x.shape)


def flip_bit_in_leaf(tree, key: jax.Array):
    """Flip one random bit in one random (largest-ish) leaf of a pytree.

    Host-side demo helper (serve driver / examples): picks a leaf weighted
    by size so big weight matrices — the realistic victims — dominate.
    Returns (corrupted_tree, leaf_path_str).
    """
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    sizes = jnp.asarray([l.size for _, l in leaves], jnp.float32)
    k1, k2 = jax.random.split(key)
    li = int(jax.random.choice(k1, len(leaves), p=sizes / sizes.sum()))
    path, leaf = leaves[li]
    corrupted = random_bitflip(k2, leaf)
    flat = [l for _, l in leaves]
    flat[li] = corrupted
    return (jax.tree_util.tree_unflatten(treedef, flat),
            jax.tree_util.keystr(path))


@partial(jax.jit, static_argnames=("fn", "n"))
def campaign(fn, key: jax.Array, n: int):
    """Run ``fn(key_i) -> bool detected`` for n keys; returns detected count.

    The benchmark harnesses pass closures that (inject -> run op -> read
    err_count) to reproduce the paper's Tables II / III at full sample size.
    """
    keys = jax.random.split(key, n)
    detected = jax.vmap(fn)(keys)
    return jnp.sum(detected.astype(jnp.int32))
