"""Fault injection — the paper's two fault models (§IV-C, §VI-B) plus the
significant-bit-band model of Ma et al. 2023 (robustness of recommendation
systems against hardware errors).

Model 1: *random single-bit flip* — flip one random bit of one random element.
Model 2: *random data fluctuation* — replace one element with a uniform random
value of its dtype's range.
Model 3: *bit-band flip* — model 1 restricted to a named band of bit
positions (exponent / high-mantissa / significant / low / sign), expressing
"where in the word does the flip land" sweeps per dtype.

Injectors are pure functions (value in, corrupted value out) so they compose
with jit/vmap; campaign harnesses (:mod:`repro.campaign`) vmap over keys to
run thousand-sample sweeps in one call, and :func:`random_bitflips` injects
several independent flips per trial for multi-error scenarios.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def _uint_dtype(dtype) -> jnp.dtype:
    return {1: jnp.uint8, 2: jnp.uint16, 4: jnp.uint32}[jnp.dtype(dtype).itemsize]


# ---------------------------------------------------------------------------
# Named bit bands (model 3).  [lo, hi) positions, LSB = 0, per dtype.
#
# For floats the interesting split is exponent vs mantissa (an exponent flip
# rescales by 2^±2^k — the "significant" corruption Ma et al. show dominates
# accuracy loss); for quantized ints it is high vs low nibble (the paper's
# Table III splits EmbeddingBag results exactly this way).
# ---------------------------------------------------------------------------
BIT_BANDS: dict[str, dict[str, tuple[int, int]]] = {
    "int8": {"all": (0, 8), "low": (0, 4), "significant": (4, 8),
             "sign": (7, 8)},
    "uint8": {"all": (0, 8), "low": (0, 4), "significant": (4, 8)},
    "int32": {"all": (0, 32), "low": (0, 16), "significant": (16, 32),
              "sign": (31, 32)},
    "float32": {"all": (0, 32), "low": (0, 12), "mantissa": (0, 23),
                "high_mantissa": (12, 23), "exponent": (23, 31),
                "significant": (20, 31), "sign": (31, 32)},
    "bfloat16": {"all": (0, 16), "mantissa": (0, 7),
                 "exponent": (7, 15), "significant": (4, 15),
                 "sign": (15, 16)},
    "float16": {"all": (0, 16), "mantissa": (0, 10),
                "exponent": (10, 15), "significant": (7, 15),
                "sign": (15, 16)},
}


def bit_band(dtype, band: str) -> tuple[int, int]:
    """Resolve a named band to [lo, hi) bit positions for ``dtype``.

    Unknown dtypes fall back to ("all" = full word, "significant" /
    "low" = upper / lower half) so campaigns stay runnable on any dtype.
    """
    name = jnp.dtype(dtype).name
    nbits = jnp.dtype(dtype).itemsize * 8
    bands = BIT_BANDS.get(name)
    if bands is not None and band in bands:
        return bands[band]
    if band == "all":
        return (0, nbits)
    if band == "low":
        return (0, nbits // 2)
    if band == "significant":
        return (nbits // 2, nbits)
    raise KeyError(f"no bit band {band!r} for dtype {name}")


def flip_bit(x: jax.Array, flat_index: jax.Array, bit: jax.Array) -> jax.Array:
    """Flip bit ``bit`` of the element at ``flat_index`` (any int/float dtype)."""
    udtype = _uint_dtype(x.dtype)
    flat = jax.lax.bitcast_convert_type(x.reshape(-1), udtype)
    mask = (jnp.asarray(1, udtype) << bit.astype(udtype))
    flat = flat.at[flat_index].set(flat[flat_index] ^ mask)
    return jax.lax.bitcast_convert_type(flat, x.dtype).reshape(x.shape)


def random_bitflip(key: jax.Array, x: jax.Array,
                   bit_range: tuple[int, int] | None = None) -> jax.Array:
    """Fault model 1. ``bit_range=(lo, hi)`` restricts to bits [lo, hi)."""
    nbits = jnp.dtype(x.dtype).itemsize * 8
    lo, hi = bit_range if bit_range is not None else (0, nbits)
    k1, k2 = jax.random.split(key)
    idx = jax.random.randint(k1, (), 0, x.size)
    bit = jax.random.randint(k2, (), lo, hi)
    return flip_bit(x, idx, bit)


def random_bitflip_band(key: jax.Array, x: jax.Array,
                        band: str = "all") -> jax.Array:
    """Fault model 3: model 1 restricted to the named ``band`` of ``x``'s
    dtype (see :data:`BIT_BANDS`) — e.g. ``"significant"`` flips only
    exponent/high bits, the errors Ma et al. show actually move model
    output."""
    return random_bitflip(key, x, bit_range=bit_band(x.dtype, band))


def _distinct_indices(key: jax.Array, n: int, k: int) -> jax.Array:
    """k distinct uniform indices in [0, n) via Floyd's algorithm — O(k^2)
    work, vs the O(n log n) full permutation ``jax.random.choice(...,
    replace=False)`` performs (n can be millions of elements for GEMM
    weight campaigns, k is a handful of flips)."""
    sel0 = jnp.full((k,), -1, jnp.int32)

    def body(t, sel):
        i = n - k + t
        j = jax.random.randint(jax.random.fold_in(key, t), (), 0, i + 1)
        dup = jnp.any(sel == j)
        return sel.at[t].set(jnp.where(dup, i, j).astype(jnp.int32))

    return jax.lax.fori_loop(0, k, body, sel0)


def random_bitflips(key: jax.Array, x: jax.Array, n_flips: int,
                    bit_range: tuple[int, int] | None = None) -> jax.Array:
    """Batched multi-element injection: ``n_flips`` independent single-bit
    flips at element positions drawn without replacement (distinct victims,
    so k flips == k corrupted elements and campaigns can count escapes
    exactly).  ``n_flips`` is static; O(n_flips^2) index draws + one
    fori_loop of scatters, jit/vmap-safe."""
    if n_flips < 1:
        raise ValueError("n_flips must be >= 1")
    if n_flips > x.size:
        raise ValueError(f"n_flips={n_flips} exceeds {x.size} elements")
    nbits = jnp.dtype(x.dtype).itemsize * 8
    lo, hi = bit_range if bit_range is not None else (0, nbits)
    k_idx, k_bit = jax.random.split(key)
    idxs = _distinct_indices(k_idx, x.size, n_flips)
    bits = jax.random.randint(k_bit, (n_flips,), lo, hi)

    udtype = _uint_dtype(x.dtype)
    flat = jax.lax.bitcast_convert_type(x.reshape(-1), udtype)

    def body(i, f):
        mask = jnp.asarray(1, udtype) << bits[i].astype(udtype)
        return f.at[idxs[i]].set(f[idxs[i]] ^ mask)

    flat = jax.lax.fori_loop(0, n_flips, body, flat)
    return jax.lax.bitcast_convert_type(flat, x.dtype).reshape(x.shape)


def random_value(key: jax.Array, x: jax.Array) -> jax.Array:
    """Fault model 2: one element replaced by a uniform random bit-pattern."""
    udtype = _uint_dtype(x.dtype)
    k1, k2 = jax.random.split(key)
    idx = jax.random.randint(k1, (), 0, x.size)
    nbits = jnp.dtype(x.dtype).itemsize * 8
    rnd_bits = jax.random.bits(k2, (), jnp.uint32)
    rnd = (rnd_bits & jnp.uint32((1 << min(nbits, 32)) - 1)).astype(udtype)
    flat = jax.lax.bitcast_convert_type(x.reshape(-1), udtype)
    flat = flat.at[idx].set(rnd)
    return jax.lax.bitcast_convert_type(flat, x.dtype).reshape(x.shape)


def leaf_paths(tree) -> list:
    """``[(dotted_path, leaf), ...]`` in tree_flatten order.

    Paths join dict keys / sequence indices with ``.`` —
    ``layers.attn.wq.w_packed``, ``tables.table`` — the same vocabulary
    protection-plan path rules use, so one pattern can both select a plan
    rule (``qgemm/attn.wq``) and name an injection victim (``attn.wq``).
    """
    from jax.tree_util import (DictKey, FlattenedIndexKey, GetAttrKey,
                               SequenceKey, tree_flatten_with_path)
    flat, _ = tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        parts = []
        for k in path:
            if isinstance(k, DictKey):
                parts.append(str(k.key))
            elif isinstance(k, SequenceKey):
                parts.append(str(k.idx))
            elif isinstance(k, GetAttrKey):
                parts.append(k.name)
            elif isinstance(k, FlattenedIndexKey):
                parts.append(str(k.key))
            else:  # pragma: no cover - future key types
                parts.append(str(k))
        out.append((".".join(parts), leaf))
    return out


def victim_leaf_index(tree, pattern: str | None = None, *,
                      prefer_int8: bool = True) -> tuple[int, str]:
    """Pick an injection victim leaf: ``(flat_index, dotted_path)``.

    ``pattern`` is matched as ``fnmatch("*<pattern>*")`` against the
    dotted leaf paths (so ``attn.wq`` selects every layer's packed query
    weight, ``mlp.*`` the MLP projections).  Among matches, int8 leaves
    (the ABFT-protected packed weights / tables) are preferred and the
    largest wins — the realistic memory-error victim.  ``None`` keeps the
    legacy behavior: largest int8 leaf anywhere.
    """
    import fnmatch

    named = leaf_paths(tree)
    cand = list(range(len(named)))
    if pattern:
        pat = f"*{pattern}*"
        cand = [i for i in cand
                if fnmatch.fnmatchcase(named[i][0], pat)]
        if not cand:
            names = sorted({n for n, _ in named})
            raise ValueError(
                f"victim pattern {pattern!r} matches no leaf; "
                f"paths look like: {names[:8]} ...")
    if prefer_int8:
        int8 = [i for i in cand if named[i][1].dtype == jnp.int8]
        cand = int8 or cand
    victim = max(cand, key=lambda i: named[i][1].size)
    return victim, named[victim][0]


def random_bitflip_live(key: jax.Array, leaf: jax.Array, path: str = "",
                        bit_range: tuple[int, int] | None = None
                        ) -> jax.Array:
    """Model-1 flip restricted to the leaf's *live* region.

    Packed GEMM weights (``*.w_packed``) carry a 128-column checksum block
    whose lanes 1..127 are alignment zeros the kernels never read — a flip
    there is invisible by construction and would dilute an injection
    campaign with guaranteed-masked faults.  For such leaves the victim
    element is drawn from the weight block + checksum column only; every
    other leaf falls through to :func:`random_bitflip`.
    """
    from repro.core.abft_gemm import LANE

    last = leaf.shape[-1] if leaf.ndim else 0
    if not (path.endswith("w_packed") and leaf.ndim >= 2 and last > LANE):
        return random_bitflip(key, leaf, bit_range=bit_range)
    live = last - LANE + 1                      # weight cols + checksum col
    nbits = jnp.dtype(leaf.dtype).itemsize * 8
    lo, hi = bit_range if bit_range is not None else (0, nbits)
    k1, k2, k3 = jax.random.split(key, 3)
    lead = jax.random.randint(k1, (), 0, leaf.size // last)
    col = jax.random.randint(k2, (), 0, live)
    bit = jax.random.randint(k3, (), lo, hi)
    return flip_bit(leaf, lead * last + col, bit)


def flip_bit_in_leaf(tree, key: jax.Array):
    """Flip one random bit in one random (largest-ish) leaf of a pytree.

    Host-side demo helper (serve driver / examples): picks a leaf weighted
    by size so big weight matrices — the realistic victims — dominate.
    Returns (corrupted_tree, leaf_path_str).
    """
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    sizes = jnp.asarray([l.size for _, l in leaves], jnp.float32)
    k1, k2 = jax.random.split(key)
    li = int(jax.random.choice(k1, len(leaves), p=sizes / sizes.sum()))
    path, leaf = leaves[li]
    corrupted = random_bitflip(k2, leaf)
    flat = [l for _, l in leaves]
    flat[li] = corrupted
    return (jax.tree_util.tree_unflatten(treedef, flat),
            jax.tree_util.keystr(path))


@partial(jax.jit, static_argnames=("fn", "n"))
def campaign(fn, key: jax.Array, n: int):
    """Run ``fn(key_i) -> bool detected`` for n keys; returns detected count.

    The benchmark harnesses pass closures that (inject -> run op -> read
    err_count) to reproduce the paper's Tables II / III at full sample size.
    """
    keys = jax.random.split(key, n)
    detected = jax.vmap(fn)(keys)
    return jnp.sum(detected.astype(jnp.int32))
