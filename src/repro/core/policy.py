"""Fault reports and detect->act policies.

Every ABFT-protected op contributes to a :class:`FaultReport` — a small int32
pytree threaded functionally through layers, models, and step functions (it
scans/pmaps/pjits like any other pytree).  Policies decide what a step does
when ``report.total_errors() > 0``:

- ``log``       — surface counts in step metrics (default; zero control flow)
- ``recompute`` — re-run the op under ``lax.cond`` (paper §I: an error that
                  strikes twice is vanishingly rare, so one deterministic
                  retry clears transient faults; retries are counted)
- ``correct``   — repair the single flagged cell in place via the row +
                  column checksums (abft_gemm.correct_single_error); multi
                  error results fall through with their error count intact
- ``abort``     — raise via a host callback (used by serving: fail the
                  request, not the server)

``POLICIES`` maps the names to wrappers; ``apply_policy(name, op)`` is the
string-driven entry point configs/serving use.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class FaultReport:
    gemm_checks: jax.Array
    gemm_errors: jax.Array
    eb_checks: jax.Array
    eb_errors: jax.Array
    recomputes: jax.Array

    def tree_flatten(self):
        return ((self.gemm_checks, self.gemm_errors, self.eb_checks,
                 self.eb_errors, self.recomputes), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    def total_errors(self) -> jax.Array:
        return self.gemm_errors + self.eb_errors

    def as_metrics(self) -> dict:
        return {
            "abft/gemm_checks": self.gemm_checks,
            "abft/gemm_errors": self.gemm_errors,
            "abft/eb_checks": self.eb_checks,
            "abft/eb_errors": self.eb_errors,
            "abft/recomputes": self.recomputes,
        }


def empty_report() -> FaultReport:
    z = jnp.zeros((), jnp.int32)
    return FaultReport(z, z, z, z, z)


def gemm_report(err_count: jax.Array, recomputes=None) -> FaultReport:
    z = jnp.zeros((), jnp.int32)
    r = z if recomputes is None else recomputes.astype(jnp.int32)
    return FaultReport(jnp.ones((), jnp.int32), err_count.astype(jnp.int32),
                       z, z, r)


def eb_report(err_count: jax.Array) -> FaultReport:
    z = jnp.zeros((), jnp.int32)
    return FaultReport(z, z, jnp.ones((), jnp.int32),
                       err_count.astype(jnp.int32), z)


def merge_reports(*reports: FaultReport) -> FaultReport:
    if not reports:
        return empty_report()
    return jax.tree.map(lambda *xs: sum(xs), *reports)


def with_recompute(op: Callable, max_retries: int = 1):
    """Wrap an ABFT op ``op() -> (out, err_count)`` with detect->recompute.

    In simulation a deterministic re-run returns the same value; on real
    hardware a transient fault does not recur.  What matters structurally is
    the control flow (lax.cond) and the retry accounting, both preserved.
    """
    def wrapped(*args, **kwargs):
        out, err = op(*args, **kwargs)
        retries = jnp.zeros((), jnp.int32)
        for _ in range(max_retries):
            def retry(_):
                o2, e2 = op(*args, **kwargs)
                return o2, e2, jnp.ones((), jnp.int32)

            def keep(_):
                return out, err, jnp.zeros((), jnp.int32)

            out, err, did = jax.lax.cond(err > 0, retry, keep, None)
            retries = retries + did
        return out, err, retries

    return wrapped


def with_log(op: Callable):
    """Policy ``log``: pass-through with zero retries (uniform arity with
    the other policies: ``op() -> (out, err)`` becomes
    ``(out, err, retries)``)."""
    def wrapped(*args, **kwargs):
        out, err = op(*args, **kwargs)
        return out, err, jnp.zeros((), jnp.int32)

    return wrapped


def with_correct(op: Callable):
    """Policy ``correct``: single-error repair via row+column checksums.

    ``op() -> (c, err_rows, err_count, col_check)`` where ``col_check`` is
    the exact expected int32 column-sum vector
    (:func:`repro.core.abft_gemm.encode_activation_checksum` of A, times
    B).  A successfully repaired result reports zero residual errors; a
    multi-error result keeps its count so an outer recompute/abort layer
    still sees it.  Returns ``(c, err_count, corrections)``.
    """
    from repro.core.abft_gemm import correct_single_error

    def wrapped(*args, **kwargs):
        c, err_rows, err_count, col_check = op(*args, **kwargs)
        corrected, applied = correct_single_error(c, err_rows, col_check)
        residual = jnp.where(applied, 0, err_count).astype(jnp.int32)
        return corrected, residual, applied.astype(jnp.int32)

    return wrapped


class FaultAbort(RuntimeError):
    """Raised host-side by policy ``abort`` when an op reports errors."""


def is_fault_abort(exc: BaseException) -> bool:
    """True for a :class:`FaultAbort` OR the runtime error jit wraps it in.

    Inside jit, jax surfaces callback exceptions as ``XlaRuntimeError``
    (the FaultAbort text is preserved in the message); request boundaries
    should gate on this predicate rather than ``except FaultAbort``.
    """
    return isinstance(exc, FaultAbort) or "FaultAbort" in repr(exc)


def with_abort(op: Callable):
    """Policy ``abort``: host-level raise when ``err > 0`` (serving: fail
    the REQUEST, never the server).  Eager callers catch
    :class:`FaultAbort`; jitted callers get it re-wrapped by the runtime,
    so request boundaries use :func:`is_fault_abort` on the caught
    exception."""
    def _check(err):
        if int(err) > 0:
            raise FaultAbort(f"ABFT detected {int(err)} corrupted op(s)")

    def wrapped(*args, **kwargs):
        out, err = op(*args, **kwargs)
        jax.debug.callback(_check, err)
        return out, err, jnp.zeros((), jnp.int32)

    return wrapped


#: name -> wrapper; ``correct`` expects the 4-tuple GEMM contract (see
#: :func:`with_correct`), the rest wrap any ``op() -> (out, err)``.
POLICIES = {
    "log": with_log,
    "recompute": with_recompute,
    "correct": with_correct,
    "abort": with_abort,
}


def apply_policy(name: str, op: Callable, **kwargs):
    if name not in POLICIES:
        raise KeyError(f"unknown policy {name!r}; have {sorted(POLICIES)}")
    return POLICIES[name](op, **kwargs)
