"""Fault reports and detect->act policies.

Every ABFT-protected op contributes to a :class:`FaultReport` — a small int32
pytree threaded functionally through layers, models, and step functions (it
scans/pmaps/pjits like any other pytree).  Policies decide what a step does
when ``report.total_errors() > 0``:

- ``log``       — surface counts in step metrics (default; zero control flow)
- ``recompute`` — re-run the op under ``lax.cond`` (paper §I: an error that
                  strikes twice is vanishingly rare, so one deterministic
                  retry clears transient faults; retries are counted)
- ``abort``     — raise via ``checkify``-style debug check at the host level
                  (used by serving: fail the request, not the server)
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class FaultReport:
    gemm_checks: jax.Array
    gemm_errors: jax.Array
    eb_checks: jax.Array
    eb_errors: jax.Array
    recomputes: jax.Array

    def tree_flatten(self):
        return ((self.gemm_checks, self.gemm_errors, self.eb_checks,
                 self.eb_errors, self.recomputes), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    def total_errors(self) -> jax.Array:
        return self.gemm_errors + self.eb_errors

    def as_metrics(self) -> dict:
        return {
            "abft/gemm_checks": self.gemm_checks,
            "abft/gemm_errors": self.gemm_errors,
            "abft/eb_checks": self.eb_checks,
            "abft/eb_errors": self.eb_errors,
            "abft/recomputes": self.recomputes,
        }


def empty_report() -> FaultReport:
    z = jnp.zeros((), jnp.int32)
    return FaultReport(z, z, z, z, z)


def gemm_report(err_count: jax.Array, recomputes=None) -> FaultReport:
    z = jnp.zeros((), jnp.int32)
    r = z if recomputes is None else recomputes.astype(jnp.int32)
    return FaultReport(jnp.ones((), jnp.int32), err_count.astype(jnp.int32),
                       z, z, r)


def eb_report(err_count: jax.Array) -> FaultReport:
    z = jnp.zeros((), jnp.int32)
    return FaultReport(z, z, jnp.ones((), jnp.int32),
                       err_count.astype(jnp.int32), z)


def merge_reports(*reports: FaultReport) -> FaultReport:
    if not reports:
        return empty_report()
    return jax.tree.map(lambda *xs: sum(xs), *reports)


def with_recompute(op: Callable, max_retries: int = 1):
    """Wrap an ABFT op ``op() -> (out, err_count)`` with detect->recompute.

    In simulation a deterministic re-run returns the same value; on real
    hardware a transient fault does not recur.  What matters structurally is
    the control flow (lax.cond) and the retry accounting, both preserved.
    """
    def wrapped(*args, **kwargs):
        out, err = op(*args, **kwargs)
        retries = jnp.zeros((), jnp.int32)
        for _ in range(max_retries):
            def retry(_):
                o2, e2 = op(*args, **kwargs)
                return o2, e2, jnp.ones((), jnp.int32)

            def keep(_):
                return out, err, jnp.zeros((), jnp.int32)

            out, err, did = jax.lax.cond(err > 0, retry, keep, None)
            retries = retries + did
        return out, err, retries

    return wrapped
