"""Fault reports and detect->act policies.

Every ABFT-protected op contributes to a :class:`FaultReport` — a small int32
pytree threaded functionally through layers, models, and step functions (it
scans/pmaps/pjits like any other pytree).  The report is **keyed by op kind**
(``qgemm``, ``float_gemm``, ``embedding_bag``, ``kv_cache``, plus anything
registered via :func:`register_op_kind`): per-kind check and error counters
ride in dicts, so a new protected operator extends the report by registering
a name instead of growing hard-coded fields.

Scan/vmap safety: pytree structure must be static under tracing, so every
constructor (:func:`empty_report`, :func:`op_report`) materializes counters
for ALL registered kinds — a scan carry built from ``empty_report()`` always
matches the body's merged reports.  Register custom kinds at import time,
before tracing.

Policies decide what a step does when ``report.total_errors() > 0``:

- ``log``       — surface counts in step metrics (default; zero control flow)
- ``recompute`` — re-run the op under ``lax.cond`` (paper §I: an error that
                  strikes twice is vanishingly rare, so one deterministic
                  retry clears transient faults; retries are counted)
- ``correct``   — repair the single flagged cell in place via the row +
                  column checksums (abft_gemm.correct_single_error); multi
                  error results fall through with their error count intact
- ``abort``     — raise via a host callback (used by serving: fail the
                  request, not the server)

``POLICIES`` maps the names to wrappers; ``apply_policy(name, op)`` is the
string-driven entry point.  The declarative front door over all of this is
:mod:`repro.protect` — per-op-pattern :class:`~repro.protect.ProtectionPlan`
rules resolve to one of these policies per protected call site.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import jax
import jax.numpy as jnp

#: built-in op kinds — one per registered protected-op adapter
#: (repro.protect.ops registers its adapters against these names).
_DEFAULT_OP_KINDS = ("qgemm", "float_gemm", "embedding_bag", "kv_cache")
_OP_KINDS = list(_DEFAULT_OP_KINDS)


def op_kinds() -> tuple:
    """Currently registered op kinds (report key set)."""
    return tuple(_OP_KINDS)


def register_op_kind(name: str) -> None:
    """Add an op kind to the report key set.  Call at import time (before
    any tracing) so report pytree structure stays static."""
    if name not in _OP_KINDS:
        _OP_KINDS.append(name)


def _zero() -> jax.Array:
    return jnp.zeros((), jnp.int32)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class FaultReport:
    """Per-op-kind ABFT counters.

    ``checks[name]`` / ``errors[name]`` count verified calls and residual
    (post-policy) errors per op kind; ``retries`` and ``corrections``
    aggregate the recompute/correct policy actions across all kinds.
    """
    checks: Dict[str, jax.Array]
    errors: Dict[str, jax.Array]
    retries: jax.Array
    corrections: jax.Array

    def tree_flatten(self):
        names = tuple(sorted(self.checks))
        children = (tuple(self.checks[n] for n in names)
                    + tuple(self.errors[n] for n in names)
                    + (self.retries, self.corrections))
        return children, names

    @classmethod
    def tree_unflatten(cls, names, children):
        k = len(names)
        return cls(dict(zip(names, children[:k])),
                   dict(zip(names, children[k:2 * k])),
                   children[2 * k], children[2 * k + 1])

    # ------------------------------ queries ---------------------------------

    def _get(self, table: Dict[str, jax.Array], name: str):
        return table.get(name, _zero())

    def total_errors(self) -> jax.Array:
        return sum(self.errors.values(), _zero())

    def total_checks(self) -> jax.Array:
        return sum(self.checks.values(), _zero())

    def as_metrics(self) -> dict:
        m = {}
        for n in sorted(self.checks):
            m[f"abft/{n}_checks"] = self.checks[n]
            m[f"abft/{n}_errors"] = self.errors[n]
        m["abft/retries"] = self.retries
        m["abft/corrections"] = self.corrections
        # legacy aliases (pre-protect metric names; gemm = int8 + float)
        m["abft/gemm_checks"] = self.gemm_checks
        m["abft/gemm_errors"] = self.gemm_errors
        m["abft/eb_checks"] = self.eb_checks
        m["abft/eb_errors"] = self.eb_errors
        m["abft/recomputes"] = self.retries
        return m

    # legacy field names, kept as views over the keyed counters ---------------

    @property
    def gemm_checks(self):
        return self._get(self.checks, "qgemm") + self._get(self.checks,
                                                           "float_gemm")

    @property
    def gemm_errors(self):
        return self._get(self.errors, "qgemm") + self._get(self.errors,
                                                           "float_gemm")

    @property
    def eb_checks(self):
        return self._get(self.checks, "embedding_bag")

    @property
    def eb_errors(self):
        return self._get(self.errors, "embedding_bag")

    @property
    def recomputes(self):
        return self.retries


def empty_report() -> FaultReport:
    z = _zero()
    return FaultReport({n: z for n in _OP_KINDS},
                       {n: z for n in _OP_KINDS}, z, z)


def op_report(name: str, err_count, *, checks=1, retries=None,
              corrections=None) -> FaultReport:
    """A report with one op kind's counters set (all other kinds zero)."""
    if name not in _OP_KINDS:
        raise KeyError(f"unregistered op kind {name!r}; have {_OP_KINDS} "
                       "(register_op_kind at import time)")
    rep = empty_report()
    rep.checks[name] = jnp.asarray(checks, jnp.int32)
    rep.errors[name] = jnp.asarray(err_count, jnp.int32)
    if retries is not None:
        rep.retries = jnp.asarray(retries, jnp.int32)
    if corrections is not None:
        rep.corrections = jnp.asarray(corrections, jnp.int32)
    return rep


def gemm_report(err_count: jax.Array, recomputes=None) -> FaultReport:
    """Legacy helper: one verified int8 GEMM."""
    return op_report("qgemm", err_count, retries=recomputes)


def eb_report(err_count: jax.Array) -> FaultReport:
    """Legacy helper: one verified EmbeddingBag."""
    return op_report("embedding_bag", err_count)


def merge_reports(*reports: FaultReport) -> FaultReport:
    if not reports:
        return empty_report()
    names = sorted(set().union(*(r.checks.keys() for r in reports)))
    z = _zero()
    return FaultReport(
        {n: sum((r._get(r.checks, n) for r in reports), z) for n in names},
        {n: sum((r._get(r.errors, n) for r in reports), z) for n in names},
        sum((r.retries for r in reports), z),
        sum((r.corrections for r in reports), z))


def with_recompute(op: Callable, max_retries: int = 1):
    """Wrap an ABFT op ``op() -> (out, err_count)`` with detect->recompute.

    In simulation a deterministic re-run returns the same value; on real
    hardware a transient fault does not recur.  What matters structurally is
    the control flow (lax.cond) and the retry accounting, both preserved.
    """
    def wrapped(*args, **kwargs):
        out, err = op(*args, **kwargs)
        retries = jnp.zeros((), jnp.int32)
        for _ in range(max_retries):
            def retry(_):
                o2, e2 = op(*args, **kwargs)
                return o2, e2, jnp.ones((), jnp.int32)

            def keep(_):
                return out, err, jnp.zeros((), jnp.int32)

            out, err, did = jax.lax.cond(err > 0, retry, keep, None)
            retries = retries + did
        return out, err, retries

    return wrapped


def with_log(op: Callable):
    """Policy ``log``: pass-through with zero retries (uniform arity with
    the other policies: ``op() -> (out, err)`` becomes
    ``(out, err, retries)``)."""
    def wrapped(*args, **kwargs):
        out, err = op(*args, **kwargs)
        return out, err, jnp.zeros((), jnp.int32)

    return wrapped


def with_correct(op: Callable):
    """Policy ``correct``: single-error repair via row+column checksums.

    ``op() -> (c, err_rows, err_count, col_check)`` where ``col_check`` is
    the exact expected int32 column-sum vector
    (:func:`repro.core.abft_gemm.encode_activation_checksum` of A, times
    B).  A successfully repaired result reports zero residual errors; a
    multi-error result keeps its count so an outer recompute/abort layer
    still sees it.  Returns ``(c, err_count, corrections)``.
    """
    from repro.core.abft_gemm import correct_single_error

    def wrapped(*args, **kwargs):
        c, err_rows, err_count, col_check = op(*args, **kwargs)
        corrected, applied = correct_single_error(c, err_rows, col_check)
        residual = jnp.where(applied, 0, err_count).astype(jnp.int32)
        return corrected, residual, applied.astype(jnp.int32)

    return wrapped


class FaultAbort(RuntimeError):
    """Raised host-side by policy ``abort`` when an op reports errors."""


def is_fault_abort(exc: BaseException) -> bool:
    """True for a :class:`FaultAbort` OR the runtime error jit wraps it in.

    Inside jit, jax surfaces callback exceptions as ``XlaRuntimeError``
    (the FaultAbort text is preserved in the message); request boundaries
    should gate on this predicate rather than ``except FaultAbort``.
    """
    return isinstance(exc, FaultAbort) or "FaultAbort" in repr(exc)


def abort_if_errors(err) -> None:
    """Host callback body for policy ``abort`` (shared with repro.protect)."""
    if int(err) > 0:
        raise FaultAbort(f"ABFT detected {int(err)} corrupted op(s)")


def with_abort(op: Callable):
    """Policy ``abort``: host-level raise when ``err > 0`` (serving: fail
    the REQUEST, never the server).  Eager callers catch
    :class:`FaultAbort`; jitted callers get it re-wrapped by the runtime,
    so request boundaries use :func:`is_fault_abort` on the caught
    exception."""
    def wrapped(*args, **kwargs):
        out, err = op(*args, **kwargs)
        jax.debug.callback(abort_if_errors, err)
        return out, err, jnp.zeros((), jnp.int32)

    return wrapped


#: name -> wrapper; ``correct`` expects the 4-tuple GEMM contract (see
#: :func:`with_correct`), the rest wrap any ``op() -> (out, err)``.
POLICIES = {
    "log": with_log,
    "recompute": with_recompute,
    "correct": with_correct,
    "abort": with_abort,
}


def apply_policy(name: str, op: Callable, **kwargs):
    if name not in POLICIES:
        raise KeyError(f"unknown policy {name!r}; have {sorted(POLICIES)}")
    return POLICIES[name](op, **kwargs)
