"""Trip-count-aware cost analysis of compiled HLO text.

``compiled.cost_analysis()`` counts every ``while`` body **once**, which
under-reports any scanned program (layer stacks, KV-chunk flash scans,
grad-accumulation) by orders of magnitude.  All of our scans have uniform
bodies (cost is independent of the iteration index), so the exact total is

    cost(program) = Σ_ops cost(op) with cost(while) = trips × cost(body)

with trips parsed from the loop-condition computation (jax emits
``compare(counter, constant(T)), direction=LT`` — T is recoverable).  This
module walks the post-optimization HLO text and produces trip-multiplied

  * flops            — dot/conv MACs×2 + elementwise/reduce ops
  * bytes            — HBM traffic under XLA's fusion choices: a fusion
                       reads its operands and writes its result once;
                       dynamic-update-slice is in-place (update bytes);
                       internal fusion temporaries are free
  * collective bytes — per-kind counts/bytes, both raw result bytes and
                       ring-model link bytes (e.g. all-reduce counts
                       2·(G-1)/G · size for group size G)

Caveats (documented in EXPERIMENTS.md §Dry-run):
  * the CPU backend's fusion granularity differs from TPU's — byte totals
    are the CPU-compiled fusion boundaries, the best signal available in a
    CPU-only container;
  * ``conditional`` branches count the max-cost branch;
  * unparseable trip counts fall back to 1 and are flagged.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "collective-broadcast",
                "ragged-all-to-all")

# opcodes that are pure data movement / bookkeeping: no flops, no HBM bytes
_FREE = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "reshape", "after-all", "iota", "rng-bit-generator", "rng",
    "get-dimension-size", "partition-id", "replica-id", "domain",
    "opt-barrier", "custom-call",
}

# elementwise-ish opcodes: 1 flop per output element, operand+result bytes
# (when they appear OUTSIDE fusions)
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "abs", "negate", "exponential", "exponential-minus-one", "log",
    "log-plus-one", "tanh", "rsqrt", "sqrt", "cbrt", "sign", "floor",
    "ceil", "round-nearest-afz", "round-nearest-even", "logistic", "sine",
    "cosine", "tan", "atan2", "erf", "is-finite", "not", "and", "or", "xor",
    "shift-left", "shift-right-arithmetic", "shift-right-logical",
    "compare", "select", "clamp", "convert", "remainder", "map",
    "stochastic-convert", "real", "imag", "popcnt", "clz",
}


def _shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    """(elements, bytes) over all array shapes inside a (tuple) type str."""
    elems = 0
    nbytes = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dtype]
    return elems, nbytes


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    shapes: Dict[str, str]   # op name -> result type string
    byname: Dict[str, Op] = dataclasses.field(default_factory=dict)


_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*[({]")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([a-z][a-z0-9\-]*)\((.*)$")


def _split_operands(rest: str) -> Tuple[List[str], str]:
    """Split 'a, b, c), attrs...' -> ([a,b,c], attrs) respecting brackets."""
    depth = 0
    out: List[str] = []
    cur = []
    i = 0
    while i < len(rest):
        ch = rest[i]
        if ch in "([{":
            depth += 1
            cur.append(ch)
        elif ch in ")]}":
            if depth == 0 and ch == ")":
                if cur:
                    out.append("".join(cur).strip())
                return out, rest[i + 1:]
            depth -= 1
            cur.append(ch)
        elif ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
        i += 1
    if cur:
        out.append("".join(cur).strip())
    return out, ""


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry: Optional[str] = None
    for line in text.splitlines():
        if cur is None:
            if ("->" in line and line.rstrip().endswith("{")
                    and not line.startswith(" ")):
                m = _COMP_HDR.match(line.strip())
                if m:
                    cur = Computation(m.group(1), [], {})
                    if line.startswith("ENTRY"):
                        entry = m.group(1)
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_LINE.match(line)
        if not m:
            continue
        name, type_str, opcode, rest = m.groups()
        operands, attrs = _split_operands(rest)
        # Depending on the XLA version, operands print bare ("%name") or
        # type-prefixed ("f32[64,64]{1,0} %name"); keep the trailing token.
        names = [o.split()[-1].lstrip("%") if o else o for o in operands]
        op = Op(name, type_str, opcode, names, attrs)
        cur.ops.append(op)
        cur.shapes[name] = type_str
        cur.byname[name] = op
    if cur is not None:
        comps[cur.name] = cur
    if entry is not None:
        comps["__entry__"] = comps[entry]
    return comps


# ------------------------------ cost walking --------------------------------

@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_link_bytes: float = 0.0
    coll_raw_bytes: float = 0.0
    colls: Dict[str, Dict[str, float]] = dataclasses.field(
        default_factory=dict)
    flags: List[str] = dataclasses.field(default_factory=list)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        self.coll_link_bytes += mult * other.coll_link_bytes
        self.coll_raw_bytes += mult * other.coll_raw_bytes
        for k, v in other.colls.items():
            slot = self.colls.setdefault(k, {"count": 0.0, "bytes": 0.0})
            slot["count"] += mult * v["count"]
            slot["bytes"] += mult * v["bytes"]
        for f in other.flags:
            if f not in self.flags:
                self.flags.append(f)


_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_RG_V1_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_RG_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _trip_count(cond: Computation) -> Optional[int]:
    """Largest s32 scalar constant in the loop condition — jax scan/fori
    emit ``lt(i, constant(T))`` so this recovers T exactly."""
    consts = []
    for op in cond.ops:
        if op.opcode == "constant" and op.type_str.strip() == "s32[]":
            if op.operands and op.operands[0].isdigit():
                consts.append(int(op.operands[0]))
    return max(consts) if consts else None


def _group_size(attrs: str, default: int) -> int:
    m = _RG_V1_RE.search(attrs)
    if m:
        return len(m.group(1).split(","))
    m = _RG_V2_RE.search(attrs)
    if m:
        return int(m.group(2))
    return default


def _collective_link_bytes(kind: str, raw: int, g: int) -> float:
    if g <= 1:
        return 0.0
    frac = (g - 1) / g
    if kind == "all-reduce":
        return 2.0 * raw * frac
    if kind in ("all-gather", "reduce-scatter", "all-to-all",
                "ragged-all-to-all", "collective-broadcast"):
        return raw * frac
    if kind == "collective-permute":
        return float(raw)
    return raw * frac


class HloCostModel:
    def __init__(self, comps: Dict[str, Computation],
                 n_partitions: int = 1):
        self.comps = comps
        self.n_partitions = n_partitions
        self._memo: Dict[str, Cost] = {}

    # -- per-op ---------------------------------------------------------
    def op_cost(self, op: Op, comp: Computation) -> Cost:
        c = Cost()
        opcode = op.opcode
        if opcode in _FREE:
            if opcode == "custom-call":
                c.flags.append(f"custom-call:{op.attrs[:40]}")
            return c

        # async pairs: count at -start, skip -done/-update
        if opcode.endswith("-done") or opcode.endswith("-update"):
            return c
        base = opcode[:-6] if opcode.endswith("-start") else opcode

        _, out_bytes = _shape_elems_bytes(op.type_str)
        in_bytes = 0
        for o in op.operands:
            t = comp.shapes.get(o)
            if t is not None:
                in_bytes += _shape_elems_bytes(t)[1]

        if base in _COLLECTIVES:
            # convention: raw = result bytes (all-gather at gathered size)
            g = _group_size(op.attrs, self.n_partitions)
            raw = out_bytes
            c.coll_raw_bytes = raw
            c.coll_link_bytes = _collective_link_bytes(base, raw, g)
            slot = c.colls.setdefault(base, {"count": 0.0, "bytes": 0.0})
            slot["count"] += 1
            slot["bytes"] += raw
            return c

        if base == "dot":
            out_elems, _ = _shape_elems_bytes(op.type_str)
            k = 1
            lhs_t = comp.shapes.get(op.operands[0], "")
            dims = _shape_dims(lhs_t)
            m = _LHS_C_RE.search(op.attrs)
            if m and m.group(1):
                for d in m.group(1).split(","):
                    di = int(d)
                    if di < len(dims):
                        k *= dims[di]
            c.flops = 2.0 * out_elems * k
            # operand bytes at the PRE-staging dtype: the CPU backend
            # converts int8/bf16 dot operands to s32/f32 first, the TPU
            # target (MXU) consumes them natively — follow convert chains
            # back to the source so int8 dots are charged int8 traffic.
            c.bytes = sum(self._source_bytes(comp, o)
                          for o in op.operands) + out_bytes
            return c

        if base == "convolution":
            out_elems, _ = _shape_elems_bytes(op.type_str)
            kshape = _shape_dims(comp.shapes.get(op.operands[1], "")) \
                if len(op.operands) > 1 else []
            kprod = 1
            for d in kshape[:-1]:       # kernel spatial+in-feature dims
                kprod *= d
            c.flops = 2.0 * out_elems * max(kprod, 1)
            c.bytes = in_bytes + out_bytes
            return c

        if base == "fusion":
            m = _CALLS_RE.search(op.attrs)
            if m and m.group(1) in self.comps:
                called = self.comps[m.group(1)]
                if self._is_pure_convert(called):
                    # dtype-staging fusion: free on the TPU target (see
                    # `convert` below)
                    return c
                c.flops = self.flops_only(m.group(1))
            c.bytes = in_bytes + out_bytes
            return c

        if base == "convert":
            # The CPU backend materializes f32 staging copies of bf16/int8
            # dot/collective operands (verified in HLO: whole-KV-cache
            # converts hoisted out of the decode loop).  The TPU target
            # consumes bf16/int8 natively (MXU) and fuses residual dtype
            # casts into consumers — standalone converts are counted FREE,
            # and the inflation that remains on downstream f32-shaped ops
            # is reported as a documented CPU-backend artifact.
            return c

        if base == "while":
            m_c, m_b = _COND_RE.search(op.attrs), _BODY_RE.search(op.attrs)
            trips = None
            if m_c and m_c.group(1) in self.comps:
                trips = _trip_count(self.comps[m_c.group(1)])
            if trips is None:
                trips = 1
                c.flags.append(f"while-trip-unparsed:{op.name}")
            if m_b and m_b.group(1) in self.comps:
                c.add(self.comp_cost(m_b.group(1)), mult=float(trips))
            if m_c and m_c.group(1) in self.comps:
                c.add(self.comp_cost(m_c.group(1)), mult=float(trips))
            return c

        if base == "conditional":
            m = _BRANCHES_RE.search(op.attrs)
            if m:
                best = Cost()
                for name in m.group(1).split(","):
                    name = name.strip().lstrip("%")
                    if name in self.comps:
                        bc = self.comp_cost(name)
                        if bc.flops >= best.flops:
                            best = bc
                c.add(best)
            return c

        if base == "call":
            m = _TO_APPLY_RE.search(op.attrs)
            if m and m.group(1) in self.comps:
                if self._is_pure_convert(self.comps[m.group(1)]):
                    return c      # dtype-staging call: free (see `convert`)
                c.add(self.comp_cost(m.group(1)))
            c.bytes = in_bytes + out_bytes
            return c

        if base == "dynamic-update-slice":
            # in-place: update + indices read, update-sized write
            upd_b = 0
            if len(op.operands) > 1:
                upd_b = _shape_elems_bytes(
                    comp.shapes.get(op.operands[1], ""))[1]
            c.bytes = 2 * upd_b + 64
            return c

        if base in ("dynamic-slice", "gather", "slice"):
            c.bytes = 2 * out_bytes + 64     # read window + write result
            return c

        if base == "scatter":
            upd_b = 0
            if len(op.operands) > 2:
                upd_b = _shape_elems_bytes(
                    comp.shapes.get(op.operands[2], ""))[1]
            c.bytes = 2 * upd_b + 64
            c.flops = float(_shape_elems_bytes(
                comp.shapes.get(op.operands[2], ""))[0]
                if len(op.operands) > 2 else 0)
            return c

        if base in ("reduce", "reduce-window"):
            in_elems = _shape_elems_bytes(
                comp.shapes.get(op.operands[0], ""))[0]
            c.flops = float(in_elems)
            c.bytes = in_bytes + out_bytes
            return c

        if base in ("sort", "top-k"):
            in_elems = _shape_elems_bytes(
                comp.shapes.get(op.operands[0], ""))[0]
            c.flops = float(in_elems) * 10.0   # ~n log n comparisons
            c.bytes = 2 * (in_bytes + out_bytes)
            return c

        if base in _ELEMENTWISE:
            out_elems, _ = _shape_elems_bytes(op.type_str)
            c.flops = float(out_elems)
            c.bytes = in_bytes + out_bytes
            return c

        if base in ("copy", "transpose", "broadcast", "pad", "concatenate",
                    "reverse", "copy-start"):
            c.bytes = in_bytes + out_bytes
            return c

        # unknown opcode: count bytes, flag it
        c.bytes = in_bytes + out_bytes
        c.flags.append(f"unknown-op:{base}")
        return c

    def _source_bytes(self, comp: Computation, name: str,
                      depth: int = 0) -> int:
        """Bytes of ``name`` at its pre-staging dtype (follows convert /
        pure-convert call/fusion producers, bounded depth)."""
        op = comp.byname.get(name)
        if op is not None and depth < 8 and op.operands:
            if op.opcode in ("convert", "copy", "bitcast", "reshape"):
                return self._source_bytes(comp, op.operands[0], depth + 1)
            if op.opcode in ("call", "fusion"):
                rex = _TO_APPLY_RE if op.opcode == "call" else _CALLS_RE
                m = rex.search(op.attrs)
                if (m and m.group(1) in self.comps
                        and self._is_pure_convert(self.comps[m.group(1)])):
                    return self._source_bytes(comp, op.operands[0],
                                              depth + 1)
        t = comp.shapes.get(name)
        return _shape_elems_bytes(t)[1] if t else 0

    def _is_pure_convert(self, comp: Computation) -> bool:
        real = [op for op in comp.ops
                if op.opcode not in ("parameter", "bitcast", "reshape",
                                     "copy", "transpose")]
        return bool(real) and all(op.opcode == "convert" for op in real)

    # -- per-computation -------------------------------------------------
    def comp_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps[name]
        total = Cost()
        for op in comp.ops:
            total.add(self.op_cost(op, comp))
        self._memo[name] = total
        return total

    def flops_only(self, name: str) -> float:
        return self.comp_cost(name).flops


def analyze_hlo_text(text: str, n_partitions: int = 1) -> dict:
    """Full trip-aware analysis of a compiled module's text.

    Returns a JSON-friendly dict; all quantities are **global** (whole
    program across all partitions — divide by device count for per-chip).
    """
    comps = parse_hlo(text)
    if "__entry__" not in comps:
        raise ValueError("no ENTRY computation found")
    model = HloCostModel(comps, n_partitions)
    cost = model.comp_cost(comps["__entry__"].name)
    return {
        "flops": cost.flops,
        "bytes": cost.bytes,
        "collective_link_bytes": cost.coll_link_bytes,
        "collective_raw_bytes": cost.coll_raw_bytes,
        "collectives": cost.colls,
        "flags": cost.flags,
    }
