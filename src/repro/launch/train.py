"""Training driver: ``python -m repro.launch.train --arch llama3.2-1b ...``

Composes the full stack: config -> model -> mesh/shardings -> jitted
train step -> seeded data pipeline -> fault-tolerant loop (ABFT metrics,
detect->recompute, checksummed async checkpoints, straggler telemetry).

Defaults are sized for the in-container CPU (1 device, reduced configs via
``--smoke``); on a real pod the same flags drive the production mesh.
"""
from __future__ import annotations

# ruff: noqa: E402
import argparse
import dataclasses
import logging
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--smoke", action="store_true",
                    help="reduce the arch to smoke size (CPU-runnable)")
    ap.add_argument("--mesh", default="host",
                    choices=["host", "single", "multi"])
    ap.add_argument("--mesh-shape", default="1,1",
                    help="host mesh (data,model), e.g. 2,2")
    ap.add_argument("--float-abft", action="store_true",
                    help="float ABFT checks on training GEMMs")
    ap.add_argument("--compress", action="store_true",
                    help="int8 error-feedback gradient compression with "
                         "mod-checksum verification (runtime.compression; "
                         "comm/errors feeds the loop's fault policy)")
    ap.add_argument("--fault-policy", default="recompute",
                    choices=["log", "recompute", "restore"])
    ap.add_argument("--device-count", type=int, default=0,
                    help="force N host devices (set before jax init)")
    args = ap.parse_args()

    if args.device_count:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.device_count}")

    import jax
    import jax.numpy as jnp

    from repro.configs.base import ShapeConfig
    from repro.configs.registry import get_arch
    from repro.data import make_dataset
    from repro.launch.mesh import make_host_mesh, make_production_mesh
    from repro.launch.steps import (init_train_state, make_train_step,
                                    train_state_lp)
    from repro.layers.common import Ctx
    from repro.models.base import build_model
    from repro.runtime import LoopConfig, TrainLoop
    from repro.sharding import shardings_of
    from repro.sharding.rules import train_rules

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    log = logging.getLogger("repro.train")

    cfg = get_arch(args.arch)
    if args.smoke:
        import sys
        sys.path.insert(0, os.path.join(os.path.dirname(__file__),
                                        "..", "..", "..", "tests"))
        from helpers import reduce_cfg
        cfg = reduce_cfg(cfg)
    if args.accum > 1:
        cfg = dataclasses.replace(cfg, train_accum=args.accum)

    shape = ShapeConfig("cli", "train", args.seq_len, args.batch)
    model = build_model(cfg, max_pos=args.seq_len + cfg.meta_tokens + 8)

    if args.mesh == "host":
        mshape = tuple(int(x) for x in args.mesh_shape.split(","))
        mesh = make_host_mesh(mshape)
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    rules = train_rules(args.mesh == "multi")
    ctx = Ctx(rules=rules, quant=False, float_abft=args.float_abft,
              compute_dtype=jnp.bfloat16)

    step_fn = make_train_step(model, ctx, accum=cfg.train_accum,
                              peak_lr=args.lr, total_steps=args.steps,
                              compress=args.compress)
    state_lp = train_state_lp(model, compress=args.compress)
    state_sh = shardings_of(state_lp, rules, mesh)
    batch_sh = shardings_of(model.input_specs(shape), rules, mesh)

    with mesh:
        state = init_train_state(model, jax.random.key(0),
                                 compress=args.compress)
        state = jax.device_put(state, state_sh)
        jitted = jax.jit(step_fn, in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, None),
                         donate_argnums=(0,))

        dataset = make_dataset(cfg, shape)
        n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
        log.info("arch=%s params=%.1fM mesh=%s accum=%d",
                 cfg.name, n_params / 1e6, mesh.shape, cfg.train_accum)

        def hook(step, metrics):
            log.info("step %d loss=%.4f gnorm=%.3f gemm_err=%d eb_err=%d"
                     " comm_err=%d",
                     step, float(metrics.get("loss_final", float("nan"))),
                     float(metrics.get("grad_norm", float("nan"))),
                     int(metrics.get("abft/gemm_errors", 0)),
                     int(metrics.get("abft/eb_errors", 0)),
                     int(metrics.get("comm/errors", 0)))

        loop = TrainLoop(
            jitted, dataset,
            cfg=LoopConfig(ckpt_dir=args.ckpt_dir,
                           save_every=args.save_every,
                           fault_policy=args.fault_policy),
            shardings=batch_sh, metrics_hook=hook)
        state, metrics = loop.run(state, args.steps)
        log.info("done: %s | loop stats %s",
                 {k: float(v) for k, v in metrics.items()
                  if k in ("loss_final", "grad_norm")}, loop.stats)


if __name__ == "__main__":
    main()
