"""Step-function builders: train (grad-accum + AdamW + clip), prefill,
decode.  These are what the launcher jits, the dry-run lowers, and the
examples drive.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import policy
from repro.layers.common import Ctx
from repro.models.base import Model
from repro.optim import adamw_update, clip_by_global_norm
from repro.optim.schedule import warmup_cosine


def make_train_step(model: Model, ctx: Ctx, *, accum: int = 1,
                    peak_lr: float = 3e-4, warmup: int = 100,
                    total_steps: int = 10000, max_grad_norm: float = 1.0,
                    compress: bool = False, axis_name=None,
                    n_replicas: int = 1):
    """(state, batch) -> (state, metrics).

    state = {"params", "opt", "step"}; batch leaves lead with the global
    batch dim; with accum > 1 the batch is split into microbatches and
    gradients accumulate in f32 (scan — live activations stay one
    microbatch wide).

    ``compress=True`` routes gradients through the int8 error-feedback
    compressed, mod-checksum verified reduction of
    :mod:`repro.runtime.compression` (state gains a ``"comm"``
    CompressionState — init via ``init_train_state(compress=True)``) and
    surfaces the verifier in ``metrics["comm/errors"]`` for the
    TrainLoop's detect->act policy.  ``axis_name=None`` is the
    single-device verify-only path; under shard_map/pmap pass the data
    axis and its size.
    """

    def loss_fn(params, mb):
        loss, (metrics, rep) = model.loss(params, mb, ctx)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state, batch):
        params = state["params"]
        if accum == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum)
                                    + x.shape[1:]), batch)

            def body(g_acc, mb):
                (l, m), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return g_acc, (l, m)

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            grads, (losses, metrics) = jax.lax.scan(body, g0, micro)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = jnp.mean(losses)
            metrics = jax.tree.map(
                lambda x: jnp.mean(x.astype(jnp.float32)), metrics)

        metrics = dict(metrics)
        new_state = {}
        if compress:
            from repro.runtime.compression import compressed_allreduce
            grads, new_comm, comm_errs = compressed_allreduce(
                grads, state["comm"], axis_name, n_replicas)
            metrics["comm/errors"] = comm_errs
            new_state["comm"] = new_comm

        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        lr = warmup_cosine(state["step"], peak=peak_lr, warmup=warmup,
                           total=total_steps)
        new_params, new_opt = adamw_update(grads, state["opt"], params, lr)
        new_state.update(params=new_params, opt=new_opt,
                         step=state["step"] + 1)
        metrics.update({"grad_norm": gnorm, "lr": lr, "loss_final": loss})
        return new_state, metrics

    return train_step


def make_train_step_deferred(model: Model, ctx: Ctx, mesh, *,
                             accum: int = 1, peak_lr: float = 3e-4,
                             warmup: int = 100, total_steps: int = 10000,
                             max_grad_norm: float = 1.0,
                             compress: bool = True,
                             data_axes=("data",)):
    """Deferred-gradient-sync train step (EXPERIMENTS §Perf hillclimb 2).

    The pjit step syncs gradients *inside every microbatch* (XLA places the
    data-axis all-reduce in the scan body — it cannot hoist it out of the
    while loop) and re-gathers FSDP weights per microbatch.  Here the data
    axis is manual (shard_map): each device accumulates LOCAL grads over
    its microbatches, then ONE gradient collective per step — int8
    error-feedback compressed and mod-checksum verified
    (runtime.compression: the paper's checksummed-operator philosophy
    applied to the wire).  Params are replicated over `data` (sharded over
    `model` by the auto axis) — for models whose optimizer state fits
    without ZeRO.

    Returns (state, comm, batch) -> (state, comm, metrics).  ``comm`` is the
    per-device error-feedback residual tree with a leading data-axis dim
    (init via :func:`init_comm_state`); pass ``comm=None`` trees when
    ``compress=False``.
    """
    from jax.sharding import PartitionSpec as P

    from repro.runtime.compression import (checked_psum, compress_grads,
                                           decompress_grads)
    from repro.runtime.compression import CompressionState

    def loss_fn(params, mb):
        loss, (metrics, rep) = model.loss(params, mb, ctx)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    n_data = 1
    for a in data_axes:
        n_data *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    axis = data_axes if len(data_axes) > 1 else data_axes[0]

    def local_grads(params, batch):
        """Grad accumulation over local microbatches — no collectives."""
        if accum == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return grads, loss, metrics
        micro = jax.tree.map(
            lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
            batch)

        def body(g_acc, mb):
            (l, m), g = grad_fn(params, mb)
            return jax.tree.map(
                lambda a_, b_: a_ + b_.astype(jnp.float32), g_acc, g), (l, m)

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        grads, (losses, metrics) = jax.lax.scan(body, g0, micro)
        grads = jax.tree.map(lambda g: g / accum, grads)
        metrics = jax.tree.map(
            lambda x: jnp.mean(x.astype(jnp.float32)), metrics)
        return grads, jnp.mean(losses), metrics

    def _reduce_metrics(metrics):
        def red(v):
            v = jnp.asarray(v)
            if jnp.issubdtype(v.dtype, jnp.integer):
                return jax.lax.psum(v, axis)
            return jax.lax.pmean(v.astype(jnp.float32), axis)
        return jax.tree.map(red, metrics)

    def step(state, comm, batch):
        params = state["params"]
        grads, loss, metrics = local_grads(params, batch)

        if compress:
            comm_local = CompressionState(
                error=jax.tree.map(lambda e: e[0], comm.error))
            payload, comm_local = compress_grads(grads, comm_local)
            summed, scale_sum, comm_errs = checked_psum(payload, axis)
            grads = decompress_grads(summed, scale_sum, n_data)
            comm = CompressionState(
                error=jax.tree.map(lambda e: e[None], comm_local.error))
            metrics = dict(metrics)
            metrics["comm/errors"] = comm_errs
        else:
            grads = jax.lax.pmean(grads, axis)

        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        lr = warmup_cosine(state["step"], peak=peak_lr, warmup=warmup,
                           total=total_steps)
        new_params, new_opt = adamw_update(grads, state["opt"], params, lr)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = dict(metrics)
        metrics.update({"grad_norm": gnorm, "lr": lr, "loss_final": loss})
        return new_state, comm, _reduce_metrics(metrics)

    from repro.sharding import shard_map
    return shard_map(
        step, mesh=mesh,
        in_specs=(P(), P(axis), P(axis)),
        out_specs=(P(), P(axis), P()),
        manual_axes=set(data_axes))


def make_train_step_zero1(model: Model, ctx: Ctx, mesh, *,
                          accum: int = 1, peak_lr: float = 3e-4,
                          warmup: int = 100, total_steps: int = 10000,
                          max_grad_norm: float = 1.0,
                          axes=("data", "model")):
    """Pure data parallelism + ZeRO-1 over ALL mesh axes (hillclimb 2,
    iteration 4 — the right scheme for models whose bf16 params fit
    replicated on one chip, e.g. granite 3B on a 16 GB v5e).

    * no tensor parallelism -> ZERO per-microbatch collectives;
    * bf16 params replicated; f32 master/m/v live as FLAT SHARDS
      (1/N each — flat layout sidesteps per-leaf divisibility);
    * per step: one f32 gradient reduce-scatter, Adam on the local shard,
      one bf16 param all-gather.

    state = {"params": bf16 tree (replicated),
             "opt": {"master","m","v": f32 [D/N] flat shards}, "step"}.
    Returns the shard_map'd (state, batch) -> (state, metrics).
    """
    import numpy as np
    from jax.flatten_util import ravel_pytree
    from jax.sharding import PartitionSpec as P

    msizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_shards = 1
    for a in axes:
        n_shards *= msizes[a]
    axis = tuple(axes) if len(axes) > 1 else axes[0]

    def loss_fn(params, mb):
        loss, (metrics, rep) = model.loss(params, mb, ctx)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def local_grads(params, batch):
        if accum == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return grads, loss, metrics
        micro = jax.tree.map(
            lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
            batch)

        def body(g_acc, mb):
            (l, m), g = grad_fn(params, mb)
            return jax.tree.map(
                lambda a_, b_: a_ + b_.astype(jnp.float32), g_acc, g), (l, m)

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        grads, (losses, metrics) = jax.lax.scan(body, g0, micro)
        grads = jax.tree.map(lambda g: g / accum, grads)
        metrics = jax.tree.map(
            lambda x: jnp.mean(x.astype(jnp.float32)), metrics)
        return grads, jnp.mean(losses), metrics

    def step(state, batch):
        params = state["params"]
        grads, loss, metrics = local_grads(params, batch)
        # ravel in the gradients' own (bf16) dtype — an f32 staging copy
        # costs 2x params of HBM (measured: +13.5 GiB on granite); the
        # bf16 reduce-scatter is the standard TPU-pod trade, and the f32
        # conversion happens on the 1/N local shard only.
        gflat, unravel = ravel_pytree(
            jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads))
        d = gflat.shape[0]
        pad = (-d) % n_shards
        gflat = jnp.pad(gflat, (0, pad)) / n_shards
        gshard = jax.lax.psum_scatter(
            gflat.reshape(n_shards, -1), axis, scatter_dimension=0,
            tiled=False).astype(jnp.float32)

        # global-norm clip from shard-local sum of squares
        gnorm = jnp.sqrt(jax.lax.psum(jnp.sum(gshard * gshard), axis))
        scale = jnp.minimum(1.0, max_grad_norm / jnp.maximum(gnorm, 1e-9))
        gshard = gshard * scale

        lr = warmup_cosine(state["step"], peak=peak_lr, warmup=warmup,
                           total=total_steps)
        b1, b2, eps, wd = 0.9, 0.95, 1e-8, 0.1
        cnt = (state["step"] + 1).astype(jnp.float32)
        m = b1 * state["opt"]["m"] + (1 - b1) * gshard
        v = b2 * state["opt"]["v"] + (1 - b2) * gshard * gshard
        mh = m / (1 - b1 ** cnt)
        vh = v / (1 - b2 ** cnt)
        master = state["opt"]["master"]
        master = master - lr * (mh / (jnp.sqrt(vh) + eps) + wd * master)

        # ONE collective for params: bf16 all-gather of updated shards
        pflat = jax.lax.all_gather(
            master.astype(jnp.bfloat16), axis, tiled=True)
        if pad:
            pflat = pflat[:-pad]
        new_params = jax.tree.map(
            lambda a, ref: a.astype(ref.dtype), unravel(pflat), params)

        new_state = {"params": new_params,
                     "opt": {"master": master, "m": m, "v": v},
                     "step": state["step"] + 1}
        metrics = dict(metrics)
        metrics.update({"grad_norm": gnorm, "lr": lr,
                        "loss_final": jax.lax.pmean(loss, axis)})
        metrics = jax.tree.map(
            lambda x: (jax.lax.psum(x, axis)
                       if jnp.issubdtype(jnp.asarray(x).dtype, jnp.integer)
                       else jax.lax.pmean(
                           jnp.asarray(x, jnp.float32), axis)), metrics)
        return new_state, metrics

    batch_spec = P(axis)
    state_spec = {"params": P(), "opt": P(axis), "step": P()}
    from repro.sharding import shard_map
    return shard_map(
        step, mesh=mesh,
        in_specs=(state_spec, batch_spec),
        out_specs=(state_spec, P()),
        manual_axes=set(axes))


def zero1_state_sds(model: Model, mesh, axes=("data", "model")):
    """ShapeDtypeStructs + shardings for the ZeRO-1 state."""
    from jax.flatten_util import ravel_pytree  # noqa: F401
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.sharding import values_of

    msizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_shards = 1
    for a in axes:
        n_shards *= msizes[a]
    params_lp = jax.eval_shape(
        lambda: model.init(jax.random.key(0), dtype=jnp.bfloat16))
    params_sds = values_of(params_lp)
    d = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params_sds))
    d_pad = d + ((-d) % n_shards)
    shard = jax.ShapeDtypeStruct((d_pad // n_shards,), jnp.float32)
    state_sds = {
        "params": params_sds,
        "opt": {"master": jax.ShapeDtypeStruct((d_pad,), jnp.float32),
                "m": jax.ShapeDtypeStruct((d_pad,), jnp.float32),
                "v": jax.ShapeDtypeStruct((d_pad,), jnp.float32)},
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    axis = tuple(axes) if len(axes) > 1 else axes[0]
    repl = NamedSharding(mesh, P())
    state_sh = {
        "params": jax.tree.map(lambda _: repl, params_sds),
        "opt": {"master": NamedSharding(mesh, P(axis)),
                "m": NamedSharding(mesh, P(axis)),
                "v": NamedSharding(mesh, P(axis))},
        "step": repl,
    }
    del shard
    return state_sds, state_sh, params_lp


import numpy as np  # noqa: E402  (zero1_state_sds)


def init_comm_state(params_sds, n_data: int):
    """Per-device error-feedback residuals, leading data-axis dim."""
    from repro.runtime.compression import CompressionState

    return CompressionState(error=jax.tree.map(
        lambda p: jax.ShapeDtypeStruct((n_data,) + tuple(
            jnp.shape(p) if not hasattr(p, "shape") else p.shape),
            jnp.float32), params_sds))


def make_prefill_step(model: Model, ctx: Ctx, cache_len: int):
    """(params, batch) -> (next_token [B], cache, metrics)."""

    def prefill_step(params, batch):
        logits, cache, rep = model.prefill(params, batch, ctx, cache_len)
        next_tok = jnp.argmax(
            logits[..., :model.cfg.vocab], axis=-1).astype(jnp.int32)
        return next_tok, cache, rep.as_metrics()

    return prefill_step


def make_decode_step(model: Model, ctx: Ctx):
    """(params, cache, tokens [B], pos [B]) -> (next [B], cache, metrics)."""

    def decode_step(params, cache, tokens, pos):
        logits, new_cache, rep = model.decode(params, cache, tokens, pos,
                                              ctx)
        next_tok = jnp.argmax(
            logits[..., :model.cfg.vocab], axis=-1).astype(jnp.int32)
        return next_tok, new_cache, rep.as_metrics()

    return decode_step


def init_train_state(model: Model, key, *, dtype=jnp.float32,
                     compress: bool = False):
    """Concrete state (examples / small runs). Dry-run uses eval_shape.

    ``compress=True`` adds the ``"comm"`` error-feedback residual tree for
    ``make_train_step(compress=True)``."""
    from repro.optim import adamw_init
    from repro.sharding import values_of

    params = values_of(model.init(key, dtype=dtype))
    state = {"params": params, "opt": adamw_init(params),
             "step": jnp.zeros((), jnp.int32)}
    if compress:
        from repro.runtime.compression import init_compression
        state["comm"] = init_compression(params)
    return state


def train_state_lp(model: Model, *, dtype=jnp.float32,
                   compress: bool = False):
    """LogicalParam tree of ShapeDtypeStructs for the full train state.

    Moments carry the parameter's logical axes (ZeRO falls out of the FSDP
    rules); non-trainable leaves (packed int8 weights, EB tables) get
    zero-size placeholders, matching optim.adamw_init.  ``compress=True``
    adds the f32 error-feedback residuals, sharded like their parameters.
    """
    from repro.sharding import LogicalParam, is_lp

    params_lp = jax.eval_shape(
        lambda: model.init(jax.random.key(0), dtype=dtype))

    def mom(p):
        v = p.value
        if jnp.issubdtype(v.dtype, jnp.floating):
            return LogicalParam(
                jax.ShapeDtypeStruct(v.shape, jnp.float32), p.axes)
        return LogicalParam(
            jax.ShapeDtypeStruct((0,), jnp.float32), (None,))

    m_lp = jax.tree.map(mom, params_lp, is_leaf=is_lp)
    scalar = LogicalParam(jax.ShapeDtypeStruct((), jnp.int32), ())
    state = {
        "params": params_lp,
        "opt": {"m": m_lp,
                "v": jax.tree.map(lambda x: x, m_lp, is_leaf=is_lp),
                "count": scalar},
        "step": scalar,
    }
    if compress:
        from repro.runtime.compression import CompressionState

        def residual(p):
            return LogicalParam(
                jax.ShapeDtypeStruct(p.value.shape, jnp.float32), p.axes)

        state["comm"] = CompressionState(
            error=jax.tree.map(residual, params_lp, is_leaf=is_lp))
    return state
