"""HLO post-processing for the roofline: collective-byte accounting.

``collective_bytes`` is not in ``compiled.cost_analysis()``; we parse the
compiled (post-SPMD) HLO text and sum the **result** bytes of every
collective op (all-gather results count at gathered size, all-reduce at
tensor size, reduce-scatter at the scattered shard size) — a consistent,
reproducible convention recorded in EXPERIMENTS.md.

Async pairs (``all-gather-start``/``-done``) are counted once at ``-start``.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "collective-broadcast", "ragged-all-to-all")

_SHAPE_RE = re.compile(r"([a-z]+[0-9]*)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*((?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?(?:,\s*)?)+)\)?\s*"
    r"(" + "|".join(_COLLECTIVES) + r")(-start)?\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_stats(hlo_text: str) -> Dict[str, dict]:
    """Per-collective-kind {count, bytes} + total, from compiled HLO text."""
    stats: Dict[str, dict] = defaultdict(lambda: {"count": 0, "bytes": 0})
    seen_done = ("-done(", "-update(")
    for line in hlo_text.splitlines():
        if not any(c in line for c in _COLLECTIVES):
            continue
        if any(s in line for s in seen_done):
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        shapes, kind, _ = m.groups()
        b = _shape_bytes(shapes)
        stats[kind]["count"] += 1
        stats[kind]["bytes"] += b
    total = {"count": sum(v["count"] for v in stats.values()),
             "bytes": sum(v["bytes"] for v in stats.values())}
    out = dict(stats)
    out["total"] = total
    return out


def scan_trip_counts(hlo_text: str) -> int:
    """Best-effort: product-free sum of while-loop trip counts is not
    recoverable from text portably; we rely on cost_analysis flops instead.
    Kept for HLO inspection in the perf loop."""
    return hlo_text.count("while(")
