"""Roofline terms from dry-run artifacts (TPU v5e targets).

Per (arch × shape × mesh) cell::

    compute    = flops_per_device / PEAK_FLOPS
    memory     = bytes_per_device / HBM_BW
    collective = link_bytes_per_device / ICI_BW

All inputs are per-device quantities (the compiled module is the SPMD
per-partition program — verified convention, see EXPERIMENTS.md §Dry-run).
``MODEL_FLOPS`` is the analytic useful-work floor:
    train   6·N_active·tokens      (fwd 2x + bwd 4x)
    prefill 2·N_active·tokens
    decode  2·N_active·batch       (one token per sequence)
The ratio MODEL_FLOPS / (HLO flops × devices) exposes remat/redundancy
waste (>1/3 for remat-heavy training is expected: remat re-runs fwd).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax

# TPU v5e hardware constants (assignment-specified)
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip (int8 counted at same rate)
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link


def roofline_terms(cost: dict, *, n_devices: int) -> Dict[str, float]:
    compute = cost["flops"] / PEAK_FLOPS
    memory = cost["bytes"] / HBM_BW
    collective = cost["collective_link_bytes"] / ICI_BW
    dominant = max(("compute", compute), ("memory", memory),
                   ("collective", collective), key=lambda kv: kv[1])
    bound = max(compute, memory, collective)
    return {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "dominant": dominant[0],
        "step_lower_bound_s": bound,
        # fraction of roofline achieved if the step ran exactly at the
        # dominant-term bound with perfect overlap of the other two
        "roofline_fraction": (compute / bound) if bound > 0 else 0.0,
    }


def count_params(lp_tree, *, active_moe: Optional[float] = None,
                 moe_key: str = "moe") -> Dict[str, float]:
    """(total, active) parameter counts from a LogicalParam/SDS tree.

    ``active_moe`` scales leaves under a ``moe`` subtree by top_k/n_experts
    (router-active fraction) for the MoE MODEL_FLOPS convention.
    """
    from repro.sharding import is_lp

    total = 0.0
    active = 0.0

    def visit(path, leaf):
        nonlocal total, active
        v = leaf.value if is_lp(leaf) else leaf
        n = 1.0
        for d in v.shape:
            n *= d
        total += n
        frac = 1.0
        if active_moe is not None and any(
                getattr(k, "key", None) == moe_key for k in path):
            frac = active_moe
        active += frac * n

    leaves = jax.tree_util.tree_flatten_with_path(
        lp_tree, is_leaf=is_lp)[0]
    for path, leaf in leaves:
        visit(path, leaf)
    return {"total": total, "active": active}


def model_flops(kind: str, n_active: float, *, tokens: float) -> float:
    """Analytic useful FLOPs for the whole step (global, all devices)."""
    if kind == "train":
        return 6.0 * n_active * tokens
    return 2.0 * n_active * tokens     # prefill & decode fwd-only
