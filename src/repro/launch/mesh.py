"""Production mesh builders.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """(16,16)=("data","model") single pod; (2,16,16)=("pod","data","model")
    for the 2-pod / 512-chip configuration."""
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    try:
        return jax.make_mesh(shape, axes)
    except (ValueError, TypeError):
        # fall back: slice exactly prod(shape) devices and reshape
        n = int(np.prod(shape))
        devices = np.asarray(jax.devices()[:n]).reshape(shape)
        from jax.sharding import Mesh
        return Mesh(devices, axes)


def make_host_mesh(shape=(1, 1), axes=("data", "model"), devices=None):
    """Tiny mesh over host devices (tests / examples / campaign cells).

    ``devices`` picks an explicit slice (the campaign executor places
    sharded cells on disjoint slices of the forced host platform); the
    default is the front of ``jax.devices()``.  Asking for more devices
    than exist is a clear error here instead of a reshape failure deep in
    Mesh construction.
    """
    import jax

    n = int(np.prod(shape))
    pool = list(devices) if devices is not None else jax.devices()
    if len(pool) < n:
        raise ValueError(
            f"mesh shape {tuple(shape)} needs {n} devices but only "
            f"{len(pool)} are available (force more with XLA_FLAGS="
            f"--xla_force_host_platform_device_count=N before jax init)")
    arr = np.asarray(pool[:n]).reshape(shape)
    from jax.sharding import Mesh
    return Mesh(arr, axes)
