"""Perf-loop profiler: per-op byte/flop attribution for one dry-run cell.

    PYTHONPATH=src python -m repro.launch.inspect_cell \
        --arch rwkv6-1.6b --shape train_4k --set wkv_chunk=16 --top 25

Compiles the cell like repro.launch.dryrun and prints the top HBM-byte
contributors with their jax-level op_name metadata (trip-multiplied), which
maps each hot spot back to a source line — the "profile" of the dry-run
methodology (no real hardware).
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# ruff: noqa: E402
import argparse
import collections
import re

from repro.launch import dryrun as dr
from repro.launch.costs import HloCostModel, _trip_count, parse_hlo
from repro.launch.mesh import make_production_mesh
from repro.configs.base import SHAPES
from repro.configs.registry import get_arch
from repro.models.base import build_model
from repro.sharding.rules import serve_rules, train_rules

_META_RE = re.compile(r'op_name="([^"]*)"')


def attribute(comps, model, entry):
    by_name_bytes = collections.Counter()
    by_name_flops = collections.Counter()

    def walk(name, mult):
        comp = comps[name]
        for op in comp.ops:
            base = (op.opcode[:-6] if op.opcode.endswith("-start")
                    else op.opcode)
            if base == "while":
                mc = re.search(r"condition=%?([\w.\-]+)", op.attrs)
                mb = re.search(r"body=%?([\w.\-]+)", op.attrs)
                trips = (_trip_count(comps[mc.group(1)])
                         if mc and mc.group(1) in comps else None) or 1
                if mb and mb.group(1) in comps:
                    walk(mb.group(1), mult * trips)
                continue
            c = model.op_cost(op, comp)
            m = _META_RE.search(op.attrs)
            tag = m.group(1) if m else f"<{base}>"
            # strip jit wrapper + uniquifying suffixes for grouping
            tag = re.sub(r"jit\([^)]*\)/", "", tag)
            tag = re.sub(r"\[.*$", "", tag)
            by_name_bytes[tag] += mult * (c.bytes + c.coll_link_bytes)
            by_name_flops[tag] += mult * c.flops
    walk(entry, 1)
    return by_name_bytes, by_name_flops


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=25)
    ap.add_argument("--set", action="append", default=[], metavar="K=V")
    args = ap.parse_args()

    for kv in args.set:
        k, v = kv.split("=", 1)
        dr.CTX_OVERRIDES[k] = (int(v) if v.lstrip("-").isdigit()
                               else v == "True" if v in ("True", "False")
                               else float(v) if "." in v else v)

    cfg = get_arch(args.arch)
    shape = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    rules = (train_rules(args.multi_pod) if shape.kind == "train"
             else serve_rules(args.multi_pod))
    model = build_model(cfg, max_pos=max(shape.seq_len, 4096)
                        + cfg.meta_tokens + 1)
    with mesh:
        if shape.kind == "train":
            lowered, _ = dr.build_train(model, shape, rules, mesh)
        elif shape.kind == "prefill":
            lowered, _ = dr.build_prefill(model, shape, rules, mesh)
        else:
            lowered, _ = dr.build_decode(model, shape, rules, mesh)
        compiled = lowered.compile()

    comps = parse_hlo(compiled.as_text())
    cm = HloCostModel(comps, mesh.devices.size)
    by_bytes, by_flops = attribute(comps, cm, comps["__entry__"].name)
    total_b = sum(by_bytes.values())
    total_f = sum(by_flops.values())
    print(f"\n== {args.arch} × {args.shape} — per-device totals: "
          f"{total_b/1e9:.1f} GB, {total_f/1e12:.2f} TFLOP ==")
    print(f"{'bytes':>10s} {'%':>5s} {'flops%':>6s}  op_name")
    for tag, b in by_bytes.most_common(args.top):
        print(f"{b/1e9:9.1f}G {100*b/total_b:5.1f} "
              f"{100*by_flops[tag]/max(total_f,1):6.1f}  {tag[:105]}")


if __name__ == "__main__":
    main()
