"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST be run as a module entry point (``python -m repro.launch.dryrun``) —
the first two lines below force 512 host platform devices BEFORE any jax
initialization so ``make_production_mesh`` can build the production meshes:
(16,16)=("data","model") single-pod and (2,16,16)=("pod","data","model")
multi-pod.

Per cell this produces ``artifacts/dryrun/<arch>__<shape>__<mesh>.json``:
  * compile success (sharding coherence proof) + compile wall time,
  * ``memory_analysis()``  — per-device bytes (fits-in-HBM proof),
  * trip-aware cost analysis (launch.costs) — flops / HBM bytes /
    collective bytes per device,
  * analytic MODEL_FLOPS and params (launch.roofline),
  * the collective schedule breakdown.

Skips (recorded, per DESIGN.md §Arch-applicability):
  * ``long_500k`` for pure full-attention archs (O(S²)/O(S·cache) decode at
    524k is out of scope by assignment; sub-quadratic archs run it).
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# ruff: noqa: E402
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES
from repro.configs.registry import get_arch, list_archs
from repro.launch.costs import analyze_hlo_text
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (count_params, model_flops,
                                   roofline_terms)
from repro.launch.steps import (make_decode_step, make_prefill_step,
                                make_train_step, make_train_step_deferred)
from repro.layers.common import Ctx
from repro.models.base import Model, build_model
from repro.sharding import shardings_of, values_of
from repro.sharding.rules import serve_rules, train_rules

LM_ARCHS = [a for a in list_archs() if a != "dlrm"]


def replicated(mesh):
    from jax.sharding import NamedSharding, PartitionSpec as P
    return NamedSharding(mesh, P())


def cell_skip_reason(cfg, shape) -> str | None:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("full-attention: 524k dense-KV decode excluded by "
                "assignment; sub-quadratic archs (rwkv6, hymba) run it")
    return None


def _tree_shardings(lp_tree, rules, mesh):
    return shardings_of(lp_tree, rules, mesh)


#: Ctx overrides for A/B perf runs (set by --set k=v; EXPERIMENTS §Perf).
CTX_OVERRIDES: dict = {}


def _ctx(**kw) -> Ctx:
    import dataclasses as _dc
    fields = {f.name for f in _dc.fields(Ctx)}
    ov = {k: v for k, v in CTX_OVERRIDES.items() if k in fields}
    return Ctx(**kw).replace(**ov)


def build_train(model: Model, shape, rules, mesh):
    cfg = model.cfg
    ctx = _ctx(rules=rules, quant=False, abft=False, float_abft=False,
               compute_dtype=jnp.bfloat16,
               wkv_chunk=cfg.wkv_chunk, ssm_chunk=cfg.ssm_chunk)
    # microbatches must stay shardable over the batch axes: clamp accum so
    # global_batch/accum is a multiple of the data(+pod) extent
    msz = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_batch_shards = msz.get("data", 1) * msz.get("pod", 1)
    accum = max(1, min(cfg.train_accum, shape.global_batch // n_batch_shards))
    while shape.global_batch // accum % n_batch_shards:
        accum -= 1

    from repro.launch.steps import train_state_lp
    state_lp = train_state_lp(model)
    params_lp = state_lp["params"]
    batch_lp = model.input_specs(shape)

    if CTX_OVERRIDES.get("zero1") or cfg.zero1:
        # hillclimb 2, iteration 4: pure DP over every mesh axis + ZeRO-1
        # flat-sharded optimizer — zero per-microbatch collectives
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.launch.steps import (make_train_step_zero1,
                                        zero1_state_sds)
        axes = (("pod", "data", "model") if "pod" in mesh.axis_names
                else ("data", "model"))
        ctx = ctx.replace(rules=None)
        step_fn = make_train_step_zero1(model, ctx, mesh, accum=1,
                                        axes=axes)
        state_sds, state_sh, params_lp = zero1_state_sds(model, mesh,
                                                         axes=axes)
        axis = tuple(axes)
        batch_sh = jax.tree.map(
            lambda _: NamedSharding(mesh, P(axis)), values_of(batch_lp))
        jitted = jax.jit(step_fn, in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, None),
                         donate_argnums=(0,))
        lowered = jitted.lower(state_sds, values_of(batch_lp))
        return lowered, params_lp

    if CTX_OVERRIDES.get("deferred_sync") or cfg.deferred_grad_sync:
        # hillclimb 2: manual data axis, one int8+checksum grad collective
        # per step, params replicated over data (no ZeRO) — see
        # steps.make_train_step_deferred
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.launch.steps import init_comm_state
        ctx = ctx.replace(rules={**rules, "embed": None})
        repl_rules = {**rules, "embed": None}
        data_axes = (("pod", "data") if "pod" in mesh.axis_names
                     else ("data",))
        step_fn = make_train_step_deferred(
            model, ctx, mesh, accum=accum, data_axes=data_axes)
        state_sh = _tree_shardings(state_lp, repl_rules, mesh)
        state_sds = values_of(state_lp)
        n_data = 1
        for a in data_axes:
            n_data *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
        comm_sds = init_comm_state(state_sds["params"], n_data)
        # residuals shard over data on the stack dim AND over model via the
        # parameter's own logical axes (a full-f32 per-device residual set
        # would blow HBM on its own)
        from repro.runtime.compression import CompressionState
        from repro.sharding import LogicalParam, is_lp
        comm_lp = CompressionState(error=jax.tree.map(
            lambda p: LogicalParam(
                jax.ShapeDtypeStruct((n_data,) + p.value.shape, jnp.float32),
                ("comm_stack",) + p.axes),
            params_lp, is_leaf=is_lp))
        comm_rules = {**repl_rules, "comm_stack": data_axes}
        comm_sh = _tree_shardings(comm_lp, comm_rules, mesh)
        batch_sh = _tree_shardings(batch_lp, repl_rules, mesh)
        jitted = jax.jit(step_fn,
                         in_shardings=(state_sh, comm_sh, batch_sh),
                         out_shardings=(state_sh, comm_sh, None),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(state_sds, comm_sds, values_of(batch_lp))
        return lowered, params_lp

    step_fn = make_train_step(model, ctx, accum=accum)
    state_sh = _tree_shardings(state_lp, rules, mesh)
    state_sds = values_of(state_lp)
    batch_sh = _tree_shardings(batch_lp, rules, mesh)
    batch_sds = values_of(batch_lp)

    jitted = jax.jit(step_fn,
                     in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, None),
                     donate_argnums=(0,))
    lowered = jitted.lower(state_sds, batch_sds)
    return lowered, params_lp


def build_prefill(model: Model, shape, rules, mesh):
    cfg = model.cfg
    ctx = _ctx(rules=rules, quant=True, abft=True,
               compute_dtype=jnp.bfloat16,
               wkv_chunk=cfg.wkv_chunk, ssm_chunk=cfg.ssm_chunk)
    step_fn = make_prefill_step(model, ctx, cache_len=shape.seq_len)
    params_lp = jax.eval_shape(
        lambda: model.init(jax.random.key(0), quant=True))
    params_sh = _tree_shardings(params_lp, rules, mesh)
    batch_lp = model.input_specs(shape)
    batch_sh = _tree_shardings(batch_lp, rules, mesh)

    jitted = jax.jit(step_fn, in_shardings=(params_sh, batch_sh),
                     out_shardings=None)
    lowered = jitted.lower(values_of(params_lp), values_of(batch_lp))
    return lowered, params_lp


def build_decode(model: Model, shape, rules, mesh):
    ctx = _ctx(rules=rules, quant=True, abft=True,
               compute_dtype=jnp.bfloat16)
    step_fn = make_decode_step(model, ctx)
    params_lp = jax.eval_shape(
        lambda: model.init(jax.random.key(0), quant=True))
    params_sh = _tree_shardings(params_lp, rules, mesh)
    B = shape.global_batch
    cache_lp = jax.eval_shape(
        lambda: model.init_cache(B, shape.seq_len))
    cache_sh = _tree_shardings(cache_lp, rules, mesh)
    batch_lp = model.input_specs(shape)
    batch_sh = _tree_shardings(batch_lp, rules, mesh)

    jitted = jax.jit(
        step_fn,
        in_shardings=(params_sh, cache_sh, batch_sh["tokens"],
                      batch_sh["pos"]),
        out_shardings=(None, cache_sh, None),
        donate_argnums=(1,))
    lowered = jitted.lower(values_of(params_lp), values_of(cache_lp),
                           values_of(batch_lp)["tokens"],
                           values_of(batch_lp)["pos"])
    return lowered, params_lp


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str, *, skip_existing: bool = False) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    out_path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}.json")
    if skip_existing and os.path.exists(out_path):
        with open(out_path) as f:
            return json.load(f)

    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind, "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
    }
    reason = cell_skip_reason(cfg, shape)
    if reason:
        rec.update(status="skipped", skip_reason=reason)
        _write(out_path, rec)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    rec["n_devices"] = int(n_dev)
    rules = (train_rules(multi_pod) if shape.kind == "train"
             else serve_rules(multi_pod))
    if (CTX_OVERRIDES.get("seq_parallel", cfg.seq_parallel)
            and shape.kind == "train"):
        rules = {**rules, "seq": "model"}
    if CTX_OVERRIDES.get("moe_token_parallel",
                         cfg.moe_token_parallel) and shape.kind == "train":
        rules = {**rules, "expert": None, "expert_mlp": None,
                 "moe_tokens": "model"}
    max_pos = max(shape.seq_len, 4096) + cfg.meta_tokens + 1
    model = build_model(cfg, max_pos=max_pos)

    deferred = bool(CTX_OVERRIDES.get("deferred_sync")
                    or cfg.deferred_grad_sync)
    t0 = time.time()
    try:
        import contextlib
        # the deferred (shard_map) path lowers without the ambient concrete
        # mesh: its shardings carry the mesh, and an ambient (Auto,Auto)
        # mesh conflicts with the (Manual,Auto) abstract mesh inside
        cm = contextlib.nullcontext() if (deferred and shape.kind ==
                                          "train") else mesh
        with cm:
            if shape.kind == "train":
                lowered, params_lp = build_train(model, shape, rules, mesh)
            elif shape.kind == "prefill":
                lowered, params_lp = build_prefill(model, shape, rules, mesh)
            else:
                lowered, params_lp = build_decode(model, shape, rules, mesh)
            compiled = lowered.compile()
    except Exception as e:  # noqa: BLE001 — recorded as cell failure
        rec.update(status="failed", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-3000:])
        _write(out_path, rec)
        return rec
    rec["compile_seconds"] = round(time.time() - t0, 1)

    ma = compiled.memory_analysis()
    rec["memory_per_device"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "peak_estimate_bytes": int(ma.argument_size_in_bytes
                                   + ma.output_size_in_bytes
                                   + ma.temp_size_in_bytes
                                   - ma.alias_size_in_bytes),
    }
    xla_cost = compiled.cost_analysis()
    rec["xla_cost_once"] = {
        "flops": float(xla_cost.get("flops", -1)),
        "bytes_accessed": float(xla_cost.get("bytes accessed", -1)),
    }

    t1 = time.time()
    cost = analyze_hlo_text(compiled.as_text(), n_partitions=n_dev)
    rec["analyze_seconds"] = round(time.time() - t1, 1)
    rec["cost_per_device"] = cost
    rec["roofline"] = roofline_terms(cost, n_devices=n_dev)

    # analytic useful-work floor
    active_frac = (cfg.top_k / cfg.n_experts) if cfg.n_experts else None
    params = count_params(params_lp, active_moe=active_frac)
    tokens = (shape.global_batch * shape.seq_len
              if shape.kind != "decode" else shape.global_batch)
    mf = model_flops(shape.kind, params["active"], tokens=tokens)
    rec["params"] = params
    rec["model_flops_global"] = mf
    hlo_global = cost["flops"] * n_dev
    rec["model_vs_hlo"] = mf / hlo_global if hlo_global else None
    rec["status"] = "ok"
    _write(out_path, rec)
    return rec


def _write(path: str, rec: dict):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=float)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None,
                    help="one arch id (default: all LM archs)")
    ap.add_argument("--shape", default=None,
                    help="one shape name (default: all four)")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    metavar="K=V", help="Ctx override, e.g. wkv_chunk=16")
    args = ap.parse_args()

    for kv in args.set:
        k, v = kv.split("=", 1)
        CTX_OVERRIDES[k] = (int(v) if v.lstrip("-").isdigit()
                            else v == "True" if v in ("True", "False")
                            else float(v) if "." in v else v)

    archs = [args.arch] if args.arch else LM_ARCHS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp, args.out,
                               skip_existing=args.skip_existing)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f" compute={r['compute_s']:.3e}s "
                             f"mem={r['memory_s']:.3e}s "
                             f"coll={r['collective_s']:.3e}s "
                             f"dom={r['dominant']}"
                             f" compile={rec['compile_seconds']}s")
                elif status == "failed":
                    failures += 1
                    extra = " " + rec["error"][:160]
                print(f"[{status:7s}] {arch} × {shape} × "
                      f"{'multi' if mp else 'single'}{extra}", flush=True)
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")


if __name__ == "__main__":
    main()
