"""Serving driver: a thin CLI over :class:`repro.serving.ServingEngine`.

``python -m repro.launch.serve --arch llama3.2-1b --smoke``

Runs the paper's quantized pipeline as an actual serving stack: a seeded
request stream (Poisson / bursty / trace arrivals) flows through the
admission queue into the continuous batcher; per-tenant
:class:`~repro.protect.ProtectionPlan` s decide which ops are verified,
with what scheme/policy/threshold; telemetry reports per-tenant SLO
percentiles next to the ABFT fault counters.  Examples::

    --plan "*:policy=log"                        # default protection
    --plan "*:policy=recompute,kv_cache:on"      # retry faults, int8 cache
    --tenant "premium:2=*:policy=recompute,kv_cache:on" \
    --tenant "batch=*:policy=log,embedding_bag:off"
    --inject-step 7 --inject-victim attn.wq      # transient flip at step 7
    --inject-step 7 --inject-persistent          # ... left in place

``--inject-step`` restores the clean weight right after the faulty step
(unless ``--inject-persistent``), so recompute-policy retries measure one
transient upset rather than a persistent corruption.
"""
from __future__ import annotations

# ruff: noqa: E402
import argparse
import dataclasses
import json
import logging
import os
import sys


def parse_tenant(arg: str):
    """``NAME[:WEIGHT]=PLAN`` -> (name, weight, plan_text)."""
    head, _, plan_text = arg.partition("=")
    if not plan_text:
        raise ValueError(f"--tenant {arg!r}: expected NAME[:WEIGHT]=PLAN")
    name, _, w = head.partition(":")
    if not name:
        raise ValueError(f"--tenant {arg!r}: empty tenant name")
    try:
        weight = float(w) if w else 1.0
    except ValueError:
        raise ValueError(f"--tenant {arg!r}: bad weight {w!r} "
                         f"(expected NAME[:WEIGHT]=PLAN)") from None
    return name, weight, plan_text


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.serve",
        description="Continuous-batching protected serving over a "
                    "synthetic request stream.")
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--slots", "--batch", dest="slots", type=int, default=4,
                    help="decode-batch slots (continuous batching width)")
    ap.add_argument("--prompt-len", type=int, default=64,
                    help="prompt bucket (prompts pad up to this)")
    ap.add_argument("--decode-tokens", type=int, default=32,
                    help="max new tokens per request")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--arrival", default="poisson",
                    choices=["poisson", "bursty", "trace"])
    ap.add_argument("--rate", type=float, default=100.0,
                    help="arrival rate (requests/s of virtual time)")
    ap.add_argument("--trace", default=None,
                    help="JSON file with arrival offsets (--arrival trace)")
    ap.add_argument("--queue-depth", type=int, default=0,
                    help="admission queue bound (0 = unbounded)")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced model + small stream")
    ap.add_argument("--plan", default=None,
                    help="single-tenant protection plan: compact string "
                         "('*:policy=recompute,embedding_bag:off') or "
                         "@path.json holding a plan dict")
    ap.add_argument("--tenant", action="append", default=[],
                    metavar="NAME[:WEIGHT]=PLAN",
                    help="add a traffic class with its own plan "
                         "(repeatable; replaces --plan; PLAN accepts "
                         "@path.json too)")
    ap.add_argument("--paged-kv", type=int, default=0, metavar="PAGE_SIZE",
                    help="serve from the paged, prefix-shared, "
                         "per-page-checksummed KV cache with this page "
                         "size (pair with a kv_cache_paged:on plan)")
    ap.add_argument("--kv-pages", type=int, default=256,
                    help="page-pool size per lane (--paged-kv)")
    ap.add_argument("--no-abft", action="store_true",
                    help="unprotected baseline (= --plan '*:off')")
    ap.add_argument("--inject-step", type=int, action="append",
                    default=None, metavar="STEP",
                    help="flip a weight bit before this engine step "
                         "(repeatable — a burst of transient faults)")
    ap.add_argument("--inject-victim", default=None,
                    help="victim leaf-path pattern (e.g. 'attn.wq', "
                         "'mlp.down'); default: largest int8 leaf")
    ap.add_argument("--inject-persistent", action="store_true",
                    help="leave the flipped bit in place (default: "
                         "restore the clean weight after the step)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None,
                    help="write the full telemetry timeline here")
    ap.add_argument("--obs-dir", default=None,
                    help="export observability artifacts (fault-event "
                         "JSONL, Chrome trace, Prometheus text) here")
    ap.add_argument("--obs-flush-every", type=int, default=0,
                    metavar="N",
                    help="crash-durable obs: append each event to the "
                         "JSONL as it happens and rewrite the metrics/"
                         "trace snapshots every N events (needs "
                         "--obs-dir)")
    ap.add_argument("--monitor", action="store_true",
                    help="attach the live detection-health monitor: "
                         "windowed alert rules over the obs bus drive "
                         "healthy/degraded/quarantined tenant states "
                         "with real engine responses (admission "
                         "quarantine, plan escalation, paged-KV scrub)")
    ap.add_argument("--adaptive", action="store_true",
                    help="close the threshold loop: ops whose plan says "
                         "threshold=adaptive get a per-(op, tenant) "
                         "FP-budget controller over rel_bound, fed by "
                         "the monitor's Wilson flag-rate estimates "
                         "(implies --monitor)")
    ap.add_argument("--fp-budget", type=float, default=0.01,
                    help="--adaptive: tolerated false-positive rate the "
                         "controllers hold")
    ap.add_argument("--calibrate-from", default=None, metavar="ARTIFACT",
                    help="--adaptive: seed initial bounds from a "
                         "committed --grid thresholds sweep artifact "
                         "instead of the ops' static defaults")
    ap.add_argument("--device-count", type=int, default=0)
    args = ap.parse_args(argv)

    if args.device_count:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.device_count}")

    from repro.configs.registry import get_arch
    from repro.protect import (ProtectionPlan, default_plan,
                               unprotected_plan)
    from repro.serving import (FaultInjection, ServingEngine, TenantSpec,
                               chat_stream, dlrm_stream, tenant_weights)

    logging.basicConfig(level=logging.INFO, format="%(message)s")
    log = logging.getLogger("repro.serve")

    if args.no_abft and (args.plan is not None or args.tenant):
        ap.error("--no-abft conflicts with --plan/--tenant; start the "
                 "plan from '*:off' instead")
    if args.arrival == "trace" and not args.trace:
        ap.error("--arrival trace needs --trace FILE")

    if args.tenant:
        tenants = []
        for t in args.tenant:
            try:
                name, weight, plan_text = parse_tenant(t)
                plan = default_plan().with_rules(
                    *ProtectionPlan.from_any(plan_text).rules)
            except ValueError as e:
                ap.error(str(e))
            tenants.append(TenantSpec(
                name, dataclasses.replace(plan, name=name), weight))
    else:
        if args.plan is not None:
            plan = default_plan().with_rules(
                *ProtectionPlan.from_any(args.plan).rules)
        elif args.no_abft:
            plan = unprotected_plan()
        else:
            plan = default_plan()
        tenants = [TenantSpec("default", plan)]
    for t in tenants:
        log.info("tenant %-10s (weight %g): %s", t.name, t.weight,
                 t.resolved_plan().describe())

    cfg = get_arch(args.arch)
    dlrm_extras = None
    if args.smoke:
        from repro.configs import reduce_cfg
        cfg = reduce_cfg(cfg)
        args.requests = min(args.requests, 12)
        args.prompt_len = min(args.prompt_len, 32)
        args.decode_tokens = min(args.decode_tokens, 8)
        if cfg.family == "dlrm":
            from repro.configs.dlrm import EXTRAS
            dlrm_extras = dataclasses.replace(
                EXTRAS, table_rows=512, n_tables=4, emb_dim=32,
                bottom_mlp=(64, 32), top_mlp=(64, 32, 1))

    paging = None
    if args.paged_kv:
        from repro.paging import PagingConfig
        paging = PagingConfig(page_size=args.paged_kv,
                              n_pages=args.kv_pages)
    engine = ServingEngine(cfg, tenants, n_slots=args.slots,
                           max_prompt=args.prompt_len,
                           max_new_tokens=args.decode_tokens,
                           queue_depth=args.queue_depth, seed=args.seed,
                           dlrm_extras=dlrm_extras, paging=paging)

    weights = tenant_weights(tenants)
    trace = None
    if args.trace:
        with open(args.trace) as f:
            trace = json.load(f)
    if cfg.family == "dlrm":
        ex = engine.dlrm_extras
        stream = dlrm_stream(
            args.requests, tenants=weights, rate_rps=args.rate,
            arrival=args.arrival, seed=args.seed,
            lookup_batch=min(ex.batch, 10), table_rows=ex.table_rows,
            n_tables=ex.n_tables, trace=trace)
    else:
        stream = chat_stream(
            args.requests, tenants=weights, rate_rps=args.rate,
            arrival=args.arrival, seed=args.seed,
            mean_prompt=max(args.prompt_len // 2, 4),
            max_prompt=args.prompt_len,
            mean_output=max(args.decode_tokens // 2, 1),
            max_output=args.decode_tokens, trace=trace)

    inject = None
    if args.inject_step:
        inject = [FaultInjection(step=s, victim=args.inject_victim,
                                 persistent=args.inject_persistent,
                                 seed=args.seed + 17 * i)
                  for i, s in enumerate(sorted(args.inject_step))
                  if s >= 0]

    obs = None
    if args.obs_dir or args.monitor or args.adaptive:
        from repro.obs import Observability
        obs = Observability.create()
        if args.obs_dir and args.obs_flush_every > 0:
            obs.open_incremental(args.obs_dir,
                                 every=args.obs_flush_every)
    monitor = None
    if args.monitor or args.adaptive:
        from repro.obs import Monitor
        monitor = Monitor()
    adapt = None
    if args.adaptive:
        from repro.adapt import (AdaptiveThresholds, ControllerConfig,
                                 calibrate_from_sweep)
        adapt = AdaptiveThresholds(
            config=ControllerConfig(fp_budget=args.fp_budget),
            source="launch.serve")
        if args.calibrate_from:
            bound = calibrate_from_sweep(args.calibrate_from,
                                         fp_budget=args.fp_budget)
            for t in tenants:
                adapt.manage("embedding_bag", t.name, rel_bound=bound)
            log.info("adaptive: calibrated embedding_bag rel_bound=%.3g "
                     "from %s", bound, args.calibrate_from)

    log.info("serving %d %s requests (%s arrivals @ %g rps) on %d slots, "
             "%d lane(s)...", args.requests, cfg.family, args.arrival,
             args.rate, args.slots, len(engine.lanes))
    telemetry = engine.run(stream, inject=inject, obs=obs,
                           monitor=monitor, adapt=adapt)
    s = telemetry.summary()

    log.info("")
    log.info("%d requests / %d steps in %.3fs of traffic — "
             "%.1f tok/s, queue depth max %d, decode occupancy %.2f",
             s["requests"], s["steps"], s["span_s"],
             s["throughput_tok_s"], s["queue_depth_max"],
             s["decode_occupancy_mean"])
    for tname, ts in s["per_tenant"].items():
        log.info("  %-10s n=%-4d done=%-4d abort=%-3d "
                 "TTFT p50/p95/p99 = %.1f/%.1f/%.1f ms   "
                 "tok p99 = %.2f ms", tname, ts["requests"],
                 ts["completed"], ts["aborted"],
                 ts["ttft_ms"]["p50"], ts["ttft_ms"]["p95"],
                 ts["ttft_ms"]["p99"], ts["per_token_ms"]["p99"])
    f = s["faults"]
    nz = {k: v for k, v in f["counters"].items() if v}
    log.info("fault counters: %s", nz or "all zero")
    if monitor is not None:
        ms = s.get("monitor") or monitor.summary()
        log.info("monitor: %d evaluation tick(s), %d alert(s) fired, "
                 "health %s", ms["ticks"], ms["alerts_fired"],
                 ms["health"] or "{}")
        for a in ms["alerts"]:
            log.info("  alert %-16s [%s] %s: %s=%.4g %s %.4g at t=%.3fs%s",
                     a["rule"], a["severity"], a["scope"], a["metric"],
                     a["value"], ">=", a["threshold"], a["t_s"],
                     "" if a["resolved_t_s"] is None
                     else f" (resolved t={a['resolved_t_s']:.3f}s)")
        for tr in ms["transitions"]:
            log.info("  health %-16s %s -> %s at tick %d (%s)",
                     tr["scope"], tr["old"], tr["new"], tr["tick"],
                     tr["reason"] or "recovered")
    if adapt is not None:
        for c in s.get("thresholds") or adapt.summary():
            log.info("threshold %s/%s: rel_bound=%.3g after %d move(s), "
                     "%sconverged%s", c["op"], c["tenant"],
                     c["rel_bound"], c["adjustments"],
                     "" if c["converged"] else "NOT ",
                     "" if c["ticks_to_converge"] is None
                     else f" at tick {c['ticks_to_converge']}")
    for lane_key, st in engine.paging_stats().items():
        log.info("paging %s: resident=%d/%d high-water=%d "
                 "prefix-hit=%.2f evictions=%d rebuilds=%d", lane_key,
                 st["pages_resident"],
                 st["pages_resident"] + st["pages_free"],
                 st["pages_high_water"], st["prefix_hit_rate"],
                 st["page_evictions"], st["page_rebuilds"])
    for inj in f["injections"]:
        if inj["detected"]:
            log.info(">>> injected %s at step %d: DETECTED after %d "
                     "step(s) (%.2f ms)", inj["victim"], inj["step"],
                     inj["latency_steps"], 1e3 * inj["latency_s"])
        else:
            log.info(">>> injected %s at step %d: NOT detected "
                     "(masked or escaped)", inj["victim"], inj["step"])
        if inj.get("attributed_rids"):
            log.info("    touched request(s): %s",
                     " ".join(str(r) for r in inj["attributed_rids"]))
    if f.get("suspect_requests"):
        log.info("suspect requests (resident during a flagged step): %d",
                 f["suspect_requests"])

    if args.obs_dir:
        for kind, path in sorted(obs.write(args.obs_dir).items()):
            log.info("obs %s: %s", kind, path)
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as fp:
            json.dump(telemetry.to_dict(), fp, indent=2)
        log.info("telemetry written to %s", args.json)
    return 0


if __name__ == "__main__":
    sys.exit(main())
