"""Serving driver: int8+ABFT batched inference.

``python -m repro.launch.serve --arch llama3.2-1b --smoke``

Runs the paper's quantized pipeline end to end on the declarative
protection API: build a :class:`repro.protect.ProtectionPlan` from the CLI
(``--plan``), wrap the model's prefill/decode with
:func:`repro.protect.protect`, prefill a batch of requests, decode N tokens
with the sharded KV cache, and report per-phase latency + fault counters.
Which ops are verified, with what scheme/policy/threshold, is purely a plan
choice — e.g.::

    --plan "*:policy=log"                        # default protection
    --plan "embedding_bag:off"                   # EB unprotected
    --plan "*:policy=recompute,kv_cache:on"      # retry faults, int8 cache
    --plan "qgemm:policy=correct"                # row+col checksum repair
"""
from __future__ import annotations

# ruff: noqa: E402
import argparse
import functools
import logging
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--decode-tokens", type=int, default=32)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--plan", default=None,
                    help="protection plan, e.g. "
                         "'*:policy=recompute,embedding_bag:off' "
                         "(default: log-policy protection of qgemm + EB)")
    ap.add_argument("--no-abft", action="store_true",
                    help="unprotected baseline (= --plan '*:off')")
    ap.add_argument("--inject-step", type=int, default=-1,
                    help="flip a bit in a weight before this decode step "
                         "(fault-injection demo)")
    ap.add_argument("--device-count", type=int, default=0)
    args = ap.parse_args()

    if args.device_count:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.device_count}")

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.registry import get_arch
    from repro.core.inject import flip_bit_in_leaf
    from repro.models.base import build_model
    from repro.protect import (ProtectionPlan, default_plan, protect,
                               unprotected_plan)

    logging.basicConfig(level=logging.INFO, format="%(message)s")
    log = logging.getLogger("repro.serve")

    if args.plan is not None and args.no_abft:
        ap.error("--no-abft and --plan conflict; start the plan from "
                 "'*:off' instead (e.g. --plan '*:off,kv_cache:on')")
    if args.plan is not None:
        plan = default_plan().with_rules(
            *ProtectionPlan.parse(args.plan).rules)
    elif args.no_abft:
        plan = unprotected_plan()
    else:
        plan = default_plan()
    log.info("protection plan: %s", plan.describe())

    cfg = get_arch(args.arch)
    if args.smoke:
        from repro.configs import reduce_cfg
        cfg = reduce_cfg(cfg)

    cache_len = args.prompt_len + args.decode_tokens + cfg.meta_tokens + 8
    model = build_model(cfg, max_pos=cache_len + 8)

    params = jax.jit(lambda k: model.init(k, quant=True))(jax.random.key(0))
    from repro.sharding import values_of
    params = values_of(params)

    # the protected apply functions: plan-resolved Ctx, (out, report) calls
    prefill_p = protect(model.prefill, plan, compute_dtype=jnp.bfloat16)
    decode_p = protect(model.decode, plan, compute_dtype=jnp.bfloat16)

    @jax.jit
    def prefill(params, batch):
        (logits, cache), rep = prefill_p(params, batch, cache_len=cache_len)
        tok = jnp.argmax(logits[..., :cfg.vocab], axis=-1).astype(jnp.int32)
        return tok, cache, rep.as_metrics()

    @functools.partial(jax.jit, donate_argnums=(1,))
    def decode(params, cache, tokens, pos):
        (logits, new_cache), rep = decode_p(params, cache, tokens, pos)
        tok = jnp.argmax(logits[..., :cfg.vocab], axis=-1).astype(jnp.int32)
        return tok, new_cache, rep.as_metrics()

    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)),
        jnp.int32)}
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.n_patches, cfg.patch_dim)),
            jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.enc_seq, cfg.d_model)),
            jnp.float32)

    t0 = time.time()
    tok, cache, metrics = jax.block_until_ready(prefill(params, batch))
    t_prefill = time.time() - t0
    log.info("prefill: %.3fs  batch=%d len=%d  gemm_checks=%d errs=%d",
             t_prefill, args.batch, args.prompt_len,
             int(metrics.get("abft/gemm_checks", 0)),
             int(metrics.get("abft/gemm_errors", 0)))

    pos = jnp.full((args.batch,),
                   args.prompt_len + cfg.meta_tokens, jnp.int32)
    if cfg.family == "vlm":
        pos = pos + cfg.n_patches
    outputs = [np.asarray(tok)]
    faults = retries = 0
    t0 = time.time()
    for step in range(args.decode_tokens):
        if step == args.inject_step:
            params, where = flip_bit_in_leaf(params, jax.random.key(step))
            log.info(">>> injected bit flip into %s", where)
        tok, cache, metrics = decode(params, cache, tok, pos)
        errs = int(metrics.get("abft/gemm_errors", 0)) \
            + int(metrics.get("abft/eb_errors", 0)) \
            + int(metrics.get("abft/kv_cache_errors", 0))
        retries += int(metrics.get("abft/retries", 0))
        if errs:
            faults += 1
            log.info("step %d: ABFT detected %d corrupted op(s) — request "
                     "flagged (plan policy applied)", step, errs)
        outputs.append(np.asarray(tok))
        pos = pos + 1
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    log.info("decode: %d tokens in %.3fs (%.1f tok/s/seq)  faulty_steps=%d"
             "  retries=%d", args.decode_tokens, t_decode,
             args.decode_tokens / max(t_decode, 1e-9), faults, retries)
    log.info("sample output ids: %s", np.stack(outputs, 1)[0][:16])


if __name__ == "__main__":
    main()
