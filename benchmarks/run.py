"""Benchmark suite entry point: ``python -m benchmarks.run [--quick]``.

One benchmark per paper table/figure:
  * gemm_overhead   — Fig. 5  (ABFT GEMM overhead, 28 DLRM shapes)
  * eb_overhead     — Table I / Fig. 6 (ABFT EmbeddingBag overhead)
  * gemm_detection  — Table II (simulated-error detection accuracy, GEMM)
  * eb_detection    — Table III (simulated-error detection accuracy, EB)
  * roofline_table  — §Roofline (from dry-run artifacts, if present)
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="subset of shapes / smaller tables")
    ap.add_argument("--only", default=None,
                    help="run a single benchmark by name")
    args = ap.parse_args()

    from benchmarks import (eb_detection, eb_overhead, gemm_detection,
                            gemm_overhead, roofline_table)

    benches = {
        "gemm_overhead": gemm_overhead.main,
        "eb_overhead": eb_overhead.main,
        "gemm_detection": gemm_detection.main,
        "eb_detection": eb_detection.main,
        "roofline_table": roofline_table.main,
    }
    if args.only:
        benches = {args.only: benches[args.only]}
    for name, fn in benches.items():
        print(f"\n=== {name} ===", flush=True)
        t0 = time.time()
        try:
            fn(quick=args.quick)
        except FileNotFoundError as e:
            print(f"({name} skipped: {e})")
        print(f"=== {name} done in {time.time()-t0:.1f}s ===", flush=True)


if __name__ == "__main__":
    main()
