"""Fig. 5 reproduction: ABFT overhead for low-precision GEMM, 28 shapes.

Three variants per (m, n, k):
  * ``unprotected``  — plain int8 GEMM (paper baseline)
  * ``abft``         — packed-checksum GEMM + fused verify, weight encoding
                       amortized (the paper's serving configuration)
  * ``abft+encode``  — encoding on the critical path (un-amortized bound)

Reports
  * measured wall-clock overhead (CPU backend — indicative only),
  * **modelled TPU overhead**: extra flops and extra HBM bytes of the ABFT
    program over the unprotected program, from the trip-aware HLO cost
    model (launch.costs) on the compiled artifacts — the container-honest
    reproduction of Fig. 5's claim,
  * the paper's analytic overhead ``1/(2m) + 1/n + 1/(2k)`` (§IV-A1),
  * the **fused Pallas** implementation: raw interpret-mode wall-clock
    (kernel-body emulation on CPU — NOT comparable to the XLA wall
    columns) plus its modelled TPU traffic.  The fused kernel's HBM
    traffic is exactly the packed GEMM's (A + B' in, C + err out): the
    verify runs on tiles still in VMEM, so unlike ``abft`` — whose
    Eq. (3b) reduction re-reads the O(mn) product — the bytes column
    collapses to the checksum lanes + the err vector.  The twin program
    priced by launch.costs is the packed dot; the in-VMEM verify's flops
    (~3·m·n') are added analytically.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import GEMM_SHAPES, Csv, modelled_cost, time_fn
import repro.core as core
from repro.core import LANE
from repro.kernels.abft_qgemm import abft_qgemm_pallas


@functools.partial(jax.jit, static_argnums=())
def _plain(a, b):
    return jax.lax.dot_general(a.astype(jnp.int32), b.astype(jnp.int32),
                               (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.int32)


@jax.jit
def _abft_packed(a, b_packed):
    return core.abft_qgemm_packed(a, b_packed)


@jax.jit
def _abft_encode(a, b):
    return core.abft_qgemm(a, b)


def _abft_pallas(a, b_packed):
    # the fused kernel, interpret mode (already jitted with static args)
    return abft_qgemm_pallas(a, b_packed, interpret=True)


@jax.jit
def _packed_dot(a, b_packed):
    """The fused kernel's HBM traffic twin: one dot over the full packed
    operand (reads A + B', writes C including the checksum lanes)."""
    return jax.lax.dot_general(a.astype(jnp.int32),
                               b_packed.astype(jnp.int32),
                               (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.int32)


def run(csv: Csv, *, quick: bool = False):
    shapes = GEMM_SHAPES[::4] if quick else GEMM_SHAPES
    key = jax.random.key(0)
    for m, n, k in shapes:
        ka, kb = jax.random.split(jax.random.fold_in(key, m * n * k))
        a = jax.random.randint(ka, (m, k), 0, 256, jnp.uint8)
        b = jax.random.randint(kb, (k, n), -127, 128, jnp.int8)
        b_packed = jax.jit(core.pack_encoded_b)(b)
        t0 = time_fn(_plain, a, b)
        t1 = time_fn(_abft_packed, a, b_packed)
        t2 = time_fn(_abft_encode, a, b)
        t3 = time_fn(_abft_pallas, a, b_packed, iters=3, min_time_s=0.05)
        c0 = modelled_cost(_plain, a, b)
        c1 = modelled_cost(_abft_packed, a, b_packed)
        dflops = c1["flops"] / max(c0["flops"], 1) - 1
        dbytes = c1["bytes"] / max(c0["bytes"], 1) - 1
        # fused kernel: twin dot traffic + err vector out; verify flops
        # (mod + rowsum add + compare per C element) happen in VMEM
        ct = modelled_cost(_packed_dot, a, b_packed)
        p_flops = ct["flops"] + 3 * m * (n + LANE)
        p_bytes = ct["bytes"] + 4 * m
        pflops = p_flops / max(c0["flops"], 1) - 1
        pbytes = p_bytes / max(c0["bytes"], 1) - 1
        analytic = 1 / (2 * m) + 1 / n + 1 / (2 * k)
        csv.row("gemm_overhead", f"{m}x{n}x{k}",
                f"{t0*1e6:.1f}", f"{t1*1e6:.1f}", f"{t2*1e6:.1f}",
                f"{(t1/t0-1)*100:.1f}%", f"{(t2/t0-1)*100:.1f}%",
                f"{dflops*100:.2f}%", f"{dbytes*100:.2f}%",
                f"{analytic*100:.2f}%",
                f"{t3*1e6:.1f}",
                f"{pflops*100:.2f}%", f"{pbytes*100:.2f}%")


def main(quick: bool = False):
    csv = Csv(["bench", "shape_mxnxk", "plain_us", "abft_us",
               "abft_encode_us", "overhead_amortized", "overhead_encode",
               "tpu_flops_overhead", "tpu_bytes_overhead",
               "analytic_overhead", "pallas_interp_us",
               "pallas_tpu_flops_overhead", "pallas_tpu_bytes_overhead"])
    run(csv, quick=quick)
    return csv


if __name__ == "__main__":
    main()
