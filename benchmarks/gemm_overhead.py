"""Fig. 5 reproduction: ABFT overhead for low-precision GEMM, 28 shapes.

Three variants per (m, n, k):
  * ``unprotected``  — plain int8 GEMM (paper baseline)
  * ``abft``         — packed-checksum GEMM + fused verify, weight encoding
                       amortized (the paper's serving configuration)
  * ``abft+encode``  — encoding on the critical path (un-amortized bound)

Reports
  * measured wall-clock overhead (CPU backend — indicative only),
  * **modelled TPU overhead**: extra flops and extra HBM bytes of the ABFT
    program over the unprotected program, from the trip-aware HLO cost
    model (launch.costs) on the compiled artifacts — the container-honest
    reproduction of Fig. 5's claim,
  * the paper's analytic overhead ``1/(2m) + 1/n + 1/(2k)`` (§IV-A1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import GEMM_SHAPES, Csv, modelled_cost, time_fn
import repro.core as core


@functools.partial(jax.jit, static_argnums=())
def _plain(a, b):
    return jax.lax.dot_general(a.astype(jnp.int32), b.astype(jnp.int32),
                               (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.int32)


@jax.jit
def _abft_packed(a, b_packed):
    return core.abft_qgemm_packed(a, b_packed)


@jax.jit
def _abft_encode(a, b):
    return core.abft_qgemm(a, b)


def run(csv: Csv, *, quick: bool = False):
    shapes = GEMM_SHAPES[::4] if quick else GEMM_SHAPES
    key = jax.random.key(0)
    for m, n, k in shapes:
        ka, kb = jax.random.split(jax.random.fold_in(key, m * n * k))
        a = jax.random.randint(ka, (m, k), 0, 256, jnp.uint8)
        b = jax.random.randint(kb, (k, n), -127, 128, jnp.int8)
        b_packed = jax.jit(core.pack_encoded_b)(b)
        t0 = time_fn(_plain, a, b)
        t1 = time_fn(_abft_packed, a, b_packed)
        t2 = time_fn(_abft_encode, a, b)
        c0 = modelled_cost(_plain, a, b)
        c1 = modelled_cost(_abft_packed, a, b_packed)
        dflops = c1["flops"] / max(c0["flops"], 1) - 1
        dbytes = c1["bytes"] / max(c0["bytes"], 1) - 1
        analytic = 1 / (2 * m) + 1 / n + 1 / (2 * k)
        csv.row("gemm_overhead", f"{m}x{n}x{k}",
                f"{t0*1e6:.1f}", f"{t1*1e6:.1f}", f"{t2*1e6:.1f}",
                f"{(t1/t0-1)*100:.1f}%", f"{(t2/t0-1)*100:.1f}%",
                f"{dflops*100:.2f}%", f"{dbytes*100:.2f}%",
                f"{analytic*100:.2f}%")


def main(quick: bool = False):
    csv = Csv(["bench", "shape_mxnxk", "plain_us", "abft_us",
               "abft_encode_us", "overhead_amortized", "overhead_encode",
               "tpu_flops_overhead", "tpu_bytes_overhead",
               "analytic_overhead"])
    run(csv, quick=quick)
    return csv


if __name__ == "__main__":
    main()
