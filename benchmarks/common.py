"""Shared benchmark utilities: timing, CSV emit, DLRM shape set."""
from __future__ import annotations

from typing import Callable, List

import jax

# The paper's 28 Fig. 5 DLRM GEMM shapes — canonical definition moved to
# the campaign subsystem (repro.campaign.spec), re-exported here for the
# overhead benchmarks.
from repro.campaign.spec import DLRM_GEMM_SHAPES as GEMM_SHAPES  # noqa: E402,F401

assert len(GEMM_SHAPES) == 28


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 10,
            min_time_s: float = 0.2) -> float:
    """Median wall seconds per call of a jitted fn (blocks on outputs).

    Delegates to the campaign subsystem's helper so benchmarks/ tables and
    campaign overhead cells share one timing methodology.
    """
    from repro.campaign.timing import median_time

    return median_time(fn, *args, warmup=warmup, iters=iters,
                       min_time_s=min_time_s)


def modelled_cost(fn: Callable, *args) -> dict:
    """Trip-aware (flops, bytes) of a jitted fn from its compiled HLO.

    The wall-clock columns measure the CPU backend; these columns measure
    the *program* (what a TPU deployment executes), via launch.costs.
    """
    from repro.launch.costs import analyze_hlo_text

    compiled = jax.jit(fn).lower(*args).compile()
    return analyze_hlo_text(compiled.as_text())


class Csv:
    def __init__(self, header: List[str]):
        self.header = header
        self.rows: List[list] = []
        print(",".join(header), flush=True)

    def row(self, *vals):
        self.rows.append(list(vals))
        print(",".join(str(v) for v in vals), flush=True)
