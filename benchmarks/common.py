"""Shared benchmark utilities: timing, CSV emit, DLRM shape set."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax
import numpy as np

# ---------------------------------------------------------------------------
# The paper's Fig. 5 evaluates 28 DLRM GEMM shapes (m, n, k) — "peculiar
# matrix sizes": small m (batch), large n/k (layer widths).  The figure axis
# lists shapes from production DLRM MLP stacks; we reconstruct the set from
# the DLRM bottom (13-512-256-128) and top (479-1024-1024-512-256-1) MLPs,
# the paper's quoted (1, 800, 3200) point, and FBGEMM benchmark shapes.
# ---------------------------------------------------------------------------
GEMM_SHAPES: List[Tuple[int, int, int]] = [
    # bottom MLP, batch 1..256
    (1, 512, 13), (1, 256, 512), (1, 128, 256),
    (20, 512, 13), (20, 256, 512), (20, 128, 256),
    (100, 512, 13), (100, 256, 512), (100, 128, 256),
    (256, 512, 13), (256, 256, 512), (256, 128, 256),
    # top MLP, batch 1..256
    (1, 1024, 479), (1, 1024, 1024), (1, 512, 1024), (1, 256, 512),
    (20, 1024, 479), (20, 1024, 1024), (20, 512, 1024),
    (100, 1024, 479), (100, 1024, 1024), (100, 512, 1024),
    (256, 1024, 479), (256, 1024, 1024),
    # wide serving projections (paper's fast case (1, 800, 3200) included)
    (1, 800, 3200), (10, 800, 3200), (64, 800, 3200), (100, 800, 3200),
]
assert len(GEMM_SHAPES) == 28


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 10,
            min_time_s: float = 0.2) -> float:
    """Median wall seconds per call of a jitted fn (blocks on outputs)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    total = 0.0
    while total < min_time_s or len(times) < iters:
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        dt = time.perf_counter() - t0
        times.append(dt)
        total += dt
        if len(times) >= 100:
            break
    return float(np.median(times))


def modelled_cost(fn: Callable, *args) -> dict:
    """Trip-aware (flops, bytes) of a jitted fn from its compiled HLO.

    The wall-clock columns measure the CPU backend; these columns measure
    the *program* (what a TPU deployment executes), via launch.costs.
    """
    from repro.launch.costs import analyze_hlo_text

    compiled = jax.jit(fn).lower(*args).compile()
    return analyze_hlo_text(compiled.as_text())


class Csv:
    def __init__(self, header: List[str]):
        self.header = header
        self.rows: List[list] = []
        print(",".join(header), flush=True)

    def row(self, *vals):
        self.rows.append(list(vals))
        print(",".join(str(v) for v in vals), flush=True)
