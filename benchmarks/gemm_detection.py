"""Table II reproduction: detection accuracy with simulated errors in GEMM.

Thin wrapper over the resilience-campaign engine (repro.campaign): one
spec sweeps (gemm_packed × B bit flips) and (gemm_c × C bit flips) over
the 28 Fig. 5 shapes at 100 runs each, with per-cell clean runs counting
false positives.  All inject→run→count loops live in the engine.

Paper results: B-errors 2663/2800 (95.11%), C-errors 2800/2800 (100%),
false positives 0/2800.  Analytic bound for B (§IV-C1): ≥ 1-(3/256)^m.
"""
from __future__ import annotations

from benchmarks.common import GEMM_SHAPES, Csv
from repro.campaign import CampaignSpec, run_specs

RUNS_PER_SHAPE = 100


def build_spec(*, quick: bool = False, seed: int = 1000) -> CampaignSpec:
    shapes = tuple(GEMM_SHAPES[::4] if quick else GEMM_SHAPES)
    return CampaignSpec(
        name="table2-gemm",
        targets=("gemm_packed", "gemm_c"),
        fault_models=("bitflip",),
        bit_bands=("all",),
        shapes=shapes,
        dtypes=("int8", "int32"),
        samples=RUNS_PER_SHAPE,
        seed=seed)


def run(csv: Csv, *, quick: bool = False):
    spec = build_spec(quick=quick)
    results, _ = run_specs([spec])
    by_shape: dict = {}
    for r in results:
        by_shape.setdefault(r.plan.shape, {})[r.plan.target] = r.metrics

    tot_b = tot_c = tot_fp = n_runs = 0
    for shape, cells in by_shape.items():
        m, n, k = shape
        mb, mc = cells["gemm_packed"], cells["gemm_c"]
        det_b = mb.effective_detected
        det_c = mc.effective_detected
        fp = mb.false_positives + mc.false_positives
        tot_b += det_b
        tot_c += det_c
        tot_fp += fp
        n_runs += mb.samples
        csv.row("gemm_detect", f"{m}x{n}x{k}", det_b, det_c, fp,
                mb.samples, f"{(mb.analytic_bound or 0)*100:.2f}%")
    csv.row("gemm_detect_TOTAL", "all", tot_b, tot_c, tot_fp, n_runs,
            f"B:{tot_b/n_runs*100:.2f}% C:{tot_c/n_runs*100:.2f}% "
            f"FP:{tot_fp/(2*n_runs)*100:.2f}% "
            f"(paper: 95.11% / 100% / 0%)")
    return tot_b, tot_c, tot_fp, n_runs


def main(quick: bool = False):
    csv = Csv(["bench", "shape", "detected_B", "detected_C",
               "false_pos", "runs", "note"])
    run(csv, quick=quick)
    return csv


if __name__ == "__main__":
    main()
