"""Table II reproduction: detection accuracy with simulated errors in GEMM.

Paper campaign: for each of the 28 Fig. 5 shapes, 100 runs with a random
bit flip in B *after* its checksum was computed (amortized-encode serving
model — the flip is a memory error the checksum must catch), 100 runs with
a flip in the int32 intermediate C, and 100 error-free runs.
2800 samples per column, reproduced here with vmapped injection campaigns.

Paper results: B-errors 2663/2800 (95.11%), C-errors 2800/2800 (100%),
false positives 0/2800.  Analytic bound for B (§IV-C1): ≥ 1-(3/256)^m.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import GEMM_SHAPES, Csv
from repro.core import abft_gemm as ag
from repro.core.inject import random_bitflip

RUNS_PER_SHAPE = 100


@functools.partial(jax.jit, static_argnums=(1, 2, 3))
def _campaign_b(key, m, n, k):
    """Bit flip in B after encoding; count detected runs."""
    ka, kb, kf = jax.random.split(key, 3)
    a = jax.random.randint(ka, (m, k), 0, 256, jnp.uint8)
    b = jax.random.randint(kb, (k, n), -127, 128, jnp.int8)
    checksum = ag.encode_weight_checksum(b)        # encode the clean B

    def one(kk):
        b_bad = random_bitflip(kk, b)
        out = ag.abft_qgemm(a, b_bad, checksum=checksum)
        changed = jnp.any(b_bad != b)              # flip may be masked by
        detected = out.err_count > 0               # clip-range symmetry: no
        return detected | ~changed                 # corruption -> "detected"

    keys = jax.random.split(kf, RUNS_PER_SHAPE)
    return jnp.sum(jax.vmap(one)(keys).astype(jnp.int32))


@functools.partial(jax.jit, static_argnums=(1, 2, 3))
def _campaign_c(key, m, n, k):
    """Bit flip in the int32 C_temp before verification."""
    ka, kb, kf = jax.random.split(key, 3)
    a = jax.random.randint(ka, (m, k), 0, 256, jnp.uint8)
    b = jax.random.randint(kb, (k, n), -127, 128, jnp.int8)
    checksum = ag.encode_weight_checksum(b)
    b_packed = ag.pack_encoded_b(b, checksum)
    c_full = jax.lax.dot_general(
        a.astype(jnp.int32), b_packed.astype(jnp.int32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    c, check_col = c_full[:, :n], c_full[:, n]

    def one(kk):
        c_bad = random_bitflip(kk, c)
        _, err = ag.verify_rows(c_bad, check_col)
        return err > 0

    keys = jax.random.split(kf, RUNS_PER_SHAPE)
    return jnp.sum(jax.vmap(one)(keys).astype(jnp.int32))


@functools.partial(jax.jit, static_argnums=(1, 2, 3))
def _campaign_clean(key, m, n, k):
    """Error-free runs: count FALSE positives."""
    ka, kb = jax.random.split(key)
    a = jax.random.randint(ka, (m, k), 0, 256, jnp.uint8)
    b = jax.random.randint(kb, (k, n), -127, 128, jnp.int8)
    out = ag.abft_qgemm(a, b)
    return (out.err_count > 0).astype(jnp.int32) * RUNS_PER_SHAPE


def run(csv: Csv, *, quick: bool = False):
    shapes = GEMM_SHAPES[::4] if quick else GEMM_SHAPES
    tot_b = tot_c = tot_fp = 0
    n_runs = 0
    for i, (m, n, k) in enumerate(shapes):
        key = jax.random.key(1000 + i)
        det_b = int(_campaign_b(key, m, n, k))
        det_c = int(_campaign_c(key, m, n, k))
        fp = int(_campaign_clean(key, m, n, k))
        tot_b += det_b
        tot_c += det_c
        tot_fp += fp
        n_runs += RUNS_PER_SHAPE
        bound = 1.0 - (3.0 / 256.0) ** m
        csv.row("gemm_detect", f"{m}x{n}x{k}", det_b, det_c, fp,
                RUNS_PER_SHAPE, f"{bound*100:.2f}%")
    csv.row("gemm_detect_TOTAL", "all", tot_b, tot_c, tot_fp, n_runs,
            f"B:{tot_b/n_runs*100:.2f}% C:{tot_c/n_runs*100:.2f}% "
            f"FP:{tot_fp/n_runs*100:.2f}% "
            f"(paper: 95.11% / 100% / 0%)")
    return tot_b, tot_c, tot_fp, n_runs


def main(quick: bool = False):
    csv = Csv(["bench", "shape", "detected_B", "detected_C",
               "false_pos", "runs", "note"])
    run(csv, quick=quick)
    return csv


if __name__ == "__main__":
    main()
