"""Table I / Fig. 6 reproduction: ABFT overhead for low-precision
EmbeddingBag.

Paper settings: 4M-row int8 tables, d ∈ {32, 64, 128, 256}, average pooling
100, batch 10; regular and weighted sums.  (``--quick`` shrinks rows to keep
the CPU container responsive; full-table runs are the default for
``python -m benchmarks.eb_overhead``.)

Reports measured overhead vs the unprotected EB and the paper's analytic
``1/d + 1/(3m)`` (§V-C), plus the **fused Pallas** implementation: raw
interpret-mode wall-clock (kernel-body emulation on CPU — not comparable
to the XLA wall columns) and its modelled extra TPU bytes.  The fused
kernel folds ``Σ_j R_b[j]`` into the same pass that writes each bag, so
its verify traffic is only the gathered ``C_T`` rowsums plus the rsum
vector — the XLA path's re-read of R for the row reduction disappears.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv, modelled_cost, time_fn
import repro.core as core
from repro.kernels import ops as kops

ROWS = 4_000_000
DIMS = (32, 64, 128, 256)
POOL = 100
BATCH = 10


def make_table(key, rows: int, d: int):
    kt, ka, kb = jax.random.split(key, 3)
    table = jax.random.randint(kt, (rows, d), -128, 128, jnp.int8)
    alphas = jax.random.uniform(ka, (rows,), jnp.float32, 1e-3, 2e-3)
    betas = jax.random.uniform(kb, (rows,), jnp.float32, -1e-2, 1e-2)
    return table, alphas, betas


def run(csv: Csv, *, quick: bool = False):
    rows = 200_000 if quick else ROWS
    dims = DIMS[:2] if quick else DIMS
    rng = np.random.default_rng(0)
    plain = jax.jit(core.embedding_bag)
    abft = jax.jit(core.abft_embedding_bag)
    for d in dims:
        table, alphas, betas = make_table(jax.random.key(d), rows, d)
        rowsums = jax.jit(core.table_rowsums)(table)
        jax.block_until_ready(rowsums)
        for weighted in (False, True):
            # fresh indices per timing iteration would flush cache like the
            # paper; one fixed large random batch approximates it on CPU
            idx = jnp.asarray(
                rng.integers(0, rows, (BATCH, POOL)), jnp.int32)
            w = (jnp.asarray(rng.uniform(0.5, 1.5, (BATCH, POOL)),
                             jnp.float32) if weighted else None)
            t0 = time_fn(plain, table, alphas, betas, idx, w)
            t1 = time_fn(abft, table, alphas, betas, idx, rowsums, w)
            t2 = time_fn(
                lambda t, a, b, i, r, ww: kops.abft_embedding_bag(
                    t, a, b, i, r, ww, use_pallas=True, interpret=True),
                table, alphas, betas, idx, rowsums, w,
                iters=3, min_time_s=0.05)
            c0 = modelled_cost(core.embedding_bag, table, alphas, betas,
                               idx, w)
            c1 = modelled_cost(
                lambda t, a, b, i, r, ww: core.abft_embedding_bag(
                    t, a, b, i, r, ww),
                table, alphas, betas, idx, rowsums, w)
            dbytes = c1["bytes"] / max(c0["bytes"], 1) - 1
            # fused kernel's verify traffic: the gathered C_T rowsums (one
            # int32 per (bag, idx)) + the rsum vector it emits — the fused
            # row reduction reads R while the bag is still in VMEM
            p_extra = 4 * idx.size + 4 * BATCH
            pbytes = p_extra / max(c0["bytes"], 1)
            analytic = 1 / d + 1 / (3 * POOL)
            csv.row("eb_overhead", f"d={d}",
                    "weighted" if weighted else "regular",
                    f"{rows}", f"{t0*1e6:.1f}", f"{t1*1e6:.1f}",
                    f"{(t1/t0-1)*100:.1f}%", f"{dbytes*100:.2f}%",
                    f"{analytic*100:.2f}%",
                    f"{t2*1e6:.1f}", f"{pbytes*100:.2f}%")


def main(quick: bool = False):
    csv = Csv(["bench", "dim", "mode", "rows", "plain_us", "abft_us",
               "overhead", "tpu_bytes_overhead", "analytic_overhead",
               "pallas_interp_us", "pallas_tpu_bytes_overhead"])
    run(csv, quick=quick)
    return csv


if __name__ == "__main__":
    main()
