"""§Roofline table: render per-cell roofline terms from dry-run artifacts.

Reads ``artifacts/dryrun/*.json`` (produced by ``repro.launch.dryrun``) and
emits the per-(arch × shape × mesh) three-term table with the dominant
bottleneck, MODEL_FLOPS ratio, and fits-in-HBM flag.

Also emits (no dry-run artifacts needed) the **ABFT implementation
roofline**: per DLRM GEMM shape, the modelled v5e HBM traffic and roofline
terms of the unprotected GEMM, the fused Pallas kernel (verify in the
epilogue, on tiles still in VMEM), and the unfused XLA path (Eq. (3b)
re-reads the O(mn) product).  The ``verify_extra_bytes`` column is the
point of the fused kernel: the checksum lanes + err vector only, vs the
unfused path's full product re-read.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import GEMM_SHAPES, Csv
from repro.core import LANE
from repro.launch.roofline import roofline_terms

HBM_PER_CHIP = 16 * 1024 ** 3   # v5e: 16 GiB


def load_cells(art_dir: str = "artifacts/dryrun"):
    cells = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def run(csv: Csv, art_dir: str = "artifacts/dryrun"):
    cells = load_cells(art_dir)
    for c in cells:
        if c["status"] == "skipped":
            csv.row("roofline", c["arch"], c["shape"], c["mesh"], "SKIP",
                    "-", "-", "-", "-", "-",
                    c["skip_reason"].split(":")[0])
            continue
        if c["status"] != "ok":
            csv.row("roofline", c["arch"], c["shape"], c["mesh"], "FAIL",
                    "-", "-", "-", "-", "-", c.get("error", "")[:60])
            continue
        r = c["roofline"]
        mem = c["memory_per_device"]["peak_estimate_bytes"]
        fits = "fits" if mem <= HBM_PER_CHIP else "OOM!"
        ratio = c.get("model_vs_hlo")
        csv.row("roofline", c["arch"], c["shape"], c["mesh"],
                f"{r['compute_s']:.3e}", f"{r['memory_s']:.3e}",
                f"{r['collective_s']:.3e}", r["dominant"],
                f"{ratio:.2f}" if ratio else "-",
                f"{mem/2**30:.1f}GiB", fits)
    return cells


def _abft_traffic(m: int, n: int, k: int, scheme: str) -> dict:
    """Modelled (flops, bytes) of one protected GEMM call on TPU.

    Traffic model (int8 operands, int32 product): the unprotected dot
    reads A [m,k] + B [k,n] and writes C [m,n]·4B.  Both protected
    schemes widen B to B' [k, n+LANE] and the product accordingly; the
    verify itself then differs:

    * ``pallas`` — Eq. (3b) runs in the kernel epilogue on tiles still in
      VMEM: extra traffic is the err vector alone (4·m bytes).
    * ``unfused`` — XLA materializes the product, then the row reduction
      re-reads all of it: extra 4·m·(n+LANE) bytes.
    """
    np_ = n + LANE
    if scheme == "unprotected":
        flops = 2.0 * m * n * k
        bytes_ = m * k + k * n + 4.0 * m * n
    elif scheme == "pallas":
        flops = 2.0 * m * np_ * k + 3.0 * m * np_
        bytes_ = m * k + k * np_ + 4.0 * m * np_ + 4.0 * m
    elif scheme == "unfused":
        flops = 2.0 * m * np_ * k + 3.0 * m * np_
        bytes_ = m * k + k * np_ + 4.0 * m * np_ + 4.0 * m * np_ + 4.0 * m
    else:
        raise ValueError(scheme)
    return {"flops": flops, "bytes": bytes_, "collective_link_bytes": 0.0}


def run_abft(csv: Csv, *, quick: bool = False):
    shapes = GEMM_SHAPES[::4] if quick else GEMM_SHAPES
    for m, n, k in shapes:
        base = _abft_traffic(m, n, k, "unprotected")
        base_bound = roofline_terms(base, n_devices=1)["step_lower_bound_s"]
        for scheme in ("unprotected", "pallas", "unfused"):
            c = _abft_traffic(m, n, k, scheme)
            r = roofline_terms(c, n_devices=1)
            extra = c["bytes"] - base["bytes"]
            overhead = r["step_lower_bound_s"] / base_bound - 1
            csv.row("abft_roofline", f"{m}x{n}x{k}", scheme,
                    f"{c['flops']:.3e}", f"{c['bytes']:.3e}",
                    f"{extra:.3e}" if scheme != "unprotected" else "-",
                    f"{r['compute_s']:.3e}", f"{r['memory_s']:.3e}",
                    r["dominant"], f"{overhead*100:.2f}%")


def main(quick: bool = False):
    csv = Csv(["bench", "arch", "shape", "mesh", "compute_s", "memory_s",
               "collective_s", "dominant", "model/hlo", "mem_per_dev",
               "hbm"])
    run(csv)
    abft_csv = Csv(["bench", "shape_mxnxk", "scheme", "flops", "hbm_bytes",
                    "verify_extra_bytes", "compute_s", "memory_s",
                    "dominant", "roofline_overhead"])
    run_abft(abft_csv, quick=quick)
    return csv


if __name__ == "__main__":
    main()
