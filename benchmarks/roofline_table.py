"""§Roofline table: render per-cell roofline terms from dry-run artifacts.

Reads ``artifacts/dryrun/*.json`` (produced by ``repro.launch.dryrun``) and
emits the per-(arch × shape × mesh) three-term table with the dominant
bottleneck, MODEL_FLOPS ratio, and fits-in-HBM flag.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import Csv

HBM_PER_CHIP = 16 * 1024 ** 3   # v5e: 16 GiB


def load_cells(art_dir: str = "artifacts/dryrun"):
    cells = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def run(csv: Csv, art_dir: str = "artifacts/dryrun"):
    cells = load_cells(art_dir)
    for c in cells:
        if c["status"] == "skipped":
            csv.row("roofline", c["arch"], c["shape"], c["mesh"], "SKIP",
                    "-", "-", "-", "-", "-",
                    c["skip_reason"].split(":")[0])
            continue
        if c["status"] != "ok":
            csv.row("roofline", c["arch"], c["shape"], c["mesh"], "FAIL",
                    "-", "-", "-", "-", "-", c.get("error", "")[:60])
            continue
        r = c["roofline"]
        mem = c["memory_per_device"]["peak_estimate_bytes"]
        fits = "fits" if mem <= HBM_PER_CHIP else "OOM!"
        ratio = c.get("model_vs_hlo")
        csv.row("roofline", c["arch"], c["shape"], c["mesh"],
                f"{r['compute_s']:.3e}", f"{r['memory_s']:.3e}",
                f"{r['collective_s']:.3e}", r["dominant"],
                f"{ratio:.2f}" if ratio else "-",
                f"{mem/2**30:.1f}GiB", fits)
    return cells


def main(quick: bool = False):
    csv = Csv(["bench", "arch", "shape", "mesh", "compute_s", "memory_s",
               "collective_s", "dominant", "model/hlo", "mem_per_dev",
               "hbm"])
    run(csv)
    return csv


if __name__ == "__main__":
    main()
