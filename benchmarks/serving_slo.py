"""Serving SLO benchmark: what does protection cost under live traffic?

Runs the SAME seeded request stream through the serving engine under a
ladder of protection plans — unprotected, log-only, recompute+QuantKV —
and reports per-tenant p50/p95/p99 TTFT, per-token latency, and
throughput side by side, plus the protected-over-unprotected p99 ratios.
This is the paper's Fig. 6 overhead argument restated in SLO terms: the
offline kernel overhead only matters insofar as it moves these tails.

    PYTHONPATH=src python -m benchmarks.serving_slo --quick
    PYTHONPATH=src python -m benchmarks.serving_slo --arch llama3.2-1b \
        --requests 200 --rate 300 --arrival bursty --out bench/
"""
from __future__ import annotations

import argparse
import json
import os
import time


PLANS = (
    ("unprotected", "*:off"),
    ("log", "*:policy=log"),
    ("recompute+kv", "*:policy=recompute,kv_cache:on"),
)


def run_ladder(arch: str, *, requests: int, rate: float, arrival: str,
               slots: int, max_new: int, seed: int, smoke: bool,
               emit=print) -> dict:
    from repro.configs.registry import get_arch
    from repro.protect import ProtectionPlan
    from repro.serving import ServingEngine, TenantSpec, chat_stream

    cfg = get_arch(arch)
    if smoke:
        from repro.configs import reduce_cfg
        cfg = reduce_cfg(cfg)

    rows = {}
    stream_kw = dict(rate_rps=rate, arrival=arrival, seed=seed,
                     mean_prompt=24, max_prompt=32,
                     mean_output=max(max_new // 2, 1), max_output=max_new)
    for name, plan_text in PLANS:
        engine = ServingEngine(
            cfg, [TenantSpec("t", ProtectionPlan.parse(plan_text,
                                                       name=name))],
            n_slots=slots, max_prompt=32, max_new_tokens=max_new,
            seed=seed)
        stream = chat_stream(requests, tenants={"t": 1.0}, **stream_kw)
        t0 = time.perf_counter()
        telemetry = engine.run(stream)
        s = telemetry.summary()
        ts = s["per_tenant"]["t"]
        rows[name] = {
            "plan": plan_text,
            "ttft_ms": ts["ttft_ms"],
            "per_token_ms": ts["per_token_ms"],
            "e2e_ms": ts["e2e_ms"],
            "throughput_tok_s": s["throughput_tok_s"],
            "span_s": s["span_s"],
            "wall_s": time.perf_counter() - t0,
        }
        emit(f"[{name:>13}] TTFT p50/p95/p99 = "
             f"{ts['ttft_ms']['p50']:.2f}/{ts['ttft_ms']['p95']:.2f}/"
             f"{ts['ttft_ms']['p99']:.2f} ms  "
             f"tok p99 = {ts['per_token_ms']['p99']:.3f} ms  "
             f"{s['throughput_tok_s']:.0f} tok/s")

    base = rows["unprotected"]
    for name in rows:
        if name == "unprotected":
            continue
        rows[name]["ttft_p99_ratio"] = (
            rows[name]["ttft_ms"]["p99"] / base["ttft_ms"]["p99"]
            if base["ttft_ms"]["p99"] > 0 else float("nan"))
        rows[name]["tok_p99_ratio"] = (
            rows[name]["per_token_ms"]["p99"]
            / base["per_token_ms"]["p99"]
            if base["per_token_ms"]["p99"] > 0 else float("nan"))
        emit(f"{name}: p99 TTFT ×{rows[name]['ttft_p99_ratio']:.3f}, "
             f"p99 per-token ×{rows[name]['tok_p99_ratio']:.3f} "
             f"vs unprotected")
    return {"arch": arch, "requests": requests, "rate_rps": rate,
            "arrival": arrival, "slots": slots, "seed": seed,
            "plans": rows}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--requests", type=int, default=100)
    ap.add_argument("--rate", type=float, default=300.0)
    ap.add_argument("--arrival", default="poisson",
                    choices=["poisson", "bursty"])
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--decode-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true",
                    help="reduced model + 40 requests")
    ap.add_argument("--out", default=None,
                    help="directory for BENCH_serving_slo.json")
    args = ap.parse_args(argv)

    requests = 40 if args.quick else args.requests
    result = run_ladder(args.arch, requests=requests, rate=args.rate,
                        arrival=args.arrival, slots=args.slots,
                        max_new=args.decode_tokens, seed=args.seed,
                        smoke=args.quick)
    if args.out:
        os.makedirs(args.out, exist_ok=True)
        path = os.path.join(args.out, "BENCH_serving_slo.json")
        with open(path, "w") as f:
            json.dump(result, f, indent=2, sort_keys=True)
        print(f"artifact: {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
