"""Measure the live monitor's per-tick cost against serving step time.

Wall-clock A/B of whole runs (monitored vs not) is hopeless at smoke
scale: the deltas are a few ms against ±20% scheduler noise.  Instead,
run ONE monitored smoke soak to capture (a) the mean engine step time
and (b) the exact event stream the monitor saw, then fold that stream
into fresh ``Monitor`` instances and time the fold alone.  Per-tick
monitor cost over per-step engine time is the committed overhead
number — deterministic event count, best-of-N timing.

    PYTHONPATH=src python benchmarks/monitor_overhead.py [OUT.json]
"""
import json
import sys
import time

from repro.configs import reduce_cfg
from repro.configs.registry import get_arch
from repro.obs import Monitor, Observability
from repro.protect import ProtectionPlan
from repro.serving import ServingEngine, TenantSpec, chat_stream

REPS = 7
ACCEPT_FRAC = 0.05


def main(out_path=None) -> int:
    cfg = reduce_cfg(get_arch("llama3.2-1b"))
    tenants = [TenantSpec("t", ProtectionPlan.parse("*:policy=log",
                                                    name="t"))]
    eng = ServingEngine(cfg, tenants, n_slots=4, max_prompt=32,
                        max_new_tokens=8, seed=0)
    eng.warmup()

    def stream():
        return chat_stream(32, tenants={"t": 1.0}, rate_rps=200.0,
                           seed=5, mean_prompt=16, max_prompt=32,
                           mean_output=4, max_output=8)

    eng.reset_state()
    obs = Observability.create()
    mon = Monitor()
    t0 = time.perf_counter()
    tel = eng.run(stream(), obs=obs, monitor=mon)
    run_s = time.perf_counter() - t0
    steps = len(tel.steps)
    events = list(obs.bus)

    best = float("inf")
    ticks = 0
    for _ in range(REPS):
        m2 = Monitor()
        t0 = time.perf_counter()
        for ev in events:
            m2.on_event(ev)
        best = min(best, time.perf_counter() - t0)
        ticks = m2.summary()["ticks"]

    per_tick_ms = 1e3 * best / max(1, ticks)
    per_step_ms = 1e3 * run_s / max(1, steps)
    frac = per_tick_ms / per_step_ms
    out = {
        "bench": "monitor_smoke",
        "arch": "llama3.2-1b (reduced smoke config)",
        "requests": 32,
        "steps": steps,
        "ticks": ticks,
        "events": len(events),
        "reps": REPS,
        "timing": "best-of",
        "monitored_run_wall_s": round(run_s, 4),
        "per_step_ms": round(per_step_ms, 3),
        "monitor_per_tick_ms": round(per_tick_ms, 4),
        "monitor_overhead_frac_of_step": round(frac, 4),
        "monitor_overhead_pct_of_step": round(100 * frac, 2),
        "method": "fold the run's captured event stream into a fresh "
                  "Monitor (default rules), best-of-%d; per-tick cost "
                  "vs the monitored run's mean step time" % REPS,
        "acceptance": "monitor_overhead_frac_of_step < %.2f"
                      % ACCEPT_FRAC,
    }
    print(json.dumps(out, indent=2))
    if out_path:
        with open(out_path, "w") as f:
            json.dump(out, f, indent=2)
            f.write("\n")
        print(f"wrote {out_path}")
    if frac >= ACCEPT_FRAC:
        print(f"FAIL: monitor overhead {100 * frac:.2f}% of step time "
              f"(accept < {100 * ACCEPT_FRAC:.0f}%)")
        return 1
    print(f"monitor overhead OK: {100 * frac:.2f}% of step time "
          f"(< {100 * ACCEPT_FRAC:.0f}%)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1] if len(sys.argv) > 1 else None))
