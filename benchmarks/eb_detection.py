"""Table III reproduction: EmbeddingBag detection accuracy.

Thin wrapper over the resilience-campaign engine: one spec sweeps the
embedding_bag target over the significant (upper-4) and low (lower-4) bit
bands — 200 fault runs each, plus 200 clean runs per cell (400 total,
the paper's protocol) — in the paper's trained-table regime
(α ~ U(0.01, 0.02), β ~ U(0.3, 0.7), the campaign target's default
calibration; see repro.campaign.targets).

Paper results: high bits 199/200 (99.5%), low bits 94/200 (47%), false
positives 38/400 (9.5%).  The repo's magnitude-scaled bound (see
core.abft_embedding) trades the paper's 9.5% FP rate for stricter
low-bit masking, so low-bit detection lands below 47% and FP near 0.
"""
from __future__ import annotations

from benchmarks.common import Csv
from repro.campaign import CampaignSpec, run_specs

ROWS = 10_000        # detection probability is row-count independent —
DIM = 128            # the flip targets accessed rows (scaled-down table
POOL = 100           # keeps the vmapped campaign CPU-friendly)
BATCH = 10
RUNS = 200


def build_spec(*, quick: bool = False, seed: int = 42) -> CampaignSpec:
    del quick      # the EB table is already CPU-sized
    return CampaignSpec(
        name="table3-eb",
        targets=("embedding_bag",),
        fault_models=("bitflip",),
        bit_bands=("significant", "low"),
        shapes=((ROWS, DIM, BATCH, POOL),),
        samples=RUNS,
        clean_samples=RUNS,     # × 2 band cells = the paper's 400 clean
        seed=seed)


def run(csv: Csv, *, quick: bool = False):
    results, _ = run_specs([build_spec(quick=quick)])
    by_band = {r.plan.bit_band: r.metrics for r in results}
    hi, lo = by_band["significant"], by_band["low"]
    fp = hi.false_positives + lo.false_positives
    fp_n = hi.clean_samples + lo.clean_samples
    csv.row("eb_detect", "high_bits", hi.effective_detected, hi.samples,
            f"{hi.detection_rate*100:.1f}%", "paper: 99.5%")
    csv.row("eb_detect", "low_bits", lo.effective_detected, lo.samples,
            f"{lo.detection_rate*100:.1f}%", "paper: 47%")
    csv.row("eb_detect", "false_pos", fp, fp_n,
            f"{fp/fp_n*100:.1f}%", "paper: 9.5%")
    return hi.effective_detected, lo.effective_detected, fp


def main(quick: bool = False):
    csv = Csv(["bench", "case", "count", "runs", "rate", "reference"])
    run(csv, quick=quick)
    return csv


if __name__ == "__main__":
    main()
