"""Table III reproduction: EmbeddingBag detection accuracy.

Paper campaign (§VI-B2): int8 table; per run flip a random bit of a random
element *among the rows the bag accesses* (a flip in an untouched row is
invisible by construction), 200 runs in the upper 4 bits, 200 in the lower
4 bits, 400 error-free runs; relative round-off bound 1e-5.

Paper results: high bits 199/200 (99.5%), low bits 94/200 (47%), false
positives 38/400 (9.5%).

Distribution calibration: the low-bit detection rate is a *ratio* effect —
it depends on  (α·2^bit) / (1e-5 · |RSum|), i.e. where the flip magnitude
sits relative to the round-off bound.  The paper's tables come from trained
quantized embeddings whose bias terms (β ≈ row-min) give |RSum| ≫ α; we
match that regime with α ~ U(0.01, 0.02), β ~ U(0.3, 0.7) so the low 4
bits straddle the bound exactly as in the paper (a flat tiny-β synthetic
table makes every low-bit flip detectable and reads as a false 100%).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import Csv
from repro.core import abft_embedding as ae
from repro.core.inject import random_bitflip

ROWS = 100_000       # detection probability is row-count independent —
DIM = 128            # the flip targets accessed rows (scaled-down table
POOL = 100           # keeps the vmapped campaign CPU-friendly)
BATCH = 10
RUNS = 200


def _setup(key):
    kt, ka, kb = jax.random.split(key, 3)
    table = jax.random.randint(kt, (ROWS, DIM), -128, 128, jnp.int8)
    alphas = jax.random.uniform(ka, (ROWS,), jnp.float32, 1e-2, 2e-2)
    betas = jax.random.uniform(kb, (ROWS,), jnp.float32, 0.3, 0.7)
    rowsums = ae.table_rowsums(table)
    return table, alphas, betas, rowsums


@functools.partial(jax.jit, static_argnums=(1,))
def _campaign_bits(key, bit_range):
    """Flip a bit (restricted to ``bit_range``) of one accessed element."""
    table, alphas, betas, rowsums = _setup(jax.random.key(7))

    def one(kk):
        k1, k2, k3, k4 = jax.random.split(kk, 4)
        idx = jax.random.randint(k1, (BATCH, POOL), 0, ROWS, jnp.int32)
        # corrupt one random accessed element: row from idx, col random
        b = jax.random.randint(k2, (), 0, BATCH)
        p = jax.random.randint(k2, (), 0, POOL)
        row = idx[b, p]
        col = jax.random.randint(k3, (), 0, DIM)
        elem = table[row, col]
        bad = random_bitflip(k4, elem[None], bit_range=bit_range)[0]
        table_bad = table.at[row, col].set(bad)
        out = ae.abft_embedding_bag(table_bad, alphas, betas, idx, rowsums)
        return (out.err_count > 0) | (bad == elem)

    keys = jax.random.split(key, RUNS)
    return jnp.sum(jax.vmap(one)(keys).astype(jnp.int32))


@jax.jit
def _campaign_clean(key):
    table, alphas, betas, rowsums = _setup(jax.random.key(7))

    def one(kk):
        idx = jax.random.randint(kk, (BATCH, POOL), 0, ROWS, jnp.int32)
        out = ae.abft_embedding_bag(table, alphas, betas, idx, rowsums)
        return out.err_count > 0

    keys = jax.random.split(key, 2 * RUNS)
    return jnp.sum(jax.vmap(one)(keys).astype(jnp.int32))


def run(csv: Csv, *, quick: bool = False):
    key = jax.random.key(42)
    hi = int(_campaign_bits(key, (4, 8)))        # upper 4 bits of int8
    lo = int(_campaign_bits(jax.random.fold_in(key, 1), (0, 4)))
    fp = int(_campaign_clean(jax.random.fold_in(key, 2)))
    csv.row("eb_detect", "high_bits", hi, RUNS,
            f"{hi/RUNS*100:.1f}%", "paper: 99.5%")
    csv.row("eb_detect", "low_bits", lo, RUNS,
            f"{lo/RUNS*100:.1f}%", "paper: 47%")
    csv.row("eb_detect", "false_pos", fp, 2 * RUNS,
            f"{fp/(2*RUNS)*100:.1f}%", "paper: 9.5%")
    return hi, lo, fp


def main(quick: bool = False):
    csv = Csv(["bench", "case", "count", "runs", "rate", "reference"])
    run(csv, quick=quick)
    return csv


if __name__ == "__main__":
    main()
