"""Victim-selection sweep: plan-path-addressed injection victims in the
decode soak (`--grid victims`), and the live-region flip helper."""
import jax
import jax.numpy as jnp
import pytest

from repro.campaign.spec import CampaignSpec, expand
from repro.core.abft_gemm import LANE
from repro.core.inject import (leaf_paths, random_bitflip_live,
                               victim_leaf_index)


def test_expand_sweeps_victims_for_selectable_targets():
    spec = CampaignSpec(name="t", targets=("decode_step",),
                        fault_models=("bitflip",),
                        bit_bands=("significant",),
                        victims=("attn.wq", "mlp.down"), samples=2)
    plans, skipped = expand(spec)
    assert [p.victim for p in plans] == ["attn.wq", "mlp.down"]
    assert all("vic=" in p.cell_id for p in plans)
    assert not skipped
    # seeds stay stable per cell id
    plans2, _ = expand(spec)
    assert [(p.cell_id, p.seed) for p in plans] == \
        [(p.cell_id, p.seed) for p in plans2]


def test_expand_skips_victims_for_non_selectable_targets():
    spec = CampaignSpec(name="t", targets=("gemm_packed",),
                        victims=("attn.wq",), samples=2)
    plans, skipped = expand(spec)
    assert len(plans) == 1 and plans[0].victim is None
    assert any("no selectable victim" in s["reason"] for s in skipped)


def test_victim_leaf_index_patterns():
    tree = {
        "layers": {
            "attn": {"wq": {"w_packed":
                            jnp.zeros((2, 8, 8 + LANE), jnp.int8)},
                     "wo": {"w_packed":
                            jnp.zeros((2, 8, 8 + LANE), jnp.int8)}},
            "mlp": {"down": {"w_packed":
                             jnp.zeros((2, 16, 8 + LANE), jnp.int8)}},
        },
        "embed": {"table": jnp.zeros((64, 8), jnp.int8),
                  "alphas": jnp.zeros((64,), jnp.float32)},
    }
    idx, path = victim_leaf_index(tree, "attn.wq")
    assert path == "layers.attn.wq.w_packed"
    idx2, path2 = victim_leaf_index(tree, "embed.table")
    assert path2 == "embed.table"
    # default: largest int8 leaf
    _, path3 = victim_leaf_index(tree, None)
    assert path3 == "layers.mlp.down.w_packed"
    with pytest.raises(ValueError, match="matches no leaf"):
        victim_leaf_index(tree, "nonexistent.thing")
    # int8 preferred over larger float leaves
    tree["huge_f32"] = jnp.zeros((10000,), jnp.float32)
    _, path4 = victim_leaf_index(tree, None)
    assert path4 == "layers.mlp.down.w_packed"


def test_leaf_paths_cover_all_leaves_in_flatten_order():
    tree = {"a": {"b": jnp.zeros(3)}, "c": [jnp.ones(2), jnp.ones(1)]}
    named = leaf_paths(tree)
    flat = jax.tree_util.tree_flatten(tree)[0]
    assert len(named) == len(flat)
    for (name, leaf), ref in zip(named, flat):
        assert leaf is ref
    assert [n for n, _ in named] == ["a.b", "c.0", "c.1"]


def test_random_bitflip_live_avoids_dead_lanes():
    """Every flip in a packed weight must land in the weight block or the
    checksum column — never in the alignment-zero lanes 1..127."""
    n = 4
    packed = jnp.zeros((8, n + LANE), jnp.int8)
    for s in range(64):
        flipped = random_bitflip_live(jax.random.key(s), packed,
                                      "layers.mlp.down.w_packed")
        changed = jnp.argwhere(flipped != packed)
        assert changed.shape[0] == 1
        col = int(changed[0, 1])
        assert col <= n, col                  # weight cols or checksum col
    # non-packed leaves keep full-leaf semantics
    plain = jnp.zeros((8, 8), jnp.int8)
    flipped = random_bitflip_live(jax.random.key(0), plain, "embed.table")
    assert int(jnp.sum(flipped != plain)) == 1
