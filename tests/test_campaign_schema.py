"""Golden schema for ``BENCH_campaign_*.json`` artifacts.

The cross-PR differ matches cells by id and reads metric fields by NAME;
a silent rename would make ``--diff`` read ``None``s and report "no
regressions" forever.  These tests pin the CellMetrics field set to a
literal golden list (a rename breaks HERE first), assert every committed
baseline still carries the core fields, and assert freshly-written
artifacts emit the full set — including the multi-device ``shards`` /
``collective_verified`` columns and the soak/latency columns.
"""
import dataclasses
import glob
import os

import pytest

from repro.campaign import CellMetrics, compute_metrics, load_artifact

BASELINE_DIR = os.path.join(os.path.dirname(os.path.dirname(__file__)),
                            "benchmarks", "baselines")
BASELINES = sorted(glob.glob(os.path.join(BASELINE_DIR,
                                          "BENCH_campaign_*.json")))

#: fields every artifact cell must carry (the differ + CI assertions
#: read these) — a rename in metrics.py must be caught here, not by
#: --diff silently comparing missing keys
CORE_FIELDS = {
    "samples", "corrupted", "detected", "effective_detected", "escapes",
    "clean_samples", "false_positives", "detection_rate",
    "raw_detection_rate", "escape_rate", "fp_rate", "ci95",
    "analytic_bound", "overhead", "protected_s", "unprotected_s",
}

#: per-phase overhead accounting (optional; quantize/encode/gemm/verify
#: medians from targets exposing ``overhead_phases``) — committed
#: baselines predate it, so it lives outside CORE: baselines assert
#: ``CORE <= keys <= full`` and need no regeneration
BREAKDOWN_FIELDS = {"overhead_breakdown"}

#: multi-step soak columns (latency histograms + clean-twin divergence)
SOAK_FIELDS = {
    "steps", "detection_latency_hist", "mean_detection_latency",
    "divergence_mean", "divergence_max", "loss_divergence_mean",
}

#: multi-device soak columns
SHARD_FIELDS = {"shards", "collective_verified", "shard_detections"}

#: the fields --diff actually compares — must stay inside CORE
DIFF_READS = {"detection_rate", "fp_rate", "overhead"}

#: serving-engine paging cells (``plan.kind``) emit engine telemetry
#: instead of executor CellMetrics; pin the fields the differ and the
#: CI ``paging-smoke`` acceptance gate read so a rename breaks here
#: first.  Engine columns are additive, so no upper bound.
PAGING_FIELDS = {
    "parity": DIFF_READS | {
        "samples", "detected", "escapes", "escape_rate",
        "clean_samples", "false_positives", "completed",
        "parity_ok", "verify_ok", "bytes_ok",
        "pages_verified_per_token", "contig_rows_verified_per_token",
        "peak_resident_kv_bytes", "fixed_slot_kv_bytes",
        "prefix_hit_rate",
    },
    "rebuild": DIFF_READS | {
        "samples", "detected", "escapes", "escape_rate",
        "clean_samples", "false_positives", "completed", "aborted",
        "rebuild_ok", "page_rebuilds",
    },
}

#: threshold-controller convergence cells (``--grid adaptive``): pin the
#: fields the differ and the CI ``adaptive-smoke`` acceptance gate read
ADAPTIVE_FIELDS = {
    "adaptive": DIFF_READS | {
        "samples", "corrupted", "detected", "escapes", "escape_rate",
        "clean_samples", "false_positives", "fp_budget",
        "realized_fp_rate", "realized_fp_low", "realized_fp_high",
        "fp_budget_held", "fp_budget_in_ci", "converged",
        "converged_rel_bound", "ticks_to_converge", "adjustments",
        "best_static_rel_bound", "best_static_detection",
        "best_static_fp", "detection_ok",
    },
}


def test_cellmetrics_field_set_is_exactly_the_golden_schema():
    names = {f.name for f in dataclasses.fields(CellMetrics)}
    assert names == CORE_FIELDS | BREAKDOWN_FIELDS | SOAK_FIELDS | SHARD_FIELDS
    assert DIFF_READS <= CORE_FIELDS


def test_fresh_metrics_emit_the_full_schema():
    m = compute_metrics(samples=4, detected=3, corrupted=3,
                        detected_and_corrupted=3, clean_samples=2,
                        false_positives=0)
    assert set(m.to_dict()) == CORE_FIELDS | BREAKDOWN_FIELDS | SOAK_FIELDS | SHARD_FIELDS


def test_baselines_exist():
    # the schema guarantees below are vacuous without committed artifacts
    names = {os.path.basename(p) for p in BASELINES}
    assert {"BENCH_campaign_quick.json",
            "BENCH_campaign_training_quick.json",
            "BENCH_campaign_multidevice_quick.json",
            "BENCH_campaign_adaptive_quick.json"} <= names


@pytest.mark.parametrize("path", BASELINES,
                         ids=[os.path.basename(p) for p in BASELINES])
def test_committed_baselines_carry_core_schema(path):
    art = load_artifact(path)
    assert art["cells"], path
    full = CORE_FIELDS | BREAKDOWN_FIELDS | SOAK_FIELDS | SHARD_FIELDS
    for c in art["cells"]:
        keys = set(c["metrics"])
        kind = c["plan"].get("kind")
        if kind in PAGING_FIELDS:
            assert PAGING_FIELDS[kind] <= keys, \
                (c["cell_id"], PAGING_FIELDS[kind] - keys)
            continue
        if kind in ADAPTIVE_FIELDS:
            assert ADAPTIVE_FIELDS[kind] <= keys, \
                (c["cell_id"], ADAPTIVE_FIELDS[kind] - keys)
            continue
        assert CORE_FIELDS <= keys, (c["cell_id"], CORE_FIELDS - keys)
        assert keys <= full, (c["cell_id"], keys - full)
        # must round-trip: --diff and CI assertions load through this
        CellMetrics.from_dict(c["metrics"])


def test_paging_baseline_carries_claim_and_diff_fields():
    art = load_artifact(os.path.join(
        BASELINE_DIR, "BENCH_campaign_paging_quick.json"))
    kinds = {c["plan"]["kind"]: c["metrics"] for c in art["cells"]}
    assert set(kinds) == set(PAGING_FIELDS)
    # the committed baseline must witness the three paging claims the
    # CI gate asserts on fresh runs — a stale/failing baseline would
    # make the --diff gate compare against a broken reference
    par, reb = kinds["parity"], kinds["rebuild"]
    assert par["parity_ok"] and par["verify_ok"] and par["bytes_ok"]
    assert par["pages_verified_per_token"] < \
        par["contig_rows_verified_per_token"]
    assert par["peak_resident_kv_bytes"] < par["fixed_slot_kv_bytes"]
    assert reb["rebuild_ok"] and reb["page_rebuilds"] >= 1


def test_adaptive_baseline_witnesses_the_convergence_claims():
    art = load_artifact(os.path.join(
        BASELINE_DIR, "BENCH_campaign_adaptive_quick.json"))
    drifts = {c["plan"]["drift"]: c["metrics"] for c in art["cells"]}
    assert set(drifts) == {"variance_shift", "prompt_mix", "bursty"}
    # every cell must witness the three gates the CI adaptive-smoke
    # job asserts on fresh runs: the controller converged, held the FP
    # budget post-convergence, and lost no detection to the best
    # offline-swept constant on the identical stream
    for drift, m in drifts.items():
        assert m["converged"] is True, drift
        assert m["fp_budget_held"] is True, drift
        assert m["detection_ok"] is True, drift
        assert m["ticks_to_converge"] is not None, drift
        assert m["realized_fp_low"] <= m["fp_budget"], drift
    # the drift the controller exists for: mixed-precision variance
    # shift, where no static bound can serve both regimes — adaptive
    # detection must strictly beat the best budget-holding constant
    vs = drifts["variance_shift"]
    assert vs["detection_rate"] > vs["best_static_detection"]
    # controllers move: each cell adjusted at least once and recorded
    # a trajectory consistent with its adjustment count
    for drift, m in drifts.items():
        assert m["adjustments"] >= 1, drift
        assert len(m["move_ticks"]) == m["adjustments"], drift


def test_multidevice_baseline_carries_shard_and_soak_columns():
    art = load_artifact(os.path.join(
        BASELINE_DIR, "BENCH_campaign_multidevice_quick.json"))
    sharded = [c for c in art["cells"]
               if c["plan"]["data_shards"] > 1]
    assert sharded, "no sharded cells in the multidevice baseline"
    for c in sharded:
        m = c["metrics"]
        assert m["shards"] == c["plan"]["data_shards"], c["cell_id"]
        assert m["collective_verified"] is True, c["cell_id"]
        assert len(m["shard_detections"]) == m["shards"], c["cell_id"]
        assert len(m["detection_latency_hist"]) == m["steps"], \
            c["cell_id"]
    # the grid also holds the single-device contrast cell: fallback path
    single = [c for c in art["cells"] if c["plan"]["data_shards"] == 1]
    assert single and all(
        c["metrics"]["collective_verified"] is False for c in single)
