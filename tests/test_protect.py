"""The repro.protect subsystem: plan parsing/resolution, ProtectedOp
adapters, per-op policy application, the generalized FaultReport under
jit/scan/vmap, and protect(apply_fn, plan) on a real model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import policy
from repro.core.inject import random_bitflip
from repro.protect import (Check, OpRule, ProtectionPlan, default_plan,
                           encode_tree, get_op, protect, protected_call,
                           unprotected_plan)
from repro.protect.plan import ResolvedRule
from repro.protect.runtime import rule_for


# ------------------------------ plan ----------------------------------------

def test_plan_parse_round_trip():
    text = ("*:policy=log,embedding_bag:off,"
            "qgemm/attn.*:policy=recompute:retries=2,"
            "embedding_bag:rel_bound=0.0001")
    plan = ProtectionPlan.parse(text)
    assert len(plan.rules) == 4
    back = ProtectionPlan.from_dict(plan.to_dict())
    assert back == plan
    assert "qgemm/attn.*" in plan.describe()


def test_plan_resolution_precedence_and_paths():
    plan = ProtectionPlan.parse(
        "*:policy=log,qgemm:policy=recompute,qgemm/attn.*:scheme=unfused,"
        "qgemm/attn.wq:off")
    r = plan.resolve("qgemm", "mlp.up")
    assert r.enabled and r.policy == "recompute" and r.scheme is None
    r = plan.resolve("qgemm", "attn.wk")
    assert r.enabled and r.scheme == "unfused" and r.policy == "recompute"
    assert not plan.resolve("qgemm", "attn.wq").enabled
    # unrelated op inherits only the wildcard
    assert plan.resolve("embedding_bag", "tables").policy == "log"


def test_plan_parse_rejects_garbage():
    with pytest.raises(ValueError):
        ProtectionPlan.parse("qgemm:policy=sacrifice")
    with pytest.raises(ValueError):
        ProtectionPlan.parse("qgemm:frobnicate")
    with pytest.raises(ValueError):
        ProtectionPlan.parse("qgemm:rel_bound=not_a_float")


def test_plan_bare_on_off_and_empty():
    assert not ProtectionPlan.parse("off").resolve("qgemm").enabled
    assert ProtectionPlan.parse("").resolve("qgemm").enabled
    assert not unprotected_plan().resolve("embedding_bag").enabled
    d = default_plan()
    assert d.resolve("qgemm").enabled
    assert not d.resolve("kv_cache").enabled
    assert not d.resolve("float_gemm").enabled


def test_opt_in_ops_stay_off_in_parsed_plans():
    # a parse()-built plan must not silently enable the opt-in kinds —
    # same string, same behavior as default_plan-seeded entry points
    p = ProtectionPlan.parse("*:policy=recompute")
    assert p.resolve("qgemm").enabled
    assert not p.resolve("kv_cache").enabled
    assert not p.resolve("float_gemm").enabled
    # ...but an explicit rule (or explicit wildcard on/off) opts in
    assert ProtectionPlan.parse("kv_cache:on").resolve("kv_cache").enabled
    assert ProtectionPlan.parse("*:on").resolve("kv_cache").enabled


def test_plan_is_hashable_and_ctx_embeddable():
    from repro.layers.common import Ctx
    plan = ProtectionPlan.parse("*:policy=recompute")
    hash(plan)
    ctx = Ctx(quant=True, plan=plan)
    assert rule_for(ctx, "qgemm").policy == "recompute"


def test_rule_for_legacy_flags():
    from repro.layers.common import Ctx
    assert rule_for(Ctx(abft=True), "qgemm").enabled
    assert not rule_for(Ctx(abft=False), "embedding_bag").enabled
    assert not rule_for(Ctx(abft=True), "kv_cache").enabled
    assert rule_for(Ctx(float_abft=True), "float_gemm").enabled
    assert not rule_for(Ctx(), "float_gemm").enabled


# --------------------------- adapters ---------------------------------------

def _gemm_fixture(m=8, k=64, n=32):
    ka, kb = jax.random.split(jax.random.key(0))
    a = jax.random.randint(ka, (m, k), 0, 256, jnp.uint8)
    b = jax.random.randint(kb, (k, n), -127, 128, jnp.int8)
    return a, b, get_op("qgemm").encode(b)


def test_qgemm_adapter_schemes_detect_flip():
    a, b, packed = _gemm_fixture()
    n = b.shape[1]
    b_bad = random_bitflip(jax.random.key(7), b)
    bad_packed = jnp.concatenate([b_bad, packed[:, n:]], axis=1)
    qg = get_op("qgemm")
    for scheme in ("packed", "unfused"):
        _, check = qg(packed, a, rule=ResolvedRule(scheme=scheme))
        assert int(check.err_count) == 0, scheme
        _, check = qg(bad_packed, a, rule=ResolvedRule(scheme=scheme))
        assert int(check.err_count) > 0, scheme
    # unprotected baseline matches the protected C
    c, _ = qg(packed, a)
    np.testing.assert_array_equal(np.asarray(qg.unprotected(packed, a)),
                                  np.asarray(c))


def test_eb_adapter_rel_bound_changes_detection():
    kt, ki = jax.random.split(jax.random.key(1))
    table = jax.random.randint(kt, (512, 64), -128, 128, jnp.int8)
    alphas = jnp.full((512,), 1e-2, jnp.float32)
    betas = jnp.full((512,), 0.5, jnp.float32)
    eb = get_op("embedding_bag")
    enc = eb.encode((table, alphas, betas))
    idx = jax.random.randint(ki, (4, 20), 0, 512, jnp.int32)
    # low-bit corruption on an accessed element
    row = int(idx[0, 0])
    bad = (table.at[row, 3].add(1),) + enc[1:]
    _, tight = eb(bad, idx, rule=ResolvedRule(rel_bound=1e-9))
    _, loose = eb(bad, idx, rule=ResolvedRule(rel_bound=1e-1))
    assert int(tight.err_count) >= 1
    assert int(loose.err_count) == 0


def test_kv_adapter_verify_and_attend():
    kv = get_op("kv_cache")
    b, kvh, s, dh = 2, 2, 16, 8
    kx = jax.random.normal(jax.random.key(2), (b, kvh, s, dh))
    vx = jax.random.normal(jax.random.key(3), (b, kvh, s, dh))
    enc = kv.encode((kx, vx))
    q = jax.random.normal(jax.random.key(4), (b, 4, dh))
    pos = jnp.full((b,), s - 1, jnp.int32)
    out, check = kv(enc, q, pos, n_heads=4, n_kv=kvh)
    assert out.shape == (b, 4, dh) and int(check.err_count) == 0
    qk = np.asarray(enc[0].q).copy()
    qk[0, 0, 3, 0] ^= 0x40
    bad_k = enc[0]._replace(q=jnp.asarray(qk))
    _, check2 = kv((bad_k, enc[1]), q, pos, n_heads=4, n_kv=kvh)
    assert int(check2.err_count) >= 1


# ---------------------- protected_call + policies ---------------------------

def test_protected_call_disabled_runs_baseline():
    a, b, packed = _gemm_fixture()
    c, rep = protected_call("qgemm", packed, a,
                            rule=ResolvedRule(enabled=False))
    assert int(rep.total_checks()) == 0 and int(rep.total_errors()) == 0
    np.testing.assert_array_equal(
        np.asarray(c), np.asarray(get_op("qgemm").unprotected(packed, a)))


def test_policy_recompute_counts_retries_via_plan():
    a, b, packed = _gemm_fixture()
    n = b.shape[1]
    bad = jnp.concatenate([random_bitflip(jax.random.key(5), b),
                           packed[:, n:]], axis=1)
    _, rep = protected_call("qgemm", bad, a,
                            rule=ResolvedRule(policy="recompute",
                                              max_retries=2))
    assert int(rep.retries) == 2          # deterministic sim: persists
    assert int(rep.errors["qgemm"]) > 0
    _, rep2 = protected_call("qgemm", packed, a,
                             rule=ResolvedRule(policy="recompute"))
    assert int(rep2.retries) == 0 and int(rep2.errors["qgemm"]) == 0


def test_policy_correct_repairs_single_row_weight_fault():
    # m=1 (DLRM's classic skinny GEMM): a weight flip corrupts exactly one
    # C cell, so the row+column checksums localize and repair it
    a, b, packed = _gemm_fixture(m=1)
    n = b.shape[1]
    b_bad = random_bitflip(jax.random.key(9), b)
    bad_packed = jnp.concatenate([b_bad, packed[:, n:]], axis=1)
    qg = get_op("qgemm")
    # expected C from clean weights
    c_clean = qg.unprotected(packed, a)
    c_corrupt = qg.unprotected(bad_packed, a)
    assert np.any(np.asarray(c_clean) != np.asarray(c_corrupt))
    # correction repairs C *relative to the operands it ran with*: here we
    # emulate an accumulator upset by handing correct() the clean col aux
    _, check = qg(bad_packed, a, rule=ResolvedRule(policy="correct"))
    col_clean = jax.lax.dot_general(
        jnp.sum(a.astype(jnp.int32), axis=0), b.astype(jnp.int32),
        (((0,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    fixed, residual, applied = qg.correct(
        c_corrupt, Check(check.err_count, check.err_mask, col_clean))
    assert int(applied) == 1 and int(residual) == 0
    np.testing.assert_array_equal(np.asarray(fixed), np.asarray(c_clean))


def test_policy_correct_end_to_end_on_accumulator_fault():
    """The correct policy behind protected_call: a custom adapter whose
    run corrupts C after the dot (an accumulator upset, §IV-C2) — the
    colcheck threaded through kernels.ops repairs it."""
    from repro.kernels import ops as kops
    from repro.protect import register_op
    from repro.protect.ops import QGemmOp
    from repro.core import verify_rows

    class UpsetQGemm(QGemmOp):
        name = "qgemm_upset"

        def __call__(self, encoded, a_q, *, rule=ResolvedRule()):
            c, _, col_check = kops.abft_qgemm(a_q, encoded,
                                              with_colcheck=True)
            c = c.at[2, 5].add(-4321)          # the upset
            n = encoded.shape[1] - self.lane
            # re-verify rows of the corrupted C against the fused column
            c_full = jax.lax.dot_general(
                a_q, encoded, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32)
            err_rows, err = verify_rows(c, c_full[:, n])
            return c, Check(err, err_rows, col_check)

    register_op(UpsetQGemm())
    a, b, packed = _gemm_fixture()
    c, rep = protected_call("qgemm_upset", packed, a,
                            rule=ResolvedRule(policy="correct"))
    assert int(rep.corrections) == 1
    assert int(rep.errors["qgemm_upset"]) == 0
    np.testing.assert_array_equal(
        np.asarray(c), np.asarray(get_op("qgemm").unprotected(packed, a)))


def test_policy_correct_repairs_weight_flip_via_colsum():
    """(packed, colsum_ref) tuple encoding: a weight flip poisons a whole
    C column (m > 1), which the single-cell accumulator repair declines —
    the B-side column-sum reference localizes and repairs it end to end
    through protected_call."""
    from repro.core.abft_gemm import encode_weight_colsum

    a, b, packed = _gemm_fixture()
    colsum = encode_weight_colsum(b)
    n = b.shape[1]
    bq = np.asarray(b).copy()
    bq[5, 7] ^= np.int8(0x20)
    bad_packed = jnp.concatenate([jnp.asarray(bq), packed[:, n:]], axis=1)
    c, rep = protected_call("qgemm", (bad_packed, colsum), a,
                            rule=ResolvedRule(policy="correct"))
    assert int(rep.corrections) == 1
    assert int(rep.errors["qgemm"]) == 0
    np.testing.assert_array_equal(
        np.asarray(c), np.asarray(get_op("qgemm").unprotected(packed, a)))
    # without the colsum reference the same fault is detected but not
    # repairable: it falls through with residual errors
    _, rep2 = protected_call("qgemm", bad_packed, a,
                             rule=ResolvedRule(policy="correct"))
    assert int(rep2.corrections) == 0
    assert int(rep2.errors["qgemm"]) > 0


def test_qlinear_correct_policy_repairs_weight_flip():
    """The layer wiring: a correct-policy call site hands the stored
    colsum over as the repair reference, so a flipped packed weight
    yields the clean activations plus one recorded correction."""
    from repro.layers.common import Ctx
    from repro.layers.linear import init_qlinear, qlinear

    p = init_qlinear(jax.random.key(3), 32, 16, bias=False)
    p = {k: v.value for k, v in p.items()}
    x = jax.random.normal(jax.random.key(4), (4, 32))
    plan = ProtectionPlan.parse("*:policy=correct")
    y_clean, rep0 = qlinear(p, x, Ctx(quant=True, plan=plan))
    assert int(rep0.total_errors()) == 0 and int(rep0.corrections) == 0
    bad = dict(p)
    w = np.asarray(p["w_packed"]).copy()
    w[7, 5] ^= np.int8(0x10)             # payload flip; refs stay clean
    bad["w_packed"] = jnp.asarray(w)
    y_bad, rep = qlinear(bad, x, Ctx(quant=True, plan=plan))
    assert int(rep.corrections) == 1
    assert int(rep.total_errors()) == 0
    np.testing.assert_array_equal(np.asarray(y_bad), np.asarray(y_clean))


def test_policy_correct_falls_back_to_recompute_for_eb():
    kt, ki = jax.random.split(jax.random.key(6))
    table = jax.random.randint(kt, (256, 32), -128, 128, jnp.int8)
    alphas = jnp.full((256,), 1e-2, jnp.float32)
    betas = jnp.zeros((256,), jnp.float32)
    eb = get_op("embedding_bag")
    enc = eb.encode((table, alphas, betas))
    idx = jax.random.randint(ki, (2, 8), 0, 256, jnp.int32)
    bad = (table.at[int(idx[0, 0]), 0].add(100),) + enc[1:]
    _, rep = protected_call("embedding_bag", bad, idx,
                            rule=ResolvedRule(policy="correct"))
    assert int(rep.retries) == 1          # fell back to detect->retry


def test_policy_abort_raises_through_jit():
    a, b, packed = _gemm_fixture()
    n = b.shape[1]
    bad = jnp.concatenate([random_bitflip(jax.random.key(11), b),
                           packed[:, n:]], axis=1)
    fn = jax.jit(lambda: protected_call(
        "qgemm", bad, a, rule=ResolvedRule(policy="abort"))[0])
    try:
        jax.block_until_ready(fn())
        raised = None
    except Exception as e:
        raised = e
    assert raised is not None and policy.is_fault_abort(raised)


# -------------------------- FaultReport pytree ------------------------------

def test_report_round_trips_under_jit_scan_vmap():
    def one(err):
        return policy.op_report("qgemm", err)

    rep = jax.jit(one)(jnp.asarray(3, jnp.int32))
    assert int(rep.errors["qgemm"]) == 3

    def body(carry, x):
        return policy.merge_reports(carry, one(x)), None

    final, _ = jax.lax.scan(body, policy.empty_report(),
                            jnp.arange(5, dtype=jnp.int32))
    assert int(final.errors["qgemm"]) == 10
    assert int(final.checks["qgemm"]) == 5

    reps = jax.vmap(one)(jnp.arange(4, dtype=jnp.int32))
    summed = jax.tree.map(jnp.sum, reps)
    assert int(summed.errors["qgemm"]) == 6


def test_report_keyed_metrics_and_legacy_aliases():
    rep = policy.merge_reports(
        policy.op_report("qgemm", 2),
        policy.op_report("embedding_bag", 1),
        policy.op_report("kv_cache", 4))
    m = rep.as_metrics()
    assert int(m["abft/qgemm_errors"]) == 2
    assert int(m["abft/embedding_bag_errors"]) == 1
    assert int(m["abft/kv_cache_errors"]) == 4
    # legacy names still resolve (pre-protect consumers)
    assert int(m["abft/gemm_errors"]) == 2
    assert int(m["abft/eb_errors"]) == 1
    assert int(rep.total_errors()) == 7


def test_report_unknown_kind_raises():
    with pytest.raises(KeyError):
        policy.op_report("not_registered", 1)


# --------------------------- protect(apply_fn) ------------------------------

@pytest.fixture(scope="module")
def small_model():
    from repro.configs.reduce import reduce_cfg
    from repro.configs.registry import get_arch
    from repro.models.base import build_model
    from repro.sharding import values_of

    cfg = reduce_cfg(get_arch("llama3.2-1b"))
    model = build_model(cfg, max_pos=128)
    params = values_of(model.init(jax.random.key(2), quant=True))
    tokens = jax.random.randint(jax.random.key(3), (2, 16), 0, cfg.vocab,
                                jnp.int32)
    return cfg, model, params, tokens


def _prefill(model, plan, params, tokens):
    pf = protect(model.prefill, plan)
    return jax.jit(lambda p, t: pf(p, {"tokens": t}, cache_len=32))(
        params, tokens)


def test_protect_plan_flips_eb_off_without_model_edits(small_model):
    cfg, model, params, tokens = small_model
    (l_on, _), rep_on = _prefill(model, default_plan(), params, tokens)
    (l_off, _), rep_off = _prefill(
        model, default_plan().with_rules(OpRule("embedding_bag",
                                                enabled=False)),
        params, tokens)
    assert int(rep_on.eb_checks) > 0
    assert int(rep_off.eb_checks) == 0
    assert int(rep_off.gemm_checks) == int(rep_on.gemm_checks)
    np.testing.assert_allclose(np.asarray(l_on, np.float32),
                               np.asarray(l_off, np.float32))


def test_protect_plan_policy_recompute_without_model_edits(small_model):
    cfg, model, params, tokens = small_model
    plan = default_plan().with_rules(OpRule("*", policy="recompute"))
    (_, _), rep = _prefill(model, plan, params, tokens)
    assert int(rep.retries) == 0          # clean run: cond never fires
    assert int(rep.gemm_checks) > 0


def test_protect_kv_cache_plan_decode(small_model):
    cfg, model, params, tokens = small_model
    plan = default_plan().with_rules(OpRule("kv_cache", enabled=True))
    pf = protect(model.prefill, plan)
    dec = protect(model.decode, plan)
    (logits, cache), _ = jax.jit(
        lambda p, t: pf(p, {"tokens": t}, cache_len=32))(params, tokens)
    from repro.protect.ops import QuantKV
    assert isinstance(cache["attn"]["k"], QuantKV)
    tok = jnp.argmax(logits[..., :cfg.vocab], -1).astype(jnp.int32)
    pos = jnp.full((2,), 16, jnp.int32)
    (l2, cache2), rep = jax.jit(dec)(params, cache, tok, pos)
    assert int(rep.checks["kv_cache"]) == cfg.n_layers
    assert int(rep.errors["kv_cache"]) == 0
    assert l2.shape[0] == 2


def test_protect_surfaces_nested_loss_report(small_model):
    # Model.loss nests its report: (loss, (metrics, rep)) — protect() must
    # surface the merged report, not a silent empty one
    cfg, model, params, tokens = small_model
    loss_p = protect(model.loss, default_plan())
    batch = {"tokens": tokens, "labels": tokens}
    out, rep = jax.jit(loss_p)(params, batch)
    loss, (metrics, inner_rep) = out
    assert int(rep.total_checks()) > 0
    assert int(rep.total_checks()) == int(inner_rep.total_checks())


def test_encode_tree_refreshes_colsum_with_lanes():
    # swapping the weight block inside w_packed then encode()ing must
    # refresh BOTH the checksum lanes and the Eq. 1 colsum constant —
    # a stale colsum is silent output corruption, not a detection miss
    from repro.layers.common import Ctx
    from repro.layers.linear import init_qlinear, qlinear

    p = init_qlinear(jax.random.key(0), 32, 16)
    p = {k: v.value for k, v in p.items()}
    new_w = jax.random.randint(jax.random.key(1), (32, 16), -127, 128,
                               jnp.int8)
    p["w_packed"] = jnp.concatenate([new_w, p["w_packed"][:, 16:]], axis=1)
    p2 = encode_tree(p)
    np.testing.assert_array_equal(
        np.asarray(p2["colsum"]),
        np.asarray(jnp.sum(new_w.astype(jnp.int32), axis=0), np.float32))
    x = jax.random.normal(jax.random.key(2), (4, 32))
    _, rep = qlinear(p2, x, Ctx(quant=True, plan=default_plan()))
    assert int(rep.total_errors()) == 0
    # reference: a fresh init from the same weight block gives the same y
    ref = encode_tree({"w_packed": p2["w_packed"], "alpha": p["alpha"],
                       "colsum": jnp.zeros_like(p["colsum"]),
                       "b": p["b"]})
    np.testing.assert_array_equal(np.asarray(ref["colsum"]),
                                  np.asarray(p2["colsum"]))


def test_encode_tree_refreshes_checksums(small_model):
    cfg, model, params, tokens = small_model
    # corrupt a packed weight, then re-encode: the fresh checksum matches
    # the corrupted weight again (zero detections)
    leaves, treedef = jax.tree_util.tree_flatten(params)
    idx = max((i for i, l in enumerate(leaves)
               if l.dtype == jnp.int8 and l.ndim >= 2),
              key=lambda i: leaves[i].size)
    leaves[idx] = random_bitflip(jax.random.key(8), leaves[idx])
    bad_params = jax.tree_util.tree_unflatten(treedef, leaves)
    (_, _), rep_bad = _prefill(model, default_plan(), bad_params, tokens)
    reencoded = encode_tree(bad_params)
    (_, _), rep_fixed = _prefill(model, default_plan(), reencoded, tokens)
    assert int(rep_fixed.total_errors()) == 0
    # (the flip may or may not land in a checked op's weight block; the
    # invariant under test is that re-encoding always clears detections)
    assert int(rep_bad.total_errors()) >= int(rep_fixed.total_errors())
