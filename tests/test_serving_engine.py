"""ServingEngine: per-tenant plan lanes, FaultReport merging across
interleaved prefill/decode under jit, online fault injection (transient
restore), and abort-policy request failure."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduce_cfg
from repro.configs.registry import get_arch
from repro.protect import ProtectionPlan, protect, merge_reports
from repro.serving import (FaultInjection, ServingEngine, TenantSpec,
                           chat_stream)

N_SLOTS = 2
MAX_PROMPT = 8
MAX_NEW = 4


@pytest.fixture(scope="module")
def engine():
    cfg = reduce_cfg(get_arch("llama3.2-1b"))
    tenants = [
        TenantSpec("premium", ProtectionPlan.parse(
            "*:policy=recompute,kv_cache:on", name="premium")),
        TenantSpec("standard", ProtectionPlan.parse(
            "*:policy=log", name="standard"), weight=2.0),
    ]
    eng = ServingEngine(cfg, tenants, n_slots=N_SLOTS,
                        max_prompt=MAX_PROMPT, max_new_tokens=MAX_NEW,
                        seed=0)
    eng.warmup()
    return eng


def _stream(n, seed=0, rate=500.0, arrival="poisson"):
    return chat_stream(n, tenants={"premium": 1.0, "standard": 2.0},
                       rate_rps=rate, arrival=arrival, seed=seed,
                       mean_prompt=6, max_prompt=MAX_PROMPT,
                       mean_output=3, max_output=MAX_NEW)


def test_lanes_group_tenants_by_plan(engine):
    assert len(engine.lanes) == 2
    lanes = {next(iter(lane.tenants)): lane for lane in engine.lanes}
    assert lanes["premium"] is not lanes["standard"]
    # same-plan tenants share a lane
    cfg = reduce_cfg(get_arch("llama3.2-1b"))
    p = ProtectionPlan.parse("*:policy=log")
    eng = ServingEngine(cfg, [TenantSpec("a", p), TenantSpec("b", p)],
                        n_slots=1, max_prompt=4, max_new_tokens=1)
    assert len(eng.lanes) == 1
    assert eng.lanes[0].tenants == {"a", "b"}


def test_run_completes_all_requests_and_slots_drain(engine):
    engine.reset_state()
    stream = _stream(8, seed=1)
    tel = engine.run(stream)
    assert len(tel.requests) == 8
    assert {r.rid for r in tel.requests} == set(range(8))
    assert all(not r.aborted for r in tel.requests)
    for lane in engine.lanes:
        assert lane.batcher.occupancy() == 0
        lane.batcher.check_invariants()
    s = tel.summary()
    assert set(s["per_tenant"]) <= {"premium", "standard"}
    for t in s["per_tenant"].values():
        assert t["completed"] == t["requests"]
        assert np.isfinite(t["ttft_ms"]["p99"])
    # every request got exactly the tokens it asked for
    by_rid = {r.rid: r for r in stream}
    for r in tel.requests:
        assert r.tokens_out == by_rid[r.rid].max_new_tokens
        assert r.first_token_s is not None
        assert r.finish_s >= r.first_token_s >= r.arrival_s


def test_fault_report_merging_interleaved_prefill_decode_under_jit():
    """The engine telemetry path sums per-step op-keyed counters; one
    jitted program interleaving prefill + decode with merged reports must
    agree with that sum exactly."""
    cfg = reduce_cfg(get_arch("llama3.2-1b"))
    plan = ProtectionPlan.parse("*:policy=log,kv_cache:on")
    from repro.models.base import build_model
    from repro.sharding import values_of

    cache_len = 16
    model = build_model(cfg, max_pos=cache_len + 8)
    params = values_of(jax.jit(
        lambda k: model.init(k, quant=True))(jax.random.key(0)))
    prefill_p = protect(model.prefill, plan, compute_dtype=jnp.bfloat16)
    decode_p = protect(model.decode, plan, compute_dtype=jnp.bfloat16)
    batch = {"tokens": jnp.zeros((1, 4), jnp.int32)}
    pos0 = jnp.asarray([4], jnp.int32)

    @jax.jit
    def stepwise(params, batch):
        (logits, cache), r1 = prefill_p(params, batch,
                                        cache_len=cache_len)
        tok = jnp.argmax(logits[..., :cfg.vocab], -1).astype(jnp.int32)
        (l2, cache), r2 = decode_p(params, cache, tok, pos0)
        tok2 = jnp.argmax(l2[..., :cfg.vocab], -1).astype(jnp.int32)
        (l3, cache), r3 = decode_p(params, cache, tok2, pos0 + 1)
        return [r.as_metrics() for r in (r1, r2, r3)], \
            merge_reports(r1, r2, r3).as_metrics()

    per_step, merged = stepwise(params, batch)
    from repro.core.policy import op_kinds
    for kind in op_kinds():
        for col in ("checks", "errors"):
            key = f"abft/{kind}_{col}"
            assert int(merged[key]) == sum(int(m[key]) for m in per_step)
    assert int(merged["abft/qgemm_checks"]) > 0
    assert int(merged["abft/kv_cache_checks"]) > 0


def test_engine_step_counters_consistent_across_interleaving(engine):
    engine.reset_state()
    tel = engine.run(_stream(6, seed=2))
    decode_checks = {}
    for ev in tel.steps:
        assert ev.kind in ("prefill", "decode")
        assert ev.counters.get("qgemm_checks", 0) > 0
        if ev.kind == "decode":
            # per-lane decode programs are fixed — identical check counts
            decode_checks.setdefault(ev.lane, set()).add(
                ev.counters["qgemm_checks"])
    for lane, counts in decode_checks.items():
        assert len(counts) == 1, (lane, counts)
    totals = tel.fault_counters()
    assert totals["qgemm_checks"] == sum(
        ev.counters["qgemm_checks"] for ev in tel.steps)
    assert totals["qgemm_errors"] == 0


def test_transient_injection_detected_and_weight_restored(engine):
    engine.reset_state()
    before = [np.asarray(x).copy() for x in jax.tree.leaves(engine.params)]
    tel = engine.run(_stream(8, seed=3),
                     inject=[FaultInjection(step=2, victim="mlp.down",
                                            seed=0)])
    after = jax.tree.leaves(engine.params)
    for b, a in zip(before, after):
        np.testing.assert_array_equal(b, np.asarray(a))
    (inj,) = tel.summary()["faults"]["injections"]
    assert "mlp.down" in inj["victim"]
    assert inj["detected"] and inj["latency_steps"] == 0
    flagged = [ev.step for ev in tel.steps if ev.errors > 0]
    assert flagged and all(s == inj["step"] for s in flagged)


def test_persistent_injection_restored_only_at_reset(engine):
    engine.reset_state()
    before = [np.asarray(x).copy() for x in jax.tree.leaves(engine.params)]
    engine.run(_stream(4, seed=4),
               inject=[FaultInjection(step=1, victim="attn.wq", seed=1,
                                      persistent=True)])
    changed = any(
        not np.array_equal(b, np.asarray(a))
        for b, a in zip(before, jax.tree.leaves(engine.params)))
    assert changed
    engine.reset_state()
    for b, a in zip(before, jax.tree.leaves(engine.params)):
        np.testing.assert_array_equal(b, np.asarray(a))


def test_abort_policy_fails_requests_not_server():
    cfg = reduce_cfg(get_arch("llama3.2-1b"))
    eng = ServingEngine(cfg, [TenantSpec("t", ProtectionPlan.parse(
        "*:policy=abort", name="abortive"))], n_slots=2,
        max_prompt=MAX_PROMPT, max_new_tokens=MAX_NEW, seed=0)
    stream = chat_stream(6, tenants={"t": 1.0}, rate_rps=500.0, seed=5,
                         mean_prompt=6, max_prompt=MAX_PROMPT,
                         mean_output=3, max_output=MAX_NEW)
    tel = eng.run(stream, inject=[FaultInjection(step=2, victim="mlp.down",
                                                 seed=0)])
    recs = {r.rid: r for r in tel.requests}
    assert len(recs) == 6                    # the server survived
    assert any(r.aborted for r in tel.requests)
    assert any(not r.aborted for r in tel.requests)
    for lane in eng.lanes:
        assert lane.batcher.occupancy() == 0


def test_bounded_queue_sheds_load_into_telemetry():
    cfg = reduce_cfg(get_arch("llama3.2-1b"))
    eng = ServingEngine(cfg, [TenantSpec("t", ProtectionPlan.parse(
        "*:policy=log"))], n_slots=1, max_prompt=MAX_PROMPT,
        max_new_tokens=MAX_NEW, queue_depth=1, seed=0)
    # a hard burst: everyone arrives at t=0 into 1 slot + depth-1 queue
    stream = chat_stream(10, tenants={"t": 1.0}, rate_rps=1e6, seed=6,
                         mean_prompt=6, max_prompt=MAX_PROMPT,
                         mean_output=3, max_output=MAX_NEW)
    tel = eng.run(stream)
    assert len(tel.requests) == 10           # shed requests recorded too
    ts = tel.summary()["per_tenant"]["t"]
    assert ts["rejected"] > 0
    assert ts["completed"] + ts["rejected"] == 10
    # rejected requests carry no latency samples
    for r in tel.requests:
        if r.rejected:
            assert r.first_token_s is None and r.tokens_out == 0


def test_stacked_persistent_and_transient_injections_restore(engine):
    engine.reset_state()
    before = [np.asarray(x).copy() for x in jax.tree.leaves(engine.params)]
    engine.run(_stream(8, seed=7), inject=[
        FaultInjection(step=1, victim="mlp.down", seed=0,
                       persistent=True),
        FaultInjection(step=3, victim="mlp.down", seed=1),   # transient
    ])
    # the transient was restored, the persistent fault survives it
    changed = any(
        not np.array_equal(b, np.asarray(a))
        for b, a in zip(before, jax.tree.leaves(engine.params)))
    assert changed
    engine.reset_state()
    for b, a in zip(before, jax.tree.leaves(engine.params)):
        np.testing.assert_array_equal(b, np.asarray(a))


def test_unknown_tenant_rejected(engine):
    engine.reset_state()
    bad = chat_stream(1, tenants={"nosuch": 1.0}, rate_rps=1.0, seed=0)
    with pytest.raises(ValueError, match="unknown tenant"):
        engine.run(bad)
