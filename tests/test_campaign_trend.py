"""--trend: the longitudinal detection-quality gate over artifact series."""
import copy
import json
import os

import pytest

from repro.campaign.artifacts import load_artifact
from repro.campaign.trend import (default_baseline_paths, format_trend,
                                  load_history, run_trend, trend_gate)

BASELINE = os.path.join(os.path.dirname(os.path.dirname(__file__)),
                        "benchmarks", "baselines",
                        "BENCH_campaign_quick.json")


def _write(tmp_path, name, art):
    p = tmp_path / name
    p.write_text(json.dumps(art))
    return str(p)


@pytest.fixture()
def series(tmp_path):
    """Three-version history of the committed quick baseline."""
    art = load_artifact(BASELINE)
    return [_write(tmp_path, f"BENCH_campaign_quick_v{i}.json",
                   copy.deepcopy(art)) for i in range(3)], art


def test_pristine_series_exits_zero(series, tmp_path):
    paths, _ = series
    out = tmp_path / "hist.md"
    assert run_trend(paths, out_path=str(out), emit=lambda s: None) == 0
    md = out.read_text()
    assert "No trend regressions" in md
    assert "v0 det/fp" in md and "v2 det/fp" in md


def test_detection_drop_beyond_tol_gates_nonzero(series, tmp_path):
    paths, art = series
    bad = copy.deepcopy(art)
    cid = bad["cells"][0]["cell_id"]
    bad["cells"][0]["metrics"]["detection_rate"] -= 0.10
    paths[-1] = _write(tmp_path, "BENCH_campaign_quick_bad.json", bad)
    out = []
    assert run_trend(paths, emit=out.append) == 1
    assert "Trend regressions" in out[0] and cid in out[0]
    # the same drop inside tolerance passes
    ok = copy.deepcopy(art)
    ok["cells"][0]["metrics"]["detection_rate"] -= 0.01
    paths[-1] = _write(tmp_path, "BENCH_campaign_quick_ok.json", ok)
    assert run_trend(paths, emit=lambda s: None) == 0


def test_fp_rise_and_optin_latency_gate(series, tmp_path):
    paths, art = series
    bad = copy.deepcopy(art)
    bad["cells"][1]["metrics"]["fp_rate"] += 0.05
    paths[-1] = _write(tmp_path, "BENCH_campaign_quick_fp.json", bad)
    assert run_trend(paths, emit=lambda s: None) == 1

    slow = copy.deepcopy(art)
    over = [c for c in slow["cells"]
            if c["metrics"]["overhead"] is not None]
    assert over, "quick baseline has no overhead cells"
    over[0]["metrics"]["overhead"] += 0.50
    paths[-1] = _write(tmp_path, "BENCH_campaign_quick_slow.json", slow)
    # latency gate is opt-in: off by default, fires when enabled
    assert run_trend(paths, emit=lambda s: None) == 0
    assert run_trend(paths, latency_tol=0.10, emit=lambda s: None) == 1


def test_median_reference_absorbs_one_noisy_entry(series, tmp_path):
    """One bad HISTORICAL entry must not gate a healthy newest entry —
    the point of median-of-priors over pairwise diff."""
    paths, art = series
    noisy = copy.deepcopy(art)
    noisy["cells"][0]["metrics"]["detection_rate"] -= 0.30
    paths[1] = _write(tmp_path, "BENCH_campaign_quick_noisy.json", noisy)
    assert run_trend(paths, emit=lambda s: None) == 0


def test_vanished_cell_is_a_coverage_regression(series, tmp_path):
    paths, art = series
    pruned = copy.deepcopy(art)
    gone = pruned["cells"].pop(0)
    paths[-1] = _write(tmp_path, "BENCH_campaign_quick_pruned.json",
                       pruned)
    out = []
    assert run_trend(paths, emit=out.append) == 1
    assert "coverage" in out[0] and gone["cell_id"] in out[0]


def test_single_entry_cells_listed_not_gated(tmp_path):
    history = load_history([BASELINE])
    report = trend_gate(history)
    assert report["gated_cells"] == 0
    assert report["ungated_cells"] > 0
    assert report["regressions"] == []
    md = format_trend(history, report)
    assert "single" in md


def test_default_paths_resolve_committed_baselines():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    names = [os.path.basename(p) for p in default_baseline_paths(root)]
    assert "BENCH_campaign_quick.json" in names


def test_cli_trend_flag(series, tmp_path, capsys):
    from repro.campaign.__main__ import main

    paths, art = series
    assert main(["--trend", *paths]) == 0
    assert "Detection-quality trend" in capsys.readouterr().out
    bad = copy.deepcopy(art)
    bad["cells"][0]["metrics"]["detection_rate"] -= 0.10
    paths[-1] = _write(tmp_path, "BENCH_campaign_quick_cli.json", bad)
    out = tmp_path / "cli_hist.md"
    assert main(["--trend", *paths, "--trend-out", str(out)]) == 1
    assert "Trend regressions" in out.read_text()
