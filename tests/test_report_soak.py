"""FaultReport counter semantics under multi-step loops.

The multi-step campaign soak and the serving engine both thread
FaultReports through ``lax.scan`` / ``vmap`` bodies; these tests pin the
contract they rely on: counters are a monoid (merge is associative with
``empty_report`` as identity), they accumulate monotonically across scan
steps (never reset mid-soak), the pytree structure stays static under
tracing, and batch (vmap) dimensions sum cleanly.
"""
import jax
import jax.numpy as jnp

from repro.core.policy import (FaultReport, empty_report, merge_reports,
                               op_kinds, op_report)


def _step_report(errs):
    return op_report("qgemm", errs)


def test_scan_carry_accumulates_and_never_resets():
    """A soak body that merges each step's report into the carry: after N
    steps the totals are the exact per-step sums, and the running totals
    collected along the way are monotonically non-decreasing."""
    per_step = jnp.asarray([0, 2, 0, 1, 3, 0], jnp.int32)

    def body(carry, errs):
        merged = merge_reports(carry, _step_report(errs))
        return merged, merged.total_errors()

    final, running = jax.lax.scan(body, empty_report(), per_step)
    assert int(final.errors["qgemm"]) == int(per_step.sum())
    assert int(final.checks["qgemm"]) == per_step.shape[0]
    # never resets: running totals are a cumulative sum, not per-step
    assert list(map(int, running)) == list(
        map(int, jnp.cumsum(per_step)))
    assert all(b >= a for a, b in zip(running[:-1], running[1:]))


def test_scan_structure_static_across_kinds():
    """The carry built from empty_report() must match the body's merged
    reports structurally for EVERY registered kind — the scan/vmap safety
    rule in the policy module docstring."""
    def body(carry, x):
        rep = merge_reports(
            carry, op_report("embedding_bag", x),
            op_report("kv_cache", x * 2, retries=1))
        return rep, rep.total_errors()

    final, _ = jax.jit(
        lambda xs: jax.lax.scan(body, empty_report(), xs))(
            jnp.ones((5,), jnp.int32))
    assert sorted(final.errors) == sorted(op_kinds())
    assert int(final.errors["embedding_bag"]) == 5
    assert int(final.errors["kv_cache"]) == 10
    assert int(final.retries) == 5


def test_vmap_batched_reports_sum_to_scalar():
    """vmap over per-trial reports produces batched counters that reduce
    to the same totals as merging sequentially — the executor's chunked
    trial accounting in miniature."""
    errs = jnp.asarray([1, 0, 4, 2], jnp.int32)
    batched = jax.vmap(_step_report)(errs)
    assert batched.errors["qgemm"].shape == (4,)
    total = jax.tree.map(lambda x: jnp.sum(x, axis=0), batched)
    seq = merge_reports(*[_step_report(e) for e in errs])
    assert int(total.total_errors()) == int(seq.total_errors()) == 7
    assert int(total.checks["qgemm"]) == int(seq.checks["qgemm"]) == 4


def test_merge_is_monoid():
    a = op_report("qgemm", 2, retries=1)
    b = op_report("embedding_bag", 3)
    c = op_report("kv_cache", 1, corrections=2)

    def totals(r: FaultReport):
        return (int(r.total_errors()), int(r.total_checks()),
                int(r.retries), int(r.corrections))

    assert totals(merge_reports(merge_reports(a, b), c)) \
        == totals(merge_reports(a, merge_reports(b, c)))
    assert totals(merge_reports(a, empty_report())) == totals(
        merge_reports(a))


def test_loop_errors_in_counts_keyed_fractional_and_comm(tmp_path):
    """TrainLoop's detect->act trigger: keyed counters beat legacy
    aliases (no double count), comm/errors is included, and the
    microbatch-AVERAGED fractions a grad-accum step emits (one error over
    accum=4 arrives as 0.25) still trip the policy instead of truncating
    to zero."""
    from repro.runtime import LoopConfig, TrainLoop

    loop = TrainLoop(lambda s, b: (s, {}), None,
                     cfg=LoopConfig(ckpt_dir=str(tmp_path)))
    # keyed + legacy aliases together (FaultReport.as_metrics emits both):
    # only the keyed set is summed
    assert loop._errors_in({"abft/qgemm_errors": 2,
                            "abft/float_gemm_errors": 1,
                            "abft/gemm_errors": 3,       # alias of the two
                            "abft/kv_cache_errors": 1,
                            "comm/errors": 1}) == 5
    # legacy-only metrics (pre-protect step fns) still work
    assert loop._errors_in({"abft/gemm_errors": 2}) == 2
    # grad-accum averaging: 1 error / accum 4 -> 0.25 -> must still fire
    assert loop._errors_in(
        {"abft/qgemm_errors": jnp.asarray(0.25)}) == 1
    assert loop._errors_in({"abft/qgemm_errors": 0,
                            "comm/errors": 0}) == 0


def test_scan_of_vmap_soak_counters():
    """The full multi-step shape: scan over steps of a vmapped batch of
    op calls — counters merge across both axes without resetting."""
    def step(carry, errs_batch):
        batched = jax.vmap(_step_report)(errs_batch)
        step_rep = jax.tree.map(lambda x: jnp.sum(x, axis=0), batched)
        merged = merge_reports(carry, step_rep)
        return merged, merged.total_errors()

    errs = jnp.arange(12, dtype=jnp.int32).reshape(4, 3)   # [steps, batch]
    final, running = jax.lax.scan(step, empty_report(), errs)
    assert int(final.total_errors()) == int(errs.sum())
    assert list(map(int, running)) == list(
        map(int, jnp.cumsum(errs.sum(axis=1))))
