"""Tests: data pipeline, checkpoints (incl. corruption), compression,
straggler monitor, elastic planning, and the fault-tolerant loop."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from helpers import given, settings, st

from repro.checkpoint import (CheckpointCorruption, CheckpointManager,
                              latest_step, load_checkpoint, save_checkpoint)
from repro.configs.base import ShapeConfig
from repro.configs.registry import get_arch
from repro.data import DataConfig, make_dataset
from repro.runtime import (LoopConfig, StragglerMonitor, TrainLoop,
                           init_compression, plan_remesh)
from repro.runtime.compression import (MOD, _mod_checksum, compress_grads,
                                       decompress_grads, verify_payload)


# ------------------------------- data ---------------------------------------

def test_lm_dataset_deterministic_and_shifted():
    ds = make_dataset(get_arch("llama3.2-1b"), ShapeConfig("t", "train", 64, 4))
    b0a, b0b, b1 = ds.batch_at(0), ds.batch_at(0), ds.batch_at(1)
    np.testing.assert_array_equal(b0a["tokens"], b0b["tokens"])
    assert not np.array_equal(b0a["tokens"], b1["tokens"])
    # labels are tokens shifted by one (same underlying stream)
    assert b0a["tokens"].shape == b0a["labels"].shape == (4, 64)
    np.testing.assert_array_equal(b0a["tokens"][:, 1:], b0a["labels"][:, :-1])


def test_vlm_encdec_dataset_shapes():
    vlm = make_dataset(get_arch("llava-next-mistral-7b"),
                       ShapeConfig("t", "train", 4096, 2))
    b = vlm.batch_at(3)
    cfg = get_arch("llava-next-mistral-7b")
    assert b["patches"].shape == (2, cfg.n_patches, cfg.patch_dim)
    assert b["tokens"].shape == (2, 4096 - cfg.n_patches)

    wh = make_dataset(get_arch("whisper-large-v3"),
                      ShapeConfig("t", "train", 64, 2))
    bw = wh.batch_at(0)
    assert bw["frames"].shape[1] == get_arch("whisper-large-v3").enc_seq


def test_dlrm_dataset_padding():
    ds = make_dataset(get_arch("dlrm"), ShapeConfig("t", "train", 1, 8))
    b = ds.batch_at(0, table_rows=500)
    assert b["bags"].shape == (26, 8, 128)
    assert (b["bags"] >= -1).all() and (b["bags"] < 500).all()
    # every bag has >= 1 valid index
    assert ((b["bags"] >= 0).sum(axis=-1) >= 1).all()


# ----------------------------- checkpoint ------------------------------------

def _state():
    return {"params": {"w": jnp.arange(24.0).reshape(4, 6),
                       "b": jnp.ones((6,), jnp.bfloat16)},
            "step": jnp.zeros((), jnp.int32)}


def test_ckpt_roundtrip_and_resume(tmp_path):
    base = str(tmp_path / "ck")
    st_ = _state()
    save_checkpoint(base, 5, st_)
    assert latest_step(base) == 5
    back = load_checkpoint(base, 5, jax.device_get(st_))
    np.testing.assert_allclose(np.asarray(back["params"]["w"]),
                               np.asarray(st_["params"]["w"]))
    assert back["params"]["b"].dtype == np.asarray(st_["params"]["b"]).dtype


def test_ckpt_detects_corruption_and_falls_back(tmp_path):
    base = str(tmp_path / "ck")
    st_ = _state()
    save_checkpoint(base, 1, st_)
    save_checkpoint(base, 2, st_)
    # flip a byte in the newest shard (silent data corruption in storage)
    shard = os.path.join(base, "step_000000002", "shard_00000.npz")
    data = bytearray(open(shard, "rb").read())
    data[len(data) // 2] ^= 0x40
    open(shard, "wb").write(bytes(data))
    with pytest.raises((CheckpointCorruption, Exception)):
        load_checkpoint(base, 2, jax.device_get(st_))
    mgr = CheckpointManager(base)
    restored, step = mgr.restore_latest(jax.device_get(st_))
    assert step == 1  # fell back past the corrupt step


def test_ckpt_torn_write_ignored(tmp_path):
    base = str(tmp_path / "ck")
    st_ = _state()
    save_checkpoint(base, 1, st_)
    # simulate a crash mid-save: step dir without COMMIT
    os.makedirs(os.path.join(base, "step_000000009"))
    assert latest_step(base) == 1


def test_ckpt_manager_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2, save_every=1)
    st_ = _state()
    for s in range(1, 6):
        mgr.maybe_save(s, st_)
    mgr.wait()
    mgr._gc()
    steps = sorted(n for n in os.listdir(str(tmp_path))
                   if n.startswith("step_") and not n.endswith(".tmp"))
    assert steps == ["step_000000004", "step_000000005"]


# ----------------------------- compression -----------------------------------

def test_mod_checksum_additivity():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(-127, 128, (1000,), dtype=np.int32))
    b = jnp.asarray(rng.integers(-127, 128, (1000,), dtype=np.int32))
    lhs = int(_mod_checksum(a + b))
    rhs = (int(_mod_checksum(a)) + int(_mod_checksum(b))) % MOD
    assert lhs == rhs


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 2), st.integers(1, 4096))
def test_mod_checksum_matches_bigint(seed, n):
    rng = np.random.default_rng(seed)
    x = rng.integers(-(2 ** 20), 2 ** 20, (n,), dtype=np.int32)
    expect = int(sum(int(v) % MOD for v in x) % MOD)
    assert int(_mod_checksum(jnp.asarray(x))) == expect


def test_compress_error_feedback_converges():
    """With error feedback the quantization error does not accumulate:
    averaging compressed grads over steps approaches the true mean."""
    g = {"w": jnp.asarray(np.random.default_rng(1).standard_normal((64,)),
                          jnp.float32)}
    state = init_compression(g)
    acc = np.zeros((64,))
    steps = 50
    for _ in range(steps):
        payload, state = compress_grads(g, state)
        deq = np.asarray(payload["q"]["w"], np.float32) \
            * float(payload["scale"]["w"])
        acc += deq
    mean = acc / steps
    np.testing.assert_allclose(mean, np.asarray(g["w"]), atol=2e-2)


def test_verify_payload_detects_flip():
    g = {"w": jnp.ones((32,), jnp.float32)}
    payload, _ = compress_grads(g, init_compression(g))
    assert int(verify_payload(payload)) == 0
    bad = dict(payload)
    q = np.asarray(payload["q"]["w"]).copy()
    q[3] ^= 0x10   # bit flip in transported payload
    bad["q"] = {"w": jnp.asarray(q)}
    assert int(verify_payload(bad)) == 1


def test_checked_psum_multidevice_subprocess():
    """checked_psum under shard_map on 4 host devices (subprocess sets
    XLA_FLAGS before jax init)."""
    import subprocess, sys, textwrap
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.sharding import shard_map
        from repro.runtime.compression import (compress_grads,
            init_compression, checked_psum, decompress_grads)
        mesh = Mesh(np.asarray(jax.devices()).reshape(4), ("data",))
        gs = jnp.stack([jnp.full((8,), float(i + 1)) for i in range(4)])

        @partial(shard_map, mesh=mesh, in_specs=P("data"),
                 out_specs=(P(), P()))
        def reduce(g_shard):
            g = {"w": g_shard[0]}
            payload, _ = compress_grads(g, init_compression(g))
            summed, ssum, errs = checked_psum(payload, "data")
            mean = decompress_grads(summed, ssum, 4)
            return mean["w"], errs
        mean, errs = reduce(gs)
        np.testing.assert_allclose(np.asarray(mean), 2.5, atol=0.05)
        assert int(errs) == 0
        print("OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={**os.environ, "PYTHONPATH": "src"},
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "OK" in r.stdout, r.stderr[-2000:]


# ----------------------------- straggler -------------------------------------

def test_straggler_monitor_flags_slow_steps():
    mon = StragglerMonitor(window=50, threshold=2.0, patience=2)
    for i in range(20):
        assert mon.observe(i, 1.0) is None
    ev = mon.observe(20, 3.0)
    assert ev is not None and ev["ratio"] == pytest.approx(3.0)
    fired = []
    mon.on_straggler = fired.append
    mon.observe(21, 3.0)
    assert fired and fired[0]["consecutive"] == 2


def test_straggler_host_attribution():
    mon = StragglerMonitor(window=50, threshold=2.0)
    for i in range(20):
        mon.observe(i, 1.0)
    ev = mon.observe(20, 5.0,
                     host_times={0: 1.0, 1: 1.1, 2: 5.0, 3: 0.9})
    assert ev["slow_hosts"] == [2]


# ------------------------------ elastic --------------------------------------

def test_plan_remesh_shrinks_data_axis():
    plan = plan_remesh(512, model_parallel=16)
    assert plan.new_shape == (32, 16)
    plan2 = plan_remesh(500, model_parallel=16)   # 12 hosts died
    assert plan2.new_shape == (31, 16) and plan2.dropped_devices == 4
    with pytest.raises(ValueError):
        plan_remesh(8, model_parallel=16)


# ------------------------------- loop ----------------------------------------

def test_train_loop_runs_resumes_and_recomputes(tmp_path):
    """Tiny quadratic 'model'; a fault injected via metrics at one step
    triggers recompute; crash-restart resumes from checkpoint."""
    calls = {"n": 0}

    def step_fn(state, batch):
        calls["n"] += 1
        w = state["w"] - 0.1 * (state["w"] - batch["x"].mean())
        # simulated detected soft error at exactly one (step, first try)
        faulty = (int(state["step"]) == 3 and calls.setdefault("f", 0) == 0)
        if faulty:
            calls["f"] = 1
        m = {"abft/gemm_errors": jnp.asarray(1 if faulty else 0, jnp.int32),
             "loss": jnp.mean((w - batch["x"].mean()) ** 2)}
        return {"w": w, "step": state["step"] + 1}, m

    class DS:
        def batch_at(self, step):
            rng = np.random.default_rng(step)
            return {"x": jnp.asarray(rng.standard_normal(8), jnp.float32)}

    cfg = LoopConfig(ckpt_dir=str(tmp_path / "ck"), save_every=2,
                     fault_policy="recompute", log_every=100)
    loop = TrainLoop(step_fn, DS(), cfg=cfg)
    state0 = {"w": jnp.zeros(()), "step": jnp.zeros((), jnp.int32)}
    state, _ = loop.run(state0, 6)
    assert int(state["step"]) == 6
    assert loop.stats["recomputes"] == 1 and loop.stats["faulty_steps"] == 1

    # "crash": new loop resumes from committed step 6, runs to 8
    loop2 = TrainLoop(step_fn, DS(), cfg=cfg)
    state2, _ = loop2.run(state0, 8)
    assert int(state2["step"]) == 8
