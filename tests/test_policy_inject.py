"""Fault report plumbing, policies, injection utilities, checksums."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import abft_gemm as ag
from repro.core import policy
from repro.core.checksum import tensor_checksum, tree_checksum, verify_tree
from repro.core.inject import (bit_band, flip_bit, random_bitflip,
                               random_bitflip_band, random_bitflips,
                               random_value)


def test_flip_bit_int8_roundtrip():
    x = jnp.asarray([1, -5, 100], jnp.int8)
    y = flip_bit(x, jnp.asarray(1), jnp.asarray(3))
    z = flip_bit(y, jnp.asarray(1), jnp.asarray(3))
    np.testing.assert_array_equal(np.asarray(z), np.asarray(x))
    assert int(y[1]) != -5


def test_flip_bit_f32():
    x = jnp.asarray([1.0, 2.0], jnp.float32)
    y = flip_bit(x, jnp.asarray(0), jnp.asarray(31))  # sign bit
    assert float(y[0]) == -1.0


def test_random_bitflip_changes_exactly_one_element():
    x = jnp.zeros((64,), jnp.int32)
    y = random_bitflip(jax.random.PRNGKey(0), x)
    assert int((y != x).sum()) == 1
    # the change is a power of two (single-bit model)
    delta = abs(int(np.asarray(y - x).sum()))
    assert delta & (delta - 1) == 0


def test_random_value_changes_at_most_one():
    x = jnp.zeros((32,), jnp.int8)
    y = random_value(jax.random.PRNGKey(1), x)
    assert int((y != x).sum()) <= 1


def test_report_merge_and_metrics():
    r1 = policy.gemm_report(jnp.asarray(2, jnp.int32))
    r2 = policy.eb_report(jnp.asarray(1, jnp.int32))
    m = policy.merge_reports(r1, r2, policy.empty_report())
    assert int(m.total_errors()) == 3
    assert int(m.as_metrics()["abft/gemm_checks"]) == 1


def test_report_is_pytree_scannable():
    def body(carry, _):
        return policy.merge_reports(carry, policy.gemm_report(
            jnp.asarray(1, jnp.int32))), None

    final, _ = jax.lax.scan(body, policy.empty_report(), jnp.arange(5))
    assert int(final.gemm_errors) == 5


def test_with_recompute_counts_retry():
    calls = {"n": 0}

    def op():
        calls["n"] += 1
        return jnp.zeros((2,)), jnp.asarray(1, jnp.int32)  # always "errors"

    out, err, retries = policy.with_recompute(op)()
    assert int(retries) == 1


def test_with_recompute_clean_op_never_retries():
    def op():
        return jnp.ones((3,)), jnp.asarray(0, jnp.int32)

    out, err, retries = policy.with_recompute(op, max_retries=3)()
    assert int(retries) == 0 and int(err) == 0


def test_with_recompute_max_retries_accounting():
    def op():
        return jnp.zeros((2,)), jnp.asarray(2, jnp.int32)  # persistent

    out, err, retries = policy.with_recompute(op, max_retries=3)()
    assert int(retries) == 3          # every round re-fires and is counted
    assert int(err) == 2              # deterministic sim: error persists


# ------------------------- bit bands / multi-flip ----------------------------

def test_bit_band_lookup_and_fallback():
    assert bit_band(jnp.int8, "significant") == (4, 8)
    assert bit_band(jnp.float32, "exponent") == (23, 31)
    assert bit_band(jnp.int16, "all") == (0, 16)        # fallback dtype
    assert bit_band(jnp.int16, "low") == (0, 8)
    with pytest.raises(KeyError):
        bit_band(jnp.int16, "exponent")


def test_random_bitflip_band_respects_band():
    x = jnp.zeros((128,), jnp.int8)
    for i in range(20):
        y = random_bitflip_band(jax.random.key(i), x, "significant")
        delta = abs(int(np.asarray(y, np.int32).sum()))
        # magnitudes of upper-nibble flips: 16/32/64/128
        assert delta in (16, 32, 64, 128)


def test_random_bitflips_changes_exactly_n_distinct_elements():
    x = jnp.zeros((256,), jnp.int8)
    for n in (1, 4, 9):
        y = random_bitflips(jax.random.key(n), x, n)
        assert int((y != x).sum()) == n


def test_random_bitflips_vmaps():
    x = jnp.zeros((64,), jnp.int32)
    keys = jax.random.split(jax.random.key(0), 50)
    ys = jax.vmap(lambda k: random_bitflips(k, x, 2))(keys)
    assert np.all(np.asarray((ys != x[None]).sum(axis=-1)) == 2)


def test_random_bitflips_rejects_zero():
    with pytest.raises(ValueError):
        random_bitflips(jax.random.key(0), jnp.zeros((4,), jnp.int8), 0)


# --------------------- correction + policy registry --------------------------

def _gemm_fixture():
    ka, kb = jax.random.split(jax.random.key(0))
    a = jax.random.randint(ka, (8, 32), 0, 256, jnp.uint8)
    b = jax.random.randint(kb, (32, 16), -127, 128, jnp.int8)
    c = jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.int32)
    check_col = jax.lax.dot_general(
        a, ag.encode_weight_checksum(b), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    col_check = jax.lax.dot_general(
        ag.encode_activation_checksum(a), b.astype(jnp.int32),
        (((0,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    return a, b, c, check_col, col_check


def test_correct_single_error_repairs_exactly():
    _, _, c, check_col, col_check = _gemm_fixture()
    c_bad = c.at[3, 7].add(-4321)
    err_rows, err = ag.verify_rows(c_bad, check_col)
    assert int(err) == 1
    fixed, applied = ag.correct_single_error(c_bad, err_rows, col_check)
    assert bool(applied)
    np.testing.assert_array_equal(np.asarray(fixed), np.asarray(c))


def test_correct_single_error_leaves_multi_error_alone():
    _, _, c, check_col, col_check = _gemm_fixture()
    c_bad = c.at[1, 2].add(7).at[5, 9].add(-99)
    err_rows, _ = ag.verify_rows(c_bad, check_col)
    fixed, applied = ag.correct_single_error(c_bad, err_rows, col_check)
    assert not bool(applied)
    np.testing.assert_array_equal(np.asarray(fixed), np.asarray(c_bad))


def test_policy_correct_wrapper_and_registry():
    _, _, c, check_col, col_check = _gemm_fixture()
    c_bad = c.at[2, 4].add(1 << 20)
    err_rows, err = ag.verify_rows(c_bad, check_col)

    def op():
        return c_bad, err_rows, err, col_check

    fixed, residual, corrections = policy.apply_policy("correct", op)()
    np.testing.assert_array_equal(np.asarray(fixed), np.asarray(c))
    assert int(residual) == 0 and int(corrections) == 1
    # jit-safe
    fixed_j, _, _ = jax.jit(policy.POLICIES["correct"](op))()
    np.testing.assert_array_equal(np.asarray(fixed_j), np.asarray(c))


def test_policy_log_and_unknown_name():
    def op():
        return jnp.ones((2,)), jnp.asarray(0, jnp.int32)

    out, err, retries = policy.apply_policy("log", op)()
    assert int(retries) == 0
    with pytest.raises(KeyError):
        policy.apply_policy("sacrifice", op)
    assert set(policy.POLICIES) == {"log", "recompute", "correct", "abort"}


def test_policy_abort_raises_on_error():
    def bad_op():
        return jnp.ones((2,)), jnp.asarray(3, jnp.int32)

    with pytest.raises(policy.FaultAbort, match="3 corrupted"):
        policy.apply_policy("abort", bad_op)()

    def clean_op():
        return jnp.ones((2,)), jnp.asarray(0, jnp.int32)

    out, err, _ = policy.apply_policy("abort", clean_op)()
    assert int(err) == 0


def test_policy_abort_jitted_caught_via_is_fault_abort():
    def bad_op():
        return jnp.ones((2,)), jnp.asarray(1, jnp.int32)

    wrapped = jax.jit(policy.apply_policy("abort", bad_op))
    try:
        jax.block_until_ready(wrapped())
        raised = None
    except Exception as e:           # jit wraps it in XlaRuntimeError
        raised = e
    assert raised is not None and policy.is_fault_abort(raised)
    assert not policy.is_fault_abort(ValueError("unrelated"))


def test_tensor_checksum_detects_flip():
    x = jnp.asarray(np.arange(100, dtype=np.float32))
    before = int(tensor_checksum(x))
    y = flip_bit(x, jnp.asarray(7), jnp.asarray(13))
    assert int(tensor_checksum(y)) != before


def test_tree_checksum_verify():
    tree = {"w": jnp.ones((4, 4)), "b": jnp.arange(3, dtype=jnp.int32)}
    cs = tree_checksum(tree)
    assert verify_tree(tree, cs)
    bad = {"w": tree["w"].at[0, 0].set(2.0), "b": tree["b"]}
    assert not verify_tree(bad, cs)
