"""Fault report plumbing, policies, injection utilities, checksums."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import policy
from repro.core.checksum import tensor_checksum, tree_checksum, verify_tree
from repro.core.inject import flip_bit, random_bitflip, random_value


def test_flip_bit_int8_roundtrip():
    x = jnp.asarray([1, -5, 100], jnp.int8)
    y = flip_bit(x, jnp.asarray(1), jnp.asarray(3))
    z = flip_bit(y, jnp.asarray(1), jnp.asarray(3))
    np.testing.assert_array_equal(np.asarray(z), np.asarray(x))
    assert int(y[1]) != -5


def test_flip_bit_f32():
    x = jnp.asarray([1.0, 2.0], jnp.float32)
    y = flip_bit(x, jnp.asarray(0), jnp.asarray(31))  # sign bit
    assert float(y[0]) == -1.0


def test_random_bitflip_changes_exactly_one_element():
    x = jnp.zeros((64,), jnp.int32)
    y = random_bitflip(jax.random.PRNGKey(0), x)
    assert int((y != x).sum()) == 1
    # the change is a power of two (single-bit model)
    delta = abs(int(np.asarray(y - x).sum()))
    assert delta & (delta - 1) == 0


def test_random_value_changes_at_most_one():
    x = jnp.zeros((32,), jnp.int8)
    y = random_value(jax.random.PRNGKey(1), x)
    assert int((y != x).sum()) <= 1


def test_report_merge_and_metrics():
    r1 = policy.gemm_report(jnp.asarray(2, jnp.int32))
    r2 = policy.eb_report(jnp.asarray(1, jnp.int32))
    m = policy.merge_reports(r1, r2, policy.empty_report())
    assert int(m.total_errors()) == 3
    assert int(m.as_metrics()["abft/gemm_checks"]) == 1


def test_report_is_pytree_scannable():
    def body(carry, _):
        return policy.merge_reports(carry, policy.gemm_report(
            jnp.asarray(1, jnp.int32))), None

    final, _ = jax.lax.scan(body, policy.empty_report(), jnp.arange(5))
    assert int(final.gemm_errors) == 5


def test_with_recompute_counts_retry():
    calls = {"n": 0}

    def op():
        calls["n"] += 1
        return jnp.zeros((2,)), jnp.asarray(1, jnp.int32)  # always "errors"

    out, err, retries = policy.with_recompute(op)()
    assert int(retries) == 1


def test_tensor_checksum_detects_flip():
    x = jnp.asarray(np.arange(100, dtype=np.float32))
    before = int(tensor_checksum(x))
    y = flip_bit(x, jnp.asarray(7), jnp.asarray(13))
    assert int(tensor_checksum(y)) != before


def test_tree_checksum_verify():
    tree = {"w": jnp.ones((4, 4)), "b": jnp.arange(3, dtype=jnp.int32)}
    cs = tree_checksum(tree)
    assert verify_tree(tree, cs)
    bad = {"w": tree["w"].at[0, 0].set(2.0), "b": tree["b"]}
    assert not verify_tree(bad, cs)
